# Allow `pytest python/tests/` from the repo root: make the `compile`
# package importable the same way `cd python && pytest tests/` does.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
