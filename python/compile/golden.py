"""Export golden vectors for the Rust reference implementation.

Small, deterministic GEMM cases per precision whose expected outputs come
from the pytest-validated oracle (`kernels.ref`). The Rust test suite
(`rust/tests/golden.rs`) replays them through `gemm::refimpl` and the
functional executor, closing the loop between the two reference
implementations (DESIGN.md §6, step 2).

Run as `python -m compile.golden --out ../artifacts/golden.json`.
"""

from __future__ import annotations

import argparse
import json

import numpy as np
import jax.numpy as jnp

from .kernels import ref

CASES = [
    # (precision, m, k, n, seed, extreme)
    ("i8i8", 8, 16, 8, 11, False),
    ("i8i8", 12, 64, 8, 12, True),  # saturating
    ("i8i16", 8, 16, 8, 13, False),
    ("i8i16", 4, 256, 8, 14, True),  # saturating past int16
    ("i8i32", 8, 24, 12, 15, True),
    ("bf16", 8, 16, 8, 16, False),
]


def f32_bits(x: np.ndarray) -> list:
    return np.asarray(x, np.float32).reshape(-1).view(np.uint32).tolist()


def make_case(prec, m, k, n, seed, extreme):
    rng = np.random.default_rng(seed)
    if prec == "bf16":
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.bfloat16)
    else:
        lo, hi = (-128, 128) if extreme else (-16, 16)
        a = jnp.asarray(rng.integers(lo, hi, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(lo, hi, (k, n)), jnp.int8)
    out = ref.ref_gemm(a, b, prec)
    acc = ref.ref_gemm_acc(a, b, prec)
    case = {"precision": prec, "m": m, "k": k, "n": n}
    if prec == "bf16":
        # bf16 values are exactly representable in f32: ship bit patterns.
        case["a_f32bits"] = f32_bits(a)
        case["b_f32bits"] = f32_bits(b)
        case["out_f32bits"] = f32_bits(out)
        case["acc_f32bits"] = f32_bits(acc)
    else:
        case["a"] = np.asarray(a, np.int64).reshape(-1).tolist()
        case["b"] = np.asarray(b, np.int64).reshape(-1).tolist()
        case["out"] = np.asarray(out, np.int64).reshape(-1).tolist()
        case["acc"] = np.asarray(acc, np.int64).reshape(-1).tolist()
    return case


def build():
    return [make_case(*c) for c in CASES]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/golden.json")
    args = ap.parse_args()
    with open(args.out, "w") as f:
        json.dump(build(), f)
    print(f"wrote {len(CASES)} golden cases to {args.out}")


if __name__ == "__main__":
    main()
