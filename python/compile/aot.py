"""AOT pipeline: lower the Layer-2 model to HLO *text* artifacts + manifest.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (behind
the Rust `xla` crate) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Interface-dtype convention (what the Rust runtime feeds/receives):

* int8 precisions: A/B as s8 literals, accumulator in/out as s32 — all
  natively supported by the `xla` crate.
* bf16: the Rust side has no bf16 literal type, so artifact boundaries are
  f32 and the graph converts f32 -> bf16 at entry (and accumulates in f32),
  preserving bf16 *compute* numerics while keeping marshalling simple.

Artifacts (one HLO module each) per (generation, precision, B layout):
`step_<gen>_<prec>_<layout>` — the native GEMM step (Sec. 4.2.2) the
coordinator chains at runtime. Plus `quickstart_bf16` (one full small GEMM)
and `mlp_bf16` (two chained GEMMs), used by the examples.

Run via `make artifacts`; a no-op when outputs are newer than inputs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import BALANCED, GENERATIONS, PRECISIONS
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _wrap_bf16(fn, n_inputs):
    """f32 interface around a bf16-computing function."""

    def wrapped(*args):
        conv = [a.astype(jnp.bfloat16) for a in args[:n_inputs]]
        rest = [a for a in args[n_inputs:]]  # accumulators stay f32
        out = fn(*conv, *rest)
        return out.astype(jnp.float32)

    return wrapped


def native_step_entry(gen: str, prec: str, b_col_major: bool):
    """Build (fn, arg_specs, io description) for one native-step artifact."""
    cfg = BALANCED[(gen, prec)]
    step = model.make_native_step(cfg, b_col_major)
    m, k, n = cfg.native_m, cfg.k_mt, cfg.native_n
    b_shape = (n, k) if b_col_major else (k, n)
    adt = ref.acc_dtype(prec)

    if prec == "bf16":
        fn = _wrap_bf16(lambda a, b, acc: step(a, b, acc), 2)
        specs = [_spec((m, k), jnp.float32), _spec(b_shape, jnp.float32),
                 _spec((m, n), jnp.float32)]
        iface = ["f32", "f32", "f32"]
        out = "f32"
    else:
        fn = step
        specs = [_spec((m, k), jnp.int8), _spec(b_shape, jnp.int8),
                 _spec((m, n), adt)]
        iface = ["s8", "s8", "s32"]
        out = "s32"

    layout = "colmajor" if b_col_major else "rowmajor"
    name = f"step_{gen}_{prec}_{layout}"
    meta = {
        "name": name,
        "kind": "native_step",
        "gen": gen,
        "precision": prec,
        "b_col_major": b_col_major,
        "m": m,
        "k": k,
        "n": n,
        "arg_shapes": [list(s.shape) for s in specs],
        "arg_dtypes": iface,
        "out_dtype": out,
        "config": {
            "m_ct": cfg.m_ct, "k_ct": cfg.k_ct, "n_ct": cfg.n_ct,
            "k_mt": cfg.k_mt, "m_rows": cfg.m_rows, "n_cols": cfg.n_cols,
            "micro_tile": list(cfg.micro_tile),
        },
    }
    return fn, specs, meta


def quickstart_entry():
    """One full small bf16 GEMM (XDNA config): 384 x 448 x 384."""
    cfg = BALANCED[("xdna", "bf16")]
    m, k, n = cfg.native_m, 2 * cfg.k_mt, cfg.native_n
    gemm = model.make_gemm(cfg, m, k, n)
    fn = _wrap_bf16(lambda a, b: gemm(a, b), 2)
    specs = [_spec((m, k), jnp.float32), _spec((k, n), jnp.float32)]
    meta = {
        "name": "quickstart_bf16", "kind": "gemm", "gen": "xdna",
        "precision": "bf16", "b_col_major": False, "m": m, "k": k, "n": n,
        "arg_shapes": [list(s.shape) for s in specs],
        "arg_dtypes": ["f32", "f32"], "out_dtype": "f32",
    }
    return fn, specs, meta


def mlp_entry():
    """Two chained bf16 GEMMs (the DL-integration demo).

    Uses a dedicated config (96x48x96 kernel, Table 2's second-ranked bf16
    shape, with k_mt = 96) so the hidden dimension is aligned both as a GEMM
    output (multiple of native_n) and as the next GEMM's reduction dim
    (multiple of k_mt) without padding.
    """
    from .configs import NpuConfig

    cfg = NpuConfig("xdna", "bf16", 96, 48, 96, 96, 4, 4)
    m, d_in, d_h, d_out = cfg.native_m, cfg.native_n, cfg.native_n, cfg.native_n
    mlp = model.make_mlp(cfg, m, d_in, d_h, d_out)
    fn = _wrap_bf16(lambda x, w1, w2: mlp(x, w1, w2), 3)
    specs = [_spec((m, d_in), jnp.float32), _spec((d_in, d_h), jnp.float32),
             _spec((d_h, d_out), jnp.float32)]
    meta = {
        "name": "mlp_bf16", "kind": "mlp", "gen": "xdna", "precision": "bf16",
        "b_col_major": False, "m": m, "k": d_in, "n": d_out,
        "arg_shapes": [list(s.shape) for s in specs],
        "arg_dtypes": ["f32", "f32", "f32"], "out_dtype": "f32",
        "d_hidden": d_h,
    }
    return fn, specs, meta


def build_entries(only=None):
    entries = []
    for gen in GENERATIONS:
        for prec in PRECISIONS:
            for bcm in (False, True):
                entries.append(native_step_entry(gen, prec, bcm))
    entries.append(quickstart_entry())
    entries.append(mlp_entry())
    if only:
        entries = [e for e in entries if only in e[2]["name"]]
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for fn, specs, meta in build_entries(args.only):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = meta["name"] + ".hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        meta["file"] = fname
        manifest.append(meta)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
