"""Balanced GEMM configurations (paper Tables 2 & 3, bold rows).

This is the Python mirror of `rust/src/arch` — the AOT pipeline uses it to
decide which native-step artifacts to emit; the Rust coordinator reads the
same numbers from its own `arch::balanced_config` table plus the generated
`artifacts/manifest.json`. Keep the two in sync (checked by
`rust/tests/manifest.rs`).
"""

from __future__ import annotations

from dataclasses import dataclass

from .kernels.ref import MICRO_TILE


@dataclass(frozen=True)
class NpuConfig:
    """One (generation, precision) balanced design point."""

    gen: str  # "xdna" | "xdna2"
    precision: str  # key into ref.PRECISIONS
    m_ct: int
    k_ct: int
    n_ct: int
    k_mt: int  # contiguity parameter (Sec. 4.2.2)
    m_rows: int
    n_cols: int

    @property
    def micro_tile(self):
        return MICRO_TILE[self.precision]

    @property
    def native_m(self) -> int:
        return self.m_ct * self.m_rows

    @property
    def native_n(self) -> int:
        return self.n_ct * self.n_cols

    @property
    def native_k(self) -> int:
        return self.k_mt

    def __post_init__(self):
        r, s, t = MICRO_TILE[self.precision]
        assert self.m_ct % r == 0 and self.k_ct % s == 0 and self.n_ct % t == 0
        assert self.k_mt % self.k_ct == 0, "k_mt must hold whole k_ct tiles"


#: Optimal balanced kernels (bold rows of Tables 2 and 3) + the paper's
#: chosen k_mt values (Sec. 5.2.2). XDNA maps 4x4 (no ShimTile in the last
#: column), XDNA2 maps the full 4x8 array.
BALANCED = {
    ("xdna", "i8i8"): NpuConfig("xdna", "i8i8", 112, 112, 112, 448, 4, 4),
    ("xdna", "i8i16"): NpuConfig("xdna", "i8i16", 96, 112, 96, 448, 4, 4),
    ("xdna", "i8i32"): NpuConfig("xdna", "i8i32", 80, 88, 96, 352, 4, 4),
    ("xdna", "bf16"): NpuConfig("xdna", "bf16", 96, 56, 96, 224, 4, 4),
    ("xdna2", "i8i8"): NpuConfig("xdna2", "i8i8", 144, 72, 144, 432, 4, 8),
    ("xdna2", "i8i16"): NpuConfig("xdna2", "i8i16", 128, 72, 112, 432, 4, 8),
    ("xdna2", "i8i32"): NpuConfig("xdna2", "i8i32", 96, 64, 96, 384, 4, 8),
    ("xdna2", "bf16"): NpuConfig("xdna2", "bf16", 112, 48, 96, 384, 4, 8),
}

GENERATIONS = ("xdna", "xdna2")
PRECISIONS = ("i8i8", "i8i16", "i8i32", "bf16")
