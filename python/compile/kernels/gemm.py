"""Layer-1 Pallas GEMM kernel: the paper's single-core compute hot-spot.

The paper's AIE kernel computes an `m_ct x k_ct x n_ct` GEMM out of 64 KB L1
memory, output-stationary: partial C tiles stay resident while `K/k_ct`
A/B tile pairs stream through (Sec. 4.2.1), with a vectorized zeroing kernel
re-initializing C between reductions.

TPU-style adaptation (see DESIGN.md §Hardware-Adaptation):

* L1 residency is expressed with `BlockSpec`s — A blocks `(m_ct, k_ct)`,
  B blocks `(k_ct, n_ct)`, accumulator blocks `(m_ct, n_ct)` live in
  VMEM for the duration of a grid step.
* The reduction-in-time mapping is the innermost grid dimension `k`;
  `pl.when(k == 0)` performs the zero/accumulator-load step (the paper's
  zeroing kernel).
* The AIE-API `r x s x t` micro-tile becomes the MXU-native inner shape of
  `jnp.dot` with a wide `preferred_element_type` accumulator; `r, s, t`
  survive as *layout* parameters for the DMA-transform layer (Rust `xform`),
  exactly as on the NPU where DMAs pre-tile and the core consumes tiles.
* The AIE-API `transpose` shuffle used when B is column-major in DRAM
  (Sec. 4.3) becomes an in-kernel block transpose (`b_col_major=True`).

Kernels are executed with `interpret=True` everywhere: the CPU PJRT plugin
cannot run Mosaic custom-calls, and correctness (vs `ref.py`) is the
build-time contract. Real-TPU performance is estimated analytically in
DESIGN.md.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


@dataclass(frozen=True)
class KernelSpec:
    """Static configuration of a single-core kernel instance."""

    m_ct: int
    k_ct: int
    n_ct: int
    precision: str  # key into ref.PRECISIONS
    b_col_major: bool = False  # B arrives transposed (N-major) in VMEM

    def __post_init__(self):
        r, s, t = ref.MICRO_TILE[self.precision]
        if self.m_ct % r or self.k_ct % s or self.n_ct % t:
            raise ValueError(
                f"kernel {self.m_ct}x{self.k_ct}x{self.n_ct} not a multiple of "
                f"micro-tile {r}x{s}x{t} for {self.precision}"
            )

    @property
    def micro_tile(self):
        return ref.MICRO_TILE[self.precision]


def _gemm_kernel_body(a_ref, b_ref, acc_ref, *, spec: KernelSpec, k_grid: int):
    """Grid body: one `m_ct x k_ct x n_ct` MAC step, output stationary."""
    k = pl.program_id(2)

    # The paper's vectorized zeroing kernel: C re-initialized at the start of
    # each reduction (Sec. 4.2.1).
    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if spec.b_col_major:
        # AIE-API transpose shuffle: B tile arrives N-major, swizzle to K-major.
        b = b.T
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)


def make_panel_gemm(spec: KernelSpec, m: int, k: int, n: int):
    """Build a jittable panel GEMM `(m, k) @ (k, n) -> (m, n)` in accumulator
    precision, tiled over a `(m/m_ct, n/n_ct, k/k_ct)` grid of single-core
    kernel invocations.

    Grid dims (i, j) model the *spatial* broadcast across the NPU array rows
    and columns (the same A block feeds every j, the same B block every i);
    dim k is the paper's reduction *in time*.
    """
    if m % spec.m_ct or k % spec.k_ct or n % spec.n_ct:
        raise ValueError(f"panel {m}x{k}x{n} not tileable by {spec}")
    adt = ref.acc_dtype(spec.precision)
    k_grid = k // spec.k_ct

    if spec.b_col_major:
        b_shape = (n, k)
        b_block = (spec.n_ct, spec.k_ct)
        b_index = lambda i, j, kk: (j, kk)
    else:
        b_shape = (k, n)
        b_block = (spec.k_ct, spec.n_ct)
        b_index = lambda i, j, kk: (kk, j)

    kernel = functools.partial(_gemm_kernel_body, spec=spec, k_grid=k_grid)

    call = pl.pallas_call(
        kernel,
        grid=(m // spec.m_ct, n // spec.n_ct, k_grid),
        in_specs=[
            pl.BlockSpec((spec.m_ct, spec.k_ct), lambda i, j, kk: (i, kk)),
            pl.BlockSpec(b_block, b_index),
        ],
        out_specs=pl.BlockSpec((spec.m_ct, spec.n_ct), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), adt),
        interpret=True,
    )

    def panel_gemm(a, b):
        assert a.shape == (m, k), (a.shape, (m, k))
        assert b.shape == b_shape, (b.shape, b_shape)
        return call(a, b)

    return panel_gemm


def make_single_core_gemm(spec: KernelSpec):
    """The L1-resident kernel itself: one `m_ct x k_ct x n_ct` tile GEMM,
    narrowed to the output precision (the shape the AIE API executes)."""
    panel = make_panel_gemm(spec, spec.m_ct, spec.k_ct, spec.n_ct)

    def single(a, b):
        return ref.narrow(panel(a, b), spec.precision)

    return single


def _accum_kernel_body(a_ref, b_ref, acc_in_ref, acc_ref, *, spec: KernelSpec):
    """Like `_gemm_kernel_body` but seeds the accumulator from `acc_in`
    instead of zero — the native-step building block for K > k_mt reductions
    (outer-most tiling level, Sec. 4.4)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _seed():
        acc_ref[...] = acc_in_ref[...]

    a = a_ref[...]
    b = b_ref[...]
    if spec.b_col_major:
        b = b.T
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)


def make_panel_gemm_acc(spec: KernelSpec, m: int, k: int, n: int):
    """Panel GEMM with carried accumulator: `acc + (m,k) @ (k,n)`."""
    if m % spec.m_ct or k % spec.k_ct or n % spec.n_ct:
        raise ValueError(f"panel {m}x{k}x{n} not tileable by {spec}")
    adt = ref.acc_dtype(spec.precision)

    if spec.b_col_major:
        b_block = (spec.n_ct, spec.k_ct)
        b_index = lambda i, j, kk: (j, kk)
    else:
        b_block = (spec.k_ct, spec.n_ct)
        b_index = lambda i, j, kk: (kk, j)

    kernel = functools.partial(_accum_kernel_body, spec=spec)

    return pl.pallas_call(
        kernel,
        grid=(m // spec.m_ct, n // spec.n_ct, k // spec.k_ct),
        in_specs=[
            pl.BlockSpec((spec.m_ct, spec.k_ct), lambda i, j, kk: (i, kk)),
            pl.BlockSpec(b_block, b_index),
            pl.BlockSpec((spec.m_ct, spec.n_ct), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((spec.m_ct, spec.n_ct), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), adt),
        interpret=True,
    )
