"""Pure-jnp reference oracle for the single-core GEMM kernel.

Mirrors the numerics of the AIE-API GEMM modes used by the paper:

* int8 x int8 inputs accumulate in int32; the result is then narrowed to the
  requested output precision (int8 / int16 / int32) with saturation — the
  paper's "precision reduction" (Sec. 5.1).
* bf16 x bf16 inputs accumulate in float32 (the AIE fp32 accumulator) and the
  result is stored back as bf16.

This module is the single source of truth for correctness: the Pallas kernel
(`gemm.py`), the whole-array model (`model.py`) and the Rust reference
implementation (`gemm::refimpl`, via golden vectors) are all tested against
it.
"""

from __future__ import annotations

import jax.numpy as jnp

#: (input dtype, accumulator dtype, output dtype) per precision pair.
PRECISIONS = {
    "i8i8": (jnp.int8, jnp.int32, jnp.int8),
    "i8i16": (jnp.int8, jnp.int32, jnp.int16),
    "i8i32": (jnp.int8, jnp.int32, jnp.int32),
    "bf16": (jnp.bfloat16, jnp.float32, jnp.bfloat16),
}

#: AIE-API micro-tile (r, s, t) per precision pair (AIE-ML mmul modes).
MICRO_TILE = {
    "i8i8": (4, 8, 8),
    "i8i16": (4, 8, 8),
    "i8i32": (4, 8, 8),
    "bf16": (4, 8, 4),
}


def acc_dtype(precision: str):
    return PRECISIONS[precision][1]


def in_dtype(precision: str):
    return PRECISIONS[precision][0]


def out_dtype(precision: str):
    return PRECISIONS[precision][2]


def narrow(acc, precision: str):
    """Narrow an accumulator tensor to the output precision, saturating."""
    _, _, out = PRECISIONS[precision]
    if out == jnp.int8:
        return jnp.clip(acc, -128, 127).astype(jnp.int8)
    if out == jnp.int16:
        return jnp.clip(acc, -32768, 32767).astype(jnp.int16)
    if out == jnp.int32:
        return acc.astype(jnp.int32)
    # bf16: round-to-nearest-even cast from the f32 accumulator.
    return acc.astype(jnp.bfloat16)


def ref_gemm_acc(a, b, precision: str, acc=None):
    """GEMM in accumulator precision: acc + a @ b (no narrowing)."""
    adt = acc_dtype(precision)
    prod = jnp.matmul(
        a.astype(in_dtype(precision)),
        b.astype(in_dtype(precision)),
        preferred_element_type=adt,
    )
    if acc is not None:
        prod = prod + acc.astype(adt)
    return prod


def ref_gemm(a, b, precision: str):
    """Full reference GEMM: multiply, accumulate wide, narrow with saturation."""
    return narrow(ref_gemm_acc(a, b, precision), precision)
