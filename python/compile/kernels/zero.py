"""The paper's fast vectorized zeroing kernel (Sec. 4.2.1) as a standalone
Pallas kernel.

On the NPU this runs on the core between complete K-reductions to
re-initialize the stationary C tile. In the fused GEMM kernel
(`gemm._gemm_kernel_body`) the same step is expressed with
`pl.when(k == 0)`; this standalone version exists so the zeroing cost model
(`sim::core::zeroing_cycles`) has a concrete, testable kernel behind it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _zero_body(o_ref):
    o_ref[...] = jnp.zeros_like(o_ref)


def make_zero_kernel(m_ct: int, n_ct: int, dtype=jnp.int32):
    """Zero an `(m_ct, n_ct)` tile in place-style (fresh output buffer)."""
    return pl.pallas_call(
        _zero_body,
        out_shape=jax.ShapeDtypeStruct((m_ct, n_ct), dtype),
        interpret=True,
    )
