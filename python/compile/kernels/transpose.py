"""AIE-API `transpose` shuffle analog (Sec. 4.3).

DMA address generation on the NPU works at 32-bit granularity, so when an
int8/bf16 matrix B is stored column-major in DRAM the element-level swizzle
cannot be done by the DMAs alone — the paper modifies the GEMM kernel to use
shuffle instructions (the AIE API transpose function) so that both data
within tiles and the tiles themselves end up column-major.

Here the same fine-grained swizzle is a Pallas kernel operating on `r x s`
micro-tiles: the input arrives as the DMA left it (tile-of-tiles, inner
dimension still K-contiguous) and the kernel emits the transposed tile the
MAC loop consumes. Used by `gemm.KernelSpec(b_col_major=True)` in fused form;
standalone version kept for the swizzle unit tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_body(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def make_tile_transpose(rows: int, cols: int, dtype=jnp.int8):
    """Transpose a `(rows, cols)` tile: the in-core shuffle primitive."""
    return pl.pallas_call(
        _transpose_body,
        out_shape=jax.ShapeDtypeStruct((cols, rows), dtype),
        interpret=True,
    )


def make_blocked_transpose(n: int, k: int, n_ct: int, k_ct: int, dtype=jnp.int8):
    """Transpose an `(n, k)` panel block-wise in `(n_ct, k_ct)` tiles.

    Models the per-tile shuffle the modified GEMM kernel performs on each
    B tile it receives, grid-iterated over the whole panel.
    """
    if n % n_ct or k % k_ct:
        raise ValueError("panel not tileable")
    return pl.pallas_call(
        _transpose_body,
        grid=(n // n_ct, k // k_ct),
        in_specs=[pl.BlockSpec((n_ct, k_ct), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((k_ct, n_ct), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((k, n), dtype),
        interpret=True,
    )
