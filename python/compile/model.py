"""Layer-2 JAX model: whole-array GEMM built from the Pallas kernel.

The paper's "native GEMM size" — `(m_ct*m_rows) x k_mt x (n_ct*n_cols)`
(Sec. 4.2.2) — is the unit of work dispatched to the NPU array. This module
expresses it as a JAX function over the Layer-1 Pallas kernel:

* `make_native_step`  — one native-size step with carried accumulator; the
  Rust coordinator chains these along K and over output tiles (outer-most
  tiling level, Sec. 4.4), which is exactly the paper's command-processor
  schedule.
* `make_gemm`         — a full (padded) GEMM: scan over K panels, narrow at
  the end. Used for the quickstart artifact and for pytest model tests.
* `make_mlp`          — two chained GEMMs with narrowing in between; the
  DL-workload integration demo (GGML-style consumer, Sec. 1).

Everything here lowers to a single HLO module per variant via
`compile.aot`; Python never runs at serving time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import NpuConfig
from .kernels import ref
from .kernels.gemm import KernelSpec, make_panel_gemm, make_panel_gemm_acc


def kernel_spec(cfg: NpuConfig, b_col_major: bool = False) -> KernelSpec:
    return KernelSpec(
        m_ct=cfg.m_ct,
        k_ct=cfg.k_ct,
        n_ct=cfg.n_ct,
        precision=cfg.precision,
        b_col_major=b_col_major,
    )


def make_native_step(cfg: NpuConfig, b_col_major: bool = False):
    """One native GEMM step: `acc + A_panel @ B_panel` in accumulator dtype.

    A_panel: (m_ct*m_rows, k_mt)   — one m_ct x k_mt tile per array row
    B_panel: (k_mt, n_ct*n_cols)   — one k_mt x n_ct tile per array column
             (transposed layout when `b_col_major`)
    acc:     (m_ct*m_rows, n_ct*n_cols), stays resident across K panels —
             the output-stationary mapping in time.
    """
    spec = kernel_spec(cfg, b_col_major)
    step = make_panel_gemm_acc(spec, cfg.native_m, cfg.k_mt, cfg.native_n)

    def native_step(a_panel, b_panel, acc):
        return step(a_panel, b_panel, acc)

    return native_step


def make_gemm(cfg: NpuConfig, m: int, k: int, n: int, b_col_major: bool = False):
    """Full GEMM `(m,k) @ (k,n)`, narrowed to the output precision.

    `m, n` must be multiples of the native M/N; `k` a multiple of `k_mt`
    (the Rust coordinator handles padding of arbitrary sizes before calling
    the artifact). Reduction over K panels is a `lax.scan` so the lowered
    HLO stays compact at any K.
    """
    if m % cfg.native_m or n % cfg.native_n or k % cfg.k_mt:
        raise ValueError(
            f"GEMM {m}x{k}x{n} not aligned to native "
            f"{cfg.native_m}x{cfg.k_mt}x{cfg.native_n}"
        )
    step = make_native_step(cfg, b_col_major)
    adt = ref.acc_dtype(cfg.precision)
    n_panels = k // cfg.k_mt

    def gemm(a, b):
        # Split K into panels: (n_panels, m, k_mt) / (n_panels, k_mt, n).
        a_p = a.reshape(m, n_panels, cfg.k_mt).transpose(1, 0, 2)
        if b_col_major:
            b_p = b.reshape(n, n_panels, cfg.k_mt).transpose(1, 0, 2)
        else:
            b_p = b.reshape(n_panels, cfg.k_mt, n)

        # Tile the native step across the (m, n) output grid.
        mt, nt = m // cfg.native_m, n // cfg.native_n

        def one_output_tile(a_col, b_row):
            # a_col: (n_panels, native_m, k_mt); b_row: per-tile panels of B.
            def body(acc, ab):
                ap, bp = ab
                return step(ap, bp, acc), None

            init = jnp.zeros((cfg.native_m, cfg.native_n), adt)
            acc, _ = jax.lax.scan(body, init, (a_col, b_row))
            return acc

        # Carve A into row blocks and B into column blocks of native size.
        a_blocks = a_p.reshape(n_panels, mt, cfg.native_m, cfg.k_mt)
        if b_col_major:
            b_blocks = b_p.reshape(n_panels, nt, cfg.native_n, cfg.k_mt)
        else:
            b_blocks = b_p.reshape(n_panels, cfg.k_mt, nt, cfg.native_n)

        rows = []
        for i in range(mt):
            cols = []
            for j in range(nt):
                if b_col_major:
                    b_ij = b_blocks[:, j]
                else:
                    b_ij = b_blocks[:, :, j]
                cols.append(one_output_tile(a_blocks[:, i], b_ij))
            rows.append(jnp.concatenate(cols, axis=1))
        acc = jnp.concatenate(rows, axis=0)
        return ref.narrow(acc, cfg.precision)

    return gemm


def make_mlp(cfg: NpuConfig, m: int, d_in: int, d_hidden: int, d_out: int):
    """Two-layer MLP block: `relu(X @ W1) @ W2`, each GEMM through the
    Pallas kernel — the paper's motivating DL-workload shape."""
    gemm1 = make_gemm(cfg, m, d_in, d_hidden)
    gemm2 = make_gemm(cfg, m, d_hidden, d_out)
    idt = ref.in_dtype(cfg.precision)

    def mlp(x, w1, w2):
        h = gemm1(x, w1)
        h = jnp.maximum(h, jnp.zeros_like(h))  # relu in output precision
        return gemm2(h.astype(idt), w2)

    return mlp


def reference_gemm(cfg: NpuConfig, a, b, b_col_major: bool = False):
    """Oracle for the above (delegates to kernels.ref)."""
    if b_col_major:
        b = b.T
    return ref.ref_gemm(a, b, cfg.precision)
