"""AOT pipeline sanity: lowering produces loadable HLO text + sane manifest.

Full-size artifact generation is `make artifacts`; here we lower a scaled-
down native step end to end (same code path, small shapes) and validate the
HLO text structurally, plus round-trip it through XLA's own parser — the
same parser the Rust `xla` crate calls via `HloModuleProto::from_text_file`.
"""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.configs import BALANCED, NpuConfig
from compile.kernels import ref
from compile.golden import build as build_golden

TINY = NpuConfig("xdna", "i8i16", 8, 16, 8, 32, 4, 4)


def lower_tiny(b_col_major=False):
    step = model.make_native_step(TINY, b_col_major)
    m, k, n = TINY.native_m, TINY.k_mt, TINY.native_n
    b_shape = (n, k) if b_col_major else (k, n)
    specs = [
        jax.ShapeDtypeStruct((m, k), jnp.int8),
        jax.ShapeDtypeStruct(b_shape, jnp.int8),
        jax.ShapeDtypeStruct((m, n), jnp.int32),
    ]
    return jax.jit(step).lower(*specs)


def test_hlo_text_structure():
    text = aot.to_hlo_text(lower_tiny())
    assert "ENTRY" in text and "HloModule" in text
    assert "s8[" in text  # int8 interface preserved
    assert "s32[" in text  # accumulator dtype preserved


def test_hlo_text_reparses():
    """The text must round-trip through XLA's HLO parser (what Rust uses)."""
    xe = pytest.importorskip("jax._src.lib")
    from jax._src.lib import xla_client as xc

    text = aot.to_hlo_text(lower_tiny())
    # hlo_module_from_text exists on recent xla_client builds; fall back to
    # checking the computation can be re-created from the module proto.
    if hasattr(xc._xla, "hlo_module_from_text"):
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None
    else:
        assert text.startswith("HloModule")


def test_manifest_entries_cover_all_configs():
    entries = [meta for _, _, meta in aot.build_entries()]
    names = {m["name"] for m in entries}
    for gen in ("xdna", "xdna2"):
        for prec in ("i8i8", "i8i16", "i8i32", "bf16"):
            for layout in ("rowmajor", "colmajor"):
                assert f"step_{gen}_{prec}_{layout}" in names
    assert "quickstart_bf16" in names and "mlp_bf16" in names
    # Interface dtypes follow the convention the Rust runtime expects.
    for m in entries:
        if m["precision"] == "bf16":
            assert all(d == "f32" for d in m["arg_dtypes"])
        else:
            assert m["arg_dtypes"][0] == "s8"
    # Shapes match the configs table.
    for m in entries:
        if m["kind"] != "native_step":
            continue
        cfg = BALANCED[(m["gen"], m["precision"])]
        assert m["m"] == cfg.native_m and m["k"] == cfg.k_mt and m["n"] == cfg.native_n


def test_manifest_is_json_serializable():
    entries = [meta for _, _, meta in aot.build_entries("quickstart")]
    s = json.dumps(entries)
    assert "quickstart_bf16" in s


def test_golden_vectors_selfconsistent():
    cases = build_golden()
    assert len(cases) >= 6
    for c in cases:
        if c["precision"] == "bf16":
            a = np.asarray(c["a_f32bits"], np.uint32).view(np.float32).reshape(c["m"], c["k"])
            b = np.asarray(c["b_f32bits"], np.uint32).view(np.float32).reshape(c["k"], c["n"])
            out = np.asarray(c["out_f32bits"], np.uint32).view(np.float32)
            want = ref.ref_gemm(jnp.asarray(a, jnp.bfloat16), jnp.asarray(b, jnp.bfloat16), "bf16")
            np.testing.assert_array_equal(out, np.asarray(want, np.float32).reshape(-1))
        else:
            a = np.asarray(c["a"], np.int8).reshape(c["m"], c["k"])
            b = np.asarray(c["b"], np.int8).reshape(c["k"], c["n"])
            want = ref.ref_gemm(jnp.asarray(a), jnp.asarray(b), c["precision"])
            np.testing.assert_array_equal(
                np.asarray(c["out"], np.int64),
                np.asarray(want, np.int64).reshape(-1),
            )
        # int8*int8*K bound: accumulators must fit int32 comfortably.
        if c["precision"] != "bf16":
            assert max(abs(v) for v in c["acc"]) < 2**31 - 1
