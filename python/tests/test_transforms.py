"""Swizzle/zero kernels + a jnp mirror of the Fig.-4 DMA layout pipeline.

The authoritative transform implementation lives in Rust (`xform`); this
file keeps a numpy mirror of the same decomposition so the two sides can be
cross-checked through identical parameter sets, and tests the Pallas shuffle
(transpose) and zeroing kernels the modified GEMM kernel relies on.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.transpose import make_blocked_transpose, make_tile_transpose
from compile.kernels.zero import make_zero_kernel


def pretile(a: np.ndarray, r: int, s: int) -> np.ndarray:
    """Direct pre-tiling oracle: (M, K) row-major -> r x s tiles, tiles
    row-major, elements within a tile row-major (upper part of Fig. 4)."""
    m, k = a.shape
    return (
        a.reshape(m // r, r, k // s, s).transpose(0, 2, 1, 3).reshape(-1)
    )


def dma_pipeline(a: np.ndarray, r: int, s: int, m_ct: int, k_ct: int, k_mt: int):
    """The Fig.-4 chain for one `m_ct x K` ShimTile transfer, in numpy:

    1. Shim MM2S 3D:   m_ct x K row-major -> sequence of m_ct x k_mt tiles
    2. MemTile S2MM 3D: each m_ct x k_mt -> m_ct x k_ct tiles
    3. MemTile MM2S 4D: m_ct x k_ct -> m_ct x s tiles (linearize r x s)
    4. CompTile S2MM 3D: (r*s, m_ct, k_ct) -> final pre-tiled layout
    """
    m_rows, K = a.shape
    assert m_rows == m_ct and K % k_mt == 0 and k_mt % k_ct == 0
    out_tiles = []
    for kmt0 in range(0, K, k_mt):  # step 1: shim splits K into k_mt tiles
        panel = a[:, kmt0 : kmt0 + k_mt]
        for kct0 in range(0, k_mt, k_ct):  # step 2: memtile splits into k_ct
            tile = panel[:, kct0 : kct0 + k_ct]
            # step 3: 4D memtile read emits m_ct x s column chunks in
            # row-of-tiles order => stream order (k-tile, m-tile, r, s)
            # step 4: comptile 3D regroups r*s words per (m-tile, k-tile).
            out_tiles.append(pretile(tile, r, s))
    return np.concatenate(out_tiles)


@settings(max_examples=20, deadline=None)
@given(
    r=st.sampled_from([2, 4]),
    s=st.sampled_from([4, 8]),
    mi=st.integers(1, 3),
    ki=st.integers(1, 3),
    kp=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_dma_pipeline_equals_direct_pretile(r, s, mi, ki, kp, seed):
    """Streaming through the 4-hop DMA chain == pre-tiling every k_ct tile
    in order: the paper's claim that matrices can stay in regular order in
    DRAM with no explicit pre-tiling."""
    m_ct, k_ct = mi * r, ki * s
    k_mt = kp * k_ct
    K = 2 * k_mt
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, (m_ct, K)).astype(np.int8)
    got = dma_pipeline(a, r, s, m_ct, k_ct, k_mt)
    want = np.concatenate(
        [pretile(a[:, c : c + k_ct], r, s) for c in range(0, K, k_ct)]
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("rows,cols", [(4, 8), (8, 8), (16, 4)])
def test_tile_transpose(rows, cols):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (rows, cols)), jnp.int8)
    got = make_tile_transpose(rows, cols)(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x).T)


def test_blocked_transpose():
    rng = np.random.default_rng(1)
    n, k, n_ct, k_ct = 16, 24, 8, 8
    x = jnp.asarray(rng.integers(-128, 128, (n, k)), jnp.int8)
    got = make_blocked_transpose(n, k, n_ct, k_ct)(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x).T)


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_zero_kernel(dtype):
    z = make_zero_kernel(8, 16, dtype)()
    assert z.shape == (8, 16) and z.dtype == dtype
    assert not np.any(np.asarray(z))
