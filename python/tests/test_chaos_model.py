"""Independent re-derivation of the chaos/fault layer (ISSUE 6).

Three cross-language pins against `rust/src/coordinator/fault.rs` and
the router's admission accounting in
`rust/src/coordinator/service.rs`:

1. **Fault-plan goldens** — a from-scratch transliteration of the
   xoshiro256** RNG (`rust/src/util/rng.rs`, SplitMix64-seeded) and the
   per-device fault-plan draw (`FaultPlan::from_seed`): unique 1-based
   seqs in `1..=horizon`, sorted, then one kind draw per seq
   (`u64 % 4` → kill / DMA-stall / cache-storm / drop, with the stall
   duration drawn uniformly in 0.5–5 ms). The seed-2 plan literal here
   must equal the one pinned by `fault.rs::tests` — if either side's
   draw order changes, both tests fail in the same commit.

2. **Quota admission model** — a virtual-time replay of the router's
   per-tenant bound: with quota Q, at most Q units are in flight at
   once, the backlog drains FIFO within a priority class, and the
   conservation invariant `completed + failed + pending == submitted`
   holds at every step (pinned in Rust by `tests/chaos_props.rs`).

3. **Requeue/makespan model** — leader death moves the dead leader's
   queued work to the surviving sibling; the makespan arithmetic of
   that spill is re-derived here with the same `est_s` cost model the
   router uses (`ops / (peak_tops * 1e12)`), including the exact
   XDNA2 int8 golden `3.640888888888889e-05 s` for a 1024³ GEMM.

If a constant changes on the Rust side, change it here in the same
commit.
"""

M64 = (1 << 64) - 1
GOLD = 0x9E3779B97F4A7C15
DEVICE_SALT = 0xA24BAED4963EE407


def _rotl(v, k):
    return ((v << k) | (v >> (64 - k))) & M64


class Rng:
    """Transliteration of rust/src/util/rng.rs (xoshiro256**)."""

    def __init__(self, seed):
        # SplitMix64 expansion; the seed itself is pre-advanced once.
        x = (seed + GOLD) & M64
        s = []
        for _ in range(4):
            x = (x + GOLD) & M64
            z = x
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
            s.append((z ^ (z >> 31)) & M64)
        self.s = s

    def next_u64(self):
        s = self.s
        r = (_rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)


def fault_plan(seed, n_devices, horizon, per_device):
    """Transliteration of FaultPlan::from_seed."""
    horizon = max(horizon, 1)
    plan = []
    for d in range(n_devices):
        rng = Rng((seed + ((d + 1) * DEVICE_SALT)) & M64)
        want = min(per_device, horizon)
        seqs = []
        while len(seqs) < want:
            c = 1 + rng.next_u64() % horizon
            if c not in seqs:
                seqs.append(c)
        seqs.sort()
        evs = []
        for seq in seqs:
            k = rng.next_u64() % 4
            if k == 0:
                evs.append((seq, "leader_kill", None))
            elif k == 1:
                evs.append((seq, "dma_stall", (0.5 + 4.5 * rng.f64()) * 1e-3))
            elif k == 2:
                evs.append((seq, "cache_storm", None))
            else:
                evs.append((seq, "drop_response", None))
        plan.append(evs)
    return plan


# ---- 1. fault-plan goldens --------------------------------------------------


def test_fault_plan_seed2_matches_rust_golden():
    # Must equal the literal pinned in fault.rs::tests::seeded_plan_golden.
    plan = fault_plan(2, 2, 32, 4)
    assert plan[0] == [
        (3, "cache_storm", None),
        (12, "cache_storm", None),
        (18, "drop_response", None),
        (25, "leader_kill", None),
    ]
    assert plan[1][0] == (6, "leader_kill", None)
    assert plan[1][1] == (7, "leader_kill", None)
    seq, kind, stall = plan[1][2]
    assert (seq, kind) == (13, "dma_stall")
    assert stall == 0.004359766823757453
    assert plan[1][3] == (17, "leader_kill", None)


def test_fault_plan_structural_invariants():
    for seed in range(8):
        plan = fault_plan(seed, 3, 24, 5)
        assert len(plan) == 3
        for evs in plan:
            seqs = [seq for (seq, _, _) in evs]
            assert seqs == sorted(seqs)
            assert len(set(seqs)) == len(seqs), "seqs are unique"
            assert all(1 <= s <= 24 for s in seqs), "1-based, within horizon"
            for _, kind, stall in evs:
                if kind == "dma_stall":
                    assert 0.5e-3 <= stall <= 5.0e-3
                else:
                    assert stall is None
    # Same seed → same plan; sibling devices get decorrelated streams.
    assert fault_plan(9, 2, 32, 4) == fault_plan(9, 2, 32, 4)
    p = fault_plan(9, 2, 32, 4)
    assert p[0] != p[1]


def test_per_device_draw_exceeding_horizon_saturates():
    # want = min(per_device, horizon): a tiny horizon can't loop forever.
    plan = fault_plan(5, 1, 3, 10)
    assert sorted(seq for (seq, _, _) in plan[0]) == [1, 2, 3]


# ---- 2. quota admission model ----------------------------------------------


def replay_admission(quota, arrivals):
    """Virtual-time replay of the router's per-tenant quota gate.

    `arrivals` is a list of service times. Units are admitted FIFO; at
    most `quota` run concurrently (0 = unbounded); admission blocks on
    the earliest in-flight retirement. Returns (retirement-times,
    max-in-flight, completed-count); conservation
    (completed + in-flight + not-yet-admitted == submitted) is asserted
    at every step.
    """
    slots = []  # busy-until virtual times, one per in-flight unit
    t = 0.0
    done = []
    peak = 0
    submitted = len(arrivals)
    completed = 0
    for i, svc in enumerate(arrivals):
        if quota and len(slots) >= quota:
            # Block until the earliest in-flight unit retires.
            slots.sort()
            t = max(t, slots.pop(0))
            completed += 1
            done.append(t)
        slots.append(t + svc)
        peak = max(peak, len(slots))
        not_yet_admitted = submitted - i - 1
        assert completed + len(slots) + not_yet_admitted == submitted
    while slots:
        slots.sort()
        done.append(slots.pop(0))
        completed += 1
    return done, peak, completed


def test_quota_bounds_in_flight_and_everything_completes():
    svc = [0.01, 0.02, 0.01, 0.03, 0.01, 0.02, 0.01, 0.01]
    done, peak, completed = replay_admission(2, svc)
    assert peak == 2, "quota 2 caps concurrency at 2"
    assert completed == len(svc) == len(done)
    assert done == sorted(done), "retirements advance in virtual time"
    unbounded_done, unbounded_peak, _ = replay_admission(0, svc)
    assert unbounded_peak == len(svc), "quota 0 admits everything at once"
    assert max(unbounded_done) <= max(done), "quota can only delay completion"


def test_conservation_holds_under_partial_failure():
    # Mirror of TenantStats::conserves(): completed + failed + pending
    # == submitted, with requeues counted separately as re-placement
    # events (a requeued unit stays pending until it completes, or
    # fails when no live device remains — never double-completed).
    submitted, completed, failed, pending, requeued = 10, 7, 1, 2, 3
    assert completed + failed + pending == submitted
    assert requeued >= 0  # orthogonal counter, can exceed failures
    # After a drained shutdown pending must be 0 and nothing is lost.
    drained = dict(submitted=10, completed=9, failed=1, pending=0)
    assert drained["completed"] + drained["failed"] + drained["pending"] == drained["submitted"]


# ---- 3. requeue/makespan model ---------------------------------------------

# arch.rs statics: XDNA2 = 4 rows x 8 cols, 512 int8 MACs/core/cycle,
# 1.8 GHz → peak = 2*512*32*1.8e9 ops/s. est_s = ops / (peak_tops*1e12).
XDNA2_PEAK_OPS = 2.0 * 512 * 32 * 1.8e9
XDNA_PEAK_OPS = 2.0 * 256 * 16 * 1.0e9


def est_s(ops, peak_ops):
    return ops / peak_ops


def test_est_model_golden_xdna2_i8i8_1024():
    ops = 2.0 * 1024.0**3
    assert est_s(ops, XDNA2_PEAK_OPS) == 3.640888888888889e-05


def test_leader_death_spills_work_to_sibling_and_makespan_adds_up():
    # Fleet of [XDNA2, XDNA]; 6 identical 1024³ int8 units, 3 queued per
    # device. Device 0's leader dies with its respawn budget exhausted:
    # its 3 units spill to device 1, which then owns all 6. The no-fault
    # makespan is max over devices; the faulted makespan is serial on
    # the survivor. Both derive from the same est_s model the router's
    # load balancer uses.
    unit = 2.0 * 1024.0**3
    t2, t1 = est_s(unit, XDNA2_PEAK_OPS), est_s(unit, XDNA_PEAK_OPS)
    no_fault = max(3 * t2, 3 * t1)
    spilled = 6 * t1
    assert no_fault == 3 * t1, "XDNA is the slower device"
    assert spilled == 2 * no_fault, "survivor serves both queues serially"
    # Requeue accounting for the spill: 3 requeue events, 0 failures,
    # all 6 complete — conservation intact.
    submitted, completed, failed, requeued = 6, 6, 0, 3
    assert completed + failed == submitted
    assert requeued == 3


def test_requeued_unit_is_served_exactly_once():
    # A dropped response requeues the unit; the retry serves it. The
    # completion count must not double: model a 4-unit queue where unit
    # 2 is dropped once.
    served = []
    queue = [0, 1, 2, 3]
    dropped_once = {2}
    requeues = 0
    while queue:
        u = queue.pop(0)
        if u in dropped_once:
            dropped_once.discard(u)
            queue.append(u)  # requeue at the back, tag consumed
            requeues += 1
            continue
        served.append(u)
    assert sorted(served) == [0, 1, 2, 3]
    assert len(served) == 4, "exactly once despite the drop"
    assert requeues == 1
    assert served == [0, 1, 3, 2], "retry lands after the survivors"
