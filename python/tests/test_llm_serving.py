"""Independent transliteration of the ISSUE 7 serving-runtime math.

Mirrors three pieces of `rust/src/` with no Rust toolchain in the loop:

* the open-loop Poisson arrival process of `workload/llm.rs::LlmLoad`
  (xoshiro256** stream, exponential gaps, seeded-uniform decode
  lengths — the seed XOR salt and draw order are pinned here);
* the `util/stats.rs::percentile` semantics after the ISSUE 7
  latency-accounting fixes (NaN filtered, total_cmp ordering, empty
  sample -> None instead of a fabricated 0.0);
* the coalescing arithmetic the `llm_serving` bench asserts: every
  decode batch M <= 64 pads to one native-M row of the skinny design,
  so a coalesced round costs ceil(S / max_batch) chains where the
  per-session baseline costs S.
"""

import math

M64 = (1 << 64) - 1
GOLD = 0x9E3779B97F4A7C15
ARRIVAL_SALT = 0x11F377A9  # LlmLoad::sessions() seeds with seed ^ salt
SKINNY_M_MAX = 64


def _rotl(v, k):
    return ((v << k) | (v >> (64 - k))) & M64


class Rng:
    """Transliteration of rust/src/util/rng.rs (xoshiro256**)."""

    def __init__(self, seed):
        x = (seed + GOLD) & M64
        s = []
        for _ in range(4):
            x = (x + GOLD) & M64
            z = x
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
            s.append((z ^ (z >> 31)) & M64)
        self.s = s

    def next_u64(self):
        s = self.s
        r = (_rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def below(self, n):
        return self.next_u64() % n


def llm_sessions(sessions, arrival_rate, decode_lo, decode_hi, seed):
    """Transliteration of LlmLoad::sessions(): one RNG stream drives
    both the exponential inter-arrival gaps and the decode lengths, in
    arrival order (gap draw first, then length draw, per session)."""
    rng = Rng(seed ^ ARRIVAL_SALT)
    t = 0.0
    out = []
    for sid in range(sessions):
        t += -math.log(1.0 - rng.f64()) / arrival_rate
        decode = decode_lo + rng.below(decode_hi - decode_lo + 1)
        out.append((sid, t, decode))
    return out


def percentile(xs, p):
    """Transliteration of util/stats.rs::percentile post-ISSUE 7."""
    v = sorted(x for x in xs if not math.isnan(x))
    if not v:
        return None
    rank = (p / 100.0) * (len(v) - 1)
    lo, hi = math.floor(rank), math.ceil(rank)
    if lo == hi:
        return v[lo]
    return v[lo] + (rank - lo) * (v[hi] - v[lo])


# ---------------------------------------------------------------- arrivals


def test_arrivals_are_deterministic_sorted_and_rate_scaled():
    a = llm_sessions(64, 4.0, 8, 32, 7)
    b = llm_sessions(64, 4.0, 8, 32, 7)
    assert a == b, "same seed must replay bit-exact"
    times = [t for (_, t, _) in a]
    assert times == sorted(times)
    assert all(t > 0.0 for t in times)
    # Mean inter-arrival ~ 1/rate (loose bound, 64 samples) — the same
    # window the Rust test pins.
    mean_gap = times[-1] / 64.0
    assert 0.5 / 4.0 < mean_gap < 2.0 / 4.0
    assert llm_sessions(64, 4.0, 8, 32, 99) != a, "seed must matter"


def test_decode_lengths_cover_the_inclusive_range():
    lens = [d for (_, _, d) in llm_sessions(256, 4.0, 4, 6, 7)]
    assert all(4 <= d <= 6 for d in lens)
    assert {4, 5, 6} <= set(lens), "256 draws must hit every length"


def test_arrival_rate_rescales_the_same_gap_sequence():
    # The rate divides the same unit-exponential draws, so doubling it
    # exactly halves every arrival time — the property that makes
    # `--rate` sweeps comparable under one seed.
    slow = llm_sessions(32, 2.0, 8, 8, 7)
    fast = llm_sessions(32, 4.0, 8, 8, 7)
    for (_, ts, _), (_, tf, _) in zip(slow, fast):
        assert math.isclose(ts, 2.0 * tf, rel_tol=1e-12)


# -------------------------------------------------------------- percentile


def test_percentile_empty_sample_is_none_not_zero():
    # The ISSUE 7 bugfix: a fleet that completed nothing must report
    # n/a, not a perfect p99 of 0.0.
    assert percentile([], 50.0) is None
    assert percentile([], 99.0) is None
    assert percentile([float("nan")], 99.0) is None


def test_percentile_ignores_nan_and_interpolates():
    clean = [4.0, 1.0, 3.0, 2.0]
    laced = clean + [float("nan")]
    assert percentile(laced, 50.0) == percentile(clean, 50.0) == 2.5
    assert percentile(clean, 0.0) == 1.0
    assert percentile(clean, 100.0) == 4.0
    assert percentile([7.0], 99.0) == 7.0
    p50, p99 = percentile(clean, 50.0), percentile(clean, 99.0)
    assert p99 >= p50


# -------------------------------------------------------------- coalescing


def round_up(x, q):
    return -(-x // q) * q


def test_every_decode_batch_pads_to_one_skinny_native_row():
    # TilingConfig::padded with the skinny class's native M = 64: any
    # coalesced batch 1..=64 costs the same padded GEMM, which is why
    # the decode_busy_s ratio approaches the mean batch.
    for m in range(1, SKINNY_M_MAX + 1):
        assert round_up(m, SKINNY_M_MAX) == SKINNY_M_MAX
    assert round_up(SKINNY_M_MAX + 1, SKINNY_M_MAX) == 2 * SKINNY_M_MAX


def test_coalesced_round_cost_model_matches_the_bench_pin():
    # A round with S ready sessions and chunking at max_batch submits
    # ceil(S/max_batch) chains coalesced vs S chains per-session; with
    # identical padded-M per chain the decode-device-time ratio is
    # S / ceil(S/max_batch). The bench pins >= 2x at mean batch > 2.
    def ratio(s, max_batch):
        return s / -(-s // max_batch)

    assert ratio(1, 64) == 1.0
    assert ratio(6, 64) == 6.0
    assert ratio(5, 2) == 5.0 / 3.0
    for s in range(3, 65):
        assert ratio(s, 64) >= 2.0


def test_token_conservation_closes_under_partial_failure():
    # Replay the accounting: every session either completes all its
    # tokens or fails with its remaining tokens counted failed; pending
    # is the closing residual and must be 0 after a full drain.
    sessions = llm_sessions(16, 1000.0, 8, 32, 11)
    submitted = sum(d for (_, _, d) in sessions)
    completed = failed = 0
    for sid, _, decode in sessions:
        if sid % 5 == 3:  # a failed prefill loses the whole session
            failed += decode
        elif sid % 7 == 6:  # a failed decode round loses the remainder
            done = decode // 2
            completed += done
            failed += decode - done
        else:
            completed += decode
    pending = submitted - completed - failed
    assert pending == 0
    assert completed + failed + pending == submitted
