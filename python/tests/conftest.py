"""Make `hypothesis` optional for this suite.

When hypothesis is not installed, register a minimal stand-in module
before the test modules import it: `@given(...)`-decorated tests are
skipped, `@settings(...)` is a no-op, and any strategy expression
(`st.integers(...)`, including chained calls like `.filter(...)`)
evaluates to an inert placeholder. The example-based tests keep running
unchanged.
"""

import sys
import types

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import pytest

    class _Anything:
        """Absorbs any strategy construction/chaining at decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    def _given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    _shim = types.ModuleType("hypothesis")
    _shim.given = _given
    _shim.settings = _settings
    _shim.strategies = _Anything()
    sys.modules["hypothesis"] = _shim
