"""Layer-2 correctness: native step, full GEMM, MLP vs the oracle.

Uses a scaled-down NpuConfig (same structure as the paper's balanced
configs, smaller tiles) so interpret-mode Pallas stays fast.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import BALANCED, NpuConfig
from compile.kernels import ref

# Scaled-down design points: same (m_rows x n_cols) topologies as the paper,
# micro-tile-aligned kernels, k_mt holding multiple k_ct tiles.
TINY = {
    "xdna": NpuConfig("xdna", "i8i16", 8, 16, 8, 32, 4, 4),
    "xdna2": NpuConfig("xdna2", "i8i16", 8, 16, 8, 32, 4, 8),
}
TINY_BF16 = NpuConfig("xdna", "bf16", 8, 16, 8, 32, 4, 4)


def rand_for(cfg, rng, m, k, n):
    if cfg.precision == "bf16":
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.bfloat16)
    else:
        a = jnp.asarray(rng.integers(-64, 64, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-64, 64, (k, n)), jnp.int8)
    return a, b


@pytest.mark.parametrize("gen", ["xdna", "xdna2"])
@pytest.mark.parametrize("b_col_major", [False, True])
def test_native_step(gen, b_col_major):
    cfg = TINY[gen]
    rng = np.random.default_rng(1)
    m, k, n = cfg.native_m, cfg.k_mt, cfg.native_n
    a, b = rand_for(cfg, rng, m, k, n)
    acc0 = jnp.asarray(rng.integers(-100, 100, (m, n)), jnp.int32)
    step = model.make_native_step(cfg, b_col_major)
    got = step(a, b.T if b_col_major else b, acc0)
    want = ref.ref_gemm_acc(a, b, cfg.precision, acc=acc0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("gen", ["xdna", "xdna2"])
def test_full_gemm_multi_panel_multi_tile(gen):
    """2x2 output tiles, 3 K panels: exercises the scan + concat plumbing."""
    cfg = TINY[gen]
    rng = np.random.default_rng(2)
    m, k, n = 2 * cfg.native_m, 3 * cfg.k_mt, 2 * cfg.native_n
    a, b = rand_for(cfg, rng, m, k, n)
    got = model.make_gemm(cfg, m, k, n)(a, b)
    want = ref.ref_gemm(a, b, cfg.precision)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_full_gemm_b_col_major():
    cfg = TINY["xdna"]
    rng = np.random.default_rng(4)
    m, k, n = cfg.native_m, 2 * cfg.k_mt, cfg.native_n
    a, b = rand_for(cfg, rng, m, k, n)
    got = model.make_gemm(cfg, m, k, n, b_col_major=True)(a, jnp.asarray(np.asarray(b).T))
    want = ref.ref_gemm(a, b, cfg.precision)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_full_gemm_bf16():
    cfg = TINY_BF16
    rng = np.random.default_rng(5)
    m, k, n = cfg.native_m, 2 * cfg.k_mt, cfg.native_n
    a, b = rand_for(cfg, rng, m, k, n)
    got = np.asarray(model.make_gemm(cfg, m, k, n)(a, b), np.float64)
    want = np.asarray(ref.ref_gemm(a, b, cfg.precision), np.float64)
    np.testing.assert_allclose(got, want, rtol=2.0 ** -7, atol=2.0 ** -6)


def test_gemm_alignment_errors():
    cfg = TINY["xdna"]
    with pytest.raises(ValueError):
        model.make_gemm(cfg, cfg.native_m + 1, cfg.k_mt, cfg.native_n)
    with pytest.raises(ValueError):
        model.make_gemm(cfg, cfg.native_m, cfg.k_mt + 1, cfg.native_n)


def test_mlp_chain():
    cfg = TINY["xdna"]
    rng = np.random.default_rng(6)
    m, d_in, d_h, d_out = cfg.native_m, cfg.k_mt, cfg.native_n, cfg.native_n
    # d_h must be k_mt-alignable for the second GEMM: use k=d_h=32 = k_mt.
    x, w1 = rand_for(cfg, rng, m, d_in, d_h)
    _, w2 = rand_for(cfg, rng, d_h, d_h, d_out)
    got = model.make_mlp(cfg, m, d_in, d_h, d_out)(x, w1, w2)
    h = ref.ref_gemm(x, w1, cfg.precision)
    h = jnp.maximum(h, 0).astype(jnp.int8)
    want = ref.ref_gemm(h, w2, cfg.precision)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_balanced_configs_consistent():
    """The table aot.py ships must satisfy every structural invariant the
    Rust side assumes (micro-tile alignment, k_mt multiple of k_ct, array
    geometry per generation)."""
    for (gen, prec), cfg in BALANCED.items():
        assert cfg.gen == gen and cfg.precision == prec
        assert cfg.m_rows == 4
        assert cfg.n_cols == (4 if gen == "xdna" else 8)
        assert cfg.k_mt % cfg.k_ct == 0
        r, s, t = cfg.micro_tile
        assert cfg.m_ct % r == 0 and cfg.k_ct % s == 0 and cfg.n_ct % t == 0
