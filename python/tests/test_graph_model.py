"""Numerical cross-check of the Rust graph compiler (ISSUE 5).

Self-contained transliteration of the pieces the fleet partitioner
depends on — the simplified GEMM cost model (shared with
test_bfp16_model.py), the chain-lowering cut rule, the fused-edge
overrides, and the critical-path list scheduler — replayed on the
one-layer attention graph (default dims, int8) over a warm 2×XDNA2
fleet. It pins the same *structural* goldens `rust/tests/graph_props.rs`
asserts (chain shapes, staged edges, chain-level DAG, device
assignment, makespan == critical path < serial), plus its own makespan
value so cost-model drift is caught on this side too.

The Rust simulator additionally models BD-queue stalls, so absolute
seconds differ slightly; every cross-language assertion here is chosen
to be insensitive to that (decisions are driven by structure and by
margins orders of magnitude above the stall term). If a constant
changes on the Rust side, change it here in the same commit.
"""

import math

# ---- cost model (transliterates sim::engine, Overlapped, no stalls) ----

SPECS = {
    "xdna": dict(rows=4, cols=4, clock=1.0e9, dma=4.0, dispatch=0.5e-3,
                 reconfig=3.4e-3, l2=512 * 1024),
    "xdna2": dict(rows=4, cols=8, clock=1.8e9, dma=8.0, dispatch=0.1e-3,
                  reconfig=4.9e-3, l2=512 * 1024),
}
PEAK = {("xdna", "i8i8"): 256.0, ("xdna2", "i8i8"): 512.0}
BETA = {("xdna", "i8i8"): 0.0895, ("xdna2", "i8i8"): 0.068}
DRAM = {"xdna": (32.4e9, 435.0, 16.0e9), "xdna2": (70.5e9, 178.0, 57.6e9)}
CFG = {("xdna", "i8i8"): (112, 112, 112, 448), ("xdna2", "i8i8"): (144, 72, 144, 432)}
IN_B = OUT_B = 1.0  # int8-int8


def round_up(x, q):
    return -(-x // q) * q


def bw_eff(gen, run):
    mx, x0, cap = DRAM[gen]
    return min(mx * run / (run + x0), cap)


def simulate(gen, m, k, n, a_in_l2=False, c_stays=False, elide_dispatch=False):
    """One dispatch's seconds under chain overrides (sans BD stalls)."""
    m_ct, k_ct, n_ct, k_mt = CFG[(gen, "i8i8")]
    s = SPECS[gen]
    nm, nn = m_ct * s["rows"], n_ct * s["cols"]
    pm, pk, pn = round_up(m, nm), round_up(k, k_mt), round_up(n, nn)
    kc = m_ct * k_ct * n_ct / PEAK[(gen, "i8i8")] + BETA[(gen, "i8i8")] * m_ct * n_ct
    tiles = (pm // nm) * (pn // nn)
    zero = m_ct * n_ct * OUT_B / 128.0
    drain = m_ct * n_ct * OUT_B / s["dma"]
    t_comp = tiles * ((pk // k_ct) * kc + zero + drain) / s["clock"]
    mkn = pm * pk * pn
    a_bytes = 0.0 if a_in_l2 else mkn * IN_B / (n_ct * s["cols"])
    b_bytes = mkn * IN_B / (m_ct * s["rows"])
    c_bytes = 0.0 if c_stays else pm * pn * OUT_B
    run = k_mt * IN_B
    c_run = n_ct * OUT_B * (2.8 if gen == "xdna" else 1.45)
    t_mem = max((a_bytes + b_bytes) / bw_eff(gen, run), c_bytes / bw_eff(gen, c_run))
    a_first = 0.0 if a_in_l2 else s["rows"] * m_ct * k_mt * IN_B
    b_first = s["cols"] * k_mt * n_ct * IN_B
    t_pro = (a_first + b_first) / bw_eff(gen, run)
    t_disp = 0.0 if elide_dispatch else s["dispatch"]
    return max(t_comp, t_mem) + t_pro + t_disp


def l2_headroom(gen):
    m_ct, k_ct, n_ct, k_mt = CFG[(gen, "i8i8")]
    s = SPECS[gen]
    a = m_ct * k_mt * IN_B
    b = k_mt * n_ct * IN_B
    c = s["rows"] * m_ct * n_ct * OUT_B
    used = s["cols"] * (2 * b + c) + s["rows"] * 2 * a
    return s["cols"] * s["l2"] - used


def chain_exec(gen, ops, edges):
    """plan::overrides_for + per-op simulate: one chain's seconds on a
    warm same-design device (mirrors graph::partition::chain_exec_s)."""
    m_ct, _, n_ct, _ = CFG[(gen, "i8i8")]
    s = SPECS[gen]
    nm, nn = m_ct * s["rows"], n_ct * s["cols"]
    headroom = l2_headroom(gen)
    held = 0.0
    t = 0.0
    for i, (m, k, n) in enumerate(ops):
        a_in, c_stays = False, False
        fused_in = 0.0
        if i > 0 and edges[i]:
            pm, pn = round_up(ops[i - 1][0], nm), round_up(ops[i - 1][2], nn)
            cb = pm * pn * OUT_B
            if cb + held <= headroom:
                a_in = True
                fused_in = cb
        held = fused_in
        # c_stays for op i: does op i+1 fuse its inbound edge?
        if i + 1 < len(ops) and edges[i + 1]:
            pm, pn = round_up(m, nm), round_up(n, nn)
            if pm * pn * OUT_B + fused_in <= headroom:
                c_stays = True
        t += simulate(gen, m, k, n, a_in_l2=a_in, c_stays=c_stays,
                      elide_dispatch=i > 0)
    return t


# ---- the one-layer attention graph, lowered (graph::ir + graph::lower) --

S, D, F, V = 512, 768, 3072, 50257
# Nodes: 0 embed, 1 q, 2 k, 3 v, 4 attn_out, 5 ffn_up, 6 ffn_down, 7 lm_head
NODES = [(S, D, D)] * 5 + [(S, D, F), (S, F, D), (S, D, V)]
INPUTS = [[], [0], [0], [0], [3], [0, 4], [5], [6]]
# Lowering cut rule: extend iff in-edges ⊆ {prev} and prev feeds only me.
CHAINS = [[0], [1], [2], [3, 4], [5, 6, 7]]
CHAIN_EDGES = [[False], [False], [False], [False, True], [False, True, True]]
STAGED = [(0, 1), (0, 2), (0, 3), (0, 5), (4, 5)]
CHAIN_DEPS = [[], [0], [0], [0], [0, 3]]


def chain_of(node):
    return next(ci for ci, c in enumerate(CHAINS) if node in c)


def test_lowering_structure_matches_rust_goldens():
    # Derive the cut rule independently and confirm the hand table.
    consumers = [[c for c, ins in enumerate(INPUTS) if p in ins] for p in range(8)]
    chains, staged, pos = [], [], {}
    for i in range(8):
        extendable = (i > 0 and all(p == i - 1 for p in INPUTS[i])
                      and all(c == i for c in consumers[i - 1]))
        if extendable:
            chains[-1].append(i)
        else:
            chains.append([i])
            staged.extend((p, i) for p in INPUTS[i])
        pos[i] = len(chains) - 1
    assert chains == CHAINS
    assert staged == STAGED
    deps = [sorted({pos[p] for p, c in staged if pos[c] == ci and pos[p] != ci})
            for ci in range(len(chains))]
    assert deps == CHAIN_DEPS


def xfer_s(gen, producer):
    m, _, n = NODES[producer]
    bytes_ = m * n * OUT_B
    return bytes_ / bw_eff(gen, n * OUT_B)


def partition_2dev(gen="xdna2"):
    """graph::partition's list scheduler on a warm 2-device fleet."""
    n_chain = len(CHAINS)
    cost = [chain_exec(gen, [NODES[i] for i in c], CHAIN_EDGES[ci])
            for ci, c in enumerate(CHAINS)]
    # Priority: critical path to sink; succs have higher chain index.
    succs = [[c for c in range(n_chain) if d in CHAIN_DEPS[c]] for d in range(n_chain)]
    prio = list(cost)
    for c in reversed(range(n_chain)):
        prio[c] = cost[c] + max((prio[sc] for sc in succs[c]), default=0.0)
    cp_end = [0.0] * n_chain
    for c in range(n_chain):
        cp_end[c] = max((cp_end[d] for d in CHAIN_DEPS[c]), default=0.0) + cost[c]
    avail = [0.0, 0.0]
    finish = [0.0] * n_chain
    device_of = [None] * n_chain
    placed = [False] * n_chain
    for _ in range(n_chain):
        ready = [c for c in range(n_chain)
                 if not placed[c] and all(placed[d] for d in CHAIN_DEPS[c])]
        pick = max(ready, key=lambda c: (prio[c], -c))
        head = CHAINS[pick][0]
        best = None
        for d in (0, 1):
            start = avail[d]
            xfer = 0.0
            for p in INPUTS[head]:
                pc = chain_of(p)
                start = max(start, finish[pc])
                if device_of[pc] != d:
                    xfer += xfer_s(gen, p)
            fin = start + xfer + cost[pick]  # warm fleet, one design: no reconfig
            if best is None or fin < best[0]:
                best = (fin, d)
        fin, d = best
        placed[pick] = True
        device_of[pick] = d
        finish[pick] = fin
        avail[d] = fin
    return device_of, max(finish), max(cp_end), sum(cost)


# Pinned by this file (the Rust side pins the same structure; absolute
# seconds differ by the stall term it models and this file does not).
PINNED_MAKESPAN_S = 0.002015148556595745


def test_partitioner_critical_path_makespan_on_the_attention_graph():
    device_of, makespan, critical_path, serial = partition_2dev()
    # The Rust goldens (rust/tests/graph_props.rs): critical path
    # embed → v/attn_out → ffn/lm_head on device 0, q/k on device 1.
    assert device_of == [0, 1, 1, 0, 0]
    # Device 0 never idles: the makespan IS the critical path, and the
    # fleet strictly beats the serial single-device schedule.
    assert abs(makespan - critical_path) < 1e-12
    assert makespan < serial
    # Drift pin for this cost model.
    assert abs(makespan - PINNED_MAKESPAN_S) / PINNED_MAKESPAN_S < 1e-6, makespan


def test_fused_edges_inside_the_lowered_chains():
    # graph lowering frees ffn_up of a resident A (its inbound edge is a
    # staged join, not an L2-resident chain edge), so on XDNA2 the
    # ffn_up→ffn_down edge fits headroom and fuses — an edge the PR-2
    # transformer *chain* planner provably cannot fuse (its ffn_up holds
    # attn_out's C resident). v→attn_out fuses on both generations.
    m_ct, _, n_ct, _ = CFG[("xdna2", "i8i8")]
    s = SPECS["xdna2"]
    nm, nn = m_ct * s["rows"], n_ct * s["cols"]
    head = l2_headroom("xdna2")
    # v→attn_out: v's padded C.
    assert round_up(S, nm) * round_up(D, nn) * OUT_B <= head
    # ffn_up→ffn_down with no held A.
    assert round_up(S, nm) * round_up(F, nn) * OUT_B <= head
    # ...but lm_head's inbound edge cannot coexist with ffn_up's C.
    held = round_up(S, nm) * round_up(F, nn) * OUT_B
    assert round_up(S, nm) * round_up(D, nn) * OUT_B + held > head


def test_transliterated_costs_are_sane():
    # Anchors keeping this file honest against gross drift: the ffn
    # chain dominates (lm_head is ~20 GMACs), the small chains cost
    # about one dispatch plus compute.
    cost = [chain_exec("xdna2", [NODES[i] for i in c], CHAIN_EDGES[ci])
            for ci, c in enumerate(CHAINS)]
    assert cost[4] > 3 * cost[3] > 0
    assert all(c > SPECS["xdna2"]["dispatch"] for c in cost)
    # q and k are symmetric.
    assert math.isclose(cost[1], cost[2], rel_tol=1e-12)
