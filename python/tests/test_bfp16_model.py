"""Numerical cross-check of the Rust-side native-bfp16 layer (ISSUE 4).

Self-contained transliteration of the pieces of `rust/src/{sim,tiling,
dtype_bfp16}` that the bfp16 path depends on, validated against the
paper's published rows and then used to pin the numbers the Rust tests
assert: the ≥1.5x bfp16-vs-bf16 speedup on XDNA2 at the Table-3 bf16
shape, the shipped balanced configs' validity, the planner fused-edge
goldens (incl. the XDNA2 knife-edge), and the block codec's error
bound. If a constant changes on the Rust side, change it here in the
same commit — this file is the independent recomputation, not a copy.
"""

import math

import numpy as np

SPECS = {
    "xdna": dict(rows=4, cols=4, l1=64 * 1024 - 1024, l2=512 * 1024, clock=1.0e9,
                 dma=4.0, neighbor=False, dispatch=0.5e-3),
    "xdna2": dict(rows=4, cols=8, l1=64 * 1024 - 1024, l2=512 * 1024, clock=1.8e9,
                  dma=8.0, neighbor=True, dispatch=0.1e-3),
}
PEAK = {("xdna2", "bf16"): 192.0, ("xdna2", "bfp16"): 512.0,
        ("xdna", "bf16"): 128.0, ("xdna", "bfp16"): 128.0,
        ("xdna", "i8i8"): 256.0, ("xdna2", "i8i8"): 512.0}
BETA = {("xdna2", "bf16"): 0.115, ("xdna2", "bfp16"): 0.085,
        ("xdna", "bf16"): 0.117, ("xdna", "bfp16"): 0.13,
        ("xdna", "i8i8"): 0.0895, ("xdna2", "i8i8"): 0.068}
IN_B = {"i8i8": 1.0, "bf16": 2.0, "bfp16": 1.5}
OUT_B = {"i8i8": 1.0, "bf16": 2.0, "bfp16": 1.5}
DRAM = {"xdna": (32.4e9, 435.0, 16.0e9), "xdna2": (70.5e9, 178.0, 57.6e9)}

# Mirrors rust/src/arch.rs::balanced_config (the rows this file pins).
CFG = {
    ("xdna", "i8i8"): (112, 112, 112, 448),
    ("xdna2", "i8i8"): (144, 72, 144, 432),
    ("xdna", "bf16"): (96, 56, 96, 224),
    ("xdna2", "bf16"): (112, 48, 96, 384),
    ("xdna", "bfp16"): (100, 104, 72, 312),
    ("xdna2", "bfp16"): (140, 40, 144, 440),
}


def round_up(x, q):
    return -(-x // q) * q


def bw_eff(gen, run):
    mx, x0, cap = DRAM[gen]
    return min(mx * run / (run + x0), cap)


def simulate(gen, p, cfg, m, k, n):
    """Transliteration of sim::engine::simulate_gemm (Overlapped mode)."""
    m_ct, k_ct, n_ct, k_mt = cfg
    s = SPECS[gen]
    nm, nn = m_ct * s["rows"], n_ct * s["cols"]
    pm, pk, pn = round_up(m, nm), round_up(k, k_mt), round_up(n, nn)
    kc = m_ct * k_ct * n_ct / PEAK[(gen, p)] + BETA[(gen, p)] * m_ct * n_ct
    tiles = (pm // nm) * (pn // nn)
    zero = m_ct * n_ct * OUT_B[p] / 128.0
    drain = m_ct * n_ct * OUT_B[p] / s["dma"]
    t_comp = tiles * ((pk // k_ct) * kc + zero + drain) / s["clock"]
    mkn = pm * pk * pn
    a_bytes = mkn * IN_B[p] / (n_ct * s["cols"])
    b_bytes = mkn * IN_B[p] / (m_ct * s["rows"])
    c_bytes = pm * pn * OUT_B[p]
    run = k_mt * IN_B[p]
    c_run = n_ct * OUT_B[p] * (2.8 if gen == "xdna" else 1.45)
    t_mem = max((a_bytes + b_bytes) / bw_eff(gen, run), c_bytes / bw_eff(gen, c_run))
    a_first = s["rows"] * m_ct * k_mt * IN_B[p]
    b_first = s["cols"] * k_mt * n_ct * IN_B[p]
    t_pro = (a_first + b_first) / bw_eff(gen, run)
    t_total = max(t_comp, t_mem) + t_pro + s["dispatch"]
    return 2.0 * m * k * n / t_total / 1e12


def l1_bytes(p, m, k, n):
    return (2 * m * k + 2 * k * n + m * n) * IN_B[p] if p != "i8i8" else 0


def l2_usage(gen, p, cfg):
    m_ct, k_ct, n_ct, k_mt = cfg
    s = SPECS[gen]
    a = m_ct * k_mt * IN_B[p]
    b = k_mt * n_ct * IN_B[p]
    c = s["rows"] * m_ct * n_ct * OUT_B[p]
    used = s["cols"] * (2 * b + c) + s["rows"] * 2 * a
    return used, s["cols"] * s["l2"], (2 * a + 2 * b + c, 2 * b + c)


def test_transliteration_reproduces_published_rows():
    # Anchor: the same formulas reproduce the paper's bold rows, so the
    # bfp16 projections below rest on a validated model.
    for gen, p, size, paper in [
        ("xdna", "i8i8", (4032, 4032, 4032), 6.52),
        ("xdna2", "i8i8", (4032, 4320, 4608), 37.35),
        ("xdna2", "bf16", (4032, 4224, 4608), 14.52),
    ]:
        got = simulate(gen, p, CFG[(gen, p)], *size)
        assert abs(got - paper) / paper < 0.055, f"{gen}/{p}: {got} vs {paper}"


def test_bfp16_configs_fit_and_speedup_holds():
    # The shipped bfp16 balanced configs respect L1/L2 (12 bits/value on
    # every buffer — the padded wire format)...
    for gen in ["xdna", "xdna2"]:
        m, k, n, kmt = CFG[(gen, "bfp16")]
        assert m % 4 == 0 and k % 8 == 0 and n % 8 == 0 and kmt % k == 0
        assert l1_bytes("bfp16", m, k, n) <= SPECS[gen]["l1"]
        used, cap, (even, odd) = l2_usage(gen, "bfp16", CFG[(gen, "bfp16")])
        assert used <= cap
        if SPECS[gen]["neighbor"]:
            assert even + odd <= 2 * SPECS[gen]["l2"]
        else:
            assert even <= SPECS[gen]["l2"]
    # ...and the acceptance bar: ≥1.5x over the bf16 balanced design on
    # XDNA2 at the paper's Table-3 bf16 shape (rust: sim::engine tests).
    bf = simulate("xdna2", "bf16", CFG[("xdna2", "bf16")], 4032, 4224, 4608)
    bfp = simulate("xdna2", "bfp16", CFG[("xdna2", "bfp16")], 4032, 4224, 4608)
    assert bfp / bf >= 1.5, f"speedup {bfp / bf:.3f}"
    assert bfp / bf <= 2.3


def test_fused_edge_goldens_including_the_knife_edge():
    # Mirrors plan::schedule::overrides_for on the default transformer
    # layer; the values are the goldens rust/tests/plan_golden.rs pins.
    def fused(gen, p):
        cfg = CFG[(gen, p)]
        m_ct, k_ct, n_ct, k_mt = cfg
        s = SPECS[gen]
        nm, nn = m_ct * s["rows"], n_ct * s["cols"]
        used, cap, _ = l2_usage(gen, p, cfg)
        headroom = cap - used
        ops = [(512, 768, 2304), (512, 768, 768), (512, 768, 3072), (512, 3072, 768)]
        edges = [False, False, True, True]
        held = 0
        count = 0
        for i in range(4):
            fused_in = 0
            if edges[i]:
                pm = round_up(ops[i - 1][0], nm)
                pn = round_up(ops[i - 1][2], nn)
                cb = pm * pn * OUT_B[p]
                if cb + held <= headroom:
                    count += 1
                    fused_in = cb
            held = fused_in
        return count, headroom

    assert fused("xdna", "i8i8")[0] == 1
    assert fused("xdna2", "i8i8")[0] == 1
    assert fused("xdna", "bf16")[0] == 0
    assert fused("xdna2", "bf16")[0] == 1
    assert fused("xdna", "bfp16")[0] == 1
    # The XDNA2 bfp16 knife-edge: attn_out's padded C (560·1152·1.5 =
    # 967 680 B) misses the design's headroom by 896 bytes.
    count, headroom = fused("xdna2", "bfp16")
    assert count == 0
    assert headroom == 966784
    assert round_up(512, 560) * round_up(768, 1152) * 1.5 == 967680


# --- block codec (mirrors dtype_bfp16.rs with the clamped-exponent fix) --


def encode(vals):
    v = np.asarray(vals, np.float32)
    mx = float(np.max(np.abs(v)))
    if mx == 0.0 or not math.isfinite(mx):
        return 0, np.zeros(8, np.int8)
    # top clamp 254: at 255 the block max would decode to 2^128 = inf
    biased = int(np.clip(math.floor(math.log2(mx)) + 127, 0, 254))
    scale = np.float32(2.0 ** (biased - 133))
    m = np.clip(np.round(v / scale), -128, 127).astype(np.int8)
    return biased, m


def decode(e, m):
    return (m.astype(np.float32) * np.float32(2.0 ** (e - 133))).astype(np.float32)


def test_block_codec_roundtrip_bound_and_denormal_edge():
    rng = np.random.default_rng(7)
    worst = 0.0
    for _ in range(500):
        s = 2.0 ** rng.integers(-110, 110)
        v = (rng.standard_normal(8) * s).astype(np.float32)
        e, m = encode(v)
        back = decode(e, m)
        mx = np.max(np.abs(v))
        if mx > 0:
            worst = max(worst, float(np.max(np.abs(back - v)) / mx))
    assert worst <= (0.5 / 64) * 1.001, worst
    # Denormal-range blocks: the clamped exponent keeps decode in the
    # right binade (quantize toward zero, never a 64x blow-up).
    e, m = encode([1e-40, 2e-41, 0, 0, 0, 0, 0, 0])
    assert e == 0
    assert np.max(np.abs(decode(e, m))) <= 2e-40


def test_tiled_f32_reduction_is_bit_identical_to_reference_order():
    # The executor reduces per k_ct tile in ascending order; the
    # reference runs one flat ascending-k loop. Same adds, same order,
    # same f32 bits — the bit-exactness contract of exec_diff's bfp16
    # rows, checked here in exact float32 emulation.
    rng = np.random.default_rng(3)
    m, k, n, kct = 4, 64, 8, 16
    a = np.zeros((m, k), np.float32)
    b = np.zeros((k, n), np.float32)
    for i in range(m):
        for b0 in range(0, k, 8):
            e, mm = encode(rng.standard_normal(8).astype(np.float32))
            a[i, b0:b0 + 8] = decode(e, mm)
    for j in range(n):
        for b0 in range(0, k, 8):
            e, mm = encode(rng.standard_normal(8).astype(np.float32))
            b[b0:b0 + 8, j] = decode(e, mm)

    def scalar(order):
        c = np.zeros((m, n), np.float32)
        for i in range(m):
            for j in range(n):
                acc = np.float32(0)
                for kk in order:
                    acc = np.float32(acc + np.float32(a[i, kk] * b[kk, j]))
                c[i, j] = acc
        return c

    flat = scalar(range(k))
    tiled = scalar([t + kk for t in range(0, k, kct) for kk in range(kct)])
    assert np.array_equal(flat.view(np.uint32), tiled.view(np.uint32))
