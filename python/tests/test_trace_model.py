"""Cross-language pins for the flight-recorder layer (ISSUE 10).

Four independent re-derivations against `rust/src/trace/`:

1. **Histogram buckets** — the `LATENCY_BUCKETS_S` literals, the
   `bucket_index` rule (first bound `>= v`, Prometheus `le` semantics,
   final slot = `+Inf` overflow), and the exact Prometheus label text
   each bound renders as (`fmt_num`: integral values print without a
   trailing `.0`), mirroring `rust/src/trace/metrics.rs`.

2. **Span phase arithmetic** — the Chrome exporter's parent-span
   duration (`t_total * dispatches + fault_stall + integrity`) and its
   phase-children partition (dma-in / steady / bd-stall / dispatch /
   fault-stall / integrity, steady by subtraction, non-positive phases
   elided), mirroring `rust/src/trace/chrome.rs::{span_seconds,
   push_phases}`: the children must sum exactly to the parent.

3. **Roofline ridge points** — `peak_tops * 1e12 / bw_max` from the
   machine constants, pinned to the same literals as
   `rust/src/trace/roofline.rs::tests` (XDNA i8i8 ~252.8 ops/B, XDNA2
   i8i8 ~836.6 ops/B, bf16 = i8i8 / 2).

4. **Bound classification** — the engine's `t_comp >= t_mem` verdict
   (transliterated cost model shared with test_graph_model.py) at
   shapes with robust margins, matching the verdicts
   `roofline.rs::tests::tag_reflects_engine_bound` pins: the XDNA
   balanced design is compute-bound at square kilo-shapes, the XDNA2
   balanced design lands just on the memory side at its own Table 3
   shape, and the skinny decode design is DRAM-limited everywhere.

If a constant changes on the Rust side, change it here in the same
commit.
"""

import math

# ---- 1. latency histogram (rust/src/trace/metrics.rs) ----------------

LATENCY_BUCKETS_S = [
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 1e-1,
    2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
]

# What Rust's shortest-roundtrip f64 Display (via fmt_num) prints for
# each bound — the `le="..."` label text in the Prometheus exposition.
BUCKET_LABELS = [
    "0.0001", "0.00025", "0.0005", "0.001", "0.0025", "0.005", "0.01",
    "0.025", "0.05", "0.1", "0.25", "0.5", "1", "2.5", "5", "10",
]


def bucket_index(v):
    """First bound >= v, else the overflow slot (le semantics)."""
    for i, b in enumerate(LATENCY_BUCKETS_S):
        if v <= b:
            return i
    return len(LATENCY_BUCKETS_S)


def fmt_num(n):
    """rust/src/trace/metrics.rs::fmt_num."""
    if float(n) == int(n) and abs(n) < 9e15:
        return str(int(n))
    return repr(float(n))


def test_bucket_literals():
    assert len(LATENCY_BUCKETS_S) == 16
    assert all(a < b for a, b in zip(LATENCY_BUCKETS_S, LATENCY_BUCKETS_S[1:]))
    # The spread straddles the simulated Table 2-3 device times
    # (~0.1 ms - 10 ms) with headroom for chains and stalls.
    assert LATENCY_BUCKETS_S[0] == 1e-4 and LATENCY_BUCKETS_S[-1] == 10.0


def test_bucket_index_le_semantics():
    # Mirrors metrics.rs::tests::bucket_boundaries_are_inclusive_upper.
    assert bucket_index(1e-4) == 0
    assert bucket_index(1.0000001e-4) == 1
    assert bucket_index(0.0) == 0
    assert bucket_index(10.0) == 15
    assert bucket_index(10.1) == 16  # overflow
    # Every bound lands in its own bucket; just above lands one later.
    for i, b in enumerate(LATENCY_BUCKETS_S):
        assert bucket_index(b) == i
        assert bucket_index(b * (1 + 1e-9)) == i + 1


def test_bucket_label_text():
    assert [fmt_num(b) for b in LATENCY_BUCKETS_S] == BUCKET_LABELS


def test_cumulative_counts():
    counts = [0] * (len(LATENCY_BUCKETS_S) + 1)
    for v in (2e-4, 2e-4, 3.0, 42.0):
        counts[bucket_index(v)] += 1
    assert counts[1] == 2 and counts[14] == 1 and counts[16] == 1
    assert sum(counts[:2]) == 2       # cumulative(1)
    assert sum(counts[:15]) == 3      # cumulative(14)
    assert sum(counts) == 4           # le="+Inf" == count


# ---- 2. span phase arithmetic (rust/src/trace/chrome.rs) -------------

def span_seconds(f):
    return f["t_total"] * f["dispatches"] + f["fault_stall_s"] + f["integrity_s"]


def phase_children(f):
    """(name, duration) children, non-positive elided; steady phase by
    subtraction so the partition is exact."""
    steady = f["t_total"] - f["t_prologue"] - f["t_stall"] - f["t_dispatch"]
    steady_name = "compute" if f["bound"] == "compute" else "dma"
    d = f["dispatches"]
    raw = [
        ("dma-in", f["t_prologue"] * d),
        (steady_name, steady * d),
        ("bd-stall", f["t_stall"] * d),
        ("dispatch", f["t_dispatch"] * d),
        ("fault-stall", f["fault_stall_s"]),
        ("integrity", f["integrity_s"]),
    ]
    return [(n, v) for n, v in raw if v > 0.0]


def _fact(**kw):
    base = dict(t_total=4.6e-3, t_prologue=5e-4, t_stall=0.0, t_dispatch=1e-4,
                dispatches=1.0, fault_stall_s=0.0, integrity_s=0.0, bound="compute")
    base.update(kw)
    return base


def test_phase_children_partition_the_span():
    for f in (
        _fact(),
        _fact(dispatches=12.0),
        _fact(fault_stall_s=2e-3, integrity_s=1e-4),
        _fact(t_stall=3e-4, bound="memory"),
        _fact(dispatches=7.0, t_stall=1.2e-4, fault_stall_s=4.5e-3,
              integrity_s=2.5e-4, bound="memory"),
    ):
        kids = phase_children(f)
        total = math.fsum(v for _, v in kids)
        span = span_seconds(f)
        assert abs(total - span) <= 1e-12 * max(span, 1.0), (total, span)
        # Elision: zero-duration phases never appear.
        assert all(v > 0.0 for _, v in kids)
        names = [n for n, _ in kids]
        assert names == sorted(names, key=["dma-in", "compute", "dma", "bd-stall",
                                           "dispatch", "fault-stall",
                                           "integrity"].index)


def test_steady_phase_name_tracks_bound():
    assert ("compute" in dict(phase_children(_fact(bound="compute"))))
    assert ("dma" in dict(phase_children(_fact(bound="memory"))))


# ---- 3. ridge points (rust/src/trace/roofline.rs) --------------------

PEAK_TOPS_I8 = {"xdna": 8.192, "xdna2": 58.9824}
BW_MAX = {"xdna": 32.4e9, "xdna2": 70.5e9}


def ridge_point(gen, precision):
    peak = PEAK_TOPS_I8[gen] * (0.5 if precision == "bf16" else 1.0)
    return peak * 1e12 / BW_MAX[gen]


def test_ridge_point_literals():
    assert abs(ridge_point("xdna", "i8i8") - 252.83950617283952) < 1e-9
    assert abs(ridge_point("xdna2", "i8i8") - 836.6297872340426) < 1e-9


def test_bf16_ridge_is_half_of_i8():
    for gen in ("xdna", "xdna2"):
        assert abs(ridge_point(gen, "bf16") - ridge_point(gen, "i8i8") / 2) < 1e-9


# ---- 4. bound classification (sim::engine t_comp vs t_mem) -----------
# Shared cost-model constants with test_graph_model.py / test_bfp16_model.py.

SPECS = {
    "xdna": dict(rows=4, cols=4, clock=1.0e9, dma=4.0),
    "xdna2": dict(rows=4, cols=8, clock=1.8e9, dma=8.0),
}
PEAK_MACS = {"xdna": 256.0, "xdna2": 512.0}
BETA = {"xdna": 0.0895, "xdna2": 0.068}
DRAM = {"xdna": (32.4e9, 435.0, 16.0e9), "xdna2": (70.5e9, 178.0, 57.6e9)}
BALANCED = {"xdna": (112, 112, 112, 448), "xdna2": (144, 72, 144, 432)}
# skinny_balanced_config: m_ct=16, rest inherited from the wide design.
SKINNY = {g: (16,) + BALANCED[g][1:] for g in BALANCED}


def round_up(x, q):
    return -(-x // q) * q


def bw_eff(gen, run):
    mx, x0, cap = DRAM[gen]
    return min(mx * run / (run + x0), cap)


def t_comp_t_mem(gen, cfg, m, k, n):
    """i8i8 col-major transliteration of sim::engine's two bound sides."""
    m_ct, k_ct, n_ct, k_mt = cfg
    s = SPECS[gen]
    nm, nn = m_ct * s["rows"], n_ct * s["cols"]
    pm, pk, pn = round_up(m, nm), round_up(k, k_mt), round_up(n, nn)
    kc = m_ct * k_ct * n_ct / PEAK_MACS[gen] + BETA[gen] * m_ct * n_ct
    tiles = (pm // nm) * (pn // nn)
    zero = m_ct * n_ct / 128.0
    drain = m_ct * n_ct / s["dma"]
    t_comp = tiles * ((pk // k_ct) * kc + zero + drain) / s["clock"]
    mkn = pm * pk * pn
    a_bytes, b_bytes, c_bytes = mkn / nn, mkn / nm, pm * pn
    c_run = n_ct * (2.8 if gen == "xdna" else 1.45)
    t_mem = max((a_bytes + b_bytes) / bw_eff(gen, k_mt * 1.0),
                c_bytes / bw_eff(gen, c_run))
    return t_comp, t_mem


def bound(gen, cfg, m, k, n):
    t_comp, t_mem = t_comp_t_mem(gen, cfg, m, k, n)
    return "compute" if t_comp >= t_mem else "memory"


def test_xdna_balanced_is_compute_bound_at_kilo_shapes():
    # ~7-10% compute margin: robust to model drift on either side.
    for shape in [(1024, 1024, 1024), (2048, 2048, 2048), (4032, 4032, 4032)]:
        t_comp, t_mem = t_comp_t_mem("xdna", BALANCED["xdna"], *shape)
        assert t_comp >= t_mem * 1.05, (shape, t_comp, t_mem)
        assert bound("xdna", BALANCED["xdna"], *shape) == "compute"


def test_xdna2_balanced_is_marginally_memory_bound_at_table3_shape():
    # The paper's XDNA2 design is tuned *just* onto the memory side of
    # its (much higher) ridge at its own Table 3 shape — striking the
    # balance. ~2.5% margin; the square 1024-cube is a ~0.1% knife-edge
    # and deliberately not pinned (same choice as roofline.rs tests).
    t_comp, t_mem = t_comp_t_mem("xdna2", BALANCED["xdna2"], 4032, 4320, 4608)
    assert t_mem > t_comp * 1.01, (t_comp, t_mem)
    assert bound("xdna2", BALANCED["xdna2"], 4032, 4320, 4608) == "memory"


def test_skinny_decode_is_memory_bound_everywhere():
    # A decode GEMV streams a full B panel per output row: DRAM-limited
    # by 4-6x on both generations, for any decode batch size.
    for gen in ("xdna", "xdna2"):
        for m in (1, 16, 64):
            t_comp, t_mem = t_comp_t_mem(gen, SKINNY[gen], m, 4096, 4096)
            assert t_mem > 2.0 * t_comp, (gen, m, t_comp, t_mem)
            assert bound(gen, SKINNY[gen], m, 4096, 4096) == "memory"
