"""Transliteration of the PR-8 result-integrity layer (ISSUE 8).

Mirrors, constant for constant:

* the corruption-event stream of `coordinator/fault.rs` —
  `FaultPlan::with_corruption` draws `CorruptResult { word, xor_mask }`
  events from a **separate** per-device xoshiro stream (CORRUPT_SALT),
  decorrelated from the PR-6 fault stream so arming corruption never
  shifts the existing seed-2 golden plan. The seed-2 literals pinned
  here are pinned identically in `rust/src/coordinator/fault.rs`.
* the ABFT capture checksums of `gemm/abft.rs` — per-storage-row and
  per-word-column wrapping u64 sums of the C image's raw 32-bit words.
  Bit-pattern sums make the re-validation an *exact integer* compare
  for every precision (bf16/bfp16 included), so a single corrupted word
  always changes its row sum and its column sum: detection is
  guaranteed, false positives are impossible.
* the operand grand-total invariant (Huang–Abraham): (eᵀA)(Be) vs the
  total of C — exact in int64 for i8i32, derived tolerance bounds for
  bf16/bfp16 (the `TOL_*` constants below), undefined for i8i8/i8i16
  whose saturating narrowing breaks linearity (shown adversarially).
* the sim-model cost term `abft_check_seconds` that keeps reported
  TOPS honest when the checksum pass is on.

Keep in lock-step with `rust/src/gemm/abft.rs` and
`rust/src/coordinator/fault.rs` (see `rust/tests/integrity_props.rs`).
"""

import math

import numpy as np

from test_bfp16_model import decode as bfp_decode
from test_bfp16_model import encode as bfp_encode
from test_chaos_model import M64, Rng, fault_plan

# Decorrelated per-device salt for the corruption stream (fault.rs).
CORRUPT_SALT = 0xC3A5C85C97CB3127

# Tolerance model for the operand invariant (gemm/abft.rs):
#   tol = SAFETY * abs_total * (REL + k*2^-24 + (m+n+k)*2^-52)
# REL: bf16 C elements are RNE-rounded (half-ulp 2^-9); bfp16 C blocks
# quantize to the block max (0.5/64 per element, ×8 elements per block
# in the worst case → 2^-4). k*2^-24 covers the f32 accumulation,
# (m+n+k)*2^-52 the f64 checksum arithmetic itself.
TOL_SAFETY = 2.0
TOL_REL_BF16 = 2.0 ** -9
TOL_REL_BFP16 = 2.0 ** -4


def tolerance(rel, m, k, n, abs_total):
    return TOL_SAFETY * abs_total * (rel + k * 2.0 ** -24 + (m + n + k) * 2.0 ** -52)


# --- corruption plan (fault.rs with_corruption transliteration) ---------


def corruption_events(seed, existing_seqs, horizon, per_device, d):
    """CorruptResult events for device `d`: rejection-sample fresh seqs
    against the device's existing fault seqs, then draw (word, mask) per
    seq in ascending-seq order. Mask 0 is forced to 1 (a zero xor would
    be an invisible 'corruption')."""
    rng = Rng((seed + ((d + 1) * CORRUPT_SALT)) & M64)
    horizon = max(horizon, 1)
    seen = set(existing_seqs)
    want = min(per_device, max(horizon - len(seen), 0))
    seqs = []
    while len(seqs) < want:
        c = 1 + rng.next_u64() % horizon
        if c not in seen:
            seen.add(c)
            seqs.append(c)
    seqs.sort()
    out = []
    for seq in seqs:
        word = rng.next_u64()
        mask = rng.next_u64() & 0xFFFFFFFF
        out.append((seq, word, mask if mask else 1))
    return out


def corruption_plan(seed, n_devices, horizon, per_device, base=None):
    base = base if base is not None else [[] for _ in range(n_devices)]
    out = []
    for d in range(n_devices):
        existing = [ev[0] for ev in base[d]]
        out.append(corruption_events(seed, existing, horizon, per_device, d))
    return out


def test_corruption_plan_seed2_golden():
    # The PR-6 seed-2 golden plan gains two CorruptResult events per
    # device without moving any existing event: the corruption stream is
    # salted independently. Literals pinned in fault.rs.
    base = fault_plan(2, 2, 32, 4)
    plan = corruption_plan(2, 2, 32, 2, base=base)
    assert plan[0] == [
        (21, 6898576805263037612, 0x1EDAFEBC),
        (29, 12113513064234870111, 0x9725FF6F),
    ]
    assert plan[1] == [
        (11, 10056184684129657251, 0xB1B360CB),
        (30, 6101993186801645025, 0x7B160F40),
    ]
    # Decorrelation: fresh seqs never collide with the base plan's.
    for d in range(2):
        base_seqs = {ev[0] for ev in base[d]}
        assert all(seq not in base_seqs for (seq, _w, _m) in plan[d])


def test_corruption_only_plan_seed7_golden():
    evs = corruption_events(7, [], 16, 3, 0)
    assert evs == [
        (10, 5158167014563121986, 0xA3203E96),
        (11, 5166436897857171591, 0x545A7A14),
        (12, 15423587528627081610, 0x49CACBA2),
    ]


def test_corruption_sites_in_a_64x64_i8_image():
    # Site resolution: a 64x64 int8 C is 1024 u32 words; the event's
    # word index is `word % len`. Pinned in integrity_props.rs so the
    # injected bit flips land on identical words in both languages.
    base = fault_plan(2, 2, 32, 4)
    plan = corruption_plan(2, 2, 32, 2, base=base)
    sites = [(d, seq, word % 1024, mask)
             for d in range(2) for (seq, word, mask) in plan[d]]
    assert sites == [
        (0, 21, 172, 0x1EDAFEBC),
        (0, 29, 351, 0x9725FF6F),
        (1, 11, 419, 0xB1B360CB),
        (1, 30, 481, 0x7B160F40),
    ]


def test_bfp16_pad_byte_masking():
    # A bfp16 block cell is 3 words; word 2 carries mantissa[7] in byte
    # 0 and 3 dead padding bytes. `corrupt_word` masks a pad-word flip
    # down to its live byte (and forces mask 0 → 1) so every injected
    # corruption is logically visible. 64x64 bfp16 C → 64x8 block cells
    # → 1536 words.
    def site(word, mask, n_words, bfp=True):
        idx = word % n_words
        if bfp and idx % 3 == 2:
            mask &= 0xFF
        return idx, (mask if mask else 1)

    # The seed-2 dev-0 word really lands on a pad word here (1196 % 3
    # == 2): the mask degrades to its live byte 0xBC.
    idx, mask = site(6898576805263037612, 0x1EDAFEBC, 1536)
    assert (idx, mask) == (1196, 0xBC)
    # A mask confined entirely to the dead bytes degrades to bit 0 of
    # mantissa[7] — never a no-op flip.
    idx, mask = site(5, 0x1EDAFE00, 1536)
    assert (idx, mask) == (5, 1)
    # Non-pad words keep the full 32-bit mask.
    idx, mask = site(4, 0x1EDAFE00, 1536)
    assert (idx, mask) == (4, 0x1EDAFE00)


# --- capture checksums (gemm/abft.rs transliteration) -------------------


def words_from_bytes(rows_of_bytes):
    """Little-endian u32 words per storage row (mem::Matrix layout)."""
    out = []
    for row in rows_of_bytes:
        assert len(row) % 4 == 0
        words = []
        for i in range(0, len(row), 4):
            w = row[i] | row[i + 1] << 8 | row[i + 2] << 16 | row[i + 3] << 24
            words.append(w)
        out.append(words)
    return out


def capture(word_rows):
    rows = [sum(r) & M64 for r in word_rows]
    cols = [sum(r[c] for r in word_rows) & M64 for c in range(len(word_rows[0]))]
    return rows, cols


def test_capture_sums_pin():
    # 2x4 row-major int8 C [[1,-2,3,-4],[5,6,-7,8]] → one word per row.
    img = words_from_bytes([[1, 254, 3, 252], [5, 6, 249, 8]])
    assert img == [[4228120065], [150537733]]
    rows, cols = capture(img)
    assert rows == [4228120065, 150537733]
    assert cols == [4378657798]


def test_single_word_corruption_always_detected():
    # Property behind the whole design: flipping any bit of any word
    # changes that word's u64 row sum and column sum by a nonzero delta
    # (word values < 2^32; a u64 wrapping sum of <2^32 terms cannot
    # cancel a single <2^32 change). Exercised over a seeded sweep.
    rng = np.random.default_rng(0x1B)
    for _ in range(200):
        r, c = int(rng.integers(1, 12)), int(rng.integers(1, 12))
        img = [[int(x) for x in rng.integers(0, 2 ** 32, c)] for _ in range(r)]
        rows, cols = capture(img)
        i = int(rng.integers(0, r))
        j = int(rng.integers(0, c))
        mask = int(rng.integers(1, 2 ** 32))
        img[i][j] ^= mask
        rows2, cols2 = capture(img)
        assert rows2[i] != rows[i] and cols2[j] != cols[j]
        assert [x for k, x in enumerate(rows2) if k != i] == \
               [x for k, x in enumerate(rows) if k != i]


# --- operand grand-total invariant --------------------------------------


def test_i8i32_grand_total_is_exact():
    rng = np.random.default_rng(3)
    for (m, k, n) in [(8, 16, 8), (52, 100, 36), (64, 64, 64), (17, 33, 9)]:
        a = rng.integers(-128, 128, (m, k), dtype=np.int64)
        b = rng.integers(-128, 128, (k, n), dtype=np.int64)
        c = a @ b  # i32 accumulate, no narrowing for i8i32
        want = int(np.sum(a.sum(axis=0) * b.sum(axis=1)))
        assert int(c.sum()) == want


def test_i8i8_saturation_breaks_linearity():
    # Why the int8/int16-narrowed invariant is `None` in abft.rs: the
    # saturating store is not linear, so (eᵀA)(Be) no longer equals the
    # total of the *narrowed* C. The capture sums (exact, bit-pattern)
    # carry detection for those precisions instead.
    a = np.full((4, 64), 127, dtype=np.int64)
    b = np.full((64, 4), 127, dtype=np.int64)
    c = np.clip(a @ b, -128, 127)  # every element saturates to 127
    want = int(np.sum(a.sum(axis=0) * b.sum(axis=1)))
    assert int(c.sum()) != want


def bf16_rne(x):
    """f32 → bf16 → f32 with round-to-nearest-even (dtype.rs Bf16)."""
    bits = np.asarray(x, np.float32).view(np.uint32)
    lsb = (bits >> 16) & 1
    rounded = (bits + 0x7FFF + lsb) & 0xFFFF0000
    return rounded.view(np.float32)


def test_bf16_tolerance_bound_has_zero_false_positives():
    # The Rust reference: products exact in f32 (8-bit mantissas),
    # ascending-k f32 accumulation, RNE narrowing per element. Over a
    # seeded shape grid the invariant residual must sit well inside the
    # tolerance (margin < 0.5), so the identical Rust check can never
    # fire on a clean run.
    rng = np.random.default_rng(11)
    worst = 0.0
    for (m, k, n) in [(8, 24, 16), (52, 100, 36), (64, 64, 64), (24, 56, 120)]:
        a = bf16_rne(rng.standard_normal((m, k)).astype(np.float32))
        b = bf16_rne(rng.standard_normal((k, n)).astype(np.float32))
        c = np.zeros((m, n), np.float32)
        for kk in range(k):  # ascending-k f32 accumulation
            c = (c + a[:, kk : kk + 1] * b[kk : kk + 1, :]).astype(np.float32)
        c = bf16_rne(c)
        got = float(np.sum(c, dtype=np.float64))
        want = float(np.sum(a.sum(axis=0, dtype=np.float64)
                            * b.sum(axis=1, dtype=np.float64)))
        abs_total = float(np.sum(np.abs(a).sum(axis=0, dtype=np.float64)
                                 * np.abs(b).sum(axis=1, dtype=np.float64)))
        tol = tolerance(TOL_REL_BF16, m, k, n, abs_total)
        assert abs(got - want) <= tol, (m, k, n)
        worst = max(worst, abs(got - want) / tol)
    assert worst < 0.5, f"margin too thin for a portable bound: {worst}"


def test_bfp16_tolerance_bound_has_zero_false_positives():
    rng = np.random.default_rng(13)
    worst = 0.0
    for (m, k, n) in [(16, 32, 16), (52, 104, 40), (8, 64, 24), (64, 64, 64)]:
        # Block-encoded operands (blocks along K), decoded exactly.
        def blocked(rows, cols, g):
            out = np.zeros((rows, cols), np.float32)
            for i in range(rows):
                for j0 in range(0, cols, 8):
                    e, mant = bfp_encode(g.standard_normal(8).astype(np.float32))
                    out[i, j0 : j0 + 8] = bfp_decode(e, mant)
            return out

        a = blocked(m, k, rng)
        b = blocked(n, k, rng).T  # col-major B: blocks along K
        c = np.zeros((m, n), np.float32)
        for kk in range(k):
            c = (c + a[:, kk : kk + 1] * b[kk : kk + 1, :]).astype(np.float32)
        # C re-encodes per 8-block along N.
        cq = np.zeros_like(c)
        for i in range(m):
            for j0 in range(0, n, 8):
                e, mant = bfp_encode(c[i, j0 : j0 + 8])
                cq[i, j0 : j0 + 8] = bfp_decode(e, mant)
        got = float(np.sum(cq, dtype=np.float64))
        want = float(np.sum(a.sum(axis=0, dtype=np.float64)
                            * b.sum(axis=1, dtype=np.float64)))
        abs_total = float(np.sum(np.abs(a).sum(axis=0, dtype=np.float64)
                                 * np.abs(b).sum(axis=1, dtype=np.float64)))
        tol = tolerance(TOL_REL_BFP16, m, k, n, abs_total)
        assert abs(got - want) <= tol, (m, k, n)
        worst = max(worst, abs(got - want) / tol)
    assert worst < 0.5, f"margin too thin for a portable bound: {worst}"


# --- sim-model cost term ------------------------------------------------


def test_abft_cost_model_golden():
    # checksum MACs ≈ m·k + k·n + 2·m·n + 2·k, charged at the device's
    # int-MAC rate (sim::engine::abft_check_seconds). At 1024³ on XDNA2
    # int8 the pass costs < 0.2% of the GEMM's 2·m·k·n — the headroom
    # behind the bench's ≤5% makespan bound.
    m = k = n = 1024
    macs = m * k + k * n + 2 * m * n + 2 * k
    assert macs == 4196352
    xdna2_peak_ops = 2.0 * 512 * 32 * 1.8e9
    est = macs / xdna2_peak_ops
    golden = 7.114583333333334e-08
    assert abs(est - golden) / golden < 1e-12, est
    assert macs / (2.0 * m * k * n) < 0.002
