"""Transliteration of the PR-9 Ozaki fp32-split path (ISSUE 9).

Mirrors, constant for constant:

* the error-free two-limb split of `rust/src/dtype_split.rs` —
  `hi = bf16(x)` (round-to-nearest-even), `lo = bf16(x - hi)`, residual
  `|r| <= u^2 |x|` with u = 2^-9, non-finite values riding the hi limb;
* the three-limb GEMM expansion `A.B ~ Ahi.Bhi + Ahi.Blo + Alo.Bhi`
  (LIMB_GEMMS = 3, the O(u^2) `lo.lo` term dropped), each limb
  accumulated ascending-k in f32 and rejoined `(hh + hl) + lh` in f32;
* the derived worst-case `error_bound(k, max_a, max_b)` and the ISSUE-9
  acceptance pin: >= 50x tighter than plain bf16 at <= 4x the device
  dispatches;
* the accuracy-budget economics of `graph/assign.rs` — the err-unit
  table (fp32_split = 0.001, 50x below bf16's 0.05), the LIMB_GEMMS
  time multiple, and the greedy's never-overdraw / typed-infeasible
  contract.

Keep in lock-step with `rust/src/dtype_split.rs` and
`rust/src/graph/assign.rs` (see `rust/tests/fp32split_props.rs`).
"""

import numpy as np

U_BF16 = 2.0 ** -9  # bf16 unit roundoff (8 mantissa bits + hidden one)
LIMB_GEMMS = 3

# graph/assign.rs err-unit table: error units per op at each precision
# class. fp32_split sits 50x below bf16 — the recovery the split buys.
ERR_COST = {
    "i8i8": 1.0,
    "i8i16": 0.5,
    "i8i32": 0.25,
    "bfp16": 0.25,
    "bf16": 0.05,
    "fp32_split": 0.001,
}


# ---- the limb codec (dtype_split::split_f32) ---------------------------


def bf16(x):
    """Round f32 values to bf16 (round-to-nearest-even), kept as f32."""
    x = np.asarray(x, dtype=np.float32)
    u = x.view(np.uint32)
    nan = np.isnan(x)
    rounded = (u + 0x7FFF + ((u >> np.uint32(16)) & np.uint32(1))) & np.uint32(0xFFFF0000)
    out = np.where(nan, u | np.uint32(0x00400000), rounded).view(np.float32)
    return out


def split(x):
    """hi/lo limb split; non-finite values carry entirely in hi."""
    x = np.asarray(x, dtype=np.float32)
    hi = bf16(x)
    with np.errstate(invalid="ignore"):
        lo = np.where(np.isfinite(x), bf16(np.float32(x - hi)), np.float32(0.0))
    return hi, lo


def gemm_f32(a, b):
    """Ascending-k f32 accumulation (refimpl's reduction order)."""
    m, k = a.shape
    _, n = b.shape
    acc = np.zeros((m, n), dtype=np.float32)
    for kk in range(k):
        acc += np.outer(a[:, kk], b[kk, :]).astype(np.float32)
    return acc


def split_gemm(a, b):
    """The three bf16 limb GEMMs + fixed-order f32 rejoin."""
    a_hi, a_lo = split(a)
    b_hi, b_lo = split(b)
    hh = gemm_f32(a_hi, b_hi)
    hl = gemm_f32(a_hi, b_lo)
    lh = gemm_f32(a_lo, b_hi)
    return np.float32(np.float32(hh + hl) + lh)


def error_bound(k, max_a, max_b):
    """dtype_split::error_bound, term for term."""
    split_term = 4.0 * 2.0 ** -18 * k * max_a * max_b
    accum = 3.0 * (k + 2.0) * 2.0 ** -24 * k * max_a * max_b
    subnormal = k * (max_a + max_b) * 2.0 ** -134
    return split_term + accum + subnormal


# ---- codec properties --------------------------------------------------


def test_split_is_error_free_to_second_order():
    rng = np.random.default_rng(9)
    x = (rng.standard_normal(4096) * np.exp2(rng.integers(-100, 100, 4096))).astype(
        np.float32
    )
    hi, lo = split(x)
    resid = np.abs(x.astype(np.float64) - (hi.astype(np.float64) + lo.astype(np.float64)))
    # 4u^2 = 2^-16: both roundings can land on the wide side of their
    # half-ulp near a binade edge — the same bound the Rust tests pin.
    bound = 2.0 ** -16 * np.abs(x).astype(np.float64) + 2.0 ** -134
    assert (resid <= bound).all()
    # hi alone is plain bf16: the lo limb recovers all but O(u^2).
    worst_plain = np.max(np.abs(x.astype(np.float64) - hi.astype(np.float64)))
    assert worst_plain > np.max(resid)


def test_split_handles_nonfinite_and_denormals():
    hi, lo = split(np.array([np.nan, np.inf, -np.inf], dtype=np.float32))
    assert np.isnan(hi[0]) and hi[1] == np.inf and hi[2] == -np.inf
    assert (lo == 0.0).all()
    tiny = np.array([1e-40, -3.4e-41, 1.4e-45, 0.0], dtype=np.float32)
    hi, lo = split(tiny)
    back = hi.astype(np.float64) + lo.astype(np.float64)
    assert np.isfinite(back).all()
    assert (np.abs(tiny.astype(np.float64) - back) <= 2.0 ** -134 + U_BF16 ** 2 * 1e-40).all()


# ---- GEMM accuracy -----------------------------------------------------


def test_split_gemm_stays_inside_error_bound():
    rng = np.random.default_rng(21)
    for m, k, n in [(8, 32, 8), (4, 128, 4), (16, 64, 3)]:
        a = (rng.standard_normal((m, k)) * np.exp2(rng.integers(-12, 12, (m, k)))).astype(
            np.float32
        )
        b = (rng.standard_normal((k, n)) * np.exp2(rng.integers(-12, 12, (k, n)))).astype(
            np.float32
        )
        c = split_gemm(a, b)
        oracle = a.astype(np.float64) @ b.astype(np.float64)
        err = np.max(np.abs(c.astype(np.float64) - oracle))
        bound = error_bound(k, np.max(np.abs(a)), np.max(np.abs(b)))
        assert err <= bound, f"{m}x{k}x{n}: {err} > {bound}"


def test_recovery_is_at_least_50x_over_plain_bf16():
    # The ISSUE-9 accuracy pin, mirrored: same f32 operands through the
    # split path and through plain bf16 (quantized operands, bf16 C).
    rng = np.random.default_rng(11)
    m, k, n = (64, 512, 64)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    oracle = a.astype(np.float64) @ b.astype(np.float64)

    c_split = split_gemm(a, b)
    err_split = np.max(np.abs(c_split.astype(np.float64) - oracle))
    assert err_split <= error_bound(k, np.max(np.abs(a)), np.max(np.abs(b)))

    c_bf16 = bf16(gemm_f32(bf16(a), bf16(b)))
    err_bf16 = np.max(np.abs(c_bf16.astype(np.float64) - oracle))

    assert err_bf16 >= 50.0 * err_split, f"recovery {err_bf16 / err_split:.1f}x < 50x"
    # ...at <= 4x the device dispatches (the simulated-time multiple the
    # Rust cost sites charge per fp32_split op).
    assert LIMB_GEMMS <= 4


# ---- accuracy-budget economics (graph/assign.rs) -----------------------


def test_err_cost_table_puts_split_50x_below_bf16():
    assert ERR_COST["bf16"] / ERR_COST["fp32_split"] == 50.0
    # fp32_split is the most accurate and (at 3 dispatches of the same
    # bf16 design) the slowest tier: it only wins below bf16's floor.
    assert ERR_COST["fp32_split"] == min(ERR_COST.values())


def greedy_assign(n_nodes, budget_per_node):
    """graph/assign.rs greedy, single component: fastest class whose
    err fits the remaining budget; typed failure when even the most
    accurate class does not fit."""
    budget = budget_per_node * n_nodes
    # (class, err units, relative time) fastest-first; fp32_split pays
    # the LIMB_GEMMS multiple on the bf16 time.
    cands = [("i8i8", 1.0, 1.0), ("bf16", 0.05, 2.0), ("fp32_split", 0.001, 2.0 * LIMB_GEMMS)]
    remaining = budget
    err = n_nodes * min(c[1] for c in cands)
    picks = []
    for _ in range(n_nodes):
        err -= min(c[1] for c in cands)  # reserve for the nodes after me
        pick = next((c for c in cands if c[1] <= remaining - err + 1e-12), None)
        if pick is None:
            cheapest = min(c[1] for c in cands)
            raise ValueError(
                f"accuracy budget infeasible: needs >= {cheapest} error units "
                f"but only {remaining - err} of the {budget}-unit budget remains"
            )
        picks.append(pick[0])
        remaining -= pick[1]
    assert remaining >= -1e-12, "greedy overdrew the budget"
    return picks


def test_sub_bf16_budget_buys_fp32_split():
    picks = greedy_assign(4, 0.01)
    assert picks == ["fp32_split"] * 4
    assert greedy_assign(4, 0.06) == ["bf16"] * 4
    assert greedy_assign(4, 1.0) == ["i8i8"] * 4


def test_infeasible_budget_is_a_typed_error_not_an_overdraw():
    try:
        greedy_assign(4, 0.0005)
    except ValueError as e:
        assert "infeasible" in str(e)
    else:
        raise AssertionError("expected the greedy to refuse the infeasible budget")
