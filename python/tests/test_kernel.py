"""Layer-1 correctness: Pallas GEMM kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the build path: if these pass, the
HLO that `compile.aot` ships to the Rust runtime computes the paper's
kernel semantics (int32 accumulate + saturating narrow for int8 modes,
f32 accumulate for bf16).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gemm import (
    KernelSpec,
    make_panel_gemm,
    make_panel_gemm_acc,
    make_single_core_gemm,
)

PRECS = list(ref.PRECISIONS)


def rand_inputs(rng, m, k, n, prec, extreme=False):
    if prec == "bf16":
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.bfloat16)
    else:
        lo, hi = (-128, 128) if extreme else (-16, 16)
        a = jnp.asarray(rng.integers(lo, hi, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(lo, hi, (k, n)), jnp.int8)
    return a, b


def assert_matches(got, want, prec, narrowed=False):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    if prec == "bf16":
        # f32 accumulation order differs between blocked and one-shot matmul;
        # after bf16 narrowing values may differ by 1 ulp near ties.
        tol = 2.0 ** -7 if narrowed else 1e-5
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)
    else:
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("prec", PRECS)
@pytest.mark.parametrize("b_col_major", [False, True])
def test_panel_gemm_matches_ref(prec, b_col_major):
    rng = np.random.default_rng(42)
    spec = KernelSpec(8, 16, 8, prec, b_col_major=b_col_major)
    m, k, n = 24, 48, 16
    a, b = rand_inputs(rng, m, k, n, prec)
    fn = make_panel_gemm(spec, m, k, n)
    got = fn(a, b.T if b_col_major else b)
    assert_matches(got, ref.ref_gemm_acc(a, b, prec), prec)


@pytest.mark.parametrize("prec", PRECS)
def test_single_core_kernel_narrowing(prec):
    """The single-core kernel narrows with saturation (paper Sec. 5.1)."""
    rng = np.random.default_rng(7)
    spec = KernelSpec(8, 32, 8, prec)
    a, b = rand_inputs(rng, 8, 32, 8, prec, extreme=True)
    got = make_single_core_gemm(spec)(a, b)
    want = ref.ref_gemm(a, b, prec)
    assert got.dtype == want.dtype == ref.out_dtype(prec)
    assert_matches(got, want, prec, narrowed=True)
    if prec == "i8i8":
        # int8 x int8 over K=32 virtually always saturates with extreme
        # inputs — make sure the clamp actually engaged.
        w = np.asarray(want, np.int64)
        assert w.max() == 127 or w.min() == -128


@pytest.mark.parametrize("prec", PRECS)
def test_accumulator_carry(prec):
    """Seeded-accumulator variant: acc' = acc + A@B (native-step semantics)."""
    rng = np.random.default_rng(3)
    r, s, t = ref.MICRO_TILE[prec]
    spec = KernelSpec(r, s, t, prec)
    m, k, n = 2 * r, 2 * s, 2 * t
    a, b = rand_inputs(rng, m, k, n, prec)
    first = ref.ref_gemm_acc(a, b, prec)
    got = make_panel_gemm_acc(spec, m, k, n)(a, b, first)
    assert_matches(got, 2 * np.asarray(first, np.float64), prec)


def test_micro_tile_validation():
    with pytest.raises(ValueError):
        KernelSpec(6, 16, 8, "i8i8")  # m_ct not a multiple of r=4
    with pytest.raises(ValueError):
        KernelSpec(8, 12, 8, "i8i8")  # k_ct not a multiple of s=8
    with pytest.raises(ValueError):
        KernelSpec(8, 16, 6, "bf16")  # n_ct not a multiple of t=4


def test_paper_kernel_shapes_are_valid():
    """Every kernel size published in Tables 1-3 obeys the micro-tile rule."""
    table = [
        ("i8i8", 64, 232, 64), ("i8i16", 64, 216, 64), ("i8i32", 48, 280, 48),
        ("bf16", 64, 104, 64), ("bf16", 48, 152, 48),
        ("i8i8", 112, 112, 112), ("i8i16", 96, 112, 96), ("i8i32", 80, 88, 96),
        ("bf16", 96, 56, 96), ("i8i8", 144, 72, 144), ("i8i16", 128, 72, 112),
        ("i8i32", 96, 64, 96), ("bf16", 112, 48, 96),
    ]
    for prec, m, k, n in table:
        KernelSpec(m, k, n, prec)  # must not raise


# ---- hypothesis sweeps: shapes, dtypes, layouts -----------------------------

tile_counts = st.integers(min_value=1, max_value=3)


@settings(max_examples=25, deadline=None)
@given(
    prec=st.sampled_from(PRECS),
    mi=tile_counts, ki=tile_counts, ni=tile_counts,
    b_col_major=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shapes(prec, mi, ki, ni, b_col_major, seed):
    r, s, t = ref.MICRO_TILE[prec]
    m_ct, k_ct, n_ct = 2 * r, s, t
    spec = KernelSpec(m_ct, k_ct, n_ct, prec, b_col_major=b_col_major)
    m, k, n = mi * m_ct, ki * k_ct, ni * n_ct
    rng = np.random.default_rng(seed)
    a, b = rand_inputs(rng, m, k, n, prec, extreme=True)
    got = make_panel_gemm(spec, m, k, n)(a, b.T if b_col_major else b)
    assert_matches(got, ref.ref_gemm_acc(a, b, prec), prec)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_saturation_boundaries(seed):
    """Saturating narrow near the int8/int16 boundaries matches the oracle."""
    rng = np.random.default_rng(seed)
    # K=256 of +/-128 products reaches +/-4M: far past int16.
    spec = KernelSpec(4, 256, 8, "i8i16")
    a = jnp.asarray(rng.choice([-128, -1, 1, 127], (4, 256)), jnp.int8)
    b = jnp.asarray(rng.choice([-128, -1, 1, 127], (256, 8)), jnp.int8)
    got = make_single_core_gemm(spec)(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.ref_gemm(a, b, "i8i16")))
