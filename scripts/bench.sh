#!/usr/bin/env bash
# Perf-trajectory artifact (ISSUE 3, extended by ISSUE 4): run the
# hotpath, chain_vs_isolated and bfp16_vs_bf16 benches with JSON
# recording enabled and merge them into BENCH_PR4.json — GEMM/s,
# functional GB/s, the packing / threading speedups over the
# re-streaming serial executor, and the native-bfp16 vs bf16-emulation
# speedup — so future PRs can diff against a machine-readable baseline.
#
# usage: scripts/bench.sh [out.json]     (default: BENCH_PR4.json)
#        BENCH_MS=500 scripts/bench.sh   (longer per-case budget)
set -euo pipefail
cd "$(dirname "$0")/.."

out="${1:-BENCH_PR4.json}"
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

export BENCH_MS="${BENCH_MS:-200}"

echo "==> cargo bench --bench hotpath"
BENCH_JSON="$tmp/hotpath.json" cargo bench --bench hotpath

echo "==> cargo bench --bench chain_vs_isolated"
BENCH_JSON="$tmp/chain.json" cargo bench --bench chain_vs_isolated

echo "==> cargo bench --bench bfp16_vs_bf16"
BENCH_JSON="$tmp/bfp16.json" cargo bench --bench bfp16_vs_bf16

echo "==> merging into $out"
python3 - "$tmp/hotpath.json" "$tmp/chain.json" "$tmp/bfp16.json" "$out" <<'PY'
import json
import sys

hot, chain, bfp, out = sys.argv[1], sys.argv[2], sys.argv[3], sys.argv[4]
groups = [json.load(open(p)) for p in (hot, chain, bfp)]


def thrpt(group, name):
    for t in group.get("throughput", []):
        if t["name"] == name:
            return t["value"]
    return None


summary = {
    "artifact": "BENCH_PR4",
    "description": "packed+parallel functional executor vs re-streaming serial "
    "baseline, plus native bfp16 vs bf16 emulation on XDNA2",
    "gemms_per_s": thrpt(groups[0], "executor_gemms_per_s"),
    "functional_gb_per_s": thrpt(groups[0], "executor_functional_gb_s"),
    "packing_speedup_serial": thrpt(groups[0], "executor_packing_speedup"),
    "threads8_speedup": thrpt(groups[0], "executor_threads8_speedup"),
    "bfp16_vs_bf16_speedup": thrpt(groups[2], "bfp16_vs_bf16_speedup"),
    "bfp16_vs_bf16_aligned_speedup": thrpt(groups[2], "bfp16_vs_bf16_aligned_speedup"),
    "bfp16_table3_tops": thrpt(groups[2], "bfp16_table3_tops"),
    "groups": groups,
}
with open(out, "w") as f:
    json.dump(summary, f, indent=2)
print(f"wrote {out}")
PY
