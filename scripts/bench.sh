#!/usr/bin/env bash
# Perf-trajectory artifact (ISSUE 3, extended by ISSUEs 4–9): run the
# hotpath, chain_vs_isolated, bfp16_vs_bf16, graph_vs_chain, soak,
# llm_serving, abft_overhead and fp32_split benches with JSON recording
# enabled and merge them into BENCH_PR9.json — GEMM/s, functional GB/s,
# packing/threading speedups, the native-bfp16 vs bf16-emulation
# speedup, the graph compiler's DAG-aware-schedule speedups, the
# chaos-soak's sustained TOPS / p99 / fault counters, the
# continuous-batching LLM serving tokens/s + p50/p99 token latency +
# coalescing speedup, the ABFT integrity layer's device-time overhead
# vs integrity-off and vs a full reference recompute, and the Ozaki
# fp32-split path's accuracy recovery over bf16 + its simulated device
# cost — so future PRs can diff against a machine-readable baseline.
#
# usage: scripts/bench.sh [out.json]     (default: BENCH_PR9.json)
#        BENCH_MS=500 scripts/bench.sh   (longer per-case budget)
#        SOAK_OPS=1500 scripts/bench.sh  (shorter soak horizon)
#        LLM_SESSIONS=6 scripts/bench.sh (lighter serving load)
set -euo pipefail
cd "$(dirname "$0")/.."

out="${1:-BENCH_PR9.json}"
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

export BENCH_MS="${BENCH_MS:-200}"
export SOAK_OPS="${SOAK_OPS:-10000}"

echo "==> cargo bench --bench hotpath"
BENCH_JSON="$tmp/hotpath.json" cargo bench --bench hotpath

echo "==> cargo bench --bench chain_vs_isolated"
BENCH_JSON="$tmp/chain.json" cargo bench --bench chain_vs_isolated

echo "==> cargo bench --bench bfp16_vs_bf16"
BENCH_JSON="$tmp/bfp16.json" cargo bench --bench bfp16_vs_bf16

echo "==> cargo bench --bench graph_vs_chain"
BENCH_JSON="$tmp/graph.json" cargo bench --bench graph_vs_chain

echo "==> cargo bench --bench soak (SOAK_OPS=$SOAK_OPS)"
BENCH_JSON="$tmp/soak.json" cargo bench --bench soak

echo "==> cargo bench --bench llm_serving"
BENCH_JSON="$tmp/llm.json" cargo bench --bench llm_serving

echo "==> cargo bench --bench abft_overhead"
BENCH_JSON="$tmp/abft.json" cargo bench --bench abft_overhead

echo "==> cargo bench --bench fp32_split"
BENCH_JSON="$tmp/fp32split.json" cargo bench --bench fp32_split

echo "==> merging into $out"
python3 - "$tmp/hotpath.json" "$tmp/chain.json" "$tmp/bfp16.json" "$tmp/graph.json" \
    "$tmp/soak.json" "$tmp/llm.json" "$tmp/abft.json" "$tmp/fp32split.json" "$out" <<'PY'
import json
import sys

hot, chain, bfp, graph, soak, llm, abft, fp32split, out = sys.argv[1:10]
groups = [json.load(open(p)) for p in (hot, chain, bfp, graph, soak, llm, abft, fp32split)]


def thrpt(group, name):
    for t in group.get("throughput", []):
        if t["name"] == name:
            return t["value"]
    return None


summary = {
    "artifact": "BENCH_PR9",
    "description": "packed+parallel functional executor vs re-streaming serial "
    "baseline, native bfp16 vs bf16 emulation on XDNA2, the graph "
    "compiler's DAG-aware fleet schedule vs isolated-dispatch and "
    "single-device-chain baselines, the two-tenant chaos soak "
    "(sustained TOPS / p99 under seeded fault injection), the "
    "continuous-batching LLM serving runtime (tokens/s, p50/p99 token "
    "latency, coalesced-vs-per-session decode speedup on both "
    "generations), and the ABFT integrity layer's device-time overhead "
    "at the paper's Table 2-3 shapes (vs integrity-off and vs a full "
    "reference recompute, both generations), and the fp32-split "
    "path's accuracy recovery over plain bf16 at its LIMB_GEMMS-dispatch "
    "simulated device cost",
    "gemms_per_s": thrpt(groups[0], "executor_gemms_per_s"),
    "functional_gb_per_s": thrpt(groups[0], "executor_functional_gb_s"),
    "packing_speedup_serial": thrpt(groups[0], "executor_packing_speedup"),
    "threads8_speedup": thrpt(groups[0], "executor_threads8_speedup"),
    "bfp16_vs_bf16_speedup": thrpt(groups[2], "bfp16_vs_bf16_speedup"),
    "bfp16_vs_bf16_aligned_speedup": thrpt(groups[2], "bfp16_vs_bf16_aligned_speedup"),
    "bfp16_table3_tops": thrpt(groups[2], "bfp16_table3_tops"),
    "graph_vs_isolated_speedup_xdna": thrpt(groups[3], "graph_vs_isolated_speedup_xdna"),
    "graph_vs_isolated_speedup_xdna2": thrpt(groups[3], "graph_vs_isolated_speedup_xdna2"),
    "graph_vs_chain_speedup_xdna": thrpt(groups[3], "graph_vs_chain_speedup_xdna"),
    "graph_vs_chain_speedup_xdna2": thrpt(groups[3], "graph_vs_chain_speedup_xdna2"),
    "moe_vs_isolated_speedup_xdna2": thrpt(groups[3], "moe_vs_isolated_speedup_xdna2"),
    "moe_vs_chain_speedup_xdna2": thrpt(groups[3], "moe_vs_chain_speedup_xdna2"),
    "soak_ops_per_s": thrpt(groups[4], "soak_ops_per_s"),
    "soak_fleet_tops": thrpt(groups[4], "soak_fleet_tops"),
    "soak_sustained_tops": thrpt(groups[4], "soak_sustained_tops"),
    "soak_p99_device_ms": thrpt(groups[4], "soak_p99_device_ms"),
    "soak_faults_fired": thrpt(groups[4], "soak_faults_fired"),
    "soak_requeues": thrpt(groups[4], "soak_requeues"),
    "llm_tokens_per_s_xdna2": thrpt(groups[5], "llm_tokens_per_s_xdna2"),
    "llm_token_p50_ms_xdna2": thrpt(groups[5], "llm_token_p50_ms_xdna2"),
    "llm_token_p99_ms_xdna2": thrpt(groups[5], "llm_token_p99_ms_xdna2"),
    "llm_coalesce_speedup_xdna2": thrpt(groups[5], "llm_coalesce_speedup_xdna2"),
    "llm_tokens_per_s_xdna": thrpt(groups[5], "llm_tokens_per_s_xdna"),
    "llm_token_p50_ms_xdna": thrpt(groups[5], "llm_token_p50_ms_xdna"),
    "llm_token_p99_ms_xdna": thrpt(groups[5], "llm_token_p99_ms_xdna"),
    "llm_coalesce_speedup_xdna": thrpt(groups[5], "llm_coalesce_speedup_xdna"),
    "abft_overhead_pct_xdna": thrpt(groups[6], "abft_overhead_pct_xdna"),
    "abft_overhead_pct_xdna2": thrpt(groups[6], "abft_overhead_pct_xdna2"),
    "full_verify_overhead_pct_xdna": thrpt(groups[6], "full_verify_overhead_pct_xdna"),
    "full_verify_overhead_pct_xdna2": thrpt(groups[6], "full_verify_overhead_pct_xdna2"),
    "full_over_abft_cost_ratio_xdna": thrpt(groups[6], "full_over_abft_cost_ratio_xdna"),
    "full_over_abft_cost_ratio_xdna2": thrpt(groups[6], "full_over_abft_cost_ratio_xdna2"),
    "fp32_split_recovery_x": thrpt(groups[7], "fp32_split_recovery_x"),
    "fp32_split_cost_ratio_xdna": thrpt(groups[7], "fp32_split_cost_ratio_xdna"),
    "fp32_split_cost_ratio_xdna2": thrpt(groups[7], "fp32_split_cost_ratio_xdna2"),
    "groups": groups,
}
with open(out, "w") as f:
    json.dump(summary, f, indent=2)
print(f"wrote {out}")
PY
