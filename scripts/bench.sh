#!/usr/bin/env bash
# Perf-trajectory artifact (ISSUE 3, extended by ISSUEs 4–5): run the
# hotpath, chain_vs_isolated, bfp16_vs_bf16 and graph_vs_chain benches
# with JSON recording enabled and merge them into BENCH_PR5.json —
# GEMM/s, functional GB/s, packing/threading speedups, the native-bfp16
# vs bf16-emulation speedup, and the graph compiler's DAG-aware-schedule
# speedups over the isolated-dispatch and single-device-chain baselines
# (both generations) — so future PRs can diff against a machine-readable
# baseline.
#
# usage: scripts/bench.sh [out.json]     (default: BENCH_PR5.json)
#        BENCH_MS=500 scripts/bench.sh   (longer per-case budget)
set -euo pipefail
cd "$(dirname "$0")/.."

out="${1:-BENCH_PR5.json}"
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

export BENCH_MS="${BENCH_MS:-200}"

echo "==> cargo bench --bench hotpath"
BENCH_JSON="$tmp/hotpath.json" cargo bench --bench hotpath

echo "==> cargo bench --bench chain_vs_isolated"
BENCH_JSON="$tmp/chain.json" cargo bench --bench chain_vs_isolated

echo "==> cargo bench --bench bfp16_vs_bf16"
BENCH_JSON="$tmp/bfp16.json" cargo bench --bench bfp16_vs_bf16

echo "==> cargo bench --bench graph_vs_chain"
BENCH_JSON="$tmp/graph.json" cargo bench --bench graph_vs_chain

echo "==> merging into $out"
python3 - "$tmp/hotpath.json" "$tmp/chain.json" "$tmp/bfp16.json" "$tmp/graph.json" "$out" <<'PY'
import json
import sys

hot, chain, bfp, graph, out = sys.argv[1:6]
groups = [json.load(open(p)) for p in (hot, chain, bfp, graph)]


def thrpt(group, name):
    for t in group.get("throughput", []):
        if t["name"] == name:
            return t["value"]
    return None


summary = {
    "artifact": "BENCH_PR5",
    "description": "packed+parallel functional executor vs re-streaming serial "
    "baseline, native bfp16 vs bf16 emulation on XDNA2, and the graph "
    "compiler's DAG-aware fleet schedule vs isolated-dispatch and "
    "single-device-chain baselines",
    "gemms_per_s": thrpt(groups[0], "executor_gemms_per_s"),
    "functional_gb_per_s": thrpt(groups[0], "executor_functional_gb_s"),
    "packing_speedup_serial": thrpt(groups[0], "executor_packing_speedup"),
    "threads8_speedup": thrpt(groups[0], "executor_threads8_speedup"),
    "bfp16_vs_bf16_speedup": thrpt(groups[2], "bfp16_vs_bf16_speedup"),
    "bfp16_vs_bf16_aligned_speedup": thrpt(groups[2], "bfp16_vs_bf16_aligned_speedup"),
    "bfp16_table3_tops": thrpt(groups[2], "bfp16_table3_tops"),
    "graph_vs_isolated_speedup_xdna": thrpt(groups[3], "graph_vs_isolated_speedup_xdna"),
    "graph_vs_isolated_speedup_xdna2": thrpt(groups[3], "graph_vs_isolated_speedup_xdna2"),
    "graph_vs_chain_speedup_xdna": thrpt(groups[3], "graph_vs_chain_speedup_xdna"),
    "graph_vs_chain_speedup_xdna2": thrpt(groups[3], "graph_vs_chain_speedup_xdna2"),
    "moe_vs_isolated_speedup_xdna2": thrpt(groups[3], "moe_vs_isolated_speedup_xdna2"),
    "moe_vs_chain_speedup_xdna2": thrpt(groups[3], "moe_vs_chain_speedup_xdna2"),
    "groups": groups,
}
with open(out, "w") as f:
    json.dump(summary, f, indent=2)
print(f"wrote {out}")
PY
