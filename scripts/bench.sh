#!/usr/bin/env bash
# Perf-trajectory artifact (ISSUE 3, extended by ISSUEs 4–10): run the
# hotpath, chain_vs_isolated, bfp16_vs_bf16, graph_vs_chain, soak,
# llm_serving, abft_overhead, fp32_split and trace_overhead benches with
# JSON recording enabled and merge them into BENCH_PR${PR}.json —
# GEMM/s, functional GB/s, packing/threading speedups, the native-bfp16
# vs bf16-emulation speedup, the graph compiler's DAG-aware-schedule
# speedups, the chaos-soak's sustained TOPS / p99 / fault counters, the
# continuous-batching LLM serving tokens/s + p50/p99 token latency +
# coalescing speedup, the ABFT integrity layer's device-time overhead
# vs integrity-off and vs a full reference recompute, the Ozaki
# fp32-split path's accuracy recovery over bf16 + its simulated device
# cost, and the flight recorder's device-time overhead (gated ≤1%, and
# bit-identical in practice) — so future PRs can diff against a
# machine-readable baseline. scripts/bench_trend.py reads every
# BENCH_PR*.json in the repo root and prints the per-key trajectory.
#
# usage: scripts/bench.sh [out.json]     (default: BENCH_PR${PR}.json)
#        PR=11 scripts/bench.sh          (stamp a different PR number)
#        BENCH_MS=500 scripts/bench.sh   (longer per-case budget)
#        SOAK_OPS=1500 scripts/bench.sh  (shorter soak horizon)
#        LLM_SESSIONS=6 scripts/bench.sh (lighter serving load)
set -euo pipefail
cd "$(dirname "$0")/.."

PR="${PR:-10}"
out="${1:-BENCH_PR${PR}.json}"
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

export BENCH_MS="${BENCH_MS:-200}"
export SOAK_OPS="${SOAK_OPS:-10000}"

benches=(hotpath chain_vs_isolated bfp16_vs_bf16 graph_vs_chain soak \
    llm_serving abft_overhead fp32_split trace_overhead)
json_args=()
for bench in "${benches[@]}"; do
    echo "==> cargo bench --bench $bench"
    BENCH_JSON="$tmp/$bench.json" cargo bench --bench "$bench"
    json_args+=("$tmp/$bench.json")
done

echo "==> merging into $out"
python3 - "$PR" "${json_args[@]}" "$out" <<'PY'
import json
import sys

pr = sys.argv[1]
*paths, out = sys.argv[2:]
groups = [json.load(open(p)) for p in paths]
hot, chain, bfp, graph, soak, llm, abft, fp32split, trace = groups


def thrpt(group, name):
    for t in group.get("throughput", []):
        if t["name"] == name:
            return t["value"]
    return None


summary = {
    "artifact": f"BENCH_PR{pr}",
    "description": "packed+parallel functional executor vs re-streaming serial "
    "baseline, native bfp16 vs bf16 emulation on XDNA2, the graph "
    "compiler's DAG-aware fleet schedule vs isolated-dispatch and "
    "single-device-chain baselines, the two-tenant chaos soak "
    "(sustained TOPS / p99 under seeded fault injection), the "
    "continuous-batching LLM serving runtime (tokens/s, p50/p99 token "
    "latency, coalesced-vs-per-session decode speedup on both "
    "generations), the ABFT integrity layer's device-time overhead "
    "at the paper's Table 2-3 shapes (vs integrity-off and vs a full "
    "reference recompute, both generations), the fp32-split "
    "path's accuracy recovery over plain bf16 at its LIMB_GEMMS-dispatch "
    "simulated device cost, and the flight recorder's virtual-device-time "
    "overhead (host-side recorder; gated at 1% and bit-identical in "
    "practice, both generations)",
    "gemms_per_s": thrpt(hot, "executor_gemms_per_s"),
    "functional_gb_per_s": thrpt(hot, "executor_functional_gb_s"),
    "packing_speedup_serial": thrpt(hot, "executor_packing_speedup"),
    "threads8_speedup": thrpt(hot, "executor_threads8_speedup"),
    "bfp16_vs_bf16_speedup": thrpt(bfp, "bfp16_vs_bf16_speedup"),
    "bfp16_vs_bf16_aligned_speedup": thrpt(bfp, "bfp16_vs_bf16_aligned_speedup"),
    "bfp16_table3_tops": thrpt(bfp, "bfp16_table3_tops"),
    "graph_vs_isolated_speedup_xdna": thrpt(graph, "graph_vs_isolated_speedup_xdna"),
    "graph_vs_isolated_speedup_xdna2": thrpt(graph, "graph_vs_isolated_speedup_xdna2"),
    "graph_vs_chain_speedup_xdna": thrpt(graph, "graph_vs_chain_speedup_xdna"),
    "graph_vs_chain_speedup_xdna2": thrpt(graph, "graph_vs_chain_speedup_xdna2"),
    "moe_vs_isolated_speedup_xdna2": thrpt(graph, "moe_vs_isolated_speedup_xdna2"),
    "moe_vs_chain_speedup_xdna2": thrpt(graph, "moe_vs_chain_speedup_xdna2"),
    "soak_ops_per_s": thrpt(soak, "soak_ops_per_s"),
    "soak_fleet_tops": thrpt(soak, "soak_fleet_tops"),
    "soak_sustained_tops": thrpt(soak, "soak_sustained_tops"),
    "soak_p99_device_ms": thrpt(soak, "soak_p99_device_ms"),
    "soak_faults_fired": thrpt(soak, "soak_faults_fired"),
    "soak_requeues": thrpt(soak, "soak_requeues"),
    "llm_tokens_per_s_xdna2": thrpt(llm, "llm_tokens_per_s_xdna2"),
    "llm_token_p50_ms_xdna2": thrpt(llm, "llm_token_p50_ms_xdna2"),
    "llm_token_p99_ms_xdna2": thrpt(llm, "llm_token_p99_ms_xdna2"),
    "llm_coalesce_speedup_xdna2": thrpt(llm, "llm_coalesce_speedup_xdna2"),
    "llm_tokens_per_s_xdna": thrpt(llm, "llm_tokens_per_s_xdna"),
    "llm_token_p50_ms_xdna": thrpt(llm, "llm_token_p50_ms_xdna"),
    "llm_token_p99_ms_xdna": thrpt(llm, "llm_token_p99_ms_xdna"),
    "llm_coalesce_speedup_xdna": thrpt(llm, "llm_coalesce_speedup_xdna"),
    "abft_overhead_pct_xdna": thrpt(abft, "abft_overhead_pct_xdna"),
    "abft_overhead_pct_xdna2": thrpt(abft, "abft_overhead_pct_xdna2"),
    "full_verify_overhead_pct_xdna": thrpt(abft, "full_verify_overhead_pct_xdna"),
    "full_verify_overhead_pct_xdna2": thrpt(abft, "full_verify_overhead_pct_xdna2"),
    "full_over_abft_cost_ratio_xdna": thrpt(abft, "full_over_abft_cost_ratio_xdna"),
    "full_over_abft_cost_ratio_xdna2": thrpt(abft, "full_over_abft_cost_ratio_xdna2"),
    "fp32_split_recovery_x": thrpt(fp32split, "fp32_split_recovery_x"),
    "fp32_split_cost_ratio_xdna": thrpt(fp32split, "fp32_split_cost_ratio_xdna"),
    "fp32_split_cost_ratio_xdna2": thrpt(fp32split, "fp32_split_cost_ratio_xdna2"),
    "trace_device_time_overhead_pct_xdna": thrpt(trace, "trace_device_time_overhead_pct_xdna"),
    "trace_device_time_overhead_pct_xdna2": thrpt(trace, "trace_device_time_overhead_pct_xdna2"),
    "trace_facts_per_request_xdna": thrpt(trace, "trace_facts_per_request_xdna"),
    "trace_facts_per_request_xdna2": thrpt(trace, "trace_facts_per_request_xdna2"),
    "groups": groups,
}
with open(out, "w") as f:
    json.dump(summary, f, indent=2)
print(f"wrote {out}")
PY

echo "==> trend across BENCH_PR*.json"
# Fails (exit 1) if a pinned speedup key regressed >10% vs the previous
# PR's artifact, so a perf regression is caught at bench time.
python3 scripts/bench_trend.py
