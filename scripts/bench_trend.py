#!/usr/bin/env python3
"""Perf trajectory across PR bench artifacts.

Loads every ``BENCH_PR*.json`` in the repo root (the artifacts
``scripts/bench.sh`` writes, one per PR), prints a per-key trajectory
table ordered by PR number, and gates the pinned speedup keys: if the
newest artifact regressed more than ``REGRESSION_PCT`` (10%) below the
previous artifact on any key in ``PINNED`` that both artifacts carry,
the script exits nonzero with the offending keys named.

Keys only present in newer artifacts (each PR extends the schema) are
shown with ``-`` for the PRs that predate them and are never treated as
regressions. With fewer than two artifacts there is nothing to compare;
the table (if any) still prints and the gate passes.

usage: scripts/bench_trend.py [root-dir]
"""

import glob
import json
import os
import re
import sys

REGRESSION_PCT = 10.0

# Higher-is-better keys gated against the previous PR's artifact. Pure
# measurements (TOPS, tokens/s) wobble with runner hardware, so the gate
# pins the *ratios* — speedups and recovery factors are self-normalizing
# (numerator and denominator run on the same machine).
PINNED = [
    "packing_speedup_serial",
    "threads8_speedup",
    "bfp16_vs_bf16_speedup",
    "graph_vs_isolated_speedup_xdna",
    "graph_vs_isolated_speedup_xdna2",
    "graph_vs_chain_speedup_xdna",
    "graph_vs_chain_speedup_xdna2",
    "llm_coalesce_speedup_xdna",
    "llm_coalesce_speedup_xdna2",
    "fp32_split_recovery_x",
]


def load_artifacts(root):
    arts = []
    for path in glob.glob(os.path.join(root, "BENCH_PR*.json")):
        m = re.fullmatch(r"BENCH_PR(\d+)\.json", os.path.basename(path))
        if not m:
            continue
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {path}: {e}", file=sys.stderr)
            continue
        arts.append((int(m.group(1)), os.path.basename(path), data))
    arts.sort()
    return arts


def numeric_keys(arts):
    """Every scalar key across all artifacts, first-seen order."""
    keys = []
    for _, _, data in arts:
        for k, v in data.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool) and k not in keys:
                keys.append(k)
    return keys


def fmt(v):
    if v is None:
        return "-"
    if abs(v) >= 1000:
        return f"{v:.0f}"
    return f"{v:.3g}"


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.join(os.path.dirname(__file__), "..")
    arts = load_artifacts(root)
    if not arts:
        print("no BENCH_PR*.json artifacts found — run scripts/bench.sh first")
        return 0

    keys = numeric_keys(arts)
    cols = [f"PR{pr}" for pr, _, _ in arts]
    width = max(len(k) for k in keys)
    print(f"{'key':<{width}}  " + "  ".join(f"{c:>10}" for c in cols))
    for k in keys:
        row = [fmt(data.get(k)) for _, _, data in arts]
        print(f"{k:<{width}}  " + "  ".join(f"{v:>10}" for v in row))

    if len(arts) < 2:
        print("\nonly one artifact — nothing to gate against")
        return 0

    (_, prev_name, prev), (_, cur_name, cur) = arts[-2], arts[-1]
    regressions = []
    for k in PINNED:
        a, b = prev.get(k), cur.get(k)
        if not isinstance(a, (int, float)) or not isinstance(b, (int, float)) or a <= 0:
            continue
        drop_pct = 100.0 * (a - b) / a
        if drop_pct > REGRESSION_PCT:
            regressions.append((k, a, b, drop_pct))

    if regressions:
        print(f"\nREGRESSION: {cur_name} vs {prev_name} (>{REGRESSION_PCT:.0f}% drop):")
        for k, a, b, drop in regressions:
            print(f"  {k}: {fmt(a)} -> {fmt(b)}  ({drop:.1f}% drop)")
        return 1

    print(f"\nok: no pinned key regressed >{REGRESSION_PCT:.0f}% ({cur_name} vs {prev_name})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
