#!/usr/bin/env bash
# Pre-PR gate (documented in README.md, run by .github/workflows/ci.yml):
# release build, tests, a rustdoc pass with warnings denied so the doc
# layer cannot rot, and the python suite when pytest is available.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (rustdoc warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

if python3 -c 'import pytest' >/dev/null 2>&1; then
  echo "==> python -m pytest python/tests -q"
  python3 -m pytest python/tests -q
else
  echo "==> skipping python tests (pytest not installed)"
fi

echo "==> all checks passed"
