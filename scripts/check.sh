#!/usr/bin/env bash
# Pre-PR gate (documented in README.md): release build, tests, and a
# rustdoc pass with warnings denied so the doc layer cannot rot.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (rustdoc warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "==> all checks passed"
