//! DL-workload driver: run the GEMM trace of a ~110M-parameter
//! transformer (GPT-2-small-like prefill) through the coordinator on both
//! NPU generations — the deployment scenario of Sec. 5.3.1: one tuned
//! design serves every layer shape; only the cheap per-size parameters
//! change between GEMMs.
//!
//! Run: `cargo run --release --example llm_layer -- [seq] [i8i8|bf16|...]`

use anyhow::Result;

use xdna_gemm::arch::Generation;
use xdna_gemm::coordinator::{Coordinator, CoordinatorOptions, GemmRequest};
use xdna_gemm::dtype::Precision;
use xdna_gemm::report::Table;
use xdna_gemm::workload::TransformerConfig;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seq = args.first().and_then(|s| s.parse().ok()).unwrap_or(512);
    let prec = args.get(1).and_then(|s| Precision::parse(s)).unwrap_or(Precision::I8I8);

    let model = TransformerConfig { seq, precision: prec, ..Default::default() };
    println!(
        "transformer: d={} layers={} ffn={} vocab={} seq={} → {:.1}M params, {} GEMMs/pass\n",
        model.d_model,
        model.n_layers,
        model.d_ffn,
        model.vocab,
        model.seq,
        model.n_params() as f64 / 1e6,
        model.trace().len()
    );

    for gen in Generation::ALL {
        let coord = Coordinator::start(CoordinatorOptions { gen, ..Default::default() });
        let trace = model.trace();
        let responses: Vec<_> = trace
            .iter()
            .map(|g| coord.submit(GemmRequest::sim(g.clone())).expect("coordinator up"))
            .collect::<Vec<_>>()
            .into_iter()
            .map(|rx| rx.recv().unwrap())
            .collect();

        let mut t = Table::new(
            &format!("{gen}: per-layer-kind GEMM performance ({})", prec.paper_name()),
            &["gemm", "shape", "padded", "device ms", "TOPS", "padding eff"],
        );
        // One row per distinct layer kind (first occurrence).
        let mut seen = std::collections::BTreeSet::new();
        for (g, r) in trace.iter().zip(&responses) {
            let kind = g.name.split('.').next_back().unwrap_or(&g.name);
            if !seen.insert(kind.to_string()) {
                continue;
            }
            t.row(vec![
                kind.to_string(),
                format!("{}x{}x{}", g.m, g.k, g.n),
                format!("{}x{}x{}", r.sim.pm, r.sim.pk, r.sim.pn),
                format!("{:.3}", r.device_s * 1e3),
                format!("{:.2}", r.sim.tops),
                format!("{:.0}%", {
                    let padded = 2.0 * r.sim.pm as f64 * r.sim.pk as f64 * r.sim.pn as f64;
                    100.0 * g.ops() / padded
                }),
            ]);
        }
        t.print();

        let m = coord.shutdown().expect("clean shutdown");
        let pass_ms = m.total_device_s() * 1e3;
        println!(
            "full prefill pass: {:.2} ms on device | sustained {:.2} TOPS | \
             {} reconfiguration(s)\n",
            pass_ms,
            m.device_tops(),
            m.reconfigurations()
        );
    }
    Ok(())
}
