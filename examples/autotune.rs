//! Autotune: run the paper's full two-stage optimization (Sec. 4.5) for a
//! chosen generation/precision and print the iteration trail — the
//! reproduction of the "optimal balanced kernel" methodology behind
//! Tables 2 and 3.
//!
//! Run: `cargo run --release --example autotune -- [xdna|xdna2] [i8i8|i8i16|i8i32|bf16]`

use anyhow::Result;

use xdna_gemm::arch::{balanced_config, Generation};
use xdna_gemm::dtype::Precision;
use xdna_gemm::optimizer::{optimize_balanced, solve_single_core, BalancedOptions, IpOptions};
use xdna_gemm::sim::{simulate_gemm, BdMode};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let gen = args.first().and_then(|s| Generation::parse(s)).unwrap_or(Generation::Xdna2);
    let prec = args.get(1).and_then(|s| Precision::parse(s)).unwrap_or(Precision::I8I16);
    println!("== autotuning {gen} / {} ==\n", prec.paper_name());

    // Stage 1 (Sec. 4.5.1): single-core IP.
    println!("stage 1 — single-core IP (exhaustive):");
    for (rank, sol) in solve_single_core(gen, prec, &IpOptions::default(), 3).iter().enumerate() {
        println!(
            "  #{rank}: {:>12}  {:.1} MACs/cyc  eff {:.3}  L1 {:.1} KB",
            sol.tile.label(),
            sol.macs_per_cycle,
            sol.efficiency,
            sol.l1_bytes as f64 / 1024.0
        );
    }

    // Stage 2 (Sec. 4.5.2): balanced-point walk with simulated measurement.
    println!("\nstage 2 — balanced-point search (k_ct ↓, IP maximizes m_ct·n_ct):");
    let res = optimize_balanced(gen, prec, &BalancedOptions::default())?;
    for h in &res.history {
        println!(
            "  {:>12} k_mt {:>5} → {:>6.2} TOPS  [{}]",
            h.cfg.kernel.label(),
            h.cfg.k_mt,
            h.tops,
            if h.memory_bound { "memory-bound" } else { "compute-bound" }
        );
    }
    println!(
        "\nwinner: {} k_mt={} → {:.2} TOPS at {}x{}x{}",
        res.winner.kernel.label(),
        res.winner.k_mt,
        res.winner_report.tops,
        res.eval.0,
        res.eval.1,
        res.eval.2
    );

    // Compare against the paper's published balance point.
    let paper = balanced_config(gen, prec);
    let r = simulate_gemm(&paper, res.eval.0, res.eval.1, res.eval.2, BdMode::Overlapped);
    println!(
        "paper's design {} k_mt={} → {:.2} TOPS on the same simulator ({:+.1}% vs our winner)",
        paper.kernel.label(),
        paper.k_mt,
        r.tops,
        100.0 * (r.tops / res.winner_report.tops - 1.0)
    );
    Ok(())
}
