//! Chain-planner demo: fused transformer-layer chains vs isolated
//! dispatches, on both NPU generations (docs/workloads.md).
//!
//! Builds the default ~110M-parameter transformer's prefill as chains
//! (`qkv → attn_out → ffn_up → ffn_down` per layer), plans them with
//! L2-resident reuse, and prints the phase-by-phase savings: elided
//! host dispatches, fused DRAM round-trips, and — for the mixed int8 +
//! bf16 workload — design-grouped reconfigurations. Then serves the
//! same chains through the sharded coordinator to show chain affinity
//! (each chain whole on one device) end to end.
//!
//! Run: `cargo run --release --example chain -- [seq] [layers]`

use anyhow::Result;

use xdna_gemm::arch::Generation;
use xdna_gemm::coordinator::CoordinatorOptions;
use xdna_gemm::dtype::Precision;
use xdna_gemm::harness;
use xdna_gemm::plan::{evaluate, mixed_transformer_chains, transformer_chains, Planner};
use xdna_gemm::sim::BdMode;
use xdna_gemm::workload::TransformerConfig;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seq: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(512);
    let n_layers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);

    let cfg = TransformerConfig { seq, n_layers, ..Default::default() };
    println!(
        "transformer prefill: seq={seq}, {n_layers} layers, d={}, ffn={} (~{:.0}M params)\n",
        cfg.d_model,
        cfg.d_ffn,
        cfg.n_params() as f64 / 1e6
    );

    for gen in Generation::ALL {
        let chains = transformer_chains(&cfg);
        let planner = Planner::new(gen);
        let fused = evaluate(&planner.plan(&chains), BdMode::Overlapped);
        let isolated = evaluate(&planner.plan_isolated(&chains), BdMode::Overlapped);
        println!("{gen} int8:");
        println!("  isolated: {}", isolated.summary());
        println!("  chained:  {}", fused.summary());
        println!("  speedup: {:.2}x\n", fused.speedup_over(&isolated));
    }

    // Mixed int8 + bf16 layers: the isolated in-order schedule pays a
    // full array reconfiguration on every precision flip; the planner
    // groups chains by design and pays each once.
    let mixed = mixed_transformer_chains(&cfg, Precision::Bf16);
    let planner = Planner::new(Generation::Xdna2);
    let fused = evaluate(&planner.plan(&mixed), BdMode::Overlapped);
    let isolated = evaluate(&planner.plan_isolated(&mixed), BdMode::Overlapped);
    println!("xdna2 mixed int8+bf16 (design grouping):");
    println!("  isolated: {}", isolated.summary());
    println!("  chained:  {}", fused.summary());
    println!(
        "  reconfig saved: {:.1} ms ({} → {}) | speedup {:.2}x\n",
        (isolated.t_reconfig - fused.t_reconfig) * 1e3,
        isolated.reconfigurations,
        fused.reconfigurations,
        fused.speedup_over(&isolated)
    );

    // The same chains through the sharded coordinator: chain affinity
    // keeps every chain whole on one device with its design cache-hot.
    let m = harness::serve_chains(
        CoordinatorOptions::fleet(vec![Generation::Xdna2, Generation::Xdna2]),
        &mixed,
    )?;
    println!("served on a 2-device fleet:\n{}", m.summary());
    Ok(())
}
