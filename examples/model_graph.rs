//! Graph-compiler demo: a whole attention block as a DAG — QKV fan-out,
//! residual rejoin — compiled down to precision-assigned, fleet-
//! partitioned chains and executed functionally through the coordinator
//! (docs/graphs.md).
//!
//! Shows every stage of `xdna-gemm compile` as a library walkthrough:
//! ingest (builder/generator), mixed-precision assignment under an
//! accuracy budget, lowering at branch/join points, critical-path fleet
//! partitioning, then live serving with device-pinned, tensor-staged
//! chain submissions — bit-exact against the reference dataflow.
//!
//! Run: `cargo run --release --example model_graph -- [seq] [layers] [budget]`

use anyhow::Result;

use xdna_gemm::arch::Generation;
use xdna_gemm::coordinator::{Backend, Coordinator, CoordinatorOptions};
use xdna_gemm::gemm::refimpl;
use xdna_gemm::graph::{
    assign, execute_functional, isolate, lower, partition, serve_graph, AssignOptions,
    PartitionOptions,
};
use xdna_gemm::workload::TransformerConfig;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seq: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(512);
    let n_layers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let budget: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1.0);

    let cfg = TransformerConfig { seq, n_layers, ..Default::default() };
    let g = cfg.attention_graph()?;
    println!(
        "attention DAG: {} nodes, {} edges ({} fan-outs, {} joins), {:.2} GMACs\n",
        g.len(),
        g.edges(),
        g.fan_outs(),
        g.joins(),
        g.total_ops() / 2e9
    );

    // Mixed-precision assignment against the accuracy budget.
    let fleet = vec![Generation::Xdna2, Generation::Xdna2];
    let assigned = assign(&g, &AssignOptions { budget_per_node: budget, fleet: fleet.clone() })?;
    println!(
        "assignment (budget {:.2} err units): spent {:.2}, Σ isolated est {:.3} ms",
        assigned.err_budget,
        assigned.err_spent,
        assigned.est_s * 1e3
    );
    for (node, choice) in assigned.graph.nodes().iter().zip(&assigned.choices) {
        println!("  {:<16} {:>6} on {}", node.shape.name, node.shape.precision, choice.gen);
    }

    // Lowering + fleet partitioning, against both baselines.
    let low = lower(&assigned.graph);
    let part = partition(&assigned.graph, &low, &PartitionOptions::fleet(fleet.clone()));
    let iso = partition(
        &assigned.graph,
        &isolate(&assigned.graph),
        &PartitionOptions::fleet(fleet.clone()),
    );
    let one = partition(&assigned.graph, &low, &PartitionOptions::fleet(vec![fleet[0]]));
    println!(
        "\nlowered: {} chains ({} fusable edges), {} staged tensors",
        low.chains.len(),
        low.chain_edges(),
        low.staged.len()
    );
    for sc in &part.schedule {
        println!(
            "  dev{} {:<28} start {:>8.3} ms  finish {:>8.3} ms",
            sc.device,
            low.chains[sc.chain].name,
            sc.start_s * 1e3,
            sc.finish_s * 1e3
        );
    }
    println!(
        "makespan {:.3} ms (critical path {:.3} ms) | isolated {:.3} ms → {:.2}x | \
         single-device {:.3} ms → {:.2}x",
        part.makespan_s * 1e3,
        part.critical_path_s * 1e3,
        iso.makespan_s * 1e3,
        iso.makespan_s / part.makespan_s,
        one.makespan_s * 1e3,
        one.makespan_s / part.makespan_s
    );

    // Functional serving on a small copy of the same structure (the
    // padded native grid dominates executor wall-clock at seq 512).
    let small = TransformerConfig {
        seq: 32,
        d_model: 32,
        d_ffn: 64,
        vocab: 48,
        n_layers: 1,
        ..cfg
    };
    let sg = small.attention_graph()?;
    let slow = lower(&sg);
    // XDNA's smaller native grid keeps the padded functional work light.
    let small_fleet = vec![Generation::Xdna, Generation::Xdna];
    let spart = partition(&sg, &slow, &PartitionOptions::fleet(small_fleet.clone()));
    let coord = Coordinator::start(CoordinatorOptions {
        devices: small_fleet,
        backend: Backend::Functional,
        ..Default::default()
    });
    let responses = serve_graph(&coord, &sg, &slow, &spart, true)?;
    let pure = execute_functional(&sg, Generation::Xdna, 1)?;
    let mut exact = true;
    for (ci, resp) in responses.iter().enumerate() {
        let tail = slow.chain_tail(ci);
        exact &= refimpl::matrices_equal(
            resp.result.as_ref().expect("functional result"),
            &pure[tail],
            sg.node(tail).shape.precision,
        );
    }
    let m = coord.shutdown()?;
    println!(
        "\nfunctionally served {} chains on the fleet (bit-exact vs dataflow: {exact}):\n{}",
        responses.len(),
        m.summary()
    );
    Ok(())
}
