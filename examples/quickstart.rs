//! Quickstart: the smallest complete tour of the library.
//!
//! 1. load the AOT artifact bundle via PJRT and run the pre-compiled
//!    bf16 GEMM (Layer 1+2, built once by `make artifacts`);
//! 2. run the same problem through the functional executor (real bytes
//!    through the BD transform chains) and the reference;
//! 3. simulate its wall-clock on both NPU generations.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use xdna_gemm::arch::{balanced_config, Generation};
use xdna_gemm::dtype::{Bf16, Layout, Precision};
use xdna_gemm::gemm::refimpl;
use xdna_gemm::mem::Matrix;
use xdna_gemm::runtime::Runtime;
use xdna_gemm::sim::{simulate_gemm, BdMode};

fn main() -> Result<()> {
    // --- 1. PJRT: execute the AOT-compiled JAX/Pallas GEMM ---------------
    let mut rt = Runtime::load("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let meta = rt.meta("quickstart_bf16").expect("run `make artifacts`").clone();
    let (m, k, n) = (meta.m, meta.k, meta.n);
    println!("artifact quickstart_bf16: {m}x{k}x{n} bf16 GEMM");

    let mut a = Matrix::zeroed(m, k, 2, Layout::RowMajor)?;
    let mut b = Matrix::zeroed(k, n, 2, Layout::RowMajor)?;
    refimpl::fill_random(&mut a, Precision::Bf16, 1);
    refimpl::fill_random(&mut b, Precision::Bf16, 2);
    let af: Vec<f32> = (0..m)
        .flat_map(|i| (0..k).map(move |j| (i, j)))
        .map(|(i, j)| a.get_bf16(i, j).to_f32())
        .collect();
    let bf: Vec<f32> = (0..k)
        .flat_map(|i| (0..n).map(move |j| (i, j)))
        .map(|(i, j)| b.get_bf16(i, j).to_f32())
        .collect();
    let t0 = std::time::Instant::now();
    let out = rt.execute_f32("quickstart_bf16", &[&af, &bf])?;
    println!(
        "PJRT execute: {:.1} ms (compile included on first call)",
        t0.elapsed().as_secs_f64() * 1e3
    );

    // --- 2. cross-check: reference + worst-case error --------------------
    let want = refimpl::ref_gemm(&a, &b, Precision::Bf16)?;
    let mut max_rel = 0f32;
    for i in 0..m {
        for j in 0..n {
            let w = want.get_bf16(i, j).to_f32();
            let g = Bf16::from_f32(out[i * n + j]).to_f32();
            max_rel = max_rel.max((g - w).abs() / w.abs().max(1.0));
        }
    }
    println!("max relative error vs reference: {max_rel:.2e} (bf16 1-ulp ≈ 7.8e-3)");
    assert!(max_rel < 2.0f32.powi(-6));

    // --- 3. simulate the same GEMM on both NPU generations ---------------
    for gen in Generation::ALL {
        let cfg = balanced_config(gen, Precision::Bf16);
        let r = simulate_gemm(&cfg, m, k, n, BdMode::Overlapped);
        println!(
            "{gen}: design {} → {:.3} ms, {:.2} TOPS ({:?}-bound)",
            cfg.kernel.label(),
            r.t_total * 1e3,
            r.tops,
            r.bound
        );
    }
    println!("quickstart OK");
    Ok(())
}
