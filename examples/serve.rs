//! Multi-device serving demo: a sharded coordinator fleet under a
//! skewed mixed-design trace (docs/serving.md).
//!
//! A fleet of simulated NPUs (generations mixable) serves a transformer
//! prefill stream with a hot int8 design plus mixed-precision/layout
//! tails. The admission router keeps each design resident where it
//! already lives, spills hot designs across devices when backlogs
//! exceed a reconfiguration, and the run ends with the per-device and
//! fleet rollups. A single-device baseline on the same trace shows the
//! aggregate-throughput win.
//!
//! Run: `cargo run --release --example serve -- [n_requests] [n_devices] [mix]`
//! e.g. `cargo run --release --example serve -- 512 4 xdna:xdna2`

use anyhow::Result;

use xdna_gemm::coordinator::{expand_mix, parse_mix, CoordinatorOptions};
use xdna_gemm::harness;
use xdna_gemm::workload::skewed_trace;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(256);
    let n_devices: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let pattern = parse_mix(args.get(2).map(String::as_str).unwrap_or("xdna:xdna2"))?;

    let devices = expand_mix(&pattern, n_devices);
    let trace = skewed_trace(n_requests.max(1), 2025);
    println!(
        "serving {n_requests} skewed requests on a {n_devices}-device fleet {:?}\n",
        devices.iter().map(|g| g.name()).collect::<Vec<_>>()
    );

    let fleet = harness::serve_trace(CoordinatorOptions::fleet(devices), &trace, n_requests)?;
    println!("{}\n", fleet.summary());

    // Single-device baseline on the identical trace with the same
    // (leading) generation: same total work, one leader — the fleet's
    // makespan win is the whole point.
    let baseline_opts = CoordinatorOptions::fleet(expand_mix(&pattern, 1));
    let baseline = harness::serve_trace(baseline_opts, &trace, n_requests)?;
    println!("single-device baseline:\n{}\n", baseline.summary());

    let speedup = if baseline.fleet_tops() > 0.0 {
        fleet.fleet_tops() / baseline.fleet_tops()
    } else {
        0.0
    };
    println!(
        "aggregate throughput: fleet {:.2} TOPS vs single-device {:.2} TOPS ({speedup:.2}x)",
        fleet.fleet_tops(),
        baseline.fleet_tops()
    );
    Ok(())
}
