//! End-to-end serving driver (the system-prompt's E2E requirement): load
//! the AOT artifact bundle, serve batched GEMM requests with REAL numerics
//! — every request executes through the PJRT-compiled JAX/Pallas native
//! step chained by the Rust coordinator logic — and report
//! latency/throughput percentiles. Timing of the simulated NPU runs
//! alongside for each request.
//!
//! Python never runs here: the artifacts were compiled once by
//! `make artifacts`.
//!
//! Run: `cargo run --release --example serve -- [n_requests] [xdna|xdna2]`

use anyhow::Result;
use std::time::Instant;

use xdna_gemm::arch::{balanced_config, Generation};
use xdna_gemm::dtype::{Layout, Precision};
use xdna_gemm::gemm::refimpl;
use xdna_gemm::mem::Matrix;
use xdna_gemm::runtime::{pjrt_gemm, Runtime};
use xdna_gemm::sim::{simulate_gemm, BdMode};
use xdna_gemm::util::rng::Rng;
use xdna_gemm::util::stats;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(12);
    let gen = args.get(1).and_then(|s| Generation::parse(s)).unwrap_or(Generation::Xdna);

    // Serve bf16 requests on the generation's balanced design. The design
    // is resident once (one reconfiguration); requests then stream.
    let prec = Precision::Bf16;
    let cfg = balanced_config(gen, prec);
    let (nm, nk, nn) = cfg.native();
    println!(
        "serving GEMM on {gen}/{} | design {} k_mt={} | native {}x{}x{}",
        prec.paper_name(),
        cfg.kernel.label(),
        cfg.k_mt,
        nm,
        nk,
        nn
    );

    let mut rt = Runtime::load("artifacts")?;
    println!("PJRT platform: {}\n", rt.platform());

    // Mixed request sizes: multiples of the native grid (the library
    // case) plus ragged ones that exercise padding.
    let mut rng = Rng::seeded(2025);
    let mut sizes = Vec::new();
    for i in 0..n_requests {
        let (m, k, n) = if i % 3 == 2 {
            // Ragged request (padded internally).
            (nm + 4 * (1 + rng.below(8)), nk, nn)
        } else {
            ((1 + rng.below(2)) * nm, (1 + rng.below(2)) * nk, (1 + rng.below(2)) * nn)
        };
        sizes.push((m, k, n));
    }

    let mut host_lat = Vec::new();
    let mut device_lat = Vec::new();
    let mut total_ops = 0.0;
    let mut verified = 0usize;
    let t_serve = Instant::now();
    for (i, (m, k, n)) in sizes.iter().copied().enumerate() {
        let mut a = Matrix::zeroed(m, k, prec.ty_in(), Layout::RowMajor)?;
        let mut b = Matrix::zeroed(k, n, prec.ty_in(), cfg.b_layout)?;
        refimpl::fill_random(&mut a, prec, 100 + i as u64);
        refimpl::fill_random(&mut b, prec, 200 + i as u64);

        let t0 = Instant::now();
        let out = pjrt_gemm(&mut rt, &cfg, &a, &b)?; // REAL numerics via PJRT
        let host_s = t0.elapsed().as_secs_f64();
        let sim = simulate_gemm(&cfg, m, k, n, BdMode::Overlapped);

        // Verify a sample of responses bit-for-bit against the reference.
        let check = i % 4 == 0;
        let ok = if check {
            let want = refimpl::ref_gemm(&a, &b, prec)?;
            // bf16: same narrowing, different f32 summation order across
            // panel boundaries → compare with 1-ulp tolerance.
            let mut ok = true;
            for ii in 0..m {
                for jj in 0..n {
                    let w = want.get_bf16(ii, jj).to_f32();
                    let g = out.get_bf16(ii, jj).to_f32();
                    if (g - w).abs() > 2.0f32.powi(-6) * w.abs().max(1.0) {
                        ok = false;
                    }
                }
            }
            verified += 1;
            ok
        } else {
            true
        };
        assert!(ok, "request {i}: numerics mismatch");

        host_lat.push(host_s);
        device_lat.push(sim.t_total);
        total_ops += 2.0 * m as f64 * k as f64 * n as f64;
        println!(
            "req {i:>3}: {m:>5}x{k:>5}x{n:>5}  host {:>8.1} ms | simulated NPU {:>7.3} ms \
             ({:>5.2} TOPS){}",
            host_s * 1e3,
            sim.t_total * 1e3,
            sim.tops,
            if check { "  [verified]" } else { "" }
        );
    }
    let wall = t_serve.elapsed().as_secs_f64();

    println!("\n== serving summary ==");
    println!("requests: {n_requests} | verified: {verified} (all passed)");
    println!(
        "host latency  p50 {:.1} ms | p95 {:.1} ms | mean {:.1} ms",
        stats::median(&host_lat) * 1e3,
        stats::percentile(&host_lat, 95.0) * 1e3,
        stats::mean(&host_lat) * 1e3
    );
    println!(
        "simulated NPU p50 {:.3} ms | p95 {:.3} ms | sustained {:.2} TOPS",
        stats::median(&device_lat) * 1e3,
        stats::percentile(&device_lat, 95.0) * 1e3,
        total_ops / device_lat.iter().sum::<f64>() / 1e12
    );
    println!(
        "host throughput: {:.2} req/s ({:.2} GFLOP/s functional on CPU-PJRT)",
        n_requests as f64 / wall,
        total_ops / wall / 1e9
    );
    Ok(())
}
