//! Multi-device serving demo: a sharded coordinator fleet under a
//! skewed mixed-design trace (docs/serving.md).
//!
//! A fleet of simulated NPUs (generations mixable) serves a transformer
//! prefill stream with a hot int8 design plus mixed-precision/layout
//! tails. The admission router keeps each design resident where it
//! already lives, spills hot designs across devices when backlogs
//! exceed a reconfiguration, and the run ends with the per-device and
//! fleet rollups. A single-device baseline on the same trace shows the
//! aggregate-throughput win.
//!
//! Run: `cargo run --release --example serve -- [n_requests] [n_devices] [mix]`
//! e.g. `cargo run --release --example serve -- 512 4 xdna:xdna2`

use anyhow::Result;

use xdna_gemm::coordinator::{
    expand_mix, parse_mix, Backend, CoordinatorOptions, FaultPlan, IntegrityMode,
};
use xdna_gemm::dtype::Precision;
use xdna_gemm::harness;
use xdna_gemm::workload::{skewed_trace, GemmShape};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(256);
    let n_devices: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let pattern = parse_mix(args.get(2).map(String::as_str).unwrap_or("xdna:xdna2"))?;

    let devices = expand_mix(&pattern, n_devices);
    let trace = skewed_trace(n_requests.max(1), 2025);
    println!(
        "serving {n_requests} skewed requests on a {n_devices}-device fleet {:?}\n",
        devices.iter().map(|g| g.name()).collect::<Vec<_>>()
    );

    let fleet = harness::serve_trace(CoordinatorOptions::fleet(devices), &trace, n_requests)?;
    println!("{}\n", fleet.summary());

    // Single-device baseline on the identical trace with the same
    // (leading) generation: same total work, one leader — the fleet's
    // makespan win is the whole point.
    let baseline_opts = CoordinatorOptions::fleet(expand_mix(&pattern, 1));
    let baseline = harness::serve_trace(baseline_opts, &trace, n_requests)?;
    println!("single-device baseline:\n{}\n", baseline.summary());

    let speedup = if baseline.fleet_tops() > 0.0 {
        fleet.fleet_tops() / baseline.fleet_tops()
    } else {
        0.0
    };
    println!(
        "aggregate throughput: fleet {:.2} TOPS vs single-device {:.2} TOPS ({speedup:.2}x)",
        fleet.fleet_tops(),
        baseline.fleet_tops()
    );

    // Integrity demo (DESIGN.md §14): a small functional trace under
    // seeded silent corruption with ABFT checking on. Every injected
    // bit-flip is detected and recomputed bit-exactly — visible as
    // `recovered` units in the integrity rollup rather than corrupt
    // results served to clients.
    let demo: Vec<GemmShape> = (0..8)
        .map(|i| GemmShape::new(&format!("int8_{i}"), 256, 256, 256, Precision::I8I8))
        .collect();
    let opts = CoordinatorOptions {
        backend: Backend::Functional,
        devices: vec![pattern[0]],
        chaos: Some(FaultPlan::corruption_only(2025, 1, 8, 2)),
        integrity: IntegrityMode::Abft,
        ..Default::default()
    };
    let m = harness::serve_trace(opts, &demo, demo.len())?;
    let (checked, passed, recovered, failed) = m.integrity_totals();
    println!(
        "\nABFT under seeded corruption ({} faults injected): \
         {checked} checked | {passed} passed | {recovered} recovered | {failed} failed",
        m.fault_log().len()
    );
    Ok(())
}
