//! Table / series emitters for the bench harness: every paper table and
//! figure is regenerated as one of these (markdown to stdout, CSV to
//! `target/reports/` for plotting).

use std::fmt::Write as _;
use std::path::PathBuf;

/// A paper-style table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as aligned markdown.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}", self.title);
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {c:<w$} |");
            }
            let _ = writeln!(out, "{s}");
        };
        line(&self.headers, &widths, &mut out);
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<1$}|", "", w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }

    /// Write CSV under `target/reports/<name>.csv`; returns the path.
    pub fn save_csv(&self, name: &str) -> std::io::Result<PathBuf> {
        let dir = PathBuf::from("target/reports");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// A figure series: (x, y) points with labels — the roofline sweeps and
/// k_mt curves.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub x_label: String,
    pub y_label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str, x_label: &str, y_label: &str) -> Series {
        Series {
            name: name.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn max_y(&self) -> f64 {
        self.points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max)
    }

    /// Crude terminal scatter plot (for the fig6/7/8 harnesses).
    pub fn to_ascii(&self, width: usize, height: usize) -> String {
        if self.points.is_empty() {
            return String::from("(empty series)\n");
        }
        let (mut x_min, mut x_max) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y_min, mut y_max) = (0.0f64, f64::NEG_INFINITY);
        for &(x, y) in &self.points {
            x_min = x_min.min(x);
            x_max = x_max.max(x);
            y_min = y_min.min(y);
            y_max = y_max.max(y);
        }
        let mut grid = vec![vec![b' '; width]; height];
        for &(x, y) in &self.points {
            let xi = if x_max > x_min {
                ((x - x_min) / (x_max - x_min) * (width - 1) as f64) as usize
            } else {
                0
            };
            let yi = if y_max > y_min {
                ((y - y_min) / (y_max - y_min) * (height - 1) as f64) as usize
            } else {
                0
            };
            grid[height - 1 - yi][xi] = b'*';
        }
        let mut out = String::new();
        let _ = writeln!(out, "{} — {} vs {}", self.name, self.y_label, self.x_label);
        let _ = writeln!(out, "y: [{y_min:.2}, {y_max:.2}]  x: [{x_min:.0}, {x_max:.0}]");
        for row in grid {
            let _ = writeln!(out, "|{}", String::from_utf8(row).unwrap());
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{},{}", self.x_label, self.y_label);
        for (x, y) in &self.points {
            let _ = writeln!(out, "{x},{y}");
        }
        out
    }

    pub fn save_csv(&self, name: &str) -> std::io::Result<PathBuf> {
        let dir = PathBuf::from("target/reports");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Paper-vs-measured comparison row helper used across harnesses.
pub fn ratio_cell(measured: f64, paper: f64) -> String {
    format!("{:.2} ({:+.1}%)", measured, 100.0 * (measured - paper) / paper)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "long-cell".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1 | long-cell |"));
        assert!(t.to_csv().contains("a,b\n1,long-cell"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_checks_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn series_plot_contains_points() {
        let mut s = Series::new("roofline", "ARI", "TOPS");
        for i in 0..50 {
            s.push(i as f64, (i as f64).sqrt());
        }
        let ascii = s.to_ascii(40, 10);
        assert!(ascii.contains('*'));
        assert_eq!(s.max_y(), 7.0);
        assert!(s.to_csv().lines().count() == 51);
    }

    // Byte-exact goldens (ISSUE 10): the emitters feed committed CSV
    // artifacts and the docs, so their output format is a contract —
    // alignment, separator widths, and float formatting are pinned
    // character-for-character, not just substring-probed.

    #[test]
    fn table_markdown_golden() {
        let mut t = Table::new("Golden", &["shape", "TOPS"]);
        t.row(vec!["4096x4096".into(), "22.63".into()]);
        t.row(vec!["1x2048".into(), "0.91".into()]);
        assert_eq!(
            t.to_markdown(),
            "### Golden\n\
             | shape     | TOPS  |\n\
             |-----------|-------|\n\
             | 4096x4096 | 22.63 |\n\
             | 1x2048    | 0.91  |\n"
        );
    }

    #[test]
    fn table_csv_golden() {
        let mut t = Table::new("Golden", &["shape", "TOPS"]);
        t.row(vec!["4096x4096".into(), "22.63".into()]);
        t.row(vec!["1x2048".into(), "0.91".into()]);
        assert_eq!(t.to_csv(), "shape,TOPS\n4096x4096,22.63\n1x2048,0.91\n");
    }

    #[test]
    fn series_ascii_golden() {
        let mut s = Series::new("diag", "x", "y");
        s.push(0.0, 0.0);
        s.push(1.0, 1.0);
        s.push(2.0, 2.0);
        assert_eq!(
            s.to_ascii(3, 3),
            "diag — y vs x\n\
             y: [0.00, 2.00]  x: [0, 2]\n\
             |  *\n\
             | * \n\
             |*  \n"
        );
        assert_eq!(Series::new("empty", "x", "y").to_ascii(3, 3), "(empty series)\n");
    }

    #[test]
    fn series_csv_golden() {
        let mut s = Series::new("diag", "x", "y");
        s.push(0.0, 0.0);
        s.push(1.5, 2.25);
        assert_eq!(s.to_csv(), "x,y\n0,0\n1.5,2.25\n");
    }

    #[test]
    fn ratio_cell_golden() {
        assert_eq!(ratio_cell(2.0, 1.6), "2.00 (+25.0%)");
        assert_eq!(ratio_cell(1.2, 1.6), "1.20 (-25.0%)");
    }
}
