//! Seeded property-testing driver (proptest stand-in).
//!
//! Runs a closure over `cases` randomized inputs; on failure reports the
//! seed so the case reproduces exactly. No shrinking — inputs are kept
//! small by construction in the generators.

use super::rng::Rng;

/// Serializes the panic-hook swap across concurrently running property
/// tests: the hook is process-global, so without this two interleaved
/// `prop_check`s could each save the other's silent hook as "previous"
/// and leave it permanently installed.
static HOOK_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Run `f` with `cases` independently-seeded RNGs; panic with the
/// offending seed on the first failure.
///
/// The default panic hook is suppressed while the probes run (and
/// restored before this function returns or re-panics), so a failing
/// property reports only the seed line instead of one full backtrace
/// per probed failure. Property tests serialize on [`HOOK_LOCK`] for
/// the duration of the probes; a concurrently panicking *non*-property
/// test still loses its backtrace during that window — the price of a
/// process-global hook.
pub fn prop_check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    // Base seed overridable for reproduction: PROP_SEED=1234.
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    let guard = HOOK_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let mut failure: Option<(u64, u64, String)> = None;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            failure = Some((case, seed, msg));
            break;
        }
    }
    // Restore the previous hook (and only then release the lock) before
    // re-panicking, so the property's own failure — and any later
    // unrelated panic — reports normally.
    std::panic::set_hook(prev_hook);
    drop(guard);
    if let Some((case, seed, msg)) = failure {
        panic!(
            "property '{name}' failed on case {case} (reproduce with \
             PROP_SEED={base} — failing seed {seed}): {msg}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("addition commutes", 50, |rng| {
            let a = rng.range_i64(-1000, 1000);
            let b = rng.range_i64(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        prop_check("always fails", 5, |_| panic!("nope"));
    }

    #[test]
    fn failure_message_carries_the_reproduction_seed() {
        // The suppressed-hook path must still surface the seed line —
        // the only output a failing property is supposed to produce.
        let err = std::panic::catch_unwind(|| {
            prop_check("seeded", 2, |_| panic!("boom"));
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("property 'seeded' failed on case 0"), "{msg}");
        assert!(msg.contains("PROP_SEED="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }
}
