//! Seeded property-testing driver (proptest stand-in).
//!
//! Runs a closure over `cases` randomized inputs; on failure reports the
//! seed so the case reproduces exactly. No shrinking — inputs are kept
//! small by construction in the generators.

use super::rng::Rng;

/// Run `f` with `cases` independently-seeded RNGs; panic with the
/// offending seed on the first failure.
pub fn prop_check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    // Base seed overridable for reproduction: PROP_SEED=1234.
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (reproduce with \
                 PROP_SEED={base} — failing seed {seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("addition commutes", 50, |rng| {
            let a = rng.range_i64(-1000, 1000);
            let b = rng.range_i64(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        prop_check("always fails", 5, |_| panic!("nope"));
    }
}
