//! Small deterministic PRNG (xoshiro256**), shared by the property-test
//! driver, workload generators, and examples. No external `rand` needed.

/// xoshiro256** — fast, high-quality, seedable.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seeded(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Random i8 across the full range.
    #[inline]
    pub fn i8(&mut self) -> i8 {
        self.next_u64() as i8
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distributions_sane() {
        let mut r = Rng::seeded(1);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
        let nm: f64 = (0..10_000).map(|_| r.normal()).sum::<f64>() / 10_000.0;
        assert!(nm.abs() < 0.05, "{nm}");
        for _ in 0..1000 {
            let v = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
