//! Minimal measurement harness (criterion stand-in) for `cargo bench`.
//!
//! Each bench target is a plain `main()` (harness = false) that builds a
//! [`Bench`] and registers timed closures; output is a criterion-style
//! `name  time: [min mean max]  (n samples)` line per case, plus optional
//! paper-table rows emitted by the harness itself. When `$BENCH_JSON` is
//! set, [`Bench::finish`] also writes every recorded case and throughput
//! to that path — the machine-readable artifact `scripts/bench.sh` merges
//! into `BENCH_PR3.json`.

use std::cell::RefCell;
use std::hint::black_box as bb;
use std::time::{Duration, Instant};

use super::json::{num, obj, s, Json};
use super::stats;

/// Re-export for bench bodies: prevent the optimizer from deleting work.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

pub struct Bench {
    name: String,
    /// Target measurement time per case.
    budget: Duration,
    /// Minimum sample count.
    min_samples: usize,
    /// Everything measured so far, for the JSON artifact.
    records: RefCell<Vec<Sample>>,
    throughputs: RefCell<Vec<(String, f64, String)>>,
}

#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
    pub samples: usize,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("== bench group: {name} ==");
        Bench {
            name: name.to_string(),
            budget: Duration::from_millis(
                std::env::var("BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(500),
            ),
            min_samples: 10,
            records: RefCell::new(Vec::new()),
            throughputs: RefCell::new(Vec::new()),
        }
    }

    pub fn with_budget_ms(mut self, ms: u64) -> Self {
        self.budget = Duration::from_millis(ms);
        self
    }

    /// Time `f`, auto-scaling iteration count to the budget.
    pub fn case<R>(&self, case: &str, mut f: impl FnMut() -> R) -> Sample {
        // Warm-up + estimate.
        let t0 = Instant::now();
        bb(f());
        let one = t0.elapsed().max(Duration::from_nanos(50));

        let mut times = Vec::new();
        let deadline = Instant::now() + self.budget;
        while times.len() < self.min_samples || (Instant::now() < deadline && times.len() < 5000) {
            let t = Instant::now();
            bb(f());
            times.push(t.elapsed().as_secs_f64());
            if one > self.budget {
                break; // single run exceeds budget: one sample is all we get
            }
        }
        let s = Sample {
            name: format!("{}/{}", self.name, case),
            mean_s: stats::mean(&times),
            min_s: stats::min(&times),
            max_s: stats::max(&times),
            stddev_s: stats::stddev(&times),
            samples: times.len(),
        };
        println!(
            "{:<48} time: [{} {} {}]  ({} samples)",
            s.name,
            fmt_time(s.min_s),
            fmt_time(s.mean_s),
            fmt_time(s.max_s),
            s.samples
        );
        self.records.borrow_mut().push(s.clone());
        s
    }

    /// Report a derived throughput metric alongside a case.
    pub fn throughput(&self, case: &str, value: f64, unit: &str) {
        println!("{:<48} thrpt: {value:.3} {unit}", format!("{}/{}", self.name, case));
        self.throughputs.borrow_mut().push((case.to_string(), value, unit.to_string()));
    }

    /// Write every recorded case + throughput to `$BENCH_JSON` when the
    /// env var is set (no-op otherwise). Call once at the end of a bench
    /// `main()` — only targets that call it emit a record (currently
    /// `hotpath` and `chain_vs_isolated`; `scripts/bench.sh` drives
    /// those). When the path is an *existing* directory (or ends with
    /// `/`), each group writes `<dir>/<group>.json` so multi-target
    /// runs don't clobber a single file.
    pub fn finish(&self) {
        let Some(path) = std::env::var_os("BENCH_JSON") else { return };
        let cases: Vec<Json> = self
            .records
            .borrow()
            .iter()
            .map(|r| {
                obj(vec![
                    ("name", s(&r.name)),
                    ("mean_s", num(r.mean_s)),
                    ("min_s", num(r.min_s)),
                    ("max_s", num(r.max_s)),
                    ("stddev_s", num(r.stddev_s)),
                    ("samples", num(r.samples as f64)),
                ])
            })
            .collect();
        let thrpt: Vec<Json> = self
            .throughputs
            .borrow()
            .iter()
            .map(|(name, value, unit)| {
                obj(vec![("name", s(name)), ("value", num(*value)), ("unit", s(unit))])
            })
            .collect();
        let doc = obj(vec![
            ("group", s(&self.name)),
            ("cases", Json::Arr(cases)),
            ("throughput", Json::Arr(thrpt)),
        ]);
        let mut path = std::path::PathBuf::from(path);
        if path.is_dir() || path.as_os_str().to_string_lossy().ends_with('/') {
            path.push(format!("{}.json", self.name));
        }
        if let Err(e) = std::fs::write(&path, doc.to_string_pretty()) {
            eprintln!("bench: cannot write {}: {e}", path.display());
        } else {
            println!("bench: wrote {}", path.display());
        }
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::new("selftest").with_budget_ms(20);
        let s = b.case("noop-loop", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(s.samples >= 10);
        assert!(s.mean_s > 0.0);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s + 1e-12);
    }

    #[test]
    fn records_accumulate_for_the_json_artifact() {
        let b = Bench::new("selftest-json").with_budget_ms(5);
        b.case("noop", || 1 + 1);
        b.throughput("noop", 42.0, "x");
        assert_eq!(b.records.borrow().len(), 1);
        assert_eq!(b.throughputs.borrow().len(), 1);
        assert_eq!(b.throughputs.borrow()[0].1, 42.0);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(3.2e-9).contains("ns"));
        assert!(fmt_time(4.5e-5).contains("µs"));
        assert!(fmt_time(2.0e-3).contains("ms"));
        assert!(fmt_time(1.5).contains(" s"));
    }
}
