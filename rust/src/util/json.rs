//! Minimal JSON reader/writer (serde_json stand-in).
//!
//! Parses the machine-generated `artifacts/manifest.json` /
//! `artifacts/golden.json` files and serializes benchmark reports. Supports
//! the full JSON grammar except exotic number forms; numbers are kept as
//! f64 (integers round-trip exactly up to 2^53 — far beyond anything in
//! our manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Field access that errors with the key name (manifest plumbing).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs not needed for our manifests;
                            // map unpaired surrogates to replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-borrow the original str slice for multi-byte chars.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        // Find the full UTF-8 char starting at i-1.
                        let s = std::str::from_utf8(&self.b[self.i - 1..])?;
                        let ch = s.chars().next().unwrap();
                        out.push(ch);
                        self.i += ch.len_utf8() - 1;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' got '{}' at byte {}", c as char, self.i),
            }
        }
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_negatives_and_exponents() {
        let v = Json::parse("[-128, 1e3, 2.5E-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(-128));
        assert_eq!(a[1].as_f64(), Some(1000.0));
        assert!((a[2].as_f64().unwrap() - 0.025).abs() < 1e-12);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""π≈3.14""#).unwrap();
        assert_eq!(v.as_str(), Some("π≈3.14"));
    }
}
