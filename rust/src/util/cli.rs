//! Tiny argument parser (clap stand-in): `prog <subcommand> [--key value]
//! [--flag] [positional...]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` minus the program name. Tokens starting
    /// with `--` are options if followed by a non-`--` token, else flags.
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Args {
        let toks: Vec<String> = raw.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.options.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn usize_opt(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_opt(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }

    pub fn subcommand_required(&self, usage: &str) -> Result<&str> {
        match &self.subcommand {
            Some(s) => Ok(s.as_str()),
            None => bail!("missing subcommand\n{usage}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // NB: `--flag token` is ambiguous in this grammar (token binds as
        // the value); pass bare flags last or as `--flag=`-free trailers.
        let a = parse("table2 --gen xdna --size=4096 pos1 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("table2"));
        assert_eq!(a.get("gen"), Some("xdna"));
        assert_eq!(a.get("size"), Some("4096"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 42 --f 2.5");
        assert_eq!(a.usize_opt("n", 0).unwrap(), 42);
        assert_eq!(a.usize_opt("missing", 7).unwrap(), 7);
        assert_eq!(a.f64_opt("f", 0.0).unwrap(), 2.5);
        assert!(a.usize_opt("f", 0).is_err());
        assert!(a.require("absent").is_err());
    }
}
