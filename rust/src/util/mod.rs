//! Offline stand-ins for crates unavailable in this build environment
//! (serde_json, criterion, proptest, clap — see DESIGN.md §1).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
