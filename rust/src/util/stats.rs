//! Summary statistics for benchmark and metrics reporting.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((stddev(&xs) - 1.5811388).abs() < 1e-6);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.5);
    }
}
