//! Summary statistics for benchmark and metrics reporting.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy (p in [0, 100]).
///
/// `None` when there is nothing to rank: an empty sample, or one that is
/// all-NaN after filtering. NaN samples (a poisoned latency, a 0/0 rate)
/// are dropped rather than sorted — `partial_cmp().unwrap()` on NaN used
/// to panic the metrics rollup mid-serve, and `total_cmp` alone would
/// instead rank NaN above +inf and corrupt the high percentiles.
pub fn percentile(xs: &[f64], p: f64) -> Option<f64> {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return None;
    }
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    Some(if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    })
}

pub fn median(xs: &[f64]) -> Option<f64> {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), Some(3.0));
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        assert_eq!(percentile(&xs, 100.0), Some(5.0));
        assert!((stddev(&xs) - 1.5811388).abs() < 1e-6);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), Some(5.0));
        assert_eq!(percentile(&xs, 25.0), Some(2.5));
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // Regression: a single NaN used to panic the sort. It must be
        // filtered, not ranked (total_cmp would put it above +inf).
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 100.0), Some(3.0));
        assert_eq!(percentile(&xs, 50.0), Some(2.0));
        assert_eq!(median(&[f64::NAN, 5.0]), Some(5.0));
    }

    #[test]
    fn empty_or_all_nan_percentile_is_none_not_zero() {
        // Regression: empty samples used to report 0.0 — a tenant with
        // zero completed ops claimed a perfect p99.
        assert_eq!(percentile(&[], 99.0), None);
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 99.0), None);
        assert_eq!(median(&[]), None);
    }
}
