//! Hardware lock units (Sec. 3.2): semaphore-style synchronization between
//! DMA channels and consumers (cores / DRAM).
//!
//! AIE-ML locks are small counting semaphores with acquire-greater-equal /
//! release-with-value semantics. The functional executor uses them to
//! assert the double-buffering protocol is well-formed (a buffer is never
//! read while being written); the timing engine models their latency as
//! part of the DMA setup constants.

use anyhow::{bail, Result};

/// One lock unit with a bounded counter value.
#[derive(Clone, Debug)]
pub struct Lock {
    value: i32,
    /// AIE-ML lock values are 6-bit; keep the hardware bound.
    max: i32,
}

impl Lock {
    pub fn new(init: i32) -> Self {
        Lock { value: init, max: 63 }
    }

    pub fn value(&self) -> i32 {
        self.value
    }

    /// Acquire-greater-equal: succeeds (and decrements by `dec`) when
    /// `value >= dec`. Returns false when it would block.
    pub fn try_acquire(&mut self, dec: i32) -> bool {
        if self.value >= dec {
            self.value -= dec;
            true
        } else {
            false
        }
    }

    /// Release: increments by `inc`, saturating at the hardware bound.
    pub fn release(&mut self, inc: i32) -> Result<()> {
        let next = self.value + inc;
        if next > self.max {
            bail!("lock overflow: {} + {inc} > {}", self.value, self.max);
        }
        self.value = next;
        Ok(())
    }
}

/// A producer/consumer buffer pair guarded by two locks, mirroring the
/// IRON object-fifo pattern: `prod` counts free slots, `cons` counts
/// filled slots.
#[derive(Clone, Debug)]
pub struct BufferFifo {
    pub depth: usize,
    prod: Lock,
    cons: Lock,
    /// Write/read cursors for assertions.
    wr: usize,
    rd: usize,
}

impl BufferFifo {
    /// `depth` = 1 models single buffering (the paper's C tiles), 2 models
    /// double buffering (A and B tiles).
    pub fn new(depth: usize) -> Self {
        BufferFifo {
            depth,
            prod: Lock::new(depth as i32),
            cons: Lock::new(0),
            wr: 0,
            rd: 0,
        }
    }

    /// Producer side: returns the slot index to fill, or None if full.
    pub fn try_begin_write(&mut self) -> Option<usize> {
        if self.prod.try_acquire(1) {
            let slot = self.wr % self.depth;
            self.wr += 1;
            Some(slot)
        } else {
            None
        }
    }

    pub fn end_write(&mut self) -> Result<()> {
        self.cons.release(1)
    }

    /// Consumer side: returns the slot index to drain, or None if empty.
    pub fn try_begin_read(&mut self) -> Option<usize> {
        if self.cons.try_acquire(1) {
            let slot = self.rd % self.depth;
            self.rd += 1;
            Some(slot)
        } else {
            None
        }
    }

    pub fn end_read(&mut self) -> Result<()> {
        self.prod.release(1)
    }

    /// Filled slots currently visible to the consumer.
    pub fn available(&self) -> i32 {
        self.cons.value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_semantics() {
        let mut l = Lock::new(2);
        assert!(l.try_acquire(1));
        assert!(l.try_acquire(1));
        assert!(!l.try_acquire(1));
        l.release(1).unwrap();
        assert!(l.try_acquire(1));
        // Overflow guarded.
        let mut l2 = Lock::new(63);
        assert!(l2.release(1).is_err());
    }

    #[test]
    fn double_buffer_protocol() {
        let mut f = BufferFifo::new(2);
        // Producer can fill both buffers ahead of the consumer...
        assert_eq!(f.try_begin_write(), Some(0));
        f.end_write().unwrap();
        assert_eq!(f.try_begin_write(), Some(1));
        f.end_write().unwrap();
        // ...but not a third.
        assert_eq!(f.try_begin_write(), None);
        // Consumer drains in order.
        assert_eq!(f.try_begin_read(), Some(0));
        f.end_read().unwrap();
        // Slot 0 is free again.
        assert_eq!(f.try_begin_write(), Some(0));
    }

    #[test]
    fn single_buffer_serializes() {
        // depth=1: write and read strictly alternate — the reason C-tile
        // drains serialize with compute (Sec. 5.3.2).
        let mut f = BufferFifo::new(1);
        assert_eq!(f.try_begin_write(), Some(0));
        assert_eq!(f.try_begin_write(), None);
        f.end_write().unwrap();
        assert_eq!(f.try_begin_read(), Some(0));
        assert_eq!(f.try_begin_write(), None); // still reading
        f.end_read().unwrap();
        assert_eq!(f.try_begin_write(), Some(0));
    }
}
