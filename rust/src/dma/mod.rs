//! DMA engine model: buffer descriptors with multi-dimensional address
//! generation (Sec. 3.2).
//!
//! A BD describes one DMA transfer as up to four nested loops of
//! `(size, stride)` pairs over **32-bit words** — address generation in the
//! NPU DMAs happens at 32-bit granularity, which is why element-level
//! swizzles of int8/bf16 data need in-core shuffle instructions instead
//! (Sec. 4.3, `python/compile/kernels/transpose.py`).
//!
//! * An **MM2S** channel *gathers*: it walks its BD over memory and pushes
//!   words to a stream in loop order.
//! * An **S2MM** channel *scatters*: it walks its BD and writes successive
//!   stream words to the generated addresses.
//!
//! Composing one gather with one scatter per hop reproduces the on-the-fly
//! layout transformations of Fig. 4 (`crate::xform`). CompTiles and
//! ShimTiles expose 3 dims, MemTiles 4 (Sec. 3.2); constructors enforce
//! the limits.

use anyhow::{bail, Result};

pub mod lock;

/// One address-generation loop: `size` iterations advancing `stride` words.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dim {
    pub size: usize,
    pub stride: isize,
}

impl Dim {
    pub fn new(size: usize, stride: isize) -> Self {
        Dim { size, stride }
    }
}

/// Which tile kind a BD executes on — bounds its dimensionality.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileKind {
    CompTile,
    MemTile,
    ShimTile,
}

impl TileKind {
    pub fn max_dims(self) -> usize {
        match self {
            TileKind::CompTile | TileKind::ShimTile => 3,
            TileKind::MemTile => 4,
        }
    }
}

/// A buffer descriptor: base word address + nested loops (outer→inner).
#[derive(Clone, Debug, PartialEq)]
pub struct Bd {
    pub tile: TileKind,
    pub base: usize,
    /// Loops outer-to-inner. Innermost is typically `(run, 1)`.
    pub dims: Vec<Dim>,
}

impl Bd {
    pub fn new(tile: TileKind, base: usize, dims: Vec<Dim>) -> Result<Bd> {
        if dims.is_empty() {
            bail!("BD needs at least one dim");
        }
        if dims.len() > tile.max_dims() {
            bail!(
                "{:?} supports {}D addressing, got {} dims (the paper's \
                 Sec. 4.3 decomposition exists precisely to avoid this)",
                tile,
                tile.max_dims(),
                dims.len()
            );
        }
        if dims.iter().any(|d| d.size == 0) {
            bail!("BD dim with zero size");
        }
        Ok(Bd { tile, base, dims })
    }

    /// Linear transfer of `words` contiguous words.
    pub fn linear(tile: TileKind, base: usize, words: usize) -> Result<Bd> {
        Bd::new(tile, base, vec![Dim::new(words, 1)])
    }

    /// Total words transferred.
    pub fn len(&self) -> usize {
        self.dims.iter().map(|d| d.size).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Iterate generated word addresses in loop order.
    pub fn addresses(&self) -> AddrIter<'_> {
        AddrIter { bd: self, idx: vec![0; self.dims.len()], done: false }
    }

    /// Visit the BD's address stream as `(base, run_len)` maximal
    /// contiguous runs when the innermost dim is unit-stride (always the
    /// case for the Fig.-4 chains), falling back to single-word runs.
    /// This is the hot path of the functional mover (§Perf).
    fn for_each_run(&self, mut f: impl FnMut(usize, usize) -> Result<()>) -> Result<()> {
        let (outer, run_len) = match self.dims.split_last() {
            Some((last, rest)) if last.stride == 1 => (rest, last.size),
            _ => (&self.dims[..], 1),
        };
        // Odometer over the outer dims, emitting one run per position.
        let mut idx = vec![0usize; outer.len()];
        loop {
            let mut addr = self.base as isize;
            for (i, d) in outer.iter().enumerate() {
                addr += idx[i] as isize * d.stride;
            }
            debug_assert!(addr >= 0, "negative DMA address");
            f(addr as usize, run_len)?;
            // Increment from the innermost outer dim.
            let mut done = outer.is_empty();
            for i in (0..outer.len()).rev() {
                idx[i] += 1;
                if idx[i] < outer[i].size {
                    break;
                }
                idx[i] = 0;
                if i == 0 {
                    done = true;
                }
            }
            if done {
                return Ok(());
            }
        }
    }

    /// Gather: read `self.len()` words from `mem` in BD order (MM2S).
    pub fn gather(&self, mem: &[u32]) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(self.len());
        self.for_each_run(|base, run| match mem.get(base..base + run) {
            Some(words) => {
                out.extend_from_slice(words);
                Ok(())
            }
            None => bail!("gather run {base}+{run} out of bounds ({} words)", mem.len()),
        })?;
        Ok(out)
    }

    /// Scatter: write `stream` into `mem` in BD order (S2MM).
    pub fn scatter(&self, mem: &mut [u32], stream: &[u32]) -> Result<()> {
        if stream.len() != self.len() {
            bail!("scatter stream {} words, BD expects {}", stream.len(), self.len());
        }
        let mut pos = 0usize;
        self.for_each_run(|base, run| match mem.get_mut(base..base + run) {
            Some(slot) => {
                slot.copy_from_slice(&stream[pos..pos + run]);
                pos += run;
                Ok(())
            }
            None => bail!("scatter run {base}+{run} out of bounds ({} words)", mem.len()),
        })
    }

    /// Average contiguous run length, in **bytes** — the quantity the
    /// effective-DRAM-bandwidth model keys on (DESIGN.md §5.2). A run is a
    /// maximal sequence of consecutive word addresses.
    pub fn avg_contig_run_bytes(&self) -> f64 {
        let mut runs = 0u64;
        let mut prev: Option<usize> = None;
        for a in self.addresses() {
            match prev {
                Some(p) if a == p + 1 => {}
                _ => runs += 1,
            }
            prev = Some(a);
        }
        if runs == 0 {
            return 0.0;
        }
        (self.len() as u64 * 4) as f64 / runs as f64
    }
}

/// Address iterator over a BD's nested loops.
pub struct AddrIter<'a> {
    bd: &'a Bd,
    idx: Vec<usize>,
    done: bool,
}

impl Iterator for AddrIter<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        if self.done {
            return None;
        }
        let mut addr = self.bd.base as isize;
        for (i, d) in self.bd.dims.iter().enumerate() {
            addr += self.idx[i] as isize * d.stride;
        }
        // Increment odometer from the innermost dim.
        for i in (0..self.idx.len()).rev() {
            self.idx[i] += 1;
            if self.idx[i] < self.bd.dims[i].size {
                break;
            }
            self.idx[i] = 0;
            if i == 0 {
                self.done = true;
            }
        }
        debug_assert!(addr >= 0, "negative DMA address");
        Some(addr as usize)
    }
}

/// Bytes→words helper; errors if not word-aligned (the 32-bit granularity
/// rule).
pub fn words(elems: usize, elem_bytes: usize) -> Result<usize> {
    let bytes = elems * elem_bytes;
    if bytes % 4 != 0 {
        bail!(
            "{elems} elements of {elem_bytes} B = {bytes} B: not 32-bit \
             aligned; DMAs cannot address this (Sec. 4.3)"
        );
    }
    Ok(bytes / 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn linear_bd() {
        let bd = Bd::linear(TileKind::ShimTile, 3, 5).unwrap();
        let addrs: Vec<_> = bd.addresses().collect();
        assert_eq!(addrs, vec![3, 4, 5, 6, 7]);
        assert_eq!(bd.avg_contig_run_bytes(), 20.0);
    }

    #[test]
    fn row_major_submatrix_gather() {
        // 2x3 tile out of a 2-row x 8-word matrix starting at word 1.
        let bd = Bd::new(
            TileKind::ShimTile,
            1,
            vec![Dim::new(2, 8), Dim::new(3, 1)],
        )
        .unwrap();
        let mem: Vec<u32> = (0..16).collect();
        assert_eq!(bd.gather(&mem).unwrap(), vec![1, 2, 3, 9, 10, 11]);
        // Two runs of 3 words = 12 B average run length.
        assert_eq!(bd.avg_contig_run_bytes(), 12.0);
    }

    #[test]
    fn dim_limits_enforced() {
        let four = vec![Dim::new(2, 1); 4];
        assert!(Bd::new(TileKind::MemTile, 0, four.clone()).is_ok());
        assert!(Bd::new(TileKind::CompTile, 0, four.clone()).is_err());
        assert!(Bd::new(TileKind::ShimTile, 0, four).is_err());
    }

    #[test]
    fn scatter_inverts_gather_for_permutations() {
        prop_check("scatter∘gather = identity on permutation BDs", 50, |rng| {
            // Random 2D tile view of a rows x cols matrix: a permutation of
            // all words when tile == matrix.
            let rows = 1 + rng.below(6);
            let cols = 1 + rng.below(6);
            let bd = Bd::new(
                TileKind::MemTile,
                0,
                vec![Dim::new(cols, 1), Dim::new(rows, cols as isize)],
            )
            .unwrap(); // column-major walk
            let mem: Vec<u32> = (0..(rows * cols) as u32).collect();
            let stream = bd.gather(&mem).unwrap();
            let mut back = vec![0u32; mem.len()];
            bd.scatter(&mut back, &stream).unwrap();
            assert_eq!(back, mem);
        });
    }

    #[test]
    fn addresses_cover_each_word_exactly_once_for_tilings() {
        prop_check("BD tiling covers memory exactly once", 60, |rng| {
            // Tile a (ro*ri) x (co*ci) word matrix into ri x ci tiles: the
            // classic 4D pre-tiling walk must visit every word once.
            let ro = 1 + rng.below(4);
            let ri = 1 + rng.below(4);
            let co = 1 + rng.below(4);
            let ci = 1 + rng.below(4);
            let width = co * ci;
            let bd = Bd::new(
                TileKind::MemTile,
                0,
                vec![
                    Dim::new(ro, (ri * width) as isize),
                    Dim::new(co, ci as isize),
                    Dim::new(ri, width as isize),
                    Dim::new(ci, 1),
                ],
            )
            .unwrap();
            let mut seen = vec![0u8; ro * ri * width];
            for a in bd.addresses() {
                seen[a] += 1;
            }
            assert!(seen.iter().all(|&c| c == 1), "not a permutation");
        });
    }

    #[test]
    fn words_alignment() {
        assert_eq!(words(8, 1).unwrap(), 2);
        assert_eq!(words(2, 2).unwrap(), 1);
        assert!(words(3, 1).is_err());
        assert!(words(1, 2).is_err());
    }
}
