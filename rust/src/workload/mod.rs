//! DL workload traces: the GEMM shapes the paper's introduction motivates
//! (transformer / MLP inference layers), GGML-style shape import, and the
//! Figs. 7–8 roofline sweep generator.

pub mod llm;

use crate::dtype::{Layout, Precision};
use crate::tiling::TilingConfig;
use crate::util::rng::Rng;

/// One GEMM in a workload trace.
#[derive(Clone, Debug, PartialEq)]
pub struct GemmShape {
    pub name: String,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub precision: Precision,
    pub b_layout: Layout,
}

impl GemmShape {
    /// Panics on a zero dimension: a degenerate GEMM has no ops and
    /// divides by zero in `padding_efficiency`/TOPS math downstream, so
    /// it is rejected at construction (ISSUE 7 bugfix). Shapes arriving
    /// from external text go through [`parse_trace`], which reports the
    /// offending line as an `Err` instead.
    pub fn new(name: &str, m: usize, k: usize, n: usize, p: Precision) -> GemmShape {
        assert!(
            m > 0 && k > 0 && n > 0,
            "GemmShape '{name}': zero dimension in {m}x{k}x{n} (all of M, K, N must be >= 1)"
        );
        GemmShape {
            name: name.to_string(),
            m,
            k,
            n,
            precision: p,
            b_layout: Layout::ColMajor,
        }
    }

    pub fn ops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }
}

/// Transformer decoder-layer GEMMs for a prompt of `seq` tokens
/// (weights stationary, column-major — the library case the paper
/// optimizes for). Defaults give a ~110M-parameter GPT-2-small-like
/// config: d=768, 12 layers, ffn 4d, vocab 50257.
#[derive(Clone, Copy, Debug)]
pub struct TransformerConfig {
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub vocab: usize,
    pub seq: usize,
    pub precision: Precision,
}

impl Default for TransformerConfig {
    fn default() -> Self {
        TransformerConfig {
            d_model: 768,
            n_layers: 12,
            d_ffn: 3072,
            vocab: 50257,
            seq: 512,
            precision: Precision::I8I8,
        }
    }
}

impl TransformerConfig {
    /// Approximate parameter count (the "~100M transformer" check).
    pub fn n_params(&self) -> usize {
        let per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ffn;
        self.n_layers * per_layer + self.vocab * self.d_model
    }

    /// The prefill GEMM trace for one forward pass.
    pub fn trace(&self) -> Vec<GemmShape> {
        let p = self.precision;
        let (s, d, f) = (self.seq, self.d_model, self.d_ffn);
        let mut out = Vec::new();
        for layer in 0..self.n_layers {
            out.push(GemmShape::new(&format!("L{layer}.qkv"), s, d, 3 * d, p));
            out.push(GemmShape::new(&format!("L{layer}.attn_out"), s, d, d, p));
            out.push(GemmShape::new(&format!("L{layer}.ffn_up"), s, d, f, p));
            out.push(GemmShape::new(&format!("L{layer}.ffn_down"), s, f, d, p));
        }
        out.push(GemmShape::new("lm_head", s, d, self.vocab, p));
        out
    }

    /// The prefill trace as producer→consumer chains (one per decoder
    /// layer plus the lm_head) — the chain planner's input
    /// (`crate::plan`).
    pub fn chains(&self) -> Vec<crate::plan::GemmChain> {
        crate::plan::transformer_chains(self)
    }

    /// The prefill trace as a whole-model graph (`crate::graph`) — the
    /// linear generator; `TransformerConfig` is just one producer of
    /// [`crate::graph::ModelGraph`]s next to the branching
    /// attention/MoE generators and the JSON parser.
    pub fn graph(&self) -> crate::graph::ModelGraph {
        crate::graph::transformer_graph(self)
    }

    /// The full attention-block DAG for this config (QKV fan-out +
    /// residual rejoins, `crate::graph::attention_graph`).
    pub fn attention_graph(&self) -> anyhow::Result<crate::graph::ModelGraph> {
        crate::graph::attention_graph(self)
    }

    /// Distinct (m, k, n) shapes in the trace — what the design cache
    /// actually has to handle (Sec. 5.3.1).
    pub fn distinct_shapes(&self) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<(usize, usize, usize)> =
            self.trace().iter().map(|g| (g.m, g.k, g.n)).collect();
        v.sort();
        v.dedup();
        v
    }
}

/// Skewed serving mix for fleet load tests (the Sec. 5.3 deployment
/// case): `count` requests drawn from the default transformer's prefill
/// shapes with a hot head — ~60% int8 column-major (the tuned library
/// path), ~10% int8→int16, ~10% native bfp16 (block-aligned shapes
/// only; the quantized-inference slice that routes hot to XDNA2),
/// ~10% bf16, ~10% int8 row-major — so a multi-device coordinator sees
/// both design reuse and design-switch pressure. Deterministic in
/// `seed`.
pub fn skewed_trace(count: usize, seed: u64) -> Vec<GemmShape> {
    let hot = TransformerConfig::default().trace();
    let mut rng = Rng::seeded(seed);
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let mut g = hot[rng.below(hot.len())].clone();
        g.name = format!("req{i}.{}", g.name);
        let roll = rng.below(10);
        if roll >= 8 {
            g.precision = Precision::Bf16;
        } else if roll == 7 && g.k % 8 == 0 && g.n % 8 == 0 {
            // Block format: only shapes whose K/N cover whole 8-value
            // blocks (everything but the ragged-vocab lm_head).
            g.precision = Precision::Bfp16;
        } else if roll >= 6 {
            g.precision = Precision::I8I16;
        }
        if roll == 9 {
            g.precision = Precision::I8I8;
            g.b_layout = Layout::RowMajor;
        }
        out.push(g);
    }
    out
}

/// Two-layer MLP trace (the quickstart-scale workload).
pub fn mlp_trace(
    batch: usize,
    d_in: usize,
    d_hidden: usize,
    d_out: usize,
    p: Precision,
) -> Vec<GemmShape> {
    vec![
        GemmShape::new("mlp.fc1", batch, d_in, d_hidden, p),
        GemmShape::new("mlp.fc2", batch, d_hidden, d_out, p),
    ]
}

/// Figs. 7–8 sweep generator: ≥`count` GEMM sizes, every dimension an
/// independent multiple of the native size, up to `max_dim` ("we select
/// more than 400 points ... up to 8K-sized matrices, without favoring any
/// particular M, K, N dimension").
pub fn roofline_sweep(
    cfg: &TilingConfig,
    count: usize,
    max_dim: usize,
    seed: u64,
) -> Vec<(usize, usize, usize)> {
    let (nm, nk, nn) = cfg.native();
    let (mi, ki, ni) = (max_dim / nm, max_dim / nk, max_dim / nn);
    let mut rng = Rng::seeded(seed);
    let mut seen = std::collections::BTreeSet::new();
    let mut out = Vec::new();
    // Deterministic low-discrepancy-ish fill of the multiplier lattice.
    while out.len() < count && seen.len() < mi * ki * ni {
        let m_mult = 1 + rng.below(mi.max(1));
        let k_mult = 1 + rng.below(ki.max(1));
        let n_mult = 1 + rng.below(ni.max(1));
        if seen.insert((m_mult, k_mult, n_mult)) {
            out.push((m_mult * nm, k_mult * nk, n_mult * nn));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{balanced_config, Generation};

    #[test]
    fn default_transformer_is_about_100m_params() {
        let cfg = TransformerConfig::default();
        let p = cfg.n_params();
        assert!((80_000_000..150_000_000).contains(&p), "{p}");
    }

    #[test]
    fn trace_covers_all_layer_gemms() {
        let cfg = TransformerConfig::default();
        let t = cfg.trace();
        assert_eq!(t.len(), 12 * 4 + 1);
        // FFN GEMMs dominate ops.
        let total: f64 = t.iter().map(|g| g.ops()).sum();
        assert!(total > 1e11);
        // Only 5 distinct shapes → design reuse is the common case.
        assert_eq!(cfg.distinct_shapes().len(), 5);
    }

    #[test]
    fn sweep_is_deterministic_unique_and_bounded() {
        let cfg = balanced_config(Generation::Xdna2, Precision::I8I16);
        let s1 = roofline_sweep(&cfg, 400, 8192, 1);
        let s2 = roofline_sweep(&cfg, 400, 8192, 1);
        assert_eq!(s1, s2);
        assert!(s1.len() >= 400);
        let mut uniq = s1.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), s1.len(), "duplicate sweep points");
        let (nm, nk, nn) = cfg.native();
        for (m, k, n) in s1 {
            assert!(m % nm == 0 && k % nk == 0 && n % nn == 0);
            assert!(m <= 8192 && k <= 8192 && n <= 8192);
        }
    }
}

/// GEMV analysis (Sec. 5.3.4 future work): matrix-vector products are the
/// M=1 degenerate case. Under the paper's output-stationary array mapping
/// they pad M up to `m_ct·m_rows`, wasting all but one row — this function
/// quantifies that, motivating the dedicated GEMV design the paper defers.
pub fn gemv_efficiency(cfg: &TilingConfig, k: usize, n: usize) -> f64 {
    cfg.padding_efficiency(1, k, n)
}

#[cfg(test)]
mod gemv_tests {
    use super::*;
    use crate::arch::{balanced_config, Generation};
    use crate::sim::{simulate_gemm, BdMode};

    #[test]
    fn gemv_wastes_the_array_under_the_gemm_mapping() {
        // The quantitative reason Sec. 5.3.4 defers GEMV: on the XDNA2
        // int8 design, a 4K GEMV uses <0.3% of the padded work.
        let cfg = balanced_config(Generation::Xdna2, Precision::I8I8);
        let eff = gemv_efficiency(&cfg, 4096, 4096);
        assert!(eff < 0.003, "{eff}");
        // And the end-to-end TOPS collapse accordingly (memory-bound on
        // the padded problem; real utility lower still).
        let r = simulate_gemm(&cfg, 1, 4096, 4096, BdMode::Overlapped);
        assert!(r.tops < 0.2, "{}", r.tops);
        assert!(r.tops_padded > 100.0 * r.tops);
    }
}

/// GGML-style shape import (Sec. 1: "seamless integration with tensor
/// libraries for DL, such as GGML"): parse a simple text trace — one GEMM
/// per line, `name M K N precision [rowmajor|colmajor]`, `#` comments —
/// the format a GGML-side exporter dumps per forward pass.
pub fn parse_trace(text: &str) -> anyhow::Result<Vec<GemmShape>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() < 5 {
            anyhow::bail!("line {}: expected `name M K N precision [layout]`", lineno + 1);
        }
        let parse_dim = |s: &str, what: &str| -> anyhow::Result<usize> {
            let v: usize = s
                .parse()
                .map_err(|_| anyhow::anyhow!("line {}: bad {what} '{s}'", lineno + 1))?;
            if v == 0 {
                anyhow::bail!(
                    "line {}: {what} must be >= 1 (got 0; a zero-dimension GEMM has no work)",
                    lineno + 1
                );
            }
            Ok(v)
        };
        let precision = Precision::parse(toks[4]).ok_or_else(|| {
            anyhow::anyhow!("line {}: unknown precision '{}'", lineno + 1, toks[4])
        })?;
        let b_layout = match toks.get(5) {
            None => Layout::ColMajor,
            Some(s) => Layout::parse(s)
                .ok_or_else(|| anyhow::anyhow!("line {}: unknown layout '{s}'", lineno + 1))?,
        };
        if precision == Precision::Fp32Split {
            anyhow::bail!(
                "line {}: fp32_split is a logical precision with no dispatch-layer \
                 schedule; route the op through the graph/compile path, which lowers \
                 it to bf16 limb GEMMs",
                lineno + 1
            );
        }
        if precision == Precision::Bfp16 && b_layout == Layout::RowMajor {
            anyhow::bail!(
                "line {}: bfp16 requires column-major B (blocks run along K)",
                lineno + 1
            );
        }
        out.push(GemmShape {
            name: toks[0].to_string(),
            m: parse_dim(toks[1], "M")?,
            k: parse_dim(toks[2], "K")?,
            n: parse_dim(toks[3], "N")?,
            precision,
            b_layout,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod trace_tests {
    use super::*;

    #[test]
    fn parses_ggml_style_traces() {
        let text = "\
# llama.cpp-ish prefill dump
blk0.attn_q  512 4096 4096 i8i8
blk0.ffn_up  512 4096 11008 i8i16 rowmajor

blk0.ffn_down 512 11008 4096 bf16  # trailing comment
";
        let t = parse_trace(text).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].name, "blk0.attn_q");
        assert_eq!((t[1].m, t[1].k, t[1].n), (512, 4096, 11008));
        assert_eq!(t[1].b_layout, Layout::RowMajor);
        assert_eq!(t[2].precision, Precision::Bf16);
        assert_eq!(t[2].b_layout, Layout::ColMajor); // default
    }

    #[test]
    fn skewed_trace_is_deterministic_with_hot_head() {
        let t1 = skewed_trace(400, 7);
        let t2 = skewed_trace(400, 7);
        assert_eq!(t1.len(), 400);
        assert_eq!(
            t1.iter().map(|g| (g.m, g.k, g.n, g.precision, g.b_layout)).collect::<Vec<_>>(),
            t2.iter().map(|g| (g.m, g.k, g.n, g.precision, g.b_layout)).collect::<Vec<_>>()
        );
        let hot = t1
            .iter()
            .filter(|g| g.precision == Precision::I8I8 && g.b_layout == Layout::ColMajor)
            .count();
        assert!(hot > 180, "hot design should dominate: {hot}/400");
        let mut keys: Vec<(Precision, Layout)> =
            t1.iter().map(|g| (g.precision, g.b_layout)).collect();
        keys.sort();
        keys.dedup();
        assert!(keys.len() >= 3, "mix must exercise several design keys");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_trace("x 1 2").is_err());
        assert!(parse_trace("x 1 2 3 notaprecision").is_err());
        assert!(parse_trace("x 1 b 3 i8i8").is_err());
        assert!(parse_trace("x 1 2 3 i8i8 diagonal").is_err());
        // Comments and blanks alone are fine.
        assert!(parse_trace("# nothing\n\n").unwrap().is_empty());
    }

    #[test]
    fn rejects_fp32_split_at_the_dispatch_layer_with_guidance() {
        // fp32_split parses as a Precision (graph JSON needs it) but has
        // no datapath schedule: a hostile/stale trace naming it must get
        // a typed line-numbered error steering at the compile path — not
        // a panic later in TilingConfig::validate.
        for spelled in ["fp32_split", "fp32-split"] {
            let err = parse_trace(&format!("ok 1 2 3 i8i8\nx 64 64 64 {spelled}"))
                .unwrap_err()
                .to_string();
            assert!(err.contains("line 2"), "{err}");
            assert!(err.contains("logical precision"), "{err}");
            assert!(err.contains("graph"), "{err}");
        }
    }

    #[test]
    fn rejects_zero_dimensions_at_parse_time() {
        // Regression (ISSUE 7): zero dims used to parse fine and then
        // divide by zero in ops()/padding_efficiency downstream. The
        // error must name the line and the dimension.
        for (text, dim) in
            [("x 0 2 3 i8i8", "M"), ("x 1 0 3 i8i8", "K"), ("x 1 2 0 i8i8", "N")]
        {
            let err = parse_trace(text).unwrap_err().to_string();
            assert!(err.contains("line 1") && err.contains(dim), "{err}");
        }
        let err = parse_trace("ok 1 2 3 i8i8\nbad 4 0 6 bf16").unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains('K'), "{err}");
    }

    #[test]
    #[should_panic(expected = "zero dimension")]
    fn gemm_shape_new_rejects_zero_dimensions() {
        let _ = GemmShape::new("bad", 512, 0, 768, Precision::I8I8);
    }

    #[test]
    fn unknown_precision_is_an_error_not_a_default() {
        // The failure mode this guards: a typo'd precision silently
        // becoming i8i8 and the trace "working". The error must name the
        // line and the bad token.
        let err = parse_trace("blk0.q 512 768 768 fp8").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("fp8"), "{err}");
        let err2 = parse_trace("a 8 8 8 i8i8\nb 8 8 8 bf17").unwrap_err().to_string();
        assert!(err2.contains("line 2") && err2.contains("bf17"), "{err2}");
    }

    #[test]
    fn accepts_bfp16_traces() {
        let t = parse_trace("blk0.ffn_up 512 4096 11008 bfp16\n").unwrap();
        assert_eq!(t[0].precision, Precision::Bfp16);
        assert_eq!(t[0].b_layout, Layout::ColMajor);
        // Paper-style alias too.
        let t2 = parse_trace("x 8 8 8 bfp16-bfp16").unwrap();
        assert_eq!(t2[0].precision, Precision::Bfp16);
        // A row-major bfp16 B is physically unschedulable (blocks run
        // along K) — rejected at parse time, not deep in a leader.
        assert!(parse_trace("x 8 8 8 bfp16 rowmajor").is_err());
    }

    #[test]
    fn skewed_trace_bfp16_slice_is_block_aligned() {
        let t = skewed_trace(400, 7);
        let bfp: Vec<_> =
            t.iter().filter(|g| g.precision == Precision::Bfp16).collect();
        assert!(!bfp.is_empty(), "mix must include the bfp16 slice");
        for g in bfp {
            assert!(g.k % 8 == 0 && g.n % 8 == 0, "{}: {}x{}x{}", g.name, g.m, g.k, g.n);
            assert_eq!(g.b_layout, Layout::ColMajor);
        }
    }
}
