//! LLM serving workload: sessions, open-loop Poisson arrivals, and the
//! prefill/decode GEMM shapes of continuous batching (ISSUE 7).
//!
//! Serving an LLM splits each request into two GEMM regimes:
//!
//! * **prefill** — the whole prompt in one forward pass: the paper's
//!   large-M shapes (`[512, 768] · [768, 2304]`-class), served through
//!   the existing chain path where the balanced *wide* designs apply;
//! * **decode** — one token per forward pass per session: `[1, K] ·
//!   [K, N]` GEMVs that waste a wide design's array. Continuous
//!   batching coalesces the concurrent sessions' next-token GEMVs into
//!   one `[S, K] · [K, N]` GEMM per layer, which is exactly the
//!   skinny-M design class (`S <= arch::SKINNY_M_MAX`).
//!
//! Everything here is deterministic from a seed: arrivals are an
//! exponential-gap Poisson process over `util::rng::Rng`, and decode
//! lengths are sampled from the same stream, so a load is reproducible
//! across runs, platforms and the coalesced/uncoalesced baselines.

use crate::plan::GemmChain;
use crate::util::rng::Rng;
use crate::workload::{GemmShape, TransformerConfig};

/// One serving session: a prompt arriving at a virtual time, followed
/// by autoregressive decode.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub id: usize,
    /// Virtual arrival time (seconds) of the open-loop Poisson process.
    pub arrival_s: f64,
    /// Prompt length in tokens (the prefill GEMM's M).
    pub prefill_tokens: usize,
    /// Tokens to generate after prefill.
    pub decode_tokens: usize,
}

/// A deterministic serving load: `sessions` sessions arriving at
/// `arrival_rate` per virtual second, each decoding a seeded-uniform
/// number of tokens in `decode_tokens`.
#[derive(Clone, Copy, Debug)]
pub struct LlmLoad {
    pub model: TransformerConfig,
    pub sessions: usize,
    /// Open-loop Poisson arrival rate, sessions per virtual second.
    pub arrival_rate: f64,
    /// Inclusive range of decode lengths, sampled per session.
    pub decode_tokens: (usize, usize),
    pub seed: u64,
}

impl Default for LlmLoad {
    fn default() -> Self {
        LlmLoad {
            // The prefill default stays the paper-class [512,768]x[768,*]
            // shape; the lm_head vocab is trimmed so a decode forward
            // pass is layer-dominated like production serving stacks
            // (the full 50k-vocab head would be one GEMM outweighing
            // all 12 layers at M <= 64).
            model: TransformerConfig { vocab: 4096, ..Default::default() },
            sessions: 16,
            arrival_rate: 4.0,
            decode_tokens: (8, 32),
            seed: 7,
        }
    }
}

impl LlmLoad {
    /// Materialize the deterministic session list. Arrivals are sorted
    /// by construction (cumulative exponential gaps).
    pub fn sessions(&self) -> Vec<SessionSpec> {
        assert!(self.arrival_rate > 0.0, "arrival rate must be positive");
        let (lo, hi) = self.decode_tokens;
        assert!(lo >= 1 && hi >= lo, "decode token range must be 1 <= lo <= hi");
        let mut rng = Rng::seeded(self.seed ^ 0x11f3_77a9);
        let mut t = 0.0;
        (0..self.sessions)
            .map(|id| {
                // Exponential inter-arrival gap: -ln(1-U)/rate. `f64()`
                // is in [0,1), so 1-U is in (0,1] and ln is finite.
                t += -(1.0 - rng.f64()).ln() / self.arrival_rate;
                let decode_tokens = lo + rng.below(hi - lo + 1);
                SessionSpec {
                    id,
                    arrival_s: t,
                    prefill_tokens: self.model.seq,
                    decode_tokens,
                }
            })
            .collect()
    }

    /// Total decode tokens across all sessions (the conservation
    /// denominator: completed + failed + pending must equal this).
    pub fn total_decode_tokens(&self) -> usize {
        self.sessions().iter().map(|s| s.decode_tokens).sum()
    }
}

/// The prefill forward pass as one chain: every layer's four GEMMs plus
/// the lm_head, with producer→consumer edges auto-detected. One chain —
/// not one per layer — so the whole prompt lands on a single device and
/// the session's KV cache is device-resident from the first token.
pub fn prefill_chain(model: &TransformerConfig, name: &str) -> GemmChain {
    GemmChain::detect(name, &model.trace())
}

/// One decode forward step for a coalesced batch of `m` sessions: the
/// per-layer GEMM trace at M = m. With `m = 1` this is the uncoalesced
/// per-session GEMV sequence; with `m = S` it is the continuous-batching
/// step where S sessions' next tokens share every weight stream.
pub fn decode_step_shapes(model: &TransformerConfig, m: usize, prefix: &str) -> Vec<GemmShape> {
    let batched = TransformerConfig { seq: m, ..*model };
    batched
        .trace()
        .into_iter()
        .map(|g| GemmShape { name: format!("{prefix}.{}", g.name), ..g })
        .collect()
}

/// [`decode_step_shapes`] as a single chain (edges auto-detected), the
/// unit the serving runtime submits per device per decode round.
pub fn decode_step_chain(model: &TransformerConfig, m: usize, name: &str) -> GemmChain {
    GemmChain::detect(name, &decode_step_shapes(model, m, name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SKINNY_M_MAX;
    use crate::coordinator::{DesignKey, MClass};

    #[test]
    fn arrivals_are_deterministic_sorted_and_rate_scaled() {
        let load = LlmLoad { sessions: 64, ..Default::default() };
        let a = load.sessions();
        let b = load.sessions();
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits(), "non-deterministic");
            assert_eq!(x.decode_tokens, y.decode_tokens);
        }
        for w in a.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s, "arrivals out of order");
        }
        // Mean inter-arrival ~ 1/rate (loose: 64 samples).
        let mean_gap = a.last().unwrap().arrival_s / 64.0;
        assert!(
            (0.5 / load.arrival_rate..2.0 / load.arrival_rate).contains(&mean_gap),
            "mean gap {mean_gap} vs 1/rate {}",
            1.0 / load.arrival_rate
        );
        // A different seed moves the arrivals.
        let other = LlmLoad { seed: 99, ..load }.sessions();
        assert!(a.iter().zip(&other).any(|(x, y)| x.arrival_s != y.arrival_s));
    }

    #[test]
    fn decode_lengths_cover_the_range() {
        let load = LlmLoad { sessions: 256, decode_tokens: (4, 6), ..Default::default() };
        let lens: Vec<usize> = load.sessions().iter().map(|s| s.decode_tokens).collect();
        assert!(lens.iter().all(|&l| (4..=6).contains(&l)));
        for want in 4..=6 {
            assert!(lens.contains(&want), "256 samples never hit {want}");
        }
        assert_eq!(load.total_decode_tokens(), lens.iter().sum::<usize>());
    }

    #[test]
    fn decode_step_is_skinny_class_and_prefill_is_wide() {
        let model = LlmLoad::default().model;
        for m in [1, 8, SKINNY_M_MAX] {
            for g in decode_step_shapes(&model, m, "r0") {
                assert_eq!(g.m, m);
                assert_eq!(DesignKey::for_shape(&g).m_class, MClass::Skinny, "{}", g.name);
            }
        }
        let chain = decode_step_chain(&model, 8, "r0");
        assert_eq!(chain.len(), 4 * model.n_layers + 1);
        // Same-layer ffn edges fuse; cross-layer residual edges too
        // (ffn_down's N == next qkv's K == d_model, same M).
        assert!(chain.edges() >= 2 * model.n_layers);

        let pre = prefill_chain(&model, "s0.prefill");
        assert_eq!(pre.len(), 4 * model.n_layers + 1);
        for op in &pre.ops {
            assert_eq!(op.shape.m, model.seq);
            assert_eq!(DesignKey::for_shape(&op.shape).m_class, MClass::Wide);
        }
    }
}
