//! Ozaki/Ootomo precision-recovery splitting: fp32-accuracy GEMM out of
//! bf16 limb GEMMs (DESIGN.md §15).
//!
//! The NPUs have no fp32 MAC path (Sec. 5 evaluates int8 and bf16 only),
//! so `Precision::Fp32Split` synthesizes one: each f32 operand element
//! splits *error-free* into a bf16 hi limb and a bf16 lo limb,
//!
//! ```text
//!   x  =  hi + lo + r,   hi = bf16(x),  lo = bf16(x − hi),
//!   |r| ≤ u²·|x|,        u = 2⁻⁹  (bf16 unit roundoff)
//! ```
//!
//! where `x − hi` is exactly representable in f32 (the classic
//! error-free transformation: `hi` is `x` rounded to a shorter mantissa
//! of the same exponent format). The product then expands into limb
//! GEMMs; dropping the second-order `lo·lo` term leaves three:
//!
//! ```text
//!   A·B  ≈  Ahi·Bhi + Ahi·Blo + Alo·Bhi          (LIMB_GEMMS = 3)
//! ```
//!
//! Each limb GEMM is a plain bf16 GEMM — bf16×bf16 products are *exact*
//! in f32 (8+8 significand bits < 24) — accumulated in f32 ascending-k,
//! exactly like [`crate::gemm::refimpl::ref_gemm`]'s bf16 path. The
//! rejoin is the fixed-order elementwise f32 sum `(hh + hl) + lh`.
//! Crucially the limb partials and the joined C stay f32: a bf16 store
//! of the `hh` term alone would reintroduce the 2⁻⁹ error the split
//! exists to remove.
//!
//! Everything here is deterministic and row-independent, so
//! [`split_exec`] reproduces [`split_gemm`] bit-for-bit at every thread
//! count — the same contract the packed executor gives bf16.

use anyhow::{ensure, Result};

use crate::dtype::{Bf16, Layout, Precision};
use crate::mem::Matrix;
use crate::workload::GemmShape;

/// bf16 limb GEMMs per logical fp32_split GEMM (the `lo·lo` term is
/// dropped — it is O(u²) relative, below the rejoin's own f32 noise).
pub const LIMB_GEMMS: usize = 3;

/// Error-free two-limb split of one f32 value. Non-finite inputs carry
/// entirely in the hi limb (`lo = 0`), so NaN/Inf propagate through the
/// hi·hi limb GEMM exactly once.
#[inline]
pub fn split_f32(x: f32) -> (Bf16, Bf16) {
    let hi = Bf16::from_f32(x);
    if !x.is_finite() {
        return (hi, Bf16::ZERO);
    }
    let lo = Bf16::from_f32(x - hi.to_f32());
    (hi, lo)
}

/// Split an f32 operand image into its bf16 hi/lo limb images (same
/// dims and layout). The input must be a 4-byte-element image; the
/// bf16 images need word-aligned 2-byte storage rows, so the split
/// inherits `Matrix::zeroed`'s alignment rules.
pub fn split_operand(m: &Matrix) -> Result<(Matrix, Matrix)> {
    ensure!(m.elem_bytes == 4, "split_operand needs an f32 image (4-byte elements)");
    let mut hi = Matrix::zeroed(m.rows, m.cols, 2, m.layout)?;
    let mut lo = Matrix::zeroed(m.rows, m.cols, 2, m.layout)?;
    for i in 0..m.rows {
        for j in 0..m.cols {
            let (h, l) = split_f32(m.get_f32(i, j));
            hi.set_bf16(i, j, h);
            lo.set_bf16(i, j, l);
        }
    }
    Ok((hi, lo))
}

/// The three bf16 limb GEMM shapes a logical fp32_split `shape` lowers
/// to, in rejoin order (`hh`, `hl`, `lh`) — the `Lowered::splits`
/// metadata the graph compiler exposes.
pub fn limb_shapes(shape: &GemmShape) -> [GemmShape; 3] {
    let limb = |suffix: &str| GemmShape {
        name: format!("{}.{suffix}", shape.name),
        m: shape.m,
        k: shape.k,
        n: shape.n,
        precision: Precision::Bf16,
        b_layout: shape.b_layout,
    };
    [limb("hh"), limb("hl"), limb("lh")]
}

/// One output row of the limb-GEMM rejoin: three ascending-k f32
/// accumulations over the packed limb panels, then the fixed-order
/// elementwise join `(hh + hl) + lh`. Shared verbatim by the serial and
/// threaded paths — the bit-exactness anchor.
fn split_row(
    ap_hi: &[f32],
    ap_lo: &[f32],
    bp_hi: &[f32],
    bp_lo: &[f32],
    k: usize,
    n: usize,
    i: usize,
    out: &mut [f32],
) {
    let mut hh = vec![0f32; n];
    let mut hl = vec![0f32; n];
    let mut lh = vec![0f32; n];
    let arow_hi = &ap_hi[i * k..(i + 1) * k];
    let arow_lo = &ap_lo[i * k..(i + 1) * k];
    for kk in 0..k {
        let (ah, al) = (arow_hi[kk], arow_lo[kk]);
        let brow_hi = &bp_hi[kk * n..(kk + 1) * n];
        let brow_lo = &bp_lo[kk * n..(kk + 1) * n];
        for j in 0..n {
            hh[j] += ah * brow_hi[j];
            hl[j] += ah * brow_lo[j];
            lh[j] += al * brow_hi[j];
        }
    }
    for j in 0..n {
        out[j] = (hh[j] + hl[j]) + lh[j];
    }
}

fn split_panels(a: &Matrix, b: &Matrix) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
    ensure!(a.layout == Layout::RowMajor, "A must be row-major");
    ensure!(a.elem_bytes == 4 && b.elem_bytes == 4, "fp32_split operands must be f32 images");
    ensure!(a.cols == b.rows, "shape mismatch: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (a_hi, a_lo) = split_operand(a)?;
    let (b_hi, b_lo) = split_operand(b)?;
    Ok((a_hi.packed_f32(), a_lo.packed_f32(), b_hi.packed_f32(), b_lo.packed_f32()))
}

/// The logical fp32_split GEMM: split both operands, run the three bf16
/// limb GEMMs, rejoin in f32. Returns a row-major f32 C image.
pub fn split_gemm(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    split_exec(a, b, 1)
}

/// [`split_gemm`] with the output rows fanned across `threads` OS
/// threads. Rows are computed by the identical per-row kernel, so the
/// result is bit-exact for every thread count.
pub fn split_exec(a: &Matrix, b: &Matrix, threads: usize) -> Result<Matrix> {
    let (m, k) = (a.rows, a.cols);
    let n = b.cols;
    let (ap_hi, ap_lo, bp_hi, bp_lo) = split_panels(a, b)?;
    let mut c = Matrix::zeroed(m, n, 4, Layout::RowMajor)?;
    let mut rows = vec![0f32; m * n];
    let threads = threads.max(1).min(m.max(1));
    if threads <= 1 {
        for i in 0..m {
            split_row(&ap_hi, &ap_lo, &bp_hi, &bp_lo, k, n, i, &mut rows[i * n..(i + 1) * n]);
        }
    } else {
        let chunk_rows = m.div_ceil(threads);
        std::thread::scope(|scope| {
            for (t, chunk) in rows.chunks_mut(chunk_rows * n).enumerate() {
                let (ap_hi, ap_lo, bp_hi, bp_lo) = (&ap_hi, &ap_lo, &bp_hi, &bp_lo);
                scope.spawn(move || {
                    let i0 = t * chunk_rows;
                    for (r, row) in chunk.chunks_mut(n).enumerate() {
                        split_row(ap_hi, ap_lo, bp_hi, bp_lo, k, n, i0 + r, row);
                    }
                });
            }
        });
    }
    for i in 0..m {
        for j in 0..n {
            c.set_f32(i, j, rows[i * n + j]);
        }
    }
    Ok(c)
}

/// Dense logical-row-major f64 widening of an operand image: bf16
/// (2-byte) or f32 (4-byte) elements, either layout — the oracle's view.
pub fn packed_f64(m: &Matrix) -> Vec<f64> {
    let mut out = vec![0f64; m.rows * m.cols];
    for i in 0..m.rows {
        for j in 0..m.cols {
            out[i * m.cols + j] = match m.elem_bytes {
                2 => m.get_bf16(i, j).to_f32() as f64,
                4 => m.get_f32(i, j) as f64,
                _ => panic!("packed_f64: {}-byte elements", m.elem_bytes),
            };
        }
    }
    out
}

/// f64 oracle GEMM over f32/bf16 operand images (ascending-k, like every
/// reference path). Returns the dense row-major result.
pub fn gemm_f64(a: &Matrix, b: &Matrix) -> Vec<f64> {
    let (m, k) = (a.rows, a.cols);
    let n = b.cols;
    let ap = packed_f64(a);
    let bp = packed_f64(b);
    let mut out = vec![0f64; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = ap[i * k + kk];
            for j in 0..n {
                out[i * n + j] += av * bp[kk * n + j];
            }
        }
    }
    out
}

/// Derived worst-case bound on `|split_gemm − f64 oracle|` for a
/// K-deep reduction with operand magnitudes ≤ `max_a` / `max_b`
/// (DESIGN.md §15 walks the derivation):
///
/// * dropped `lo·lo` + split residuals: ≤ 4·u²·|a||b| per product,
///   u = 2⁻⁹ → `4·2⁻¹⁸·K·max_a·max_b`;
/// * three f32 accumulations + the 2-step rejoin: ≤ (K+2)·2⁻²⁴ on each
///   limb's running magnitude, bounded by `3·(K+2)·2⁻²⁴·K·max_a·max_b`;
/// * bf16 subnormal floor: a lo limb below 2⁻¹³³ quantizes with ≤ 2⁻¹³⁴
///   absolute error → `K·(max_a + max_b)·2⁻¹³⁴`.
pub fn error_bound(k: usize, max_a: f64, max_b: f64) -> f64 {
    let kf = k as f64;
    let split = 4.0 * 2f64.powi(-18) * kf * max_a * max_b;
    let accum = 3.0 * (kf + 2.0) * 2f64.powi(-24) * kf * max_a * max_b;
    let subnormal = kf * (max_a + max_b) * 2f64.powi(-134);
    split + accum + subnormal
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_error_free_to_second_order() {
        for x in [1.0f32, -3.140625, 1.0e-3, 6.5e7, -2.0e-20, 1.9999999] {
            let (hi, lo) = split_f32(x);
            let back = hi.to_f32() + lo.to_f32();
            let err = (x - back).abs() as f64;
            assert!(
                err <= 2f64.powi(-16) * x.abs() as f64 + 2f64.powi(-134),
                "{x}: residual {err}"
            );
        }
        // hi alone is the plain bf16 rounding; lo recovers most of it.
        let (hi, lo) = split_f32(1.0039062);
        assert!(hi.to_f32() == 1.0 && lo.to_f32() > 0.0);
    }

    #[test]
    fn split_nonfinite_rides_hi_limb() {
        for x in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let (hi, lo) = split_f32(x);
            assert_eq!(lo.to_bits(), 0);
            if x.is_nan() {
                assert!(hi.to_f32().is_nan());
            } else {
                assert_eq!(hi.to_f32(), x);
            }
        }
    }

    #[test]
    fn limb_shapes_are_bf16_same_geometry() {
        let shape = GemmShape {
            name: "qkv".into(),
            m: 512,
            k: 768,
            n: 768,
            precision: Precision::Fp32Split,
            b_layout: Layout::ColMajor,
        };
        let limbs = limb_shapes(&shape);
        assert_eq!(limbs.len(), LIMB_GEMMS);
        let names: Vec<&str> = limbs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["qkv.hh", "qkv.hl", "qkv.lh"]);
        for l in &limbs {
            assert_eq!(l.precision, Precision::Bf16);
            assert_eq!((l.m, l.k, l.n), (512, 768, 768));
            assert_eq!(l.b_layout, Layout::ColMajor);
        }
    }

    #[test]
    fn tiny_split_gemm_matches_oracle_closely() {
        let (m, k, n) = (4, 8, 4);
        let mut a = Matrix::zeroed(m, k, 4, Layout::RowMajor).unwrap();
        let mut b = Matrix::zeroed(k, n, 4, Layout::ColMajor).unwrap();
        let mut rng = crate::util::rng::Rng::seeded(9);
        for i in 0..m {
            for j in 0..k {
                a.set_f32(i, j, rng.normal() as f32);
            }
        }
        for i in 0..k {
            for j in 0..n {
                b.set_f32(i, j, rng.normal() as f32);
            }
        }
        let c = split_gemm(&a, &b).unwrap();
        let oracle = gemm_f64(&a, &b);
        let bound = error_bound(k, 4.0, 4.0);
        for i in 0..m {
            for j in 0..n {
                let err = (c.get_f32(i, j) as f64 - oracle[i * n + j]).abs();
                assert!(err <= bound, "({i},{j}): {err} > {bound}");
            }
        }
    }

    #[test]
    fn threaded_split_exec_is_bitexact() {
        let (m, k, n) = (12, 16, 8);
        let mut a = Matrix::zeroed(m, k, 4, Layout::RowMajor).unwrap();
        let mut b = Matrix::zeroed(k, n, 4, Layout::RowMajor).unwrap();
        let mut rng = crate::util::rng::Rng::seeded(17);
        for i in 0..m {
            for j in 0..k {
                a.set_f32(i, j, (rng.normal() * 100.0) as f32);
            }
        }
        for i in 0..k {
            for j in 0..n {
                b.set_f32(i, j, (rng.normal() * 1e-3) as f32);
            }
        }
        let serial = split_gemm(&a, &b).unwrap();
        for threads in [2usize, 3, 8] {
            let t = split_exec(&a, &b, threads).unwrap();
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(
                        serial.get_f32(i, j).to_bits(),
                        t.get_f32(i, j).to_bits(),
                        "threads={threads} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn split_rejects_non_f32_images() {
        let a = Matrix::zeroed(4, 8, 2, Layout::RowMajor).unwrap();
        let b = Matrix::zeroed(8, 4, 4, Layout::ColMajor).unwrap();
        assert!(split_gemm(&a, &b).is_err());
        assert!(split_operand(&a).is_err());
    }
}
