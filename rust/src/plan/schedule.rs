//! The chain planner: compile chains into a dispatch schedule and
//! account it phase by phase.
//!
//! Three chain-level savings over isolated dispatches (docs/workloads.md):
//!
//! 1. **Fused edges** — when op *i+1* consumes op *i*'s C and the padded
//!    C fits in the design's L2 headroom, the C never round-trips DRAM:
//!    the producer's Eq. 8 write and the consumer's Eq. 6 read (plus A's
//!    prologue share) are elided.
//! 2. **Dispatch amortization** — consecutive same-design ops of a chain
//!    ride one host submission; only the first pays the 0.5 / 0.1 ms
//!    dispatch overhead.
//! 3. **Design grouping** — whole chains are scheduled grouped by design
//!    key (the leader-batch sort applied at plan level), so a workload of
//!    mixed precisions pays each 3.4 / 4.9 ms array reconfiguration once
//!    instead of on every interleaving.

use crate::arch::{balanced_config, Generation};
use crate::coordinator::router::{DesignKey, DeviceState};
use crate::dtype::{Layout, Precision};
use crate::dtype_split;
use crate::sim::{simulate_gemm_with, BdMode, DispatchOverrides};
use crate::tiling::TilingConfig;
use crate::workload::GemmShape;

use super::chain::GemmChain;

/// One scheduled GEMM dispatch.
#[derive(Clone, Debug)]
pub struct PlannedDispatch {
    pub shape: GemmShape,
    pub cfg: TilingConfig,
    /// Index into [`ChainPlan::chain_names`].
    pub chain: usize,
    pub overrides: DispatchOverrides,
}

/// A compiled dispatch schedule over one device generation.
#[derive(Clone, Debug)]
pub struct ChainPlan {
    pub gen: Generation,
    pub dispatches: Vec<PlannedDispatch>,
    /// Chain names in *schedule* order (grouped plans reorder chains).
    pub chain_names: Vec<String>,
}

impl ChainPlan {
    pub fn fused_edges(&self) -> usize {
        self.dispatches.iter().filter(|d| d.overrides.a_in_l2).count()
    }

    pub fn elided_dispatches(&self) -> usize {
        self.dispatches.iter().filter(|d| d.overrides.elide_dispatch).count()
    }
}

/// Bytes of the producer's padded C under `cfg`, and whether that fits
/// the design's free L2 (capacity minus the staged A/B/C working set) —
/// the fusion-eligibility rule.
pub fn resident_c_bytes(cfg: &TilingConfig, producer: &GemmShape) -> usize {
    let (pm, _, pn) = cfg.padded(producer.m, producer.k, producer.n);
    cfg.precision.bytes_out(pm * pn)
}

/// L2 bytes left once the design's double-buffered A/B tiles and C
/// aggregation are staged.
pub fn l2_headroom(cfg: &TilingConfig) -> usize {
    let (used, cap) = cfg.l2_usage();
    cap.saturating_sub(used)
}

/// Per-op execution overrides for one chain, given each op's resolved
/// design. Shared by [`Planner::plan`] and the coordinator's leaders
/// (which resolve designs from their own caches): an edge fuses when it
/// is structurally eligible, both ops run the *same* design (a
/// reconfiguration would tear down the resident L2 image), and the
/// resident images fit the design's L2 headroom in *every* execution
/// window they span. Concretely, while op *i−1* runs, its kept-resident
/// C (this edge) coexists with its own resident A (the previous edge,
/// if that fused — the A is re-read for every N-column block, so it
/// cannot be freed early); the greedy in-order decision therefore
/// charges the previous fused edge's bytes against the headroom.
pub fn overrides_for(cfgs: &[TilingConfig], chain: &GemmChain) -> Vec<DispatchOverrides> {
    assert_eq!(cfgs.len(), chain.ops.len());
    let mut ovs = vec![DispatchOverrides::default(); chain.ops.len()];
    // Bytes op i-1 already holds resident as its own A (0 when its
    // inbound edge didn't fuse).
    let mut held_a_bytes = 0usize;
    for i in 0..chain.ops.len() {
        let same_design = i > 0
            && DesignKey::for_shape(&chain.ops[i].shape)
                == DesignKey::for_shape(&chain.ops[i - 1].shape);
        if same_design {
            ovs[i].elide_dispatch = true;
        }
        let mut fused_in = 0usize;
        if same_design && chain.ops[i].consumes_prev {
            let producer = &chain.ops[i - 1].shape;
            let c_bytes = resident_c_bytes(&cfgs[i], producer);
            if c_bytes + held_a_bytes <= l2_headroom(&cfgs[i]) {
                ovs[i].a_in_l2 = true;
                ovs[i - 1].c_stays_in_l2 = true;
                fused_in = c_bytes;
            }
        }
        held_a_bytes = fused_in;
    }
    ovs
}

/// Compiles chains into dispatch schedules for one device generation,
/// resolving each op's design from the paper's balanced configurations.
#[derive(Clone, Copy, Debug)]
pub struct Planner {
    pub gen: Generation,
}

impl Planner {
    pub fn new(gen: Generation) -> Planner {
        Planner { gen }
    }

    fn cfg_for(&self, shape: &GemmShape) -> TilingConfig {
        // Resolve through the canonical design key (bfp16 normalizes to
        // its single valid layout), exactly like the coordinator's
        // leaders do via their design caches.
        let key = DesignKey::for_shape(shape);
        balanced_config(self.gen, key.precision).with_b_layout(key.b_layout)
    }

    /// The chain-aware schedule: chains grouped by their leading design
    /// key (stable — submission order kept within a group), edges fused
    /// where the L2 headroom allows, same-design dispatches amortized.
    pub fn plan(&self, chains: &[GemmChain]) -> ChainPlan {
        let mut order: Vec<usize> = (0..chains.len()).filter(|&i| !chains[i].is_empty()).collect();
        order.sort_by_key(|&i| {
            let s = &chains[i].ops[0].shape;
            (s.precision, s.b_layout == Layout::ColMajor)
        });
        self.emit(chains, &order, true)
    }

    /// The baseline every savings claim is measured against: chains in
    /// submission order, every op an isolated dispatch (full DRAM
    /// round-trips, a host dispatch each, reconfiguration on every
    /// design switch the interleaving produces).
    pub fn plan_isolated(&self, chains: &[GemmChain]) -> ChainPlan {
        let order: Vec<usize> = (0..chains.len()).filter(|&i| !chains[i].is_empty()).collect();
        self.emit(chains, &order, false)
    }

    fn emit(&self, chains: &[GemmChain], order: &[usize], fuse: bool) -> ChainPlan {
        let mut plan = ChainPlan { gen: self.gen, dispatches: Vec::new(), chain_names: Vec::new() };
        for &ci in order {
            let chain = &chains[ci];
            let cfgs: Vec<TilingConfig> =
                chain.ops.iter().map(|o| self.cfg_for(&o.shape)).collect();
            let ovs = if fuse {
                overrides_for(&cfgs, chain)
            } else {
                vec![DispatchOverrides::default(); chain.ops.len()]
            };
            let slot = plan.chain_names.len();
            plan.chain_names.push(chain.name.clone());
            for ((op, cfg), overrides) in chain.ops.iter().zip(cfgs).zip(ovs) {
                plan.dispatches.push(PlannedDispatch {
                    shape: op.shape.clone(),
                    cfg,
                    chain: slot,
                    overrides,
                });
            }
        }
        plan
    }
}

/// Phase-accounted evaluation of a schedule on one device.
#[derive(Clone, Debug, Default)]
pub struct PlanReport {
    pub dispatches: usize,
    pub chains: usize,
    pub fused_edges: usize,
    pub elided_dispatches: usize,
    pub reconfigurations: usize,
    /// Requested (unpadded) multiply-accumulate operations.
    pub ops: f64,
    /// DRAM bytes actually moved (fused edges move none for A/C).
    pub dram_bytes: f64,
    /// Σ per-dispatch `max(T_comp, T_mem)` — the double-buffered steady
    /// states.
    pub t_steady: f64,
    pub t_prologue: f64,
    pub t_stall: f64,
    pub t_dispatch: f64,
    pub t_reconfig: f64,
    /// Per-chain makespan (schedule order, incl. the reconfigurations
    /// its dispatches triggered) — mirrors `FleetMetrics` chain records.
    pub per_chain_s: Vec<f64>,
}

impl PlanReport {
    pub fn t_total(&self) -> f64 {
        self.t_steady + self.t_prologue + self.t_stall + self.t_dispatch + self.t_reconfig
    }

    pub fn tops(&self) -> f64 {
        let t = self.t_total();
        if t == 0.0 {
            0.0
        } else {
            self.ops / t / 1e12
        }
    }

    pub fn speedup_over(&self, baseline: &PlanReport) -> f64 {
        baseline.t_total() / self.t_total()
    }

    /// Machine-readable form for `plan --json` / `compile --json`
    /// (`scripts/bench.sh` and CI consume this instead of scraping
    /// [`Self::summary`]).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj, Json};
        obj(vec![
            ("dispatches", num(self.dispatches as f64)),
            ("chains", num(self.chains as f64)),
            ("fused_edges", num(self.fused_edges as f64)),
            ("elided_dispatches", num(self.elided_dispatches as f64)),
            ("reconfigurations", num(self.reconfigurations as f64)),
            ("ops", num(self.ops)),
            ("dram_bytes", num(self.dram_bytes)),
            ("t_steady_s", num(self.t_steady)),
            ("t_prologue_s", num(self.t_prologue)),
            ("t_stall_s", num(self.t_stall)),
            ("t_dispatch_s", num(self.t_dispatch)),
            ("t_reconfig_s", num(self.t_reconfig)),
            ("t_total_s", num(self.t_total())),
            ("tops", num(self.tops())),
            (
                "per_chain_s",
                Json::Arr(self.per_chain_s.iter().map(|&t| num(t)).collect()),
            ),
        ])
    }

    pub fn summary(&self) -> String {
        format!(
            "{} dispatches in {} chains | {:.3} ms total = steady {:.3} + prologue {:.3} + \
             stall {:.3} + dispatch {:.3} + reconfig {:.3} | {:.1} MB DRAM | {:.2} TOPS | \
             {} fused edges, {} elided dispatches, {} reconfigurations",
            self.dispatches,
            self.chains,
            self.t_total() * 1e3,
            self.t_steady * 1e3,
            self.t_prologue * 1e3,
            self.t_stall * 1e3,
            self.t_dispatch * 1e3,
            self.t_reconfig * 1e3,
            self.dram_bytes / 1e6,
            self.tops(),
            self.fused_edges,
            self.elided_dispatches,
            self.reconfigurations
        )
    }
}

/// Execute a schedule on the simulator: dispatches in order on one
/// device, reconfiguration charged on every design switch the order
/// produces (the chain-aware accounting of DESIGN.md §8).
pub fn evaluate(plan: &ChainPlan, mode: BdMode) -> PlanReport {
    let mut rep = PlanReport {
        dispatches: plan.dispatches.len(),
        chains: plan.chain_names.len(),
        fused_edges: plan.fused_edges(),
        elided_dispatches: plan.elided_dispatches(),
        per_chain_s: vec![0.0; plan.chain_names.len()],
        ..Default::default()
    };
    let mut device = DeviceState::default();
    for d in &plan.dispatches {
        let key = DesignKey::for_shape(&d.shape);
        let reconfig_s = device.switch_to(plan.gen, key);
        let r =
            simulate_gemm_with(&d.cfg, d.shape.m, d.shape.k, d.shape.n, mode, d.overrides);
        // A logical fp32_split dispatch is LIMB_GEMMS bf16 dispatches on
        // the wire: every device-side phase (and the bytes moved) scales
        // by the limb count. `ops` stays the logical 2·m·k·n — useful
        // work, not dispatched work — so its TOPS reflect the real
        // precision-recovery overhead.
        let mult = if d.shape.precision == Precision::Fp32Split {
            dtype_split::LIMB_GEMMS as f64
        } else {
            1.0
        };
        rep.ops += 2.0 * (d.shape.m * d.shape.k * d.shape.n) as f64;
        rep.dram_bytes += (r.a_bytes + r.b_bytes + r.c_bytes) * mult;
        rep.t_steady += r.t_comp.max(r.t_mem) * mult;
        rep.t_prologue += r.t_prologue * mult;
        rep.t_stall += r.t_stall * mult;
        rep.t_dispatch += r.t_dispatch * mult;
        rep.t_reconfig += reconfig_s;
        rep.per_chain_s[d.chain] += r.t_total * mult + reconfig_s;
    }
    rep.reconfigurations = device.reconfigurations;
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Precision;
    use crate::plan::chain::transformer_chains;
    use crate::workload::TransformerConfig;

    fn layer_chain(p: Precision) -> GemmChain {
        let cfg = TransformerConfig { n_layers: 1, precision: p, ..Default::default() };
        transformer_chains(&cfg).into_iter().next().unwrap()
    }

    #[test]
    fn fusion_eligibility_tracks_l2_headroom_per_generation() {
        // Default transformer (seq 512, d 768, ffn 3072). Padded-C bytes
        // vs the balanced designs' L2 headroom give per-generation fused
        // counts (hand-checked against tiling::l2_usage):
        //   XDNA  int8: attn_out→ffn_up fits (802 816 B ≤ ~1.09 MB free),
        //               ffn_up→ffn_down does not (2 809 856 B) → 1 edge;
        //   XDNA2 int8: attn_out→ffn_up fits (663 552 ≤ ~2.04 MB);
        //               ffn_up→ffn_down does NOT — ffn_up's C
        //               (1 990 656 B) would have to coexist with its
        //               resident A (663 552 B) and 2 654 208 B exceeds
        //               the headroom → 1 edge;
        //   XDNA  bf16: nothing fits (1 179 648 B > ~1.11 MB) → 0;
        //   XDNA2 bf16: attn_out→ffn_up only → 1.
        for (gen, p, want) in [
            (Generation::Xdna, Precision::I8I8, 1),
            (Generation::Xdna2, Precision::I8I8, 1),
            (Generation::Xdna, Precision::Bf16, 0),
            (Generation::Xdna2, Precision::Bf16, 1),
        ] {
            let chain = layer_chain(p);
            let plan = Planner::new(gen).plan(std::slice::from_ref(&chain));
            assert_eq!(plan.fused_edges(), want, "{gen}/{p}");
            // All four layer ops share one design: three dispatches ride
            // the first op's host submission.
            assert_eq!(plan.elided_dispatches(), 3, "{gen}/{p}");
        }
    }

    #[test]
    fn back_to_back_edges_fuse_only_when_residents_coexist_in_l2() {
        // Three chained 512x768x768 ops on XDNA2 int8: every padded C is
        // 663 552 B, so edge 2's window (op 1's resident A + its resident
        // C = 1 327 104 B) fits the ~2.04 MB headroom — both edges fuse.
        let mut small = GemmChain::new("small");
        small.push(GemmShape::new("a", 512, 768, 768, Precision::I8I8));
        for name in ["b", "c"] {
            small.push_chained(GemmShape::new(name, 512, 768, 768, Precision::I8I8)).unwrap();
        }
        let planner = Planner::new(Generation::Xdna2);
        assert_eq!(planner.plan(std::slice::from_ref(&small)).fused_edges(), 2);

        // The transformer layer's ffn_up edge is the counter-case: its C
        // alone fits, but not next to its resident A (see the headroom
        // test above) — so only the first MLP edge fuses, and the fused
        // op is ffn_up (dispatch index 2), not ffn_down.
        let chain = layer_chain(Precision::I8I8);
        let plan = planner.plan(std::slice::from_ref(&chain));
        let flags: Vec<(bool, bool)> = plan
            .dispatches
            .iter()
            .map(|d| (d.overrides.a_in_l2, d.overrides.c_stays_in_l2))
            .collect();
        assert_eq!(
            flags,
            vec![(false, false), (false, true), (true, false), (false, false)],
            "attn_out keeps C resident; ffn_up consumes it; ffn_down re-reads DRAM"
        );
    }

    #[test]
    fn chained_beats_isolated_on_both_generations() {
        let cfg = TransformerConfig { n_layers: 4, ..Default::default() };
        let chains = transformer_chains(&cfg);
        for gen in Generation::ALL {
            let planner = Planner::new(gen);
            let fused = evaluate(&planner.plan(&chains), BdMode::Overlapped);
            let isolated = evaluate(&planner.plan_isolated(&chains), BdMode::Overlapped);
            assert_eq!(fused.ops, isolated.ops);
            assert!(
                fused.t_total() < isolated.t_total(),
                "{gen}: fused {:.3} ms !< isolated {:.3} ms",
                fused.t_total() * 1e3,
                isolated.t_total() * 1e3
            );
            // The elisions show up phase by phase: fewer dispatch
            // seconds, no more DRAM bytes than the baseline, identical
            // compute-side steady work or less (fused reads shrink T_mem).
            assert!(fused.t_dispatch < isolated.t_dispatch);
            assert!(fused.dram_bytes <= isolated.dram_bytes);
            assert!(fused.t_steady <= isolated.t_steady + 1e-12);
            assert_eq!(fused.elided_dispatches, 4 * 3);
        }
    }

    #[test]
    fn grouping_pays_each_design_once() {
        // Interleaved int8 / bf16 layers: the isolated in-order schedule
        // reconfigures on every precision flip; the grouped plan pays
        // each design exactly once.
        let mut chains = Vec::new();
        for i in 0..3 {
            let mut c8 = layer_chain(Precision::I8I8);
            c8.name = format!("i8.{i}");
            let mut cb = layer_chain(Precision::Bf16);
            cb.name = format!("bf.{i}");
            chains.push(c8);
            chains.push(cb);
        }
        let planner = Planner::new(Generation::Xdna2);
        let grouped = evaluate(&planner.plan(&chains), BdMode::Overlapped);
        let isolated = evaluate(&planner.plan_isolated(&chains), BdMode::Overlapped);
        assert_eq!(grouped.reconfigurations, 2);
        assert_eq!(isolated.reconfigurations, 6);
        assert!(grouped.t_reconfig < isolated.t_reconfig);
        // Chain identity survives the reorder: same chains, new order.
        let grouped_plan = planner.plan(&chains);
        let mut names = grouped_plan.chain_names.clone();
        names.sort();
        assert_eq!(names, {
            let mut v: Vec<String> = chains.iter().map(|c| c.name.clone()).collect();
            v.sort();
            v
        });
        // Per-chain makespans cover the whole schedule.
        let sum: f64 = grouped.per_chain_s.iter().sum();
        assert!((sum - grouped.t_total()).abs() < 1e-9 * grouped.t_total().max(1.0));
    }

    #[test]
    fn plan_report_json_round_trips_the_totals() {
        let cfg = TransformerConfig { n_layers: 2, ..Default::default() };
        let chains = transformer_chains(&cfg);
        let rep = evaluate(&Planner::new(Generation::Xdna2).plan(&chains), BdMode::Overlapped);
        let j = crate::util::json::Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.get("dispatches").unwrap().as_usize(), Some(rep.dispatches));
        assert_eq!(j.get("fused_edges").unwrap().as_usize(), Some(rep.fused_edges));
        let t = j.get("t_total_s").unwrap().as_f64().unwrap();
        assert!((t - rep.t_total()).abs() < 1e-12 * rep.t_total());
        assert_eq!(
            j.get("per_chain_s").unwrap().as_arr().unwrap().len(),
            rep.per_chain_s.len()
        );
    }

    #[test]
    fn mid_chain_design_switch_breaks_fusion_and_amortization() {
        // int8 op feeding an int8→int16 op: structurally a valid edge,
        // but the designs differ, so nothing is elided.
        let mut chain = GemmChain::new("switch");
        chain.push(GemmShape::new("a", 512, 768, 768, Precision::I8I8));
        chain
            .push_chained(GemmShape::new("b", 512, 768, 768, Precision::I8I16))
            .unwrap();
        let plan = Planner::new(Generation::Xdna2).plan(std::slice::from_ref(&chain));
        assert_eq!(plan.fused_edges(), 0);
        assert_eq!(plan.elided_dispatches(), 0);
        let rep = evaluate(&plan, BdMode::Overlapped);
        assert_eq!(rep.reconfigurations, 2);
    }
}
