//! GEMM chains: producer→consumer edges over a workload trace.
//!
//! A chain is an ordered run of GEMMs where op *i+1* may consume op
//! *i*'s C as its A (`C_{i+1} = narrow(C_i @ B_{i+1})` — the QKV →
//! attention → MLP shape of transformer inference). The *structural*
//! eligibility of an edge is decided here ([`feeds`]); whether the edge
//! is actually *fused* (C kept L2-resident, DRAM round-trip elided) is
//! the planner's call in [`super::schedule`], because it depends on the
//! design's L2 headroom.

use crate::dtype::Precision;
use crate::workload::{GemmShape, TransformerConfig};

/// Can `prev`'s output dtype be consumed as `next`'s input dtype without
/// a host-side cast? int8 outputs feed any int8-input precision; bf16
/// feeds bf16; bfp16 blocks feed bfp16 (a C image's blocks run along N,
/// which is exactly the consumer's K). int8→int16/int32 outputs are
/// wider than any input dtype, and block/byte formats never mix.
pub fn out_feeds_in(prev: Precision, next: Precision) -> bool {
    match prev {
        Precision::I8I8 => {
            !matches!(next, Precision::Bf16 | Precision::Bfp16 | Precision::Fp32Split)
        }
        Precision::Bf16 => next == Precision::Bf16,
        Precision::Bfp16 => next == Precision::Bfp16,
        // An fp32_split C is an f32 image; a consuming fp32_split op
        // re-splits it into fresh bf16 limbs. No other precision reads
        // 4-byte float elements as its A.
        Precision::Fp32Split => next == Precision::Fp32Split,
        Precision::I8I16 | Precision::I8I32 => false,
    }
}

/// Structural producer→consumer eligibility: `next`'s A is exactly
/// `prev`'s C — same M, `next.K == prev.N`, and the dtypes line up.
/// (Elementwise ops between them — activation, layernorm — do not move
/// the operand and are transparent to the residency model.)
pub fn feeds(prev: &GemmShape, next: &GemmShape) -> bool {
    prev.m == next.m && prev.n == next.k && out_feeds_in(prev.precision, next.precision)
}

/// One GEMM inside a chain.
#[derive(Clone, Debug)]
pub struct ChainOp {
    pub shape: GemmShape,
    /// This op's A is the previous op's C (a [`feeds`]-eligible edge).
    /// Always `false` for the first op of a chain.
    pub consumes_prev: bool,
}

/// An ordered run of GEMMs with producer→consumer edges.
#[derive(Clone, Debug, Default)]
pub struct GemmChain {
    pub name: String,
    pub ops: Vec<ChainOp>,
}

impl GemmChain {
    pub fn new(name: &str) -> GemmChain {
        GemmChain { name: name.to_string(), ops: Vec::new() }
    }

    /// Append an op with no edge from its predecessor (fresh A from DRAM).
    pub fn push(&mut self, shape: GemmShape) {
        self.ops.push(ChainOp { shape, consumes_prev: false });
    }

    /// Append an op consuming the previous op's C as its A. Returns an
    /// error if the edge is not [`feeds`]-eligible (or there is no
    /// previous op).
    pub fn push_chained(&mut self, shape: GemmShape) -> anyhow::Result<()> {
        match self.ops.last() {
            Some(prev) if feeds(&prev.shape, &shape) => {
                self.ops.push(ChainOp { shape, consumes_prev: true });
                Ok(())
            }
            Some(prev) => anyhow::bail!(
                "'{}' ({}x{}x{} {}) cannot consume '{}' ({}x{}x{} {})",
                shape.name,
                shape.m,
                shape.k,
                shape.n,
                shape.precision,
                prev.shape.name,
                prev.shape.m,
                prev.shape.k,
                prev.shape.n,
                prev.shape.precision
            ),
            None => anyhow::bail!("'{}' has no predecessor to consume", shape.name),
        }
    }

    /// Build a chain from a shape sequence, auto-detecting every
    /// [`feeds`]-eligible edge (the `Vec<GemmShape>`-with-edges entry
    /// point: GGML-style traces come in as flat shape lists).
    pub fn detect(name: &str, shapes: &[GemmShape]) -> GemmChain {
        let mut chain = GemmChain::new(name);
        for shape in shapes {
            let edge = chain.ops.last().is_some_and(|prev| feeds(&prev.shape, shape));
            chain.ops.push(ChainOp { shape: shape.clone(), consumes_prev: edge });
        }
        chain
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total multiply-accumulate operations across the chain.
    pub fn total_ops(&self) -> f64 {
        self.ops.iter().map(|o| o.shape.ops()).sum()
    }

    /// Structurally eligible edges (an upper bound on what the planner
    /// can fuse).
    pub fn edges(&self) -> usize {
        self.ops.iter().filter(|o| o.consumes_prev).count()
    }
}

/// The transformer prefill trace as chains: one chain per decoder layer
/// (`qkv → attn_out → ffn_up → ffn_down`) plus the lm_head. Within a
/// layer, `attn_out → ffn_up` and `ffn_up → ffn_down` are
/// producer→consumer edges; `qkv → attn_out` is not (the attention
/// block computes between them), but the ops still share one design, so
/// the chain amortizes their dispatches.
pub fn transformer_chains(cfg: &TransformerConfig) -> Vec<GemmChain> {
    let trace = cfg.trace();
    let mut out = Vec::with_capacity(cfg.n_layers + 1);
    for layer in 0..cfg.n_layers {
        let chain = GemmChain::detect(&format!("layer{layer}"), &trace[4 * layer..4 * layer + 4]);
        out.push(chain);
    }
    out.push(GemmChain::detect("lm_head", &trace[4 * cfg.n_layers..]));
    out
}

/// The mixed-design chain workload used by `plan --mixed`, the `chain`
/// example and the `chain_vs_isolated` bench: `cfg`'s chains interleaved
/// layer by layer with a copy of the transformer at `other` precision,
/// so an isolated in-order schedule reconfigures on every flip while the
/// planner's design grouping pays each design once. One definition so
/// CLI, example and bench measure the same workload.
pub fn mixed_transformer_chains(
    cfg: &TransformerConfig,
    other: Precision,
) -> Vec<GemmChain> {
    let alt = TransformerConfig { precision: other, ..*cfg };
    let mut out = Vec::new();
    for (mut a, mut b) in transformer_chains(cfg).into_iter().zip(transformer_chains(&alt)) {
        a.name = format!("{}.{}", a.name, cfg.precision);
        b.name = format!("{}.{other}", b.name);
        out.push(a);
        out.push(b);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Layout;

    #[test]
    fn feeds_requires_matching_geometry_and_dtype() {
        let a = GemmShape::new("a", 64, 128, 256, Precision::I8I8);
        let good = GemmShape::new("b", 64, 256, 128, Precision::I8I8);
        assert!(feeds(&a, &good));
        // M mismatch.
        assert!(!feeds(&a, &GemmShape::new("b", 32, 256, 128, Precision::I8I8)));
        // K != prev N.
        assert!(!feeds(&a, &GemmShape::new("b", 64, 128, 128, Precision::I8I8)));
        // int8 C feeds wider-accumulating int8-input ops too.
        assert!(feeds(&a, &GemmShape::new("b", 64, 256, 128, Precision::I8I16)));
        // ...but a bf16 consumer cannot eat int8 bytes.
        assert!(!feeds(&a, &GemmShape::new("b", 64, 256, 128, Precision::Bf16)));
        // Wide int outputs feed nothing.
        let wide = GemmShape::new("w", 64, 128, 256, Precision::I8I16);
        assert!(!feeds(&wide, &good));
        // bf16 chains to bf16.
        let bf = GemmShape::new("f", 64, 128, 256, Precision::Bf16);
        assert!(feeds(&bf, &GemmShape::new("g", 64, 256, 64, Precision::Bf16)));
        // bfp16 blocks chain to bfp16 — and never mix with byte formats
        // (an int8 C image is not a block image and vice versa).
        let bfp = GemmShape::new("p", 64, 128, 256, Precision::Bfp16);
        assert!(feeds(&bfp, &GemmShape::new("q", 64, 256, 64, Precision::Bfp16)));
        assert!(!feeds(&bfp, &GemmShape::new("q", 64, 256, 64, Precision::Bf16)));
        assert!(!feeds(&a, &GemmShape::new("q", 64, 256, 64, Precision::Bfp16)));
        // fp32_split's f32 C feeds only another fp32_split op (which
        // re-splits it); no byte/block format mixes with it.
        let fs = GemmShape::new("s", 64, 128, 256, Precision::Fp32Split);
        assert!(feeds(&fs, &GemmShape::new("t", 64, 256, 64, Precision::Fp32Split)));
        assert!(!feeds(&fs, &GemmShape::new("t", 64, 256, 64, Precision::Bf16)));
        assert!(!feeds(&a, &GemmShape::new("t", 64, 256, 64, Precision::Fp32Split)));
        assert!(!feeds(&bf, &GemmShape::new("t", 64, 256, 64, Precision::Fp32Split)));
    }

    #[test]
    fn push_chained_validates_edges() {
        let mut c = GemmChain::new("t");
        assert!(c
            .push_chained(GemmShape::new("first", 8, 8, 8, Precision::I8I8))
            .is_err());
        c.push(GemmShape::new("first", 8, 8, 8, Precision::I8I8));
        assert!(c.push_chained(GemmShape::new("ok", 8, 8, 8, Precision::I8I8)).is_ok());
        assert!(c
            .push_chained(GemmShape::new("bad", 16, 8, 8, Precision::I8I8))
            .is_err());
        assert_eq!(c.len(), 2);
        assert_eq!(c.edges(), 1);
    }

    #[test]
    fn transformer_layer_edges_match_the_dataflow() {
        let cfg = TransformerConfig { n_layers: 2, ..Default::default() };
        let chains = transformer_chains(&cfg);
        assert_eq!(chains.len(), 3, "2 layer chains + lm_head");
        for chain in &chains[..2] {
            assert_eq!(chain.len(), 4);
            let edges: Vec<bool> = chain.ops.iter().map(|o| o.consumes_prev).collect();
            // qkv (no pred) | attn_out (attention in between: 3d != d) |
            // ffn_up ← attn_out | ffn_down ← ffn_up.
            assert_eq!(edges, vec![false, false, true, true]);
        }
        assert_eq!(chains[2].len(), 1);
        assert_eq!(chains[2].edges(), 0);
        let total: f64 = chains.iter().map(|c| c.total_ops()).sum();
        let trace_total: f64 = cfg.trace().iter().map(|g| g.ops()).sum();
        assert!((total - trace_total).abs() < 1e-6 * trace_total);
    }

    #[test]
    fn mixed_workload_interleaves_designs() {
        let cfg = TransformerConfig { n_layers: 2, ..Default::default() };
        let mixed = mixed_transformer_chains(&cfg, Precision::Bf16);
        assert_eq!(mixed.len(), 6, "(2 layers + lm_head) × 2 designs");
        let precs: Vec<Precision> =
            mixed.iter().map(|c| c.ops[0].shape.precision).collect();
        assert_eq!(
            precs,
            vec![
                Precision::I8I8,
                Precision::Bf16,
                Precision::I8I8,
                Precision::Bf16,
                Precision::I8I8,
                Precision::Bf16,
            ]
        );
        // Names disambiguate the two copies.
        assert_ne!(mixed[0].name, mixed[1].name);
    }

    #[test]
    fn detect_respects_layout_and_precision_runs() {
        // A mixed trace: edges only where geometry + dtype line up.
        let mut shapes = vec![
            GemmShape::new("a", 64, 128, 128, Precision::I8I8),
            GemmShape::new("b", 64, 128, 128, Precision::I8I8),
            GemmShape::new("c", 64, 128, 128, Precision::Bf16),
        ];
        shapes[1].b_layout = Layout::RowMajor; // layout doesn't break the edge
        let c = GemmChain::detect("mix", &shapes);
        assert_eq!(
            c.ops.iter().map(|o| o.consumes_prev).collect::<Vec<_>>(),
            vec![false, true, false]
        );
    }
}
