//! Chain planner: schedule whole GEMM *chains* instead of independent
//! dispatches (DESIGN.md §8, docs/workloads.md).
//!
//! The paper's end-to-end numbers are isolated GEMM dispatches, but the
//! DL workloads that motivate them are chains — QKV → attention → MLP —
//! where op *i+1* consumes op *i*'s C and reconfiguration/dispatch
//! overhead dominates small-M inference shapes. This module compiles a
//! [`crate::workload::TransformerConfig`] (or any shape list with
//! producer→consumer edges) into chains, plans a dispatch schedule, and
//! accounts the three chain-level savings: fused edges (C kept
//! L2-resident, the DRAM round-trip elided), dispatch amortization
//! (same-design ops ride one host submission), and design grouping
//! (each array reconfiguration paid once per design, not per
//! interleaving).
//!
//! * [`chain`]    — chains, producer→consumer edge eligibility, and the
//!   transformer-layer chain builder.
//! * [`schedule`] — the planner, the L2-headroom fusion rule, and the
//!   phase-accounted fused-vs-isolated evaluation.
//!
//! The coordinator consumes the same fusion rule for whole-chain
//! routing (`Coordinator::submit_chain`): a chain lands on one device's
//! leader, its design stays cache-hot, and the leader applies
//! [`schedule::overrides_for`] against its own design cache.

pub mod chain;
pub mod schedule;

pub use chain::{
    feeds, mixed_transformer_chains, out_feeds_in, transformer_chains, ChainOp, GemmChain,
};
pub use schedule::{
    evaluate, l2_headroom, overrides_for, resident_c_bytes, ChainPlan, PlanReport,
    PlannedDispatch, Planner,
};
