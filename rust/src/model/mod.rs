//! The paper's analytical model, Eqs. 1–10 (Sec. 4.5), verbatim.
//!
//! These closed forms drive the optimizer; the calibrated simulator
//! (`crate::sim`) supplies the empirical quantities the paper measures on
//! hardware (`eff` via the cycle model, `DRAM_BW` via the bandwidth
//! model). Cross-checks against the simulator live in the tests.

use crate::arch::NpuSpec;
use crate::dtype::Precision;
use crate::tiling::{KernelTile, TilingConfig};

/// Eq. 1 — single-core GEMM compute cycles:
/// `C_comp = m_ct·k_ct·n_ct / (eff · peak_MACs)`.
pub fn c_comp(t: &KernelTile, eff: f64, peak_macs: f64) -> f64 {
    t.macs() as f64 / (eff * peak_macs)
}

/// Eq. 2 — DMA cycles for the A tile:
/// `CA_comm = m_ct·k_ct·ty(A) / DMA_BW`.
pub fn ca_comm(t: &KernelTile, p: Precision, dma_bw: f64) -> f64 {
    (t.m_ct * t.k_ct) as f64 * p.in_bytes_f() / dma_bw
}

/// Eq. 3 — DMA cycles for the B tile:
/// `CB_comm = k_ct·n_ct·ty(B) / DMA_BW`.
pub fn cb_comm(t: &KernelTile, p: Precision, dma_bw: f64) -> f64 {
    (t.k_ct * t.n_ct) as f64 * p.in_bytes_f() / dma_bw
}

/// Eq. 4 — compute-bound constraint:
/// `C_comp >= max(CA_comm, CB_comm)` (double-buffered inputs must arrive
/// no slower than the kernel consumes them).
pub fn compute_bound(t: &KernelTile, p: Precision, eff: f64, peak_macs: f64, dma_bw: f64) -> bool {
    let c = c_comp(t, eff, peak_macs);
    c >= ca_comm(t, p, dma_bw) && c >= cb_comm(t, p, dma_bw)
}

/// Eq. 5 — L1 capacity: `2·A + 2·B + C <= 63 KB`
/// (delegates to [`KernelTile::l1_bytes`]).
pub fn l1_fits(t: &KernelTile, p: Precision, spec: &NpuSpec, c_double_buffered: bool) -> bool {
    t.l1_bytes(p, c_double_buffered) <= spec.l1_budget()
}

/// Eq. 6 — DRAM reads for A (bytes):
/// `A_mem = M·K·N·ty(A) / (n_ct·n_cols)`.
pub fn a_mem(cfg: &TilingConfig, m: usize, k: usize, n: usize) -> f64 {
    (m as f64 * k as f64 * n as f64) * cfg.precision.in_bytes_f()
        / (cfg.kernel.n_ct * cfg.n_cols) as f64
}

/// Eq. 6, unsimplified form (used by tests to prove the algebra):
/// `(m_ct·m_rows·K·ty) · (N/(n_ct·n_cols)) · (M/(m_ct·m_rows))`.
pub fn a_mem_unsimplified(cfg: &TilingConfig, m: usize, k: usize, n: usize) -> f64 {
    let t = &cfg.kernel;
    (t.m_ct * cfg.m_rows) as f64
        * k as f64
        * cfg.precision.in_bytes_f()
        * (n as f64 / (t.n_ct * cfg.n_cols) as f64)
        * (m as f64 / (t.m_ct * cfg.m_rows) as f64)
}

/// Eq. 7 — DRAM reads for B (bytes):
/// `B_mem = M·K·N·ty(B) / (m_ct·m_rows)`.
pub fn b_mem(cfg: &TilingConfig, m: usize, k: usize, n: usize) -> f64 {
    (m as f64 * k as f64 * n as f64) * cfg.precision.in_bytes_f()
        / (cfg.kernel.m_ct * cfg.m_rows) as f64
}

/// Eq. 8 — DRAM writes for C (bytes): `C_mem = M·N·ty(C)`.
pub fn c_mem(cfg: &TilingConfig, m: usize, n: usize) -> f64 {
    m as f64 * n as f64 * cfg.precision.out_bytes_f()
}

/// Eq. 9 — GEMM compute time on the array:
/// `T_comp = 2·M·K·N / (eff · peak_TOPS)` (seconds; peak_TOPS in ops/s).
pub fn t_comp(m: usize, k: usize, n: usize, eff: f64, peak_tops: f64) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64 / (eff * peak_tops * 1e12)
}

/// Eq. 10 — DRAM access time:
/// `T_mem = (A_mem + B_mem + C_mem) / DRAM_BW` (DRAM_BW in B/s).
pub fn t_mem(cfg: &TilingConfig, m: usize, k: usize, n: usize, dram_bw: f64) -> f64 {
    (a_mem(cfg, m, k, n) + b_mem(cfg, m, k, n) + c_mem(cfg, m, n)) / dram_bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{balanced_config, Generation};
    use crate::sim::{simulate_gemm, BdMode};

    #[test]
    fn eq6_simplification_is_exact() {
        let cfg = balanced_config(Generation::Xdna2, Precision::I8I16);
        let (m, k, n) = (4096, 4320, 4480);
        let full = a_mem_unsimplified(&cfg, m, k, n);
        let simple = a_mem(&cfg, m, k, n);
        assert!((full - simple).abs() / simple < 1e-12);
    }

    #[test]
    fn traffic_matches_simulator() {
        // The engine's Eq. 6-8 implementation must agree with this module.
        for gen in Generation::ALL {
            for p in Precision::ALL {
                let cfg = balanced_config(gen, p);
                let (m, k, n) = {
                    let (nm, nk, nn) = cfg.native();
                    (4 * nm, 4 * nk, 4 * nn)
                };
                let r = simulate_gemm(&cfg, m, k, n, BdMode::Overlapped);
                assert!((r.a_bytes - a_mem(&cfg, m, k, n)).abs() < 1.0);
                assert!((r.b_bytes - b_mem(&cfg, m, k, n)).abs() < 1.0);
                assert!((r.c_bytes - c_mem(&cfg, m, n)).abs() < 1.0);
            }
        }
    }

    #[test]
    fn inverse_relationship_between_compute_and_memory() {
        // The paper's core observation (Sec. 4.5.2): shrinking m_ct/n_ct
        // raises efficiency (lower T_comp) but raises DRAM traffic
        // (higher T_mem).
        let gen = Generation::Xdna2;
        let p = Precision::I8I16;
        let small = balanced_config(gen, p); // 128x72x112
        let tiny_kernel = crate::tiling::TilingConfig::new(
            gen, p, 64, 216, 64, 432, 4, 8, crate::dtype::Layout::ColMajor,
        )
        .unwrap(); // Table 1's compute-optimal kernel
        let (m, k, n) = (4608, 4320, 4480);

        let eff_small = crate::sim::engine::simulate_gemm(&small, m, k, n, BdMode::Overlapped);
        let eff_tiny = crate::sim::engine::simulate_gemm(&tiny_kernel, m, k, n, BdMode::Overlapped);
        // Tiny kernel: higher single-core efficiency...
        assert!(eff_tiny.efficiency > eff_small.efficiency);
        // ...but more DRAM traffic...
        assert!(
            a_mem(&tiny_kernel, m, k, n) + b_mem(&tiny_kernel, m, k, n)
                > a_mem(&small, m, k, n) + b_mem(&small, m, k, n)
        );
        // ...so the balanced kernel wins end to end (Sec. 5.2.1: 17.86
        // vs 30.77 TOPS).
        assert!(eff_small.tops > eff_tiny.tops * 1.3);
    }

    #[test]
    fn eq4_holds_for_published_balanced_kernels() {
        // Every bold kernel of Tables 2-3 satisfies the compute-bound
        // constraint with the architecture's DMA bandwidth.
        for gen in Generation::ALL {
            for p in Precision::ALL {
                let cfg = balanced_config(gen, p);
                let spec = gen.spec();
                let eff = crate::sim::engine::simulate_gemm(
                    &cfg,
                    cfg.native().0,
                    cfg.native().1,
                    cfg.native().2,
                    BdMode::Overlapped,
                )
                .efficiency;
                let dma_bw_cycles = spec.dma_bytes_per_cycle;
                assert!(
                    compute_bound(
                        &cfg.kernel,
                        p,
                        eff,
                        spec.peak_macs_per_cycle(p),
                        dma_bw_cycles
                    ) || p == Precision::Bf16,
                    "{gen}/{p} violates Eq. 4"
                );
            }
        }
    }

    #[test]
    fn t_comp_matches_table_peak_column() {
        // Eq. 9 with the model's eff reproduces "Peak Comp. TOPS":
        // XDNA2 int8-int8 144x72x144 → 39.52 TOPS at eff·peak.
        let cfg = balanced_config(Generation::Xdna2, Precision::I8I8);
        let eff = crate::sim::core::efficiency(cfg.gen, cfg.precision, &cfg.kernel);
        let peak = cfg.gen.spec().peak_tops(cfg.precision);
        let eff_tops = eff * peak;
        assert!((eff_tops - 39.52).abs() < 0.5, "{eff_tops}");
        // And T_comp for the paper's size is ops / (eff·peak).
        let t = t_comp(4032, 4320, 4608, eff, peak);
        assert!((t - 4.06e-3).abs() < 0.1e-3, "{t}");
    }
}
