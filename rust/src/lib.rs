//! # xdna-gemm
//!
//! A full-system reproduction of *"Striking the Balance: GEMM Performance
//! Optimization Across Generations of Ryzen™ AI NPUs"* (Taka et al., 2025)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate contains everything the paper's methodology needs, built from
//! scratch (see `DESIGN.md` for the substitution map — no NPU silicon is
//! required):
//!
//! * [`arch`] — XDNA / XDNA2 architecture descriptions (array geometry,
//!   peaks, clocks, DMA channel/BD budgets).
//! * [`dtype`] — precision pairs (int8→int8/int16/int32, bf16) and a
//!   software `bf16` with round-to-nearest-even.
//! * [`tiling`] — the paper's four-level tiling scheme and capacity rules.
//! * [`dma`] / [`xform`] — buffer descriptors with 3D/4D address generation
//!   and the Fig.-4 on-the-fly layout-transformation pipeline.
//! * [`mem`] — DRAM matrix images and L1/L2 allocators.
//! * [`sim`] — the calibrated performance simulator (single-core cycle
//!   model, effective-DRAM-bandwidth model, command-processor BD queues,
//!   whole-GEMM engine, trace unit).
//! * [`model`] — the analytical equations (Eqs. 1–10) verbatim.
//! * [`optimizer`] — the single-core integer program (Sec. 4.5.1) and the
//!   system-level balanced-point search (Sec. 4.5.2).
//! * [`gemm`] — bit-accurate reference GEMM and the functional tiled
//!   executor that moves real bytes through the simulated hierarchy.
//! * [`plan`] — chain planner: fuse producer→consumer GEMM chains with
//!   L2-resident reuse, amortized dispatch and design grouping.
//! * [`graph`] — graph compiler: whole-model DAG IR with fan-out/fan-in,
//!   lowering to maximal linear chains, mixed-precision assignment, and
//!   critical-path-aware fleet partitioning (`docs/graphs.md`).
//! * [`runtime`] — PJRT client; loads the AOT Pallas/JAX artifacts
//!   (`artifacts/*.hlo.txt`) and executes them from the request path.
//! * [`coordinator`] — sharded GEMM-as-a-service: admission queue,
//!   design-affinity fleet router, per-device leader threads with
//!   batching and backpressure, fleet metrics (`docs/serving.md`).
//! * [`trace`] — virtual-time flight recorder: deterministic span
//!   tracing, Chrome/Perfetto trace export, Prometheus-text metrics,
//!   per-dispatch roofline attribution (`docs/observability.md`).
//! * [`workload`] — DL GEMM traces (transformer / MLP / sweeps).
//! * [`report`] — table and CSV emitters used by the bench harness.
//! * [`util`] — offline stand-ins for clap/criterion/proptest/serde_json.

pub mod arch;
pub mod coordinator;
pub mod dma;
pub mod harness;
pub mod dtype;
pub mod dtype_bfp16;
pub mod dtype_split;
pub mod gemm;
pub mod graph;
pub mod mem;
pub mod model;
pub mod optimizer;
pub mod plan;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod tiling;
pub mod trace;
pub mod util;
pub mod workload;
pub mod xform;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
