//! Algorithm-based fault tolerance (ABFT) for GEMM results — the PR-8
//! integrity layer (DESIGN.md §14).
//!
//! Two complementary checks, both cheap next to the GEMM itself:
//!
//! * **Capture checksums** ([`capture`] / [`validate`]): per-storage-row
//!   and per-word-column wrapping u64 sums of the C image's raw 32-bit
//!   words, taken the moment the executor hands the image over (the
//!   "pack step" pass — the panels are already resident). Re-validation
//!   is an *exact integer* compare for every precision, bf16/bfp16
//!   included, so a single corrupted word always changes its row sum
//!   and its column sum: detection of any logically visible flip is
//!   guaranteed and false positives are impossible. This is what the
//!   coordinator re-checks on every staged edge before a producer's C
//!   becomes a consumer's A.
//! * **Operand grand-total invariant** ([`operand_invariant`]): the
//!   Huang–Abraham identity `(eᵀA)·(Be) = eᵀCe` — the column-sum row of
//!   A dotted with the row-sum column of B must equal the grand total
//!   of C. Exact in i64 for i8i32; bounded by a derived ULP-style
//!   tolerance for bf16 (RNE half-ulp `2⁻⁹` per element) and bfp16
//!   (block re-quantization, `2⁻⁴` worst case — blocks quantize to
//!   their max). i8i8/i8i16 return `None`: their saturating narrowing
//!   breaks linearity, so the exact capture sums carry detection alone
//!   there (the Python model shows the adversarial counterexample).
//!
//! The tolerance constants, the corruption-site arithmetic and the
//! checksum cost model are transliterated and pinned in
//! `python/tests/test_integrity_model.py`; keep them in lock-step.

use crate::dtype::Precision;
use crate::dtype_bfp16::BLOCK_WORDS;
use crate::mem::Matrix;

use super::refimpl::{logical_dims, packed_f32_bfp};

/// Tolerance model for [`operand_invariant`]:
/// `tol = SAFETY · abs_total · (rel + k·2⁻²⁴ + (m+n+k)·2⁻⁵²)` where
/// `rel` is the per-element narrowing error (bf16 RNE half-ulp, bfp16
/// block re-quantization), `k·2⁻²⁴` the f32 accumulation and the last
/// term the f64 checksum arithmetic itself. Mirrored in
/// `test_integrity_model.py` (margin shown < 0.5 over the shape grid).
const TOL_SAFETY: f64 = 2.0;

fn rel_term(p: Precision) -> Option<f64> {
    match p {
        Precision::Bf16 => Some(1.0 / 512.0),  // 2^-9
        Precision::Bfp16 => Some(1.0 / 16.0),  // 2^-4 = 8 · (0.5/64)
        // Ozaki-split C is f32 with ~4·u² = 2^-16 relative residual
        // (dropped lo·lo + split rounding, DESIGN.md §15) — far inside
        // the bf16 tolerance, but not exact.
        Precision::Fp32Split => Some(1.0 / 65536.0),
        Precision::I8I32 => Some(0.0),         // exact — checked in i64
        Precision::I8I8 | Precision::I8I16 => None, // saturation: nonlinear
    }
}

/// Derived tolerance bound for the grand-total invariant at one shape.
/// `None` for precisions whose narrowed C has no linear invariant.
pub fn tolerance(p: Precision, m: usize, k: usize, n: usize, abs_total: f64) -> Option<f64> {
    let rel = rel_term(p)?;
    let acc = k as f64 * (1.0f64 / (1u64 << 24) as f64);
    let f64_err = (m + n + k) as f64 * (1.0f64 / (1u64 << 52) as f64);
    Some(TOL_SAFETY * abs_total * (rel + acc + f64_err))
}

/// Row/column checksum vectors over a C image's raw words.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct AbftChecksums {
    /// Wrapping u64 sum of each storage row's 32-bit words.
    pub rows: Vec<u64>,
    /// Wrapping u64 sum of each word column across storage rows.
    pub cols: Vec<u64>,
}

/// Capture the checksum vectors of a result image (one pass over the
/// already-resident words — the "extra pass over packed panels" of the
/// pack step). Precision-agnostic: bit patterns, not values, so the
/// compare in [`validate`] is exact for every dtype.
pub fn capture(c: &Matrix) -> AbftChecksums {
    let rw = c.row_words();
    let nr = c.n_storage_rows();
    let mut rows = vec![0u64; nr];
    let mut cols = vec![0u64; rw];
    for sr in 0..nr {
        for wc in 0..rw {
            let w = c.data[sr * rw + wc] as u64;
            rows[sr] = rows[sr].wrapping_add(w);
            cols[wc] = cols[wc].wrapping_add(w);
        }
    }
    AbftChecksums { rows, cols }
}

/// Exact re-validation of an image against captured checksums. A single
/// corrupted word changes its row and column sums by a nonzero delta
/// (terms are < 2³², sums wrap in u64), so this never misses a flip and
/// never fires on a clean image.
pub fn validate(c: &Matrix, sums: &AbftChecksums) -> bool {
    capture(c) == *sums
}

/// The Huang–Abraham grand-total invariant: checksum row of A times
/// checksum column of B vs the total of C. `Some(ok)` where the
/// narrowed C is linear enough to check (i8i32 exactly, bf16/bfp16
/// within [`tolerance`]); `None` for the saturating narrowings.
pub fn operand_invariant(a: &Matrix, b: &Matrix, c: &Matrix, p: Precision) -> Option<bool> {
    rel_term(p)?;
    let (m, k) = logical_dims(a);
    let (_, n) = logical_dims(b);
    match p {
        Precision::I8I32 => {
            let col_a = int_sums(a);
            let row_b = int_sums_cols(b);
            let want: i64 = col_a.iter().zip(&row_b).map(|(x, y)| x * y).sum();
            let mut got = 0i64;
            for i in 0..c.rows {
                for j in 0..c.cols {
                    got += c.get_i32(i, j) as i64;
                }
            }
            Some(got == want)
        }
        Precision::Bf16 | Precision::Bfp16 | Precision::Fp32Split => {
            let av = dense_f32(a);
            let bv = dense_f32(b);
            let cv = dense_f32(c);
            let mut want = 0.0f64;
            let mut abs_total = 0.0f64;
            for kk in 0..k {
                let mut ca = 0.0f64;
                let mut ca_abs = 0.0f64;
                for i in 0..m {
                    let v = av[i * k + kk] as f64;
                    ca += v;
                    ca_abs += v.abs();
                }
                let mut rb = 0.0f64;
                let mut rb_abs = 0.0f64;
                for j in 0..n {
                    let v = bv[kk * n + j] as f64;
                    rb += v;
                    rb_abs += v.abs();
                }
                want += ca * rb;
                abs_total += ca_abs * rb_abs;
            }
            let got: f64 = cv.iter().map(|&v| v as f64).sum();
            let tol = tolerance(p, m, k, n, abs_total)?;
            Some((got - want).abs() <= tol)
        }
        Precision::I8I8 | Precision::I8I16 => None,
    }
}

/// Dense logical-row-major f32 view of a float operand (bf16 element
/// grid, decoded bfp16 block image, or fp32_split's dense f32 image).
fn dense_f32(m: &Matrix) -> Vec<f32> {
    if m.is_bfp16() {
        return packed_f32_bfp(m);
    }
    if m.elem_bytes == 4 {
        let mut out = vec![0f32; m.rows * m.cols];
        for i in 0..m.rows {
            for j in 0..m.cols {
                out[i * m.cols + j] = m.get_f32(i, j);
            }
        }
        return out;
    }
    m.packed_f32()
}

/// Column sums of a logical int8 image (`eᵀA`).
fn int_sums(a: &Matrix) -> Vec<i64> {
    let (m, k) = logical_dims(a);
    let av = a.packed_i8();
    let mut col = vec![0i64; k];
    for i in 0..m {
        for (kk, c) in col.iter_mut().enumerate() {
            *c += av[i * k + kk] as i64;
        }
    }
    col
}

/// Row sums of a logical int8 image (`Be`).
fn int_sums_cols(b: &Matrix) -> Vec<i64> {
    let (k, n) = logical_dims(b);
    let bv = b.packed_i8();
    let mut row = vec![0i64; k];
    for (kk, r) in row.iter_mut().enumerate() {
        for j in 0..n {
            *r += bv[kk * n + j] as i64;
        }
    }
    row
}

/// Flip bits in one word of a result image — the executor-side effect
/// of [`crate::coordinator::FaultKind::CorruptResult`]. The site is
/// `word % data.len()`; on bfp16 images a flip landing on a block
/// cell's third word is masked to its live byte (mantissa\[7\] — bytes
/// 1–3 are dead padding the codec ignores), and an all-dead mask
/// degrades to bit 0, so every injected corruption is logically
/// visible. Returns the resolved `(word_index, applied_mask)` — same
/// arithmetic as `test_integrity_model.py`'s site pins.
pub fn corrupt_word(c: &mut Matrix, word: u64, xor_mask: u32) -> (usize, u32) {
    let len = c.data.len();
    debug_assert!(len > 0, "cannot corrupt an empty image");
    let idx = (word % len as u64) as usize;
    let mut mask = xor_mask;
    if c.is_bfp16() && idx % BLOCK_WORDS == 2 {
        mask &= 0xFF;
    }
    if mask == 0 {
        mask = 1;
    }
    c.data[idx] ^= mask;
    (idx, mask)
}

/// Multiply-accumulate count of the full ABFT pass at one shape:
/// `m·k + k·n` operand sums (the pack-step pass), `2·m·n` capture +
/// re-validate walks over C, `2·k` for the checksum dot product. The
/// sim model charges these at the device's MAC rate
/// ([`crate::sim::abft_check_seconds`]) so reported TOPS stays honest.
pub fn checksum_ops(m: usize, k: usize, n: usize) -> f64 {
    (m * k + k * n + 2 * m * n + 2 * k) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Layout;
    use crate::gemm::refimpl;

    fn filled(rows: usize, cols: usize, p: Precision, layout: Layout, seed: u64) -> Matrix {
        let mut m = refimpl::input_matrix(rows, cols, p, layout).unwrap();
        refimpl::fill_random(&mut m, p, seed);
        m
    }

    #[test]
    fn capture_sums_match_python_pin() {
        // 2x4 row-major int8 [[1,-2,3,-4],[5,6,-7,8]] — the literal
        // pinned in test_integrity_model.py::test_capture_sums_pin.
        let mut c = Matrix::zeroed(2, 4, 1, Layout::RowMajor).unwrap();
        for (j, v) in [1i8, -2, 3, -4].into_iter().enumerate() {
            c.set_i8(0, j, v);
        }
        for (j, v) in [5i8, 6, -7, 8].into_iter().enumerate() {
            c.set_i8(1, j, v);
        }
        let s = capture(&c);
        assert_eq!(s.rows, vec![4228120065, 150537733]);
        assert_eq!(s.cols, vec![4378657798]);
        assert!(validate(&c, &s));
    }

    #[test]
    fn every_single_word_flip_is_detected() {
        for p in [Precision::I8I8, Precision::Bf16, Precision::Bfp16] {
            let c0 = filled(16, 16, p, Layout::RowMajor, 5);
            let sums = capture(&c0);
            for word in [0u64, 7, 63, 0x5FBC_AB0D_DD73_D4AC] {
                let mut c = c0.clone();
                let (idx, mask) = corrupt_word(&mut c, word, 0x1EDA_FEBC);
                assert!(mask != 0 && idx < c.data.len());
                assert!(!validate(&c, &sums), "{p}: flip at word {idx} missed");
                c.data[idx] ^= mask; // undo → exact match again
                assert!(validate(&c, &sums));
            }
        }
    }

    #[test]
    fn bfp16_pad_words_are_masked_to_the_live_byte() {
        // 64x64 bfp16 C = 64x8 block cells = 1536 words; the seed-2
        // dev-0 word lands on a pad word (1196 % 3 == 2) and the mask
        // degrades to its live byte — pinned in test_integrity_model.py.
        let mut c = Matrix::zeroed_bfp16(64, 64, Layout::RowMajor).unwrap();
        assert_eq!(c.data.len(), 1536);
        let (idx, mask) = corrupt_word(&mut c, 6898576805263037612, 0x1EDA_FEBC);
        assert_eq!((idx, mask), (1196, 0xBC));
        // All-dead mask on a pad word degrades to bit 0 of mantissa[7].
        let mut c2 = Matrix::zeroed_bfp16(64, 64, Layout::RowMajor).unwrap();
        let (idx2, mask2) = corrupt_word(&mut c2, 5, 0x1EDA_FE00);
        assert_eq!((idx2, mask2), (5, 1));
        // Either way the flip stays visible to the block codec: the
        // mutated word is a live mantissa byte, not dead padding.
        let blk = c2.get_bfp_block(0, 1);
        assert_ne!(blk.mantissas[7], 0);
    }

    #[test]
    fn i8i32_grand_total_invariant_is_exact() {
        for (m, k, n) in [(8, 16, 8), (52, 100, 36), (17, 33, 9)] {
            let a = filled(m, k, Precision::I8I32, Layout::RowMajor, 1);
            let b = filled(k, n, Precision::I8I32, Layout::ColMajor, 2);
            let c = refimpl::ref_gemm(&a, &b, Precision::I8I32).unwrap();
            assert_eq!(operand_invariant(&a, &b, &c, Precision::I8I32), Some(true));
            // A corrupted C (bit 30 of an i32 cell — far above any
            // legitimate accumulation here) must break the identity.
            let mut bad = c.clone();
            corrupt_word(&mut bad, 3, 1 << 30);
            assert_eq!(operand_invariant(&a, &b, &bad, Precision::I8I32), Some(false));
        }
    }

    #[test]
    fn float_invariants_pass_clean_and_saturating_kinds_opt_out() {
        for (p, layout) in [(Precision::Bf16, Layout::ColMajor), (Precision::Bfp16, Layout::ColMajor)]
        {
            let (m, k, n) = (24, 56, 40);
            let a = filled(m, k, p, Layout::RowMajor, 3);
            let b = filled(k, n, p, layout, 4);
            let c = refimpl::ref_gemm(&a, &b, p).unwrap();
            assert_eq!(operand_invariant(&a, &b, &c, p), Some(true), "{p} clean run");
        }
        let a = filled(8, 16, Precision::I8I8, Layout::RowMajor, 5);
        let b = filled(16, 8, Precision::I8I8, Layout::ColMajor, 6);
        let c = refimpl::ref_gemm(&a, &b, Precision::I8I8).unwrap();
        assert_eq!(operand_invariant(&a, &b, &c, Precision::I8I8), None);
        assert_eq!(operand_invariant(&a, &b, &c, Precision::I8I16), None);
    }

    #[test]
    fn checksum_ops_is_negligible_next_to_the_gemm() {
        assert_eq!(checksum_ops(1024, 1024, 1024), 4196352.0);
        let ratio = checksum_ops(1024, 1024, 1024) / (2.0 * 1024f64 * 1024.0 * 1024.0);
        assert!(ratio < 0.002, "{ratio}");
    }
}
