//! Functional GEMM: bit-accurate numerics through the simulated hierarchy.
//!
//! * [`refimpl`] — the Rust reference implementation (the mirror of
//!   `python/compile/kernels/ref.py`, cross-checked by golden vectors).
//! * [`exec`]   — the tiled executor: real bytes flow DRAM → L2 → L1
//!   through the BD transform chains of [`crate::xform`], per-core
//!   micro-kernels consume pre-tiled tiles, and C drains back through the
//!   MemTile aggregation path. Proves the paper's mapping end to end.

pub mod abft;
pub mod exec;
pub mod refimpl;
