//! The functional tiled executor: the paper's whole-array GEMM mapping
//! (Sec. 4.2) run with real bytes on the simulated hierarchy.
//!
//! Per output native tile (Fig. 3):
//! 1. each array row's `m_ct × K` A panel and each column's B panel are
//!    streamed DRAM → L2 → L1 through the BD transform chains of
//!    [`crate::xform`] (the Fig.-4 pipeline), arriving *pre-tiled*;
//! 2. every core runs the output-stationary micro-kernel over `K/k_ct`
//!    pre-tiled tile pairs (the zeroing step is the accumulator init);
//! 3. the narrowed C tile is produced in pre-tiled `r × t` layout and
//!    drained through the MemTile aggregation + 4D de-tiling path back to
//!    row-major DRAM (Sec. 4.2.2).
//!
//! Two fidelity levels produce *identical* bytes (property-tested):
//! `BdChain` drives every hop through real BD gathers/scatters;
//! `Direct` uses the algebraic pre-tiling oracle (faster; the default for
//! examples and the coordinator's functional mode).

use anyhow::{ensure, Result};

use crate::dtype::{Bf16, Layout, Precision};
use crate::mem::Matrix;
use crate::tiling::TilingConfig;
use crate::xform::{pretile_oracle, BRowMajorChain, InputChain, OutputChain};



/// How faithfully to move the bytes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Fidelity {
    /// Full BD-chain streaming (every hop of Fig. 4).
    BdChain,
    /// Algebraic pre-tiling (same layout, no per-hop simulation).
    Direct,
}

pub struct Executor {
    pub cfg: TilingConfig,
    pub fidelity: Fidelity,
}

impl Executor {
    pub fn new(cfg: TilingConfig, fidelity: Fidelity) -> Executor {
        Executor { cfg, fidelity }
    }

    fn a_chain(&self) -> InputChain {
        let (r, s, _) = self.cfg.precision.micro_tile();
        InputChain {
            rows: self.cfg.kernel.m_ct,
            micro_r: r,
            micro_s: s,
            k_ct: self.cfg.kernel.k_ct,
            k_mt: self.cfg.k_mt,
            elem_bytes: self.cfg.precision.ty_in(),
        }
    }

    fn bt_chain(&self) -> InputChain {
        let (_, s, t) = self.cfg.precision.micro_tile();
        InputChain {
            rows: self.cfg.kernel.n_ct,
            micro_r: t,
            micro_s: s,
            k_ct: self.cfg.kernel.k_ct,
            k_mt: self.cfg.k_mt,
            elem_bytes: self.cfg.precision.ty_in(),
        }
    }

    fn brm_chain(&self) -> BRowMajorChain {
        let (_, s, t) = self.cfg.precision.micro_tile();
        BRowMajorChain {
            k_ct: self.cfg.kernel.k_ct,
            n_ct: self.cfg.kernel.n_ct,
            micro_s: s,
            micro_t: t,
            elem_bytes: self.cfg.precision.ty_in(),
        }
    }

    fn out_chain(&self) -> OutputChain {
        let (r, _, t) = self.cfg.precision.micro_tile();
        OutputChain {
            m_ct: self.cfg.kernel.m_ct,
            n_ct: self.cfg.kernel.n_ct,
            micro_r: r,
            micro_t: t,
            elem_bytes: self.cfg.precision.ty_out(),
        }
    }

    /// Stream one input panel into per-`k_ct`-tile pre-tiled L1 images.
    fn stream_input(&self, chain: &InputChain, img: &Matrix, row0: usize, pk: usize) -> Result<Vec<Vec<u32>>> {
        match self.fidelity {
            Fidelity::BdChain => chain.stream_panel(&img.data, row0, img.row_words(), pk),
            Fidelity::Direct => {
                let k_ct_w = chain.k_ct * chain.elem_bytes / 4;
                Ok((0..pk / chain.k_ct)
                    .map(|ti| pretile_oracle(&img.data, img.row_words(), row0, ti * k_ct_w, chain))
                    .collect())
            }
        }
    }

    fn stream_b_rowmajor(&self, img: &Matrix, col0_w: usize, pk: usize) -> Result<Vec<Vec<u32>>> {
        let c = self.brm_chain();
        match self.fidelity {
            Fidelity::BdChain => c.stream_panel(&img.data, col0_w, img.row_words(), pk),
            Fidelity::Direct => Ok((0..pk / c.k_ct)
                .map(|ti| c.pretile_oracle(&img.data, img.row_words(), ti * c.k_ct, col0_w))
                .collect()),
        }
    }

    /// Execute `C = narrow(A @ B)` through the full mapping.
    ///
    /// `a`: `m × k` row-major; `b`: `k × n`, layout per `cfg.b_layout`.
    /// Returns the `m × n` row-major result (padding stripped).
    pub fn execute(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let p = self.cfg.precision;
        ensure!(a.layout == Layout::RowMajor, "A must be row-major");
        ensure!(b.layout == self.cfg.b_layout, "B layout must match the design");
        ensure!(a.cols == b.rows, "shape mismatch");
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let (pm, pk, pn) = self.cfg.padded(m, k, n);

        // Zero-pad into fresh DRAM images (the paper's Sec. 5.3.1 notes
        // the NPU can zero-pad on the fly in MemTile channels; host-side
        // padding exercises the same aligned code path).
        let pa = pad_matrix(a, pm, pk)?;
        let pb = match self.cfg.b_layout {
            Layout::RowMajor => pad_matrix(b, pk, pn)?,
            Layout::ColMajor => pad_matrix(b, pk, pn)?,
        };
        let mut pc = Matrix::zeroed(pm, pn, p.ty_out(), Layout::RowMajor)?;

        let kt = self.cfg.kernel;
        let (nm, _, nn) = self.cfg.native();
        let (r, s, t) = p.micro_tile();
        let _ = s;
        let a_chain = self.a_chain();
        let bt_chain = self.bt_chain();
        let out_chain = self.out_chain();
        let k_tiles = pk / kt.k_ct;

        for trow in 0..pm / nm {
            for tcol in 0..pn / nn {
                // Per array row: pre-tiled A tiles for the whole reduction.
                let mut a_tiles: Vec<Vec<Vec<u32>>> = Vec::with_capacity(self.cfg.m_rows);
                for ar in 0..self.cfg.m_rows {
                    let row0 = trow * nm + ar * kt.m_ct;
                    a_tiles.push(self.stream_input(&a_chain, &pa, row0, pk)?);
                }
                // Per array column: pre-tiled B tiles.
                let mut b_tiles: Vec<Vec<Vec<u32>>> = Vec::with_capacity(self.cfg.n_cols);
                for ac in 0..self.cfg.n_cols {
                    let tiles = match self.cfg.b_layout {
                        Layout::ColMajor => {
                            // Column-major B == row panel of the Bᵀ image.
                            let row0 = tcol * nn + ac * kt.n_ct;
                            self.stream_input(&bt_chain, &pb, row0, pk)?
                        }
                        Layout::RowMajor => {
                            let col0_w = (tcol * nn + ac * kt.n_ct) * p.ty_in() / 4;
                            self.stream_b_rowmajor(&pb, col0_w, pk)?
                        }
                    };
                    b_tiles.push(tiles);
                }

                // Decode each pre-tiled tile to dense form ONCE (the
                // broadcast means every A tile feeds n_cols cores and
                // every B tile m_rows cores — §Perf optimization 2).
                let a_dense: Vec<Vec<DenseTile>> = a_tiles
                    .iter()
                    .map(|tiles| tiles.iter().map(|w| self.decode_a(w)).collect())
                    .collect();
                let b_dense: Vec<Vec<DenseTile>> = b_tiles
                    .iter()
                    .map(|tiles| tiles.iter().map(|w| self.decode_b(w)).collect())
                    .collect();

                // Every core computes its output-stationary tile, then each
                // column drains through its MemTile to DRAM.
                for ac in 0..self.cfg.n_cols {
                    let mut column_c: Vec<Vec<u32>> = Vec::with_capacity(self.cfg.m_rows);
                    for ar in 0..self.cfg.m_rows {
                        let pretiled_c =
                            self.core_compute(&a_dense[ar], &b_dense[ac], k_tiles)?;
                        column_c.push(pretiled_c);
                    }
                    let col0_w = (tcol * nn + ac * kt.n_ct) * p.ty_out() / 4;
                    let ld_w = pc.row_words();
                    out_chain.drain_column(&column_c, &mut pc.data, trow * nm, col0_w, ld_w)?;
                }
                let _ = r;
                let _ = t;
            }
        }

        crop_matrix(&pc, m, n, p.ty_out())
    }

    /// Execute a GEMM chain: `C_0 = narrow(A @ B_0)`, then each staged
    /// C feeds the next op as its A — the functional mirror of the
    /// planner's fused edges (`crate::plan`), where the intermediate
    /// image never leaves the device. Multi-op chains require a
    /// precision whose output dtype equals its input dtype (int8→int8,
    /// bf16); every weight must match the design's B layout. Numerics
    /// are identical to re-dispatching each op, because the drained C
    /// image is exactly the next dispatch's A image.
    pub fn execute_chain(&self, a: &Matrix, weights: &[Matrix]) -> Result<Matrix> {
        ensure!(!weights.is_empty(), "empty chain");
        let p = self.cfg.precision;
        ensure!(
            weights.len() == 1 || matches!(p, Precision::I8I8 | Precision::Bf16),
            "{p} output cannot feed the next op's input (chain of {} ops)",
            weights.len()
        );
        let mut c = self.execute(a, &weights[0])?;
        for b in &weights[1..] {
            c = self.execute(&c, b)?;
        }
        Ok(c)
    }

    /// One core's whole reduction over pre-decoded dense tiles: MAC into
    /// the stationary accumulator, narrow, re-tile for the output path.
    fn core_compute(&self, a_tiles: &[DenseTile], b_tiles: &[DenseTile], k_tiles: usize) -> Result<Vec<u32>> {
        let p = self.cfg.precision;
        let kt = self.cfg.kernel;
        let (r, _, t) = p.micro_tile();
        match p {
            Precision::Bf16 => {
                let mut acc = vec![0f32; kt.m_ct * kt.n_ct]; // zeroing kernel
                for ti in 0..k_tiles {
                    let (DenseTile::F32(a), DenseTile::F32(b)) = (&a_tiles[ti], &b_tiles[ti])
                    else {
                        unreachable!("precision fixed per executor")
                    };
                    dense_mac_f32(a, b, &mut acc, kt.m_ct, kt.k_ct, kt.n_ct);
                }
                // Narrow to bf16 and lay out pre-tiled r × t.
                let mut bytes = Vec::with_capacity(kt.m_ct * kt.n_ct * 2);
                for_each_pretiled(kt.m_ct, kt.n_ct, r, t, |i, j| {
                    let v = Bf16::from_f32(acc[i * kt.n_ct + j]);
                    bytes.extend_from_slice(&v.to_bits().to_le_bytes());
                });
                Ok(pack_words(&bytes))
            }
            _ => {
                let mut acc = vec![0i32; kt.m_ct * kt.n_ct]; // zeroing kernel
                for ti in 0..k_tiles {
                    let (DenseTile::I8(a), DenseTile::I8(b)) = (&a_tiles[ti], &b_tiles[ti])
                    else {
                        unreachable!("precision fixed per executor")
                    };
                    dense_mac_i32(a, b, &mut acc, kt.m_ct, kt.k_ct, kt.n_ct);
                }
                let mut bytes = Vec::with_capacity(kt.m_ct * kt.n_ct * p.ty_out());
                for_each_pretiled(kt.m_ct, kt.n_ct, r, t, |i, j| {
                    let v = acc[i * kt.n_ct + j];
                    match p {
                        Precision::I8I8 => bytes.push(crate::dtype::sat_i8(v) as u8),
                        Precision::I8I16 => {
                            bytes.extend_from_slice(&crate::dtype::sat_i16(v).to_le_bytes())
                        }
                        Precision::I8I32 => bytes.extend_from_slice(&v.to_le_bytes()),
                        Precision::Bf16 => unreachable!(),
                    }
                });
                Ok(pack_words(&bytes))
            }
        }
    }

    /// Decode one pre-tiled A tile to dense `m_ct × k_ct`.
    fn decode_a(&self, words: &[u32]) -> DenseTile {
        let kt = self.cfg.kernel;
        let (r, s, _) = self.cfg.precision.micro_tile();
        match self.cfg.precision {
            Precision::Bf16 => {
                DenseTile::F32(decode_pretiled_bf16(words, kt.m_ct, kt.k_ct, r, s))
            }
            _ => DenseTile::I8(decode_pretiled_i8(words, kt.m_ct, kt.k_ct, r, s)),
        }
    }

    /// Decode one pre-tiled B tile to dense `k_ct × n_ct` (applying the
    /// in-core shuffle — the AIE-API transpose — for column-major B).
    fn decode_b(&self, words: &[u32]) -> DenseTile {
        let kt = self.cfg.kernel;
        let (_, s, t) = self.cfg.precision.micro_tile();
        match self.cfg.precision {
            Precision::Bf16 => {
                let mut out = vec![0f32; kt.k_ct * kt.n_ct];
                let mut write = |dst: usize, src_idx: usize| {
                    let bits = (words[src_idx >> 1] >> ((src_idx & 1) * 16)) as u16;
                    out[dst] = Bf16::from_bits(bits).to_f32();
                };
                match self.cfg.b_layout {
                    Layout::ColMajor => decode_bt_blocks(kt.k_ct, kt.n_ct, s, t, &mut write),
                    Layout::RowMajor => decode_b_blocks(kt.k_ct, kt.n_ct, s, t, &mut write),
                }
                DenseTile::F32(out)
            }
            _ => {
                let mut out = vec![0i8; kt.k_ct * kt.n_ct];
                let mut write = |dst: usize, src_idx: usize| {
                    out[dst] = (words[src_idx >> 2] >> ((src_idx & 3) * 8)) as u8 as i8;
                };
                match self.cfg.b_layout {
                    Layout::ColMajor => decode_bt_blocks(kt.k_ct, kt.n_ct, s, t, &mut write),
                    Layout::RowMajor => decode_b_blocks(kt.k_ct, kt.n_ct, s, t, &mut write),
                }
                DenseTile::I8(out)
            }
        }
    }
}

/// A decoded (dense, row-major) operand tile.
enum DenseTile {
    I8(Vec<i8>),
    F32(Vec<f32>),
}

/// Walk a pre-tiled row-major-B image (`s × t` micro-tiles) in source
/// order, emitting (dense `k·n_ct + j` index, source index) pairs —
/// division-free (§Perf optimization 3).
fn decode_b_blocks(k_ct: usize, n_ct: usize, s: usize, t: usize, f: &mut impl FnMut(usize, usize)) {
    let mut src = 0;
    for ko in 0..k_ct / s {
        for jo in 0..n_ct / t {
            for ki in 0..s {
                let row = (ko * s + ki) * n_ct + jo * t;
                for w in 0..t {
                    f(row + w, src);
                    src += 1;
                }
            }
        }
    }
}

/// Walk a pre-tiled Bᵀ image (`t × s` micro-tiles of the transposed
/// panel) in source order; destination indices are transposed — this IS
/// the in-core shuffle.
fn decode_bt_blocks(k_ct: usize, n_ct: usize, s: usize, t: usize, f: &mut impl FnMut(usize, usize)) {
    let mut src = 0;
    for jo in 0..n_ct / t {
        for ko in 0..k_ct / s {
            for ji in 0..t {
                let col = jo * t + ji;
                let k0 = ko * s;
                for ki in 0..s {
                    f((k0 + ki) * n_ct + col, src);
                    src += 1;
                }
            }
        }
    }
}

/// Visit (i, j) of an `m × n` tile in pre-tiled `r × t` stream order.
fn for_each_pretiled(m: usize, n: usize, r: usize, t: usize, mut f: impl FnMut(usize, usize)) {
    for mo in 0..m / r {
        for jo in 0..n / t {
            for mi in 0..r {
                for w in 0..t {
                    f(mo * r + mi, jo * t + w);
                }
            }
        }
    }
}

/// Decode one pre-tiled A tile to dense `m_ct × k_ct` i8 (division-free:
/// walk micro-tiles in source order — §Perf optimization 3).
fn decode_pretiled_i8(words: &[u32], m_ct: usize, k_ct: usize, r: usize, s: usize) -> Vec<i8> {
    // Read bytes straight out of the word image (no intermediate Vec —
    // §Perf optimization 4).
    let byte = |i: usize| (words[i >> 2] >> ((i & 3) * 8)) as u8;
    let mut out = vec![0i8; m_ct * k_ct];
    let mut src = 0;
    for mo in 0..m_ct / r {
        for ko in 0..k_ct / s {
            for mi in 0..r {
                let base = (mo * r + mi) * k_ct + ko * s;
                for si in 0..s {
                    out[base + si] = byte(src) as i8;
                    src += 1;
                }
            }
        }
    }
    out
}

fn decode_pretiled_bf16(words: &[u32], m_ct: usize, k_ct: usize, r: usize, s: usize) -> Vec<f32> {
    let half = |i: usize| (words[i >> 1] >> ((i & 1) * 16)) as u16;
    let mut out = vec![0f32; m_ct * k_ct];
    let mut src = 0;
    for mo in 0..m_ct / r {
        for ko in 0..k_ct / s {
            for mi in 0..r {
                let base = (mo * r + mi) * k_ct + ko * s;
                for si in 0..s {
                    out[base + si] = Bf16::from_bits(half(src)).to_f32();
                    src += 1;
                }
            }
        }
    }
    out
}

/// Dense micro-kernel: `acc += a @ b` (int32 accumulate — the MAC array).
fn dense_mac_i32(a: &[i8], b: &[i8], acc: &mut [i32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk] as i32;
            if av == 0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let crow = &mut acc[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j] as i32;
            }
        }
    }
}

/// Dense micro-kernel, f32 accumulators (the bf16 datapath).
fn dense_mac_f32(a: &[f32], b: &[f32], acc: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            let brow = &b[kk * n..(kk + 1) * n];
            let crow = &mut acc[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

fn pack_words(bytes: &[u8]) -> Vec<u32> {
    assert!(bytes.len() % 4 == 0);
    bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Zero-pad a matrix image to `rows × cols` (same layout/elem size).
pub fn pad_matrix(src: &Matrix, rows: usize, cols: usize) -> Result<Matrix> {
    if src.rows == rows && src.cols == cols {
        return Ok(src.clone());
    }
    let mut out = Matrix::zeroed(rows, cols, src.elem_bytes, src.layout)?;
    // Copy storage row by storage row; when both images' rows are
    // word-aligned (the common case — Matrix enforces word-aligned
    // storage rows), this is a straight word memcpy per row.
    let src_row_w = src.row_words();
    let dst_row_w = out.row_words();
    for sr in 0..src.n_storage_rows() {
        let s0 = sr * src_row_w;
        let d0 = sr * dst_row_w;
        out.data[d0..d0 + src_row_w].copy_from_slice(&src.data[s0..s0 + src_row_w]);
    }
    Ok(out)
}

/// Crop a row-major matrix image to `rows × cols`.
fn crop_matrix(src: &Matrix, rows: usize, cols: usize, elem_bytes: usize) -> Result<Matrix> {
    if src.rows == rows && src.cols == cols {
        return Ok(src.clone());
    }
    let mut out = Matrix::zeroed(rows, cols, elem_bytes, Layout::RowMajor)?;
    for i in 0..rows {
        for j in 0..cols {
            for b in 0..elem_bytes {
                let v = src.get_byte((i * src.cols + j) * elem_bytes + b);
                out.set_byte((i * cols + j) * elem_bytes + b, v);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Generation;
    use crate::gemm::refimpl;
    use crate::tiling::TilingConfig;
    use crate::util::prop::prop_check;

    /// Scaled-down configs (same structure, small tiles) so the functional
    /// path stays fast.
    fn tiny_cfg(gen: Generation, p: Precision, b_layout: Layout) -> TilingConfig {
        let (_, _, t) = p.micro_tile();
        let n_ct = 2 * t.max(4);
        let spec = gen.spec();
        TilingConfig::new(gen, p, 8, 16, n_ct, 32, spec.array_rows, spec.shim_cols, b_layout)
            .unwrap()
    }

    fn run_case(gen: Generation, p: Precision, layout: Layout, fidelity: Fidelity, m: usize, k: usize, n: usize, seed: u64) {
        let cfg = tiny_cfg(gen, p, layout);
        let mut a = Matrix::zeroed(m, k, p.ty_in(), Layout::RowMajor).unwrap();
        let mut b = Matrix::zeroed(k, n, p.ty_in(), layout).unwrap();
        refimpl::fill_random(&mut a, p, seed);
        refimpl::fill_random(&mut b, p, seed + 1);
        let got = Executor::new(cfg, fidelity).execute(&a, &b).unwrap();
        let want = refimpl::ref_gemm(&a, &b, p).unwrap();
        assert!(
            refimpl::matrices_equal(&got, &want, p),
            "{gen}/{p}/{layout:?}/{fidelity:?} {m}x{k}x{n} mismatch"
        );
    }

    #[test]
    fn all_precisions_native_size_bdchain() {
        for gen in Generation::ALL {
            for p in Precision::ALL {
                for layout in [Layout::ColMajor, Layout::RowMajor] {
                    let cfg = tiny_cfg(gen, p, layout);
                    let (nm, nk, nn) = cfg.native();
                    run_case(gen, p, layout, Fidelity::BdChain, nm, nk, nn, 7);
                }
            }
        }
    }

    #[test]
    fn multi_tile_multi_panel() {
        // 2x2 native tiles, 3 K panels — exercises the outer tiling level.
        let cfg = tiny_cfg(Generation::Xdna, Precision::I8I16, Layout::ColMajor);
        let (nm, nk, nn) = cfg.native();
        run_case(
            Generation::Xdna,
            Precision::I8I16,
            Layout::ColMajor,
            Fidelity::Direct,
            2 * nm,
            3 * nk,
            2 * nn,
            11,
        );
    }

    #[test]
    fn ragged_sizes_are_padded_correctly() {
        // Non-aligned sizes round up to the native grid; results must
        // still match the reference exactly on the unpadded region.
        let cfg = tiny_cfg(Generation::Xdna, Precision::I8I8, Layout::ColMajor);
        let (nm, nk, nn) = cfg.native();
        // m is free; k and n stay word-aligned (DMA-visible DRAM images).
        run_case(
            Generation::Xdna,
            Precision::I8I8,
            Layout::ColMajor,
            Fidelity::Direct,
            nm - 3,
            nk + 4,
            nn - 4,
            13,
        );
    }

    #[test]
    fn bd_chain_equals_direct() {
        prop_check("BdChain ≡ Direct fidelity", 8, |rng| {
            let gens = [Generation::Xdna, Generation::Xdna2];
            let precs = Precision::ALL;
            let layouts = [Layout::RowMajor, Layout::ColMajor];
            let gen = *rng.pick(&gens);
            let p = *rng.pick(&precs);
            let layout = *rng.pick(&layouts);
            let cfg = tiny_cfg(gen, p, layout);
            let (nm, nk, nn) = cfg.native();
            // m is free; k and n move in word-aligned (4-element) steps.
            let m = nm - rng.below(4);
            let k = nk + 4 * rng.below(2);
            let n = nn - 4 * rng.below(2);
            let mut a = Matrix::zeroed(m, k, p.ty_in(), Layout::RowMajor).unwrap();
            let mut b = Matrix::zeroed(k, n, p.ty_in(), layout).unwrap();
            refimpl::fill_random(&mut a, p, rng.next_u64());
            refimpl::fill_random(&mut b, p, rng.next_u64());
            let via_bd = Executor::new(cfg, Fidelity::BdChain).execute(&a, &b).unwrap();
            let direct = Executor::new(cfg, Fidelity::Direct).execute(&a, &b).unwrap();
            assert!(refimpl::matrices_equal(&via_bd, &direct, p));
        });
    }

    #[test]
    fn saturating_inputs_end_to_end() {
        // Extreme int8 inputs saturate through the full pipeline exactly
        // like the reference.
        run_case(
            Generation::Xdna2,
            Precision::I8I8,
            Layout::ColMajor,
            Fidelity::Direct,
            16,
            64,
            16,
            99,
        );
    }

    #[test]
    fn chain_matches_folded_reference() {
        // 3-op int8 chain: the staged C of each op is the next op's A —
        // bit-exact against folding the reference GEMM the same way.
        let cfg = tiny_cfg(Generation::Xdna2, Precision::I8I8, Layout::ColMajor);
        let (m, dims) = (16, [32usize, 16, 24, 8]);
        let mut a = Matrix::zeroed(m, dims[0], 1, Layout::RowMajor).unwrap();
        refimpl::fill_random(&mut a, Precision::I8I8, 21);
        let weights: Vec<Matrix> = (0..3)
            .map(|i| {
                let mut b = Matrix::zeroed(dims[i], dims[i + 1], 1, Layout::ColMajor).unwrap();
                refimpl::fill_random(&mut b, Precision::I8I8, 100 + i as u64);
                b
            })
            .collect();
        let got = Executor::new(cfg, Fidelity::Direct).execute_chain(&a, &weights).unwrap();
        let mut want = a.clone();
        for b in &weights {
            want = refimpl::ref_gemm(&want, b, Precision::I8I8).unwrap();
        }
        assert_eq!((got.rows, got.cols), (m, dims[3]));
        assert!(refimpl::matrices_equal(&got, &want, Precision::I8I8));
    }

    #[test]
    fn chain_rejects_widening_precisions_beyond_one_op() {
        let cfg = tiny_cfg(Generation::Xdna, Precision::I8I16, Layout::ColMajor);
        let mut a = Matrix::zeroed(8, 16, 1, Layout::RowMajor).unwrap();
        let mut b = Matrix::zeroed(16, 16, 1, Layout::ColMajor).unwrap();
        refimpl::fill_random(&mut a, Precision::I8I16, 1);
        refimpl::fill_random(&mut b, Precision::I8I16, 2);
        let exec = Executor::new(cfg, Fidelity::Direct);
        // One op is fine (no chained consumption)...
        assert!(exec.execute_chain(&a, std::slice::from_ref(&b)).is_ok());
        // ...but an int16 C cannot feed an int8-input op.
        assert!(exec.execute_chain(&a, &[b.clone(), b.clone()]).is_err());
        assert!(exec.execute_chain(&a, &[]).is_err());
    }

    #[test]
    fn rejects_mismatched_layout() {
        let cfg = tiny_cfg(Generation::Xdna, Precision::I8I8, Layout::ColMajor);
        let a = Matrix::zeroed(8, 16, 1, Layout::RowMajor).unwrap();
        let b = Matrix::zeroed(16, 16, 1, Layout::RowMajor).unwrap(); // wrong
        assert!(Executor::new(cfg, Fidelity::Direct).execute(&a, &b).is_err());
    }
}
