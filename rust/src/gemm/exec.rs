//! The functional tiled executor: the paper's whole-array GEMM mapping
//! (Sec. 4.2) run with real bytes on the simulated hierarchy.
//!
//! Per output native tile (Fig. 3):
//! 1. each array row's `m_ct × K` A panel and each column's B panel are
//!    streamed DRAM → L2 → L1 through the BD transform chains of
//!    [`crate::xform`] (the Fig.-4 pipeline), arriving *pre-tiled*;
//! 2. every core runs the output-stationary micro-kernel over `K/k_ct`
//!    pre-tiled tile pairs (the zeroing step is the accumulator init);
//! 3. the narrowed C tile is produced in pre-tiled `r × t` layout and
//!    drained through the MemTile aggregation + 4D de-tiling path back to
//!    row-major DRAM (Sec. 4.2.2).
//!
//! **The packed hot path (DESIGN.md §9).** A panel is streamed and
//! decoded *once* per consumer, not once per output tile: every B panel
//! is packed up front into a grid-wide cache of dense tiles (each B
//! panel feeds all `M/nm` tile rows), and each tile row's A panels are
//! packed once and reused across every `tcol` — the GotoBLAS-style
//! packing discipline of Lei & Quintana-Ortí's Versal port. All scratch
//! (streamed words, packed panels, accumulators, the column's C tiles,
//! the drain's L2 image) is sized once from the [`TilingConfig`] and
//! reused, so the per-tile loop allocates nothing.
//!
//! **Parallelism.** Output tile rows fan out across
//! `std::thread::scope` workers ([`ExecOptions::threads`]); each worker
//! owns a disjoint `nm`-row band of the C image, so there is no shared
//! mutable state. Results are *bit-identical for every thread count*
//! (int8 and bf16 alike): each output tile's reduction runs in fixed
//! `k_ct`-tile order on one worker, and thread count only changes which
//! worker runs a tile, never the order within it.
//!
//! Two fidelity levels produce *identical* bytes (property-tested):
//! `BdChain` drives every hop through real BD gathers/scatters;
//! `Direct` uses the algebraic pre-tiling oracle (faster; the default for
//! examples and the coordinator's functional mode).

use anyhow::{anyhow, ensure, Result};

use crate::dtype::{Bf16, Layout, Precision};
use crate::dtype_bfp16::{BfpBlock, BLOCK, BLOCK_WORDS, PADDED_BYTES};
use crate::mem::Matrix;
use crate::tiling::TilingConfig;
use crate::xform::{pretile_oracle_into, BRowMajorChain, InputChain, OutputChain};

/// How faithfully to move the bytes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Fidelity {
    /// Full BD-chain streaming (every hop of Fig. 4).
    BdChain,
    /// Algebraic pre-tiling (same layout, no per-hop simulation).
    Direct,
}

/// Knobs of the packed, parallel execution backend (DESIGN.md §9).
#[derive(Clone, Copy, Debug)]
pub struct ExecOptions {
    pub fidelity: Fidelity,
    /// Worker threads for the output-tile fan-out (1 = inline serial).
    /// Results are bit-identical for every value: the per-tile reduction
    /// order is fixed, threads only partition the tile-row grid.
    pub threads: usize,
    /// Reuse packed panels across the native-tile grid (B grid-wide, A
    /// per tile row). `false` re-streams and re-decodes every panel per
    /// output tile — the packing-off ablation the `hotpath` bench
    /// measures against. (It still uses the flat scratch and slice
    /// kernels, so measured reuse speedups *understate* the delta vs
    /// the true pre-PR3 executor, which also churned per-tile Vecs.)
    pub pack_reuse: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { fidelity: Fidelity::Direct, threads: 1, pack_reuse: true }
    }
}

pub struct Executor {
    pub cfg: TilingConfig,
    pub opts: ExecOptions,
}

impl Executor {
    pub fn new(cfg: TilingConfig, fidelity: Fidelity) -> Executor {
        Executor::with_options(cfg, ExecOptions { fidelity, ..Default::default() })
    }

    pub fn with_options(cfg: TilingConfig, opts: ExecOptions) -> Executor {
        Executor { cfg, opts }
    }

    /// Whether this design runs the native block-FP path: the DMA chains
    /// then move whole 12-byte padded blocks as opaque 3-word elements
    /// (the word-aligned repack of DESIGN.md §10), so every chain below
    /// is parameterized in *block units* along K (and along N for C).
    fn is_bfp(&self) -> bool {
        self.cfg.precision == Precision::Bfp16
    }

    /// Elements per K-axis storage unit (8 for bfp16 blocks, else 1).
    fn k_unit(&self) -> usize {
        if self.is_bfp() {
            BLOCK
        } else {
            1
        }
    }

    fn a_chain(&self) -> InputChain {
        let (r, s, _) = self.cfg.precision.micro_tile();
        let u = self.k_unit();
        InputChain {
            rows: self.cfg.kernel.m_ct,
            micro_r: r,
            micro_s: s / u,
            k_ct: self.cfg.kernel.k_ct / u,
            k_mt: self.cfg.k_mt / u,
            elem_bytes: if self.is_bfp() { PADDED_BYTES } else { self.cfg.precision.ty_in() },
        }
    }

    fn bt_chain(&self) -> InputChain {
        let (_, s, t) = self.cfg.precision.micro_tile();
        let u = self.k_unit();
        InputChain {
            rows: self.cfg.kernel.n_ct,
            micro_r: t,
            micro_s: s / u,
            k_ct: self.cfg.kernel.k_ct / u,
            k_mt: self.cfg.k_mt / u,
            elem_bytes: if self.is_bfp() { PADDED_BYTES } else { self.cfg.precision.ty_in() },
        }
    }

    fn brm_chain(&self) -> BRowMajorChain {
        let (_, s, t) = self.cfg.precision.micro_tile();
        BRowMajorChain {
            k_ct: self.cfg.kernel.k_ct,
            n_ct: self.cfg.kernel.n_ct,
            micro_s: s,
            micro_t: t,
            elem_bytes: self.cfg.precision.ty_in(),
        }
    }

    fn out_chain(&self) -> OutputChain {
        let (r, _, t) = self.cfg.precision.micro_tile();
        if self.is_bfp() {
            // C blocks run along N (t == BLOCK): one micro-tile column is
            // one block, stored padded like the inputs so the C image can
            // chain straight into the next op's A.
            OutputChain {
                m_ct: self.cfg.kernel.m_ct,
                n_ct: self.cfg.kernel.n_ct / BLOCK,
                micro_r: r,
                micro_t: 1,
                elem_bytes: PADDED_BYTES,
            }
        } else {
            OutputChain {
                m_ct: self.cfg.kernel.m_ct,
                n_ct: self.cfg.kernel.n_ct,
                micro_r: r,
                micro_t: t,
                elem_bytes: self.cfg.precision.ty_out(),
            }
        }
    }

    /// Words per pre-tiled B tile (both layouts pre-tile to the same size).
    fn b_tile_words(&self) -> usize {
        match self.cfg.b_layout {
            Layout::ColMajor => self.bt_chain().tile_words(),
            Layout::RowMajor => self.brm_chain().tile_words(),
        }
    }

    /// Stream one A/Bᵀ panel as `pk/k_ct` consecutive pre-tiled tiles
    /// into the flat `words` scratch (no per-tile allocation).
    fn stream_input_into(
        &self,
        chain: &InputChain,
        img: &Matrix,
        row0: usize,
        pk: usize,
        words: &mut [u32],
    ) -> Result<()> {
        match self.opts.fidelity {
            Fidelity::BdChain => {
                chain.stream_panel_into(&img.data, row0, img.row_words(), pk, words)
            }
            Fidelity::Direct => {
                let k_ct_w = chain.k_ct * chain.elem_bytes / 4;
                for (ti, tile) in words.chunks_mut(chain.tile_words()).enumerate() {
                    pretile_oracle_into(&img.data, img.row_words(), row0, ti * k_ct_w, chain, tile);
                }
                Ok(())
            }
        }
    }

    fn stream_b_rowmajor_into(
        &self,
        img: &Matrix,
        col0_w: usize,
        pk: usize,
        words: &mut [u32],
    ) -> Result<()> {
        let c = self.brm_chain();
        match self.opts.fidelity {
            Fidelity::BdChain => c.stream_panel_into(&img.data, col0_w, img.row_words(), pk, words),
            Fidelity::Direct => {
                for (ti, tile) in words.chunks_mut(c.tile_words()).enumerate() {
                    c.pretile_oracle_into(&img.data, img.row_words(), ti * c.k_ct, col0_w, tile);
                }
                Ok(())
            }
        }
    }

    /// Pack one array row's A panel: stream all `k_tiles` tiles into the
    /// `stream` scratch, then decode each into `dst`'s dense buffer.
    fn pack_a_panel(
        &self,
        pa: &Matrix,
        row0: usize,
        k_tiles: usize,
        stream: &mut [u32],
        dst: &mut PackedPanel,
    ) -> Result<()> {
        let chain = self.a_chain();
        let tw = chain.tile_words();
        let words = &mut stream[..k_tiles * tw];
        self.stream_input_into(&chain, pa, row0, k_tiles * chain.k_ct, words)?;
        for ti in 0..k_tiles {
            self.decode_a_tile(&words[ti * tw..(ti + 1) * tw], dst.tile_mut(ti));
        }
        Ok(())
    }

    /// Pack one array column's B panel for output-tile column `tcol`.
    fn pack_b_panel(
        &self,
        pb: &Matrix,
        tcol: usize,
        ac: usize,
        k_tiles: usize,
        stream: &mut [u32],
        dst: &mut PackedPanel,
    ) -> Result<()> {
        let kt = self.cfg.kernel;
        let (_, _, nn) = self.cfg.native();
        let tw = self.b_tile_words();
        let words = &mut stream[..k_tiles * tw];
        match self.cfg.b_layout {
            Layout::ColMajor => {
                // Column-major B == row panel of the Bᵀ image.
                let row0 = tcol * nn + ac * kt.n_ct;
                let chain = self.bt_chain();
                self.stream_input_into(&chain, pb, row0, k_tiles * chain.k_ct, words)?;
            }
            Layout::RowMajor => {
                let col0_w = self.cfg.precision.bytes_in(tcol * nn + ac * kt.n_ct) / 4;
                self.stream_b_rowmajor_into(pb, col0_w, k_tiles * kt.k_ct, words)?;
            }
        }
        for ti in 0..k_tiles {
            self.decode_b_tile(&words[ti * tw..(ti + 1) * tw], dst.tile_mut(ti));
        }
        Ok(())
    }

    /// Pack the grid-wide B cache (`cache[tcol][ac]`), fanning the
    /// prepack across up to `workers` scoped threads (one `tcol` bucket
    /// each, disjoint slots — no synchronization).
    fn pack_b_cache(
        &self,
        pb: &Matrix,
        k_tiles: usize,
        t_cols: usize,
        workers: usize,
    ) -> Result<Vec<Vec<PackedPanel>>> {
        let p = self.cfg.precision;
        let kt = self.cfg.kernel;
        let mut cache: Vec<Vec<PackedPanel>> = (0..t_cols)
            .map(|_| {
                (0..self.cfg.n_cols)
                    .map(|_| PackedPanel::new(p, kt.k_ct * kt.n_ct, k_tiles))
                    .collect()
            })
            .collect();
        let stream_len = k_tiles * self.b_tile_words();
        let w = workers.max(1).min(t_cols.max(1));
        if w <= 1 {
            let mut stream = vec![0u32; stream_len];
            for (tcol, panels) in cache.iter_mut().enumerate() {
                for (ac, panel) in panels.iter_mut().enumerate() {
                    self.pack_b_panel(pb, tcol, ac, k_tiles, &mut stream, panel)?;
                }
            }
        } else {
            let mut buckets: Vec<Vec<(usize, &mut Vec<PackedPanel>)>> =
                (0..w).map(|_| Vec::new()).collect();
            for (tcol, panels) in cache.iter_mut().enumerate() {
                buckets[tcol % w].push((tcol, panels));
            }
            std::thread::scope(|s| -> Result<()> {
                let handles: Vec<_> = buckets
                    .into_iter()
                    .map(|bucket| {
                        s.spawn(move || -> Result<()> {
                            let mut stream = vec![0u32; stream_len];
                            for (tcol, panels) in bucket {
                                for (ac, panel) in panels.iter_mut().enumerate() {
                                    self.pack_b_panel(pb, tcol, ac, k_tiles, &mut stream, panel)?;
                                }
                            }
                            Ok(())
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().map_err(|_| anyhow!("B-prepack worker panicked"))??;
                }
                Ok(())
            })?;
        }
        Ok(cache)
    }

    /// Decode one pre-tiled A tile to dense `m_ct × k_ct` (for bfp16 the
    /// core-side pack: strip each 3-word block's pad and widen to f32).
    fn decode_a_tile(&self, words: &[u32], dst: TileMut<'_>) {
        let kt = self.cfg.kernel;
        let (r, s, _) = self.cfg.precision.micro_tile();
        if self.is_bfp() {
            let TileMut::F32(out) = dst else { unreachable!("bfp16 decodes to f32 panels") };
            decode_pretiled_bfp_a(words, kt.m_ct, kt.k_ct, r, out);
            return;
        }
        match dst {
            TileMut::I8(out) => decode_pretiled_i8(words, kt.m_ct, kt.k_ct, r, s, out),
            TileMut::F32(out) => decode_pretiled_bf16(words, kt.m_ct, kt.k_ct, r, s, out),
        }
    }

    /// Decode one pre-tiled B tile to dense `k_ct × n_ct` (applying the
    /// in-core shuffle — the AIE-API transpose — for column-major B; the
    /// bfp16 path transposes block-wise while stripping pad).
    fn decode_b_tile(&self, words: &[u32], dst: TileMut<'_>) {
        let kt = self.cfg.kernel;
        let (_, s, t) = self.cfg.precision.micro_tile();
        if self.is_bfp() {
            let TileMut::F32(out) = dst else { unreachable!("bfp16 decodes to f32 panels") };
            decode_pretiled_bfp_bt(words, kt.k_ct, kt.n_ct, t, out);
            return;
        }
        let walk: fn(usize, usize, usize, usize, &mut dyn FnMut(usize, usize)) =
            match self.cfg.b_layout {
                Layout::ColMajor => decode_bt_blocks,
                Layout::RowMajor => decode_b_blocks,
            };
        match dst {
            TileMut::I8(out) => walk(kt.k_ct, kt.n_ct, s, t, &mut |di, si| {
                out[di] = (words[si >> 2] >> ((si & 3) * 8)) as u8 as i8;
            }),
            TileMut::F32(out) => walk(kt.k_ct, kt.n_ct, s, t, &mut |di, si| {
                let bits = (words[si >> 1] >> ((si & 1) * 16)) as u16;
                out[di] = Bf16::from_bits(bits).to_f32();
            }),
        }
    }

    /// Execute `C = narrow(A @ B)` through the full mapping.
    ///
    /// `a`: `m × k` row-major; `b`: `k × n`, layout per `cfg.b_layout`.
    /// Returns the `m × n` row-major result (padding stripped). bfp16
    /// operands are padded-block images (`Matrix::zeroed_bfp16`, block
    /// units along K) and the result is one too — blocks along N, which
    /// is exactly the next op's K, so chains stage it unchanged.
    pub fn execute(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let p = self.cfg.precision;
        let bfp = self.is_bfp();
        ensure!(a.layout == Layout::RowMajor, "A must be row-major");
        ensure!(b.layout == self.cfg.b_layout, "B layout must match the design");
        if bfp {
            ensure!(self.cfg.b_layout == Layout::ColMajor, "bfp16 requires column-major B");
            ensure!(a.is_bfp16() && b.is_bfp16(), "bfp16 operands must be block images");
        }
        ensure!(a.cols == b.rows, "shape mismatch");
        let u = self.k_unit();
        let (m, k, n) = (a.rows, a.cols * u, b.cols);
        if bfp {
            ensure!(n % BLOCK == 0, "bfp16 N must cover whole 8-value blocks");
        }
        let (pm, pk, pn) = self.cfg.padded(m, k, n);

        // Zero-pad into fresh DRAM images (the paper's Sec. 5.3.1 notes
        // the NPU can zero-pad on the fly in MemTile channels; host-side
        // padding exercises the same aligned code path). Block images
        // pad in block units; a zero word block decodes to an all-zero
        // block, so padded K terms are exact no-ops in the reduction.
        let pa = pad_matrix(a, pm, pk / u)?;
        let pb = pad_matrix(b, pk / u, pn)?;
        let mut pc = if bfp {
            Matrix::zeroed_bfp16(pm, pn, Layout::RowMajor)?
        } else {
            Matrix::zeroed(pm, pn, p.ty_out(), Layout::RowMajor)?
        };

        let kt = self.cfg.kernel;
        let (nm, _, nn) = self.cfg.native();
        let t_rows = pm / nm;
        let t_cols = pn / nn;
        let k_tiles = pk / kt.k_ct;
        let ld_w = pc.row_words();

        // Pack every B panel once, up front: panel (tcol, ac) feeds every
        // tile row, so the grid re-reads the decoded cache instead of
        // re-streaming it per trow. The prepack itself fans out across
        // the same worker budget so it doesn't become the serial
        // fraction on B-dominated (small-M, wide-N) shapes.
        let b_cache: Vec<Vec<PackedPanel>> = if self.opts.pack_reuse {
            self.pack_b_cache(&pb, k_tiles, t_cols, self.opts.threads.max(1))?
        } else {
            Vec::new()
        };

        // Fan tile rows out across scoped workers: each worker owns a
        // disjoint nm-row band of the C image, so the bands write in
        // parallel without synchronization.
        let band_words = nm * ld_w;
        let n_workers = self.opts.threads.max(1).min(t_rows.max(1));
        if n_workers <= 1 {
            let mut st = WorkerState::new(self, k_tiles);
            for (trow, band) in pc.data.chunks_mut(band_words).enumerate() {
                self.run_band(&mut st, trow, band, &pa, &pb, &b_cache, k_tiles, t_cols, ld_w)?;
            }
        } else {
            let mut buckets: Vec<Vec<(usize, &mut [u32])>> =
                (0..n_workers).map(|_| Vec::new()).collect();
            for (trow, band) in pc.data.chunks_mut(band_words).enumerate() {
                buckets[trow % n_workers].push((trow, band));
            }
            let (pa_ref, pb_ref, cache_ref) = (&pa, &pb, &b_cache);
            std::thread::scope(|s| -> Result<()> {
                let handles: Vec<_> = buckets
                    .into_iter()
                    .map(|bucket| {
                        s.spawn(move || -> Result<()> {
                            let mut st = WorkerState::new(self, k_tiles);
                            for (trow, band) in bucket {
                                self.run_band(
                                    &mut st, trow, band, pa_ref, pb_ref, cache_ref, k_tiles,
                                    t_cols, ld_w,
                                )?;
                            }
                            Ok(())
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().map_err(|_| anyhow!("executor worker panicked"))??;
                }
                Ok(())
            })?;
        }

        if bfp {
            crop_matrix(&pc, m, n / BLOCK, PADDED_BYTES)
        } else {
            crop_matrix(&pc, m, n, p.ty_out())
        }
    }

    /// One worker's tile row: pack the row's A panels once, then walk
    /// every output-tile column, computing each core's stationary tile
    /// and draining the column through the MemTile aggregation path.
    #[allow(clippy::too_many_arguments)]
    fn run_band(
        &self,
        st: &mut WorkerState,
        trow: usize,
        band: &mut [u32],
        pa: &Matrix,
        pb: &Matrix,
        b_cache: &[Vec<PackedPanel>],
        k_tiles: usize,
        t_cols: usize,
        ld_w: usize,
    ) -> Result<()> {
        let p = self.cfg.precision;
        let kt = self.cfg.kernel;
        let (nm, _, nn) = self.cfg.native();
        let out_chain = self.out_chain();
        let ctw = out_chain.tile_words();

        // A panels for this tile row, packed once and reused across every
        // tcol (previously re-streamed and re-decoded per output tile).
        if self.opts.pack_reuse {
            for ar in 0..self.cfg.m_rows {
                let row0 = trow * nm + ar * kt.m_ct;
                self.pack_a_panel(pa, row0, k_tiles, &mut st.stream, &mut st.a_panels[ar])?;
            }
        }
        for tcol in 0..t_cols {
            if !self.opts.pack_reuse {
                // Ablation baseline: re-stream + re-decode both operands
                // per output tile (the pre-packing executor).
                for ar in 0..self.cfg.m_rows {
                    let row0 = trow * nm + ar * kt.m_ct;
                    self.pack_a_panel(pa, row0, k_tiles, &mut st.stream, &mut st.a_panels[ar])?;
                }
                for ac in 0..self.cfg.n_cols {
                    let panel = &mut st.b_panels[ac];
                    self.pack_b_panel(pb, tcol, ac, k_tiles, &mut st.stream, panel)?;
                }
            }
            let b_panels: &[PackedPanel] =
                if self.opts.pack_reuse { &b_cache[tcol] } else { &st.b_panels };

            // Every core computes its output-stationary tile, then each
            // column drains through its MemTile to the band's DRAM rows.
            for ac in 0..self.cfg.n_cols {
                for ar in 0..self.cfg.m_rows {
                    self.core_compute_into(
                        &st.a_panels[ar],
                        &b_panels[ac],
                        k_tiles,
                        &mut st.acc_i,
                        &mut st.acc_f,
                        &mut st.column_c[ar * ctw..(ar + 1) * ctw],
                    )?;
                }
                let col0_w = p.bytes_out(tcol * nn + ac * kt.n_ct) / 4;
                out_chain.drain_column_flat(
                    &st.column_c,
                    self.cfg.m_rows,
                    band,
                    0,
                    col0_w,
                    ld_w,
                    &mut st.drain_l2,
                )?;
            }
        }
        Ok(())
    }

    /// Execute a GEMM chain: `C_0 = narrow(A @ B_0)`, then each staged
    /// C feeds the next op as its A — the functional mirror of the
    /// planner's fused edges (`crate::plan`), where the intermediate
    /// image never leaves the device. The staged C re-enters `execute`
    /// as a row-major A image, so it rides the packed-A path like any
    /// fresh operand. Multi-op chains require a precision whose output
    /// dtype equals its input dtype (int8→int8, bf16, bfp16 — whose C
    /// blocks along N are exactly the next op's K blocks); every weight
    /// must match the design's B layout. Numerics are identical to
    /// re-dispatching each op, because the drained C image is exactly
    /// the next dispatch's A image.
    pub fn execute_chain(&self, a: &Matrix, weights: &[Matrix]) -> Result<Matrix> {
        ensure!(!weights.is_empty(), "empty chain");
        let p = self.cfg.precision;
        ensure!(
            weights.len() == 1
                || matches!(p, Precision::I8I8 | Precision::Bf16 | Precision::Bfp16),
            "{p} output cannot feed the next op's input (chain of {} ops)",
            weights.len()
        );
        let mut c = self.execute(a, &weights[0])?;
        for b in &weights[1..] {
            c = self.execute(&c, b)?;
        }
        Ok(c)
    }

    /// One core's whole reduction over a packed panel pair: MAC into the
    /// stationary accumulator in fixed `k_ct`-tile order (the determinism
    /// contract), narrow, and emit the pre-tiled `r × t` stream straight
    /// into `out` words (no intermediate byte buffer).
    fn core_compute_into(
        &self,
        a: &PackedPanel,
        b: &PackedPanel,
        k_tiles: usize,
        acc_i: &mut [i32],
        acc_f: &mut [f32],
        out: &mut [u32],
    ) -> Result<()> {
        let p = self.cfg.precision;
        let kt = self.cfg.kernel;
        let (r, _, t) = p.micro_tile();
        out.fill(0);
        match (&a.data, &b.data) {
            (PanelData::F32(_), PanelData::F32(_)) => {
                acc_f.fill(0.0); // zeroing kernel
                for ti in 0..k_tiles {
                    dense_mac_f32(a.tile_f32(ti), b.tile_f32(ti), acc_f, kt.m_ct, kt.k_ct, kt.n_ct);
                }
                match p {
                    Precision::Bf16 => {
                        let mut lane = 0usize; // 16-bit lanes of `out`
                        for_each_pretiled(kt.m_ct, kt.n_ct, r, t, |i, j| {
                            let bits = Bf16::from_f32(acc_f[i * kt.n_ct + j]).to_bits() as u32;
                            out[lane >> 1] |= bits << ((lane & 1) * 16);
                            lane += 1;
                        });
                    }
                    Precision::Bfp16 => {
                        // Narrow each accumulator row's 8-value groups to
                        // shared-exponent blocks and emit them padded, in
                        // pre-tiled (r × 1-block) stream order — the same
                        // encode the reference applies, so bits match.
                        let mut idx = 0usize; // block index into `out`
                        for_each_pretiled(kt.m_ct, kt.n_ct / BLOCK, r, 1, |i, jo| {
                            let at = i * kt.n_ct + jo * BLOCK;
                            let group: &[f32; BLOCK] =
                                acc_f[at..at + BLOCK].try_into().unwrap();
                            out[idx * BLOCK_WORDS..(idx + 1) * BLOCK_WORDS]
                                .copy_from_slice(&BfpBlock::encode(group).to_words());
                            idx += 1;
                        });
                    }
                    _ => unreachable!("f32 panels belong to the float precisions"),
                }
            }
            (PanelData::I8(_), PanelData::I8(_)) => {
                acc_i.fill(0); // zeroing kernel
                for ti in 0..k_tiles {
                    dense_mac_i32(a.tile_i8(ti), b.tile_i8(ti), acc_i, kt.m_ct, kt.k_ct, kt.n_ct);
                }
                let mut lane = 0usize; // ty_out-sized lanes of `out`
                match p {
                    Precision::I8I8 => for_each_pretiled(kt.m_ct, kt.n_ct, r, t, |i, j| {
                        let v = crate::dtype::sat_i8(acc_i[i * kt.n_ct + j]) as u8 as u32;
                        out[lane >> 2] |= v << ((lane & 3) * 8);
                        lane += 1;
                    }),
                    Precision::I8I16 => for_each_pretiled(kt.m_ct, kt.n_ct, r, t, |i, j| {
                        let v = crate::dtype::sat_i16(acc_i[i * kt.n_ct + j]) as u16 as u32;
                        out[lane >> 1] |= v << ((lane & 1) * 16);
                        lane += 1;
                    }),
                    Precision::I8I32 => for_each_pretiled(kt.m_ct, kt.n_ct, r, t, |i, j| {
                        out[lane] = acc_i[i * kt.n_ct + j] as u32;
                        lane += 1;
                    }),
                    Precision::Bf16 | Precision::Bfp16 | Precision::Fp32Split => {
                        unreachable!("float precisions use the f32 panels")
                    }
                }
            }
            _ => return Err(anyhow!("operand panels decoded at different precisions")),
        }
        Ok(())
    }
}

/// Packed cache of decoded tiles for one operand panel: `k_tiles` dense
/// row-major tiles (`m_ct × k_ct` for A, `k_ct × n_ct` for B) stored
/// back to back in one flat buffer.
struct PackedPanel {
    tile_len: usize,
    data: PanelData,
}

enum PanelData {
    I8(Vec<i8>),
    F32(Vec<f32>),
}

/// A mutable view of one dense tile inside a [`PackedPanel`].
enum TileMut<'a> {
    I8(&'a mut [i8]),
    F32(&'a mut [f32]),
}

impl PackedPanel {
    fn new(p: Precision, tile_len: usize, k_tiles: usize) -> PackedPanel {
        let data = match p {
            Precision::Bf16 | Precision::Bfp16 => PanelData::F32(vec![0.0; tile_len * k_tiles]),
            _ => PanelData::I8(vec![0; tile_len * k_tiles]),
        };
        PackedPanel { tile_len, data }
    }

    fn tile_mut(&mut self, ti: usize) -> TileMut<'_> {
        let r = ti * self.tile_len..(ti + 1) * self.tile_len;
        match &mut self.data {
            PanelData::I8(v) => TileMut::I8(&mut v[r]),
            PanelData::F32(v) => TileMut::F32(&mut v[r]),
        }
    }

    fn tile_i8(&self, ti: usize) -> &[i8] {
        match &self.data {
            PanelData::I8(v) => &v[ti * self.tile_len..(ti + 1) * self.tile_len],
            PanelData::F32(_) => unreachable!("precision fixed per executor"),
        }
    }

    fn tile_f32(&self, ti: usize) -> &[f32] {
        match &self.data {
            PanelData::F32(v) => &v[ti * self.tile_len..(ti + 1) * self.tile_len],
            PanelData::I8(_) => unreachable!("precision fixed per executor"),
        }
    }
}

/// Per-worker scratch, sized once from the design and the padded K — the
/// per-tile loop allocates nothing.
struct WorkerState {
    /// Flat streamed-panel words (large enough for an A or a B panel).
    stream: Vec<u32>,
    /// Packed A panels for the current tile row (one per array row).
    a_panels: Vec<PackedPanel>,
    /// Packed B panels for the current output tile (no-reuse mode only).
    b_panels: Vec<PackedPanel>,
    /// The column's narrowed, pre-tiled C tiles (`m_rows × tile_words`).
    column_c: Vec<u32>,
    /// L2 aggregation scratch for the output drain.
    drain_l2: Vec<u32>,
    acc_i: Vec<i32>,
    acc_f: Vec<f32>,
}

impl WorkerState {
    fn new(exec: &Executor, k_tiles: usize) -> WorkerState {
        let p = exec.cfg.precision;
        let kt = exec.cfg.kernel;
        let a_tw = exec.a_chain().tile_words();
        let b_tw = exec.b_tile_words();
        let ctw = exec.out_chain().tile_words();
        let (acc_i, acc_f) = match p {
            Precision::Bf16 | Precision::Bfp16 => (Vec::new(), vec![0.0; kt.m_ct * kt.n_ct]),
            _ => (vec![0; kt.m_ct * kt.n_ct], Vec::new()),
        };
        WorkerState {
            stream: vec![0; k_tiles * a_tw.max(b_tw)],
            a_panels: (0..exec.cfg.m_rows)
                .map(|_| PackedPanel::new(p, kt.m_ct * kt.k_ct, k_tiles))
                .collect(),
            b_panels: if exec.opts.pack_reuse {
                Vec::new()
            } else {
                (0..exec.cfg.n_cols)
                    .map(|_| PackedPanel::new(p, kt.k_ct * kt.n_ct, k_tiles))
                    .collect()
            },
            column_c: vec![0; exec.cfg.m_rows * ctw],
            drain_l2: Vec::new(),
            acc_i,
            acc_f,
        }
    }
}

/// Walk a pre-tiled row-major-B image (`s × t` micro-tiles) in source
/// order, emitting (dense `k·n_ct + j` index, source index) pairs —
/// division-free (§Perf optimization 3).
fn decode_b_blocks(k_ct: usize, n_ct: usize, s: usize, t: usize, f: &mut dyn FnMut(usize, usize)) {
    let mut src = 0;
    for ko in 0..k_ct / s {
        for jo in 0..n_ct / t {
            for ki in 0..s {
                let row = (ko * s + ki) * n_ct + jo * t;
                for w in 0..t {
                    f(row + w, src);
                    src += 1;
                }
            }
        }
    }
}

/// Walk a pre-tiled Bᵀ image (`t × s` micro-tiles of the transposed
/// panel) in source order; destination indices are transposed — this IS
/// the in-core shuffle.
fn decode_bt_blocks(k_ct: usize, n_ct: usize, s: usize, t: usize, f: &mut dyn FnMut(usize, usize)) {
    let mut src = 0;
    for jo in 0..n_ct / t {
        for ko in 0..k_ct / s {
            for ji in 0..t {
                let col = jo * t + ji;
                let k0 = ko * s;
                for ki in 0..s {
                    f((k0 + ki) * n_ct + col, src);
                    src += 1;
                }
            }
        }
    }
}

/// Visit (i, j) of an `m × n` tile in pre-tiled `r × t` stream order.
fn for_each_pretiled(m: usize, n: usize, r: usize, t: usize, mut f: impl FnMut(usize, usize)) {
    for mo in 0..m / r {
        for jo in 0..n / t {
            for mi in 0..r {
                for w in 0..t {
                    f(mo * r + mi, jo * t + w);
                }
            }
        }
    }
}

/// Decode one pre-tiled A tile into dense `m_ct × k_ct` i8 (division-free:
/// walk micro-tiles in source order — §Perf optimization 3).
fn decode_pretiled_i8(words: &[u32], m_ct: usize, k_ct: usize, r: usize, s: usize, out: &mut [i8]) {
    // Read bytes straight out of the word image (no intermediate Vec —
    // §Perf optimization 4).
    let byte = |i: usize| (words[i >> 2] >> ((i & 3) * 8)) as u8;
    let mut src = 0;
    for mo in 0..m_ct / r {
        for ko in 0..k_ct / s {
            for mi in 0..r {
                let base = (mo * r + mi) * k_ct + ko * s;
                for si in 0..s {
                    out[base + si] = byte(src) as i8;
                    src += 1;
                }
            }
        }
    }
}

fn decode_pretiled_bf16(
    words: &[u32],
    m_ct: usize,
    k_ct: usize,
    r: usize,
    s: usize,
    out: &mut [f32],
) {
    let half = |i: usize| (words[i >> 1] >> ((i & 1) * 16)) as u16;
    let mut src = 0;
    for mo in 0..m_ct / r {
        for ko in 0..k_ct / s {
            for mi in 0..r {
                let base = (mo * r + mi) * k_ct + ko * s;
                for si in 0..s {
                    out[base + si] = Bf16::from_bits(half(src)).to_f32();
                    src += 1;
                }
            }
        }
    }
}

/// Decode one pre-tiled bfp16 A tile (micro-tiles of `r` rows × 1 padded
/// block, source order `(mo, kb, mi)`) into dense `m_ct × k_ct` f32 —
/// the core-side pack: pad bytes are stripped here, where the kernel's
/// byte-granular vector shuffles live, which is what the word-granular
/// DMA chain cannot do (DESIGN.md §10).
fn decode_pretiled_bfp_a(words: &[u32], m_ct: usize, k_ct: usize, r: usize, out: &mut [f32]) {
    let mut src = 0;
    for mo in 0..m_ct / r {
        for kb in 0..k_ct / BLOCK {
            for mi in 0..r {
                let vals = BfpBlock::from_words(&words[src..src + BLOCK_WORDS]).decode();
                let base = (mo * r + mi) * k_ct + kb * BLOCK;
                out[base..base + BLOCK].copy_from_slice(&vals);
                src += BLOCK_WORDS;
            }
        }
    }
}

/// Decode one pre-tiled bfp16 Bᵀ tile (micro-tiles of `t` Bᵀ rows × 1
/// block, source order `(jo, kb, ji)`) into dense `k_ct × n_ct` f32 —
/// the block-wise in-core shuffle for column-major B.
fn decode_pretiled_bfp_bt(words: &[u32], k_ct: usize, n_ct: usize, t: usize, out: &mut [f32]) {
    let mut src = 0;
    for jo in 0..n_ct / t {
        for kb in 0..k_ct / BLOCK {
            for ji in 0..t {
                let vals = BfpBlock::from_words(&words[src..src + BLOCK_WORDS]).decode();
                let col = jo * t + ji;
                for (kk, &v) in vals.iter().enumerate() {
                    out[(kb * BLOCK + kk) * n_ct + col] = v;
                }
                src += BLOCK_WORDS;
            }
        }
    }
}

/// Dense micro-kernel: `acc += a @ b` (int32 accumulate — the MAC array).
fn dense_mac_i32(a: &[i8], b: &[i8], acc: &mut [i32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut acc[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let av = av as i32;
            if av == 0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (c, &bv) in crow.iter_mut().zip(brow) {
                *c += av * bv as i32;
            }
        }
    }
}

/// Dense micro-kernel, f32 accumulators (the bf16 datapath).
fn dense_mac_f32(a: &[f32], b: &[f32], acc: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut acc[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (c, &bv) in crow.iter_mut().zip(brow) {
                *c += av * bv;
            }
        }
    }
}

/// Zero-pad a matrix image to `rows × cols` (same layout/elem size).
pub fn pad_matrix(src: &Matrix, rows: usize, cols: usize) -> Result<Matrix> {
    if src.rows == rows && src.cols == cols {
        return Ok(src.clone());
    }
    let mut out = Matrix::zeroed(rows, cols, src.elem_bytes, src.layout)?;
    // Copy storage row by storage row; when both images' rows are
    // word-aligned (the common case — Matrix enforces word-aligned
    // storage rows), this is a straight word memcpy per row.
    let src_row_w = src.row_words();
    let dst_row_w = out.row_words();
    for sr in 0..src.n_storage_rows() {
        let s0 = sr * src_row_w;
        let d0 = sr * dst_row_w;
        out.data[d0..d0 + src_row_w].copy_from_slice(&src.data[s0..s0 + src_row_w]);
    }
    Ok(out)
}

/// Crop a row-major matrix image to `rows × cols` (word copies per row —
/// both images' rows start word-aligned at column 0).
fn crop_matrix(src: &Matrix, rows: usize, cols: usize, elem_bytes: usize) -> Result<Matrix> {
    if src.rows == rows && src.cols == cols {
        return Ok(src.clone());
    }
    let mut out = Matrix::zeroed(rows, cols, elem_bytes, Layout::RowMajor)?;
    let src_row_w = src.row_words();
    let dst_row_w = out.row_words();
    for i in 0..rows {
        let s0 = i * src_row_w;
        let d0 = i * dst_row_w;
        out.data[d0..d0 + dst_row_w].copy_from_slice(&src.data[s0..s0 + dst_row_w]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Generation;
    use crate::gemm::refimpl;
    use crate::tiling::TilingConfig;
    use crate::util::prop::prop_check;

    /// Scaled-down configs (same structure, small tiles) so the functional
    /// path stays fast.
    fn tiny_cfg(gen: Generation, p: Precision, b_layout: Layout) -> TilingConfig {
        let (_, _, t) = p.micro_tile();
        let n_ct = 2 * t.max(4);
        let spec = gen.spec();
        TilingConfig::new(gen, p, 8, 16, n_ct, 32, spec.array_rows, spec.shim_cols, b_layout)
            .unwrap()
    }

    #[allow(clippy::too_many_arguments)]
    fn run_case_opts(
        gen: Generation,
        p: Precision,
        layout: Layout,
        opts: ExecOptions,
        m: usize,
        k: usize,
        n: usize,
        seed: u64,
    ) {
        let cfg = tiny_cfg(gen, p, layout);
        let mut a = refimpl::input_matrix(m, k, p, Layout::RowMajor).unwrap();
        let mut b = refimpl::input_matrix(k, n, p, layout).unwrap();
        refimpl::fill_random(&mut a, p, seed);
        refimpl::fill_random(&mut b, p, seed + 1);
        let got = Executor::with_options(cfg, opts).execute(&a, &b).unwrap();
        let want = refimpl::ref_gemm(&a, &b, p).unwrap();
        assert!(
            refimpl::matrices_equal(&got, &want, p),
            "{gen}/{p}/{layout:?}/{opts:?} {m}x{k}x{n} mismatch"
        );
    }

    #[allow(clippy::too_many_arguments)]
    fn run_case(
        gen: Generation,
        p: Precision,
        layout: Layout,
        fidelity: Fidelity,
        m: usize,
        k: usize,
        n: usize,
        seed: u64,
    ) {
        run_case_opts(
            gen,
            p,
            layout,
            ExecOptions { fidelity, ..Default::default() },
            m,
            k,
            n,
            seed,
        );
    }

    #[test]
    fn all_precisions_native_size_bdchain() {
        for gen in Generation::ALL {
            for p in Precision::ALL {
                for layout in [Layout::ColMajor, Layout::RowMajor] {
                    let cfg = tiny_cfg(gen, p, layout);
                    let (nm, nk, nn) = cfg.native();
                    run_case(gen, p, layout, Fidelity::BdChain, nm, nk, nn, 7);
                }
            }
        }
    }

    #[test]
    fn bfp16_native_size_both_fidelities() {
        // The native block-FP path: padded 3-word blocks ride the same
        // Fig.-4 chains (BdChain) and the algebraic oracle (Direct),
        // bit-exact against the reference on the native grid and on a
        // ragged-m multi-tile grid.
        let p = Precision::Bfp16;
        for gen in Generation::ALL {
            let cfg = tiny_cfg(gen, p, Layout::ColMajor);
            let (nm, nk, nn) = cfg.native();
            run_case(gen, p, Layout::ColMajor, Fidelity::BdChain, nm, nk, nn, 31);
            run_case(gen, p, Layout::ColMajor, Fidelity::Direct, 2 * nm - 3, 2 * nk, 2 * nn, 37);
        }
    }

    #[test]
    fn bfp16_rejects_row_major_and_ragged_blocks() {
        // Row-major B scatters shared-exponent blocks across storage
        // rows — the design layer refuses to build such a config at all.
        let spec = Generation::Xdna2.spec();
        assert!(TilingConfig::new(
            Generation::Xdna2,
            Precision::Bfp16,
            8,
            16,
            16,
            32,
            spec.array_rows,
            spec.shim_cols,
            Layout::RowMajor,
        )
        .is_err());
        // And block images refuse non-block-aligned K/N.
        assert!(Matrix::zeroed_bfp16(8, 20, Layout::RowMajor).is_err());
        assert!(Matrix::zeroed_bfp16(20, 8, Layout::ColMajor).is_err());
    }

    #[test]
    fn multi_tile_multi_panel() {
        // 2x2 native tiles, 3 K panels — exercises the outer tiling level.
        let cfg = tiny_cfg(Generation::Xdna, Precision::I8I16, Layout::ColMajor);
        let (nm, nk, nn) = cfg.native();
        run_case(
            Generation::Xdna,
            Precision::I8I16,
            Layout::ColMajor,
            Fidelity::Direct,
            2 * nm,
            3 * nk,
            2 * nn,
            11,
        );
    }

    #[test]
    fn threaded_fan_out_matches_reference() {
        // The scoped-thread fan-out on a grid taller than the worker
        // count, both layouts.
        for layout in [Layout::ColMajor, Layout::RowMajor] {
            let cfg = tiny_cfg(Generation::Xdna, Precision::I8I8, layout);
            let (nm, nk, nn) = cfg.native();
            for threads in [2, 3, 8] {
                run_case_opts(
                    Generation::Xdna,
                    Precision::I8I8,
                    layout,
                    ExecOptions { threads, ..Default::default() },
                    3 * nm - 2,
                    2 * nk,
                    2 * nn,
                    17,
                );
            }
        }
    }

    #[test]
    fn no_reuse_ablation_matches_reference() {
        // pack_reuse=false (the re-streaming baseline) stays correct —
        // it is the hotpath bench's comparison point.
        let cfg = tiny_cfg(Generation::Xdna, Precision::I8I16, Layout::ColMajor);
        let (nm, nk, nn) = cfg.native();
        run_case_opts(
            Generation::Xdna,
            Precision::I8I16,
            Layout::ColMajor,
            ExecOptions { pack_reuse: false, ..Default::default() },
            2 * nm,
            2 * nk,
            2 * nn,
            23,
        );
    }

    #[test]
    fn ragged_sizes_are_padded_correctly() {
        // Non-aligned sizes round up to the native grid; results must
        // still match the reference exactly on the unpadded region.
        let cfg = tiny_cfg(Generation::Xdna, Precision::I8I8, Layout::ColMajor);
        let (nm, nk, nn) = cfg.native();
        // m is free; k and n stay word-aligned (DMA-visible DRAM images).
        run_case(
            Generation::Xdna,
            Precision::I8I8,
            Layout::ColMajor,
            Fidelity::Direct,
            nm - 3,
            nk + 4,
            nn - 4,
            13,
        );
    }

    #[test]
    fn bd_chain_equals_direct() {
        prop_check("BdChain ≡ Direct fidelity", 8, |rng| {
            let gens = [Generation::Xdna, Generation::Xdna2];
            let precs = Precision::ALL;
            let layouts = [Layout::RowMajor, Layout::ColMajor];
            let gen = *rng.pick(&gens);
            let p = *rng.pick(&precs);
            let layout = *rng.pick(&layouts);
            let cfg = tiny_cfg(gen, p, layout);
            let (nm, nk, nn) = cfg.native();
            // m is free; k and n move in word-aligned (4-element) steps.
            let m = nm - rng.below(4);
            let k = nk + 4 * rng.below(2);
            let n = nn - 4 * rng.below(2);
            let mut a = refimpl::input_matrix(m, k, p, Layout::RowMajor).unwrap();
            let mut b = refimpl::input_matrix(k, n, p, layout).unwrap();
            refimpl::fill_random(&mut a, p, rng.next_u64());
            refimpl::fill_random(&mut b, p, rng.next_u64());
            let via_bd = Executor::new(cfg, Fidelity::BdChain).execute(&a, &b).unwrap();
            let direct = Executor::new(cfg, Fidelity::Direct).execute(&a, &b).unwrap();
            assert!(refimpl::matrices_equal(&via_bd, &direct, p));
        });
    }

    #[test]
    fn saturating_inputs_end_to_end() {
        // Extreme int8 inputs saturate through the full pipeline exactly
        // like the reference.
        run_case(
            Generation::Xdna2,
            Precision::I8I8,
            Layout::ColMajor,
            Fidelity::Direct,
            16,
            64,
            16,
            99,
        );
    }

    #[test]
    fn chain_matches_folded_reference() {
        // 3-op int8 chain: the staged C of each op is the next op's A —
        // bit-exact against folding the reference GEMM the same way.
        let cfg = tiny_cfg(Generation::Xdna2, Precision::I8I8, Layout::ColMajor);
        let (m, dims) = (16, [32usize, 16, 24, 8]);
        let mut a = Matrix::zeroed(m, dims[0], 1, Layout::RowMajor).unwrap();
        refimpl::fill_random(&mut a, Precision::I8I8, 21);
        let weights: Vec<Matrix> = (0..3)
            .map(|i| {
                let mut b = Matrix::zeroed(dims[i], dims[i + 1], 1, Layout::ColMajor).unwrap();
                refimpl::fill_random(&mut b, Precision::I8I8, 100 + i as u64);
                b
            })
            .collect();
        let got = Executor::new(cfg, Fidelity::Direct).execute_chain(&a, &weights).unwrap();
        let mut want = a.clone();
        for b in &weights {
            want = refimpl::ref_gemm(&want, b, Precision::I8I8).unwrap();
        }
        assert_eq!((got.rows, got.cols), (m, dims[3]));
        assert!(refimpl::matrices_equal(&got, &want, Precision::I8I8));
    }

    #[test]
    fn chain_rejects_widening_precisions_beyond_one_op() {
        let cfg = tiny_cfg(Generation::Xdna, Precision::I8I16, Layout::ColMajor);
        let mut a = Matrix::zeroed(8, 16, 1, Layout::RowMajor).unwrap();
        let mut b = Matrix::zeroed(16, 16, 1, Layout::ColMajor).unwrap();
        refimpl::fill_random(&mut a, Precision::I8I16, 1);
        refimpl::fill_random(&mut b, Precision::I8I16, 2);
        let exec = Executor::new(cfg, Fidelity::Direct);
        // One op is fine (no chained consumption)...
        assert!(exec.execute_chain(&a, std::slice::from_ref(&b)).is_ok());
        // ...but an int16 C cannot feed an int8-input op.
        assert!(exec.execute_chain(&a, &[b.clone(), b.clone()]).is_err());
        assert!(exec.execute_chain(&a, &[]).is_err());
    }

    #[test]
    fn rejects_mismatched_layout() {
        let cfg = tiny_cfg(Generation::Xdna, Precision::I8I8, Layout::ColMajor);
        let a = Matrix::zeroed(8, 16, 1, Layout::RowMajor).unwrap();
        let b = Matrix::zeroed(16, 16, 1, Layout::RowMajor).unwrap(); // wrong
        assert!(Executor::new(cfg, Fidelity::Direct).execute(&a, &b).is_err());
    }
}
