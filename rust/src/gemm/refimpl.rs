//! Reference GEMM over DRAM matrix images — the Rust mirror of
//! `python/compile/kernels/ref.py`.
//!
//! Semantics per precision pair (Sec. 5.1):
//! * int8 inputs accumulate in int32; outputs narrow with saturation to
//!   int8 / int16 / int32 ("precision reduction");
//! * bf16 inputs accumulate in f32; outputs round-to-nearest-even to bf16.
//!
//! Cross-checked against the pytest-validated oracle through
//! `artifacts/golden.json` (`rust/tests/golden.rs`).

use anyhow::{ensure, Result};

use crate::dtype::{sat_i16, sat_i8, Bf16, Layout, Precision};
use crate::dtype_bfp16::{BfpBlock, BLOCK};
use crate::mem::Matrix;

/// Allocate the output image for an `m × n` result (`n` in elements;
/// bfp16 results are block images, so `n` must cover whole blocks).
pub fn out_matrix(m: usize, n: usize, p: Precision) -> Result<Matrix> {
    match p {
        Precision::Bfp16 => Matrix::zeroed_bfp16(m, n, Layout::RowMajor),
        _ => Matrix::zeroed(m, n, p.ty_out(), Layout::RowMajor),
    }
}

/// Allocate an input operand image of `rows × cols` logical elements at
/// the precision's storage format — the one constructor every caller
/// (tests, harness, coordinator) should use, since bfp16 operands are
/// padded-block images rather than `ty_in`-byte element grids.
pub fn input_matrix(rows: usize, cols: usize, p: Precision, layout: Layout) -> Result<Matrix> {
    match p {
        Precision::Bfp16 => Matrix::zeroed_bfp16(rows, cols, layout),
        _ => Matrix::zeroed(rows, cols, p.ty_in(), layout),
    }
}

/// Logical `(rows, cols)` of an operand image (block images scale their
/// blocked axis back up by 8).
pub fn logical_dims(m: &Matrix) -> (usize, usize) {
    if m.is_bfp16() {
        match m.layout {
            Layout::RowMajor => (m.rows, m.cols * BLOCK),
            Layout::ColMajor => (m.rows * BLOCK, m.cols),
        }
    } else {
        (m.rows, m.cols)
    }
}

/// Reference GEMM: `C = narrow(A @ B)`. `a` must be row-major; `b` may be
/// row- or column-major (the packing hides the layout).
///
/// Blocked + packed: both operands are unpacked once into dense
/// row-major panels ([`Matrix::packed_i8`] / [`Matrix::packed_f32`]) and
/// the kernel runs row-slice inner loops — no per-element accessor calls
/// on the O(m·k·n) path (this function dominates differential-test wall
/// time). The reduction order per output element is ascending `k`,
/// identical to the textbook per-element definition, so results are
/// bit-identical to it for every precision (bf16 included).
pub fn ref_gemm(a: &Matrix, b: &Matrix, p: Precision) -> Result<Matrix> {
    ensure!(a.layout == Layout::RowMajor, "A must be row-major");
    let (m, k) = logical_dims(a);
    let (bk, n) = logical_dims(b);
    ensure!(k == bk, "shape mismatch: {m}x{k} @ {bk}x{n}");
    // The logical Ozaki-split precision: f32 operand images through the
    // three bf16 limb GEMMs + f32 rejoin (a row-major 4-byte C image,
    // matching `out_matrix`'s allocation for this precision).
    if p == Precision::Fp32Split {
        return crate::dtype_split::split_gemm(a, b);
    }
    let mut c = out_matrix(m, n, p)?;
    match p {
        Precision::Bfp16 => {
            ensure!(b.layout == Layout::ColMajor, "bfp16 B must be column-major");
            ensure!(a.is_bfp16() && b.is_bfp16(), "bfp16 operands must be block images");
            // Decode both operands to dense f32 (exact — mantissa · 2^e),
            // accumulate ascending k in f32, then encode each output
            // row's 8-value groups back to blocks. This is the same
            // arithmetic, in the same order, as the tiled executor's
            // core-side pack + MAC + narrow, so results are bit-exact
            // against it for every thread count.
            let ap = packed_f32_bfp(a);
            let bp = packed_f32_bfp(b);
            let mut acc = vec![0f32; n];
            for i in 0..m {
                acc.fill(0.0);
                let arow = &ap[i * k..(i + 1) * k];
                for (kk, &av) in arow.iter().enumerate() {
                    let brow = &bp[kk * n..(kk + 1) * n];
                    for (c, &bv) in acc.iter_mut().zip(brow) {
                        *c += av * bv;
                    }
                }
                for (g, group) in acc.chunks_exact(BLOCK).enumerate() {
                    c.set_bfp_block(i, g, BfpBlock::encode(group.try_into().unwrap()));
                }
            }
        }
        Precision::Bf16 => {
            let ap = a.packed_f32();
            let bp = b.packed_f32();
            let mut acc = vec![0f32; n];
            for i in 0..m {
                acc.fill(0.0);
                let arow = &ap[i * k..(i + 1) * k];
                for (kk, &av) in arow.iter().enumerate() {
                    let brow = &bp[kk * n..(kk + 1) * n];
                    for (c, &bv) in acc.iter_mut().zip(brow) {
                        *c += av * bv;
                    }
                }
                for (j, &v) in acc.iter().enumerate() {
                    c.set_bf16(i, j, Bf16::from_f32(v));
                }
            }
        }
        _ => {
            let ap = a.packed_i8();
            let bp = b.packed_i8();
            let mut acc = vec![0i32; n];
            for i in 0..m {
                acc.fill(0);
                let arow = &ap[i * k..(i + 1) * k];
                for (kk, &av) in arow.iter().enumerate() {
                    let av = av as i32;
                    if av == 0 {
                        continue; // exact: integer accumulation
                    }
                    let brow = &bp[kk * n..(kk + 1) * n];
                    for (c, &bv) in acc.iter_mut().zip(brow) {
                        *c += av * bv as i32;
                    }
                }
                for (j, &v) in acc.iter().enumerate() {
                    store_narrowed(&mut c, i, j, v, p);
                }
            }
        }
    }
    Ok(c)
}

/// Narrow-and-store one accumulator value (the AIE `srs` step).
pub fn store_narrowed(c: &mut Matrix, i: usize, j: usize, acc: i32, p: Precision) {
    match p {
        Precision::I8I8 => c.set_i8(i, j, sat_i8(acc)),
        Precision::I8I16 => c.set_i16(i, j, sat_i16(acc)),
        Precision::I8I32 => c.set_i32(i, j, acc),
        Precision::Bf16 | Precision::Bfp16 | Precision::Fp32Split => {
            unreachable!("float precisions use the f32 path")
        }
    }
}

/// Dense logical-row-major f32 decode of a bfp16 block image (either
/// layout) — the reference GEMM's core-side pack.
pub fn packed_f32_bfp(m: &Matrix) -> Vec<f32> {
    debug_assert!(m.is_bfp16());
    let (rows, cols) = logical_dims(m);
    let mut out = vec![0f32; rows * cols];
    match m.layout {
        Layout::RowMajor => {
            for i in 0..rows {
                for bj in 0..cols / BLOCK {
                    let vals = m.get_bfp_block(i, bj).decode();
                    out[i * cols + bj * BLOCK..i * cols + (bj + 1) * BLOCK]
                        .copy_from_slice(&vals);
                }
            }
        }
        Layout::ColMajor => {
            for j in 0..cols {
                for bi in 0..rows / BLOCK {
                    let vals = m.get_bfp_block(bi, j).decode();
                    for (kk, &v) in vals.iter().enumerate() {
                        out[(bi * BLOCK + kk) * cols + j] = v;
                    }
                }
            }
        }
    }
    out
}

/// Fill a matrix with deterministic pseudo-random inputs appropriate for
/// the precision (full int8 range / unit normals for bf16 / encoded
/// unit-normal blocks for bfp16).
pub fn fill_random(mat: &mut Matrix, p: Precision, seed: u64) {
    let mut rng = crate::util::rng::Rng::seeded(seed);
    if p == Precision::Bfp16 {
        // The image is a block-unit grid; fill every cell with an
        // encoded block of normals (realistic shared-exponent content).
        for i in 0..mat.rows {
            for j in 0..mat.cols {
                let mut vals = [0f32; BLOCK];
                for v in vals.iter_mut() {
                    *v = rng.normal() as f32;
                }
                mat.set_bfp_block(i, j, BfpBlock::encode(&vals));
            }
        }
        return;
    }
    for i in 0..mat.rows {
        for j in 0..mat.cols {
            match p {
                Precision::Bf16 => mat.set_bf16(i, j, Bf16::from_f32(rng.normal() as f32)),
                // fp32_split operands are dense f32 images; full-precision
                // unit normals exercise the lo limbs the split recovers.
                Precision::Fp32Split => mat.set_f32(i, j, rng.normal() as f32),
                _ => mat.set_i8(i, j, rng.i8()),
            }
        }
    }
}

/// Exact equality of two matrices of the same precision/shape (bfp16
/// compares block contents: exponent + mantissas, pad bytes ignored).
pub fn matrices_equal(x: &Matrix, y: &Matrix, p: Precision) -> bool {
    if x.rows != y.rows || x.cols != y.cols {
        return false;
    }
    for i in 0..x.rows {
        for j in 0..x.cols {
            let same = match p {
                Precision::I8I8 => x.get_i8(i, j) == y.get_i8(i, j),
                Precision::I8I16 => x.get_i16(i, j) == y.get_i16(i, j),
                Precision::I8I32 => x.get_i32(i, j) == y.get_i32(i, j),
                Precision::Bf16 => x.get_bf16(i, j).to_bits() == y.get_bf16(i, j).to_bits(),
                Precision::Bfp16 => x.get_bfp_block(i, j) == y.get_bfp_block(i, j),
                Precision::Fp32Split => x.get_f32(i, j).to_bits() == y.get_f32(i, j).to_bits(),
            };
            if !same {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(rows: usize, cols: usize, p: Precision, layout: Layout, vals: &[i8]) -> Matrix {
        let mut m = Matrix::zeroed(rows, cols, p.ty_in(), layout).unwrap();
        for i in 0..rows {
            for j in 0..cols {
                m.set_i8(i, j, vals[i * cols + j]);
            }
        }
        m
    }

    #[test]
    fn tiny_known_product() {
        // 2x4 @ 4x4, checked against a hand computation (word-aligned
        // shapes — the DRAM images are DMA-visible).
        let a = mk(2, 4, Precision::I8I32, Layout::RowMajor, &[1, 2, 3, 4, 5, 6, 7, 8]);
        let b = mk(
            4,
            4,
            Precision::I8I32,
            Layout::RowMajor,
            &[1, 0, 2, 0, 0, 1, 0, 2, 1, 1, 0, 0, 2, 0, 1, 1],
        );
        let c = ref_gemm(&a, &b, Precision::I8I32).unwrap();
        // row0: [1+3+8, 2+3, 2+4, 4+4] = [12, 5, 6, 8]
        assert_eq!(
            [c.get_i32(0, 0), c.get_i32(0, 1), c.get_i32(0, 2), c.get_i32(0, 3)],
            [12, 5, 6, 8]
        );
        // row1: [5+7+16, 6+7, 10+8, 12+8] = [28, 13, 18, 20]
        assert_eq!(
            [c.get_i32(1, 0), c.get_i32(1, 1), c.get_i32(1, 2), c.get_i32(1, 3)],
            [28, 13, 18, 20]
        );
    }

    #[test]
    fn col_major_b_gives_same_result() {
        let vals: Vec<i8> = (1..=16).collect();
        let a = mk(4, 4, Precision::I8I16, Layout::RowMajor, &vals);
        let b_row = mk(4, 4, Precision::I8I16, Layout::RowMajor, &vals);
        // Same logical B stored column-major.
        let mut b_col = Matrix::zeroed(4, 4, 1, Layout::ColMajor).unwrap();
        for i in 0..4 {
            for j in 0..4 {
                b_col.set_i8(i, j, b_row.get_i8(i, j));
            }
        }
        let c1 = ref_gemm(&a, &b_row, Precision::I8I16).unwrap();
        let c2 = ref_gemm(&a, &b_col, Precision::I8I16).unwrap();
        assert!(matrices_equal(&c1, &c2, Precision::I8I16));
    }

    #[test]
    fn saturation_engages() {
        // 127*127*4 = 64516 >> 127: int8 output clamps.
        let a = mk(1, 4, Precision::I8I8, Layout::RowMajor, &[127; 4]);
        let b = mk(4, 4, Precision::I8I8, Layout::RowMajor, &[127; 16]);
        let c = ref_gemm(&a, &b, Precision::I8I8).unwrap();
        assert_eq!(c.get_i8(0, 0), 127);
        let c16 = ref_gemm(&a, &b, Precision::I8I16).unwrap();
        assert_eq!(c16.get_i16(0, 0), 32767);
        let c32 = ref_gemm(&a, &b, Precision::I8I32).unwrap();
        assert_eq!(c32.get_i32(0, 0), 64516);
    }

    #[test]
    fn blocked_bf16_matches_per_element_definition_bitwise() {
        // The packed row-slice kernel keeps ascending-k reduction order,
        // so it is bit-identical to the textbook triple loop.
        let (m, k, n) = (4usize, 8usize, 4usize);
        let mut a = Matrix::zeroed(m, k, 2, Layout::RowMajor).unwrap();
        let mut b = Matrix::zeroed(k, n, 2, Layout::ColMajor).unwrap();
        fill_random(&mut a, Precision::Bf16, 5);
        fill_random(&mut b, Precision::Bf16, 6);
        let c = ref_gemm(&a, &b, Precision::Bf16).unwrap();
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += a.get_bf16(i, kk).to_f32() * b.get_bf16(kk, j).to_f32();
                }
                assert_eq!(
                    c.get_bf16(i, j).to_bits(),
                    Bf16::from_f32(acc).to_bits(),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn bf16_accumulates_in_f32() {
        let mut a = Matrix::zeroed(1, 4, 2, Layout::RowMajor).unwrap();
        let mut b = Matrix::zeroed(4, 4, 2, Layout::RowMajor).unwrap();
        for kk in 0..4 {
            a.set_bf16(0, kk, Bf16::from_f32(0.5));
            b.set_bf16(kk, 0, Bf16::from_f32(2.0));
        }
        let c = ref_gemm(&a, &b, Precision::Bf16).unwrap();
        assert_eq!(c.get_bf16(0, 0).to_f32(), 4.0);
        assert_eq!(c.get_bf16(0, 1).to_f32(), 0.0);
    }
}
