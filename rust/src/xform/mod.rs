//! On-the-fly tensor transformations (Sec. 4.3, Fig. 4).
//!
//! The single-core kernels expect *pre-tiled* operands: `r × s` (A) /
//! `s × t` (B) / `r × t` (C) micro-tiles, tiles row-major, elements within
//! a tile row-major. Matrices in DRAM stay in regular order; the DMA
//! chain re-tiles in flight:
//!
//! ```text
//!  A (row-major DRAM)
//!   └─ Shim MM2S, 3D  (m_ct, k_mt, K)      → m_ct × k_mt tiles
//!       └─ MemTile S2MM, 3D (m_ct, k_ct, k_mt) → m_ct × k_ct tiles in L2
//!           └─ MemTile MM2S, 4D (s, m_ct, k_ct, k_mt) → m_ct × s chunks
//!               └─ CompTile S2MM, 3D (r·s, m_ct, k_ct) → pre-tiled L1
//! ```
//!
//! The MemTile/CompTile split is the paper's workaround for the 5-parameter
//! transform (r, s, m_ct, k_ct, k_mt) exceeding the MemTile's 4D address
//! generator: emitting `m_ct × s` chunks *linearizes* each `r × s` tile —
//! `r` consecutive rows of an `s`-chunk land contiguously — so the CompTile
//! can finish the job in 3D.
//!
//! B column-major runs the same chain on the transposed image (with the
//! in-core shuffle handling the sub-32-bit element swizzle — see
//! `python/compile/kernels/transpose.py`); B row-major needs a single 4D
//! MemTile transform (s, t, k_ct, n_ct); C needs a single 4D de-tiling
//! (r, t, m_ct, n_ct) plus the aggregation described in Sec. 4.2.2.
//!
//! Everything here is *functional*: BDs gather/scatter real words, and
//! tests prove chain-equals-direct-pre-tiling for every parameter set.

use anyhow::{ensure, Result};

use crate::dma::{words, Bd, Dim, TileKind};

/// Parameters of the input chain for one row-panel operand (A, or Bᵀ when
/// B is column-major).
///
/// `rows` is `m_ct` for A / `n_ct` for Bᵀ; `micro_r`/`micro_s` are the
/// micro-tile extents along (rows, K) — `(r, s)` for A, `(t, s)` for Bᵀ.
#[derive(Clone, Copy, Debug)]
pub struct InputChain {
    pub rows: usize,
    pub micro_r: usize,
    pub micro_s: usize,
    pub k_ct: usize,
    pub k_mt: usize,
    pub elem_bytes: usize,
}

impl InputChain {
    pub fn validate(&self, k_total: usize) -> Result<()> {
        ensure!(self.rows % self.micro_r == 0, "rows % r != 0");
        ensure!(self.k_ct % self.micro_s == 0, "k_ct % s != 0");
        ensure!(self.k_mt % self.k_ct == 0, "k_mt % k_ct != 0");
        ensure!(k_total % self.k_mt == 0, "K % k_mt != 0");
        words(self.micro_s, self.elem_bytes)?; // s must be word-aligned
        Ok(())
    }

    fn s_w(&self) -> usize {
        self.micro_s * self.elem_bytes / 4
    }

    fn k_ct_w(&self) -> usize {
        self.k_ct * self.elem_bytes / 4
    }

    fn k_mt_w(&self) -> usize {
        self.k_mt * self.elem_bytes / 4
    }

    /// Words in one `rows × k_ct` CompTile tile.
    pub fn tile_words(&self) -> usize {
        self.rows * self.k_ct_w()
    }

    /// Words in one `rows × k_mt` MemTile buffer.
    pub fn l2_words(&self) -> usize {
        self.rows * self.k_mt_w()
    }

    /// Shim MM2S (3D, params m_ct/k_mt/K): read a `rows × k_total` panel
    /// starting at storage row `row0` of a row-major image with row stride
    /// `ld_w` words, emitting it as consecutive `rows × k_mt` tiles.
    pub fn shim_mm2s(&self, row0: usize, ld_w: usize, k_total: usize) -> Result<Bd> {
        let k_tiles = k_total / self.k_mt;
        Bd::new(
            TileKind::ShimTile,
            row0 * ld_w,
            vec![
                Dim::new(k_tiles, self.k_mt_w() as isize),
                Dim::new(self.rows, ld_w as isize),
                Dim::new(self.k_mt_w(), 1),
            ],
        )
    }

    /// MemTile S2MM (3D, params m_ct/k_ct/k_mt): scatter one incoming
    /// `rows × k_mt` tile (row-major stream) into L2 as consecutive
    /// `rows × k_ct` row-major tiles.
    pub fn memtile_s2mm(&self, base: usize) -> Result<Bd> {
        Bd::new(
            TileKind::MemTile,
            base,
            vec![
                Dim::new(self.rows, self.k_ct_w() as isize),
                Dim::new(self.k_mt / self.k_ct, (self.rows * self.k_ct_w()) as isize),
                Dim::new(self.k_ct_w(), 1),
            ],
        )
    }

    /// MemTile MM2S (4D, params s/m_ct/k_ct/k_mt): emit the L2 buffer as
    /// `rows × s` chunks — the address-linearization step.
    pub fn memtile_mm2s(&self, base: usize) -> Result<Bd> {
        Bd::new(
            TileKind::MemTile,
            base,
            vec![
                Dim::new(self.k_mt / self.k_ct, (self.rows * self.k_ct_w()) as isize),
                Dim::new(self.k_ct / self.micro_s, self.s_w() as isize),
                Dim::new(self.rows, self.k_ct_w() as isize),
                Dim::new(self.s_w(), 1),
            ],
        )
    }

    /// CompTile S2MM (3D, effective params r·s/m_ct/k_ct): scatter one
    /// incoming `rows × k_ct` tile (arriving as `rows × s` chunks) into
    /// the pre-tiled L1 layout.
    pub fn comptile_s2mm(&self, base: usize) -> Result<Bd> {
        let rs_w = self.micro_r * self.s_w();
        let tiles_per_row = self.k_ct / self.micro_s;
        Bd::new(
            TileKind::CompTile,
            base,
            vec![
                Dim::new(tiles_per_row, rs_w as isize),
                Dim::new(self.rows / self.micro_r, (tiles_per_row * rs_w) as isize),
                Dim::new(rs_w, 1),
            ],
        )
    }

    /// Run the full chain: DRAM panel → per-CompTile-tile L1 images.
    ///
    /// Returns `K/k_ct` pre-tiled tiles of `tile_words()` each — what the
    /// core consumes in reduction order.
    pub fn stream_panel(
        &self,
        dram: &[u32],
        row0: usize,
        ld_w: usize,
        k_total: usize,
    ) -> Result<Vec<Vec<u32>>> {
        let tw = self.tile_words();
        let mut flat = vec![0u32; k_total / self.k_ct * tw];
        self.stream_panel_into(dram, row0, ld_w, k_total, &mut flat)?;
        Ok(flat.chunks(tw).map(<[u32]>::to_vec).collect())
    }

    /// [`Self::stream_panel`] into a caller-owned flat buffer
    /// (`(K/k_ct) · tile_words()` words, tiles back to back) with one
    /// reused L2 scratch — the allocation-free form the packed executor
    /// drives per panel.
    pub fn stream_panel_into(
        &self,
        dram: &[u32],
        row0: usize,
        ld_w: usize,
        k_total: usize,
        out: &mut [u32],
    ) -> Result<()> {
        self.validate(k_total)?;
        let tw = self.tile_words();
        ensure!(out.len() == k_total / self.k_ct * tw, "flat tile buffer mis-sized");
        let shim = self.shim_mm2s(row0, ld_w, k_total)?;
        let stream = shim.gather(dram)?;

        let l2_words = self.l2_words();
        let tiles_per_mt = self.k_mt / self.k_ct;
        let mut l2 = vec![0u32; l2_words];
        for (mi, mt) in stream.chunks(l2_words).enumerate() {
            // Hop 2: into L2 (the scatter covers every word, so the
            // scratch is safely reused across k_mt tiles).
            self.memtile_s2mm(0)?.scatter(&mut l2, mt)?;
            // Hop 3: L2 → stream of m_ct × s chunks.
            let chunks = self.memtile_mm2s(0)?.gather(&l2)?;
            // Hop 4: per k_ct tile into its pre-tiled L1 slot.
            for (ci, ct) in chunks.chunks(tw).enumerate() {
                let ti = mi * tiles_per_mt + ci;
                self.comptile_s2mm(0)?.scatter(&mut out[ti * tw..(ti + 1) * tw], ct)?;
            }
        }
        Ok(())
    }
}

/// Direct pre-tiling oracle: extract the `rows × k_ct` tile at
/// `(row0, k0)` from a row-major word image and lay it out pre-tiled
/// (micro-tiles row-major, elements within a micro-tile row-major).
/// Operates at word granularity like the DMAs.
pub fn pretile_oracle(
    dram: &[u32],
    ld_w: usize,
    row0: usize,
    k0_w: usize,
    chain: &InputChain,
) -> Vec<u32> {
    let mut out = vec![0u32; chain.tile_words()];
    pretile_oracle_into(dram, ld_w, row0, k0_w, chain, &mut out);
    out
}

/// [`pretile_oracle`] into a caller-owned `tile_words()` slice (word-run
/// copies, no allocation — the packed executor's Direct-fidelity path).
pub fn pretile_oracle_into(
    dram: &[u32],
    ld_w: usize,
    row0: usize,
    k0_w: usize,
    chain: &InputChain,
    out: &mut [u32],
) {
    let s_w = chain.s_w();
    let k_ct_w = chain.k_ct_w();
    let mut idx = 0;
    for mo in 0..chain.rows / chain.micro_r {
        for j in 0..k_ct_w / s_w {
            for mi in 0..chain.micro_r {
                let row = row0 + mo * chain.micro_r + mi;
                let src = row * ld_w + k0_w + j * s_w;
                out[idx..idx + s_w].copy_from_slice(&dram[src..src + s_w]);
                idx += s_w;
            }
        }
    }
}

/// B row-major: single 4D MemTile transform (params s/t/k_ct/n_ct).
#[derive(Clone, Copy, Debug)]
pub struct BRowMajorChain {
    pub k_ct: usize,
    pub n_ct: usize,
    pub micro_s: usize,
    pub micro_t: usize,
    pub elem_bytes: usize,
}

impl BRowMajorChain {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.k_ct % self.micro_s == 0);
        ensure!(self.n_ct % self.micro_t == 0);
        words(self.micro_t, self.elem_bytes)?;
        words(self.n_ct, self.elem_bytes)?;
        Ok(())
    }

    fn t_w(&self) -> usize {
        self.micro_t * self.elem_bytes / 4
    }

    fn n_ct_w(&self) -> usize {
        self.n_ct * self.elem_bytes / 4
    }

    pub fn tile_words(&self) -> usize {
        self.k_ct * self.n_ct_w()
    }

    /// Shim MM2S: `k_total × n_ct` column panel of row-major B
    /// (row stride `ld_w`), k_ct rows at a time. Contiguous run = n_ct
    /// elements only — the reason row-major B underperforms (Sec. 5.2.3).
    pub fn shim_mm2s(&self, col0_w: usize, ld_w: usize, k_total: usize) -> Result<Bd> {
        Bd::new(
            TileKind::ShimTile,
            col0_w,
            vec![Dim::new(k_total, ld_w as isize), Dim::new(self.n_ct_w(), 1)],
        )
    }

    /// MemTile S2MM is linear (the stream already matches the
    /// `k_ct × n_ct` row-major L2 tile).
    pub fn memtile_s2mm(&self, base: usize) -> Result<Bd> {
        Bd::linear(TileKind::MemTile, base, self.tile_words())
    }

    /// MemTile MM2S (4D, params s/t/k_ct/n_ct): pre-tile the L2 tile into
    /// `s × t` micro-tiles; CompTile S2MM is then linear.
    pub fn memtile_mm2s(&self, base: usize) -> Result<Bd> {
        Bd::new(
            TileKind::MemTile,
            base,
            vec![
                Dim::new(self.k_ct / self.micro_s, (self.micro_s * self.n_ct_w()) as isize),
                Dim::new(self.n_ct / self.micro_t, self.t_w() as isize),
                Dim::new(self.micro_s, self.n_ct_w() as isize),
                Dim::new(self.t_w(), 1),
            ],
        )
    }

    /// Full chain for one `k_total × n_ct` panel → per-tile L1 images.
    pub fn stream_panel(
        &self,
        dram: &[u32],
        col0_w: usize,
        ld_w: usize,
        k_total: usize,
    ) -> Result<Vec<Vec<u32>>> {
        let tw = self.tile_words();
        let mut flat = vec![0u32; k_total / self.k_ct * tw];
        self.stream_panel_into(dram, col0_w, ld_w, k_total, &mut flat)?;
        Ok(flat.chunks(tw).map(<[u32]>::to_vec).collect())
    }

    /// [`Self::stream_panel`] into a caller-owned flat buffer with one
    /// reused L2 scratch (the packed executor's per-panel form).
    pub fn stream_panel_into(
        &self,
        dram: &[u32],
        col0_w: usize,
        ld_w: usize,
        k_total: usize,
        out: &mut [u32],
    ) -> Result<()> {
        self.validate()?;
        ensure!(k_total % self.k_ct == 0);
        let tw = self.tile_words();
        ensure!(out.len() == k_total / self.k_ct * tw, "flat tile buffer mis-sized");
        let stream = self.shim_mm2s(col0_w, ld_w, k_total)?.gather(dram)?;
        let mut l2 = vec![0u32; tw];
        for (ti, ct) in stream.chunks(tw).enumerate() {
            self.memtile_s2mm(0)?.scatter(&mut l2, ct)?;
            let pre = self.memtile_mm2s(0)?.gather(&l2)?;
            out[ti * tw..(ti + 1) * tw].copy_from_slice(&pre); // CompTile S2MM is linear
        }
        Ok(())
    }

    /// Direct oracle for one `k_ct × n_ct` tile at `(k0, col0_w)`.
    pub fn pretile_oracle(&self, dram: &[u32], ld_w: usize, k0: usize, col0_w: usize) -> Vec<u32> {
        let mut out = vec![0u32; self.tile_words()];
        self.pretile_oracle_into(dram, ld_w, k0, col0_w, &mut out);
        out
    }

    /// [`Self::pretile_oracle`] into a caller-owned `tile_words()` slice.
    pub fn pretile_oracle_into(
        &self,
        dram: &[u32],
        ld_w: usize,
        k0: usize,
        col0_w: usize,
        out: &mut [u32],
    ) {
        let t_w = self.t_w();
        let mut idx = 0;
        for ko in 0..self.k_ct / self.micro_s {
            for jo in 0..self.n_ct / self.micro_t {
                for ki in 0..self.micro_s {
                    let row = k0 + ko * self.micro_s + ki;
                    let src = row * ld_w + col0_w + jo * t_w;
                    out[idx..idx + t_w].copy_from_slice(&dram[src..src + t_w]);
                    idx += t_w;
                }
            }
        }
    }
}

/// C output chain: pre-tiled L1 C → row-major DRAM.
#[derive(Clone, Copy, Debug)]
pub struct OutputChain {
    pub m_ct: usize,
    pub n_ct: usize,
    pub micro_r: usize,
    pub micro_t: usize,
    pub elem_bytes: usize,
}

impl OutputChain {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.m_ct % self.micro_r == 0);
        ensure!(self.n_ct % self.micro_t == 0);
        words(self.micro_t, self.elem_bytes)?;
        words(self.n_ct, self.elem_bytes)?;
        Ok(())
    }

    fn t_w(&self) -> usize {
        self.micro_t * self.elem_bytes / 4
    }

    fn n_ct_w(&self) -> usize {
        self.n_ct * self.elem_bytes / 4
    }

    pub fn tile_words(&self) -> usize {
        self.m_ct * self.n_ct_w()
    }

    /// MemTile S2MM (4D, params r/t/m_ct/n_ct): de-tile the incoming
    /// pre-tiled stream into a row-major `m_ct × n_ct` L2 tile.
    ///
    /// Stream order (tiles row-major, in-tile row-major) maps to scatter
    /// loops (mo, jo, mi, w).
    pub fn memtile_s2mm(&self, base: usize) -> Result<Bd> {
        Bd::new(
            TileKind::MemTile,
            base,
            vec![
                Dim::new(self.m_ct / self.micro_r, (self.micro_r * self.n_ct_w()) as isize),
                Dim::new(self.n_ct / self.micro_t, self.t_w() as isize),
                Dim::new(self.micro_r, self.n_ct_w() as isize),
                Dim::new(self.t_w(), 1),
            ],
        )
    }

    /// Shim S2MM: write the aggregated `(m_rows·m_ct) × n_ct` L2 block to
    /// row-major DRAM at `(row0, col0_w)` with row stride `ld_w`.
    pub fn shim_s2mm(&self, m_rows: usize, row0: usize, col0_w: usize, ld_w: usize) -> Result<Bd> {
        Bd::new(
            TileKind::ShimTile,
            row0 * ld_w + col0_w,
            vec![
                Dim::new(m_rows * self.m_ct, ld_w as isize),
                Dim::new(self.n_ct_w(), 1),
            ],
        )
    }

    /// Full chain: `m_rows` pre-tiled L1 C tiles (one per array row) →
    /// DRAM image.
    pub fn drain_column(
        &self,
        l1_tiles: &[Vec<u32>],
        dram: &mut [u32],
        row0: usize,
        col0_w: usize,
        ld_w: usize,
    ) -> Result<()> {
        for t in l1_tiles {
            ensure!(t.len() == self.tile_words());
        }
        let flat = l1_tiles.concat();
        self.drain_column_flat(&flat, l1_tiles.len(), dram, row0, col0_w, ld_w, &mut Vec::new())
    }

    /// [`Self::drain_column`] over a flat tile buffer
    /// (`n_tiles · tile_words()` words, tiles back to back) with a
    /// caller-owned L2 aggregation scratch — the packed executor's
    /// per-column hot path (no allocation once the scratch is warm).
    #[allow(clippy::too_many_arguments)]
    pub fn drain_column_flat(
        &self,
        l1: &[u32],
        n_tiles: usize,
        dram: &mut [u32],
        row0: usize,
        col0_w: usize,
        ld_w: usize,
        l2: &mut Vec<u32>,
    ) -> Result<()> {
        self.validate()?;
        let tw = self.tile_words();
        ensure!(l1.len() == n_tiles * tw, "flat C buffer mis-sized");
        // Aggregate the column's tiles into one L2 region (Sec. 4.2.2:
        // MemTile S2MM channels collect four C tiles before the Shim
        // drains them). The scatters cover every word, so the scratch is
        // safely reused across columns.
        l2.resize(n_tiles * tw, 0);
        for (i, t) in l1.chunks(tw).enumerate() {
            self.memtile_s2mm(i * tw)?.scatter(l2, t)?;
        }
        // CompTile MM2S was linear (pre-tiled already); Shim writes rows.
        let shim = self.shim_s2mm(n_tiles, row0, col0_w, ld_w)?;
        shim.scatter(dram, l2)
    }

    /// Oracle: element (i, j) of the row-major tile from a pre-tiled image.
    pub fn detile_oracle(&self, pretiled: &[u32]) -> Vec<u32> {
        let t_w = self.t_w();
        let n_ct_w = self.n_ct_w();
        let tiles_per_row = self.n_ct / self.micro_t;
        let mut out = vec![0u32; self.tile_words()];
        let mut src = 0;
        for mo in 0..self.m_ct / self.micro_r {
            for jo in 0..tiles_per_row {
                for mi in 0..self.micro_r {
                    let row = mo * self.micro_r + mi;
                    for w in 0..t_w {
                        out[row * n_ct_w + jo * t_w + w] = pretiled[src];
                        src += 1;
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    fn rand_words(rng: &mut Rng, n: usize) -> Vec<u32> {
        (0..n).map(|_| rng.next_u64() as u32).collect()
    }

    #[test]
    fn a_chain_equals_pretile_oracle() {
        prop_check("A chain == direct pre-tiling", 40, |rng| {
            let micro_r = *rng.pick(&[2usize, 4]);
            let s_w = *rng.pick(&[1usize, 2]);
            let micro_s = s_w * 4; // elem_bytes=1: s elems = s bytes
            let chain = InputChain {
                rows: micro_r * (1 + rng.below(3)),
                micro_r,
                micro_s,
                k_ct: micro_s * (1 + rng.below(3)),
                k_mt: 0,
                elem_bytes: 1,
            };
            let chain = InputChain { k_mt: chain.k_ct * (1 + rng.below(3)), ..chain };
            let k_total = chain.k_mt * (1 + rng.below(2));
            let extra_rows = rng.below(3);
            let ld_w = k_total / 4 + rng.below(4); // slack columns allowed
            let n_rows = chain.rows + extra_rows;
            let dram = rand_words(rng, n_rows * ld_w);

            let tiles = chain.stream_panel(&dram, extra_rows, ld_w, k_total).unwrap();
            assert_eq!(tiles.len(), k_total / chain.k_ct);
            for (ti, tile) in tiles.iter().enumerate() {
                let want = pretile_oracle(
                    &dram,
                    ld_w,
                    extra_rows,
                    ti * chain.k_ct * chain.elem_bytes / 4,
                    &chain,
                );
                assert_eq!(tile, &want, "tile {ti}");
            }
        });
    }

    #[test]
    fn a_chain_bd_dims_respect_hardware() {
        let chain =
            InputChain { rows: 96, micro_r: 4, micro_s: 8, k_ct: 56, k_mt: 224, elem_bytes: 2 };
        chain.validate(448).unwrap();
        assert!(chain.shim_mm2s(0, 224, 448).unwrap().dims.len() <= 3);
        assert!(chain.memtile_s2mm(0).unwrap().dims.len() <= 3);
        assert_eq!(chain.memtile_mm2s(0).unwrap().dims.len(), 4);
        assert!(chain.comptile_s2mm(0).unwrap().dims.len() <= 3);
    }

    #[test]
    fn b_row_major_chain_equals_oracle() {
        prop_check("B row-major 4D == oracle", 40, |rng| {
            let micro_s = *rng.pick(&[4usize, 8]);
            let t_w = *rng.pick(&[1usize, 2]);
            let micro_t = t_w * 4;
            let c = BRowMajorChain {
                k_ct: micro_s * (1 + rng.below(3)),
                n_ct: micro_t * (1 + rng.below(3)),
                micro_s,
                micro_t,
                elem_bytes: 1,
            };
            let k_total = c.k_ct * (1 + rng.below(3));
            let n_total_w = c.n_ct_w() * (1 + rng.below(2)) + rng.below(3);
            let col0_w = rng.below(n_total_w - c.n_ct_w() + 1);
            let dram = rand_words(rng, k_total * n_total_w);
            let tiles = c.stream_panel(&dram, col0_w, n_total_w, k_total).unwrap();
            for (ti, tile) in tiles.iter().enumerate() {
                let want = c.pretile_oracle(&dram, n_total_w, ti * c.k_ct, col0_w);
                assert_eq!(tile, &want, "tile {ti}");
            }
        });
    }

    #[test]
    fn c_chain_roundtrip() {
        prop_check("C drain: pre-tiled L1 -> row-major DRAM", 40, |rng| {
            let micro_r = *rng.pick(&[2usize, 4]);
            let t_w = *rng.pick(&[1usize, 2]);
            let micro_t = t_w * 4;
            let c = OutputChain {
                m_ct: micro_r * (1 + rng.below(3)),
                n_ct: micro_t * (1 + rng.below(3)),
                micro_r,
                micro_t,
                elem_bytes: 1,
            };
            let m_rows = 1 + rng.below(4);
            let tiles: Vec<Vec<u32>> =
                (0..m_rows).map(|_| rand_words(rng, c.tile_words())).collect();
            let ld_w = c.n_ct_w() + rng.below(4);
            let total_rows = m_rows * c.m_ct + rng.below(3);
            let mut dram = vec![0u32; total_rows * ld_w];
            c.drain_column(&tiles, &mut dram, 0, 0, ld_w).unwrap();
            // Every tile's de-tiled rows must appear at the right offset.
            for (i, t) in tiles.iter().enumerate() {
                let want = c.detile_oracle(t);
                for row in 0..c.m_ct {
                    let dr = i * c.m_ct + row;
                    assert_eq!(
                        &dram[dr * ld_w..dr * ld_w + c.n_ct_w()],
                        &want[row * c.n_ct_w()..(row + 1) * c.n_ct_w()],
                        "tile {i} row {row}"
                    );
                }
            }
        });
    }

    #[test]
    fn paper_configs_build_valid_chains() {
        // Every balanced config must produce BDs within hardware dims.
        for gen in crate::arch::Generation::ALL {
            for p in crate::dtype::Precision::ALL {
                let cfg = crate::arch::balanced_config(gen, p);
                let (r, s, t) = p.micro_tile();
                let a = InputChain {
                    rows: cfg.kernel.m_ct,
                    micro_r: r,
                    micro_s: s,
                    k_ct: cfg.kernel.k_ct,
                    k_mt: cfg.k_mt,
                    elem_bytes: p.ty_in(),
                };
                a.validate(cfg.k_mt * 2).unwrap();
                let bt = InputChain {
                    rows: cfg.kernel.n_ct,
                    micro_r: t,
                    micro_s: s,
                    k_ct: cfg.kernel.k_ct,
                    k_mt: cfg.k_mt,
                    elem_bytes: p.ty_in(),
                };
                bt.validate(cfg.k_mt * 2).unwrap();
                let brm = BRowMajorChain {
                    k_ct: cfg.kernel.k_ct,
                    n_ct: cfg.kernel.n_ct,
                    micro_s: s,
                    micro_t: t,
                    elem_bytes: p.ty_in(),
                };
                brm.validate().unwrap();
                let c = OutputChain {
                    m_ct: cfg.kernel.m_ct,
                    n_ct: cfg.kernel.n_ct,
                    micro_r: r,
                    micro_t: t,
                    elem_bytes: p.ty_out(),
                };
                c.validate().unwrap();
            }
        }
    }

    #[test]
    fn shim_contiguity_matches_kmt() {
        // The A-chain Shim BD's average contiguous run is k_mt elements —
        // the quantity Fig. 6 sweeps.
        let chain =
            InputChain { rows: 8, micro_r: 4, micro_s: 8, k_ct: 16, k_mt: 64, elem_bytes: 1 };
        let bd = chain.shim_mm2s(0, 64, 256).unwrap();
        assert_eq!(bd.avg_contig_run_bytes(), 64.0);
        // ...except when k_mt spans the whole row: then rows merge.
        let chain2 = InputChain { k_mt: 256, ..chain };
        let bd2 = chain2.shim_mm2s(0, 64, 256).unwrap();
        assert_eq!(bd2.avg_contig_run_bytes(), (256 * 8) as f64);
    }
}
