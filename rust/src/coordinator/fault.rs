//! Deterministic fault injection for the fleet coordinator (ISSUE 6).
//!
//! A [`FaultPlan`] is a per-device schedule of faults expressed in the
//! only clock the coordinator controls deterministically: the router's
//! **forward counter** (the 1-based count of units the router has handed
//! to that device's leader). Wall-clock triggers would make chaos runs
//! unrepeatable; counter triggers make the same seed reproduce the exact
//! same event sequence on every run, which is what lets the chaos suite
//! pin bit-exactness against a fault-free baseline and CI re-run a seed
//! and diff the logs byte-for-byte.
//!
//! The plan is derived from a seed with the repo's own xoshiro256**
//! ([`crate::util::rng::Rng`]), so `python/tests/test_chaos_model.py`
//! can re-derive the identical plan in an independent implementation
//! and both sides pin the same golden literal.

use crate::util::rng::Rng;

/// What goes wrong when a fault fires. All kinds are attached to the
/// unit of work whose forward made the device's counter reach the
/// event's `seq`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The device leader dies before executing the tagged unit. The
    /// tagged unit and the rest of its batch are handed back to the
    /// router for requeue; the router respawns the leader (or spills to
    /// a sibling device once the respawn budget is exhausted).
    LeaderKill,
    /// An injected DMA-latency stall: the tagged unit executes
    /// normally but its device time is inflated by `stall_s` seconds.
    DmaStall {
        /// Extra seconds of modeled DMA latency.
        stall_s: f64,
    },
    /// A design-cache eviction storm: the leader's design cache and
    /// loaded-design state are wiped before the tagged unit runs, so it
    /// pays a cold compile + reconfiguration.
    CacheStorm,
    /// The leader drops the unit without executing it (a lost
    /// response). The router requeues it at the front of the device
    /// queue, so the client still gets exactly one reply.
    DropResponse,
    /// Silent data corruption (ISSUE 8): the tagged unit executes
    /// normally, then bits of its completed C image (or the staged
    /// chain tensor it feeds downstream) are flipped. Nothing crashes —
    /// only an integrity check can see it. Detection and recovery are
    /// the ABFT layer's job ([`crate::gemm::abft`]).
    CorruptResult {
        /// Word selector: the corrupted index is `word % c_words`, so
        /// one event is meaningful for any result shape.
        word: u64,
        /// XOR mask applied to the selected word. Never a no-op:
        /// [`crate::gemm::abft::corrupt_word`] degrades a zero mask to
        /// bit 0 and masks bfp16 pad words to their live byte.
        xor_mask: u32,
    },
}

impl FaultKind {
    /// Short stable label for logs and summaries.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::LeaderKill => "leader_kill",
            FaultKind::DmaStall { .. } => "dma_stall",
            FaultKind::CacheStorm => "cache_storm",
            FaultKind::DropResponse => "drop_response",
            FaultKind::CorruptResult { .. } => "corrupt_result",
        }
    }

    /// Device-clock seconds the fault adds to its tagged unit (nonzero
    /// only for `DmaStall`); the flight recorder charges this onto the
    /// unit's `fault-stall` child span.
    pub fn stall_seconds(&self) -> f64 {
        match self {
            FaultKind::DmaStall { stall_s } => *stall_s,
            _ => 0.0,
        }
    }
}

/// One scheduled fault: fires when the device's forward counter reaches
/// `seq` (1-based; the first unit forwarded to the device has seq 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Forward-counter threshold on the owning device.
    pub seq: u64,
    /// What happens.
    pub kind: FaultKind,
}

/// A fault that actually fired, as logged by the router.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultRecord {
    /// Device whose leader the fault targeted.
    pub device: usize,
    /// Forward count at which it fired.
    pub seq: u64,
    /// What happened.
    pub kind: FaultKind,
}

/// Per-device fault schedule. `events[d]` is sorted by `seq` with
/// distinct seqs; the router consumes it in order as forwards happen.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// `events[d]` = the schedule for device `d`.
    pub events: Vec<Vec<FaultEvent>>,
}

/// Per-device seed salt (an arbitrary odd 64-bit constant, mirrored by
/// the Python transliteration) so each device draws an independent
/// stream from the same plan seed.
pub const DEVICE_SALT: u64 = 0xA24B_AED4_963E_E407;

/// Per-device salt for the **corruption** stream — deliberately distinct
/// from [`DEVICE_SALT`] so arming [`FaultKind::CorruptResult`] events
/// draws from an independent xoshiro stream and never shifts the
/// fail-stop plan a seed already pins (the seed-2 golden below is
/// byte-identical with and without corruption armed).
pub const CORRUPT_SALT: u64 = 0xC3A5_C85C_97CB_3127;

impl FaultPlan {
    /// A plan with no events (chaos disabled).
    pub fn empty() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    /// Derive a plan from a seed: for each device, `per_device`
    /// distinct forward-counter thresholds drawn uniformly from
    /// `1..=horizon`, sorted ascending, each paired with a fault kind
    /// drawn from the same stream. Deterministic: the same
    /// `(seed, n_devices, horizon, per_device)` always yields the same
    /// plan, byte for byte.
    pub fn from_seed(seed: u64, n_devices: usize, horizon: u64, per_device: usize) -> FaultPlan {
        let horizon = horizon.max(1);
        let mut events = Vec::with_capacity(n_devices);
        for d in 0..n_devices {
            let salt = ((d as u64) + 1).wrapping_mul(DEVICE_SALT);
            let mut rng = Rng::seeded(seed.wrapping_add(salt));
            let want = per_device.min(horizon as usize);
            // Rejection sampling with set-backed membership: the
            // accept/reject decisions (and so the RNG draw order, which
            // the downstream kind draws and the Python transliteration's
            // seed-2 golden both depend on) are identical to the naive
            // linear-scan version, without the O(want·horizon) scans.
            let mut seen = std::collections::HashSet::with_capacity(want);
            let mut seqs: Vec<u64> = Vec::with_capacity(want);
            while seqs.len() < want {
                let c = 1 + rng.next_u64() % horizon;
                if seen.insert(c) {
                    seqs.push(c);
                }
            }
            seqs.sort_unstable();
            let evs: Vec<FaultEvent> =
                seqs.into_iter().map(|seq| FaultEvent { seq, kind: draw_kind(&mut rng) }).collect();
            events.push(evs);
        }
        FaultPlan { events }
    }

    /// A plan with exactly one event, for targeted regression tests.
    pub fn single(n_devices: usize, device: usize, seq: u64, kind: FaultKind) -> FaultPlan {
        FaultPlan { events: vec![Vec::new(); n_devices] }.with_event(device, seq, kind)
    }

    /// Insert one event, keeping the device's schedule sorted by `seq`.
    /// Grows the plan if `device` is beyond the current device count.
    pub fn with_event(mut self, device: usize, seq: u64, kind: FaultKind) -> FaultPlan {
        if self.events.len() <= device {
            self.events.resize(device + 1, Vec::new());
        }
        let evs = &mut self.events[device];
        let at = evs.partition_point(|e| e.seq < seq);
        evs.insert(at, FaultEvent { seq, kind });
        self
    }

    /// Schedule for device `d` (empty past the plan's device count).
    pub fn device_events(&self, d: usize) -> &[FaultEvent] {
        self.events.get(d).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Total scheduled events across all devices.
    pub fn total_events(&self) -> usize {
        self.events.iter().map(Vec::len).sum()
    }

    /// Scheduled leader deaths — what the respawn budget must cover for
    /// no work to spill off-device.
    pub fn kills(&self) -> usize {
        self.events
            .iter()
            .flatten()
            .filter(|e| e.kind == FaultKind::LeaderKill)
            .count()
    }

    /// Scheduled silent-corruption events.
    pub fn corruptions(&self) -> usize {
        self.events
            .iter()
            .flatten()
            .filter(|e| matches!(e.kind, FaultKind::CorruptResult { .. }))
            .count()
    }

    /// Arm `per_device` [`FaultKind::CorruptResult`] events per device on
    /// top of this plan. The events are drawn from an independent
    /// per-device stream (seeded with [`CORRUPT_SALT`]): fresh seqs are
    /// rejection-sampled against the device's *existing* thresholds, so
    /// corruption never lands on the same unit as a fail-stop fault, and
    /// the existing schedule is not moved by a single draw. Deterministic
    /// — mirrored by `corruption_events` in
    /// `python/tests/test_integrity_model.py`.
    pub fn with_corruption(
        mut self,
        seed: u64,
        n_devices: usize,
        horizon: u64,
        per_device: usize,
    ) -> FaultPlan {
        let horizon = horizon.max(1);
        if self.events.len() < n_devices {
            self.events.resize(n_devices, Vec::new());
        }
        for d in 0..n_devices {
            let salt = ((d as u64) + 1).wrapping_mul(CORRUPT_SALT);
            let mut rng = Rng::seeded(seed.wrapping_add(salt));
            let mut seen: std::collections::HashSet<u64> =
                self.events[d].iter().map(|e| e.seq).collect();
            let want = per_device.min((horizon as usize).saturating_sub(seen.len()));
            let mut seqs: Vec<u64> = Vec::with_capacity(want);
            while seqs.len() < want {
                let c = 1 + rng.next_u64() % horizon;
                if seen.insert(c) {
                    seqs.push(c);
                }
            }
            seqs.sort_unstable();
            for seq in seqs {
                let word = rng.next_u64();
                let mask = rng.next_u64() as u32;
                let xor_mask = if mask == 0 { 1 } else { mask };
                let evs = &mut self.events[d];
                let at = evs.partition_point(|e| e.seq < seq);
                let kind = FaultKind::CorruptResult { word, xor_mask };
                evs.insert(at, FaultEvent { seq, kind });
            }
        }
        self
    }

    /// A pure silent-corruption plan (no fail-stop events).
    pub fn corruption_only(
        seed: u64,
        n_devices: usize,
        horizon: u64,
        per_device: usize,
    ) -> FaultPlan {
        FaultPlan { events: vec![Vec::new(); n_devices] }
            .with_corruption(seed, n_devices, horizon, per_device)
    }
}

fn draw_kind(rng: &mut Rng) -> FaultKind {
    match rng.next_u64() % 4 {
        0 => FaultKind::LeaderKill,
        1 => FaultKind::DmaStall { stall_s: (0.5 + 4.5 * rng.f64()) * 1e-3 },
        2 => FaultKind::CacheStorm,
        _ => FaultKind::DropResponse,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_plan() {
        let a = FaultPlan::from_seed(0xDEAD_BEEF, 3, 64, 5);
        let b = FaultPlan::from_seed(0xDEAD_BEEF, 3, 64, 5);
        assert_eq!(a, b);
        assert_ne!(a, FaultPlan::from_seed(0xDEAD_BEF0, 3, 64, 5));
    }

    #[test]
    fn seqs_sorted_distinct_within_horizon() {
        for seed in 0..16u64 {
            let plan = FaultPlan::from_seed(seed, 4, 32, 8);
            assert_eq!(plan.events.len(), 4);
            for evs in &plan.events {
                assert_eq!(evs.len(), 8);
                for w in evs.windows(2) {
                    assert!(w[0].seq < w[1].seq, "seqs must be strictly ascending");
                }
                for e in evs {
                    assert!((1..=32).contains(&e.seq));
                }
            }
        }
    }

    #[test]
    fn per_device_clamped_to_horizon() {
        let plan = FaultPlan::from_seed(1, 2, 3, 10);
        for evs in &plan.events {
            assert_eq!(evs.len(), 3, "cannot schedule more distinct seqs than the horizon");
        }
    }

    #[test]
    fn golden_plan_matches_python_transliteration() {
        // Pinned against python/tests/test_chaos_model.py, which
        // re-derives the same plan from an independent xoshiro256**
        // implementation. Any drift in Rng or from_seed breaks both.
        let plan = FaultPlan::from_seed(2, 2, 32, 4);
        let want = FaultPlan {
            events: vec![
                vec![
                    FaultEvent { seq: 3, kind: FaultKind::CacheStorm },
                    FaultEvent { seq: 12, kind: FaultKind::CacheStorm },
                    FaultEvent { seq: 18, kind: FaultKind::DropResponse },
                    FaultEvent { seq: 25, kind: FaultKind::LeaderKill },
                ],
                vec![
                    FaultEvent { seq: 6, kind: FaultKind::LeaderKill },
                    FaultEvent { seq: 7, kind: FaultKind::LeaderKill },
                    FaultEvent {
                        seq: 13,
                        kind: FaultKind::DmaStall { stall_s: 0.004359766823757453 },
                    },
                    FaultEvent { seq: 17, kind: FaultKind::LeaderKill },
                ],
            ],
        };
        assert_eq!(plan, want);
        assert_eq!(plan.total_events(), 8);
        assert_eq!(plan.kills(), 4);
    }

    #[test]
    fn builders_keep_schedules_sorted() {
        let plan = FaultPlan::single(2, 1, 5, FaultKind::LeaderKill)
            .with_event(1, 2, FaultKind::CacheStorm)
            .with_event(1, 9, FaultKind::DropResponse)
            .with_event(3, 1, FaultKind::DropResponse);
        assert_eq!(plan.device_events(0), &[]);
        let seqs: Vec<u64> = plan.device_events(1).iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![2, 5, 9]);
        assert_eq!(plan.events.len(), 4, "with_event grows the plan");
        assert_eq!(plan.device_events(7), &[], "out-of-range devices have no events");
        assert_eq!(plan.kills(), 1);
    }

    #[test]
    fn kind_names_are_stable() {
        assert_eq!(FaultKind::LeaderKill.name(), "leader_kill");
        assert_eq!(FaultKind::DmaStall { stall_s: 1e-3 }.name(), "dma_stall");
        assert_eq!(FaultKind::CacheStorm.name(), "cache_storm");
        assert_eq!(FaultKind::DropResponse.name(), "drop_response");
        assert_eq!(FaultKind::CorruptResult { word: 0, xor_mask: 1 }.name(), "corrupt_result");
    }

    #[test]
    fn corruption_golden_matches_python_and_never_moves_the_base_plan() {
        // Pinned against test_integrity_model.py::test_corruption_plan_
        // seed2_golden: the PR-6 seed-2 plan gains exactly two
        // CorruptResult events per device, drawn from the CORRUPT_SALT
        // stream, without moving a single existing event.
        let base = FaultPlan::from_seed(2, 2, 32, 4);
        let plan = base.clone().with_corruption(2, 2, 32, 2);
        assert_eq!(plan.total_events(), base.total_events() + 4);
        assert_eq!(plan.corruptions(), 4);
        assert_eq!(plan.kills(), base.kills(), "fail-stop schedule untouched");
        for d in 0..2 {
            let base_evs = base.device_events(d);
            let kept: Vec<FaultEvent> = plan.device_events(d)
                .iter()
                .copied()
                .filter(|e| !matches!(e.kind, FaultKind::CorruptResult { .. }))
                .collect();
            assert_eq!(kept, base_evs, "device {d}: base events moved");
        }
        let corr = |d: usize| -> Vec<(u64, u64, u32)> {
            plan.device_events(d)
                .iter()
                .filter_map(|e| match e.kind {
                    FaultKind::CorruptResult { word, xor_mask } => {
                        Some((e.seq, word, xor_mask))
                    }
                    _ => None,
                })
                .collect()
        };
        assert_eq!(
            corr(0),
            vec![
                (21, 6898576805263037612, 0x1EDA_FEBC),
                (29, 12113513064234870111, 0x9725_FF6F),
            ]
        );
        assert_eq!(
            corr(1),
            vec![
                (11, 10056184684129657251, 0xB1B3_60CB),
                (30, 6101993186801645025, 0x7B16_0F40),
            ]
        );
    }

    #[test]
    fn corruption_only_golden_seed7() {
        // test_integrity_model.py::test_corruption_only_plan_seed7_golden
        let plan = FaultPlan::corruption_only(7, 1, 16, 3);
        let want = vec![
            FaultEvent {
                seq: 10,
                kind: FaultKind::CorruptResult { word: 5158167014563121986, xor_mask: 0xA320_3E96 },
            },
            FaultEvent {
                seq: 11,
                kind: FaultKind::CorruptResult { word: 5166436897857171591, xor_mask: 0x545A_7A14 },
            },
            FaultEvent {
                seq: 12,
                kind: FaultKind::CorruptResult {
                    word: 15423587528627081610,
                    xor_mask: 0x49CA_CBA2,
                },
            },
        ];
        assert_eq!(plan.device_events(0), want);
        assert_eq!(plan.corruptions(), 3);
        assert_eq!(plan.kills(), 0);
    }
}
