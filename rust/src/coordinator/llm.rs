//! Continuous-batching LLM serving runtime (ISSUE 7 tentpole).
//!
//! Drives a [`Coordinator`] fleet with the two-regime LLM load of
//! [`crate::workload::llm`]:
//!
//! * **prefill** goes through the existing chain path as one whole
//!   forward-pass chain — large M, wide designs, routed by the fleet's
//!   affinity scheduler. The device that serves a session's prefill owns
//!   its KV cache, so every later decode step is pinned there
//!   ([`ChainStaging::device`]).
//! * **decode** runs in *rounds*: each round, every device coalesces the
//!   next-token GEMVs of all its ready sessions into one `[S, K]·[K, N]`
//!   chain per layer stack (`S <= max_batch <=`
//!   [`crate::arch::SKINNY_M_MAX`]), which the router serves from the
//!   skinny design class. The `coalesce: false` baseline submits the
//!   same work as S separate M=1 chains — same device pinning, same
//!   order — isolating exactly the batching effect (S× fewer host
//!   dispatch/prologue payments, S× fewer B streams).
//!
//! Time is *virtual*: the simulator's per-chain `device_s` advances one
//! clock per device, prefill starts at `max(arrival, device clock)`, and
//! a round's tokens complete when their device's round does. No
//! wall-clock sleeps — a load at any arrival rate replays exactly, and
//! latency percentiles are deterministic bit for bit.

use anyhow::Result;

use crate::util::json::{num, obj, Json};
use crate::util::stats::percentile;
use crate::workload::llm::{decode_step_chain, prefill_chain, LlmLoad, SessionSpec};

use super::service::{ChainStaging, Coordinator};

/// Knobs for one serving run.
#[derive(Clone, Debug)]
pub struct LlmOptions {
    pub load: LlmLoad,
    /// Coalesce concurrent sessions' next-token GEMVs into one M=S chain
    /// per device per round (`false` = per-session M=1 baseline).
    pub coalesce: bool,
    /// Cap on the coalesced batch M. Defaults to
    /// [`crate::arch::SKINNY_M_MAX`] so every decode batch stays inside
    /// the skinny design class; larger rounds split into chunks.
    pub max_batch: usize,
    /// Tenant index all submissions bill to (decode-priority tenants come
    /// from [`super::CoordinatorOptions::tenants`]).
    pub tenant: usize,
}

impl Default for LlmOptions {
    fn default() -> Self {
        LlmOptions {
            load: LlmLoad::default(),
            coalesce: true,
            max_batch: crate::arch::SKINNY_M_MAX,
            tenant: 0,
        }
    }
}

/// Outcome of a serving run. All times are virtual seconds.
#[derive(Clone, Debug)]
pub struct LlmReport {
    pub sessions: usize,
    pub sessions_completed: usize,
    pub sessions_failed: usize,
    /// Decode tokens requested across all sessions (the conservation
    /// denominator).
    pub tokens_submitted: usize,
    pub tokens_completed: usize,
    /// Tokens lost to failed prefills or failed decode chains.
    pub tokens_failed: usize,
    /// Tokens never resolved (0 after a full drain).
    pub tokens_pending: usize,
    /// Per-token decode latency (ready → emitted), percentiles over all
    /// completed tokens. `None` when no token completed.
    pub token_lat_p50_s: Option<f64>,
    pub token_lat_p99_s: Option<f64>,
    /// Time to first token (arrival → first decode emitted), per session.
    pub ttft_p50_s: Option<f64>,
    pub ttft_p99_s: Option<f64>,
    /// Completed tokens per virtual second of makespan.
    pub tokens_per_s: f64,
    /// Latest device clock at drain (virtual seconds).
    pub makespan_s: f64,
    /// Device seconds consumed by decode rounds alone (excludes prefill
    /// and idle gaps) — the denominator that isolates the coalescing
    /// effect from prefill cost and prefill↔decode design switches.
    pub decode_busy_s: f64,
    /// Device-rounds executed (one per device per decode round).
    pub decode_rounds: usize,
    /// Mean sessions per device-round — the achieved coalescing degree.
    pub mean_batch: f64,
    pub coalesced: bool,
}

impl LlmReport {
    /// Token conservation: every requested token is accounted exactly
    /// once. The serving loop drains fully, so `tokens_pending` is 0
    /// unless a caller aborts mid-run.
    pub fn conserved(&self) -> bool {
        self.tokens_completed + self.tokens_failed + self.tokens_pending
            == self.tokens_submitted
    }

    /// The run as a [`Json`] value (`serve-llm --json`); same serializer
    /// as the fleet rollup and the trace exporter.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        obj(vec![
            ("sessions", num(self.sessions as f64)),
            ("sessions_completed", num(self.sessions_completed as f64)),
            ("sessions_failed", num(self.sessions_failed as f64)),
            ("tokens_submitted", num(self.tokens_submitted as f64)),
            ("tokens_completed", num(self.tokens_completed as f64)),
            ("tokens_failed", num(self.tokens_failed as f64)),
            ("tokens_pending", num(self.tokens_pending as f64)),
            ("token_lat_p50_seconds", opt(self.token_lat_p50_s)),
            ("token_lat_p99_seconds", opt(self.token_lat_p99_s)),
            ("ttft_p50_seconds", opt(self.ttft_p50_s)),
            ("ttft_p99_seconds", opt(self.ttft_p99_s)),
            ("tokens_per_second", num(self.tokens_per_s)),
            ("makespan_seconds", num(self.makespan_s)),
            ("decode_busy_seconds", num(self.decode_busy_s)),
            ("decode_rounds", num(self.decode_rounds as f64)),
            ("mean_batch", num(self.mean_batch)),
            ("coalesced", Json::Bool(self.coalesced)),
            ("conserved", Json::Bool(self.conserved())),
        ])
    }

    pub fn summary(&self) -> String {
        let fmt = |x: Option<f64>| match x {
            Some(v) => format!("{:.3} ms", v * 1e3),
            None => "n/a".to_string(),
        };
        format!(
            "llm serve ({}): {}/{} sessions ok | tokens {}/{} ok, {} failed, {} pending | \
             {:.1} tok/s over {:.1} ms | token p50 {} p99 {} | ttft p50 {} p99 {} | \
             {} device-rounds, mean batch {:.1}",
            if self.coalesced { "coalesced" } else { "per-session" },
            self.sessions_completed,
            self.sessions,
            self.tokens_completed,
            self.tokens_submitted,
            self.tokens_failed,
            self.tokens_pending,
            self.tokens_per_s,
            self.makespan_s * 1e3,
            fmt(self.token_lat_p50_s),
            fmt(self.token_lat_p99_s),
            fmt(self.ttft_p50_s),
            fmt(self.ttft_p99_s),
            self.decode_rounds,
            self.mean_batch,
        )
    }
}

/// A session past prefill: pinned to its KV-cache device, waiting for or
/// emitting decode tokens.
struct Live {
    spec: SessionSpec,
    device: usize,
    /// Virtual time the session's next token became ready to decode
    /// (prefill completion, then each emitted token).
    ready_s: f64,
    remaining: usize,
    awaiting_first_token: bool,
}

/// Serve `opts.load` through `coord` and return the run report. The
/// caller owns the coordinator (and its [`super::FleetMetrics`] at
/// shutdown); one coordinator can serve several runs back to back.
pub fn serve_llm(coord: &Coordinator, opts: &LlmOptions) -> Result<LlmReport> {
    anyhow::ensure!(opts.max_batch >= 1, "max_batch must be at least 1");
    let model = opts.load.model;
    let sessions = opts.load.sessions();
    let tokens_submitted: usize = sessions.iter().map(|s| s.decode_tokens).sum();

    let mut dev_clock = vec![0.0f64; coord.n_devices()];
    let mut arrivals = sessions.clone().into_iter().peekable();
    let mut active: Vec<Live> = Vec::new();
    let mut token_lats: Vec<f64> = Vec::new();
    let mut ttfts: Vec<f64> = Vec::new();
    let mut sessions_completed = 0usize;
    let mut sessions_failed = 0usize;
    let mut tokens_failed = 0usize;
    let mut decode_rounds = 0usize;
    let mut round_participants = 0usize;
    let mut decode_busy_s = 0.0f64;

    // Admit every pending arrival at or before the virtual horizon:
    // submit its prefill chain (router's choice of device), advance that
    // device's clock, and pin the session there.
    let admit = |horizon: f64,
                 arrivals: &mut std::iter::Peekable<std::vec::IntoIter<SessionSpec>>,
                 active: &mut Vec<Live>,
                 dev_clock: &mut [f64],
                 sessions_failed: &mut usize,
                 tokens_failed: &mut usize|
     -> Result<()> {
        while arrivals.peek().is_some_and(|s| s.arrival_s <= horizon) {
            let spec = arrivals.next().unwrap();
            let pre = prefill_chain(&model, &format!("s{}.prefill", spec.id));
            let rx = coord.submit_chain_staged_for(opts.tenant, pre, ChainStaging::default());
            let resp = match rx.and_then(|rx| rx.recv().map_err(Into::into)) {
                Ok(resp) => resp,
                Err(_) => {
                    *sessions_failed += 1;
                    *tokens_failed += spec.decode_tokens;
                    continue;
                }
            };
            let start = spec.arrival_s.max(dev_clock[resp.device]);
            dev_clock[resp.device] = start + resp.device_s;
            active.push(Live {
                device: resp.device,
                ready_s: dev_clock[resp.device],
                remaining: spec.decode_tokens,
                awaiting_first_token: true,
                spec,
            });
        }
        Ok(())
    };

    while arrivals.peek().is_some() || !active.is_empty() {
        if active.is_empty() {
            // Fleet is idle: jump virtual time to the next arrival.
            let next = arrivals.peek().unwrap().arrival_s;
            admit(
                next,
                &mut arrivals,
                &mut active,
                &mut dev_clock,
                &mut sessions_failed,
                &mut tokens_failed,
            )?;
            continue;
        }

        // One decode round: every device with ready sessions submits its
        // (chunked) batch. Chains for distinct devices run concurrently;
        // chains on one device serialize, exactly like its virtual clock.
        let mut in_flight = Vec::new();
        for d in 0..dev_clock.len() {
            let members: Vec<usize> = (0..active.len())
                .filter(|&i| active[i].device == d && active[i].ready_s <= dev_clock[d])
                .collect();
            if members.is_empty() {
                continue;
            }
            decode_rounds += 1;
            round_participants += members.len();
            for chunk in members.chunks(opts.max_batch) {
                if opts.coalesce {
                    let name = format!("d{d}.r{decode_rounds}.m{}", chunk.len());
                    let chain = decode_step_chain(&model, chunk.len(), &name);
                    let rx = coord.submit_chain_staged_for(
                        opts.tenant,
                        chain,
                        ChainStaging { device: Some(d), ..Default::default() },
                    );
                    in_flight.push((d, chunk.to_vec(), rx));
                } else {
                    for &i in chunk {
                        let name = format!("d{d}.r{decode_rounds}.s{}", active[i].spec.id);
                        let chain = decode_step_chain(&model, 1, &name);
                        let rx = coord.submit_chain_staged_for(
                            opts.tenant,
                            chain,
                            ChainStaging { device: Some(d), ..Default::default() },
                        );
                        in_flight.push((d, vec![i], rx));
                    }
                }
            }
        }

        // Collect the round: advance each device's clock by its chains'
        // summed device seconds; every participant's token completes at
        // the new clock.
        let mut failed_sessions: Vec<usize> = Vec::new();
        let mut completions: Vec<(usize, f64)> = Vec::new();
        for (d, members, rx) in in_flight {
            match rx.and_then(|rx| rx.recv().map_err(Into::into)) {
                Ok(resp) => {
                    dev_clock[d] += resp.device_s;
                    decode_busy_s += resp.device_s;
                    for i in members {
                        completions.push((i, dev_clock[d]));
                    }
                }
                Err(_) => failed_sessions.extend(members),
            }
        }
        for (i, done) in completions {
            let s = &mut active[i];
            token_lats.push(done - s.ready_s);
            if s.awaiting_first_token {
                ttfts.push(done - s.spec.arrival_s);
                s.awaiting_first_token = false;
            }
            s.ready_s = done;
            s.remaining -= 1;
        }
        for &i in &failed_sessions {
            tokens_failed += active[i].remaining;
            sessions_failed += 1;
        }
        let mut idx = 0;
        active.retain(|s| {
            let drop_now = failed_sessions.contains(&idx) || s.remaining == 0;
            if s.remaining == 0 && !failed_sessions.contains(&idx) {
                sessions_completed += 1;
            }
            idx += 1;
            !drop_now
        });

        // Open-loop admission: sessions that arrived during this round
        // join the next one.
        let frontier = dev_clock.iter().cloned().fold(0.0f64, f64::max);
        admit(
            frontier,
            &mut arrivals,
            &mut active,
            &mut dev_clock,
            &mut sessions_failed,
            &mut tokens_failed,
        )?;
    }

    let makespan_s = dev_clock.iter().cloned().fold(0.0f64, f64::max);
    let tokens_completed = token_lats.len();
    Ok(LlmReport {
        sessions: sessions.len(),
        sessions_completed,
        sessions_failed,
        tokens_submitted,
        tokens_completed,
        tokens_failed,
        tokens_pending: tokens_submitted - tokens_completed - tokens_failed,
        token_lat_p50_s: percentile(&token_lats, 50.0),
        token_lat_p99_s: percentile(&token_lats, 99.0),
        ttft_p50_s: percentile(&ttfts, 50.0),
        ttft_p99_s: percentile(&ttfts, 99.0),
        tokens_per_s: if makespan_s > 0.0 { tokens_completed as f64 / makespan_s } else { 0.0 },
        makespan_s,
        decode_busy_s,
        decode_rounds,
        mean_batch: if decode_rounds > 0 {
            round_participants as f64 / decode_rounds as f64
        } else {
            0.0
        },
        coalesced: opts.coalesce,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Generation;
    use crate::coordinator::CoordinatorOptions;
    use crate::workload::TransformerConfig;

    fn small_load() -> LlmLoad {
        LlmLoad {
            model: TransformerConfig {
                n_layers: 2,
                d_model: 256,
                d_ffn: 512,
                vocab: 512,
                seq: 128,
                ..Default::default()
            },
            sessions: 6,
            // Arrivals ~0.2 ms apart: the first prefill (which pays the
            // cold design load) outlasts the whole arrival window, so
            // sessions genuinely overlap and decode rounds coalesce.
            arrival_rate: 5000.0,
            decode_tokens: (8, 16),
            seed: 11,
        }
    }

    #[test]
    fn serves_all_tokens_with_conservation() {
        let coord = Coordinator::start(CoordinatorOptions::fleet(vec![
            Generation::Xdna2,
            Generation::Xdna,
        ]));
        let opts = LlmOptions { load: small_load(), ..Default::default() };
        let r = serve_llm(&coord, &opts).unwrap();
        assert!(r.conserved(), "{:?}", r);
        assert_eq!(r.tokens_pending, 0);
        assert_eq!(r.tokens_failed, 0);
        assert_eq!(r.sessions_completed, 6);
        assert_eq!(r.tokens_completed, opts.load.total_decode_tokens());
        assert!(r.token_lat_p50_s.is_some() && r.token_lat_p99_s.is_some());
        assert!(r.token_lat_p99_s.unwrap() >= r.token_lat_p50_s.unwrap());
        assert!(r.ttft_p50_s.unwrap() > 0.0);
        assert!(r.tokens_per_s > 0.0);
        assert!(r.mean_batch >= 1.0);
        // The fleet's own per-tenant conservation must also close.
        let m = coord.shutdown().unwrap();
        let t = &m.tenants[0];
        assert_eq!(t.submitted, t.completed + t.failed);
    }

    #[test]
    fn chaos_with_integrity_preserves_token_conservation() {
        // Satellite fix (ISSUE 8): the serve-llm path used to drop the
        // fault plan on the floor. A seeded chaos plan (kills, stalls,
        // drops, result corruption) now rides the coordinator under
        // serve_llm, and every requested token is still accounted
        // exactly once — faults surface as requeues or visible
        // failures, never as lost tokens.
        use crate::coordinator::{FaultPlan, IntegrityMode};
        let plan = FaultPlan::from_seed(2, 2, 48, 3).with_corruption(2, 2, 48, 2);
        let coord = Coordinator::start(CoordinatorOptions {
            devices: vec![Generation::Xdna2, Generation::Xdna],
            chaos: Some(plan),
            integrity: IntegrityMode::Abft,
            ..Default::default()
        });
        let opts = LlmOptions { load: small_load(), ..Default::default() };
        let r = serve_llm(&coord, &opts).unwrap();
        assert!(r.conserved(), "{r:?}");
        assert_eq!(r.tokens_pending, 0);
        let m = coord.shutdown().unwrap();
        assert!(!m.faults.is_empty(), "the plan must actually fire");
        assert!(m.tenants.iter().all(|t| t.conserves()), "{:?}", m.tenants);
    }

    #[test]
    fn coalesced_beats_per_session_decode() {
        // Same seed, same fleet, same work — only the batching differs.
        // Coalescing S sessions' GEMVs into one M=S chain pays 1/S of
        // the dispatch+prologue overhead and streams B once per round
        // instead of S times.
        let run = |coalesce: bool| {
            let coord =
                Coordinator::start(CoordinatorOptions::fleet(vec![Generation::Xdna2]));
            let opts = LlmOptions { load: small_load(), coalesce, ..Default::default() };
            let r = serve_llm(&coord, &opts).unwrap();
            coord.shutdown().unwrap();
            r
        };
        let co = run(true);
        let un = run(false);
        assert!(co.conserved() && un.conserved());
        assert_eq!(co.tokens_completed, un.tokens_completed, "same work either way");
        assert!(co.mean_batch > 1.5, "load must actually overlap sessions");
        assert!((un.mean_batch - co.mean_batch).abs() < 1e-9, "same round membership");
        // The clean comparison is decode device time: an M=1 and an M=S
        // chain pad to the same native M=64 GEMMs, so a round costs S
        // chains uncoalesced vs 1 coalesced and the ratio approaches the
        // mean batch. (Makespan dilutes this with prefill time and the
        // prefill↔decode design reconfigurations, which both modes pay
        // identically — so it must still strictly improve.)
        let speedup = un.decode_busy_s / co.decode_busy_s;
        assert!(
            speedup >= 1.8,
            "coalescing decode speedup only {speedup:.2}x ({:.4}s vs {:.4}s)",
            co.decode_busy_s,
            un.decode_busy_s
        );
        assert!(co.makespan_s < un.makespan_s);
        assert!(
            co.token_lat_p50_s.unwrap() < un.token_lat_p50_s.unwrap(),
            "per-token latency must drop when the round is one chain"
        );
    }

    #[test]
    fn virtual_time_is_deterministic() {
        let run = || {
            let coord = Coordinator::start(CoordinatorOptions::fleet(vec![
                Generation::Xdna2,
                Generation::Xdna2,
            ]));
            let r = serve_llm(&coord, &LlmOptions { load: small_load(), ..Default::default() })
                .unwrap();
            coord.shutdown().unwrap();
            r
        };
        let a = run();
        let b = run();
        // Routing is deterministic (affinity + least-loaded tie-break),
        // and virtual time contains no wall-clock, so everything down to
        // the latency percentiles replays bit-exact.
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(
            a.token_lat_p99_s.unwrap().to_bits(),
            b.token_lat_p99_s.unwrap().to_bits()
        );
        assert_eq!(a.decode_rounds, b.decode_rounds);
    }

    #[test]
    fn batches_split_at_max_batch() {
        let coord = Coordinator::start(CoordinatorOptions::fleet(vec![Generation::Xdna2]));
        let mut load = small_load();
        load.sessions = 5;
        load.arrival_rate = 10_000.0; // everyone arrives ~at once
        load.decode_tokens = (4, 4);
        let opts = LlmOptions { load, max_batch: 2, ..Default::default() };
        let r = serve_llm(&coord, &opts).unwrap();
        coord.shutdown().unwrap();
        assert!(r.conserved());
        assert_eq!(r.sessions_completed, 5);
        // Chunking caps the chain M at 2, never the round membership.
        assert!(r.mean_batch > 2.0, "round membership {:.1}", r.mean_batch);
    }
}
