//! Layer-3 coordinator: sharded GEMM-as-a-service on a fleet of
//! simulated NPUs.
//!
//! The paper ships a *library* (Sec. 1: "enabling the implementation of
//! high-performance GEMM libraries, similar to GPUs"); this module is
//! that library's serving shape, scaled past one device (DESIGN.md §7,
//! `docs/serving.md`). An admission/router thread buckets requests by
//! design key and forwards each to one of N leader threads — every
//! leader owns one simulated device (generations mixable, XDNA next to
//! XDNA2). The scheduler applies the paper's deployment insight
//! (Sec. 5.3.1) at two levels: requests stick to the device whose
//! design cache already holds their `(precision, layout)` design —
//! spilling to the least-loaded device only when the holder's backlog
//! exceeds a reconfiguration — and each leader sorts its batches by
//! design key so the full 3.4 / 4.9 ms reconfiguration cost is paid
//! only on design switches, which batching minimizes.
//!
//! Whole GEMM *chains* (`crate::plan`) are first-class requests: a
//! chain routes as one unit by its leading design key, lands on one
//! leader with its design cache-hot, and executes back to back with
//! fused L2-resident edges and amortized dispatches; per-chain makespan
//! surfaces in the fleet metrics.
//!
//! The coordinator is hardened for multi-tenant, failure-prone
//! operation (DESIGN.md §12): named tenants with priority classes and
//! admission quotas share the fleet, device leaders are restartable
//! (a killed leader's work requeues bit-exact onto a respawned leader
//! or spills to a sibling), and a deterministic seeded fault plan
//! ([`fault::FaultPlan`], `serve --chaos <seed>`) injects leader
//! deaths, DMA stalls, cache-eviction storms, dropped responses, and
//! silent result corruption.
//!
//! End-to-end result integrity (DESIGN.md §14, `serve --integrity`):
//! every completed result can be checksum-verified
//! ([`crate::gemm::abft`]) or fully recomputed before it is served; a
//! detected corruption triggers a bounded verified recompute at the
//! front of the device queue, surfaces as
//! [`metrics::Integrity::Recovered`] in the response and the tenant's
//! integrity counters, and an exhausted retry budget fails visibly —
//! a corrupt C is never served silently.
//!
//! * [`router`]  — design cache (LRU + hit accounting), device state,
//!   and the fleet's affinity/least-loaded device selector.
//! * [`service`] — admission queue, tenant quotas/priorities, leader
//!   pool + respawn, batching scheduler, backpressure,
//!   drain-on-shutdown.
//! * [`metrics`] — per-request records, per-device aggregates, the
//!   fleet rollup (fleet vs sustained TOPS, latency percentiles), and
//!   per-tenant conservation accounting.
//! * [`fault`]   — the seeded, forward-counter-clocked fault plan.

pub mod fault;
pub mod llm;
pub mod metrics;
pub mod router;
pub mod service;

pub use fault::{FaultEvent, FaultKind, FaultPlan, FaultRecord, CORRUPT_SALT};
pub use llm::{serve_llm, LlmOptions, LlmReport};
pub use metrics::{
    ChainRecord, DeviceMetrics, FleetMetrics, Integrity, Metrics, RequestRecord, TenantStats,
};
pub use router::{
    CacheStats, DesignCache, DesignKey, DeviceState, FleetRouter, MClass, RouteKind,
};
pub use service::{
    expand_mix, functional_a, functional_b, functional_inputs, parse_integrity, parse_mix,
    parse_tenants, Backend, ChainResponse, ChainStaging, Coordinator, CoordinatorOptions,
    GemmRequest, GemmResponse, IntegrityMode, TenantSpec,
};
