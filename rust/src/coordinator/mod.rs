//! Layer-3 coordinator: GEMM-as-a-service on the simulated NPU.
//!
//! The paper ships a *library* (Sec. 1: "enabling the implementation of
//! high-performance GEMM libraries, similar to GPUs"); this module is that
//! library's serving shape: a leader thread owns the device (one NPU:
//! command processor + array), clients submit `GemmRequest`s over
//! channels, and the scheduler applies the paper's deployment insight
//! (Sec. 5.3.1): keep one tuned design per (precision, layout) resident,
//! reconfigure only the two cheap parameters across problem sizes, and
//! charge the full 3.4 / 4.9 ms reconfiguration cost only on design
//! switches — which batching minimizes.
//!
//! * [`router`]  — design cache + device-state reconfiguration accounting.
//! * [`service`] — leader/worker machinery, batching scheduler.
//! * [`metrics`] — per-request records and aggregate statistics.

pub mod metrics;
pub mod router;
pub mod service;

pub use router::{DesignCache, DesignKey};
pub use service::{Backend, Coordinator, CoordinatorOptions, GemmRequest, GemmResponse};
