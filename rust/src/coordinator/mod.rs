//! Layer-3 coordinator: sharded GEMM-as-a-service on a fleet of
//! simulated NPUs.
//!
//! The paper ships a *library* (Sec. 1: "enabling the implementation of
//! high-performance GEMM libraries, similar to GPUs"); this module is
//! that library's serving shape, scaled past one device (DESIGN.md §7,
//! `docs/serving.md`). An admission/router thread buckets requests by
//! design key and forwards each to one of N leader threads — every
//! leader owns one simulated device (generations mixable, XDNA next to
//! XDNA2). The scheduler applies the paper's deployment insight
//! (Sec. 5.3.1) at two levels: requests stick to the device whose
//! design cache already holds their `(precision, layout)` design —
//! spilling to the least-loaded device only when the holder's backlog
//! exceeds a reconfiguration — and each leader sorts its batches by
//! design key so the full 3.4 / 4.9 ms reconfiguration cost is paid
//! only on design switches, which batching minimizes.
//!
//! Whole GEMM *chains* (`crate::plan`) are first-class requests: a
//! chain routes as one unit by its leading design key, lands on one
//! leader with its design cache-hot, and executes back to back with
//! fused L2-resident edges and amortized dispatches; per-chain makespan
//! surfaces in the fleet metrics.
//!
//! * [`router`]  — design cache (LRU + hit accounting), device state,
//!   and the fleet's affinity/least-loaded device selector.
//! * [`service`] — admission queue, leader pool, batching scheduler,
//!   backpressure, drain-on-shutdown.
//! * [`metrics`] — per-request records, per-device aggregates, and the
//!   fleet rollup (fleet vs sustained TOPS, latency percentiles).

pub mod metrics;
pub mod router;
pub mod service;

pub use metrics::{ChainRecord, DeviceMetrics, FleetMetrics, Metrics, RequestRecord};
pub use router::{CacheStats, DesignCache, DesignKey, DeviceState, FleetRouter, RouteKind};
pub use service::{
    expand_mix, functional_a, functional_b, functional_inputs, parse_mix, Backend,
    ChainResponse, ChainStaging, Coordinator, CoordinatorOptions, GemmRequest, GemmResponse,
};
