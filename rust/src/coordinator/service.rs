//! The service itself: a leader thread owning the (simulated) NPU device,
//! worker clients submitting over channels, and a batching scheduler that
//! groups same-design requests to amortize reconfiguration (Sec. 5.3.1).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::arch::Generation;
use crate::dtype::Layout;
use crate::gemm::exec::{Executor, Fidelity};
use crate::gemm::refimpl;
use crate::mem::Matrix;
use crate::sim::{simulate_gemm, BdMode, GemmReport};
use crate::workload::GemmShape;

use super::metrics::{Metrics, RequestRecord};
use super::router::{DesignCache, DesignKey, DeviceState};

/// How requests execute.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Backend {
    /// Timing only (sweeps, tables, load tests).
    SimOnly,
    /// Timing + real numerics through the functional executor, verified
    /// against the reference when `verify` is set.
    Functional,
}

#[derive(Clone, Debug)]
pub struct GemmRequest {
    pub shape: GemmShape,
    /// Input images for `Backend::Functional` (None → generated inputs).
    pub data: Option<(Matrix, Matrix)>,
    /// Check the functional result against `refimpl` (expensive).
    pub verify: bool,
    pub bd_mode: BdMode,
}

impl GemmRequest {
    pub fn sim(shape: GemmShape) -> GemmRequest {
        GemmRequest { shape, data: None, verify: false, bd_mode: BdMode::Overlapped }
    }
}

#[derive(Debug)]
pub struct GemmResponse {
    pub id: u64,
    pub name: String,
    /// Simulated performance report (padded sizes, phase times, TOPS).
    pub sim: GemmReport,
    /// Device seconds including any design reconfiguration.
    pub device_s: f64,
    pub reconfigured: bool,
    pub verified: Option<bool>,
    /// Functional result (when requested).
    pub result: Option<Matrix>,
}

#[derive(Clone, Copy, Debug)]
pub struct CoordinatorOptions {
    pub gen: Generation,
    pub backend: Backend,
    /// Scheduler batching window: how many queued requests are drained
    /// and design-grouped per scheduling round.
    pub batch_window: usize,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            gen: Generation::Xdna2,
            backend: Backend::SimOnly,
            batch_window: 16,
        }
    }
}

enum Msg {
    Submit(u64, GemmRequest, Sender<GemmResponse>, Instant),
    Flush(Sender<Metrics>),
    Shutdown,
}

/// Handle to a running coordinator (leader thread).
pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<Metrics>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    pub fn start(opts: CoordinatorOptions) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::spawn(move || leader_loop(opts, rx));
        Coordinator { tx, handle: Some(handle), next_id: 0.into() }
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: GemmRequest) -> Receiver<GemmResponse> {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Submit(id, req, rtx, Instant::now()))
            .expect("coordinator thread alive");
        rrx
    }

    /// Blocking convenience wrapper.
    pub fn call(&self, req: GemmRequest) -> Result<GemmResponse> {
        self.submit(req).recv().map_err(|e| anyhow!("coordinator dropped: {e}"))
    }

    /// Snapshot current metrics.
    pub fn metrics(&self) -> Result<Metrics> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Flush(tx)).map_err(|e| anyhow!("send: {e}"))?;
        rx.recv().map_err(|e| anyhow!("recv: {e}"))
    }

    /// Stop the leader and return final metrics.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle.take().unwrap().join().expect("leader panicked")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

type Pending = (u64, GemmRequest, Sender<GemmResponse>, Instant);

fn leader_loop(opts: CoordinatorOptions, rx: Receiver<Msg>) -> Metrics {
    let cache = DesignCache::new(opts.gen);
    let mut device = DeviceState::default();
    let mut metrics = Metrics::default();

    loop {
        // Block for the first message, then drain up to the batch window.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        let mut batch: Vec<Pending> = Vec::new();
        let mut shutdown = false;
        let mut handle_msg = |m: Msg, batch: &mut Vec<Pending>, metrics: &mut Metrics| match m {
            Msg::Submit(id, req, tx, t0) => batch.push((id, req, tx, t0)),
            Msg::Flush(tx) => {
                let _ = tx.send(metrics.clone());
            }
            Msg::Shutdown => shutdown = true,
        };
        handle_msg(first, &mut batch, &mut metrics);
        while batch.len() < opts.batch_window {
            match rx.try_recv() {
                Ok(m) => handle_msg(m, &mut batch, &mut metrics),
                Err(_) => break,
            }
        }

        // Size-class batching: stable-group by design key so a burst of
        // mixed-precision traffic pays each reconfiguration once.
        batch.sort_by_key(|(id, req, _, _)| {
            (
                req.shape.precision,
                req.shape.b_layout == Layout::ColMajor,
                *id,
            )
        });

        for (id, req, tx, t0) in batch {
            let key = DesignKey { precision: req.shape.precision, b_layout: req.shape.b_layout };
            let cfg = *cache.get(key);
            let reconfig_s = device.switch_to(opts.gen, key);
            let sim = simulate_gemm(&cfg, req.shape.m, req.shape.k, req.shape.n, req.bd_mode);

            let (result, verified) = match opts.backend {
                Backend::SimOnly => (None, None),
                Backend::Functional => run_functional(&cfg, &req),
            };

            let device_s = sim.t_total + reconfig_s;
            let resp = GemmResponse {
                id,
                name: req.shape.name.clone(),
                sim,
                device_s,
                reconfigured: reconfig_s > 0.0,
                verified,
                result,
            };
            metrics.push(RequestRecord {
                id,
                name: req.shape.name.clone(),
                device_s,
                host_latency_s: t0.elapsed().as_secs_f64(),
                ops: req.shape.ops(),
                reconfigured: reconfig_s > 0.0,
                verified,
            });
            let _ = tx.send(resp);
        }

        if shutdown {
            break;
        }
    }
    metrics
}

fn run_functional(cfg: &crate::tiling::TilingConfig, req: &GemmRequest) -> (Option<Matrix>, Option<bool>) {
    let p = cfg.precision;
    let (a, b) = match &req.data {
        Some((a, b)) => (a.clone(), b.clone()),
        None => {
            let mut a = Matrix::zeroed(req.shape.m, req.shape.k, p.ty_in(), Layout::RowMajor)
                .expect("aligned");
            let mut b = Matrix::zeroed(req.shape.k, req.shape.n, p.ty_in(), req.shape.b_layout)
                .expect("aligned");
            refimpl::fill_random(&mut a, p, req.shape.m as u64 ^ 0xA5A5);
            refimpl::fill_random(&mut b, p, req.shape.n as u64 ^ 0x5A5A);
            (a, b)
        }
    };
    let exec = Executor::new(*cfg, Fidelity::Direct);
    match exec.execute(&a, &b) {
        Ok(c) => {
            let verified = if req.verify {
                let want = refimpl::ref_gemm(&a, &b, p).expect("ref");
                Some(refimpl::matrices_equal(&c, &want, p))
            } else {
                None
            };
            (Some(c), verified)
        }
        Err(_) => (None, Some(false)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Precision;
    use crate::workload::{GemmShape, TransformerConfig};

    #[test]
    fn sim_requests_round_trip() {
        let c = Coordinator::start(CoordinatorOptions::default());
        let resp = c
            .call(GemmRequest::sim(GemmShape::new("t", 4096, 4320, 4480, Precision::I8I16)))
            .unwrap();
        assert!(resp.sim.tops > 25.0, "{}", resp.sim.tops);
        assert!(resp.reconfigured, "first request loads the design");
        let resp2 = c
            .call(GemmRequest::sim(GemmShape::new("t2", 4096, 4320, 4480, Precision::I8I16)))
            .unwrap();
        assert!(!resp2.reconfigured, "design reused");
        let m = c.shutdown();
        assert_eq!(m.count(), 2);
        assert_eq!(m.reconfigurations(), 1);
    }

    #[test]
    fn transformer_trace_reuses_designs() {
        // Sec. 5.3.1: one design serves all layer shapes; only the first
        // request reconfigures.
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna,
            ..Default::default()
        });
        let trace = TransformerConfig { seq: 512, ..Default::default() }.trace();
        let n = trace.len();
        let rxs: Vec<_> = trace.into_iter().map(|g| c.submit(GemmRequest::sim(g))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let m = c.shutdown();
        assert_eq!(m.count(), n);
        assert_eq!(m.reconfigurations(), 1);
        assert!(m.device_tops() > 1.0);
    }

    #[test]
    fn batching_groups_mixed_precisions() {
        // 4 precisions interleaved 4x: FIFO would reconfigure 16 times;
        // the batching scheduler pays ~4 (one per design) when requests
        // arrive together.
        let c = Coordinator::start(CoordinatorOptions {
            batch_window: 32,
            ..Default::default()
        });
        let mut rxs = Vec::new();
        for round in 0..4 {
            for p in Precision::ALL {
                let g = GemmShape::new(&format!("r{round}-{p}"), 1024, 1024, 1024, p);
                rxs.push(c.submit(GemmRequest::sim(g)));
            }
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let m = c.shutdown();
        assert_eq!(m.count(), 16);
        assert!(
            m.reconfigurations() <= 8,
            "batching should coalesce designs: {} reconfigs",
            m.reconfigurations()
        );
    }

    #[test]
    fn functional_backend_verifies() {
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna,
            backend: Backend::Functional,
            ..Default::default()
        });
        // Tiny shape (pads to one native tile of the balanced design).
        let mut req = GemmRequest::sim(GemmShape::new("fv", 64, 64, 64, Precision::I8I8));
        req.verify = true;
        let resp = c.call(req).unwrap();
        assert_eq!(resp.verified, Some(true));
        let out = resp.result.unwrap();
        assert_eq!((out.rows, out.cols), (64, 64));
        c.shutdown();
    }
}
