//! The service itself: an admission/router thread fronting a pool of
//! leader threads, one per simulated NPU device.
//!
//! Clients submit over a bounded channel (admission backpressure); the
//! router buckets each request by its [`DesignKey`] and forwards it to
//! the device chosen by the [`FleetRouter`] — the device already holding
//! the design when its backlog allows, the least-loaded device otherwise
//! (Sec. 5.3.1 applied fleet-wide). Each leader owns its device
//! (design cache + loaded-design state), drains its queue in batches,
//! and sorts every batch by design key so a burst of mixed-precision
//! traffic pays each reconfiguration once. The router keeps at most
//! `max_in_flight` requests outstanding per device; completions flow
//! back to refill the window, and shutdown drains every queue before
//! the leaders exit.
//!
//! # Multi-tenant hardening (ISSUE 6)
//!
//! The coordinator serves several named [`TenantSpec`]s at once: each
//! tenant has a priority class (higher preempts lower in the per-device
//! queues) and an admission quota (at most `quota` units in flight; the
//! excess waits in a per-tenant backlog drained highest-priority-first
//! as completions free slots). Per-tenant accounting lands in
//! [`super::metrics::TenantStats`] with the conservation invariant
//! `completed + failed + pending == submitted`.
//!
//! Leaders are **restartable**: a leader killed by the fault layer (or
//! panicked by a poisoned unit) hands its unexecuted units and its
//! receive channel back to the router, which respawns a fresh leader on
//! the same channel and requeues the units at the front of the device
//! queue — staged-tensor state lives in the unit itself
//! ([`ChainStaging`]), so re-execution is bit-exact. Once a device's
//! respawn budget is exhausted it leaves the fleet and its work spills
//! to sibling devices (or fails visibly when none remain). The
//! deterministic fault plan itself is [`super::fault::FaultPlan`].

use std::collections::VecDeque;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::arch::Generation;
use crate::dtype::{Layout, Precision};
use crate::dtype_split;
use crate::gemm::abft::{self, AbftChecksums};
use crate::gemm::exec::{ExecOptions, Executor};
use crate::gemm::refimpl;
use crate::mem::Matrix;
use crate::plan::{overrides_for, GemmChain};
use crate::sim::{simulate_gemm, simulate_gemm_with, BdMode, GemmReport};
use crate::tiling::TilingConfig;
use crate::trace::model::{DispatchFact, RequeueReason, TraceFact};
use crate::trace::{roofline, Recorder};
use crate::workload::GemmShape;

use super::fault::{FaultKind, FaultPlan, FaultRecord};
use super::metrics::{
    ChainRecord, DeviceMetrics, FleetMetrics, Integrity, Metrics, RequestRecord, TenantStats,
};
use super::router::{CacheStats, DesignCache, DesignKey, DeviceState, FleetRouter};

/// How requests execute.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Backend {
    /// Timing only (sweeps, tables, load tests).
    SimOnly,
    /// Timing + real numerics through the functional executor, verified
    /// against the reference when `verify` is set.
    Functional,
}

#[derive(Clone, Debug)]
pub struct GemmRequest {
    pub shape: GemmShape,
    /// Input images for `Backend::Functional` (None → generated inputs).
    pub data: Option<(Matrix, Matrix)>,
    /// Check the functional result against `refimpl` (expensive).
    pub verify: bool,
    pub bd_mode: BdMode,
    /// Test hook (the chaos suite's genuine-panic containment tests):
    /// the executing leader panics on this unit. Always `false` outside
    /// tests.
    #[doc(hidden)]
    pub poison: bool,
    /// Test hook (the integrity suite): XOR a deterministic bit pattern
    /// into this many of the unit's first execution attempts' C images,
    /// before the integrity check runs. The count decrements per
    /// attempt, so `corrupt: 1` yields one corrupted execution followed
    /// by a clean verified recompute. Always `0` outside tests.
    #[doc(hidden)]
    pub corrupt: u8,
}

impl GemmRequest {
    pub fn sim(shape: GemmShape) -> GemmRequest {
        GemmRequest {
            shape,
            data: None,
            verify: false,
            bd_mode: BdMode::Overlapped,
            poison: false,
            corrupt: 0,
        }
    }
}

/// One completed chain (`Coordinator::submit_chain`): every op ran back
/// to back on one device, fused edges kept the intermediate C in L2,
/// and same-design ops rode the first op's host submission.
#[derive(Debug)]
pub struct ChainResponse {
    pub id: u64,
    pub name: String,
    /// Fleet device index that served the whole chain.
    pub device: usize,
    /// Chain makespan: summed device seconds including reconfigurations.
    pub device_s: f64,
    pub fused_edges: usize,
    pub elided_dispatches: usize,
    /// Per-op simulation reports, in chain order.
    pub reports: Vec<GemmReport>,
    /// Final op's functional C (`Backend::Functional` only): each
    /// producer→consumer edge fed the staged C straight into the packed
    /// executor as the next op's A. `None` if any op's functional
    /// execution failed (the failing op's record carries
    /// [`Integrity::Failed`]).
    pub result: Option<Matrix>,
    /// Edges where a staged functional C actually fed an op's A: the
    /// chain's internal `consumes_prev` edges, plus the submission's
    /// entry A when one was staged (`ChainStaging::a0`).
    pub staged_edges: usize,
    /// Chain-level integrity outcome: `Failed` if any op failed,
    /// `Recovered` if the whole chain was recomputed after a detected
    /// corruption, `Passed` when checks ran clean, `NotChecked`
    /// otherwise.
    pub integrity: Integrity,
}

#[derive(Debug)]
pub struct GemmResponse {
    pub id: u64,
    pub name: String,
    /// Fleet device index that served the request.
    pub device: usize,
    /// Simulated performance report (padded sizes, phase times, TOPS).
    pub sim: GemmReport,
    /// Device seconds including any design reconfiguration.
    pub device_s: f64,
    pub reconfigured: bool,
    /// End-to-end integrity outcome for this result: the coordinator's
    /// configured check ([`CoordinatorOptions::integrity`]) plus the
    /// request's own `verify` reference check.
    pub integrity: Integrity,
    /// Functional result (when requested). `None` on execution failure
    /// — or when an integrity mismatch exhausted its retry budget: a
    /// corrupted C is never served.
    pub result: Option<Matrix>,
}

impl GemmResponse {
    /// Legacy tri-state view of [`Self::integrity`] (`None` = never
    /// checked). Kept for one release for callers of the old
    /// `verified` field.
    pub fn verified(&self) -> Option<bool> {
        self.integrity.into()
    }
}

/// One named tenant sharing the fleet (`serve --tenants`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantSpec {
    pub name: String,
    /// Priority class: higher-priority units preempt lower ones in every
    /// device queue (decode-style traffic ahead of batch prefill).
    pub priority: u8,
    /// Max in-flight units for this tenant (0 = unbounded). Excess
    /// admissions wait in a per-tenant backlog, drained
    /// highest-priority-first as completions free slots.
    pub quota: usize,
}

impl Default for TenantSpec {
    fn default() -> Self {
        TenantSpec { name: "default".to_string(), priority: 0, quota: 0 }
    }
}

/// Parse a `--tenants` spec: comma-separated `name[:priority[:quota]]`,
/// e.g. `decode:2:8,prefill:0:32`.
pub fn parse_tenants(s: &str) -> Result<Vec<TenantSpec>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let mut parts = tok.split(':');
        let name = parts.next().unwrap_or("").trim();
        if name.is_empty() {
            bail!("empty tenant name in '{s}'");
        }
        let priority = match parts.next() {
            Some(p) => p
                .trim()
                .parse::<u8>()
                .map_err(|_| anyhow!("tenant '{name}': priority '{p}' is not a u8"))?,
            None => 0,
        };
        let quota = match parts.next() {
            Some(q) => q
                .trim()
                .parse::<usize>()
                .map_err(|_| anyhow!("tenant '{name}': quota '{q}' is not an integer"))?,
            None => 0,
        };
        if parts.next().is_some() {
            bail!("tenant '{tok}': expected name[:priority[:quota]]");
        }
        out.push(TenantSpec { name: name.to_string(), priority, quota });
    }
    if out.is_empty() {
        bail!("empty tenant spec '{s}'");
    }
    Ok(out)
}

/// Which end-to-end integrity check runs on every completed result
/// (`serve --integrity`). Orthogonal to [`GemmRequest::verify`], the
/// per-request reference check that reports but never retries.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum IntegrityMode {
    /// No result checking: results — corrupted or not — are served
    /// exactly as produced.
    #[default]
    Off,
    /// ABFT checksum verification ([`crate::gemm::abft`]):
    /// `O(mk + kn + mn)` checksum work per result instead of the full
    /// `O(mkn)` recompute, with a bounded verified-recompute retry on
    /// mismatch.
    Abft,
    /// Full reference recompute per result (`refimpl::ref_gemm`) — the
    /// expensive baseline ABFT is measured against.
    Full,
}

/// Parse a `--integrity` flag value: `off`, `abft`, or `full`.
pub fn parse_integrity(s: &str) -> Result<IntegrityMode> {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" | "none" => Ok(IntegrityMode::Off),
        "abft" | "checksum" => Ok(IntegrityMode::Abft),
        "full" | "verify" => Ok(IntegrityMode::Full),
        other => bail!("unknown integrity mode '{other}' (expected off|abft|full)"),
    }
}

#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    /// Generation of the single device when `devices` is empty.
    pub gen: Generation,
    pub backend: Backend,
    /// Scheduler batching window: how many queued requests a leader
    /// drains and design-groups per scheduling round.
    pub batch_window: usize,
    /// Device fleet: one leader thread per entry, generations mixable
    /// (`serve --devices N --mix xdna:xdna2`). Empty → `vec![gen]`.
    pub devices: Vec<Generation>,
    /// Bounded per-device in-flight window: the router keeps at most
    /// this many requests forwarded to a leader at once; excess requests
    /// wait in the router's per-device queue, where routing decisions
    /// can still see (and rebalance around) the backlog.
    pub max_in_flight: usize,
    /// Per-device design-cache capacity (0 = unbounded). The fleet
    /// router mirrors this bound, so affinity is forgotten when a
    /// leader's cache would have evicted the design.
    pub design_capacity: usize,
    /// Admission-channel bound: `submit` blocks once this many messages
    /// are in transit to the router. Note this caps the client→router
    /// pipe, not total queued work — the router drains it continuously
    /// (completions share the channel), so its per-device queues grow
    /// without bound if producers outpace the fleet indefinitely.
    pub admission_capacity: usize,
    /// Worker threads for the functional executor's output-tile fan-out
    /// (`serve --functional --threads T`). Results are bit-identical for
    /// every value (`gemm::exec::ExecOptions::threads`).
    pub exec_threads: usize,
    /// Named tenants sharing the fleet (`serve --tenants`). Empty →
    /// one implicit unbounded "default" tenant at priority 0; every
    /// `submit` goes to tenant 0 unless `submit_for` says otherwise.
    pub tenants: Vec<TenantSpec>,
    /// Deterministic fault-injection plan (`serve --chaos <seed>`).
    /// `None` disables the chaos layer entirely.
    pub chaos: Option<FaultPlan>,
    /// How many times each device's leader may be respawned after a
    /// (injected or genuine) death before the device is marked dead and
    /// its work spills to sibling devices.
    pub max_leader_respawns: usize,
    /// End-to-end result integrity checking (`serve --integrity`):
    /// every completed result is validated before it is served, and a
    /// mismatch triggers a bounded verified recompute at the front of
    /// the device queue. Under `Backend::SimOnly` only the check's
    /// modeled cost lands on the device clock.
    pub integrity: IntegrityMode,
    /// How many verified-recompute retries an integrity mismatch may
    /// consume before the unit fails visibly ([`Integrity::Failed`],
    /// `result: None`) — a corrupted result is never served silently.
    pub max_integrity_retries: usize,
    /// The flight recorder (`serve --trace-out`): every clone —
    /// router and leaders alike — feeds one shared fact sink. The
    /// default [`Recorder::Off`] costs a discriminant test and zero
    /// allocations per unit (DESIGN.md §16).
    pub recorder: Recorder,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            gen: Generation::Xdna2,
            backend: Backend::SimOnly,
            batch_window: 16,
            devices: Vec::new(),
            max_in_flight: 64,
            design_capacity: 0,
            admission_capacity: 4096,
            exec_threads: 1,
            tenants: Vec::new(),
            chaos: None,
            max_leader_respawns: 16,
            integrity: IntegrityMode::Off,
            max_integrity_retries: 2,
            recorder: Recorder::Off,
        }
    }
}

impl CoordinatorOptions {
    /// Options for an explicit device fleet.
    pub fn fleet(devices: Vec<Generation>) -> CoordinatorOptions {
        CoordinatorOptions { devices, ..Default::default() }
    }

    /// The resolved fleet (at least one device).
    pub fn device_gens(&self) -> Vec<Generation> {
        if self.devices.is_empty() {
            vec![self.gen]
        } else {
            self.devices.clone()
        }
    }

    /// The resolved tenant list (at least the implicit default tenant).
    pub fn tenant_specs(&self) -> Vec<TenantSpec> {
        if self.tenants.is_empty() {
            vec![TenantSpec::default()]
        } else {
            self.tenants.clone()
        }
    }
}

/// Parse a `--mix` pattern like `xdna:xdna2` (also accepts commas) into
/// a generation cycle.
pub fn parse_mix(s: &str) -> Result<Vec<Generation>> {
    let mut out = Vec::new();
    for tok in s.split(|c: char| c == ':' || c == ',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        match Generation::parse(tok) {
            Some(g) => out.push(g),
            None => bail!("unknown generation '{tok}' in mix '{s}'"),
        }
    }
    if out.is_empty() {
        bail!("empty device mix '{s}'");
    }
    Ok(out)
}

/// Cycle `pattern` to fill `n` device slots: `expand_mix(&[Xdna, Xdna2],
/// 4)` → `[Xdna, Xdna2, Xdna, Xdna2]`. An empty pattern yields an empty
/// fleet (callers fall back to `CoordinatorOptions::gen`).
pub fn expand_mix(pattern: &[Generation], n: usize) -> Vec<Generation> {
    if pattern.is_empty() {
        return Vec::new();
    }
    (0..n).map(|i| pattern[i % pattern.len()]).collect()
}

/// A submitted request travelling router → leader.
struct Pending {
    id: u64,
    tenant: usize,
    req: GemmRequest,
    tx: Sender<GemmResponse>,
    t0: Instant,
    /// Set when the unit has been requeued (leader death / dropped
    /// response): requeued units do not re-advance the fault clock.
    requeued: bool,
    /// Verified-recompute attempts already consumed by integrity
    /// mismatches, bounded by
    /// [`CoordinatorOptions::max_integrity_retries`].
    integrity_retries: u32,
}

/// DAG-aware chain submission context (`Coordinator::submit_chain_staged`,
/// used by the graph compiler's `graph::exec::serve_graph`): pin the
/// chain to a partitioner-chosen device, and/or stage a producer's C as
/// the chain's entry A — the cross-chain edges of `graph::lower`, where
/// one C may fan out into several consumers' A or arrive pre-joined.
#[derive(Debug, Default)]
pub struct ChainStaging {
    /// Fleet device index to place the chain on (bypasses the router's
    /// affinity choice; load accounting still applies). `None` routes by
    /// leading design key as before. A pin to a device that has since
    /// died falls back to free routing.
    pub device: Option<usize>,
    /// Entry A for the chain's first op under `Backend::Functional`: a
    /// staged producer C (or an elementwise join of several). `None`
    /// falls back to the deterministic generated A.
    pub a0: Option<Matrix>,
    /// ABFT checksums the producer captured over `a0`
    /// (`graph::exec::serve_graph` attaches them): the consuming leader
    /// re-validates the staged image before executing on it, so a
    /// corrupted cross-chain edge is detected at the edge instead of
    /// silently feeding every downstream op. `None` skips the edge
    /// check.
    pub a0_sums: Option<AbftChecksums>,
}

/// A submitted chain travelling router → leader as one unit. The staged
/// entry A rides inside, so a requeued chain re-derives the identical
/// functional dataflow on the respawned (or sibling) leader.
struct PendingChain {
    id: u64,
    tenant: usize,
    chain: GemmChain,
    bd_mode: BdMode,
    staging: ChainStaging,
    tx: Sender<ChainResponse>,
    t0: Instant,
    requeued: bool,
    /// Whole-chain verified-recompute attempts consumed by integrity
    /// mismatches (the chain re-derives its staged dataflow from
    /// `staging`, so recovery is bit-exact).
    integrity_retries: u32,
}

/// One schedulable unit in a router queue / leader batch: a single
/// request or a whole chain (which stays contiguous and in order).
enum Unit {
    Req(Box<Pending>),
    Chain(Box<PendingChain>),
}

impl Unit {
    /// In-flight slots / record count this unit accounts for.
    fn len(&self) -> usize {
        match self {
            Unit::Req(_) => 1,
            Unit::Chain(c) => c.chain.len(),
        }
    }

    /// Design-grouping sort key (chains group by their leading op).
    fn sort_key(&self) -> (Precision, bool, u64) {
        match self {
            Unit::Req(p) => {
                (p.req.shape.precision, p.req.shape.b_layout == Layout::ColMajor, p.id)
            }
            Unit::Chain(c) => {
                let s = &c.chain.ops[0].shape;
                (s.precision, s.b_layout == Layout::ColMajor, c.id)
            }
        }
    }

    fn tenant(&self) -> usize {
        match self {
            Unit::Req(p) => p.tenant,
            Unit::Chain(c) => c.tenant,
        }
    }

    /// Coordinator-assigned unit id (request or chain id) — the span
    /// identity the flight recorder keys facts on.
    fn id(&self) -> u64 {
        match self {
            Unit::Req(p) => p.id,
            Unit::Chain(c) => c.id,
        }
    }

    fn was_requeued(&self) -> bool {
        match self {
            Unit::Req(p) => p.requeued,
            Unit::Chain(c) => c.requeued,
        }
    }

    fn mark_requeued(&mut self) {
        match self {
            Unit::Req(p) => p.requeued = true,
            Unit::Chain(c) => c.requeued = true,
        }
    }
}

/// Leader → router batch acknowledgement.
struct BatchReport {
    dev: usize,
    records: Vec<RequestRecord>,
    chains: Vec<ChainRecord>,
    cache: CacheStats,
    /// The leader's authoritative design-cache LRU state for residency
    /// reconciliation (empty on leader death — the cache died with it).
    resident: Vec<DesignKey>,
    /// In-flight slots retired by this batch: executed units plus
    /// panicked units (which produce no records but leave the window).
    retired: usize,
    /// Admission outcome per retired unit: `(tenant, failed)` where
    /// `failed` means the unit produced no response (panicked leader).
    completions: Vec<(usize, bool)>,
    /// Units the leader did not execute (dropped responses, or the
    /// remainder of a killed batch) — the router requeues them.
    requeue: Vec<Unit>,
}

enum Msg {
    Submit(Box<Pending>),
    SubmitChain(Box<PendingChain>),
    Warm(DesignKey),
    Flush(Sender<FleetMetrics>),
    /// Leader → router: a batch completed.
    Done(BatchReport),
    /// Leader → router: the leader died (fault-injected kill). Carries
    /// the batch accounting like `Done`, plus the leader's receive
    /// channel so a respawned leader inherits any units still in transit
    /// — nothing in the channel is lost.
    LeaderDown(BatchReport, Receiver<DeviceMsg>),
    Shutdown,
}

enum DeviceMsg {
    Run(Box<Pending>, Option<FaultKind>),
    RunChain(Box<PendingChain>, Option<FaultKind>),
    Warm(DesignKey),
    Shutdown,
}

/// Handle to a running coordinator (router thread + leader pool).
pub struct Coordinator {
    tx: SyncSender<Msg>,
    handle: Option<JoinHandle<FleetMetrics>>,
    next_id: std::sync::atomic::AtomicU64,
    n_devices: usize,
    n_tenants: usize,
    recorder: Recorder,
}

impl Coordinator {
    pub fn start(opts: CoordinatorOptions) -> Coordinator {
        let n_devices = opts.device_gens().len();
        let n_tenants = opts.tenant_specs().len();
        let recorder = opts.recorder.clone();
        let (tx, rx) = sync_channel::<Msg>(opts.admission_capacity.max(1));
        let done_tx = tx.clone();
        let handle = std::thread::spawn(move || router_loop(opts, rx, done_tx));
        Coordinator { tx, handle: Some(handle), next_id: 0.into(), n_devices, n_tenants, recorder }
    }

    /// Devices in the running fleet.
    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// The fleet's flight recorder (shares the sink with every leader).
    pub fn recorder(&self) -> &Recorder {
        &self.recorder
    }

    /// Configured tenants (1 when only the implicit default exists).
    pub fn n_tenants(&self) -> usize {
        self.n_tenants
    }

    /// Submit a request as the default tenant (0); the response arrives
    /// on the returned channel. Blocks only when the admission queue is
    /// full (backpressure). `Err` when the router is down — a dead
    /// coordinator is a typed error, never a caller abort.
    pub fn submit(&self, req: GemmRequest) -> Result<Receiver<GemmResponse>> {
        self.submit_for(0, req)
    }

    /// Submit a request on behalf of tenant `tenant` (an index into
    /// `CoordinatorOptions::tenants`).
    pub fn submit_for(&self, tenant: usize, req: GemmRequest) -> Result<Receiver<GemmResponse>> {
        if tenant >= self.n_tenants {
            bail!("tenant {tenant} out of range ({} tenants)", self.n_tenants);
        }
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Submit(Box::new(Pending {
                id,
                tenant,
                req,
                tx: rtx,
                t0: Instant::now(),
                requeued: false,
                integrity_retries: 0,
            })))
            .map_err(|_| anyhow!("coordinator is down (router thread exited)"))?;
        Ok(rrx)
    }

    /// Blocking convenience wrapper.
    pub fn call(&self, req: GemmRequest) -> Result<GemmResponse> {
        self.submit(req)?.recv().map_err(|e| anyhow!("coordinator dropped: {e}"))
    }

    /// Submit a whole chain: the router places it on one device by its
    /// leading design key (chain affinity — the design stays cache-hot
    /// for the entire run), and the leader executes the ops back to
    /// back, fusing L2-resident edges and amortizing same-design
    /// dispatches exactly like the offline planner
    /// (`crate::plan::overrides_for` against the leader's own design
    /// cache). Chains ride the timing path (`Backend::SimOnly`
    /// semantics); the functional staged-C path is
    /// `gemm::exec::Executor::execute_chain`.
    pub fn submit_chain(&self, chain: GemmChain) -> Result<Receiver<ChainResponse>> {
        self.submit_chain_staged_for(0, chain, ChainStaging::default())
    }

    /// [`Self::submit_chain`] on behalf of a specific tenant.
    pub fn submit_chain_for(
        &self,
        tenant: usize,
        chain: GemmChain,
    ) -> Result<Receiver<ChainResponse>> {
        self.submit_chain_staged_for(tenant, chain, ChainStaging::default())
    }

    /// The DAG-aware chain entry point (`graph::lower` cross-chain
    /// edges): like [`Self::submit_chain`], but the chain may be pinned
    /// to a specific device (the graph partitioner's placement) and may
    /// carry a staged entry A — a producer chain's functional C, cloned
    /// per consumer on fan-out or elementwise-joined on fan-in, instead
    /// of `consumes_prev`-only staging. The staged A must match the
    /// first op's logical `m × k` as a row-major image.
    pub fn submit_chain_staged(
        &self,
        chain: GemmChain,
        staging: ChainStaging,
    ) -> Result<Receiver<ChainResponse>> {
        self.submit_chain_staged_for(0, chain, staging)
    }

    /// [`Self::submit_chain_staged`] on behalf of a specific tenant.
    pub fn submit_chain_staged_for(
        &self,
        tenant: usize,
        chain: GemmChain,
        staging: ChainStaging,
    ) -> Result<Receiver<ChainResponse>> {
        if tenant >= self.n_tenants {
            bail!("tenant {tenant} out of range ({} tenants)", self.n_tenants);
        }
        if chain.is_empty() {
            bail!("empty chain '{}'", chain.name);
        }
        if let Some(d) = staging.device {
            if d >= self.n_devices {
                bail!("device {d} out of range (fleet has {})", self.n_devices);
            }
        }
        if let Some(a0) = &staging.a0 {
            let first = &chain.ops[0].shape;
            let (rows, cols) = refimpl::logical_dims(a0);
            if a0.layout != Layout::RowMajor || (rows, cols) != (first.m, first.k) {
                bail!(
                    "staged A is {rows}x{cols} {:?}, first op '{}' needs row-major {}x{}",
                    a0.layout,
                    first.name,
                    first.m,
                    first.k
                );
            }
            // Element format must match the op's *logical* input dtype —
            // a mis-typed image would otherwise be reinterpreted as raw
            // bytes and silently produce a wrong C. Note the shape's own
            // precision, not the design key's: fp32_split normalizes to
            // the bf16 design but stages 4-byte f32 images.
            let p = first.precision;
            let type_ok = if p == Precision::Bfp16 {
                a0.is_bfp16()
            } else {
                !a0.is_bfp16() && a0.elem_bytes == p.ty_in()
            };
            if !type_ok {
                bail!(
                    "staged A has {}-byte elements, first op '{}' is {p} \
                     (expects {})",
                    a0.elem_bytes,
                    first.name,
                    if p == Precision::Bfp16 {
                        "12-byte block cells".to_string()
                    } else {
                        format!("{}-byte elements", p.ty_in())
                    }
                );
            }
        }
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::SubmitChain(Box::new(PendingChain {
                id,
                tenant,
                chain,
                bd_mode: BdMode::Overlapped,
                staging,
                tx: rtx,
                t0: Instant::now(),
                requeued: false,
                integrity_retries: 0,
            })))
            .map_err(|_| anyhow!("coordinator is down (router thread exited)"))?;
        Ok(rrx)
    }

    /// Blocking convenience wrapper for [`Self::submit_chain`].
    pub fn call_chain(&self, chain: GemmChain) -> Result<ChainResponse> {
        self.submit_chain(chain)?.recv().map_err(|e| anyhow!("coordinator dropped: {e}"))
    }

    /// Pre-load `key`'s design onto a device off the request path: the
    /// router records the affinity and the chosen leader reconfigures
    /// immediately, so the first real request for `key` pays no
    /// reconfiguration.
    pub fn warm(&self, key: DesignKey) {
        let _ = self.tx.send(Msg::Warm(key));
    }

    /// Snapshot current fleet metrics.
    pub fn metrics(&self) -> Result<FleetMetrics> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Flush(tx)).map_err(|e| anyhow!("send: {e}"))?;
        rx.recv().map_err(|e| anyhow!("recv: {e}"))
    }

    /// Stop accepting work, drain every queue, stop the leaders, and
    /// return the final fleet metrics. A router thread that itself
    /// panicked surfaces as a typed `Err`, not a caller abort.
    pub fn shutdown(mut self) -> Result<FleetMetrics> {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle
            .take()
            .expect("router handle present until shutdown/drop")
            .join()
            .map_err(|_| anyhow!("coordinator router panicked"))
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Per-device router queue split into priority lanes: pop serves the
/// highest non-empty class first, FIFO within a class; requeued units
/// re-enter at the *front* of their class so a leader death never
/// reorders a tenant's stream behind later submissions.
struct PrioQueue {
    /// `lanes[p]` holds priority-`p` units; pop scans from the back.
    lanes: Vec<VecDeque<Unit>>,
}

impl PrioQueue {
    fn new(classes: usize) -> PrioQueue {
        PrioQueue { lanes: (0..classes.max(1)).map(|_| VecDeque::new()).collect() }
    }

    fn lane(&self, prio: usize) -> usize {
        prio.min(self.lanes.len() - 1)
    }

    fn push_back(&mut self, prio: usize, unit: Unit) {
        let l = self.lane(prio);
        self.lanes[l].push_back(unit);
    }

    fn push_front(&mut self, prio: usize, unit: Unit) {
        let l = self.lane(prio);
        self.lanes[l].push_front(unit);
    }

    fn pop(&mut self) -> Option<Unit> {
        self.lanes.iter_mut().rev().find_map(VecDeque::pop_front)
    }

    fn is_empty(&self) -> bool {
        self.lanes.iter().all(VecDeque::is_empty)
    }
}

/// The router thread's whole state: fleet model, per-device queues and
/// windows, tenant admission, leader lifecycle, and the fault clock.
struct RouterCore {
    opts: CoordinatorOptions,
    gens: Vec<Generation>,
    n_dev: usize,
    max_in_flight: usize,
    specs: Vec<TenantSpec>,
    /// Tenant indices in backlog-drain order: priority desc, index asc.
    tenant_order: Vec<usize>,
    fleet: FleetRouter,
    queues: Vec<PrioQueue>,
    in_flight: Vec<usize>,
    per_dev: Vec<Metrics>,
    caches: Vec<CacheStats>,
    /// Cache stats accumulated by each device's *dead* leaders — a
    /// respawned leader starts a fresh cache, so its stats are summed
    /// onto this base.
    cache_base: Vec<CacheStats>,
    chain_records: Vec<ChainRecord>,
    /// `None` marks a dead device (respawn budget exhausted).
    leader_txs: Vec<Option<Sender<DeviceMsg>>>,
    leader_handles: Vec<Option<JoinHandle<CacheStats>>>,
    /// Kept open so respawned leaders can be handed a `Done` path; the
    /// router therefore never sees the admission channel close and
    /// relies on `Msg::Shutdown` (which `Coordinator::drop` guarantees).
    respawn_tx: SyncSender<Msg>,
    respawns_left: Vec<usize>,
    leader_respawns: u64,
    tstats: Vec<TenantStats>,
    tenant_inflight: Vec<usize>,
    backlog: Vec<VecDeque<Unit>>,
    plan: FaultPlan,
    /// Next unconsumed plan event per device.
    next_event: Vec<usize>,
    /// Fresh-unit forward count per device — the fault clock. Requeued
    /// units do not advance it, so the fired-event log is a
    /// deterministic function of submission order even though batch
    /// composition (and hence kill-remainder sizes) is not.
    forwarded: Vec<u64>,
    faults: Vec<FaultRecord>,
}

impl RouterCore {
    fn new(opts: CoordinatorOptions, done_tx: SyncSender<Msg>) -> RouterCore {
        let gens = opts.device_gens();
        let n_dev = gens.len();
        let specs = opts.tenant_specs();
        let classes = specs.iter().map(|t| t.priority as usize).max().unwrap_or(0) + 1;
        let mut tenant_order: Vec<usize> = (0..specs.len()).collect();
        tenant_order.sort_by_key(|&t| (std::cmp::Reverse(specs[t].priority), t));
        let plan = opts.chaos.clone().unwrap_or_default();

        let mut leader_txs = Vec::with_capacity(n_dev);
        let mut leader_handles = Vec::with_capacity(n_dev);
        for (d, gen) in gens.iter().copied().enumerate() {
            let (ltx, lrx) = channel::<DeviceMsg>();
            let o = opts.clone();
            let done = done_tx.clone();
            leader_handles
                .push(Some(std::thread::spawn(move || leader_loop(d, gen, o, lrx, done))));
            leader_txs.push(Some(ltx));
        }

        let tstats = specs
            .iter()
            .map(|s| TenantStats {
                name: s.name.clone(),
                priority: s.priority,
                quota: s.quota,
                ..Default::default()
            })
            .collect();

        RouterCore {
            fleet: FleetRouter::with_capacity(gens.clone(), opts.design_capacity),
            queues: (0..n_dev).map(|_| PrioQueue::new(classes)).collect(),
            in_flight: vec![0; n_dev],
            per_dev: (0..n_dev).map(|_| Metrics::default()).collect(),
            caches: vec![CacheStats::default(); n_dev],
            cache_base: vec![CacheStats::default(); n_dev],
            chain_records: Vec::new(),
            leader_txs,
            leader_handles,
            respawn_tx: done_tx,
            respawns_left: vec![opts.max_leader_respawns; n_dev],
            leader_respawns: 0,
            tenant_inflight: vec![0; specs.len()],
            backlog: (0..specs.len()).map(|_| VecDeque::new()).collect(),
            tstats,
            plan,
            next_event: vec![0; n_dev],
            forwarded: vec![0; n_dev],
            faults: Vec::new(),
            max_in_flight: opts.max_in_flight.max(1),
            tenant_order,
            specs,
            gens,
            n_dev,
            opts,
        }
    }

    fn live(&self) -> usize {
        self.leader_txs.iter().filter(|t| t.is_some()).count()
    }

    /// Admit a freshly submitted unit: count it for its tenant and
    /// either launch it or park it in the tenant's quota backlog.
    fn admit(&mut self, unit: Unit) {
        let t = unit.tenant();
        self.tstats[t].submitted += 1;
        self.tstats[t].pending += 1;
        let quota = self.specs[t].quota;
        if (quota > 0 && self.tenant_inflight[t] >= quota) || !self.backlog[t].is_empty() {
            self.backlog[t].push_back(unit);
        } else {
            self.launch(unit);
        }
    }

    /// Route a unit onto a live device's queue (it now occupies one of
    /// its tenant's quota slots). With no live device left the unit
    /// fails visibly: its response channel drops and the tenant's
    /// `failed` counter records it.
    fn launch(&mut self, unit: Unit) {
        let t = unit.tenant();
        self.tenant_inflight[t] += 1;
        if self.live() == 0 {
            self.tenant_inflight[t] -= 1;
            self.finish_unit(t, true);
            return;
        }
        let hw = self.tenant_inflight[t] as u64;
        if hw > self.tstats[t].max_in_flight {
            self.tstats[t].max_in_flight = hw;
        }
        let d = self.place(&unit);
        let prio = self.specs[t].priority as usize;
        self.queues[d].push_back(prio, unit);
        self.pump(d);
    }

    /// Routing decision for a unit (requires a live device). A chain
    /// pinned to a dead device falls back to free chain routing.
    fn place(&mut self, unit: &Unit) -> usize {
        let decision = match unit {
            Unit::Req(p) => {
                let key = DesignKey::for_shape(&p.req.shape);
                self.fleet.route(key, p.req.shape.ops())
            }
            Unit::Chain(c) => {
                let key = DesignKey::for_shape(&c.chain.ops[0].shape);
                let ops = c.chain.total_ops();
                match c.staging.device {
                    Some(d) if self.leader_txs[d].is_some() => self.fleet.route_to(d, key, ops),
                    _ => self.fleet.route_chain(key, ops),
                }
            }
        };
        self.opts.recorder.with(|| TraceFact::Route {
            unit: unit.id(),
            device: decision.device,
            kind: decision.kind,
            est_s: decision.est_s,
        });
        decision.device
    }

    /// Record a unit's terminal outcome for its tenant.
    fn finish_unit(&mut self, t: usize, failed: bool) {
        if failed {
            self.tstats[t].failed += 1;
        } else {
            self.tstats[t].completed += 1;
        }
        self.tstats[t].pending -= 1;
    }

    /// Launch backlogged units while quotas allow, highest priority
    /// class first (FIFO within a tenant).
    fn drain_backlogs(&mut self) {
        for t in self.tenant_order.clone() {
            let quota = self.specs[t].quota;
            while !self.backlog[t].is_empty() && (quota == 0 || self.tenant_inflight[t] < quota)
            {
                let unit = self.backlog[t].pop_front().expect("checked non-empty");
                self.launch(unit);
            }
        }
    }

    /// Forward queued work to leader `d` while its in-flight window
    /// allows. A chain counts its full length against the window but is
    /// forwarded whole whenever any window remains (it may overshoot —
    /// splitting it would forfeit the fused edges, and a chain longer
    /// than the window must not deadlock).
    fn pump(&mut self, d: usize) {
        if self.leader_txs[d].is_none() {
            return;
        }
        while self.in_flight[d] < self.max_in_flight {
            match self.queues[d].pop() {
                Some(unit) => self.forward(d, unit),
                None => break,
            }
        }
    }

    /// Hand one unit to leader `d`, advancing the fault clock (fresh
    /// units only) and attaching the plan's next fault when its
    /// threshold is reached.
    fn forward(&mut self, d: usize, unit: Unit) {
        self.in_flight[d] += unit.len();
        let mut fault = None;
        if !unit.was_requeued() {
            self.forwarded[d] += 1;
            let seq = self.forwarded[d];
            if let Some(ev) = self.plan.device_events(d).get(self.next_event[d]).copied() {
                if ev.seq <= seq {
                    fault = Some(ev.kind);
                    self.next_event[d] += 1;
                    self.faults.push(FaultRecord { device: d, seq, kind: ev.kind });
                    self.opts.recorder.with(|| TraceFact::Fault {
                        device: d,
                        seq,
                        kind: ev.kind,
                        unit: unit.id(),
                    });
                }
            }
        }
        let msg = match unit {
            Unit::Req(p) => DeviceMsg::Run(p, fault),
            Unit::Chain(c) => DeviceMsg::RunChain(c, fault),
        };
        if let Some(tx) = &self.leader_txs[d] {
            let _ = tx.send(msg);
        }
    }

    fn warm(&mut self, key: DesignKey) {
        if self.live() == 0 {
            return;
        }
        let d = self.fleet.warm(key);
        self.opts.recorder.with(|| TraceFact::Warm { device: d, key });
        if let Some(tx) = &self.leader_txs[d] {
            let _ = tx.send(DeviceMsg::Warm(key));
        }
    }

    /// A leader's normal batch acknowledgement: retire the window
    /// slots, fold in records, complete tenants, requeue dropped units
    /// at the front of the same device's queue, and refill.
    fn on_done(&mut self, r: BatchReport) {
        let dev = r.dev;
        let back: usize = r.requeue.iter().map(Unit::len).sum();
        self.in_flight[dev] -= r.retired + back;
        self.caches[dev] = self.cache_base[dev] + r.cache;
        self.fleet.sync_residency(dev, &r.resident);
        for rec in r.records {
            self.tstats[rec.tenant].record_integrity(rec.integrity);
            self.per_dev[dev].push(rec);
        }
        self.chain_records.extend(r.chains);
        for (t, failed) in r.completions {
            self.tenant_inflight[t] -= 1;
            self.finish_unit(t, failed);
        }
        for mut unit in r.requeue.into_iter().rev() {
            unit.mark_requeued();
            let t = unit.tenant();
            self.tstats[t].requeued += 1;
            let prio = self.specs[t].priority as usize;
            self.queues[dev].push_front(prio, unit);
        }
        self.drain_backlogs();
        self.pump(dev);
    }

    /// A leader died. Fold in what it completed, then either respawn a
    /// fresh leader on the *same* channel (units still in transit are
    /// inherited, nothing is lost) and requeue the killed batch's
    /// remainder, or — once the respawn budget is exhausted — mark the
    /// device dead, drain its channel ourselves (we hold the only
    /// sender), and spill every orphan to the surviving siblings.
    fn on_leader_down(&mut self, r: BatchReport, lrx: Receiver<DeviceMsg>) {
        let dev = r.dev;
        let back: usize = r.requeue.iter().map(Unit::len).sum();
        self.in_flight[dev] -= r.retired + back;
        self.cache_base[dev] = self.cache_base[dev] + r.cache;
        self.caches[dev] = self.cache_base[dev];
        // The leader's design cache died with it.
        self.fleet.sync_residency(dev, &[]);
        for rec in r.records {
            self.tstats[rec.tenant].record_integrity(rec.integrity);
            self.per_dev[dev].push(rec);
        }
        self.chain_records.extend(r.chains);
        for (t, failed) in r.completions {
            self.tenant_inflight[t] -= 1;
            self.finish_unit(t, failed);
        }
        if let Some(h) = self.leader_handles[dev].take() {
            let _ = h.join(); // thread already returned; stats rode the report
        }

        let mut orphans: Vec<Unit> = r.requeue;
        if self.respawns_left[dev] > 0 {
            self.respawns_left[dev] -= 1;
            self.leader_respawns += 1;
            self.opts.recorder.record(TraceFact::Respawn { device: dev });
            let o = self.opts.clone();
            let done = self.respawn_tx.clone();
            let gen = self.gens[dev];
            self.leader_handles[dev] =
                Some(std::thread::spawn(move || leader_loop(dev, gen, o, lrx, done)));
            for mut unit in orphans.into_iter().rev() {
                unit.mark_requeued();
                let t = unit.tenant();
                self.tstats[t].requeued += 1;
                let prio = self.specs[t].priority as usize;
                self.queues[dev].push_front(prio, unit);
            }
            self.pump(dev);
        } else {
            self.leader_txs[dev] = None;
            self.fleet.mark_dead(dev);
            while let Ok(m) = lrx.try_recv() {
                match m {
                    DeviceMsg::Run(p, _) => {
                        self.in_flight[dev] -= 1;
                        orphans.push(Unit::Req(p));
                    }
                    DeviceMsg::RunChain(c, _) => {
                        self.in_flight[dev] -= c.chain.len();
                        orphans.push(Unit::Chain(c));
                    }
                    DeviceMsg::Warm(_) | DeviceMsg::Shutdown => {}
                }
            }
            debug_assert_eq!(self.in_flight[dev], 0, "dead leader's window fully retired");
            while let Some(u) = self.queues[dev].pop() {
                orphans.push(u);
            }
            for mut unit in orphans {
                unit.mark_requeued();
                self.requeue_elsewhere(unit);
            }
        }
        self.drain_backlogs();
    }

    /// Re-serve a unit whose device died for good: free routing across
    /// the survivors, or a visible failure when none remain.
    fn requeue_elsewhere(&mut self, unit: Unit) {
        let t = unit.tenant();
        self.tstats[t].requeued += 1;
        self.opts.recorder.with(|| TraceFact::Spill { unit: unit.id() });
        if self.live() == 0 {
            // Nowhere left to run: the unit's response channel drops
            // (the client sees a closed channel) and the tenant's
            // accounting records the failure.
            self.tenant_inflight[t] -= 1;
            self.finish_unit(t, true);
            return;
        }
        let d = self.place(&unit);
        let prio = self.specs[t].priority as usize;
        self.queues[d].push_back(prio, unit);
        self.pump(d);
    }

    fn idle(&self) -> bool {
        self.queues.iter().all(PrioQueue::is_empty)
            && self.in_flight.iter().all(|&n| n == 0)
            && self.backlog.iter().all(VecDeque::is_empty)
    }

    fn assemble(&self) -> FleetMetrics {
        let mut fm = FleetMetrics {
            devices: Vec::with_capacity(self.n_dev),
            router_hits: self.fleet.hits,
            router_misses: self.fleet.misses,
            router_spills: self.fleet.spills,
            chains: self.chain_records.clone(),
            tenants: self.tstats.clone(),
            faults: self.faults.clone(),
            leader_respawns: self.leader_respawns,
            forwards: self.forwarded.clone(),
        };
        for d in 0..self.n_dev {
            fm.devices.push(DeviceMetrics {
                gen: self.gens[d],
                metrics: self.per_dev[d].clone(),
                cache: self.caches[d],
            });
        }
        fm
    }

    /// Stop the surviving leaders (the queues are already drained) and
    /// assemble the final metrics.
    fn finish(mut self) -> FleetMetrics {
        for tx in self.leader_txs.iter().flatten() {
            let _ = tx.send(DeviceMsg::Shutdown);
        }
        self.leader_txs.clear();
        let handles: Vec<_> = self.leader_handles.iter_mut().map(Option::take).collect();
        for (d, h) in handles.into_iter().enumerate() {
            if let Some(h) = h {
                if let Ok(stats) = h.join() {
                    self.caches[d] = self.cache_base[d] + stats;
                }
            }
        }
        self.assemble()
    }
}

fn router_loop(
    opts: CoordinatorOptions,
    rx: Receiver<Msg>,
    done_tx: SyncSender<Msg>,
) -> FleetMetrics {
    let mut core = RouterCore::new(opts, done_tx);
    let mut draining = false;
    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            // Unreachable while the core holds its respawn sender, but
            // a defensive break keeps the drain semantics obvious.
            Err(_) => break,
        };
        match msg {
            Msg::Submit(p) => core.admit(Unit::Req(p)),
            Msg::SubmitChain(c) => core.admit(Unit::Chain(c)),
            Msg::Warm(key) => core.warm(key),
            Msg::Flush(tx) => {
                let _ = tx.send(core.assemble());
            }
            Msg::Done(report) => core.on_done(report),
            Msg::LeaderDown(report, lrx) => core.on_leader_down(report, lrx),
            Msg::Shutdown => draining = true,
        }
        if draining && core.idle() {
            break;
        }
    }
    core.finish()
}

/// What a leader carries between batches: its design cache and the
/// array's loaded-design state.
struct LeaderState {
    cache: DesignCache,
    device: DeviceState,
}

/// Absorb one message into the leader's batch / state.
fn absorb(
    m: DeviceMsg,
    gen: Generation,
    batch: &mut Vec<(Unit, Option<FaultKind>)>,
    state: &mut LeaderState,
    shutdown: &mut bool,
) {
    match m {
        DeviceMsg::Run(p, f) => batch.push((Unit::Req(p), f)),
        DeviceMsg::RunChain(c, f) => batch.push((Unit::Chain(c), f)),
        DeviceMsg::Warm(key) => {
            state.cache.warm(key);
            state.device.switch_to(gen, key);
        }
        DeviceMsg::Shutdown => *shutdown = true,
    }
}

/// Outcome of one chain unit on a leader: completed (respond + record)
/// or handed back for a verified recompute after a detected
/// corruption. Boxed so the enum stays pointer-sized.
enum ChainOutcome {
    Done(Box<(ChainRecord, Sender<ChainResponse>, ChainResponse)>),
    Retry(Box<PendingChain>),
}

/// Execute one chain on the leader's device: designs resolved from the
/// leader's cache, fused edges and dispatch amortization from the same
/// rule the offline planner uses, reconfiguration charged through the
/// shared device state. Under `Backend::Functional` every op also runs
/// through the packed executor, and each producer→consumer edge feeds
/// the staged C straight into the next op as its A — the functional
/// mirror of the planner's fused dataflow. An injected DMA stall is
/// charged to the first op; an injected `CorruptResult` flips bits in
/// the first op's C, where the staged dataflow would propagate it the
/// furthest. Records are appended only on completion, so a panicking
/// or retried chain leaves no partial accounting.
fn run_chain(
    dev: usize,
    gen: Generation,
    pc: PendingChain,
    opts: &CoordinatorOptions,
    state: &mut LeaderState,
    records: &mut Vec<RequestRecord>,
    fault: Option<FaultKind>,
) -> ChainOutcome {
    let PendingChain { id, tenant, chain, bd_mode, staging, tx, t0, requeued, integrity_retries } =
        pc;
    let stall_s = match fault {
        Some(FaultKind::DmaStall { stall_s }) => stall_s,
        _ => 0.0,
    };
    let checking = opts.integrity != IntegrityMode::Off;
    let functional = opts.backend == Backend::Functional;
    // A detected corruption retries the *whole* chain (recovery must
    // re-derive the identical staged dataflow), so keep a copy of the
    // submission's staging to rebuild the unit from.
    let staging_retry = if functional && checking {
        ChainStaging {
            device: staging.device,
            a0: staging.a0.clone(),
            a0_sums: staging.a0_sums.clone(),
        }
    } else {
        ChainStaging::default()
    };
    let cfgs: Vec<TilingConfig> =
        chain.ops.iter().map(|o| *state.cache.get(DesignKey::for_shape(&o.shape))).collect();
    let ovs = overrides_for(&cfgs, &chain);
    let mut chain_s = 0.0;
    let mut fused = 0;
    let mut elided = 0;
    let mut reports = Vec::with_capacity(chain.len());
    let mut chain_recs: Vec<RequestRecord> = Vec::with_capacity(chain.len());
    // Dispatch facts ride the same buffer-then-commit discipline as
    // `chain_recs`: a retried chain leaves no trace spans — only the
    // clean re-execution is replayed.
    let mut chain_facts: Vec<TraceFact> = Vec::new();
    // A staged entry A (DAG cross-chain edge) pre-loads the slot the
    // first op consumes; intra-chain edges refill it op by op.
    let mut staged: Option<Matrix> = staging.a0;
    let mut staged_edges = 0usize;
    let mut result: Option<Matrix> = None;
    let mut func_failed = false;
    // Re-validate a checksummed staged entry A before executing on it:
    // a corrupted cross-chain edge cannot be healed by recomputing
    // *this* chain (its producer already completed), so a mismatch
    // fails the chain immediately instead of burning retries.
    let mut edge_corrupt = false;
    if functional && checking {
        if let (Some(a0), Some(sums)) = (&staged, &staging.a0_sums) {
            if !abft::validate(a0, sums) {
                edge_corrupt = true;
                func_failed = true;
            }
        }
    }
    let mut retry = false;
    for (i, op) in chain.ops.iter().enumerate() {
        let key = DesignKey::for_shape(&op.shape);
        let reconfig_s = state.device.switch_to(gen, key);
        let sim =
            simulate_gemm_with(&cfgs[i], op.shape.m, op.shape.k, op.shape.n, bd_mode, ovs[i]);
        let (m, k, n) = (op.shape.m, op.shape.k, op.shape.n);
        // The op's logical precision; differs from the loaded design's
        // only for fp32_split, which rides the bf16 design as LIMB_GEMMS
        // dispatches and stages f32 images.
        let logical_p = op.shape.precision;
        let split = logical_p == Precision::Fp32Split;
        let dispatches = if split { dtype_split::LIMB_GEMMS as f64 } else { 1.0 };
        let op_stall_s = if i == 0 { stall_s } else { 0.0 };
        let integrity_s = integrity_seconds(opts.integrity, gen, cfgs[i].precision, m, k, n);
        let device_s = sim.t_total * dispatches + reconfig_s + op_stall_s + integrity_s;
        chain_s += device_s;
        fused += ovs[i].a_in_l2 as usize;
        elided += ovs[i].elide_dispatch as usize;
        // A failed op poisons the rest of the functional run: no random-A
        // substitution for downstream consumers, no final result — the
        // caller sees `result: None` instead of a silently wrong C.
        let mut op_integrity = if checking && !func_failed {
            if integrity_retries > 0 {
                Integrity::Recovered { retries: integrity_retries }
            } else {
                Integrity::Passed
            }
        } else {
            Integrity::NotChecked
        };
        if i == 0 && edge_corrupt {
            op_integrity = Integrity::Failed;
        }
        if functional && !func_failed {
            let exec = Executor::with_options(
                cfgs[i],
                ExecOptions { threads: opts.exec_threads, ..Default::default() },
            );
            let inputs: Result<(Matrix, Matrix)> = (|| {
                let a = match staged.take() {
                    // The first op consumes the submission's staged A;
                    // later ops consume the previous op's resident C.
                    Some(c) if op.consumes_prev || i == 0 => {
                        staged_edges += 1;
                        c
                    }
                    _ => functional_a(&op.shape, logical_p)?,
                };
                Ok((a, functional_b(&op.shape, logical_p)?))
            })();
            // fp32_split ops never enter the packed executor: the limb
            // GEMMs + f32 rejoin run through dtype_split (bit-exact at
            // every thread count, same kernel as the pure-executor path).
            let executed = match inputs {
                Ok((a, b)) if split => dtype_split::split_exec(&a, &b, opts.exec_threads)
                    .ok()
                    .map(|c| (a, b, c)),
                Ok((a, b)) => exec.execute(&a, &b).ok().map(|c| (a, b, c)),
                Err(_) => None,
            };
            match executed {
                Some((a, b, mut c)) => {
                    // Checksums are captured over the as-produced C;
                    // only then does the fault layer flip bits — a
                    // checksum captured afterwards would happily
                    // validate the corrupted image.
                    let sums = checking.then(|| abft::capture(&c));
                    if i == 0 {
                        if let Some(FaultKind::CorruptResult { word, xor_mask }) = fault {
                            abft::corrupt_word(&mut c, word, xor_mask);
                        }
                    }
                    // `None` = the check itself could not run (treated
                    // as a terminal failure, recompute would not help).
                    let clean: Option<bool> = match opts.integrity {
                        IntegrityMode::Off => Some(true),
                        IntegrityMode::Abft => Some(
                            abft::validate(&c, sums.as_ref().expect("captured when checking"))
                                && abft::operand_invariant(&a, &b, &c, logical_p)
                                    != Some(false),
                        ),
                        IntegrityMode::Full => refimpl::ref_gemm(&a, &b, logical_p)
                            .ok()
                            .map(|w| refimpl::matrices_equal(&c, &w, logical_p)),
                    };
                    match clean {
                        Some(true) => {
                            // Move (never clone) the C image: it becomes
                            // the final result, or the staged A of a
                            // consuming next op.
                            if i + 1 == chain.ops.len() {
                                result = Some(c);
                            } else if chain.ops[i + 1].consumes_prev {
                                staged = Some(c);
                            }
                        }
                        Some(false) if (integrity_retries as usize) < opts.max_integrity_retries =>
                        {
                            retry = true;
                            break;
                        }
                        Some(false) | None => {
                            func_failed = true;
                            op_integrity = Integrity::Failed;
                        }
                    }
                }
                None => {
                    func_failed = true;
                    op_integrity = Integrity::Failed;
                }
            }
        }
        if opts.recorder.is_on() {
            let rl = roofline::tag(gen, cfgs[i].precision, &sim);
            chain_facts.push(TraceFact::Dispatch(Box::new(DispatchFact {
                unit: id,
                op: i,
                chain: Some(id),
                device: dev,
                gen,
                name: op.shape.name.clone(),
                tenant,
                m,
                k,
                n,
                key,
                precision: logical_p,
                dispatches,
                t_comp: sim.t_comp,
                t_mem: sim.t_mem,
                t_prologue: sim.t_prologue,
                t_stall: sim.t_stall,
                t_dispatch: sim.t_dispatch,
                t_total: sim.t_total,
                fault_stall_s: op_stall_s,
                integrity_s,
                arithmetic_intensity: rl.arithmetic_intensity,
                ridge: rl.ridge,
                tops: sim.tops,
                bound: rl.bound,
                integrity: op_integrity,
            })));
        }
        chain_recs.push(RequestRecord {
            id,
            name: op.shape.name.clone(),
            device: dev,
            device_s,
            host_latency_s: t0.elapsed().as_secs_f64(),
            ops: op.shape.ops(),
            reconfigured: reconfig_s > 0.0,
            integrity: op_integrity,
            chain: Some(id),
            tenant,
        });
        reports.push(sim);
    }
    if retry {
        // Verified recompute: the unit goes back to the router, which
        // requeues it at the front of this device's queue. The retried
        // attempt leaves no records — the clean re-execution accounts
        // for the whole chain.
        return ChainOutcome::Retry(Box::new(PendingChain {
            id,
            tenant,
            chain,
            bd_mode,
            staging: staging_retry,
            tx,
            t0,
            requeued,
            integrity_retries: integrity_retries + 1,
        }));
    }
    records.append(&mut chain_recs);
    for f in chain_facts {
        opts.recorder.record(f);
    }
    let record = ChainRecord {
        id,
        name: chain.name.clone(),
        device: dev,
        ops_count: chain.len(),
        fused_edges: fused,
        elided_dispatches: elided,
        device_s: chain_s,
    };
    let chain_integrity = if func_failed {
        Integrity::Failed
    } else if checking {
        if integrity_retries > 0 {
            Integrity::Recovered { retries: integrity_retries }
        } else {
            Integrity::Passed
        }
    } else {
        Integrity::NotChecked
    };
    let response = ChainResponse {
        id,
        name: chain.name,
        device: dev,
        device_s: chain_s,
        fused_edges: fused,
        elided_dispatches: elided,
        reports,
        result,
        staged_edges,
        integrity: chain_integrity,
    };
    ChainOutcome::Done(Box::new((record, tx, response)))
}

/// Modeled device-clock cost of the enabled integrity check at one
/// shape: the ABFT checksum pass ([`crate::sim::abft_check_seconds`]),
/// or a full reference recompute charged at the generation's peak MAC
/// rate — the `O(mk + kn + mn)` vs `O(mkn)` gap the ABFT scheme exists
/// to exploit.
fn integrity_seconds(
    mode: IntegrityMode,
    gen: Generation,
    p: Precision,
    m: usize,
    k: usize,
    n: usize,
) -> f64 {
    match mode {
        IntegrityMode::Off => 0.0,
        IntegrityMode::Abft => crate::sim::abft_check_seconds(gen, p, m, k, n),
        IntegrityMode::Full => {
            2.0 * (m as f64) * (k as f64) * (n as f64) / (gen.spec().peak_tops(p) * 1e12)
        }
    }
}

/// Outcome of one single-request unit on a leader: completed, or
/// handed back for a verified recompute. Boxed so the enum stays
/// pointer-sized.
enum ReqOutcome {
    Done(Box<(RequestRecord, Sender<GemmResponse>, GemmResponse)>),
    Retry(Box<Pending>),
}

/// Execute one single-request unit (the non-chain leg of a batch).
/// The unit's injected fault (DMA stall / result corruption) is
/// applied here; a corruption caught by the integrity check within the
/// retry budget hands the unit back instead of responding.
fn run_request(
    dev: usize,
    gen: Generation,
    p: Pending,
    opts: &CoordinatorOptions,
    state: &mut LeaderState,
    fault: Option<FaultKind>,
) -> ReqOutcome {
    let Pending { id, tenant, mut req, tx, t0, requeued, integrity_retries } = p;
    if req.poison {
        panic!("poisoned request (chaos containment hook)");
    }
    let stall_s = match fault {
        Some(FaultKind::DmaStall { stall_s }) => stall_s,
        _ => 0.0,
    };
    let key = DesignKey::for_shape(&req.shape);
    let cfg = *state.cache.get(key);
    let reconfig_s = state.device.switch_to(gen, key);
    let sim = simulate_gemm(&cfg, req.shape.m, req.shape.k, req.shape.n, req.bd_mode);
    let (result, integrity) = match opts.backend {
        Backend::SimOnly => {
            // Timing-only: there are no bytes to check, but the check's
            // modeled cost still lands on the device clock (below) and
            // the record reflects that the result was covered.
            let i = if opts.integrity == IntegrityMode::Off {
                Integrity::NotChecked
            } else {
                Integrity::Passed
            };
            (None, i)
        }
        Backend::Functional => match run_functional(&cfg, &mut req, id, fault, opts) {
            Attempt::Done(result, i) => {
                let i = match i {
                    Integrity::Passed if integrity_retries > 0 => {
                        Integrity::Recovered { retries: integrity_retries }
                    }
                    other => other,
                };
                (result, i)
            }
            Attempt::Corrupt => {
                if (integrity_retries as usize) < opts.max_integrity_retries {
                    // Verified recompute: back to the router, which
                    // requeues the unit at the front of this device's
                    // queue for a clean re-execution (no fault is
                    // re-applied to requeued units).
                    return ReqOutcome::Retry(Box::new(Pending {
                        id,
                        tenant,
                        req,
                        tx,
                        t0,
                        requeued,
                        integrity_retries: integrity_retries + 1,
                    }));
                }
                // Budget exhausted: fail visibly — a corrupted C is
                // never served.
                (None, Integrity::Failed)
            }
        },
    };
    let (m, k, n) = (req.shape.m, req.shape.k, req.shape.n);
    let integrity_s = integrity_seconds(opts.integrity, gen, cfg.precision, m, k, n);
    let device_s = sim.t_total + reconfig_s + stall_s + integrity_s;
    opts.recorder.with(|| {
        let rl = roofline::tag(gen, cfg.precision, &sim);
        TraceFact::Dispatch(Box::new(DispatchFact {
            unit: id,
            op: 0,
            chain: None,
            device: dev,
            gen,
            name: req.shape.name.clone(),
            tenant,
            m,
            k,
            n,
            key,
            precision: req.shape.precision,
            dispatches: 1.0,
            t_comp: sim.t_comp,
            t_mem: sim.t_mem,
            t_prologue: sim.t_prologue,
            t_stall: sim.t_stall,
            t_dispatch: sim.t_dispatch,
            t_total: sim.t_total,
            fault_stall_s: stall_s,
            integrity_s,
            arithmetic_intensity: rl.arithmetic_intensity,
            ridge: rl.ridge,
            tops: sim.tops,
            bound: rl.bound,
            integrity,
        }))
    });
    let record = RequestRecord {
        id,
        name: req.shape.name.clone(),
        device: dev,
        device_s,
        host_latency_s: t0.elapsed().as_secs_f64(),
        ops: req.shape.ops(),
        reconfigured: reconfig_s > 0.0,
        integrity,
        chain: None,
        tenant,
    };
    let response = GemmResponse {
        id,
        name: req.shape.name,
        device: dev,
        sim,
        device_s,
        reconfigured: reconfig_s > 0.0,
        integrity,
        result,
    };
    ReqOutcome::Done(Box::new((record, tx, response)))
}

fn leader_loop(
    dev: usize,
    gen: Generation,
    opts: CoordinatorOptions,
    rx: Receiver<DeviceMsg>,
    done: SyncSender<Msg>,
) -> CacheStats {
    let mut state = LeaderState {
        cache: DesignCache::with_capacity(gen, opts.design_capacity),
        device: DeviceState::default(),
    };

    loop {
        // Block for the first message, then drain up to the batch window.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        let mut batch: Vec<(Unit, Option<FaultKind>)> = Vec::new();
        let mut shutdown = false;
        absorb(first, gen, &mut batch, &mut state, &mut shutdown);
        while batch.len() < opts.batch_window.max(1) {
            match rx.try_recv() {
                Ok(m) => absorb(m, gen, &mut batch, &mut state, &mut shutdown),
                Err(_) => break,
            }
        }

        // Size-class batching: stable-group by design key so a burst of
        // mixed-precision traffic pays each reconfiguration once. Chains
        // group by their leading op and stay contiguous.
        batch.sort_by_key(|(u, _)| u.sort_key());

        let mut records = Vec::with_capacity(batch.len());
        let mut chain_records = Vec::new();
        let mut responses = Vec::new();
        let mut chain_responses = Vec::new();
        let mut completions: Vec<(usize, bool)> = Vec::new();
        let mut dropped: Vec<Unit> = Vec::new();
        let mut retired = 0usize;
        let mut killed: Option<Vec<Unit>> = None;

        let mut it = batch.into_iter();
        loop {
            let Some((unit, fault)) = it.next() else { break };
            match fault {
                Some(FaultKind::LeaderKill) => {
                    // This leader dies before executing the tagged unit:
                    // it, any drop-tagged units already collected this
                    // batch, and the rest of the batch go back to the
                    // router (in batch order, so requeue-at-front
                    // preserves it).
                    // Only the tagged unit gets a requeue span: the
                    // collateral remainder's membership is a batch-timing
                    // accident and would break trace determinism.
                    opts.recorder.with(|| TraceFact::Requeue {
                        unit: unit.id(),
                        device: dev,
                        reason: RequeueReason::LeaderKill,
                    });
                    let mut rq = std::mem::take(&mut dropped);
                    rq.push(unit);
                    rq.extend(it.by_ref().map(|(u, _)| u));
                    killed = Some(rq);
                    break;
                }
                Some(FaultKind::DropResponse) => {
                    // Lost response: the unit is not executed here; the
                    // router re-serves it, so the client still gets
                    // exactly one reply.
                    opts.recorder.with(|| TraceFact::Requeue {
                        unit: unit.id(),
                        device: dev,
                        reason: RequeueReason::DropResponse,
                    });
                    dropped.push(unit);
                    continue;
                }
                Some(FaultKind::CacheStorm) => {
                    state.cache.clear();
                    state.device.invalidate();
                }
                _ => {}
            }
            let unit_len = unit.len();
            let tenant = unit.tenant();
            retired += unit_len;
            // Genuine panics (not injected kills) are contained per
            // unit: the unit's response channel drops with the unwound
            // stack, the tenant records a failure, and the leader keeps
            // serving the rest of the batch. An integrity retry is not
            // a completion: the unit rides the requeue path back to the
            // front of this device's queue for a clean recompute.
            match unit {
                Unit::Chain(pc) => {
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_chain(dev, gen, *pc, &opts, &mut state, &mut records, fault)
                    }));
                    match run {
                        Ok(ChainOutcome::Done(d)) => {
                            let (rec, tx, resp) = *d;
                            completions.push((tenant, false));
                            chain_records.push(rec);
                            chain_responses.push((tx, resp));
                        }
                        Ok(ChainOutcome::Retry(pc)) => {
                            retired -= unit_len;
                            opts.recorder.with(|| TraceFact::Requeue {
                                unit: pc.id,
                                device: dev,
                                reason: RequeueReason::IntegrityRetry,
                            });
                            dropped.push(Unit::Chain(pc));
                        }
                        Err(_) => completions.push((tenant, true)),
                    }
                }
                Unit::Req(p) => {
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_request(dev, gen, *p, &opts, &mut state, fault)
                    }));
                    match run {
                        Ok(ReqOutcome::Done(d)) => {
                            let (rec, tx, resp) = *d;
                            completions.push((tenant, false));
                            records.push(rec);
                            responses.push((tx, resp));
                        }
                        Ok(ReqOutcome::Retry(p)) => {
                            retired -= unit_len;
                            opts.recorder.with(|| TraceFact::Requeue {
                                unit: p.id,
                                device: dev,
                                reason: RequeueReason::IntegrityRetry,
                            });
                            dropped.push(Unit::Req(p));
                        }
                        Err(_) => completions.push((tenant, true)),
                    }
                }
            }
        }

        if let Some(requeue) = killed {
            // Leader death: ship the batch accounting, the unexecuted
            // units, and our receiver (so a respawned leader inherits
            // whatever is still in the channel) back to the router;
            // answer the clients whose units did complete; then die.
            let report = BatchReport {
                dev,
                records,
                chains: chain_records,
                cache: state.cache.stats(),
                resident: Vec::new(),
                retired,
                completions,
                requeue,
            };
            let _ = done.send(Msg::LeaderDown(report, rx));
            for (tx, resp) in responses {
                let _ = tx.send(resp);
            }
            for (tx, resp) in chain_responses {
                let _ = tx.send(resp);
            }
            return state.cache.stats();
        }

        // Acknowledge to the router before responding to clients: a
        // client holding its response can then rely on a subsequent
        // metrics snapshot including its request.
        if !records.is_empty() || !completions.is_empty() || !dropped.is_empty() {
            let report = BatchReport {
                dev,
                records,
                chains: chain_records,
                cache: state.cache.stats(),
                resident: state.cache.resident(),
                retired,
                completions,
                requeue: dropped,
            };
            let _ = done.send(Msg::Done(report));
        }
        for (tx, resp) in responses {
            let _ = tx.send(resp);
        }
        for (tx, resp) in chain_responses {
            let _ = tx.send(resp);
        }

        if shutdown {
            break;
        }
    }
    state.cache.stats()
}

/// Deterministic functional A for `shape` (seeded from its geometry) —
/// shared by the single-request and chain functional paths, and public
/// so tests can reproduce the coordinator's generated inputs. bfp16
/// shapes produce padded-block images (`refimpl::input_matrix`); an
/// unrepresentable shape (word-misaligned, or a bfp16 K not covering
/// whole blocks) is an `Err`, which the serving paths surface as a
/// failed functional op (`result: None`, [`Integrity::Failed`])
/// instead of panicking a device leader.
pub fn functional_a(shape: &GemmShape, p: Precision) -> Result<Matrix> {
    let mut a = refimpl::input_matrix(shape.m, shape.k, p, Layout::RowMajor)?;
    refimpl::fill_random(&mut a, p, shape.m as u64 ^ 0xA5A5);
    Ok(a)
}

/// Deterministic functional B for `shape` (layout per the shape).
pub fn functional_b(shape: &GemmShape, p: Precision) -> Result<Matrix> {
    let mut b = refimpl::input_matrix(shape.k, shape.n, p, shape.b_layout)?;
    refimpl::fill_random(&mut b, p, shape.n as u64 ^ 0x5A5A);
    Ok(b)
}

/// Both generated operands for `shape`.
pub fn functional_inputs(shape: &GemmShape, p: Precision) -> Result<(Matrix, Matrix)> {
    Ok((functional_a(shape, p)?, functional_b(shape, p)?))
}

/// Outcome of one functional execution attempt.
enum Attempt {
    /// The enabled integrity check caught a corrupted C — recomputable
    /// (the corruption struck *after* a correct execution).
    Corrupt,
    /// Terminal outcome: the result (if any) and its integrity verdict.
    /// Execution errors and `verify` reference mismatches land here as
    /// `Failed` — a recompute would fail identically.
    Done(Option<Matrix>, Integrity),
}

/// Deterministically corrupt a completed C image: the fault plan's
/// `CorruptResult` event, then the `GemmRequest::corrupt` test hook
/// (which burns one corrupted attempt per count, so retries converge
/// on a clean recompute). Runs whether or not an integrity check is
/// enabled — with `--integrity off` a corrupted result is served
/// as-is, which is exactly the silent-corruption failure mode the
/// checks exist to close.
fn corrupt_result(c: &mut Matrix, id: u64, fault: Option<FaultKind>, corrupt: &mut u8) {
    if let Some(FaultKind::CorruptResult { word, xor_mask }) = fault {
        abft::corrupt_word(c, word, xor_mask);
    }
    if *corrupt > 0 {
        *corrupt -= 1;
        abft::corrupt_word(c, id ^ 0x9E37_79B9_7F4A_7C15, 0xDEAD_BEEF);
    }
}

fn run_functional(
    cfg: &crate::tiling::TilingConfig,
    req: &mut GemmRequest,
    id: u64,
    fault: Option<FaultKind>,
    opts: &CoordinatorOptions,
) -> Attempt {
    let p = cfg.precision;
    // Borrow caller-supplied operands; only generated inputs are owned.
    let generated;
    let (a, b) = match &req.data {
        Some((a, b)) => (a, b),
        None => {
            generated = match functional_inputs(&req.shape, p) {
                Ok(g) => g,
                Err(_) => return Attempt::Done(None, Integrity::Failed),
            };
            (&generated.0, &generated.1)
        }
    };
    let exec = Executor::with_options(
        *cfg,
        ExecOptions { threads: opts.exec_threads, ..Default::default() },
    );
    let mut c = match exec.execute(a, b) {
        Ok(c) => c,
        Err(_) => return Attempt::Done(None, Integrity::Failed),
    };
    // Checksums are captured over the as-produced C; only then does the
    // fault layer (and the test hook) flip bits — a checksum captured
    // afterwards would happily validate the corrupted image.
    let sums = (opts.integrity != IntegrityMode::Off).then(|| abft::capture(&c));
    corrupt_result(&mut c, id, fault, &mut req.corrupt);
    let mut integrity = match opts.integrity {
        IntegrityMode::Off => Integrity::NotChecked,
        IntegrityMode::Abft => {
            let sums = sums.as_ref().expect("captured when checking");
            // Two-level check: exact raw-word checksums over C (catches
            // any post-execution flip, all precisions), plus the
            // Huang–Abraham operand grand-total invariant where the
            // precision's arithmetic admits one.
            if !abft::validate(&c, sums) || abft::operand_invariant(a, b, &c, p) == Some(false) {
                return Attempt::Corrupt;
            }
            Integrity::Passed
        }
        IntegrityMode::Full => match refimpl::ref_gemm(a, b, p) {
            Ok(want) if refimpl::matrices_equal(&c, &want, p) => Integrity::Passed,
            Ok(_) => return Attempt::Corrupt,
            Err(_) => return Attempt::Done(None, Integrity::Failed),
        },
    };
    if req.verify {
        // The legacy per-request reference check: reports, never
        // retries (and keeps the result, as it always has).
        let want = refimpl::ref_gemm(a, b, p).expect("ref");
        integrity = if refimpl::matrices_equal(&c, &want, p) {
            Integrity::Passed
        } else {
            Integrity::Failed
        };
    }
    Attempt::Done(Some(c), integrity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Precision;
    use crate::workload::{GemmShape, TransformerConfig};

    #[test]
    fn sim_requests_round_trip() {
        let c = Coordinator::start(CoordinatorOptions::default());
        let resp = c
            .call(GemmRequest::sim(GemmShape::new("t", 4096, 4320, 4480, Precision::I8I16)))
            .unwrap();
        assert!(resp.sim.tops > 25.0, "{}", resp.sim.tops);
        assert!(resp.reconfigured, "first request loads the design");
        let resp2 = c
            .call(GemmRequest::sim(GemmShape::new("t2", 4096, 4320, 4480, Precision::I8I16)))
            .unwrap();
        assert!(!resp2.reconfigured, "design reused");
        let m = c.shutdown().unwrap();
        assert_eq!(m.count(), 2);
        assert_eq!(m.reconfigurations(), 1);
        assert_eq!(m.n_devices(), 1, "default options run one device");
        // Single implicit tenant: accounting conserves and drains.
        assert_eq!(m.tenants.len(), 1);
        assert_eq!(m.tenants[0].name, "default");
        assert_eq!((m.tenants[0].submitted, m.tenants[0].completed), (2, 2));
        assert!(m.conserves());
        assert_eq!(m.tenants[0].pending, 0, "drained shutdown leaves nothing pending");
    }

    #[test]
    fn transformer_trace_reuses_designs() {
        // Sec. 5.3.1: one design serves all layer shapes; only the first
        // request reconfigures.
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna,
            ..Default::default()
        });
        let trace = TransformerConfig { seq: 512, ..Default::default() }.trace();
        let n = trace.len();
        let rxs: Vec<_> =
            trace.into_iter().map(|g| c.submit(GemmRequest::sim(g)).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let m = c.shutdown().unwrap();
        assert_eq!(m.count(), n);
        assert_eq!(m.reconfigurations(), 1);
        assert!(m.device_tops() > 1.0);
        assert_eq!(m.router_misses, 1, "one design key in the whole trace");
    }

    #[test]
    fn batching_groups_mixed_precisions() {
        // 4 precisions interleaved 4x: FIFO would reconfigure 16 times;
        // the batching scheduler pays ~4 (one per design) when requests
        // arrive together.
        let c = Coordinator::start(CoordinatorOptions {
            batch_window: 32,
            ..Default::default()
        });
        let mut rxs = Vec::new();
        for round in 0..4 {
            for p in Precision::ALL {
                let g = GemmShape::new(&format!("r{round}-{p}"), 1024, 1024, 1024, p);
                rxs.push(c.submit(GemmRequest::sim(g)).unwrap());
            }
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let m = c.shutdown().unwrap();
        assert_eq!(m.count(), 16);
        assert!(
            m.reconfigurations() <= 8,
            "batching should coalesce designs: {} reconfigs",
            m.reconfigurations()
        );
    }

    #[test]
    fn functional_backend_verifies() {
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna,
            backend: Backend::Functional,
            ..Default::default()
        });
        // Tiny shape (pads to one native tile of the balanced design).
        let mut req = GemmRequest::sim(GemmShape::new("fv", 64, 64, 64, Precision::I8I8));
        req.verify = true;
        let resp = c.call(req).unwrap();
        assert_eq!(resp.integrity, Integrity::Passed);
        assert_eq!(resp.verified(), Some(true), "legacy tri-state view");
        let out = resp.result.unwrap();
        assert_eq!((out.rows, out.cols), (64, 64));
        c.shutdown().unwrap();
    }

    #[test]
    fn functional_chain_stages_intermediate_c() {
        // A producer→consumer chain under the functional backend: op 1's
        // A is op 0's drained C (the packed executor's staged path), and
        // the final result matches folding the reference GEMM over the
        // same deterministic inputs. exec_threads=2 doubles as an
        // in-service determinism check (threaded ≡ serial bits).
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna,
            backend: Backend::Functional,
            exec_threads: 2,
            ..Default::default()
        });
        let s0 = GemmShape::new("op0", 64, 64, 64, Precision::I8I8);
        let s1 = GemmShape::new("op1", 64, 64, 64, Precision::I8I8);
        let mut chain = crate::plan::GemmChain::new("func");
        chain.push(s0.clone());
        chain.push_chained(s1.clone()).unwrap();
        let resp = c.call_chain(chain).unwrap();
        assert_eq!(resp.staged_edges, 1, "the edge must consume the staged C");
        let got = resp.result.expect("functional backend returns the final C");
        let (a0, b0) = functional_inputs(&s0, Precision::I8I8).unwrap();
        let b1 = functional_b(&s1, Precision::I8I8).unwrap();
        let mid = refimpl::ref_gemm(&a0, &b0, Precision::I8I8).unwrap();
        let want = refimpl::ref_gemm(&mid, &b1, Precision::I8I8).unwrap();
        assert!(refimpl::matrices_equal(&got, &want, Precision::I8I8));
        c.shutdown().unwrap();
    }

    #[test]
    fn ragged_bfp16_functional_request_fails_gracefully() {
        // K=100 covers no whole number of 8-value blocks, so no block
        // image can represent the operands. The functional path must
        // poison the request (result: None, Integrity::Failed) — never
        // panic the device leader (sim timing still reports, the
        // simulator pads like any precision).
        let c = Coordinator::start(CoordinatorOptions {
            backend: Backend::Functional,
            ..Default::default()
        });
        let resp = c
            .call(GemmRequest::sim(GemmShape::new("ragged", 64, 100, 64, Precision::Bfp16)))
            .unwrap();
        assert!(resp.result.is_none());
        assert_eq!(resp.integrity, Integrity::Failed);
        assert_eq!(resp.verified(), Some(false), "legacy tri-state view");
        assert!(resp.sim.tops > 0.0, "simulation still accounts the padded dispatch");
        c.shutdown().unwrap();
    }

    #[test]
    fn chain_lands_whole_on_one_device_with_fused_edges() {
        // A transformer layer chain on a two-device fleet: chain affinity
        // places every op on one leader; the L2-eligible edges fuse and
        // the same-design ops ride one host submission.
        let c = Coordinator::start(CoordinatorOptions::fleet(vec![
            Generation::Xdna2,
            Generation::Xdna2,
        ]));
        let chains = TransformerConfig { n_layers: 2, ..Default::default() }.chains();
        let resp = c.call_chain(chains[0].clone()).unwrap();
        assert_eq!(resp.reports.len(), 4);
        assert_eq!(
            resp.fused_edges, 1,
            "XDNA2 int8 fuses attn_out→ffn_up; ffn_up's C won't coexist with its resident A"
        );
        assert_eq!(resp.elided_dispatches, 3);
        // The fused op moved no A bytes; its producer wrote no C; the
        // unfused ffn_down re-reads its A from DRAM.
        assert_eq!(resp.reports[2].a_bytes, 0.0);
        assert_eq!(resp.reports[1].c_bytes, 0.0);
        assert!(resp.reports[3].a_bytes > 0.0);
        let m = c.shutdown().unwrap();
        assert_eq!(m.count(), 4, "each chain op is one record");
        assert_eq!(m.chains.len(), 1);
        assert_eq!(m.chains[0].device, resp.device);
        assert!((m.chains[0].device_s - resp.device_s).abs() < 1e-12);
        assert!(m.chain_makespan_s() > 0.0);
        let on_dev: usize = m.devices[resp.device].metrics.count();
        assert_eq!(on_dev, 4, "whole chain on one device");
        assert_eq!(m.router_misses, 1, "one routing decision per chain");
        assert!(m.devices[resp.device]
            .metrics
            .records
            .iter()
            .all(|r| r.chain == Some(resp.id)));
        // A chain is ONE tenant unit even though it yields 4 records.
        assert_eq!((m.tenants[0].submitted, m.tenants[0].completed), (1, 1));
    }

    #[test]
    fn chains_beat_isolated_ops_end_to_end() {
        // Same 2-layer workload through the coordinator both ways: as
        // chains vs as independent requests — chained device time must
        // be strictly smaller (elided dispatches + fused round-trips).
        let cfgs = TransformerConfig { n_layers: 2, ..Default::default() };
        let chained = {
            let c = Coordinator::start(CoordinatorOptions::default());
            let rxs: Vec<_> = cfgs
                .chains()
                .into_iter()
                .map(|ch| c.submit_chain(ch).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
            c.shutdown().unwrap()
        };
        let isolated = {
            let c = Coordinator::start(CoordinatorOptions::default());
            let rxs: Vec<_> = cfgs
                .trace()
                .into_iter()
                .map(|g| c.submit(GemmRequest::sim(g)).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
            c.shutdown().unwrap()
        };
        assert_eq!(chained.count(), isolated.count());
        let ops = isolated.total_ops();
        assert!((chained.total_ops() - ops).abs() < 1e-9 * ops, "ops conservation");
        assert!(
            chained.total_device_s() < isolated.total_device_s(),
            "chained {:.3} ms !< isolated {:.3} ms",
            chained.total_device_s() * 1e3,
            isolated.total_device_s() * 1e3
        );
        assert!(chained.chain_fused_edges() > 0);
        assert!(isolated.chains.is_empty());
    }

    #[test]
    fn staged_chain_pins_device_and_consumes_the_entry_a() {
        // The DAG-aware entry point: a chain pinned to device 1 whose
        // entry A is a caller-staged C (the cross-chain edge of the
        // graph compiler's lowering) — the functional result must fold
        // from that staged image, not a generated one.
        let c = Coordinator::start(CoordinatorOptions {
            backend: Backend::Functional,
            devices: vec![Generation::Xdna, Generation::Xdna],
            ..Default::default()
        });
        let s0 = GemmShape::new("prod", 64, 64, 64, Precision::I8I8);
        let s1 = GemmShape::new("cons", 64, 64, 64, Precision::I8I8);
        let (a0, b0) = functional_inputs(&s0, Precision::I8I8).unwrap();
        let staged_c = crate::gemm::refimpl::ref_gemm(&a0, &b0, Precision::I8I8).unwrap();
        let mut chain = crate::plan::GemmChain::new("staged");
        chain.push(s1.clone());
        let rx = c
            .submit_chain_staged(
                chain,
                ChainStaging { device: Some(1), a0: Some(staged_c.clone()), a0_sums: None },
            )
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.device, 1, "pin respected");
        assert_eq!(resp.staged_edges, 1, "entry A consumed");
        let got = resp.result.expect("functional result");
        let b1 = functional_b(&s1, Precision::I8I8).unwrap();
        let want = crate::gemm::refimpl::ref_gemm(&staged_c, &b1, Precision::I8I8).unwrap();
        assert!(crate::gemm::refimpl::matrices_equal(&got, &want, Precision::I8I8));

        // Out-of-range pins and mis-shaped staged images fail at submit.
        let mut chain2 = crate::plan::GemmChain::new("bad-pin");
        chain2.push(s1.clone());
        assert!(c
            .submit_chain_staged(chain2, ChainStaging { device: Some(7), ..Default::default() })
            .is_err());
        let mut chain3 = crate::plan::GemmChain::new("bad-a0");
        chain3.push(s1.clone());
        let wrong = Matrix::zeroed(32, 64, 1, Layout::RowMajor).unwrap();
        assert!(c
            .submit_chain_staged(chain3, ChainStaging { a0: Some(wrong), ..Default::default() })
            .is_err());
        // Right dims, wrong element dtype (bf16 bytes into an int8 op):
        // rejected at submit, never reinterpreted as raw bytes.
        let mut chain4 = crate::plan::GemmChain::new("bad-dtype");
        chain4.push(s1.clone());
        let wrong_ty = Matrix::zeroed(64, 64, 2, Layout::RowMajor).unwrap();
        assert!(c
            .submit_chain_staged(
                chain4,
                ChainStaging { a0: Some(wrong_ty), ..Default::default() },
            )
            .is_err());
        let m = c.shutdown().unwrap();
        assert_eq!(m.count(), 1);
        assert_eq!(c2_count(&m, 1), 1, "record landed on the pinned device");
    }

    fn c2_count(m: &crate::coordinator::FleetMetrics, dev: usize) -> usize {
        m.devices[dev].metrics.count()
    }

    #[test]
    fn empty_chain_is_rejected() {
        let c = Coordinator::start(CoordinatorOptions::default());
        assert!(c.submit_chain(crate::plan::GemmChain::new("empty")).is_err());
        let m = c.shutdown().unwrap();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn mix_parsing_and_expansion() {
        assert_eq!(parse_mix("xdna:xdna2").unwrap(), vec![Generation::Xdna, Generation::Xdna2]);
        assert_eq!(parse_mix("xdna2").unwrap(), vec![Generation::Xdna2]);
        assert_eq!(parse_mix("xdna, xdna2").unwrap(), vec![Generation::Xdna, Generation::Xdna2]);
        assert!(parse_mix("tpu").is_err());
        assert!(parse_mix(":").is_err());
        assert_eq!(
            expand_mix(&[Generation::Xdna, Generation::Xdna2], 5),
            vec![
                Generation::Xdna,
                Generation::Xdna2,
                Generation::Xdna,
                Generation::Xdna2,
                Generation::Xdna,
            ]
        );
    }

    #[test]
    fn tenant_spec_parsing() {
        assert_eq!(
            parse_tenants("decode:2:8,prefill:0:32").unwrap(),
            vec![
                TenantSpec { name: "decode".into(), priority: 2, quota: 8 },
                TenantSpec { name: "prefill".into(), priority: 0, quota: 32 },
            ]
        );
        assert_eq!(
            parse_tenants("solo").unwrap(),
            vec![TenantSpec { name: "solo".into(), priority: 0, quota: 0 }]
        );
        assert_eq!(
            parse_tenants("a:1").unwrap(),
            vec![TenantSpec { name: "a".into(), priority: 1, quota: 0 }]
        );
        assert!(parse_tenants("").is_err());
        assert!(parse_tenants(":1:2").is_err());
        assert!(parse_tenants("x:hot").is_err());
        assert!(parse_tenants("x:1:2:3").is_err());
    }

    #[test]
    fn prio_queue_orders_by_class_then_fifo() {
        fn unit(id: u64, tenant: usize) -> Unit {
            let (tx, _rx) = channel();
            Unit::Req(Box::new(Pending {
                id,
                tenant,
                req: GemmRequest::sim(GemmShape::new("q", 64, 64, 64, Precision::I8I8)),
                tx,
                t0: Instant::now(),
                requeued: false,
                integrity_retries: 0,
            }))
        }
        fn id_of(u: &Unit) -> u64 {
            match u {
                Unit::Req(p) => p.id,
                Unit::Chain(c) => c.id,
            }
        }
        let mut q = PrioQueue::new(3);
        q.push_back(0, unit(1, 0));
        q.push_back(0, unit(2, 0));
        q.push_back(2, unit(3, 1));
        q.push_back(1, unit(4, 2));
        q.push_back(2, unit(5, 1));
        // Requeue jumps the front of its own class, not other classes.
        q.push_front(1, unit(6, 2));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|u| id_of(&u)).collect();
        assert_eq!(order, vec![3, 5, 6, 4, 1, 2]);
        assert!(q.is_empty());
        // Out-of-range priorities clamp to the top class.
        let mut q2 = PrioQueue::new(1);
        q2.push_back(7, unit(9, 0));
        assert_eq!(q2.pop().map(|u| id_of(&u)), Some(9));
    }

    #[test]
    fn submit_for_validates_tenant_index() {
        let c = Coordinator::start(CoordinatorOptions::default());
        assert_eq!(c.n_tenants(), 1);
        let req = GemmRequest::sim(GemmShape::new("t", 64, 64, 64, Precision::I8I8));
        assert!(c.submit_for(1, req.clone()).is_err(), "only tenant 0 exists by default");
        assert!(c.submit_for(0, req).is_ok());
        c.shutdown().unwrap();
    }

    #[test]
    fn default_options_have_no_chaos() {
        let o = CoordinatorOptions::default();
        assert!(o.chaos.is_none());
        assert!(o.tenants.is_empty());
        assert_eq!(o.tenant_specs().len(), 1);
        assert_eq!(o.tenant_specs()[0].name, "default");
        assert_eq!(o.max_leader_respawns, 16);
        assert_eq!(o.integrity, IntegrityMode::Off, "integrity checking is opt-in");
        assert_eq!(o.max_integrity_retries, 2);
    }

    #[test]
    fn integrity_mode_parsing() {
        assert_eq!(parse_integrity("off").unwrap(), IntegrityMode::Off);
        assert_eq!(parse_integrity("abft").unwrap(), IntegrityMode::Abft);
        assert_eq!(parse_integrity(" Full ").unwrap(), IntegrityMode::Full);
        assert_eq!(parse_integrity("checksum").unwrap(), IntegrityMode::Abft);
        assert!(parse_integrity("paranoid").is_err());
    }

    #[test]
    fn abft_integrity_passes_clean_functional_traffic() {
        // Clean runs under --integrity abft: every record checks out,
        // nothing is retried, and the tenant counters conserve.
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna2,
            backend: Backend::Functional,
            integrity: IntegrityMode::Abft,
            ..Default::default()
        });
        for p in [Precision::I8I8, Precision::Bf16] {
            let resp =
                c.call(GemmRequest::sim(GemmShape::new("clean", 64, 64, 64, p))).unwrap();
            assert_eq!(resp.integrity, Integrity::Passed, "{p}");
            assert!(resp.result.is_some());
        }
        let m = c.shutdown().unwrap();
        let (checked, passed, recovered, failed) = m.integrity_totals();
        assert_eq!((checked, passed, recovered, failed), (2, 2, 0, 0));
        assert!(m.tenants.iter().all(TenantStats::conserves));
    }

    #[test]
    fn corrupted_result_is_detected_and_recovered_bit_exactly() {
        // The corrupt test hook flips a word in the first attempt's C;
        // ABFT detects it and the verified recompute must serve the
        // exact bits of an uncorrupted run.
        let mk = || {
            Coordinator::start(CoordinatorOptions {
                gen: Generation::Xdna2,
                backend: Backend::Functional,
                integrity: IntegrityMode::Abft,
                ..Default::default()
            })
        };
        let shape = GemmShape::new("c", 64, 64, 64, Precision::I8I8);
        let c = mk();
        let clean = c.call(GemmRequest::sim(shape.clone())).unwrap();
        assert_eq!(clean.integrity, Integrity::Passed);
        c.shutdown().unwrap();

        let c = mk();
        let mut req = GemmRequest::sim(shape);
        req.corrupt = 1;
        let resp = c.call(req).unwrap();
        assert_eq!(resp.integrity, Integrity::Recovered { retries: 1 });
        assert_eq!(resp.verified(), Some(true), "recovered counts as good");
        assert!(refimpl::matrices_equal(
            resp.result.as_ref().unwrap(),
            clean.result.as_ref().unwrap(),
            Precision::I8I8,
        ));
        let m = c.shutdown().unwrap();
        let (checked, passed, recovered, failed) = m.integrity_totals();
        assert_eq!((checked, passed, recovered, failed), (1, 0, 1, 0));
        assert_eq!(m.tenants[0].requeued, 1, "the retry rode the requeue path");
        assert!(m.tenants[0].conserves());
    }

    #[test]
    fn integrity_retry_budget_exhaustion_fails_visibly() {
        // Three corrupted attempts against a budget of two retries: the
        // unit fails visibly (result: None) instead of hanging or
        // serving corrupt bits.
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna2,
            backend: Backend::Functional,
            integrity: IntegrityMode::Abft,
            max_integrity_retries: 2,
            ..Default::default()
        });
        let mut req = GemmRequest::sim(GemmShape::new("c3", 64, 64, 64, Precision::I8I8));
        req.corrupt = 3;
        let resp = c.call(req).unwrap();
        assert_eq!(resp.integrity, Integrity::Failed);
        assert!(resp.result.is_none(), "a corrupted C is never served");
        let m = c.shutdown().unwrap();
        let (checked, _, _, failed) = m.integrity_totals();
        assert_eq!((checked, failed), (1, 1));
        assert_eq!(m.tenants[0].requeued, 2, "both retries were consumed");
        assert!(m.tenants[0].conserves());
        assert_eq!(m.tenants[0].completed, 1, "the unit still completes (with Failed)");
    }

    #[test]
    fn sim_only_integrity_charges_the_checksum_cost() {
        // SimOnly has no bytes to check, but --integrity abft must
        // charge the checksum pass on the device clock: same traffic,
        // strictly more device seconds, and records marked Passed.
        let run = |mode| {
            let c = Coordinator::start(CoordinatorOptions {
                gen: Generation::Xdna2,
                integrity: mode,
                ..Default::default()
            });
            let shape = GemmShape::new("s", 1024, 1024, 1024, Precision::I8I8);
            let resp = c.call(GemmRequest::sim(shape)).unwrap();
            (resp.device_s, resp.integrity, c.shutdown().unwrap())
        };
        let (off_s, off_i, m_off) = run(IntegrityMode::Off);
        let (abft_s, abft_i, m_abft) = run(IntegrityMode::Abft);
        let (full_s, _, _) = run(IntegrityMode::Full);
        assert_eq!(off_i, Integrity::NotChecked);
        assert_eq!(abft_i, Integrity::Passed);
        assert_eq!(m_off.integrity_totals().0, 0);
        assert_eq!(m_abft.integrity_totals().0, 1);
        assert!(abft_s > off_s, "checksum cost lands on the device clock");
        assert!(
            abft_s - off_s < (full_s - off_s) / 10.0,
            "ABFT at least 10x cheaper than a full recompute: abft +{:.3e}s, full +{:.3e}s",
            abft_s - off_s,
            full_s - off_s
        );
    }
}
