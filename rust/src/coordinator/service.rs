//! The service itself: an admission/router thread fronting a pool of
//! leader threads, one per simulated NPU device.
//!
//! Clients submit over a bounded channel (admission backpressure); the
//! router buckets each request by its [`DesignKey`] and forwards it to
//! the device chosen by the [`FleetRouter`] — the device already holding
//! the design when its backlog allows, the least-loaded device otherwise
//! (Sec. 5.3.1 applied fleet-wide). Each leader owns its device
//! (design cache + loaded-design state), drains its queue in batches,
//! and sorts every batch by design key so a burst of mixed-precision
//! traffic pays each reconfiguration once. The router keeps at most
//! `max_in_flight` requests outstanding per device; completions flow
//! back to refill the window, and shutdown drains every queue before
//! the leaders exit.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::arch::Generation;
use crate::dtype::{Layout, Precision};
use crate::gemm::exec::{ExecOptions, Executor};
use crate::gemm::refimpl;
use crate::mem::Matrix;
use crate::plan::{overrides_for, GemmChain};
use crate::sim::{simulate_gemm, simulate_gemm_with, BdMode, GemmReport};
use crate::tiling::TilingConfig;
use crate::workload::GemmShape;

use super::metrics::{ChainRecord, DeviceMetrics, FleetMetrics, Metrics, RequestRecord};
use super::router::{CacheStats, DesignCache, DesignKey, DeviceState, FleetRouter};

/// How requests execute.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Backend {
    /// Timing only (sweeps, tables, load tests).
    SimOnly,
    /// Timing + real numerics through the functional executor, verified
    /// against the reference when `verify` is set.
    Functional,
}

#[derive(Clone, Debug)]
pub struct GemmRequest {
    pub shape: GemmShape,
    /// Input images for `Backend::Functional` (None → generated inputs).
    pub data: Option<(Matrix, Matrix)>,
    /// Check the functional result against `refimpl` (expensive).
    pub verify: bool,
    pub bd_mode: BdMode,
}

impl GemmRequest {
    pub fn sim(shape: GemmShape) -> GemmRequest {
        GemmRequest { shape, data: None, verify: false, bd_mode: BdMode::Overlapped }
    }
}

/// One completed chain (`Coordinator::submit_chain`): every op ran back
/// to back on one device, fused edges kept the intermediate C in L2,
/// and same-design ops rode the first op's host submission.
#[derive(Debug)]
pub struct ChainResponse {
    pub id: u64,
    pub name: String,
    /// Fleet device index that served the whole chain.
    pub device: usize,
    /// Chain makespan: summed device seconds including reconfigurations.
    pub device_s: f64,
    pub fused_edges: usize,
    pub elided_dispatches: usize,
    /// Per-op simulation reports, in chain order.
    pub reports: Vec<GemmReport>,
    /// Final op's functional C (`Backend::Functional` only): each
    /// producer→consumer edge fed the staged C straight into the packed
    /// executor as the next op's A. `None` if any op's functional
    /// execution failed (the failing op's record carries
    /// `verified: Some(false)`).
    pub result: Option<Matrix>,
    /// Edges where a staged functional C actually fed an op's A: the
    /// chain's internal `consumes_prev` edges, plus the submission's
    /// entry A when one was staged (`ChainStaging::a0`).
    pub staged_edges: usize,
}

#[derive(Debug)]
pub struct GemmResponse {
    pub id: u64,
    pub name: String,
    /// Fleet device index that served the request.
    pub device: usize,
    /// Simulated performance report (padded sizes, phase times, TOPS).
    pub sim: GemmReport,
    /// Device seconds including any design reconfiguration.
    pub device_s: f64,
    pub reconfigured: bool,
    pub verified: Option<bool>,
    /// Functional result (when requested).
    pub result: Option<Matrix>,
}

#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    /// Generation of the single device when `devices` is empty.
    pub gen: Generation,
    pub backend: Backend,
    /// Scheduler batching window: how many queued requests a leader
    /// drains and design-groups per scheduling round.
    pub batch_window: usize,
    /// Device fleet: one leader thread per entry, generations mixable
    /// (`serve --devices N --mix xdna:xdna2`). Empty → `vec![gen]`.
    pub devices: Vec<Generation>,
    /// Bounded per-device in-flight window: the router keeps at most
    /// this many requests forwarded to a leader at once; excess requests
    /// wait in the router's per-device queue, where routing decisions
    /// can still see (and rebalance around) the backlog.
    pub max_in_flight: usize,
    /// Per-device design-cache capacity (0 = unbounded). The fleet
    /// router mirrors this bound, so affinity is forgotten when a
    /// leader's cache would have evicted the design.
    pub design_capacity: usize,
    /// Admission-channel bound: `submit` blocks once this many messages
    /// are in transit to the router. Note this caps the client→router
    /// pipe, not total queued work — the router drains it continuously
    /// (completions share the channel), so its per-device queues grow
    /// without bound if producers outpace the fleet indefinitely.
    pub admission_capacity: usize,
    /// Worker threads for the functional executor's output-tile fan-out
    /// (`serve --functional --threads T`). Results are bit-identical for
    /// every value (`gemm::exec::ExecOptions::threads`).
    pub exec_threads: usize,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            gen: Generation::Xdna2,
            backend: Backend::SimOnly,
            batch_window: 16,
            devices: Vec::new(),
            max_in_flight: 64,
            design_capacity: 0,
            admission_capacity: 4096,
            exec_threads: 1,
        }
    }
}

impl CoordinatorOptions {
    /// Options for an explicit device fleet.
    pub fn fleet(devices: Vec<Generation>) -> CoordinatorOptions {
        CoordinatorOptions { devices, ..Default::default() }
    }

    /// The resolved fleet (at least one device).
    pub fn device_gens(&self) -> Vec<Generation> {
        if self.devices.is_empty() {
            vec![self.gen]
        } else {
            self.devices.clone()
        }
    }
}

/// Parse a `--mix` pattern like `xdna:xdna2` (also accepts commas) into
/// a generation cycle.
pub fn parse_mix(s: &str) -> Result<Vec<Generation>> {
    let mut out = Vec::new();
    for tok in s.split(|c: char| c == ':' || c == ',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        match Generation::parse(tok) {
            Some(g) => out.push(g),
            None => bail!("unknown generation '{tok}' in mix '{s}'"),
        }
    }
    if out.is_empty() {
        bail!("empty device mix '{s}'");
    }
    Ok(out)
}

/// Cycle `pattern` to fill `n` device slots: `expand_mix(&[Xdna, Xdna2],
/// 4)` → `[Xdna, Xdna2, Xdna, Xdna2]`. An empty pattern yields an empty
/// fleet (callers fall back to `CoordinatorOptions::gen`).
pub fn expand_mix(pattern: &[Generation], n: usize) -> Vec<Generation> {
    if pattern.is_empty() {
        return Vec::new();
    }
    (0..n).map(|i| pattern[i % pattern.len()]).collect()
}

/// A submitted request travelling router → leader.
struct Pending {
    id: u64,
    req: GemmRequest,
    tx: Sender<GemmResponse>,
    t0: Instant,
}

/// DAG-aware chain submission context (`Coordinator::submit_chain_staged`,
/// used by the graph compiler's `graph::exec::serve_graph`): pin the
/// chain to a partitioner-chosen device, and/or stage a producer's C as
/// the chain's entry A — the cross-chain edges of `graph::lower`, where
/// one C may fan out into several consumers' A or arrive pre-joined.
#[derive(Debug, Default)]
pub struct ChainStaging {
    /// Fleet device index to place the chain on (bypasses the router's
    /// affinity choice; load accounting still applies). `None` routes by
    /// leading design key as before.
    pub device: Option<usize>,
    /// Entry A for the chain's first op under `Backend::Functional`: a
    /// staged producer C (or an elementwise join of several). `None`
    /// falls back to the deterministic generated A.
    pub a0: Option<Matrix>,
}

/// A submitted chain travelling router → leader as one unit.
struct PendingChain {
    id: u64,
    chain: GemmChain,
    bd_mode: BdMode,
    staging: ChainStaging,
    tx: Sender<ChainResponse>,
    t0: Instant,
}

/// One schedulable unit in a router queue / leader batch: a single
/// request or a whole chain (which stays contiguous and in order).
enum Unit {
    Req(Box<Pending>),
    Chain(Box<PendingChain>),
}

impl Unit {
    /// In-flight slots / record count this unit accounts for.
    fn len(&self) -> usize {
        match self {
            Unit::Req(_) => 1,
            Unit::Chain(c) => c.chain.len(),
        }
    }

    /// Design-grouping sort key (chains group by their leading op).
    fn sort_key(&self) -> (Precision, bool, u64) {
        match self {
            Unit::Req(p) => {
                (p.req.shape.precision, p.req.shape.b_layout == Layout::ColMajor, p.id)
            }
            Unit::Chain(c) => {
                let s = &c.chain.ops[0].shape;
                (s.precision, s.b_layout == Layout::ColMajor, c.id)
            }
        }
    }
}

enum Msg {
    Submit(Box<Pending>),
    SubmitChain(Box<PendingChain>),
    Warm(DesignKey),
    Flush(Sender<FleetMetrics>),
    /// Leader → router: a batch completed. `resident` is the leader's
    /// authoritative design-cache LRU state for residency reconciliation.
    Done {
        dev: usize,
        records: Vec<RequestRecord>,
        chains: Vec<ChainRecord>,
        cache: CacheStats,
        resident: Vec<DesignKey>,
    },
    Shutdown,
}

enum DeviceMsg {
    Run(Box<Pending>),
    RunChain(Box<PendingChain>),
    Warm(DesignKey),
    Shutdown,
}

/// Handle to a running coordinator (router thread + leader pool).
pub struct Coordinator {
    tx: SyncSender<Msg>,
    handle: Option<JoinHandle<FleetMetrics>>,
    next_id: std::sync::atomic::AtomicU64,
    n_devices: usize,
}

impl Coordinator {
    pub fn start(opts: CoordinatorOptions) -> Coordinator {
        let n_devices = opts.device_gens().len();
        let (tx, rx) = sync_channel::<Msg>(opts.admission_capacity.max(1));
        let done_tx = tx.clone();
        let handle = std::thread::spawn(move || router_loop(opts, rx, done_tx));
        Coordinator { tx, handle: Some(handle), next_id: 0.into(), n_devices }
    }

    /// Devices in the running fleet.
    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Submit a request; the response arrives on the returned channel.
    /// Blocks only when the admission queue is full (backpressure).
    pub fn submit(&self, req: GemmRequest) -> Receiver<GemmResponse> {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Submit(Box::new(Pending { id, req, tx: rtx, t0: Instant::now() })))
            .expect("coordinator thread alive");
        rrx
    }

    /// Blocking convenience wrapper.
    pub fn call(&self, req: GemmRequest) -> Result<GemmResponse> {
        self.submit(req).recv().map_err(|e| anyhow!("coordinator dropped: {e}"))
    }

    /// Submit a whole chain: the router places it on one device by its
    /// leading design key (chain affinity — the design stays cache-hot
    /// for the entire run), and the leader executes the ops back to
    /// back, fusing L2-resident edges and amortizing same-design
    /// dispatches exactly like the offline planner
    /// (`crate::plan::overrides_for` against the leader's own design
    /// cache). Chains ride the timing path (`Backend::SimOnly`
    /// semantics); the functional staged-C path is
    /// `gemm::exec::Executor::execute_chain`.
    pub fn submit_chain(&self, chain: GemmChain) -> Result<Receiver<ChainResponse>> {
        self.submit_chain_staged(chain, ChainStaging::default())
    }

    /// The DAG-aware chain entry point (`graph::lower` cross-chain
    /// edges): like [`Self::submit_chain`], but the chain may be pinned
    /// to a specific device (the graph partitioner's placement) and may
    /// carry a staged entry A — a producer chain's functional C, cloned
    /// per consumer on fan-out or elementwise-joined on fan-in, instead
    /// of `consumes_prev`-only staging. The staged A must match the
    /// first op's logical `m × k` as a row-major image.
    pub fn submit_chain_staged(
        &self,
        chain: GemmChain,
        staging: ChainStaging,
    ) -> Result<Receiver<ChainResponse>> {
        if chain.is_empty() {
            bail!("empty chain '{}'", chain.name);
        }
        if let Some(d) = staging.device {
            if d >= self.n_devices {
                bail!("device {d} out of range (fleet has {})", self.n_devices);
            }
        }
        if let Some(a0) = &staging.a0 {
            let first = &chain.ops[0].shape;
            let (rows, cols) = refimpl::logical_dims(a0);
            if a0.layout != Layout::RowMajor || (rows, cols) != (first.m, first.k) {
                bail!(
                    "staged A is {rows}x{cols} {:?}, first op '{}' needs row-major {}x{}",
                    a0.layout,
                    first.name,
                    first.m,
                    first.k
                );
            }
            // Element format must match the design's input dtype too — a
            // mis-typed image would otherwise be reinterpreted as raw
            // bytes and silently produce a wrong C.
            let p = DesignKey::for_shape(first).precision;
            let type_ok = if p == Precision::Bfp16 {
                a0.is_bfp16()
            } else {
                !a0.is_bfp16() && a0.elem_bytes == p.ty_in()
            };
            if !type_ok {
                bail!(
                    "staged A has {}-byte elements, first op '{}' is {p} \
                     (expects {})",
                    a0.elem_bytes,
                    first.name,
                    if p == Precision::Bfp16 {
                        "12-byte block cells".to_string()
                    } else {
                        format!("{}-byte elements", p.ty_in())
                    }
                );
            }
        }
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::SubmitChain(Box::new(PendingChain {
                id,
                chain,
                bd_mode: BdMode::Overlapped,
                staging,
                tx: rtx,
                t0: Instant::now(),
            })))
            .expect("coordinator thread alive");
        Ok(rrx)
    }

    /// Blocking convenience wrapper for [`Self::submit_chain`].
    pub fn call_chain(&self, chain: GemmChain) -> Result<ChainResponse> {
        self.submit_chain(chain)?.recv().map_err(|e| anyhow!("coordinator dropped: {e}"))
    }

    /// Pre-load `key`'s design onto a device off the request path: the
    /// router records the affinity and the chosen leader reconfigures
    /// immediately, so the first real request for `key` pays no
    /// reconfiguration.
    pub fn warm(&self, key: DesignKey) {
        let _ = self.tx.send(Msg::Warm(key));
    }

    /// Snapshot current fleet metrics.
    pub fn metrics(&self) -> Result<FleetMetrics> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Flush(tx)).map_err(|e| anyhow!("send: {e}"))?;
        rx.recv().map_err(|e| anyhow!("recv: {e}"))
    }

    /// Stop accepting work, drain every queue, stop the leaders, and
    /// return the final fleet metrics.
    pub fn shutdown(mut self) -> FleetMetrics {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle.take().unwrap().join().expect("router panicked")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Forward queued work to leader `d` while its in-flight window allows.
/// A chain counts its full length against the window but is forwarded
/// whole whenever any window remains (it may overshoot — splitting it
/// would forfeit the fused edges, and a chain longer than the window
/// must not deadlock).
fn pump(
    d: usize,
    max_in_flight: usize,
    queues: &mut [VecDeque<Unit>],
    in_flight: &mut [usize],
    leader_txs: &[Sender<DeviceMsg>],
) {
    while in_flight[d] < max_in_flight {
        match queues[d].pop_front() {
            Some(unit) => {
                in_flight[d] += unit.len();
                let _ = leader_txs[d].send(match unit {
                    Unit::Req(p) => DeviceMsg::Run(p),
                    Unit::Chain(c) => DeviceMsg::RunChain(c),
                });
            }
            None => break,
        }
    }
}

fn router_loop(
    opts: CoordinatorOptions,
    rx: Receiver<Msg>,
    done_tx: SyncSender<Msg>,
) -> FleetMetrics {
    let gens = opts.device_gens();
    let n_dev = gens.len();
    let max_in_flight = opts.max_in_flight.max(1);

    let mut fleet = FleetRouter::with_capacity(gens.clone(), opts.design_capacity);
    let mut queues: Vec<VecDeque<Unit>> = (0..n_dev).map(|_| VecDeque::new()).collect();
    let mut in_flight = vec![0usize; n_dev];
    let mut per_dev: Vec<Metrics> = (0..n_dev).map(|_| Metrics::default()).collect();
    let mut caches = vec![CacheStats::default(); n_dev];
    let mut chain_records: Vec<ChainRecord> = Vec::new();

    let mut leader_txs: Vec<Sender<DeviceMsg>> = Vec::with_capacity(n_dev);
    let mut leader_handles: Vec<JoinHandle<CacheStats>> = Vec::with_capacity(n_dev);
    for (d, gen) in gens.iter().copied().enumerate() {
        let (ltx, lrx) = channel::<DeviceMsg>();
        let o = opts.clone();
        let done = done_tx.clone();
        leader_handles.push(std::thread::spawn(move || leader_loop(d, gen, o, lrx, done)));
        leader_txs.push(ltx);
    }
    // The router's own clone kept the channel open for the leaders'
    // `Done` sends; those have their own clones now.
    drop(done_tx);

    let assemble = |per_dev: &[Metrics],
                    caches: &[CacheStats],
                    fleet: &FleetRouter,
                    chain_records: &[ChainRecord]| {
        let mut fm = FleetMetrics {
            devices: Vec::with_capacity(n_dev),
            router_hits: fleet.hits,
            router_misses: fleet.misses,
            router_spills: fleet.spills,
            chains: chain_records.to_vec(),
        };
        for d in 0..n_dev {
            fm.devices.push(DeviceMetrics {
                gen: gens[d],
                metrics: per_dev[d].clone(),
                cache: caches[d],
            });
        }
        fm
    };

    let mut draining = false;
    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            // All senders gone: clients dropped and every leader exited.
            Err(_) => break,
        };
        match msg {
            Msg::Submit(p) => {
                let key = DesignKey::for_shape(&p.req.shape);
                let d = fleet.route(key, p.req.shape.ops()).device;
                queues[d].push_back(Unit::Req(p));
                pump(d, max_in_flight, &mut queues, &mut in_flight, &leader_txs);
            }
            Msg::SubmitChain(c) => {
                // Chain affinity: one routing decision for the whole
                // chain, charged with its total ops. A pinned chain (the
                // graph partitioner's placement) bypasses the device
                // choice but still updates the load/residency model.
                let key = DesignKey::for_shape(&c.chain.ops[0].shape);
                let d = match c.staging.device {
                    Some(d) => fleet.route_to(d, key, c.chain.total_ops()).device,
                    None => fleet.route_chain(key, c.chain.total_ops()).device,
                };
                queues[d].push_back(Unit::Chain(c));
                pump(d, max_in_flight, &mut queues, &mut in_flight, &leader_txs);
            }
            Msg::Warm(key) => {
                let d = fleet.warm(key);
                let _ = leader_txs[d].send(DeviceMsg::Warm(key));
            }
            Msg::Flush(tx) => {
                let _ = tx.send(assemble(&per_dev, &caches, &fleet, &chain_records));
            }
            Msg::Done { dev, records, chains, cache, resident } => {
                in_flight[dev] -= records.len();
                caches[dev] = cache;
                fleet.sync_residency(dev, &resident);
                for r in records {
                    per_dev[dev].push(r);
                }
                chain_records.extend(chains);
                pump(dev, max_in_flight, &mut queues, &mut in_flight, &leader_txs);
            }
            Msg::Shutdown => draining = true,
        }
        let idle = queues.iter().all(VecDeque::is_empty) && in_flight.iter().all(|&n| n == 0);
        if draining && idle {
            break;
        }
    }

    // Leaders are idle (every forwarded request was acknowledged), so a
    // Shutdown is the next message each will see.
    for ltx in &leader_txs {
        let _ = ltx.send(DeviceMsg::Shutdown);
    }
    drop(leader_txs);
    for (d, h) in leader_handles.into_iter().enumerate() {
        if let Ok(stats) = h.join() {
            caches[d] = stats;
        }
    }
    assemble(&per_dev, &caches, &fleet, &chain_records)
}

/// Absorb one message into the leader's batch / state.
fn absorb(
    m: DeviceMsg,
    gen: Generation,
    batch: &mut Vec<Unit>,
    cache: &mut DesignCache,
    device: &mut DeviceState,
    shutdown: &mut bool,
) {
    match m {
        DeviceMsg::Run(p) => batch.push(Unit::Req(p)),
        DeviceMsg::RunChain(c) => batch.push(Unit::Chain(c)),
        DeviceMsg::Warm(key) => {
            cache.warm(key);
            device.switch_to(gen, key);
        }
        DeviceMsg::Shutdown => *shutdown = true,
    }
}

/// Execute one chain on the leader's device: designs resolved from the
/// leader's cache, fused edges and dispatch amortization from the same
/// rule the offline planner uses, reconfiguration charged through the
/// shared device state. Under `Backend::Functional` every op also runs
/// through the packed executor, and each producer→consumer edge feeds
/// the staged C straight into the next op as its A — the functional
/// mirror of the planner's fused dataflow.
fn run_chain(
    dev: usize,
    gen: Generation,
    pc: PendingChain,
    opts: &CoordinatorOptions,
    cache: &mut DesignCache,
    device: &mut DeviceState,
    records: &mut Vec<RequestRecord>,
) -> (ChainRecord, Sender<ChainResponse>, ChainResponse) {
    let PendingChain { id, chain, bd_mode, staging, tx, t0 } = pc;
    let cfgs: Vec<TilingConfig> =
        chain.ops.iter().map(|o| *cache.get(DesignKey::for_shape(&o.shape))).collect();
    let ovs = overrides_for(&cfgs, &chain);
    let mut chain_s = 0.0;
    let mut fused = 0;
    let mut elided = 0;
    let mut reports = Vec::with_capacity(chain.len());
    // A staged entry A (DAG cross-chain edge) pre-loads the slot the
    // first op consumes; intra-chain edges refill it op by op.
    let mut staged: Option<Matrix> = staging.a0;
    let mut staged_edges = 0usize;
    let mut result: Option<Matrix> = None;
    let mut func_failed = false;
    for (i, op) in chain.ops.iter().enumerate() {
        let key = DesignKey::for_shape(&op.shape);
        let reconfig_s = device.switch_to(gen, key);
        let sim =
            simulate_gemm_with(&cfgs[i], op.shape.m, op.shape.k, op.shape.n, bd_mode, ovs[i]);
        let device_s = sim.t_total + reconfig_s;
        chain_s += device_s;
        fused += ovs[i].a_in_l2 as usize;
        elided += ovs[i].elide_dispatch as usize;
        // A failed op poisons the rest of the functional run: no random-A
        // substitution for downstream consumers, no final result — the
        // caller sees `result: None` instead of a silently wrong C.
        let mut op_verified = None;
        if opts.backend == Backend::Functional && !func_failed {
            let exec = Executor::with_options(
                cfgs[i],
                ExecOptions { threads: opts.exec_threads, ..Default::default() },
            );
            let inputs: Result<(Matrix, Matrix)> = (|| {
                let a = match staged.take() {
                    // The first op consumes the submission's staged A;
                    // later ops consume the previous op's resident C.
                    Some(c) if op.consumes_prev || i == 0 => {
                        staged_edges += 1;
                        c
                    }
                    _ => functional_a(&op.shape, cfgs[i].precision)?,
                };
                Ok((a, functional_b(&op.shape, cfgs[i].precision)?))
            })();
            match inputs.and_then(|(a, b)| exec.execute(&a, &b)) {
                Ok(c) => {
                    // Move (never clone) the C image: it becomes the final
                    // result, or the staged A of a consuming next op.
                    if i + 1 == chain.ops.len() {
                        result = Some(c);
                    } else if chain.ops[i + 1].consumes_prev {
                        staged = Some(c);
                    }
                }
                Err(_) => {
                    func_failed = true;
                    op_verified = Some(false);
                }
            }
        }
        records.push(RequestRecord {
            id,
            name: op.shape.name.clone(),
            device: dev,
            device_s,
            host_latency_s: t0.elapsed().as_secs_f64(),
            ops: op.shape.ops(),
            reconfigured: reconfig_s > 0.0,
            verified: op_verified,
            chain: Some(id),
        });
        reports.push(sim);
    }
    let record = ChainRecord {
        id,
        name: chain.name.clone(),
        device: dev,
        ops_count: chain.len(),
        fused_edges: fused,
        elided_dispatches: elided,
        device_s: chain_s,
    };
    let response = ChainResponse {
        id,
        name: chain.name,
        device: dev,
        device_s: chain_s,
        fused_edges: fused,
        elided_dispatches: elided,
        reports,
        result,
        staged_edges,
    };
    (record, tx, response)
}

fn leader_loop(
    dev: usize,
    gen: Generation,
    opts: CoordinatorOptions,
    rx: Receiver<DeviceMsg>,
    done: SyncSender<Msg>,
) -> CacheStats {
    let mut cache = DesignCache::with_capacity(gen, opts.design_capacity);
    let mut device = DeviceState::default();

    loop {
        // Block for the first message, then drain up to the batch window.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        let mut batch: Vec<Unit> = Vec::new();
        let mut shutdown = false;
        absorb(first, gen, &mut batch, &mut cache, &mut device, &mut shutdown);
        while batch.len() < opts.batch_window.max(1) {
            match rx.try_recv() {
                Ok(m) => absorb(m, gen, &mut batch, &mut cache, &mut device, &mut shutdown),
                Err(_) => break,
            }
        }

        // Size-class batching: stable-group by design key so a burst of
        // mixed-precision traffic pays each reconfiguration once. Chains
        // group by their leading op and stay contiguous.
        batch.sort_by_key(Unit::sort_key);

        let mut records = Vec::with_capacity(batch.len());
        let mut chain_records = Vec::new();
        let mut responses = Vec::new();
        let mut chain_responses = Vec::new();
        for unit in batch {
            match unit {
                Unit::Chain(pc) => {
                    let (rec, tx, resp) =
                        run_chain(dev, gen, *pc, &opts, &mut cache, &mut device, &mut records);
                    chain_records.push(rec);
                    chain_responses.push((tx, resp));
                }
                Unit::Req(p) => {
                    let Pending { id, req, tx, t0 } = *p;
                    let key = DesignKey::for_shape(&req.shape);
                    let cfg = *cache.get(key);
                    let reconfig_s = device.switch_to(gen, key);
                    let sim =
                        simulate_gemm(&cfg, req.shape.m, req.shape.k, req.shape.n, req.bd_mode);

                    let (result, verified) = match opts.backend {
                        Backend::SimOnly => (None, None),
                        Backend::Functional => run_functional(&cfg, &req, opts.exec_threads),
                    };

                    let device_s = sim.t_total + reconfig_s;
                    records.push(RequestRecord {
                        id,
                        name: req.shape.name.clone(),
                        device: dev,
                        device_s,
                        host_latency_s: t0.elapsed().as_secs_f64(),
                        ops: req.shape.ops(),
                        reconfigured: reconfig_s > 0.0,
                        verified,
                        chain: None,
                    });
                    responses.push((
                        tx,
                        GemmResponse {
                            id,
                            name: req.shape.name,
                            device: dev,
                            sim,
                            device_s,
                            reconfigured: reconfig_s > 0.0,
                            verified,
                            result,
                        },
                    ));
                }
            }
        }
        // Acknowledge to the router before responding to clients: a
        // client holding its response can then rely on a subsequent
        // metrics snapshot including its request.
        if !records.is_empty() {
            let _ = done.send(Msg::Done {
                dev,
                records,
                chains: chain_records,
                cache: cache.stats(),
                resident: cache.resident(),
            });
        }
        for (tx, resp) in responses {
            let _ = tx.send(resp);
        }
        for (tx, resp) in chain_responses {
            let _ = tx.send(resp);
        }

        if shutdown {
            break;
        }
    }
    cache.stats()
}

/// Deterministic functional A for `shape` (seeded from its geometry) —
/// shared by the single-request and chain functional paths, and public
/// so tests can reproduce the coordinator's generated inputs. bfp16
/// shapes produce padded-block images (`refimpl::input_matrix`); an
/// unrepresentable shape (word-misaligned, or a bfp16 K not covering
/// whole blocks) is an `Err`, which the serving paths surface as a
/// failed functional op (`result: None`, `verified: Some(false)`)
/// instead of panicking a device leader.
pub fn functional_a(shape: &GemmShape, p: Precision) -> Result<Matrix> {
    let mut a = refimpl::input_matrix(shape.m, shape.k, p, Layout::RowMajor)?;
    refimpl::fill_random(&mut a, p, shape.m as u64 ^ 0xA5A5);
    Ok(a)
}

/// Deterministic functional B for `shape` (layout per the shape).
pub fn functional_b(shape: &GemmShape, p: Precision) -> Result<Matrix> {
    let mut b = refimpl::input_matrix(shape.k, shape.n, p, shape.b_layout)?;
    refimpl::fill_random(&mut b, p, shape.n as u64 ^ 0x5A5A);
    Ok(b)
}

/// Both generated operands for `shape`.
pub fn functional_inputs(shape: &GemmShape, p: Precision) -> Result<(Matrix, Matrix)> {
    Ok((functional_a(shape, p)?, functional_b(shape, p)?))
}

fn run_functional(
    cfg: &crate::tiling::TilingConfig,
    req: &GemmRequest,
    threads: usize,
) -> (Option<Matrix>, Option<bool>) {
    let p = cfg.precision;
    // Borrow caller-supplied operands; only generated inputs are owned.
    let generated;
    let (a, b) = match &req.data {
        Some((a, b)) => (a, b),
        None => {
            generated = match functional_inputs(&req.shape, p) {
                Ok(g) => g,
                Err(_) => return (None, Some(false)),
            };
            (&generated.0, &generated.1)
        }
    };
    let exec = Executor::with_options(*cfg, ExecOptions { threads, ..Default::default() });
    match exec.execute(a, b) {
        Ok(c) => {
            let verified = if req.verify {
                let want = refimpl::ref_gemm(a, b, p).expect("ref");
                Some(refimpl::matrices_equal(&c, &want, p))
            } else {
                None
            };
            (Some(c), verified)
        }
        Err(_) => (None, Some(false)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Precision;
    use crate::workload::{GemmShape, TransformerConfig};

    #[test]
    fn sim_requests_round_trip() {
        let c = Coordinator::start(CoordinatorOptions::default());
        let resp = c
            .call(GemmRequest::sim(GemmShape::new("t", 4096, 4320, 4480, Precision::I8I16)))
            .unwrap();
        assert!(resp.sim.tops > 25.0, "{}", resp.sim.tops);
        assert!(resp.reconfigured, "first request loads the design");
        let resp2 = c
            .call(GemmRequest::sim(GemmShape::new("t2", 4096, 4320, 4480, Precision::I8I16)))
            .unwrap();
        assert!(!resp2.reconfigured, "design reused");
        let m = c.shutdown();
        assert_eq!(m.count(), 2);
        assert_eq!(m.reconfigurations(), 1);
        assert_eq!(m.n_devices(), 1, "default options run one device");
    }

    #[test]
    fn transformer_trace_reuses_designs() {
        // Sec. 5.3.1: one design serves all layer shapes; only the first
        // request reconfigures.
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna,
            ..Default::default()
        });
        let trace = TransformerConfig { seq: 512, ..Default::default() }.trace();
        let n = trace.len();
        let rxs: Vec<_> = trace.into_iter().map(|g| c.submit(GemmRequest::sim(g))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let m = c.shutdown();
        assert_eq!(m.count(), n);
        assert_eq!(m.reconfigurations(), 1);
        assert!(m.device_tops() > 1.0);
        assert_eq!(m.router_misses, 1, "one design key in the whole trace");
    }

    #[test]
    fn batching_groups_mixed_precisions() {
        // 4 precisions interleaved 4x: FIFO would reconfigure 16 times;
        // the batching scheduler pays ~4 (one per design) when requests
        // arrive together.
        let c = Coordinator::start(CoordinatorOptions {
            batch_window: 32,
            ..Default::default()
        });
        let mut rxs = Vec::new();
        for round in 0..4 {
            for p in Precision::ALL {
                let g = GemmShape::new(&format!("r{round}-{p}"), 1024, 1024, 1024, p);
                rxs.push(c.submit(GemmRequest::sim(g)));
            }
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let m = c.shutdown();
        assert_eq!(m.count(), 16);
        assert!(
            m.reconfigurations() <= 8,
            "batching should coalesce designs: {} reconfigs",
            m.reconfigurations()
        );
    }

    #[test]
    fn functional_backend_verifies() {
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna,
            backend: Backend::Functional,
            ..Default::default()
        });
        // Tiny shape (pads to one native tile of the balanced design).
        let mut req = GemmRequest::sim(GemmShape::new("fv", 64, 64, 64, Precision::I8I8));
        req.verify = true;
        let resp = c.call(req).unwrap();
        assert_eq!(resp.verified, Some(true));
        let out = resp.result.unwrap();
        assert_eq!((out.rows, out.cols), (64, 64));
        c.shutdown();
    }

    #[test]
    fn functional_chain_stages_intermediate_c() {
        // A producer→consumer chain under the functional backend: op 1's
        // A is op 0's drained C (the packed executor's staged path), and
        // the final result matches folding the reference GEMM over the
        // same deterministic inputs. exec_threads=2 doubles as an
        // in-service determinism check (threaded ≡ serial bits).
        let c = Coordinator::start(CoordinatorOptions {
            gen: Generation::Xdna,
            backend: Backend::Functional,
            exec_threads: 2,
            ..Default::default()
        });
        let s0 = GemmShape::new("op0", 64, 64, 64, Precision::I8I8);
        let s1 = GemmShape::new("op1", 64, 64, 64, Precision::I8I8);
        let mut chain = crate::plan::GemmChain::new("func");
        chain.push(s0.clone());
        chain.push_chained(s1.clone()).unwrap();
        let resp = c.call_chain(chain).unwrap();
        assert_eq!(resp.staged_edges, 1, "the edge must consume the staged C");
        let got = resp.result.expect("functional backend returns the final C");
        let (a0, b0) = functional_inputs(&s0, Precision::I8I8).unwrap();
        let b1 = functional_b(&s1, Precision::I8I8).unwrap();
        let mid = refimpl::ref_gemm(&a0, &b0, Precision::I8I8).unwrap();
        let want = refimpl::ref_gemm(&mid, &b1, Precision::I8I8).unwrap();
        assert!(refimpl::matrices_equal(&got, &want, Precision::I8I8));
        c.shutdown();
    }

    #[test]
    fn ragged_bfp16_functional_request_fails_gracefully() {
        // K=100 covers no whole number of 8-value blocks, so no block
        // image can represent the operands. The functional path must
        // poison the request (result: None, verified: Some(false)) —
        // never panic the device leader (sim timing still reports, the
        // simulator pads like any precision).
        let c = Coordinator::start(CoordinatorOptions {
            backend: Backend::Functional,
            ..Default::default()
        });
        let resp = c
            .call(GemmRequest::sim(GemmShape::new("ragged", 64, 100, 64, Precision::Bfp16)))
            .unwrap();
        assert!(resp.result.is_none());
        assert_eq!(resp.verified, Some(false));
        assert!(resp.sim.tops > 0.0, "simulation still accounts the padded dispatch");
        c.shutdown();
    }

    #[test]
    fn chain_lands_whole_on_one_device_with_fused_edges() {
        // A transformer layer chain on a two-device fleet: chain affinity
        // places every op on one leader; the L2-eligible edges fuse and
        // the same-design ops ride one host submission.
        let c = Coordinator::start(CoordinatorOptions::fleet(vec![
            Generation::Xdna2,
            Generation::Xdna2,
        ]));
        let chains = TransformerConfig { n_layers: 2, ..Default::default() }.chains();
        let resp = c.call_chain(chains[0].clone()).unwrap();
        assert_eq!(resp.reports.len(), 4);
        assert_eq!(
            resp.fused_edges, 1,
            "XDNA2 int8 fuses attn_out→ffn_up; ffn_up's C won't coexist with its resident A"
        );
        assert_eq!(resp.elided_dispatches, 3);
        // The fused op moved no A bytes; its producer wrote no C; the
        // unfused ffn_down re-reads its A from DRAM.
        assert_eq!(resp.reports[2].a_bytes, 0.0);
        assert_eq!(resp.reports[1].c_bytes, 0.0);
        assert!(resp.reports[3].a_bytes > 0.0);
        let m = c.shutdown();
        assert_eq!(m.count(), 4, "each chain op is one record");
        assert_eq!(m.chains.len(), 1);
        assert_eq!(m.chains[0].device, resp.device);
        assert!((m.chains[0].device_s - resp.device_s).abs() < 1e-12);
        assert!(m.chain_makespan_s() > 0.0);
        let on_dev: usize = m.devices[resp.device].metrics.count();
        assert_eq!(on_dev, 4, "whole chain on one device");
        assert_eq!(m.router_misses, 1, "one routing decision per chain");
        assert!(m.devices[resp.device]
            .metrics
            .records
            .iter()
            .all(|r| r.chain == Some(resp.id)));
    }

    #[test]
    fn chains_beat_isolated_ops_end_to_end() {
        // Same 2-layer workload through the coordinator both ways: as
        // chains vs as independent requests — chained device time must
        // be strictly smaller (elided dispatches + fused round-trips).
        let cfgs = TransformerConfig { n_layers: 2, ..Default::default() };
        let chained = {
            let c = Coordinator::start(CoordinatorOptions::default());
            let rxs: Vec<_> = cfgs
                .chains()
                .into_iter()
                .map(|ch| c.submit_chain(ch).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
            c.shutdown()
        };
        let isolated = {
            let c = Coordinator::start(CoordinatorOptions::default());
            let rxs: Vec<_> =
                cfgs.trace().into_iter().map(|g| c.submit(GemmRequest::sim(g))).collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
            c.shutdown()
        };
        assert_eq!(chained.count(), isolated.count());
        let ops = isolated.total_ops();
        assert!((chained.total_ops() - ops).abs() < 1e-9 * ops, "ops conservation");
        assert!(
            chained.total_device_s() < isolated.total_device_s(),
            "chained {:.3} ms !< isolated {:.3} ms",
            chained.total_device_s() * 1e3,
            isolated.total_device_s() * 1e3
        );
        assert!(chained.chain_fused_edges() > 0);
        assert!(isolated.chains.is_empty());
    }

    #[test]
    fn staged_chain_pins_device_and_consumes_the_entry_a() {
        // The DAG-aware entry point: a chain pinned to device 1 whose
        // entry A is a caller-staged C (the cross-chain edge of the
        // graph compiler's lowering) — the functional result must fold
        // from that staged image, not a generated one.
        let c = Coordinator::start(CoordinatorOptions {
            backend: Backend::Functional,
            devices: vec![Generation::Xdna, Generation::Xdna],
            ..Default::default()
        });
        let s0 = GemmShape::new("prod", 64, 64, 64, Precision::I8I8);
        let s1 = GemmShape::new("cons", 64, 64, 64, Precision::I8I8);
        let (a0, b0) = functional_inputs(&s0, Precision::I8I8).unwrap();
        let staged_c = crate::gemm::refimpl::ref_gemm(&a0, &b0, Precision::I8I8).unwrap();
        let mut chain = crate::plan::GemmChain::new("staged");
        chain.push(s1.clone());
        let rx = c
            .submit_chain_staged(
                chain,
                ChainStaging { device: Some(1), a0: Some(staged_c.clone()) },
            )
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.device, 1, "pin respected");
        assert_eq!(resp.staged_edges, 1, "entry A consumed");
        let got = resp.result.expect("functional result");
        let b1 = functional_b(&s1, Precision::I8I8).unwrap();
        let want = crate::gemm::refimpl::ref_gemm(&staged_c, &b1, Precision::I8I8).unwrap();
        assert!(crate::gemm::refimpl::matrices_equal(&got, &want, Precision::I8I8));

        // Out-of-range pins and mis-shaped staged images fail at submit.
        let mut chain2 = crate::plan::GemmChain::new("bad-pin");
        chain2.push(s1.clone());
        assert!(c
            .submit_chain_staged(chain2, ChainStaging { device: Some(7), a0: None })
            .is_err());
        let mut chain3 = crate::plan::GemmChain::new("bad-a0");
        chain3.push(s1.clone());
        let wrong = Matrix::zeroed(32, 64, 1, Layout::RowMajor).unwrap();
        assert!(c
            .submit_chain_staged(chain3, ChainStaging { device: None, a0: Some(wrong) })
            .is_err());
        // Right dims, wrong element dtype (bf16 bytes into an int8 op):
        // rejected at submit, never reinterpreted as raw bytes.
        let mut chain4 = crate::plan::GemmChain::new("bad-dtype");
        chain4.push(s1.clone());
        let wrong_ty = Matrix::zeroed(64, 64, 2, Layout::RowMajor).unwrap();
        assert!(c
            .submit_chain_staged(chain4, ChainStaging { device: None, a0: Some(wrong_ty) })
            .is_err());
        let m = c.shutdown();
        assert_eq!(m.count(), 1);
        assert_eq!(c2_count(&m, 1), 1, "record landed on the pinned device");
    }

    fn c2_count(m: &crate::coordinator::FleetMetrics, dev: usize) -> usize {
        m.devices[dev].metrics.count()
    }

    #[test]
    fn empty_chain_is_rejected() {
        let c = Coordinator::start(CoordinatorOptions::default());
        assert!(c.submit_chain(crate::plan::GemmChain::new("empty")).is_err());
        let m = c.shutdown();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn mix_parsing_and_expansion() {
        assert_eq!(parse_mix("xdna:xdna2").unwrap(), vec![Generation::Xdna, Generation::Xdna2]);
        assert_eq!(parse_mix("xdna2").unwrap(), vec![Generation::Xdna2]);
        assert_eq!(parse_mix("xdna, xdna2").unwrap(), vec![Generation::Xdna, Generation::Xdna2]);
        assert!(parse_mix("tpu").is_err());
        assert!(parse_mix(":").is_err());
        assert_eq!(
            expand_mix(&[Generation::Xdna, Generation::Xdna2], 5),
            vec![
                Generation::Xdna,
                Generation::Xdna2,
                Generation::Xdna,
                Generation::Xdna2,
                Generation::Xdna,
            ]
        );
    }
}
