//! Request routing: design residency per device and device selection
//! across the fleet (Sec. 5.3.1 applied at two levels).
//!
//! * [`DesignCache`] — per-device tuned-design store with LRU eviction and
//!   hit/miss accounting. Unbounded by default (eight keys fit easily);
//!   a capacity models firmware that can pin only a few designs.
//! * [`DeviceState`] — which design is loaded on the array right now, and
//!   what switching costs (3.4 ms XDNA / 4.9 ms XDNA2).
//! * [`FleetRouter`] — the admission queue's device selector: sticky
//!   design affinity with load-aware spill, the scheduling-domain
//!   equivalent of the paper's balanced-point search.

use std::collections::{HashMap, VecDeque};

use crate::arch::{balanced_config, skinny_balanced_config, Generation, SKINNY_M_MAX};
use crate::dtype::{Layout, Precision};
use crate::tiling::TilingConfig;
use crate::workload::GemmShape;

/// Problem-M design class (ISSUE 7): the paper's balanced points assume
/// a large M (native M is 320–576 depending on generation/precision), so
/// a coalesced decode batch (M ≈ 8–64) would pad 5–17× under them.
/// Shapes with `m <= SKINNY_M_MAX` key on dedicated skinny designs
/// ([`crate::arch::skinny_balanced_config`]) instead — a distinct
/// residency/affinity bucket, exactly like a precision or layout change.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum MClass {
    /// Decode-batch class: `m <= SKINNY_M_MAX` (64).
    Skinny,
    /// The paper's large-M regime (prefill GEMMs, Tables 2–3 shapes).
    Wide,
}

impl MClass {
    /// Classify a problem M.
    pub fn of_m(m: usize) -> MClass {
        if m <= SKINNY_M_MAX {
            MClass::Skinny
        } else {
            MClass::Wide
        }
    }

    /// Classify a tiling config by its native M (what one array pass
    /// covers): skinny designs have native M = `SKINNY_M_MAX`.
    pub fn of_config(cfg: &TilingConfig) -> MClass {
        MClass::of_m(cfg.native().0)
    }
}

/// What identifies a loaded NPU design: same-key requests reuse the
/// configuration, changing only the cheap per-size parameters
/// (`M·N/(m_ct·n_ct)` and `K/k_ct` — "negligible reconfiguration").
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct DesignKey {
    pub precision: Precision,
    pub b_layout: Layout,
    /// Skinny (decode-batch) vs wide (prefill) design class.
    pub m_class: MClass,
}

impl DesignKey {
    /// The design a request needs: its precision/layout/M-class bucket
    /// (canonicalized — see [`Self::normalized`]).
    pub fn for_shape(shape: &GemmShape) -> DesignKey {
        DesignKey {
            precision: shape.precision,
            b_layout: shape.b_layout,
            m_class: MClass::of_m(shape.m),
        }
        .normalized()
    }

    /// The canonical key for design derivation: bfp16 has exactly one
    /// valid layout (column-major — blocks run along K), so a row-major
    /// bfp16 key — constructible programmatically, rejected by every
    /// trace path — maps to the column-major design. A functional
    /// request actually carrying row-major bfp16 operands then fails
    /// the executor's layout check and is poisoned per request, instead
    /// of panicking a leader inside `balanced_config(..).with_b_layout`.
    ///
    /// Likewise the logical `fp32_split` precision has no datapath
    /// schedule of its own (`TilingConfig::validate` rejects it): its
    /// limb GEMMs run on the bf16 design, so the key maps to bf16 —
    /// a hostile request naming fp32_split at the dispatch layer gets
    /// the bf16 design and then a typed per-op error, never a leader
    /// panic.
    pub fn normalized(self) -> DesignKey {
        match self.precision {
            Precision::Bfp16 => DesignKey { b_layout: Layout::ColMajor, ..self },
            Precision::Fp32Split => DesignKey { precision: Precision::Bf16, ..self },
            _ => self,
        }
    }
}

/// Hit/miss/eviction counters for one design cache (surfaced per device
/// in the fleet metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl std::ops::Add for CacheStats {
    type Output = CacheStats;

    /// Counter-wise sum — folding a respawned leader's fresh cache stats
    /// into the totals its dead predecessor accumulated.
    fn add(self, o: CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits + o.hits,
            misses: self.misses + o.misses,
            evictions: self.evictions + o.evictions,
        }
    }
}

/// Tuned design per key, with LRU eviction when bounded. Defaults to the
/// paper's balanced configs on a miss; `insert` lets the autotuner
/// (`optimizer::balanced`) override.
#[derive(Clone, Debug)]
pub struct DesignCache {
    gen: Generation,
    /// Max resident designs; 0 = unbounded.
    capacity: usize,
    designs: HashMap<DesignKey, TilingConfig>,
    /// Least-recently-used at the front, most-recent at the back.
    lru: VecDeque<DesignKey>,
    stats: CacheStats,
}

impl DesignCache {
    /// Unbounded cache pre-warmed with every balanced design (the cache
    /// is total over keys; first touches count as hits).
    pub fn new(gen: Generation) -> DesignCache {
        let mut c = DesignCache::with_capacity(gen, 0);
        for p in Precision::ALL {
            for layout in [Layout::RowMajor, Layout::ColMajor] {
                for m_class in [MClass::Wide, MClass::Skinny] {
                    c.warm(DesignKey { precision: p, b_layout: layout, m_class });
                }
            }
        }
        c
    }

    /// Empty cache holding at most `capacity` designs (0 = unbounded).
    /// Designs are derived lazily from the balanced defaults, so the
    /// first touch of each key counts as a miss.
    pub fn with_capacity(gen: Generation, capacity: usize) -> DesignCache {
        DesignCache {
            gen,
            capacity,
            designs: HashMap::new(),
            lru: VecDeque::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn gen(&self) -> Generation {
        self.gen
    }

    pub fn len(&self) -> usize {
        self.designs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.designs.is_empty()
    }

    pub fn contains(&self, key: DesignKey) -> bool {
        self.designs.contains_key(&key)
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Resident keys in LRU order (front = next to evict) — reported to
    /// the router so its residency model can reconcile with reality.
    pub fn resident(&self) -> Vec<DesignKey> {
        self.lru.iter().copied().collect()
    }

    /// The balanced default for a key: wide keys get the paper's Tables
    /// 2–3 points, skinny keys the dedicated decode-batch designs.
    fn derive(&self, key: DesignKey) -> TilingConfig {
        let base = match key.m_class {
            MClass::Skinny => skinny_balanced_config(self.gen, key.precision),
            MClass::Wide => balanced_config(self.gen, key.precision),
        };
        base.with_b_layout(key.b_layout)
    }

    /// Resident design for `key`, deriving the balanced default on a miss
    /// (evicting the least-recently-used entry when bounded). Keys are
    /// canonicalized first ([`DesignKey::normalized`]), so no key can
    /// force derivation of an invalid design.
    pub fn get(&mut self, key: DesignKey) -> &TilingConfig {
        let key = key.normalized();
        if self.designs.contains_key(&key) {
            self.stats.hits += 1;
            self.touch(key);
        } else {
            self.stats.misses += 1;
            self.admit(key, self.derive(key));
        }
        self.designs.get(&key).expect("resident after get")
    }

    /// Pre-load `key`'s design without touching the hit/miss counters
    /// (the warmup path: residency is being arranged, not requested).
    pub fn warm(&mut self, key: DesignKey) {
        let key = key.normalized();
        if self.designs.contains_key(&key) {
            self.touch(key);
        } else {
            self.admit(key, self.derive(key));
        }
    }

    /// Override a design (autotuning results). Counts as a warm insert.
    /// The key's M-class is inferred from the config's native M, so a
    /// tuned skinny design lands in the skinny bucket.
    pub fn insert(&mut self, cfg: TilingConfig) {
        assert_eq!(cfg.gen, self.gen);
        let key = DesignKey {
            precision: cfg.precision,
            b_layout: cfg.b_layout,
            m_class: MClass::of_config(&cfg),
        };
        if self.designs.contains_key(&key) {
            self.designs.insert(key, cfg);
            self.touch(key);
        } else {
            self.admit(key, cfg);
        }
    }

    /// Drop every resident design (a forced eviction storm — the chaos
    /// layer's `CacheStorm`). Evictions are counted; hit/miss history is
    /// retained, so a storm shows up as an eviction spike followed by
    /// cold misses.
    pub fn clear(&mut self) {
        self.stats.evictions += self.designs.len() as u64;
        self.designs.clear();
        self.lru.clear();
    }

    fn admit(&mut self, key: DesignKey, cfg: TilingConfig) {
        if self.capacity > 0 {
            while self.designs.len() >= self.capacity {
                match self.lru.pop_front() {
                    Some(old) => {
                        self.designs.remove(&old);
                        self.stats.evictions += 1;
                    }
                    None => break,
                }
            }
        }
        self.designs.insert(key, cfg);
        self.lru.push_back(key);
    }

    fn touch(&mut self, key: DesignKey) {
        if let Some(pos) = self.lru.iter().position(|k| *k == key) {
            self.lru.remove(pos);
        }
        self.lru.push_back(key);
    }
}

/// The device's loaded-design state: switching designs costs the full
/// array reconfiguration latency (3.4 ms XDNA / 4.9 ms XDNA2).
#[derive(Clone, Debug, Default)]
pub struct DeviceState {
    current: Option<DesignKey>,
    pub reconfigurations: usize,
}

impl DeviceState {
    /// Cost (seconds) to make `key` resident; updates the state.
    pub fn switch_to(&mut self, gen: Generation, key: DesignKey) -> f64 {
        if self.current == Some(key) {
            0.0
        } else {
            self.current = Some(key);
            self.reconfigurations += 1;
            gen.spec().reconfig_s
        }
    }

    pub fn current(&self) -> Option<DesignKey> {
        self.current
    }

    /// Forget the loaded design (leader restart / eviction storm): the
    /// next [`Self::switch_to`] pays a full reconfiguration even for the
    /// design that was just resident.
    pub fn invalidate(&mut self) {
        self.current = None;
    }
}

/// Why the router picked a device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteKind {
    /// A device already holding the design was cheapest.
    Affinity,
    /// No device held the design; least-loaded device takes it.
    LeastLoaded,
    /// Devices held the design but were backlogged past the
    /// reconfiguration cost — the design is replicated onto a fresh
    /// device (fairness under skew).
    Spill,
}

impl RouteKind {
    /// Stable lowercase label (trace args, metrics labels).
    pub fn name(&self) -> &'static str {
        match self {
            RouteKind::Affinity => "affinity",
            RouteKind::LeastLoaded => "least_loaded",
            RouteKind::Spill => "spill",
        }
    }
}

/// One routing decision.
#[derive(Clone, Copy, Debug)]
pub struct RouteDecision {
    /// Fleet device index.
    pub device: usize,
    /// Estimated execution seconds charged to that device's load.
    pub est_s: f64,
    pub kind: RouteKind,
}

/// Admission-queue device selector: sticky design affinity with
/// load-aware spill — the paper's Sec. 5.3 deployment balance applied to
/// scheduling.
///
/// Load is tracked in *virtual device seconds*: the cumulative estimated
/// execution time assigned to each device (ops over that generation's
/// precision peak). Reconfiguration enters only as a one-time routing
/// penalty for devices not holding the design, so a holder keeps
/// winning until its backlog exceeds an idle device's reconfiguration
/// cost — at which point the design spills (replicates) to the
/// least-loaded device. Routing minimizes the greedy makespan in
/// simulated time and is a deterministic function of submission order,
/// independent of host thread timing.
#[derive(Clone, Debug)]
pub struct FleetRouter {
    gens: Vec<Generation>,
    /// Per-device resident designs in LRU order (front = oldest):
    /// an optimistic mirror of each leader's [`DesignCache`], updated on
    /// every routing decision and reconciled with the leader's
    /// authoritative state on batch completion (`sync_residency`), so
    /// affinity is invalidated when a bounded cache evicts the design.
    held: Vec<VecDeque<DesignKey>>,
    /// Per-device design capacity (0 = unbounded), matching
    /// `CoordinatorOptions::design_capacity`.
    capacity: usize,
    /// Cumulative assigned virtual seconds per device.
    load_s: Vec<f64>,
    pub hits: u64,
    pub misses: u64,
    pub spills: u64,
}

impl FleetRouter {
    /// Router over devices with unbounded design caches.
    pub fn new(gens: Vec<Generation>) -> FleetRouter {
        FleetRouter::with_capacity(gens, 0)
    }

    /// Router whose residency model evicts like a `design_capacity`-bounded
    /// [`DesignCache`] (0 = unbounded).
    pub fn with_capacity(gens: Vec<Generation>, design_capacity: usize) -> FleetRouter {
        assert!(!gens.is_empty(), "fleet needs at least one device");
        let n = gens.len();
        FleetRouter {
            gens,
            held: vec![VecDeque::new(); n],
            capacity: design_capacity,
            load_s: vec![0.0; n],
            hits: 0,
            misses: 0,
            spills: 0,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.gens.len()
    }

    pub fn device_gen(&self, device: usize) -> Generation {
        self.gens[device]
    }

    /// Virtual-seconds load per device (cumulative assigned work).
    pub fn loads(&self) -> &[f64] {
        &self.load_s
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Whether device `d`'s modeled cache currently holds `key`.
    pub fn holds(&self, d: usize, key: DesignKey) -> bool {
        self.held[d].contains(&key)
    }

    /// Devices currently holding `key`'s design.
    pub fn holders(&self, key: DesignKey) -> Vec<usize> {
        (0..self.gens.len()).filter(|&d| self.holds(d, key)).collect()
    }

    /// Mark `key` resident on `d`, evicting the LRU design when the
    /// modeled capacity is exceeded (mirrors `DesignCache::admit`).
    fn assign(&mut self, d: usize, key: DesignKey) {
        if self.capacity > 0 {
            while self.held[d].len() >= self.capacity {
                if self.held[d].pop_front().is_none() {
                    break;
                }
            }
        }
        self.held[d].push_back(key);
    }

    fn touch_held(&mut self, d: usize, key: DesignKey) {
        if let Some(pos) = self.held[d].iter().position(|k| *k == key) {
            self.held[d].remove(pos);
        }
        self.held[d].push_back(key);
    }

    /// Replace device `d`'s modeled residency with the leader's
    /// authoritative LRU state (from a batch completion). Leaders
    /// execute batches sorted by design key, so their eviction order
    /// can differ from the router's submission-order mirror; this
    /// reconciliation bounds the divergence to the in-flight window.
    pub fn sync_residency(&mut self, d: usize, resident: &[DesignKey]) {
        self.held[d] = resident.iter().copied().collect();
    }

    /// Remove a failed device from routing: forget its modeled residency
    /// and pin its virtual load at +inf so [`Self::route`],
    /// [`Self::route_chain`] and [`Self::warm`] never select it again.
    /// Irreversible — a leader that exhausts its respawn budget leaves
    /// the fleet for the rest of the run.
    pub fn mark_dead(&mut self, d: usize) {
        self.held[d].clear();
        self.load_s[d] = f64::INFINITY;
    }

    /// Whether `d` has been removed from routing by [`Self::mark_dead`].
    pub fn is_dead(&self, d: usize) -> bool {
        self.load_s[d].is_infinite()
    }

    /// Devices still eligible for routing.
    pub fn live_devices(&self) -> usize {
        self.load_s.iter().filter(|l| l.is_finite()).count()
    }

    /// Estimated execution seconds for `ops` at `precision` on `device`
    /// (the generation's theoretical peak — an optimistic but
    /// generation-aware cost model).
    pub fn est_s(&self, device: usize, precision: Precision, ops: f64) -> f64 {
        ops / (self.gens[device].spec().peak_tops(precision) * 1e12)
    }

    /// Pick the device for a request needing `key` with `ops` operations:
    /// argmin over devices of `load + est + (reconfig unless holding)`.
    pub fn route(&mut self, key: DesignKey, ops: f64) -> RouteDecision {
        let mut best = 0usize;
        let mut best_total = f64::INFINITY;
        for d in 0..self.gens.len() {
            let est = self.est_s(d, key.precision, ops);
            let reconfig =
                if self.holds(d, key) { 0.0 } else { self.gens[d].spec().reconfig_s };
            let total = self.load_s[d] + est + reconfig;
            if total < best_total {
                best = d;
                best_total = total;
            }
        }
        let holds = self.holds(best, key);
        let had_holders = (0..self.gens.len()).any(|d| self.holds(d, key));
        let est = self.est_s(best, key.precision, ops);
        let kind = if holds {
            self.hits += 1;
            self.touch_held(best, key);
            RouteKind::Affinity
        } else {
            self.misses += 1;
            self.assign(best, key);
            if had_holders {
                self.spills += 1;
                RouteKind::Spill
            } else {
                RouteKind::LeastLoaded
            }
        };
        self.load_s[best] += est;
        RouteDecision { device: best, est_s: est, kind }
    }

    /// Chain affinity: route a whole chain as one unit. The chain's
    /// leading design key picks the device exactly like [`Self::route`],
    /// but the decision is charged with the chain's *total* ops, so the
    /// whole chain lands on one leader, its design stays cache-hot, and
    /// the load model sees the chain's real footprint. Counts one
    /// hit/miss/spill per chain, not per op.
    pub fn route_chain(&mut self, key: DesignKey, total_ops: f64) -> RouteDecision {
        self.route(key, total_ops)
    }

    /// Pinned placement (the graph partitioner's schedule): the device
    /// is the caller's choice, but load and residency accounting stay
    /// identical to [`Self::route`] — the pinned work charges the
    /// device's virtual load, counts a hit when the design is already
    /// modeled resident, and installs it (spill-aware) when not, so
    /// later *unpinned* traffic routes around the pinned backlog.
    pub fn route_to(&mut self, device: usize, key: DesignKey, ops: f64) -> RouteDecision {
        assert!(device < self.gens.len(), "device {device} out of range");
        let had_holders = (0..self.gens.len()).any(|d| self.holds(d, key));
        let kind = if self.holds(device, key) {
            self.hits += 1;
            self.touch_held(device, key);
            RouteKind::Affinity
        } else {
            self.misses += 1;
            self.assign(device, key);
            if had_holders {
                self.spills += 1;
                RouteKind::Spill
            } else {
                RouteKind::LeastLoaded
            }
        };
        let est = self.est_s(device, key.precision, ops);
        self.load_s[device] += est;
        RouteDecision { device, est_s: est, kind }
    }

    /// Cache-warmup: assign `key` to the least-loaded device to preload
    /// and return it (a no-op returning an existing holder if the design
    /// is already resident). Warmup happens off the request path, so no
    /// load is charged.
    pub fn warm(&mut self, key: DesignKey) -> usize {
        if let Some(d) = (0..self.gens.len()).find(|&d| self.holds(d, key)) {
            return d;
        }
        let mut best = 0usize;
        let mut best_load = f64::INFINITY;
        for (d, load) in self.load_s.iter().enumerate() {
            if *load < best_load {
                best = d;
                best_load = *load;
            }
        }
        self.assign(best, key);
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(p: Precision, l: Layout) -> DesignKey {
        DesignKey { precision: p, b_layout: l, m_class: MClass::Wide }
    }

    fn skinny_key(p: Precision, l: Layout) -> DesignKey {
        DesignKey { precision: p, b_layout: l, m_class: MClass::Skinny }
    }

    #[test]
    fn cache_is_total_and_uses_balanced_defaults() {
        let mut c = DesignCache::new(Generation::Xdna2);
        for p in Precision::ALL {
            for l in [Layout::RowMajor, Layout::ColMajor] {
                let cfg = *c.get(key(p, l));
                assert_eq!(cfg.precision, p);
                assert_eq!(cfg.b_layout, l);
            }
        }
        let k = key(Precision::I8I16, Layout::ColMajor);
        assert_eq!(c.get(k).kernel.label(), "128x72x112");
        // Pre-warmed: every get above was a hit.
        assert_eq!(c.stats().misses, 0);
        assert_eq!(c.stats().hits, 9);
    }

    #[test]
    fn skinny_keys_resolve_to_the_skinny_designs() {
        // Both M-classes are pre-warmed; the skinny bucket returns the
        // dedicated decode-batch design (m_ct = 16, native M = 64), not
        // the wide paper point.
        for gen in Generation::ALL {
            let mut c = DesignCache::new(gen);
            for p in Precision::ALL {
                let skinny = *c.get(skinny_key(p, Layout::ColMajor));
                let wide = *c.get(key(p, Layout::ColMajor));
                assert_eq!(skinny.kernel.m_ct, 16, "{gen} {p:?}");
                assert_eq!(skinny.native().0, crate::arch::SKINNY_M_MAX);
                assert!(wide.native().0 > crate::arch::SKINNY_M_MAX);
                // Same K/N kernel plan — only the M dimension shrinks.
                assert_eq!(skinny.kernel.k_ct, wide.kernel.k_ct);
                assert_eq!(skinny.kernel.n_ct, wide.kernel.n_ct);
            }
            assert_eq!(c.stats().misses, 0, "skinny class is pre-warmed too");
        }
    }

    #[test]
    fn for_shape_classifies_m_into_design_classes() {
        use crate::workload::GemmShape;
        for (m, want) in [(1, MClass::Skinny), (33, MClass::Skinny), (64, MClass::Skinny),
            (65, MClass::Wide), (512, MClass::Wide)]
        {
            let s = GemmShape::new("t", m, 768, 768, Precision::I8I8);
            assert_eq!(DesignKey::for_shape(&s).m_class, want, "M={m}");
        }
    }

    #[test]
    fn skinny_and_wide_are_distinct_affinity_buckets() {
        // A decode batch and a prefill GEMM at the same precision/layout
        // must not share residency: switching between them is a real
        // array reconfiguration.
        let mut r = FleetRouter::new(vec![Generation::Xdna2, Generation::Xdna2]);
        let ops = 2.0 * 1024.0f64.powi(3);
        let wide = key(Precision::I8I8, Layout::ColMajor);
        let skinny = skinny_key(Precision::I8I8, Layout::ColMajor);
        let d_wide = r.route(wide, ops);
        let d_skinny = r.route(skinny, ops);
        assert_ne!(d_wide.device, d_skinny.device, "distinct designs split the fleet");
        assert_eq!(r.route(skinny, ops).kind, RouteKind::Affinity);
        // DeviceState accounting: swapping classes costs a reconfig.
        let mut dev = DeviceState::default();
        let gen = Generation::Xdna2;
        assert!(dev.switch_to(gen, wide) > 0.0);
        assert!(dev.switch_to(gen, skinny) > 0.0, "class switch reconfigures");
        assert_eq!(dev.reconfigurations, 2);
    }

    #[test]
    fn hostile_bfp16_row_major_key_normalizes_to_the_valid_design() {
        // A row-major bfp16 key is constructible programmatically (every
        // trace path rejects it); the cache must canonicalize it to the
        // column-major design instead of panicking the leader inside
        // `with_b_layout`. The functional path then rejects the actual
        // operand-layout mismatch per request.
        let k = key(Precision::Bfp16, Layout::RowMajor);
        assert_eq!(k.normalized().b_layout, Layout::ColMajor);
        let mut c = DesignCache::new(Generation::Xdna2);
        let cfg = *c.get(k);
        assert_eq!(cfg.b_layout, Layout::ColMajor);
        assert!(cfg.validate().is_ok());
        c.warm(k); // ditto on the warmup path
    }

    #[test]
    fn bfp16_designs_resolve_on_both_generations() {
        // bfp16 keys are not pre-warmed (not a paper precision) but the
        // cache derives a valid balanced default on first touch for both
        // the native XDNA2 datapath and XDNA's decode-to-bf16 fallback —
        // a mixed fleet never panics on a block-FP request.
        for gen in Generation::ALL {
            let mut c = DesignCache::new(gen);
            let cfg = *c.get(key(Precision::Bfp16, Layout::ColMajor));
            assert_eq!(cfg.precision, Precision::Bfp16);
            assert!(cfg.validate().is_ok());
        }
    }

    #[test]
    fn bfp16_routes_to_the_native_generation() {
        // Mixed fleet: bfp16's estimated seconds on XDNA (decode-to-bf16
        // emulation, ~4 TOPS peak) dwarf XDNA2's native-rate estimate
        // (~59 TOPS), so the load model keeps block-FP traffic on the
        // XDNA2 device even as its backlog grows.
        let mut r = FleetRouter::new(vec![Generation::Xdna, Generation::Xdna2]);
        let k = key(Precision::Bfp16, Layout::ColMajor);
        let ops = 2.0 * 4096f64 * 4096.0 * 4096.0;
        for i in 0..8 {
            let d = r.route(k, ops);
            assert_eq!(r.device_gen(d.device), Generation::Xdna2, "request {i}");
        }
    }

    #[test]
    fn autotune_override() {
        let mut c = DesignCache::new(Generation::Xdna);
        let custom = crate::tiling::TilingConfig::new(
            Generation::Xdna,
            Precision::Bf16,
            96,
            48,
            96,
            192,
            4,
            4,
            Layout::ColMajor,
        )
        .unwrap();
        c.insert(custom);
        let k = key(Precision::Bf16, Layout::ColMajor);
        assert_eq!(c.get(k).kernel.k_ct, 48);
    }

    #[test]
    fn hit_and_miss_accounting() {
        let mut c = DesignCache::with_capacity(Generation::Xdna2, 0);
        let k1 = key(Precision::I8I8, Layout::ColMajor);
        let k2 = key(Precision::Bf16, Layout::ColMajor);
        c.get(k1); // miss (lazy fill)
        c.get(k1); // hit
        c.get(k2); // miss
        c.get(k1); // hit
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (2, 2, 0));
        assert!((c.stats().hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn lru_eviction_when_bounded() {
        let mut c = DesignCache::with_capacity(Generation::Xdna2, 2);
        let k1 = key(Precision::I8I8, Layout::ColMajor);
        let k2 = key(Precision::I8I16, Layout::ColMajor);
        let k3 = key(Precision::Bf16, Layout::ColMajor);
        c.get(k1); // miss → {k1}
        c.get(k2); // miss → {k1, k2}
        c.get(k1); // hit, k1 becomes most-recent → LRU order k2, k1
        c.get(k3); // miss → evicts k2 → {k1, k3}
        assert!(c.contains(k1) && c.contains(k3) && !c.contains(k2));
        assert_eq!(c.stats().evictions, 1);
        c.get(k2); // miss again → evicts k1 (LRU)
        assert!(!c.contains(k1));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 4, 2));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn warm_and_insert_do_not_count_as_traffic() {
        let mut c = DesignCache::with_capacity(Generation::Xdna, 0);
        c.warm(key(Precision::I8I8, Layout::ColMajor));
        assert_eq!(c.stats(), CacheStats::default());
        c.get(key(Precision::I8I8, Layout::ColMajor)); // hit thanks to warm
        assert_eq!((c.stats().hits, c.stats().misses), (1, 0));
    }

    #[test]
    fn reconfiguration_charged_only_on_switches() {
        let mut dev = DeviceState::default();
        let gen = Generation::Xdna2;
        let k1 = key(Precision::I8I8, Layout::ColMajor);
        let k2 = key(Precision::Bf16, Layout::ColMajor);
        assert_eq!(dev.switch_to(gen, k1), gen.spec().reconfig_s);
        assert_eq!(dev.switch_to(gen, k1), 0.0);
        assert_eq!(dev.switch_to(gen, k2), gen.spec().reconfig_s);
        assert_eq!(dev.switch_to(gen, k1), gen.spec().reconfig_s);
        assert_eq!(dev.reconfigurations, 3);
    }

    #[test]
    fn router_affinity_matches_across_precisions_and_layouts() {
        let mut r = FleetRouter::new(vec![Generation::Xdna2, Generation::Xdna2]);
        let ops = 2.0 * 1024.0 * 1024.0 * 1024.0;
        let ka = key(Precision::I8I8, Layout::ColMajor);
        let kb = key(Precision::Bf16, Layout::ColMajor);
        let d_a = r.route(ka, ops);
        assert_eq!(d_a.kind, RouteKind::LeastLoaded);
        // Same key sticks to its device; distinct keys land elsewhere.
        assert_eq!(r.route(ka, ops).device, d_a.device);
        let d_b = r.route(kb, ops);
        assert_ne!(d_b.device, d_a.device, "new design goes to the idle device");
        // A layout change is a different design key even at the same
        // precision — it must not match d_a's residency.
        let ka_row = key(Precision::I8I8, Layout::RowMajor);
        let d_row = r.route(ka_row, ops);
        assert_eq!(d_row.kind, RouteKind::LeastLoaded);
        assert_eq!(r.hits, 1);
        assert_eq!(r.misses, 3);
    }

    #[test]
    fn router_spills_under_skew() {
        let mut r = FleetRouter::new(vec![Generation::Xdna2; 4]);
        let ops = 2.0 * 2048.0f64.powi(3); // ~0.29 ms estimated per request
        let k = key(Precision::I8I8, Layout::ColMajor);
        let mut devices_used = std::collections::BTreeSet::new();
        for _ in 0..300 {
            devices_used.insert(r.route(k, ops).device);
        }
        assert_eq!(devices_used.len(), 4, "hot design must spill across the fleet");
        assert!(r.spills >= 3, "{} spills", r.spills);
        // Loads end up balanced within one spill threshold.
        let max = r.loads().iter().cloned().fold(0.0, f64::max);
        let min = r.loads().iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max - min < 2.0 * Generation::Xdna2.spec().reconfig_s + 1e-9);
    }

    #[test]
    fn router_prefers_faster_generation_once_engaged() {
        let mut r = FleetRouter::new(vec![Generation::Xdna, Generation::Xdna2]);
        let ops = 2.0 * 1024.0f64.powi(3);
        let k = key(Precision::I8I8, Layout::ColMajor);
        let mut counts = [0usize; 2];
        for _ in 0..200 {
            counts[r.route(k, ops).device] += 1;
        }
        assert!(counts[0] > 0 && counts[1] > 0);
        assert!(
            counts[1] > counts[0],
            "XDNA2 should absorb more of the stream: {counts:?}"
        );
    }

    #[test]
    fn bounded_router_evicts_affinity_with_the_cache() {
        // Capacity-1 model, one device, alternating designs: the router
        // must forget the evicted design, matching the leader's cache —
        // every request is a miss, never a stale affinity hit.
        let mut r = FleetRouter::with_capacity(vec![Generation::Xdna2], 1);
        let k1 = key(Precision::I8I8, Layout::ColMajor);
        let k2 = key(Precision::Bf16, Layout::ColMajor);
        for _ in 0..3 {
            assert_ne!(r.route(k1, 1e9).kind, RouteKind::Affinity);
            assert_ne!(r.route(k2, 1e9).kind, RouteKind::Affinity);
        }
        assert_eq!((r.hits, r.misses), (0, 6));
        // Back-to-back same key still hits within the capacity.
        assert_eq!(r.route(k1, 1e9).kind, RouteKind::LeastLoaded);
        assert_eq!(r.route(k1, 1e9).kind, RouteKind::Affinity);
    }

    #[test]
    fn pinned_routing_keeps_load_and_residency_accounting() {
        let mut r = FleetRouter::new(vec![Generation::Xdna2, Generation::Xdna2]);
        let k = key(Precision::I8I8, Layout::ColMajor);
        let ops = 2.0 * 1024.0f64.powi(3);
        // Pin to the device the free router would NOT pick next.
        let d = r.route_to(1, k, ops);
        assert_eq!((d.device, d.kind), (1, RouteKind::LeastLoaded));
        assert!(r.holds(1, k) && !r.holds(0, k));
        assert!(r.loads()[1] > 0.0 && r.loads()[0] == 0.0);
        // A second pin to the same device is an affinity hit; pinning
        // the other device replicates the design (spill accounting).
        assert_eq!(r.route_to(1, k, ops).kind, RouteKind::Affinity);
        assert_eq!(r.route_to(0, k, ops).kind, RouteKind::Spill);
        // Free routing then sees the pinned backlog: the next unpinned
        // request lands on the less-loaded holder.
        assert_eq!(r.route(k, ops).device, 0);
    }

    #[test]
    fn cache_clear_counts_evictions_and_goes_cold() {
        let mut c = DesignCache::with_capacity(Generation::Xdna2, 0);
        let k1 = key(Precision::I8I8, Layout::ColMajor);
        let k2 = key(Precision::Bf16, Layout::ColMajor);
        c.get(k1);
        c.get(k2);
        c.clear();
        assert!(c.is_empty() && c.resident().is_empty());
        assert_eq!(c.stats().evictions, 2, "a storm evicts everything resident");
        c.get(k1); // cold again
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 3));
    }

    #[test]
    fn invalidate_forces_reconfiguration() {
        let mut dev = DeviceState::default();
        let gen = Generation::Xdna;
        let k = key(Precision::I8I8, Layout::ColMajor);
        assert!(dev.switch_to(gen, k) > 0.0);
        assert_eq!(dev.switch_to(gen, k), 0.0);
        dev.invalidate();
        assert_eq!(dev.current(), None);
        assert_eq!(dev.switch_to(gen, k), gen.spec().reconfig_s, "storm → full reload");
    }

    #[test]
    fn dead_device_is_never_routed_to() {
        let mut r = FleetRouter::new(vec![Generation::Xdna2, Generation::Xdna]);
        let k = key(Precision::I8I8, Layout::ColMajor);
        assert_eq!(r.route(k, 1e9).device, 0, "XDNA2 wins while alive");
        r.mark_dead(0);
        assert!(r.is_dead(0) && !r.is_dead(1));
        assert_eq!(r.live_devices(), 1);
        assert!(!r.holds(0, k), "dead device's residency is forgotten");
        for _ in 0..8 {
            assert_eq!(r.route(k, 1e9).device, 1);
        }
        assert_eq!(r.warm(key(Precision::Bf16, Layout::ColMajor)), 1);
    }

    #[test]
    fn cache_stats_add_is_counterwise() {
        let a = CacheStats { hits: 3, misses: 2, evictions: 1 };
        let b = CacheStats { hits: 10, misses: 0, evictions: 4 };
        assert_eq!(a + b, CacheStats { hits: 13, misses: 2, evictions: 5 });
        assert_eq!(a + CacheStats::default(), a);
    }

    #[test]
    fn warm_assigns_affinity_without_traffic() {
        let mut r = FleetRouter::new(vec![Generation::Xdna2, Generation::Xdna2]);
        let k = key(Precision::I8I16, Layout::ColMajor);
        let d = r.warm(k);
        assert_eq!(r.warm(k), d, "idempotent");
        let decision = r.route(k, 1e9);
        assert_eq!(decision.device, d);
        assert_eq!(decision.kind, RouteKind::Affinity);
        assert_eq!((r.hits, r.misses), (1, 0));
    }
}
