//! Request routing: pick the resident design for a request and account
//! for NPU reconfiguration (Sec. 5.3.1).

use std::collections::HashMap;

use crate::arch::{balanced_config, Generation};
use crate::dtype::{Layout, Precision};
use crate::tiling::TilingConfig;

/// What identifies a loaded NPU design: same-key requests reuse the
/// configuration, changing only the cheap per-size parameters
/// (`M·N/(m_ct·n_ct)` and `K/k_ct` — "negligible reconfiguration").
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct DesignKey {
    pub precision: Precision,
    pub b_layout: Layout,
}

/// Tuned design per key. Defaults to the paper's balanced configs;
/// `insert` lets the autotuner (optimizer::balanced) override.
#[derive(Clone, Debug)]
pub struct DesignCache {
    gen: Generation,
    designs: HashMap<DesignKey, TilingConfig>,
}

impl DesignCache {
    pub fn new(gen: Generation) -> DesignCache {
        let mut designs = HashMap::new();
        for p in Precision::ALL {
            for layout in [Layout::RowMajor, Layout::ColMajor] {
                designs.insert(
                    DesignKey { precision: p, b_layout: layout },
                    balanced_config(gen, p).with_b_layout(layout),
                );
            }
        }
        DesignCache { gen, designs }
    }

    pub fn gen(&self) -> Generation {
        self.gen
    }

    pub fn get(&self, key: DesignKey) -> &TilingConfig {
        self.designs.get(&key).expect("cache is total over keys")
    }

    /// Override a design (autotuning results).
    pub fn insert(&mut self, cfg: TilingConfig) {
        assert_eq!(cfg.gen, self.gen);
        self.designs.insert(
            DesignKey { precision: cfg.precision, b_layout: cfg.b_layout },
            cfg,
        );
    }
}

/// The device's loaded-design state: switching designs costs the full
/// array reconfiguration latency (3.4 ms XDNA / 4.9 ms XDNA2).
#[derive(Clone, Debug, Default)]
pub struct DeviceState {
    current: Option<DesignKey>,
    pub reconfigurations: usize,
}

impl DeviceState {
    /// Cost (seconds) to make `key` resident; updates the state.
    pub fn switch_to(&mut self, gen: Generation, key: DesignKey) -> f64 {
        if self.current == Some(key) {
            0.0
        } else {
            self.current = Some(key);
            self.reconfigurations += 1;
            gen.spec().reconfig_s
        }
    }

    pub fn current(&self) -> Option<DesignKey> {
        self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_is_total_and_uses_balanced_defaults() {
        let c = DesignCache::new(Generation::Xdna2);
        for p in Precision::ALL {
            for l in [Layout::RowMajor, Layout::ColMajor] {
                let cfg = c.get(DesignKey { precision: p, b_layout: l });
                assert_eq!(cfg.precision, p);
                assert_eq!(cfg.b_layout, l);
            }
        }
        let k = DesignKey { precision: Precision::I8I16, b_layout: Layout::ColMajor };
        assert_eq!(c.get(k).kernel.label(), "128x72x112");
    }

    #[test]
    fn autotune_override() {
        let mut c = DesignCache::new(Generation::Xdna);
        let custom = crate::tiling::TilingConfig::new(
            Generation::Xdna,
            Precision::Bf16,
            96,
            48,
            96,
            192,
            4,
            4,
            Layout::ColMajor,
        )
        .unwrap();
        c.insert(custom);
        let k = DesignKey { precision: Precision::Bf16, b_layout: Layout::ColMajor };
        assert_eq!(c.get(k).kernel.k_ct, 48);
    }

    #[test]
    fn reconfiguration_charged_only_on_switches() {
        let mut dev = DeviceState::default();
        let gen = Generation::Xdna2;
        let k1 = DesignKey { precision: Precision::I8I8, b_layout: Layout::ColMajor };
        let k2 = DesignKey { precision: Precision::Bf16, b_layout: Layout::ColMajor };
        assert_eq!(dev.switch_to(gen, k1), gen.spec().reconfig_s);
        assert_eq!(dev.switch_to(gen, k1), 0.0);
        assert_eq!(dev.switch_to(gen, k2), gen.spec().reconfig_s);
        assert_eq!(dev.switch_to(gen, k1), gen.spec().reconfig_s);
        assert_eq!(dev.reconfigurations, 3);
    }
}
