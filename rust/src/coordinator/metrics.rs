//! Per-request records, per-device aggregates, and fleet-level rollups.
//!
//! Two throughput views matter for a sharded service (Sec. 5.3):
//!
//! * **sustained** (`device_tops`) — total ops over *summed* device
//!   seconds: how efficiently device time is spent, comparable to the
//!   paper's Tables 2–3 numbers;
//! * **fleet** (`fleet_tops`) — total ops over the *makespan* (the
//!   busiest device's total): what the service as a whole delivers,
//!   which is what adding devices improves.

use crate::arch::Generation;
use crate::util::json::{num, obj, s, Json};
use crate::util::stats;

use super::fault::FaultRecord;
use super::router::CacheStats;

/// Result-integrity outcome of one served unit (ISSUE 8). Replaces the
/// overloaded `verified: Option<bool>` tri-state: clients can now tell
/// "never checked" apart from "checked, silently corrupted, and healed
/// by verified recompute".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Integrity {
    /// No integrity checking was enabled for this unit.
    #[default]
    NotChecked,
    /// Every check the configured mode runs passed first try.
    Passed,
    /// A check failed and the unit was recomputed (`retries` attempts)
    /// until it validated — the served result is clean.
    Recovered {
        /// Recompute attempts spent before the result validated.
        retries: u32,
    },
    /// Checks kept failing past `max_integrity_retries`: the response
    /// is surfaced as failed, never silently served.
    Failed,
}

impl Integrity {
    /// Whether the served result is trustworthy (checked-and-clean or
    /// never checked; `Failed` is the only poisoned state).
    pub fn ok(&self) -> bool {
        *self != Integrity::Failed
    }

    /// Whether any integrity check ran on this unit.
    pub fn checked(&self) -> bool {
        *self != Integrity::NotChecked
    }

    /// Stable lowercase label (trace args, metrics labels).
    pub fn name(&self) -> &'static str {
        match self {
            Integrity::NotChecked => "not_checked",
            Integrity::Passed => "passed",
            Integrity::Recovered { .. } => "recovered",
            Integrity::Failed => "failed",
        }
    }
}

/// One-release compatibility with the pre-ISSUE-8 `verified` tri-state:
/// `NotChecked → None`, `Passed`/`Recovered → Some(true)`,
/// `Failed → Some(false)`.
impl From<Integrity> for Option<bool> {
    fn from(i: Integrity) -> Option<bool> {
        match i {
            Integrity::NotChecked => None,
            Integrity::Passed | Integrity::Recovered { .. } => Some(true),
            Integrity::Failed => Some(false),
        }
    }
}

/// One completed request's accounting.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: u64,
    pub name: String,
    /// Fleet device index that served the request.
    pub device: usize,
    /// Simulated device time (GEMM + any reconfiguration).
    pub device_s: f64,
    /// Host wall-clock from submit to response.
    pub host_latency_s: f64,
    pub ops: f64,
    pub reconfigured: bool,
    /// Result-integrity outcome (ABFT and/or full reference verify).
    pub integrity: Integrity,
    /// Chain id when the request arrived as part of a planned chain
    /// (`Coordinator::submit_chain`).
    pub chain: Option<u64>,
    /// Tenant index (`CoordinatorOptions::tenants`; 0 = the implicit
    /// default tenant).
    pub tenant: usize,
}

impl RequestRecord {
    /// Legacy view of [`Self::integrity`] (kept one release).
    pub fn verified(&self) -> Option<bool> {
        self.integrity.into()
    }
}

/// Per-tenant admission accounting (ISSUE 6 multi-model serving). The
/// conservation invariant the chaos suite pins:
/// `completed + failed + pending == submitted` at every instant, with
/// `pending == 0` after a drained shutdown. Requeues (leader death,
/// dropped responses) are counted separately and leave the invariant
/// untouched — a requeued unit stays pending until it retires as
/// completed, or as failed when no live device remains to re-serve it.
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    pub name: String,
    /// Priority class (higher preempts lower in device queues).
    pub priority: u8,
    /// Max in-flight units admitted past the backlog (0 = unbounded).
    pub quota: usize,
    /// Units accepted from this tenant (chains count as one unit).
    pub submitted: u64,
    /// Units that produced a response.
    pub completed: u64,
    /// Units whose response channel was dropped (panicked leader unit,
    /// or no live device left to serve a requeue).
    pub failed: u64,
    /// Re-placement events: any unit moved off a dead or killed leader
    /// (whether it was in flight, in transit, or still queued on that
    /// device) plus drop-response re-serves. Counts the event, not the
    /// outcome — a unit spilled when no live device remains is counted
    /// here and then terminally fails; one unit can be requeued more
    /// than once.
    pub requeued: u64,
    /// Units admitted but not yet completed/failed (snapshot depth:
    /// quota backlog + device queues + in-flight).
    pub pending: u64,
    /// High-water mark of concurrently in-flight units — the quota
    /// enforcement witness (`max_in_flight <= quota` when bounded).
    pub max_in_flight: u64,
    /// Units whose results went through at least one integrity check.
    pub integrity_checked: u64,
    /// Checked units that validated first try.
    pub integrity_passed: u64,
    /// Checked units healed by verified recompute within the budget.
    pub integrity_recovered: u64,
    /// Checked units that exhausted the recompute budget (surfaced as
    /// failed responses, never silently served).
    pub integrity_failed: u64,
}

impl TenantStats {
    /// The admission conservation invariant, extended (ISSUE 8) with
    /// integrity accounting: every checked unit is exactly one of
    /// passed / recovered / failed — a corrupt result can neither
    /// vanish nor be double-counted.
    pub fn conserves(&self) -> bool {
        self.completed + self.failed + self.pending == self.submitted
            && self.integrity_checked
                == self.integrity_passed + self.integrity_recovered + self.integrity_failed
    }

    /// Fold one served record's integrity outcome into the counters.
    pub fn record_integrity(&mut self, i: Integrity) {
        if !i.checked() {
            return;
        }
        self.integrity_checked += 1;
        match i {
            Integrity::Passed => self.integrity_passed += 1,
            Integrity::Recovered { .. } => self.integrity_recovered += 1,
            Integrity::Failed => self.integrity_failed += 1,
            Integrity::NotChecked => unreachable!("filtered above"),
        }
    }
}

/// One completed chain's accounting: every op ran back to back on one
/// device (chain affinity), so `device_s` *is* the chain's makespan.
#[derive(Clone, Debug)]
pub struct ChainRecord {
    pub id: u64,
    pub name: String,
    /// Fleet device index the whole chain landed on.
    pub device: usize,
    pub ops_count: usize,
    /// Edges executed with the C kept L2-resident.
    pub fused_edges: usize,
    /// Same-design ops that rode the first op's host submission.
    pub elided_dispatches: usize,
    /// Chain makespan: summed device seconds of its ops, including any
    /// reconfigurations they triggered.
    pub device_s: f64,
}

/// Aggregate view of one device's (or a merged) request stream.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub records: Vec<RequestRecord>,
}

impl Metrics {
    pub fn push(&mut self, r: RequestRecord) {
        self.records.push(r);
    }

    pub fn count(&self) -> usize {
        self.records.len()
    }

    pub fn total_device_s(&self) -> f64 {
        self.records.iter().map(|r| r.device_s).sum()
    }

    pub fn total_ops(&self) -> f64 {
        self.records.iter().map(|r| r.ops).sum()
    }

    /// Sustained throughput over simulated device time.
    pub fn device_tops(&self) -> f64 {
        let t = self.total_device_s();
        if t == 0.0 {
            0.0
        } else {
            self.total_ops() / t / 1e12
        }
    }

    pub fn reconfigurations(&self) -> usize {
        self.records.iter().filter(|r| r.reconfigured).count()
    }

    /// `None` when no requests completed — an empty stream has no p99,
    /// it must not report a perfect one (ISSUE 7 bugfix).
    pub fn latency_percentile(&self, p: f64) -> Option<f64> {
        let xs: Vec<f64> = self.records.iter().map(|r| r.host_latency_s).collect();
        stats::percentile(&xs, p)
    }

    /// `None` when no requests completed (see [`Self::latency_percentile`]).
    pub fn device_time_percentile(&self, p: f64) -> Option<f64> {
        let xs: Vec<f64> = self.records.iter().map(|r| r.device_s).collect();
        stats::percentile(&xs, p)
    }

    pub fn all_verified(&self) -> bool {
        self.records.iter().all(|r| r.integrity.ok())
    }

    pub fn summary(&self) -> String {
        format!(
            "{} requests | device {:.2} ms | {:.2} TOPS sustained | \
             p50/p99 device {}/{} ms | {} reconfigurations",
            self.count(),
            self.total_device_s() * 1e3,
            self.device_tops(),
            fmt_ms(self.device_time_percentile(50.0), 2),
            fmt_ms(self.device_time_percentile(99.0), 2),
            self.reconfigurations()
        )
    }
}

/// Render an optional latency (seconds) as milliseconds, or `n/a` when
/// there is no sample to rank (zero completed ops).
fn fmt_ms(x: Option<f64>, prec: usize) -> String {
    match x {
        Some(v) => format!("{:.*}", prec, v * 1e3),
        None => "n/a".to_string(),
    }
}

/// One device's slice of a fleet run.
#[derive(Clone, Debug)]
pub struct DeviceMetrics {
    pub gen: Generation,
    pub metrics: Metrics,
    /// Design-cache accounting for this device's leader.
    pub cache: CacheStats,
}

/// Aggregated view of a fleet run: per-device slices plus the admission
/// router's affinity accounting.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    pub devices: Vec<DeviceMetrics>,
    /// Requests routed to a device already holding their design.
    pub router_hits: u64,
    /// Requests that installed their design on a new device.
    pub router_misses: u64,
    /// Misses that replicated an already-resident design (skew spill).
    pub router_spills: u64,
    /// Per-chain completions (`Coordinator::submit_chain`), in
    /// completion order.
    pub chains: Vec<ChainRecord>,
    /// Per-tenant admission accounting, indexed like
    /// `CoordinatorOptions::tenants` (a single implicit "default"
    /// tenant when none were configured).
    pub tenants: Vec<TenantStats>,
    /// Faults that fired, in router observation order (see
    /// [`Self::fault_log`] for the canonical deterministic order).
    pub faults: Vec<FaultRecord>,
    /// Leaders respawned after an injected or genuine death.
    pub leader_respawns: u64,
    /// Per-device router→leader forward counts — the clock domain the
    /// fault plan's `seq` thresholds live in. An event fires iff its
    /// `seq <= forwards[device]`.
    pub forwards: Vec<u64>,
}

impl FleetMetrics {
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn count(&self) -> usize {
        self.devices.iter().map(|d| d.metrics.count()).sum()
    }

    pub fn total_ops(&self) -> f64 {
        self.devices.iter().map(|d| d.metrics.total_ops()).sum()
    }

    /// Summed busy seconds across all devices.
    pub fn total_device_s(&self) -> f64 {
        self.devices.iter().map(|d| d.metrics.total_device_s()).sum()
    }

    /// The busiest device's total busy time — the simulated wall-clock
    /// for the whole run, since devices execute in parallel.
    pub fn makespan_s(&self) -> f64 {
        self.devices.iter().map(|d| d.metrics.total_device_s()).fold(0.0, f64::max)
    }

    /// Sustained throughput over summed device time (efficiency view).
    pub fn device_tops(&self) -> f64 {
        let t = self.total_device_s();
        if t == 0.0 {
            0.0
        } else {
            self.total_ops() / t / 1e12
        }
    }

    /// Aggregate service throughput over the makespan (capacity view).
    pub fn fleet_tops(&self) -> f64 {
        let t = self.makespan_s();
        if t == 0.0 {
            0.0
        } else {
            self.total_ops() / t / 1e12
        }
    }

    pub fn reconfigurations(&self) -> usize {
        self.devices.iter().map(|d| d.metrics.reconfigurations()).sum()
    }

    pub fn all_verified(&self) -> bool {
        self.devices.iter().all(|d| d.metrics.all_verified())
    }

    /// Host-latency percentile over every record in the fleet (`None`
    /// when no requests completed).
    pub fn latency_percentile(&self, p: f64) -> Option<f64> {
        let xs: Vec<f64> = self
            .devices
            .iter()
            .flat_map(|d| d.metrics.records.iter().map(|r| r.host_latency_s))
            .collect();
        stats::percentile(&xs, p)
    }

    /// Device-time percentile over every record in the fleet (`None`
    /// when no requests completed).
    pub fn device_time_percentile(&self, p: f64) -> Option<f64> {
        let xs: Vec<f64> = self
            .devices
            .iter()
            .flat_map(|d| d.metrics.records.iter().map(|r| r.device_s))
            .collect();
        stats::percentile(&xs, p)
    }

    /// Longest single chain makespan in the run (0 when no chains ran).
    pub fn chain_makespan_s(&self) -> f64 {
        self.chains.iter().map(|c| c.device_s).fold(0.0, f64::max)
    }

    /// Fused edges executed across every chain.
    pub fn chain_fused_edges(&self) -> usize {
        self.chains.iter().map(|c| c.fused_edges).sum()
    }

    /// Fraction of requests that found their design already resident on
    /// the routed device.
    pub fn router_hit_rate(&self) -> f64 {
        let total = self.router_hits + self.router_misses;
        if total == 0 {
            0.0
        } else {
            self.router_hits as f64 / total as f64
        }
    }

    /// Per-tenant stats by configured name.
    pub fn tenant(&self, name: &str) -> Option<&TenantStats> {
        self.tenants.iter().find(|t| t.name == name)
    }

    /// Whether every tenant satisfies the admission conservation
    /// invariant (`completed + failed + pending == submitted`).
    pub fn conserves(&self) -> bool {
        self.tenants.iter().all(TenantStats::conserves)
    }

    /// Total requeue events across tenants (fault-killed or dropped
    /// units that were re-served).
    pub fn total_requeued(&self) -> u64 {
        self.tenants.iter().map(|t| t.requeued).sum()
    }

    /// Fleet-wide integrity counters:
    /// `(checked, passed, recovered, failed)` summed across tenants.
    pub fn integrity_totals(&self) -> (u64, u64, u64, u64) {
        self.tenants.iter().fold((0, 0, 0, 0), |acc, t| {
            (
                acc.0 + t.integrity_checked,
                acc.1 + t.integrity_passed,
                acc.2 + t.integrity_recovered,
                acc.3 + t.integrity_failed,
            )
        })
    }

    /// Units healed by verified recompute across the fleet.
    pub fn total_recovered(&self) -> u64 {
        self.integrity_totals().2
    }

    /// The fired-fault log in its canonical deterministic order:
    /// sorted by (device, seq). Two runs of the same seed and config
    /// must produce identical logs — pinned by `tests/chaos_props.rs`.
    pub fn fault_log(&self) -> Vec<FaultRecord> {
        let mut log = self.faults.clone();
        log.sort_by_key(|f| (f.device, f.seq));
        log
    }

    /// Host-latency percentile restricted to one tenant's records.
    /// `None` when the tenant completed nothing — a zero-op tenant has
    /// no p99, it must not report a perfect one (ISSUE 7 bugfix).
    pub fn tenant_latency_percentile(&self, tenant: usize, p: f64) -> Option<f64> {
        let xs: Vec<f64> = self
            .devices
            .iter()
            .flat_map(|d| d.metrics.records.iter())
            .filter(|r| r.tenant == tenant)
            .map(|r| r.host_latency_s)
            .collect();
        stats::percentile(&xs, p)
    }

    /// Device-time percentile restricted to one tenant's records
    /// (`None` when the tenant completed nothing — see
    /// [`Self::tenant_latency_percentile`]).
    pub fn tenant_device_time_percentile(&self, tenant: usize, p: f64) -> Option<f64> {
        let xs: Vec<f64> = self
            .devices
            .iter()
            .flat_map(|d| d.metrics.records.iter())
            .filter(|r| r.tenant == tenant)
            .map(|r| r.device_s)
            .collect();
        stats::percentile(&xs, p)
    }

    /// The full fleet rollup — device, tenant, chain, fault, and
    /// integrity breakdowns included — as a [`Json`] value
    /// (`serve --json`). Shares the serializer with the trace exporter
    /// ([`crate::trace::chrome`]), so number formatting is identical
    /// across every machine-readable artifact the CLI emits.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        let devices: Vec<Json> = self
            .devices
            .iter()
            .enumerate()
            .map(|(d, dm)| {
                obj(vec![
                    ("device", num(d as f64)),
                    ("gen", s(dm.gen.name())),
                    ("requests", num(dm.metrics.count() as f64)),
                    ("ops", num(dm.metrics.total_ops())),
                    ("device_seconds", num(dm.metrics.total_device_s())),
                    ("device_tops", num(dm.metrics.device_tops())),
                    ("reconfigurations", num(dm.metrics.reconfigurations() as f64)),
                    (
                        "cache",
                        obj(vec![
                            ("hits", num(dm.cache.hits as f64)),
                            ("misses", num(dm.cache.misses as f64)),
                            ("evictions", num(dm.cache.evictions as f64)),
                        ]),
                    ),
                ])
            })
            .collect();
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|t| {
                obj(vec![
                    ("name", s(&t.name)),
                    ("priority", num(t.priority as f64)),
                    ("quota", num(t.quota as f64)),
                    ("submitted", num(t.submitted as f64)),
                    ("completed", num(t.completed as f64)),
                    ("failed", num(t.failed as f64)),
                    ("requeued", num(t.requeued as f64)),
                    ("pending", num(t.pending as f64)),
                    ("max_in_flight", num(t.max_in_flight as f64)),
                    (
                        "integrity",
                        obj(vec![
                            ("checked", num(t.integrity_checked as f64)),
                            ("passed", num(t.integrity_passed as f64)),
                            ("recovered", num(t.integrity_recovered as f64)),
                            ("failed", num(t.integrity_failed as f64)),
                        ]),
                    ),
                ])
            })
            .collect();
        let chains: Vec<Json> = self
            .chains
            .iter()
            .map(|c| {
                obj(vec![
                    ("id", num(c.id as f64)),
                    ("name", s(&c.name)),
                    ("device", num(c.device as f64)),
                    ("ops_count", num(c.ops_count as f64)),
                    ("fused_edges", num(c.fused_edges as f64)),
                    ("elided_dispatches", num(c.elided_dispatches as f64)),
                    ("device_seconds", num(c.device_s)),
                ])
            })
            .collect();
        let faults: Vec<Json> = self
            .fault_log()
            .iter()
            .map(|f| {
                obj(vec![
                    ("device", num(f.device as f64)),
                    ("seq", num(f.seq as f64)),
                    ("kind", s(f.kind.name())),
                ])
            })
            .collect();
        let (checked, passed, recovered, failed) = self.integrity_totals();
        obj(vec![
            ("requests", num(self.count() as f64)),
            ("ops", num(self.total_ops())),
            ("device_seconds", num(self.total_device_s())),
            ("makespan_seconds", num(self.makespan_s())),
            ("device_tops", num(self.device_tops())),
            ("fleet_tops", num(self.fleet_tops())),
            ("reconfigurations", num(self.reconfigurations() as f64)),
            ("latency_p50_seconds", opt(self.latency_percentile(0.50))),
            ("latency_p99_seconds", opt(self.latency_percentile(0.99))),
            ("device_time_p99_seconds", opt(self.device_time_percentile(0.99))),
            (
                "router",
                obj(vec![
                    ("hits", num(self.router_hits as f64)),
                    ("misses", num(self.router_misses as f64)),
                    ("spills", num(self.router_spills as f64)),
                    ("hit_rate", num(self.router_hit_rate())),
                ]),
            ),
            ("leader_respawns", num(self.leader_respawns as f64)),
            ("requeued", num(self.total_requeued() as f64)),
            (
                "integrity",
                obj(vec![
                    ("checked", num(checked as f64)),
                    ("passed", num(passed as f64)),
                    ("recovered", num(recovered as f64)),
                    ("failed", num(failed as f64)),
                ]),
            ),
            ("conserves", Json::Bool(self.conserves())),
            ("devices", Json::Arr(devices)),
            ("tenants", Json::Arr(tenants)),
            ("chains", Json::Arr(chains)),
            ("faults", Json::Arr(faults)),
        ])
    }

    /// Total ops served for one tenant.
    pub fn tenant_ops(&self, tenant: usize) -> f64 {
        self.devices
            .iter()
            .flat_map(|d| d.metrics.records.iter())
            .filter(|r| r.tenant == tenant)
            .map(|r| r.ops)
            .sum()
    }

    /// All records merged into one stream (legacy single-device view).
    pub fn merged(&self) -> Metrics {
        let mut m = Metrics::default();
        for d in &self.devices {
            m.records.extend(d.metrics.records.iter().cloned());
        }
        m
    }

    /// Multi-line human-readable report: one line per device, then the
    /// fleet rollup with p50/p95/p99 latency.
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "fleet: {} device(s) | {} requests | fleet {:.2} TOPS over {:.2} ms makespan | \
             sustained {:.2} TOPS | {} reconfigurations",
            self.n_devices(),
            self.count(),
            self.fleet_tops(),
            self.makespan_s() * 1e3,
            self.device_tops(),
            self.reconfigurations()
        );
        for (i, d) in self.devices.iter().enumerate() {
            let _ = writeln!(
                s,
                "  dev{i} {:>5}: {:>5} req | busy {:>9.2} ms | {:>6.2} TOPS | \
                 {} reconfig | design cache {:.0}% hit",
                d.gen.name(),
                d.metrics.count(),
                d.metrics.total_device_s() * 1e3,
                d.metrics.device_tops(),
                d.metrics.reconfigurations(),
                100.0 * d.cache.hit_rate()
            );
        }
        let _ = writeln!(
            s,
            "latency: device p50/p95/p99 {}/{}/{} ms | host p95 {} ms",
            fmt_ms(self.device_time_percentile(50.0), 3),
            fmt_ms(self.device_time_percentile(95.0), 3),
            fmt_ms(self.device_time_percentile(99.0), 3),
            fmt_ms(self.latency_percentile(95.0), 3)
        );
        if !self.chains.is_empty() {
            let _ = writeln!(
                s,
                "chains: {} completed | longest makespan {:.3} ms | {} fused edges | \
                 {} elided dispatches",
                self.chains.len(),
                self.chain_makespan_s() * 1e3,
                self.chain_fused_edges(),
                self.chains.iter().map(|c| c.elided_dispatches).sum::<usize>()
            );
        }
        if self.tenants.len() > 1 {
            for (i, t) in self.tenants.iter().enumerate() {
                let _ = writeln!(
                    s,
                    "  tenant {:>10} (prio {}, quota {}): {} submitted | {} completed | \
                     {} failed | {} requeued | peak in-flight {} | p99 device {} ms",
                    t.name,
                    t.priority,
                    t.quota,
                    t.submitted,
                    t.completed,
                    t.failed,
                    t.requeued,
                    t.max_in_flight,
                    fmt_ms(self.tenant_device_time_percentile(i, 99.0), 3)
                );
            }
        }
        if !self.faults.is_empty() || self.leader_respawns > 0 {
            let _ = writeln!(
                s,
                "chaos: {} faults fired | {} leader respawns | {} requeues",
                self.faults.len(),
                self.leader_respawns,
                self.total_requeued()
            );
        }
        let (ichecked, ipassed, irecovered, ifailed) = self.integrity_totals();
        if ichecked > 0 {
            let _ = writeln!(
                s,
                "integrity: {ichecked} checked | {ipassed} passed | \
                 {irecovered} recovered | {ifailed} failed"
            );
        }
        let _ = write!(
            s,
            "router: {} affinity hits / {} misses ({} spills) | hit rate {:.1}%",
            self.router_hits,
            self.router_misses,
            self.router_spills,
            100.0 * self.router_hit_rate()
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, device: usize, device_s: f64, ops: f64, reconf: bool) -> RequestRecord {
        RequestRecord {
            id,
            name: format!("r{id}"),
            device,
            device_s,
            host_latency_s: device_s * 1.1,
            ops,
            reconfigured: reconf,
            integrity: Integrity::Passed,
            chain: None,
            tenant: 0,
        }
    }

    #[test]
    fn aggregates() {
        let mut m = Metrics::default();
        m.push(rec(1, 0, 0.010, 1e9, true));
        m.push(rec(2, 0, 0.020, 4e9, false));
        assert_eq!(m.count(), 2);
        assert!((m.total_device_s() - 0.030).abs() < 1e-12);
        assert!((m.device_tops() - (5e9 / 0.030 / 1e12)).abs() < 1e-9);
        assert_eq!(m.reconfigurations(), 1);
        assert!(m.all_verified());
        assert!(m.summary().contains("2 requests"));
    }

    #[test]
    fn fleet_rollup_separates_makespan_from_busy_time() {
        let mut d0 = Metrics::default();
        d0.push(rec(1, 0, 0.010, 1e9, true));
        d0.push(rec(2, 0, 0.010, 1e9, false));
        let mut d1 = Metrics::default();
        d1.push(rec(3, 1, 0.030, 3e9, true));
        let fm = FleetMetrics {
            devices: vec![
                DeviceMetrics {
                    gen: Generation::Xdna,
                    metrics: d0,
                    cache: CacheStats { hits: 1, misses: 1, evictions: 0 },
                },
                DeviceMetrics {
                    gen: Generation::Xdna2,
                    metrics: d1,
                    cache: CacheStats::default(),
                },
            ],
            router_hits: 2,
            router_misses: 1,
            router_spills: 0,
            ..Default::default()
        };
        assert_eq!(fm.count(), 3);
        assert_eq!(fm.n_devices(), 2);
        assert!((fm.total_device_s() - 0.050).abs() < 1e-12);
        assert!((fm.makespan_s() - 0.030).abs() < 1e-12);
        // Fleet throughput uses the makespan; sustained uses busy time.
        assert!((fm.fleet_tops() - (5e9 / 0.030 / 1e12)).abs() < 1e-9);
        assert!((fm.device_tops() - (5e9 / 0.050 / 1e12)).abs() < 1e-9);
        assert!(fm.fleet_tops() > fm.device_tops());
        assert_eq!(fm.reconfigurations(), 2);
        assert_eq!(fm.merged().count(), 3);
        assert!((fm.router_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        let s = fm.summary();
        assert!(s.contains("2 device(s)") && s.contains("router:"), "{s}");
    }

    #[test]
    fn chain_records_roll_up() {
        let mut fm = FleetMetrics::default();
        fm.chains.push(ChainRecord {
            id: 0,
            name: "layer0".into(),
            device: 0,
            ops_count: 4,
            fused_edges: 2,
            elided_dispatches: 3,
            device_s: 0.004,
        });
        fm.chains.push(ChainRecord {
            id: 1,
            name: "layer1".into(),
            device: 1,
            ops_count: 4,
            fused_edges: 1,
            elided_dispatches: 3,
            device_s: 0.007,
        });
        assert!((fm.chain_makespan_s() - 0.007).abs() < 1e-12);
        assert_eq!(fm.chain_fused_edges(), 3);
        assert!(fm.summary().contains("chains: 2 completed"), "{}", fm.summary());
    }

    #[test]
    fn empty_fleet_is_all_zeros() {
        let fm = FleetMetrics::default();
        assert_eq!(fm.count(), 0);
        assert_eq!(fm.chain_makespan_s(), 0.0);
        assert_eq!(fm.fleet_tops(), 0.0);
        assert_eq!(fm.device_tops(), 0.0);
        assert_eq!(fm.makespan_s(), 0.0);
        assert_eq!(fm.router_hit_rate(), 0.0);
        assert!(fm.all_verified());
        assert!(fm.conserves(), "no tenants vacuously conserve");
        assert_eq!(fm.total_requeued(), 0);
        assert!(fm.fault_log().is_empty());
    }

    #[test]
    fn tenant_conservation_invariant() {
        let t = TenantStats {
            name: "llm".into(),
            submitted: 10,
            completed: 7,
            failed: 1,
            pending: 2,
            requeued: 3,
            ..Default::default()
        };
        assert!(t.conserves(), "requeues do not break conservation");
        let lost = TenantStats { submitted: 10, completed: 9, ..Default::default() };
        assert!(!lost.conserves(), "a lost unit must be visible");
    }

    #[test]
    fn integrity_counters_fold_into_conservation() {
        let mut t = TenantStats { name: "llm".into(), submitted: 4, ..Default::default() };
        t.record_integrity(Integrity::NotChecked); // no-op
        t.record_integrity(Integrity::Passed);
        t.record_integrity(Integrity::Recovered { retries: 1 });
        t.record_integrity(Integrity::Failed);
        t.completed = 3;
        t.failed = 1;
        assert_eq!(
            (t.integrity_checked, t.integrity_passed, t.integrity_recovered, t.integrity_failed),
            (3, 1, 1, 1)
        );
        assert!(t.conserves());
        // A checked unit that lands in no outcome bucket is a bug the
        // invariant must catch.
        t.integrity_checked += 1;
        assert!(!t.conserves(), "orphaned integrity check must be visible");
    }

    #[test]
    fn integrity_legacy_tristate_mapping() {
        assert_eq!(Option::<bool>::from(Integrity::NotChecked), None);
        assert_eq!(Option::<bool>::from(Integrity::Passed), Some(true));
        assert_eq!(Option::<bool>::from(Integrity::Recovered { retries: 2 }), Some(true));
        assert_eq!(Option::<bool>::from(Integrity::Failed), Some(false));
        assert!(Integrity::Recovered { retries: 1 }.ok());
        assert!(!Integrity::Failed.ok());
        assert!(!Integrity::NotChecked.checked());
        let r = RequestRecord { integrity: Integrity::Failed, ..rec(9, 0, 0.01, 1e9, false) };
        assert_eq!(r.verified(), Some(false));
        let mut m = Metrics::default();
        m.push(r);
        assert!(!m.all_verified(), "a Failed record poisons all_verified");
    }

    #[test]
    fn fleet_integrity_rollup_and_summary_line() {
        let fm = FleetMetrics {
            tenants: vec![
                TenantStats {
                    name: "a".into(),
                    submitted: 3,
                    completed: 3,
                    integrity_checked: 3,
                    integrity_passed: 2,
                    integrity_recovered: 1,
                    ..Default::default()
                },
                TenantStats {
                    name: "b".into(),
                    submitted: 2,
                    completed: 1,
                    failed: 1,
                    integrity_checked: 2,
                    integrity_passed: 1,
                    integrity_failed: 1,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        assert_eq!(fm.integrity_totals(), (5, 3, 1, 1));
        assert_eq!(fm.total_recovered(), 1);
        assert!(fm.conserves());
        let s = fm.summary();
        assert!(s.contains("integrity: 5 checked"), "{s}");
        // Integrity-off runs keep the summary free of the line.
        assert!(!FleetMetrics::default().summary().contains("integrity:"));
    }

    #[test]
    fn tenant_rollups_filter_by_tenant_index() {
        let mut d0 = Metrics::default();
        d0.push(RequestRecord { tenant: 1, ..rec(1, 0, 0.010, 1e9, false) });
        d0.push(rec(2, 0, 0.020, 4e9, false));
        let fm = FleetMetrics {
            devices: vec![DeviceMetrics {
                gen: Generation::Xdna2,
                metrics: d0,
                cache: CacheStats::default(),
            }],
            tenants: vec![
                TenantStats { name: "a".into(), submitted: 1, completed: 1, ..Default::default() },
                TenantStats { name: "b".into(), submitted: 1, completed: 1, ..Default::default() },
            ],
            ..Default::default()
        };
        assert!((fm.tenant_ops(0) - 4e9).abs() < 1.0);
        assert!((fm.tenant_ops(1) - 1e9).abs() < 1.0);
        assert!((fm.tenant_device_time_percentile(1, 99.0).unwrap() - 0.010).abs() < 1e-12);
        assert!(fm.tenant("a").is_some() && fm.tenant("zzz").is_none());
        assert!(fm.conserves());
        let s = fm.summary();
        assert!(s.contains("tenant"), "multi-tenant runs list tenants: {s}");
    }

    #[test]
    fn zero_op_tenant_has_no_percentile_not_a_perfect_one() {
        // Regression (ISSUE 7): a tenant with zero completed ops used to
        // report p99 = 0.0 ms — indistinguishable from "infinitely fast".
        let mut d0 = Metrics::default();
        d0.push(rec(1, 0, 0.010, 1e9, false)); // tenant 0 only
        let fm = FleetMetrics {
            devices: vec![DeviceMetrics {
                gen: Generation::Xdna2,
                metrics: d0,
                cache: CacheStats::default(),
            }],
            tenants: vec![
                TenantStats { name: "busy".into(), submitted: 1, completed: 1, ..Default::default() },
                TenantStats { name: "idle".into(), ..Default::default() },
            ],
            ..Default::default()
        };
        assert_eq!(fm.tenant_latency_percentile(1, 99.0), None);
        assert_eq!(fm.tenant_device_time_percentile(1, 99.0), None);
        assert!(fm.tenant_latency_percentile(0, 99.0).is_some());
        // Fleet-wide empty case too: no records at all → None.
        let empty = FleetMetrics::default();
        assert_eq!(empty.latency_percentile(99.0), None);
        assert_eq!(empty.device_time_percentile(99.0), None);
        // And the summary renders the hole as n/a rather than 0.000.
        assert!(fm.summary().contains("n/a"), "{}", fm.summary());
    }

    #[test]
    fn fault_log_is_sorted_by_device_then_seq() {
        use super::super::fault::FaultKind;
        let fm = FleetMetrics {
            faults: vec![
                FaultRecord { device: 1, seq: 4, kind: FaultKind::LeaderKill },
                FaultRecord { device: 0, seq: 9, kind: FaultKind::CacheStorm },
                FaultRecord { device: 0, seq: 2, kind: FaultKind::DropResponse },
            ],
            leader_respawns: 1,
            ..Default::default()
        };
        let log = fm.fault_log();
        let order: Vec<(usize, u64)> = log.iter().map(|f| (f.device, f.seq)).collect();
        assert_eq!(order, vec![(0, 2), (0, 9), (1, 4)]);
        assert!(fm.summary().contains("chaos: 3 faults fired"), "{}", fm.summary());
    }
}
