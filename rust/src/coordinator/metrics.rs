//! Per-request records and aggregate service statistics.

use crate::util::stats;

/// One completed request's accounting.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: u64,
    pub name: String,
    /// Simulated device time (GEMM + any reconfiguration).
    pub device_s: f64,
    /// Host wall-clock from submit to response.
    pub host_latency_s: f64,
    pub ops: f64,
    pub reconfigured: bool,
    pub verified: Option<bool>,
}

/// Aggregate view of a service run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub records: Vec<RequestRecord>,
}

impl Metrics {
    pub fn push(&mut self, r: RequestRecord) {
        self.records.push(r);
    }

    pub fn count(&self) -> usize {
        self.records.len()
    }

    pub fn total_device_s(&self) -> f64 {
        self.records.iter().map(|r| r.device_s).sum()
    }

    pub fn total_ops(&self) -> f64 {
        self.records.iter().map(|r| r.ops).sum()
    }

    /// Sustained throughput over simulated device time.
    pub fn device_tops(&self) -> f64 {
        let t = self.total_device_s();
        if t == 0.0 {
            0.0
        } else {
            self.total_ops() / t / 1e12
        }
    }

    pub fn reconfigurations(&self) -> usize {
        self.records.iter().filter(|r| r.reconfigured).count()
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        let xs: Vec<f64> = self.records.iter().map(|r| r.host_latency_s).collect();
        stats::percentile(&xs, p)
    }

    pub fn device_time_percentile(&self, p: f64) -> f64 {
        let xs: Vec<f64> = self.records.iter().map(|r| r.device_s).collect();
        stats::percentile(&xs, p)
    }

    pub fn all_verified(&self) -> bool {
        self.records.iter().all(|r| r.verified != Some(false))
    }

    pub fn summary(&self) -> String {
        format!(
            "{} requests | device {:.2} ms | {:.2} TOPS sustained | \
             p50/p99 device {:.2}/{:.2} ms | {} reconfigurations",
            self.count(),
            self.total_device_s() * 1e3,
            self.device_tops(),
            self.device_time_percentile(50.0) * 1e3,
            self.device_time_percentile(99.0) * 1e3,
            self.reconfigurations()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, device_s: f64, ops: f64, reconf: bool) -> RequestRecord {
        RequestRecord {
            id,
            name: format!("r{id}"),
            device_s,
            host_latency_s: device_s * 1.1,
            ops,
            reconfigured: reconf,
            verified: Some(true),
        }
    }

    #[test]
    fn aggregates() {
        let mut m = Metrics::default();
        m.push(rec(1, 0.010, 1e9, true));
        m.push(rec(2, 0.020, 4e9, false));
        assert_eq!(m.count(), 2);
        assert!((m.total_device_s() - 0.030).abs() < 1e-12);
        assert!((m.device_tops() - (5e9 / 0.030 / 1e12)).abs() < 1e-9);
        assert_eq!(m.reconfigurations(), 1);
        assert!(m.all_verified());
        assert!(m.summary().contains("2 requests"));
    }
}
