//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the Rust request path — the XRT-equivalent host runtime
//! (DESIGN.md §1). Python never runs here.
//!
//! Interchange is HLO *text* (`artifacts/*.hlo.txt`): jax ≥ 0.5 emits
//! HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md
//! and `python/compile/aot.py`).
//!
//! Interface-dtype convention (mirrors `aot.py`):
//! * int8 precisions: A/B as s8 literals, accumulator in/out s32;
//! * bf16: f32 at the boundary, converted to bf16 inside the graph.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::dtype::{Layout, Precision};
use crate::util::json::Json;

/// Parsed manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub gen: String,
    pub precision: String,
    pub b_col_major: bool,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub arg_shapes: Vec<Vec<usize>>,
    pub arg_dtypes: Vec<String>,
    pub out_dtype: String,
}

impl ArtifactMeta {
    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let shapes = j
            .req("arg_shapes")?
            .as_arr()
            .ok_or_else(|| anyhow!("arg_shapes not an array"))?
            .iter()
            .map(|s| {
                s.as_arr()
                    .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                    .ok_or_else(|| anyhow!("bad shape"))
            })
            .collect::<Result<Vec<Vec<usize>>>>()?;
        Ok(ArtifactMeta {
            name: j.req("name")?.as_str().unwrap_or_default().to_string(),
            file: j.req("file")?.as_str().unwrap_or_default().to_string(),
            kind: j.req("kind")?.as_str().unwrap_or_default().to_string(),
            gen: j.req("gen")?.as_str().unwrap_or_default().to_string(),
            precision: j.req("precision")?.as_str().unwrap_or_default().to_string(),
            b_col_major: j.req("b_col_major")?.as_bool().unwrap_or(false),
            m: j.req("m")?.as_usize().unwrap_or(0),
            k: j.req("k")?.as_usize().unwrap_or(0),
            n: j.req("n")?.as_usize().unwrap_or(0),
            arg_shapes: shapes,
            arg_dtypes: j
                .req("arg_dtypes")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|d| d.as_str().map(str::to_string))
                .collect(),
            out_dtype: j.req("out_dtype")?.as_str().unwrap_or_default().to_string(),
        })
    }
}

/// Canonical native-step artifact name for a design point.
pub fn step_artifact_name(gen: crate::arch::Generation, p: Precision, b_layout: Layout) -> String {
    format!("step_{}_{}_{}", gen.name(), p.name(), b_layout.name())
}

/// The PJRT runtime: one CPU client + lazily compiled executables.
pub struct Runtime {
    dir: PathBuf,
    client: xla::PjRtClient,
    manifest: HashMap<String, ArtifactMeta>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load the artifact manifest and start the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let parsed = Json::parse(&text)?;
        let mut manifest = HashMap::new();
        for entry in parsed.as_arr().ok_or_else(|| anyhow!("manifest not an array"))? {
            let meta = ArtifactMeta::from_json(entry)?;
            manifest.insert(meta.name.clone(), meta);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        Ok(Runtime { dir, client, manifest, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.get(name)
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    fn run(&mut self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal> {
        self.ensure_compiled(name)?;
        let exe = self.executables.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        // aot.py lowers with return_tuple=True.
        lit.to_tuple1().map_err(|e| anyhow!("untupling {name}: {e}"))
    }

    /// Execute an int8 native step: `acc' = acc + A_panel @ B_panel`.
    pub fn execute_step_i8(
        &mut self,
        name: &str,
        a: &[i8],
        b: &[i8],
        acc: &[i32],
    ) -> Result<Vec<i32>> {
        let meta = self.meta(name).ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if meta.arg_dtypes.first().map(String::as_str) != Some("s8") {
            bail!("artifact '{name}' does not take s8 inputs");
        }
        let shapes = meta.arg_shapes.clone();
        let la = lit_i8(a, &shapes[0])?;
        let lb = lit_i8(b, &shapes[1])?;
        let lacc = lit_i32(acc, &shapes[2])?;
        let out = self.run(name, &[la, lb, lacc])?;
        out.to_vec::<i32>().map_err(|e| anyhow!("result marshal: {e}"))
    }

    /// Execute a bf16 native step (f32 interface): `acc' = acc + A @ B`.
    pub fn execute_step_f32(
        &mut self,
        name: &str,
        a: &[f32],
        b: &[f32],
        acc: &[f32],
    ) -> Result<Vec<f32>> {
        let meta = self.meta(name).ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if meta.arg_dtypes.first().map(String::as_str) != Some("f32") {
            bail!("artifact '{name}' does not take f32 inputs");
        }
        let shapes = meta.arg_shapes.clone();
        let la = lit_f32(a, &shapes[0])?;
        let lb = lit_f32(b, &shapes[1])?;
        let lacc = lit_f32(acc, &shapes[2])?;
        let out = self.run(name, &[la, lb, lacc])?;
        out.to_vec::<f32>().map_err(|e| anyhow!("result marshal: {e}"))
    }

    /// Execute an f32-interface artifact with arbitrary arity
    /// (quickstart / MLP demos).
    pub fn execute_f32(&mut self, name: &str, args: &[&[f32]]) -> Result<Vec<f32>> {
        let meta = self.meta(name).ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if args.len() != meta.arg_shapes.len() {
            bail!("artifact '{name}' takes {} args, got {}", meta.arg_shapes.len(), args.len());
        }
        let shapes = meta.arg_shapes.clone();
        let lits = args
            .iter()
            .zip(shapes.iter())
            .map(|(a, s)| lit_f32(a, s))
            .collect::<Result<Vec<_>>>()?;
        let out = self.run(name, &lits)?;
        out.to_vec::<f32>().map_err(|e| anyhow!("result marshal: {e}"))
    }
}

/// Execute a full GEMM by chaining native-step artifacts — the outer-most
/// tiling level (Sec. 4.4) driven from Rust, with PJRT executing each
/// native step. This is the functional serving path of `examples/serve.rs`.
///
/// `cfg` must be the balanced config whose step artifact was AOT-compiled
/// (`step_<gen>_<prec>_<layout>`); arbitrary `a`/`b` sizes are padded to
/// its native grid.
pub fn pjrt_gemm(
    rt: &mut Runtime,
    cfg: &crate::tiling::TilingConfig,
    a: &crate::mem::Matrix,
    b: &crate::mem::Matrix,
) -> Result<crate::mem::Matrix> {
    use crate::gemm::exec::pad_matrix;
    use crate::gemm::refimpl::store_narrowed;
    use crate::mem::Matrix;

    let p = cfg.precision;
    let name = step_artifact_name(cfg.gen, p, cfg.b_layout);
    let meta = rt.meta(&name).ok_or_else(|| anyhow!("no artifact '{name}'"))?.clone();
    let (nm, nk, nn) = cfg.native();
    if (meta.m, meta.k, meta.n) != (nm, nk, nn) {
        bail!(
            "artifact '{name}' was compiled for native {}x{}x{}, config wants {}x{}x{} — \
             regenerate artifacts",
            meta.m,
            meta.k,
            meta.n,
            nm,
            nk,
            nn
        );
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let (pm, pk, pn) = cfg.padded(m, k, n);
    let pa = pad_matrix(a, pm, pk)?;
    let pb = pad_matrix(b, pk, pn)?;
    let mut out = Matrix::zeroed(m, n, p.ty_out(), crate::dtype::Layout::RowMajor)?;

    let is_f32 = p == Precision::Bf16;
    for trow in 0..pm / nm {
        for tcol in 0..pn / nn {
            // Output-stationary accumulator for this native tile.
            let mut acc_i = vec![0i32; nm * nn];
            let mut acc_f = vec![0f32; nm * nn];
            for kp in 0..pk / nk {
                // A panel: nm x nk row-major.
                let (mut a_i8, mut a_f32) = (Vec::new(), Vec::new());
                for i in 0..nm {
                    for kk in 0..nk {
                        let (gi, gk) = (trow * nm + i, kp * nk + kk);
                        if is_f32 {
                            a_f32.push(pa.get_bf16(gi, gk).to_f32());
                        } else {
                            a_i8.push(pa.get_i8(gi, gk));
                        }
                    }
                }
                // B panel: nk x nn (row-major iface) or nn x nk (col-major).
                let (mut b_i8, mut b_f32) = (Vec::new(), Vec::new());
                let push = |b_i8: &mut Vec<i8>, b_f32: &mut Vec<f32>, gk: usize, gj: usize| {
                    if is_f32 {
                        b_f32.push(pb.get_bf16(gk, gj).to_f32());
                    } else {
                        b_i8.push(pb.get_i8(gk, gj));
                    }
                };
                if meta.b_col_major {
                    for j in 0..nn {
                        for kk in 0..nk {
                            push(&mut b_i8, &mut b_f32, kp * nk + kk, tcol * nn + j);
                        }
                    }
                } else {
                    for kk in 0..nk {
                        for j in 0..nn {
                            push(&mut b_i8, &mut b_f32, kp * nk + kk, tcol * nn + j);
                        }
                    }
                }
                if is_f32 {
                    acc_f = rt.execute_step_f32(&name, &a_f32, &b_f32, &acc_f)?;
                } else {
                    acc_i = rt.execute_step_i8(&name, &a_i8, &b_i8, &acc_i)?;
                }
            }
            // Narrow into the (cropped) output.
            for i in 0..nm {
                let gi = trow * nm + i;
                if gi >= m {
                    break;
                }
                for j in 0..nn {
                    let gj = tcol * nn + j;
                    if gj >= n {
                        continue;
                    }
                    if is_f32 {
                        out.set_bf16(gi, gj, crate::dtype::Bf16::from_f32(acc_f[i * nn + j]));
                    } else {
                        store_narrowed(&mut out, gi, gj, acc_i[i * nn + j], p);
                    }
                }
            }
        }
    }
    Ok(out)
}

fn check_len(data_len: usize, dims: &[usize]) -> Result<()> {
    let want: usize = dims.iter().product();
    if data_len != want {
        bail!("literal data {} elements, shape {:?} needs {}", data_len, dims, want);
    }
    Ok(())
}

fn lit_i8(data: &[i8], dims: &[usize]) -> Result<xla::Literal> {
    check_len(data.len(), dims)?;
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S8, dims);
    lit.copy_raw_from(data).map_err(|e| anyhow!("i8 literal: {e}"))?;
    Ok(lit)
}

fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    check_len(data.len(), dims)?;
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S32, dims);
    lit.copy_raw_from(data).map_err(|e| anyhow!("i32 literal: {e}"))?;
    Ok(lit)
}

fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    check_len(data.len(), dims)?;
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::F32, dims);
    lit.copy_raw_from(data).map_err(|e| anyhow!("f32 literal: {e}"))?;
    Ok(lit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_are_canonical() {
        assert_eq!(
            step_artifact_name(crate::arch::Generation::Xdna2, Precision::I8I16, Layout::ColMajor),
            "step_xdna2_i8i16_colmajor"
        );
    }

    #[test]
    fn literal_length_checked() {
        assert!(lit_f32(&[1.0; 5], &[2, 3]).is_err());
        assert!(lit_i8(&[1; 6], &[2, 3]).is_ok());
    }
    // PJRT execution tests live in rust/tests/runtime_e2e.rs (they need
    // `make artifacts` outputs).
}
