//! Precision pairs and element types.
//!
//! The paper evaluates four precision pairs (Sec. 5): `int8-int8`,
//! `int8-int16`, `int8-int32` (int8 inputs, int32 accumulation, output
//! narrowed with saturation — "precision reduction"), and `bf16-bf16`
//! (bf16 inputs, fp32 accumulators, bf16 stores). XDNA2 additionally runs
//! bf16 through its bfp16 datapath, which the simulator models as a higher
//! effective peak (see `sim::core`).

use std::fmt;

/// Software bfloat16: upper 16 bits of an IEEE-754 f32, rounded
/// to-nearest-even on conversion — the rounding AIE bf16 stores use.
#[derive(Clone, Copy, PartialEq, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);

    /// Convert from f32 with round-to-nearest-even.
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // Quiet NaN, preserving sign.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Round to nearest even on the truncated 16 bits.
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
        let _ = round_bit;
        Bf16((rounded >> 16) as u16)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    #[inline]
    pub fn from_bits(b: u16) -> Self {
        Bf16(b)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }
}

impl fmt::Debug for Bf16 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}bf16", self.to_f32())
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> f32 {
        x.to_f32()
    }
}

/// Saturating narrowing from a 32-bit accumulator (the AIE `srs` step).
#[inline]
pub fn sat_i8(x: i32) -> i8 {
    x.clamp(-128, 127) as i8
}

/// Saturating narrowing to int16.
#[inline]
pub fn sat_i16(x: i32) -> i16 {
    x.clamp(-32768, 32767) as i16
}

/// A GEMM precision pair: input element type + output element type.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum Precision {
    /// int8 inputs, int32 accumulate, saturate to int8 on store.
    I8I8,
    /// int8 inputs, int32 accumulate, saturate to int16 on store.
    I8I16,
    /// int8 inputs, full int32 outputs.
    I8I32,
    /// bf16 inputs, f32 accumulate, bf16 stores.
    Bf16,
    /// Native block floating point (`dtype_bfp16`): 8-value blocks with a
    /// shared 8-bit exponent, int8-class MAC rate on XDNA2 (Sec. 5.3.4).
    /// Blocks are padded to 12-byte words on every DMA leg (the
    /// word-aligned repack of DESIGN.md §10), so the wire/buffer density
    /// is 12 bits/value over the dense format's 9.
    Bfp16,
    /// *Logical* fp32-accuracy GEMM via Ozaki/Ootomo error-free operand
    /// splitting (`dtype_split`, DESIGN.md §15): f32 operands decompose
    /// into bf16 hi/lo limbs, each limb product runs as a plain bf16
    /// GEMM on the existing datapath, and the f32 partials rejoin
    /// elementwise. This precision never reaches a tiling schedule or a
    /// device datapath — `TilingConfig::validate` rejects it and
    /// `DesignKey::normalized` maps it to the bf16 design it physically
    /// executes on; one logical dispatch costs
    /// [`crate::dtype_split::LIMB_GEMMS`] bf16 dispatches.
    Fp32Split,
}

impl Precision {
    /// The paper's four evaluated precision pairs (Sec. 5). Loops that
    /// mirror published tables/artifacts iterate this set.
    pub const ALL: [Precision; 4] =
        [Precision::I8I8, Precision::I8I16, Precision::I8I32, Precision::Bf16];

    /// Every supported precision including the native-bfp16 extension
    /// (the Sec. 5.3.4 future-work path this crate implements).
    /// [`Precision::Fp32Split`] is deliberately absent: it is a logical
    /// precision with no device schedule, so design-cache warm loops and
    /// table sweeps must never iterate it.
    pub const ALL_EXTENDED: [Precision; 5] = [
        Precision::I8I8,
        Precision::I8I16,
        Precision::I8I32,
        Precision::Bf16,
        Precision::Bfp16,
    ];

    /// `ty(A)` / `ty(B)`: input element size in bytes (Eqs. 2, 3, 6, 7).
    ///
    /// Panics for [`Precision::Bfp16`], whose 12-bit amortized elements
    /// have no per-element byte size — use [`Self::bytes_in`] /
    /// [`Self::in_bits`] (all capacity and traffic math does).
    #[inline]
    pub fn ty_in(self) -> usize {
        match self {
            Precision::Bf16 => 2,
            Precision::Bfp16 => panic!("bfp16 is a block format; use bytes_in/in_bits"),
            Precision::Fp32Split => 4,
            _ => 1,
        }
    }

    /// `ty(C)`: output element size in bytes (Eqs. 5, 8). Panics for
    /// [`Precision::Bfp16`] (see [`Self::ty_in`]).
    #[inline]
    pub fn ty_out(self) -> usize {
        match self {
            Precision::I8I8 => 1,
            Precision::I8I16 => 2,
            Precision::I8I32 => 4,
            Precision::Bf16 => 2,
            Precision::Bfp16 => panic!("bfp16 is a block format; use bytes_out/out_bits"),
            Precision::Fp32Split => 4,
        }
    }

    /// Amortized input element size in bits: the DMA-leg density. bfp16
    /// moves 12-byte padded blocks of 8 values (12 bits/value); every
    /// other precision is byte-granular.
    #[inline]
    pub fn in_bits(self) -> usize {
        match self {
            Precision::Bf16 => 16,
            Precision::Bfp16 => 12,
            Precision::Fp32Split => 32,
            _ => 8,
        }
    }

    /// Amortized output element size in bits (bfp16 C tiles are stored
    /// as padded blocks too, so they can chain into the next op's A).
    #[inline]
    pub fn out_bits(self) -> usize {
        match self {
            Precision::I8I8 => 8,
            Precision::I8I16 => 16,
            Precision::I8I32 => 32,
            Precision::Bf16 => 16,
            Precision::Bfp16 => 12,
            Precision::Fp32Split => 32,
        }
    }

    /// Exact storage bytes of `elems` input elements. For bfp16 the
    /// count must cover whole 8-value blocks (guaranteed by the
    /// micro-tile alignment every caller operates under, and asserted
    /// here — half a shared-exponent block cannot physically exist).
    #[inline]
    pub fn bytes_in(self, elems: usize) -> usize {
        debug_assert!(
            self != Precision::Bfp16 || elems % crate::dtype_bfp16::BLOCK == 0,
            "{elems} elements do not cover whole bfp16 blocks"
        );
        let bits = elems * self.in_bits();
        debug_assert!(bits % 8 == 0, "{elems} elements not byte-aligned at {}", self.name());
        bits / 8
    }

    /// Exact storage bytes of `elems` output elements (same whole-block
    /// requirement as [`Self::bytes_in`]).
    #[inline]
    pub fn bytes_out(self, elems: usize) -> usize {
        debug_assert!(
            self != Precision::Bfp16 || elems % crate::dtype_bfp16::BLOCK == 0,
            "{elems} elements do not cover whole bfp16 blocks"
        );
        let bits = elems * self.out_bits();
        debug_assert!(bits % 8 == 0, "{elems} elements not byte-aligned at {}", self.name());
        bits / 8
    }

    /// Input element size in bytes as a float (the simulator's traffic
    /// equations work in f64 bytes).
    #[inline]
    pub fn in_bytes_f(self) -> f64 {
        self.in_bits() as f64 / 8.0
    }

    /// Output element size in bytes as a float.
    #[inline]
    pub fn out_bytes_f(self) -> f64 {
        self.out_bits() as f64 / 8.0
    }

    /// Accumulator element size in bytes (resident C tile in L1 during the
    /// reduction; int32 / f32 accumulators are 4 B).
    ///
    /// Note Eq. 5 budgets the C tile at its *output* precision — the AIE
    /// API keeps the accumulator in the vector register file / acc
    /// registers, and the L1 buffer holds the narrowed tile. We follow the
    /// paper (`ty_out`) for capacity checks and use `acc_bytes` only for
    /// host-side functional buffers.
    #[inline]
    pub fn acc_bytes(self) -> usize {
        4
    }

    /// AIE-API micro-tile `r x s x t` for this precision (AIE-ML modes;
    /// mirrored in `python/compile/kernels/ref.py::MICRO_TILE`). bfp16
    /// runs the int8-class `4x8x8` mode — `s = t = 8` means one
    /// micro-tile K/N extent is exactly one shared-exponent block, which
    /// is what lets the Fig.-4 chains move whole 12-byte blocks as
    /// opaque 3-word elements (DESIGN.md §10).
    #[inline]
    pub fn micro_tile(self) -> (usize, usize, usize) {
        match self {
            // Fp32Split reports the bf16 mode its limb GEMMs run in
            // (it never owns a schedule of its own — see `validate`).
            Precision::Bf16 | Precision::Fp32Split => (4, 8, 4),
            _ => (4, 8, 8),
        }
    }

    /// Manifest / CLI name (`i8i8`, `i8i16`, `i8i32`, `bf16`, `bfp16`).
    pub fn name(self) -> &'static str {
        match self {
            Precision::I8I8 => "i8i8",
            Precision::I8I16 => "i8i16",
            Precision::I8I32 => "i8i32",
            Precision::Bf16 => "bf16",
            Precision::Bfp16 => "bfp16",
            Precision::Fp32Split => "fp32_split",
        }
    }

    /// Paper-style name (`int8-int8`, ..., `bf16-bf16`).
    pub fn paper_name(self) -> &'static str {
        match self {
            Precision::I8I8 => "int8-int8",
            Precision::I8I16 => "int8-int16",
            Precision::I8I32 => "int8-int32",
            Precision::Bf16 => "bf16-bf16",
            Precision::Bfp16 => "bfp16-bfp16",
            Precision::Fp32Split => "fp32-split",
        }
    }

    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "i8i8" | "int8-int8" => Some(Precision::I8I8),
            "i8i16" | "int8-int16" => Some(Precision::I8I16),
            "i8i32" | "int8-int32" => Some(Precision::I8I32),
            "bf16" | "bf16-bf16" => Some(Precision::Bf16),
            "bfp16" | "bfp16-bfp16" => Some(Precision::Bfp16),
            "fp32_split" | "fp32-split" => Some(Precision::Fp32Split),
            _ => None,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Storage order of a matrix in DRAM (Sec. 4.2.2): A and C are always
/// row-major in this work; B may be either.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum Layout {
    RowMajor,
    ColMajor,
}

impl Layout {
    pub fn name(self) -> &'static str {
        match self {
            Layout::RowMajor => "rowmajor",
            Layout::ColMajor => "colmajor",
        }
    }

    pub fn parse(s: &str) -> Option<Layout> {
        match s {
            "rowmajor" | "row" | "row-major" => Some(Layout::RowMajor),
            "colmajor" | "col" | "col-major" | "column-major" => Some(Layout::ColMajor),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_roundtrip_exact() {
        for x in [0.0f32, 1.0, -1.0, 0.5, -2.5, 3.140625] {
            // Values with <= 8 significand bits survive exactly.
            assert_eq!(Bf16::from_f32(x).to_f32(), x, "{x}");
        }
    }

    #[test]
    fn bf16_round_to_nearest_even() {
        // 1.0039062 = 1 + 2^-8: exactly halfway between bf16(1.0) and
        // bf16(1.0078125); ties-to-even keeps the even significand (1.0).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(halfway).to_bits(), 0x3F80);
        // Just above halfway rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(Bf16::from_f32(above).to_bits(), 0x3F81);
        // Odd significand + exact tie rounds up to even.
        let tie_odd = f32::from_bits(0x3F81_8000);
        assert_eq!(Bf16::from_f32(tie_odd).to_bits(), 0x3F82);
    }

    #[test]
    fn bf16_nan_inf() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn saturation() {
        assert_eq!(sat_i8(127), 127);
        assert_eq!(sat_i8(128), 127);
        assert_eq!(sat_i8(-128), -128);
        assert_eq!(sat_i8(-129), -128);
        assert_eq!(sat_i8(1 << 20), 127);
        assert_eq!(sat_i16(32768), 32767);
        assert_eq!(sat_i16(-40000), -32768);
    }

    #[test]
    fn precision_tables() {
        assert_eq!(Precision::I8I8.ty_in(), 1);
        assert_eq!(Precision::Bf16.ty_in(), 2);
        assert_eq!(Precision::I8I32.ty_out(), 4);
        assert_eq!(Precision::Bf16.micro_tile(), (4, 8, 4));
        for p in Precision::ALL_EXTENDED {
            assert_eq!(Precision::parse(p.name()), Some(p));
            assert_eq!(Precision::parse(p.paper_name()), Some(p));
        }
    }

    #[test]
    fn fp32_split_is_logical_and_parses() {
        let p = Precision::Fp32Split;
        assert_eq!(p.ty_in(), 4);
        assert_eq!(p.ty_out(), 4);
        assert_eq!(p.in_bits(), 32);
        assert_eq!(p.out_bits(), 32);
        assert_eq!(p.bytes_in(48), 192);
        assert_eq!(p.micro_tile(), (4, 8, 4), "reports its limbs' bf16 mode");
        assert_eq!(Precision::parse("fp32_split"), Some(p));
        assert_eq!(Precision::parse("fp32-split"), Some(p));
        assert_eq!(Precision::parse(p.name()), Some(p));
        assert_eq!(Precision::parse(p.paper_name()), Some(p));
        // Logical-only: table sweeps and design-cache warm loops must
        // never see it.
        assert!(!Precision::ALL.contains(&p));
        assert!(!Precision::ALL_EXTENDED.contains(&p));
    }

    #[test]
    fn bit_granular_sizes_agree_with_byte_sizes() {
        // The bit-granular API is the byte API for the byte-granular
        // precisions...
        for p in Precision::ALL {
            assert_eq!(p.in_bits(), 8 * p.ty_in());
            assert_eq!(p.out_bits(), 8 * p.ty_out());
            assert_eq!(p.bytes_in(48), 48 * p.ty_in());
            assert_eq!(p.bytes_out(48), 48 * p.ty_out());
        }
        // ...and the padded-block density for bfp16: 12 bytes per
        // 8-value block on every DMA leg (9 data bytes + 3 pad).
        let b = Precision::Bfp16;
        assert_eq!(b.in_bits(), 12);
        assert_eq!(b.bytes_in(8), 12);
        assert_eq!(b.bytes_out(16), 24);
        assert_eq!(b.micro_tile(), (4, 8, 8));
        assert_eq!(b.in_bytes_f(), 1.5);
    }
}
