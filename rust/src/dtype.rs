//! Precision pairs and element types.
//!
//! The paper evaluates four precision pairs (Sec. 5): `int8-int8`,
//! `int8-int16`, `int8-int32` (int8 inputs, int32 accumulation, output
//! narrowed with saturation — "precision reduction"), and `bf16-bf16`
//! (bf16 inputs, fp32 accumulators, bf16 stores). XDNA2 additionally runs
//! bf16 through its bfp16 datapath, which the simulator models as a higher
//! effective peak (see `sim::core`).

use std::fmt;

/// Software bfloat16: upper 16 bits of an IEEE-754 f32, rounded
/// to-nearest-even on conversion — the rounding AIE bf16 stores use.
#[derive(Clone, Copy, PartialEq, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);

    /// Convert from f32 with round-to-nearest-even.
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // Quiet NaN, preserving sign.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Round to nearest even on the truncated 16 bits.
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
        let _ = round_bit;
        Bf16((rounded >> 16) as u16)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    #[inline]
    pub fn from_bits(b: u16) -> Self {
        Bf16(b)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }
}

impl fmt::Debug for Bf16 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}bf16", self.to_f32())
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> f32 {
        x.to_f32()
    }
}

/// Saturating narrowing from a 32-bit accumulator (the AIE `srs` step).
#[inline]
pub fn sat_i8(x: i32) -> i8 {
    x.clamp(-128, 127) as i8
}

/// Saturating narrowing to int16.
#[inline]
pub fn sat_i16(x: i32) -> i16 {
    x.clamp(-32768, 32767) as i16
}

/// A GEMM precision pair: input element type + output element type.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum Precision {
    /// int8 inputs, int32 accumulate, saturate to int8 on store.
    I8I8,
    /// int8 inputs, int32 accumulate, saturate to int16 on store.
    I8I16,
    /// int8 inputs, full int32 outputs.
    I8I32,
    /// bf16 inputs, f32 accumulate, bf16 stores.
    Bf16,
}

impl Precision {
    pub const ALL: [Precision; 4] =
        [Precision::I8I8, Precision::I8I16, Precision::I8I32, Precision::Bf16];

    /// `ty(A)` / `ty(B)`: input element size in bytes (Eqs. 2, 3, 6, 7).
    #[inline]
    pub fn ty_in(self) -> usize {
        match self {
            Precision::Bf16 => 2,
            _ => 1,
        }
    }

    /// `ty(C)`: output element size in bytes (Eqs. 5, 8).
    #[inline]
    pub fn ty_out(self) -> usize {
        match self {
            Precision::I8I8 => 1,
            Precision::I8I16 => 2,
            Precision::I8I32 => 4,
            Precision::Bf16 => 2,
        }
    }

    /// Accumulator element size in bytes (resident C tile in L1 during the
    /// reduction; int32 / f32 accumulators are 4 B).
    ///
    /// Note Eq. 5 budgets the C tile at its *output* precision — the AIE
    /// API keeps the accumulator in the vector register file / acc
    /// registers, and the L1 buffer holds the narrowed tile. We follow the
    /// paper (`ty_out`) for capacity checks and use `acc_bytes` only for
    /// host-side functional buffers.
    #[inline]
    pub fn acc_bytes(self) -> usize {
        4
    }

    /// AIE-API micro-tile `r x s x t` for this precision (AIE-ML modes;
    /// mirrored in `python/compile/kernels/ref.py::MICRO_TILE`).
    #[inline]
    pub fn micro_tile(self) -> (usize, usize, usize) {
        match self {
            Precision::Bf16 => (4, 8, 4),
            _ => (4, 8, 8),
        }
    }

    /// Manifest / CLI name (`i8i8`, `i8i16`, `i8i32`, `bf16`).
    pub fn name(self) -> &'static str {
        match self {
            Precision::I8I8 => "i8i8",
            Precision::I8I16 => "i8i16",
            Precision::I8I32 => "i8i32",
            Precision::Bf16 => "bf16",
        }
    }

    /// Paper-style name (`int8-int8`, ..., `bf16-bf16`).
    pub fn paper_name(self) -> &'static str {
        match self {
            Precision::I8I8 => "int8-int8",
            Precision::I8I16 => "int8-int16",
            Precision::I8I32 => "int8-int32",
            Precision::Bf16 => "bf16-bf16",
        }
    }

    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "i8i8" | "int8-int8" => Some(Precision::I8I8),
            "i8i16" | "int8-int16" => Some(Precision::I8I16),
            "i8i32" | "int8-int32" => Some(Precision::I8I32),
            "bf16" | "bf16-bf16" => Some(Precision::Bf16),
            _ => None,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Storage order of a matrix in DRAM (Sec. 4.2.2): A and C are always
/// row-major in this work; B may be either.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum Layout {
    RowMajor,
    ColMajor,
}

impl Layout {
    pub fn name(self) -> &'static str {
        match self {
            Layout::RowMajor => "rowmajor",
            Layout::ColMajor => "colmajor",
        }
    }

    pub fn parse(s: &str) -> Option<Layout> {
        match s {
            "rowmajor" | "row" | "row-major" => Some(Layout::RowMajor),
            "colmajor" | "col" | "col-major" | "column-major" => Some(Layout::ColMajor),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_roundtrip_exact() {
        for x in [0.0f32, 1.0, -1.0, 0.5, -2.5, 3.140625] {
            // Values with <= 8 significand bits survive exactly.
            assert_eq!(Bf16::from_f32(x).to_f32(), x, "{x}");
        }
    }

    #[test]
    fn bf16_round_to_nearest_even() {
        // 1.0039062 = 1 + 2^-8: exactly halfway between bf16(1.0) and
        // bf16(1.0078125); ties-to-even keeps the even significand (1.0).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(halfway).to_bits(), 0x3F80);
        // Just above halfway rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(Bf16::from_f32(above).to_bits(), 0x3F81);
        // Odd significand + exact tie rounds up to even.
        let tie_odd = f32::from_bits(0x3F81_8000);
        assert_eq!(Bf16::from_f32(tie_odd).to_bits(), 0x3F82);
    }

    #[test]
    fn bf16_nan_inf() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn saturation() {
        assert_eq!(sat_i8(127), 127);
        assert_eq!(sat_i8(128), 127);
        assert_eq!(sat_i8(-128), -128);
        assert_eq!(sat_i8(-129), -128);
        assert_eq!(sat_i8(1 << 20), 127);
        assert_eq!(sat_i16(32768), 32767);
        assert_eq!(sat_i16(-40000), -32768);
    }

    #[test]
    fn precision_tables() {
        assert_eq!(Precision::I8I8.ty_in(), 1);
        assert_eq!(Precision::Bf16.ty_in(), 2);
        assert_eq!(Precision::I8I32.ty_out(), 4);
        assert_eq!(Precision::Bf16.micro_tile(), (4, 8, 4));
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.name()), Some(p));
            assert_eq!(Precision::parse(p.paper_name()), Some(p));
        }
    }
}
