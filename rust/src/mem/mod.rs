//! Memory substrate: DRAM matrix images (word-addressable, as the DMAs see
//! them) and on-chip buffer allocators with the capacity rules of Sec. 4.2.
//!
//! Matrices live in DRAM in *regular order* — row-major for A and C,
//! row- or column-major for B (Sec. 4.2.2); there is no explicit
//! pre-tiling, that's the `xform` pipeline's job.

use anyhow::{bail, Result};

use crate::dtype::{Bf16, Layout};
use crate::dtype_bfp16::{BfpBlock, BLOCK, BLOCK_WORDS, PADDED_BYTES};

/// A DRAM-resident matrix as a word-addressable image.
///
/// `data` is a `Vec<u32>` so DMA gathers/scatters (32-bit granularity)
/// operate directly; element accessors pack/unpack within words.
/// For `Layout::ColMajor` the *storage* is the transposed matrix laid out
/// row-major (i.e. `data[j * rows + i]` holds element `(i, j)`), which is
/// byte-identical to textbook column-major.
#[derive(Clone, Debug)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub elem_bytes: usize,
    pub layout: Layout,
    pub data: Vec<u32>,
}

impl Matrix {
    pub fn zeroed(rows: usize, cols: usize, elem_bytes: usize, layout: Layout) -> Result<Matrix> {
        let bytes = rows * cols * elem_bytes;
        if bytes % 4 != 0 {
            bail!("matrix image {rows}x{cols}x{elem_bytes}B not word-aligned");
        }
        // The *storage row* (contiguous run) must also be word-aligned for
        // DMA addressing: rows of `cols` elements (row-major) or `rows`
        // elements (col-major).
        let run = match layout {
            Layout::RowMajor => cols * elem_bytes,
            Layout::ColMajor => rows * elem_bytes,
        };
        if run % 4 != 0 {
            bail!("matrix storage rows of {run} B not word-aligned");
        }
        Ok(Matrix { rows, cols, elem_bytes, layout, data: vec![0; bytes / 4] })
    }

    /// A native-bfp16 matrix image of `rows × cols` *logical* elements.
    ///
    /// Shared-exponent blocks run along the reduction-facing axis — the
    /// columns of a row-major image (A, C) or the rows of a column-major
    /// one (B) — and each block is stored in the padded 12-byte wire
    /// layout ([`BfpBlock::to_words`]), so the image is word-addressable
    /// and the Fig.-4 DMA chains re-tile it as 3-word elements.
    ///
    /// The returned `Matrix` is in *block units* on the blocked axis
    /// (`elem_bytes == 12`): a row-major `m × k` operand is stored as
    /// `m × k/8` block cells. Access it with
    /// [`Self::get_bfp_block`]/[`Self::set_bfp_block`]; the byte-granular
    /// accessors do not apply.
    pub fn zeroed_bfp16(rows: usize, cols: usize, layout: Layout) -> Result<Matrix> {
        let blocked = match layout {
            Layout::RowMajor => cols,
            Layout::ColMajor => rows,
        };
        if blocked % BLOCK != 0 {
            bail!("bfp16 image {rows}x{cols}: blocked axis {blocked} not a multiple of {BLOCK}");
        }
        match layout {
            Layout::RowMajor => Matrix::zeroed(rows, cols / BLOCK, PADDED_BYTES, layout),
            Layout::ColMajor => Matrix::zeroed(rows / BLOCK, cols, PADDED_BYTES, layout),
        }
    }

    /// Whether this image stores padded bfp16 blocks.
    pub fn is_bfp16(&self) -> bool {
        self.elem_bytes == PADDED_BYTES
    }

    /// Read the block cell at `(i, j)` of the block-unit grid (for a
    /// row-major image `j` indexes blocks along the row; for column-major
    /// `i` indexes blocks down the column).
    pub fn get_bfp_block(&self, i: usize, j: usize) -> BfpBlock {
        debug_assert!(self.is_bfp16());
        let b = self.byte_index(i, j);
        debug_assert_eq!(b % 4, 0);
        BfpBlock::from_words(&self.data[b / 4..b / 4 + BLOCK_WORDS])
    }

    /// Write the block cell at `(i, j)` in the padded wire layout.
    pub fn set_bfp_block(&mut self, i: usize, j: usize, blk: BfpBlock) {
        debug_assert!(self.is_bfp16());
        let b = self.byte_index(i, j);
        debug_assert_eq!(b % 4, 0);
        self.data[b / 4..b / 4 + BLOCK_WORDS].copy_from_slice(&blk.to_words());
    }

    /// Words per storage row (the DMA row stride).
    pub fn row_words(&self) -> usize {
        match self.layout {
            Layout::RowMajor => self.cols * self.elem_bytes / 4,
            Layout::ColMajor => self.rows * self.elem_bytes / 4,
        }
    }

    /// Number of storage rows.
    pub fn n_storage_rows(&self) -> usize {
        match self.layout {
            Layout::RowMajor => self.rows,
            Layout::ColMajor => self.cols,
        }
    }

    fn byte_index(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.rows && j < self.cols, "({i},{j}) out of bounds");
        match self.layout {
            Layout::RowMajor => (i * self.cols + j) * self.elem_bytes,
            Layout::ColMajor => (j * self.rows + i) * self.elem_bytes,
        }
    }

    #[inline]
    pub fn get_byte(&self, b: usize) -> u8 {
        (self.data[b / 4] >> (8 * (b % 4))) as u8
    }

    #[inline]
    pub fn set_byte(&mut self, b: usize, v: u8) {
        let w = &mut self.data[b / 4];
        let sh = 8 * (b % 4);
        *w = (*w & !(0xFFu32 << sh)) | ((v as u32) << sh);
    }

    pub fn get_i8(&self, i: usize, j: usize) -> i8 {
        self.get_byte(self.byte_index(i, j)) as i8
    }

    pub fn set_i8(&mut self, i: usize, j: usize, v: i8) {
        let b = self.byte_index(i, j);
        self.set_byte(b, v as u8);
    }

    pub fn get_i16(&self, i: usize, j: usize) -> i16 {
        let b = self.byte_index(i, j);
        i16::from_le_bytes([self.get_byte(b), self.get_byte(b + 1)])
    }

    pub fn set_i16(&mut self, i: usize, j: usize, v: i16) {
        let b = self.byte_index(i, j);
        let [lo, hi] = v.to_le_bytes();
        self.set_byte(b, lo);
        self.set_byte(b + 1, hi);
    }

    pub fn get_i32(&self, i: usize, j: usize) -> i32 {
        let b = self.byte_index(i, j);
        debug_assert_eq!(b % 4, 0);
        self.data[b / 4] as i32
    }

    pub fn set_i32(&mut self, i: usize, j: usize, v: i32) {
        let b = self.byte_index(i, j);
        debug_assert_eq!(b % 4, 0);
        self.data[b / 4] = v as u32;
    }

    /// Read an f32 element (4-byte, word-aligned image — the
    /// `fp32_split` logical dtype's operand/result format).
    pub fn get_f32(&self, i: usize, j: usize) -> f32 {
        let b = self.byte_index(i, j);
        debug_assert_eq!(b % 4, 0);
        f32::from_bits(self.data[b / 4])
    }

    pub fn set_f32(&mut self, i: usize, j: usize, v: f32) {
        let b = self.byte_index(i, j);
        debug_assert_eq!(b % 4, 0);
        self.data[b / 4] = v.to_bits();
    }

    pub fn get_bf16(&self, i: usize, j: usize) -> Bf16 {
        Bf16::from_bits(self.get_i16(i, j) as u16)
    }

    pub fn set_bf16(&mut self, i: usize, j: usize, v: Bf16) {
        self.set_i16(i, j, v.to_bits() as i16);
    }

    /// Unpack storage row `sr` into i8 elements — word-at-a-time (LE
    /// within words, exactly [`Self::get_byte`]'s order), not per-element.
    fn unpack_storage_row_i8(&self, sr: usize, out: &mut [i8]) {
        let w0 = sr * self.row_words();
        for (wi, chunk) in out.chunks_mut(4).enumerate() {
            let w = self.data[w0 + wi];
            for (bi, o) in chunk.iter_mut().enumerate() {
                *o = (w >> (8 * bi)) as u8 as i8;
            }
        }
    }

    /// Unpack storage row `sr` of a bf16 image into widened f32 elements.
    fn unpack_storage_row_f32(&self, sr: usize, out: &mut [f32]) {
        let w0 = sr * self.row_words();
        for (wi, pair) in out.chunks_mut(2).enumerate() {
            let w = self.data[w0 + wi];
            pair[0] = Bf16::from_bits(w as u16).to_f32();
            pair[1] = Bf16::from_bits((w >> 16) as u16).to_f32();
        }
    }

    /// Row `i` of a row-major int8 image as a dense slice
    /// (`out.len() == cols`) — the hot-loop replacement for per-element
    /// `get_i8` walks.
    pub fn row_i8(&self, i: usize, out: &mut [i8]) {
        debug_assert!(self.layout == Layout::RowMajor && self.elem_bytes == 1);
        debug_assert_eq!(out.len(), self.cols);
        self.unpack_storage_row_i8(i, out);
    }

    /// Row `i` of a row-major bf16 image, widened to f32
    /// (`out.len() == cols`).
    pub fn row_bf16(&self, i: usize, out: &mut [f32]) {
        debug_assert!(self.layout == Layout::RowMajor && self.elem_bytes == 2);
        debug_assert_eq!(out.len(), self.cols);
        self.unpack_storage_row_f32(i, out);
    }

    /// Column `j` of a column-major int8 image (its contiguous storage
    /// row) — the packed panel view of a col-major B operand.
    pub fn col_i8(&self, j: usize, out: &mut [i8]) {
        debug_assert!(self.layout == Layout::ColMajor && self.elem_bytes == 1);
        debug_assert_eq!(out.len(), self.rows);
        self.unpack_storage_row_i8(j, out);
    }

    /// Column `j` of a column-major bf16 image, widened to f32.
    pub fn col_bf16(&self, j: usize, out: &mut [f32]) {
        debug_assert!(self.layout == Layout::ColMajor && self.elem_bytes == 2);
        debug_assert_eq!(out.len(), self.rows);
        self.unpack_storage_row_f32(j, out);
    }

    /// Dense logical-row-major i8 copy of the whole image (packs either
    /// storage layout) — the packed-operand form of the reference GEMM.
    pub fn packed_i8(&self) -> Vec<i8> {
        debug_assert_eq!(self.elem_bytes, 1);
        let mut out = vec![0i8; self.rows * self.cols];
        match self.layout {
            Layout::RowMajor => {
                for i in 0..self.rows {
                    self.row_i8(i, &mut out[i * self.cols..(i + 1) * self.cols]);
                }
            }
            Layout::ColMajor => {
                let mut col = vec![0i8; self.rows];
                for j in 0..self.cols {
                    self.col_i8(j, &mut col);
                    for (i, &v) in col.iter().enumerate() {
                        out[i * self.cols + j] = v;
                    }
                }
            }
        }
        out
    }

    /// Dense logical-row-major f32 copy of a bf16 image (either layout).
    pub fn packed_f32(&self) -> Vec<f32> {
        debug_assert_eq!(self.elem_bytes, 2);
        let mut out = vec![0f32; self.rows * self.cols];
        match self.layout {
            Layout::RowMajor => {
                for i in 0..self.rows {
                    self.row_bf16(i, &mut out[i * self.cols..(i + 1) * self.cols]);
                }
            }
            Layout::ColMajor => {
                let mut col = vec![0f32; self.rows];
                for j in 0..self.cols {
                    self.col_bf16(j, &mut col);
                    for (i, &v) in col.iter().enumerate() {
                        out[i * self.cols + j] = v;
                    }
                }
            }
        }
        out
    }
}

/// On-chip buffer allocator for one tile's memory (L1 or L2): bump
/// allocation with capacity accounting — enough to prove the paper's
/// designs fit and to catch regressions in the functional executor.
#[derive(Debug)]
pub struct TileAlloc {
    pub capacity: usize,
    used: usize,
    labels: Vec<(String, usize)>,
}

impl TileAlloc {
    pub fn new(capacity: usize) -> Self {
        TileAlloc { capacity, used: 0, labels: Vec::new() }
    }

    /// Reserve `bytes`; errors when the tile overflows.
    pub fn alloc(&mut self, label: &str, bytes: usize) -> Result<usize> {
        if self.used + bytes > self.capacity {
            bail!(
                "{label}: {} + {bytes} B exceeds tile capacity {} B \
                 (allocations: {:?})",
                self.used,
                self.capacity,
                self.labels
            );
        }
        let offset = self.used;
        self.used += bytes;
        self.labels.push((label.to_string(), bytes));
        Ok(offset)
    }

    pub fn used(&self) -> usize {
        self.used
    }

    pub fn utilization(&self) -> f64 {
        self.used as f64 / self.capacity as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Precision;
    use crate::util::prop::prop_check;

    #[test]
    fn row_major_element_access() {
        let mut m = Matrix::zeroed(4, 8, 1, Layout::RowMajor).unwrap();
        m.set_i8(2, 3, -5);
        m.set_i8(0, 0, 127);
        m.set_i8(3, 7, -128);
        assert_eq!(m.get_i8(2, 3), -5);
        assert_eq!(m.get_i8(0, 0), 127);
        assert_eq!(m.get_i8(3, 7), -128);
        assert_eq!(m.get_i8(1, 1), 0);
        assert_eq!(m.row_words(), 2);
    }

    #[test]
    fn col_major_storage_is_transposed_rowmajor() {
        let mut m = Matrix::zeroed(4, 8, 1, Layout::ColMajor).unwrap();
        m.set_i8(1, 2, 42);
        // Element (1,2) lives at byte 2*4+1 = 9.
        assert_eq!(m.get_byte(9), 42);
        assert_eq!(m.row_words(), 1); // 4 elems * 1 B per storage row
        assert_eq!(m.n_storage_rows(), 8);
    }

    #[test]
    fn i16_i32_bf16_roundtrip() {
        let mut m = Matrix::zeroed(2, 4, 2, Layout::RowMajor).unwrap();
        m.set_i16(1, 3, -12345);
        assert_eq!(m.get_i16(1, 3), -12345);
        m.set_bf16(0, 1, Bf16::from_f32(1.5));
        assert_eq!(m.get_bf16(0, 1).to_f32(), 1.5);

        let mut w = Matrix::zeroed(2, 2, 4, Layout::RowMajor).unwrap();
        w.set_i32(1, 1, i32::MIN);
        assert_eq!(w.get_i32(1, 1), i32::MIN);
    }

    #[test]
    fn f32_roundtrip_bitexact() {
        let mut m = Matrix::zeroed(2, 4, 4, Layout::RowMajor).unwrap();
        for (idx, v) in
            [1.5f32, -0.0, f32::MIN_POSITIVE / 2.0, 3.4e38, -1.0e-40, f32::INFINITY]
                .into_iter()
                .enumerate()
        {
            m.set_f32(idx / 4, idx % 4, v);
            assert_eq!(m.get_f32(idx / 4, idx % 4).to_bits(), v.to_bits(), "{v}");
        }
        let mut c = Matrix::zeroed(4, 2, 4, Layout::ColMajor).unwrap();
        c.set_f32(3, 1, -2.75);
        assert_eq!(c.get_f32(3, 1), -2.75);
        assert_eq!(c.get_f32(0, 0), 0.0);
    }

    #[test]
    fn alignment_rejected() {
        assert!(Matrix::zeroed(3, 3, 1, Layout::RowMajor).is_err());
        assert!(Matrix::zeroed(4, 6, 1, Layout::RowMajor).is_err()); // 6B rows
        assert!(Matrix::zeroed(6, 4, 1, Layout::ColMajor).is_err()); // 6B cols
    }

    #[test]
    fn element_access_never_aliases() {
        prop_check("matrix set/get isolation", 30, |rng| {
            let rows = 4 * (1 + rng.below(3));
            let cols = 4 * (1 + rng.below(3));
            let mut m = Matrix::zeroed(rows, cols, 1, Layout::RowMajor).unwrap();
            let mut shadow = vec![0i8; rows * cols];
            for _ in 0..64 {
                let i = rng.below(rows);
                let j = rng.below(cols);
                let v = rng.i8();
                m.set_i8(i, j, v);
                shadow[i * cols + j] = v;
            }
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(m.get_i8(i, j), shadow[i * cols + j], "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn row_and_col_slices_match_element_accessors() {
        prop_check("row/col slice views ≡ get_*", 20, |rng| {
            let rows = 4 * (1 + rng.below(3));
            let cols = 4 * (1 + rng.below(3));
            for layout in [Layout::RowMajor, Layout::ColMajor] {
                let mut m = Matrix::zeroed(rows, cols, 1, layout).unwrap();
                for i in 0..rows {
                    for j in 0..cols {
                        m.set_i8(i, j, rng.i8());
                    }
                }
                let packed = m.packed_i8();
                for i in 0..rows {
                    for j in 0..cols {
                        assert_eq!(packed[i * cols + j], m.get_i8(i, j), "({i},{j})");
                    }
                }
                match layout {
                    Layout::RowMajor => {
                        let mut row = vec![0i8; cols];
                        m.row_i8(rows - 1, &mut row);
                        assert_eq!(row, packed[(rows - 1) * cols..].to_vec());
                    }
                    Layout::ColMajor => {
                        let mut col = vec![0i8; rows];
                        m.col_i8(cols - 1, &mut col);
                        for (i, &v) in col.iter().enumerate() {
                            assert_eq!(v, m.get_i8(i, cols - 1));
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn bf16_slices_widen_exactly() {
        for layout in [Layout::RowMajor, Layout::ColMajor] {
            let mut m = Matrix::zeroed(4, 4, 2, layout).unwrap();
            for i in 0..4 {
                for j in 0..4 {
                    m.set_bf16(i, j, Bf16::from_f32((i * 4 + j) as f32 - 7.5));
                }
            }
            let packed = m.packed_f32();
            for i in 0..4 {
                for j in 0..4 {
                    assert_eq!(packed[i * 4 + j], m.get_bf16(i, j).to_f32());
                }
            }
            let mut lane = vec![0f32; 4];
            match layout {
                Layout::RowMajor => m.row_bf16(2, &mut lane),
                Layout::ColMajor => m.col_bf16(2, &mut lane),
            }
            for (idx, &v) in lane.iter().enumerate() {
                let want = match layout {
                    Layout::RowMajor => m.get_bf16(2, idx),
                    Layout::ColMajor => m.get_bf16(idx, 2),
                };
                assert_eq!(v, want.to_f32());
            }
        }
    }

    #[test]
    fn tile_alloc_capacity() {
        let spec = crate::arch::Generation::Xdna.spec();
        let mut l1 = TileAlloc::new(spec.l1_budget());
        // The paper's balanced XDNA int8-int8 kernel fits with double A/B.
        let p = Precision::I8I8;
        let (m, k, n) = (112, 112, 112);
        for label in ["a0", "a1"] {
            l1.alloc(label, m * k * p.ty_in()).unwrap();
        }
        for label in ["b0", "b1"] {
            l1.alloc(label, k * n * p.ty_in()).unwrap();
        }
        l1.alloc("c", m * n * p.ty_out()).unwrap();
        assert!(l1.utilization() > 0.9);
        // No room for a second C buffer (Sec. 5.3.2).
        assert!(l1.alloc("c2", m * n * p.ty_out()).is_err());
    }
}
