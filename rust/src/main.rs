//! `xdna-gemm` CLI — leader entrypoint for the reproduction harness.
//!
//! Subcommands regenerate every paper artifact (DESIGN.md §4) and drive
//! the coordinator/optimizer interactively:
//!
//! ```text
//! xdna-gemm table1 [--gen xdna|xdna2]        Table 1 (single-core kernels)
//! xdna-gemm table2                            Table 2 (XDNA balanced)
//! xdna-gemm table3                            Table 3 (XDNA2 balanced)
//! xdna-gemm fig6                              Fig. 6 (k_mt sweeps)
//! xdna-gemm fig7 [--points N]                 Fig. 7 (XDNA rooflines)
//! xdna-gemm fig8 [--points N]                 Fig. 8 (XDNA2 rooflines)
//! xdna-gemm ablations [--which a1|a2|a3|a4]   Sec. 5.2.2 / 5.3.x studies
//! xdna-gemm optimize --gen G --precision P    run the balanced search
//! xdna-gemm simulate --gen G --precision P --m M --k K --n N [--rowmajor-b]
//! xdna-gemm serve --requests N [--devices D] [--mix xdna:xdna2] [--gen G]
//!                 [--window W] [--in-flight F] [--skew | --trace FILE]
//!                 [--threads T --functional]
//!                 [--tenants NAME[:PRIO[:QUOTA]],...]
//!                 [--chaos SEED [--chaos-events E] [--chaos-horizon H]
//!                  [--chaos-corrupt C]]
//!                 [--integrity off|abft|full] [--integrity-retries R]
//!                 [--trace-out T.json] [--metrics-out M.prom] [--json]
//!                                             sharded coordinator load demo
//!                                             (multi-tenant admission,
//!                                             seeded fault injection,
//!                                             checksum-verified results, and
//!                                             the flight recorder's Perfetto
//!                                             trace / Prometheus metrics,
//!                                             docs/serving.md,
//!                                             docs/observability.md)
//! xdna-gemm serve-llm [--sessions S] [--rate R] [--decode-min A] [--decode-max B]
//!                 [--seed SEED] [--devices D] [--mix xdna:xdna2] [--gen G]
//!                 [--no-coalesce] [--max-batch M] [--precision P]
//!                 [--seq S] [--layers L] [--d-model D] [--d-ffn F] [--vocab V]
//!                 [--chaos SEED [--chaos-events E] [--chaos-horizon H]
//!                  [--chaos-corrupt C]]
//!                 [--integrity off|abft|full] [--integrity-retries R]
//!                 [--trace-out T.json] [--metrics-out M.prom] [--json]
//!                                             continuous-batching LLM serving:
//!                                             prefill chains (wide designs) +
//!                                             coalesced decode rounds (skinny
//!                                             designs), p50/p99 token latency
//!                                             under open-loop Poisson load
//!                                             (docs/serving.md)
//! xdna-gemm exec [--gen G] [--precision P] [--m M] [--k K] [--n N]
//!                [--threads T] [--iters I] [--rowmajor-b] [--bdchain]
//!                [--no-pack]                  packed functional executor timing
//! xdna-gemm plan [--gen G] [--precision P] [--seq S] [--layers L]
//!                [--mixed] [--serve] [--devices D] [--json]
//!                                             chain planner: fused vs isolated
//! xdna-gemm compile [--graph FILE.json | --workload attention|moe|transformer]
//!                   [--gen G] [--devices D] [--mix xdna:xdna2] [--budget B]
//!                   [--precision P] [--seq S] [--layers L] [--d-model D]
//!                   [--d-ffn F] [--vocab V] [--experts E] [--json]
//!                   [--serve] [--functional] [--threads T]
//!                   [--trace-out T.json] [--metrics-out M.prom]
//!                                             graph compiler: DAG → assigned,
//!                                             lowered, fleet-partitioned plan
//!                                             (docs/graphs.md)
//! xdna-gemm artifacts [--dir artifacts]       list + smoke the AOT bundle
//! ```
//!
//! `--precision` accepts `i8i8|i8i16|i8i32|bf16|bfp16` everywhere; `bfp16`
//! is the native block-FP path (XDNA2 datapath rate, DESIGN.md §10) and
//! requires column-major B. `fp32_split` is the *logical* Ozaki-split
//! precision (DESIGN.md §15): `compile`/`exec` accept it (graph lowering
//! expands it to bf16 limb GEMMs; `exec` runs the accuracy/cost demo),
//! while the dispatch-layer paths (`simulate`, traces) reject it with a
//! typed error.

use anyhow::{bail, Result};

use xdna_gemm::arch::Generation;
use xdna_gemm::coordinator::{
    expand_mix, parse_integrity, parse_mix, parse_tenants, Backend, CoordinatorOptions, FaultPlan,
};
use xdna_gemm::dtype::{Layout, Precision};
use xdna_gemm::gemm::exec::{ExecOptions, Fidelity};
use xdna_gemm::harness;
use xdna_gemm::optimizer::{optimize_balanced, BalancedOptions};
use xdna_gemm::sim::{simulate_gemm, BdMode};
use xdna_gemm::util::cli::Args;
use xdna_gemm::workload::TransformerConfig;

const USAGE: &str = "usage: xdna-gemm <table1|table2|table3|fig6|fig7|fig8|ablations|optimize|\
                     simulate|exec|serve|serve-llm|plan|compile|artifacts> [options]";

fn main() -> Result<()> {
    let args = Args::from_env();
    let sub = args.subcommand_required(USAGE)?;
    match sub {
        "table1" => {
            let gen = args.get("gen").map(parse_gen).transpose()?;
            harness::table1(gen).print();
        }
        "table2" => {
            let t = harness::table23(Generation::Xdna);
            t.print();
            t.save_csv("table2")?;
        }
        "table3" => {
            let t = harness::table23(Generation::Xdna2);
            t.print();
            t.save_csv("table3")?;
        }
        "fig6" => {
            for (s, paper) in harness::fig6() {
                println!("{}", s.to_ascii(60, 12));
                println!("paper saturated TOPS: {paper:.2}  model max: {:.2}\n", s.max_y());
                s.save_csv(&format!("fig6_{}", s.name.replace([' ', '/'], "_")))?;
            }
        }
        "fig7" | "fig8" => {
            let gen = if sub == "fig7" { Generation::Xdna } else { Generation::Xdna2 };
            let points = args.usize_opt("points", 400)?;
            run_roofline(gen, points)?;
        }
        "ablations" => {
            let which = args.get("which").unwrap_or("all");
            if matches!(which, "a1" | "all") {
                harness::ablation_baseline().print();
            }
            if matches!(which, "a2" | "all") {
                harness::ablation_reconfig(Generation::Xdna2).print();
            }
            if matches!(which, "a3" | "all") {
                harness::ablation_cbuffer().print();
            }
            if matches!(which, "a4" | "all") {
                harness::ablation_bd_overlap().print();
            }
        }
        "optimize" => {
            let gen = parse_gen(args.get("gen").unwrap_or("xdna2"))?;
            let p = parse_precision(args.get("precision").unwrap_or("i8i16"))?;
            let res = optimize_balanced(gen, p, &BalancedOptions::default())?;
            println!("balanced search for {gen}/{p}:");
            for h in &res.history {
                println!(
                    "  kernel {:>12} k_mt {:>5} → {:>6.2} TOPS  [{}]",
                    h.cfg.kernel.label(),
                    h.cfg.k_mt,
                    h.tops,
                    if h.memory_bound { "memory-bound" } else { "compute-bound" }
                );
            }
            println!(
                "winner: {} k_mt={} → {:.2} TOPS at {}x{}x{}",
                res.winner.kernel.label(),
                res.winner.k_mt,
                res.winner_report.tops,
                res.eval.0,
                res.eval.1,
                res.eval.2
            );
        }
        "simulate" => {
            let gen = parse_gen(args.get("gen").unwrap_or("xdna2"))?;
            let p = parse_precision(args.get("precision").unwrap_or("i8i8"))?;
            if p == Precision::Fp32Split {
                bail!(
                    "fp32_split is a logical precision with no single-dispatch schedule; \
                     use `compile --precision fp32_split` (graph lowering) or \
                     `exec --precision fp32_split` (accuracy/cost demo)"
                );
            }
            let m = args.usize_opt("m", 4096)?;
            let k = args.usize_opt("k", 4096)?;
            let n = args.usize_opt("n", 4096)?;
            let mut cfg = xdna_gemm::arch::balanced_config(gen, p);
            if args.flag("rowmajor-b") {
                if p == Precision::Bfp16 {
                    bail!("--rowmajor-b is invalid for bfp16 (blocks run along K)");
                }
                cfg = cfg.with_b_layout(Layout::RowMajor);
            }
            let mode =
                if args.flag("sequential-bd") { BdMode::Sequential } else { BdMode::Overlapped };
            let r = simulate_gemm(&cfg, m, k, n, mode);
            println!("design: {}", cfg.label());
            println!("padded: {}x{}x{}", r.pm, r.pk, r.pn);
            println!(
                "phases: comp {:.3} ms | read {:.3} ms | write {:.3} ms | \
                 prologue {:.3} ms | bd-stall {:.3} ms | dispatch {:.3} ms",
                r.t_comp * 1e3,
                r.t_read * 1e3,
                r.t_write * 1e3,
                r.t_prologue * 1e3,
                r.t_stall * 1e3,
                r.t_dispatch * 1e3
            );
            println!(
                "total {:.3} ms → {:.2} TOPS ({:?}-bound, eff {:.3}, \
                 kernel {:.1} MACs/cyc, ARI {:.0})",
                r.t_total * 1e3,
                r.tops,
                r.bound,
                r.efficiency,
                r.kernel_macs_per_cycle,
                r.arithmetic_intensity
            );
            println!(
                "trace: mac {:.0} cyc | zero {:.0} | drain {:.0} | dma-idle {:.0} | util {:.1}%",
                r.trace.mac_cycles,
                r.trace.zero_cycles,
                r.trace.drain_cycles,
                r.trace.dma_idle_cycles,
                100.0 * r.trace.mac_utilization()
            );
        }
        "exec" => {
            // Drive the packed, parallel functional executor at a design
            // point and report wall-clock rates (DESIGN.md §9).
            let gen = parse_gen(args.get("gen").unwrap_or("xdna"))?;
            let p = parse_precision(args.get("precision").unwrap_or("i8i8"))?;
            if p == Precision::Fp32Split {
                // The logical Ozaki-split precision has no datapath
                // schedule; its exec demo reports recovered accuracy vs
                // the f64 oracle (against plain bf16) and the simulated
                // limb-dispatch cost on the bf16 design (DESIGN.md §15).
                let m = args.usize_opt("m", 256)?;
                let k = args.usize_opt("k", 768)?;
                let n = args.usize_opt("n", 768)?;
                let threads = args.usize_opt("threads", 1)?;
                run_fp32_split_demo(gen, m, k, n, threads)?;
                return Ok(());
            }
            let threads = args.usize_opt("threads", 1)?;
            let iters = args.usize_opt("iters", 3)?;
            let mut cfg = xdna_gemm::arch::balanced_config(gen, p);
            if args.flag("rowmajor-b") {
                if p == Precision::Bfp16 {
                    bail!("--rowmajor-b is invalid for bfp16 (blocks run along K)");
                }
                cfg = cfg.with_b_layout(Layout::RowMajor);
            }
            let (nm, nk, nn) = cfg.native();
            let m = args.usize_opt("m", nm)?;
            let k = args.usize_opt("k", nk)?;
            let n = args.usize_opt("n", nn)?;
            let opts = ExecOptions {
                fidelity: if args.flag("bdchain") { Fidelity::BdChain } else { Fidelity::Direct },
                threads,
                pack_reuse: !args.flag("no-pack"),
            };
            let perf = harness::functional_perf(&cfg, m, k, n, opts, iters)?;
            println!(
                "functional {m}x{k}x{n} on {} ({} threads, pack_reuse={}, {:?}):",
                cfg.label(),
                threads,
                opts.pack_reuse,
                opts.fidelity
            );
            println!(
                "  {:.3} ms/GEMM | {:.2} GEMM/s | {:.3} GB/s",
                perf.secs_per_gemm * 1e3,
                perf.gemms_per_s,
                perf.gb_per_s
            );
            if threads > 1 {
                let serial_opts = ExecOptions { threads: 1, ..opts };
                let serial = harness::functional_perf(&cfg, m, k, n, serial_opts, iters)?;
                println!(
                    "  speedup vs threads=1: {:.2}x ({:.3} ms serial)",
                    serial.secs_per_gemm / perf.secs_per_gemm,
                    serial.secs_per_gemm * 1e3
                );
            }
        }
        "serve" => {
            let gen = parse_gen(args.get("gen").unwrap_or("xdna2"))?;
            let n = args.usize_opt("requests", 64)?;
            let n_devices = args.usize_opt("devices", 1)?;
            if n_devices == 0 {
                bail!("--devices must be at least 1");
            }
            // `--mix xdna:xdna2` cycles generations across the fleet;
            // without it every device is `--gen`.
            let pattern = match args.get("mix") {
                Some(s) => parse_mix(s)?,
                None => vec![gen],
            };
            let devices = expand_mix(&pattern, n_devices);
            // `--tenants hi:2:8,lo` names tenant classes; requests are
            // round-robined across them by the harness. `--chaos SEED`
            // arms the deterministic fault-injection layer (ISSUE 6);
            // `--integrity abft` checksum-verifies every served result
            // and recomputes on mismatch (ISSUE 8).
            let tenants = match args.get("tenants") {
                Some(s) => parse_tenants(s)?,
                None => Vec::new(),
            };
            let chaos = parse_chaos(&args, devices.len())?;
            // `--trace-out t.json` arms the flight recorder (zero-cost
            // when absent) and writes a Perfetto-loadable Chrome trace;
            // `--metrics-out m.prom` writes Prometheus-text metrics.
            let recorder = if args.get("trace-out").is_some() {
                xdna_gemm::trace::Recorder::on()
            } else {
                xdna_gemm::trace::Recorder::Off
            };
            let device_gens = devices.clone();
            let opts = CoordinatorOptions {
                gen,
                devices,
                tenants,
                chaos,
                integrity: parse_integrity(args.get("integrity").unwrap_or("off"))?,
                max_integrity_retries: args.usize_opt("integrity-retries", 2)?,
                batch_window: args.usize_opt("window", 16)?,
                max_in_flight: args.usize_opt("in-flight", 64)?,
                // `--functional` runs real numerics through the packed
                // executor; `--threads` fans its output tiles out.
                backend: if args.flag("functional") {
                    Backend::Functional
                } else {
                    Backend::SimOnly
                },
                exec_threads: args.usize_opt("threads", 1)?,
                recorder: recorder.clone(),
                ..Default::default()
            };
            // Workload: a GGML-style trace file (`--trace shapes.txt`,
            // lines of `name M K N precision [layout]`), the skewed
            // mixed-design serving mix (`--skew`), or the built-in
            // transformer prefill.
            let trace = match args.get("trace") {
                Some(path) => {
                    xdna_gemm::workload::parse_trace(&std::fs::read_to_string(path)?)?
                }
                None if args.flag("skew") => xdna_gemm::workload::skewed_trace(n.max(1), 7),
                None => TransformerConfig::default().trace(),
            };
            let m = harness::serve_trace(opts, &trace, n)?;
            harness::write_trace_artifacts(
                &recorder,
                &device_gens,
                &m,
                None,
                args.get("trace-out"),
                args.get("metrics-out"),
            )?;
            if args.flag("json") {
                println!("{}", m.to_json().to_string_pretty());
            } else {
                println!("{}", m.summary());
            }
        }
        "serve-llm" => {
            use xdna_gemm::coordinator::LlmOptions;
            use xdna_gemm::workload::llm::LlmLoad;
            let gen = parse_gen(args.get("gen").unwrap_or("xdna2"))?;
            let n_devices = args.usize_opt("devices", 2)?;
            if n_devices == 0 {
                bail!("--devices must be at least 1");
            }
            let pattern = match args.get("mix") {
                Some(s) => parse_mix(s)?,
                None => vec![gen],
            };
            let devices = expand_mix(&pattern, n_devices);
            let p = parse_precision(args.get("precision").unwrap_or("i8i8"))?;
            let default_load = LlmLoad::default();
            let model = TransformerConfig {
                precision: p,
                seq: args.usize_opt("seq", default_load.model.seq)?,
                n_layers: args.usize_opt("layers", default_load.model.n_layers)?,
                d_model: args.usize_opt("d-model", default_load.model.d_model)?,
                d_ffn: args.usize_opt("d-ffn", default_load.model.d_ffn)?,
                vocab: args.usize_opt("vocab", default_load.model.vocab)?,
            };
            let load = LlmLoad {
                model,
                sessions: args.usize_opt("sessions", default_load.sessions)?,
                arrival_rate: args.f64_opt("rate", default_load.arrival_rate)?,
                decode_tokens: (
                    args.usize_opt("decode-min", default_load.decode_tokens.0)?,
                    args.usize_opt("decode-max", default_load.decode_tokens.1)?,
                ),
                seed: args.usize_opt("seed", default_load.seed as usize)? as u64,
            };
            if load.arrival_rate <= 0.0 {
                bail!("--rate must be positive");
            }
            if load.decode_tokens.0 < 1 || load.decode_tokens.1 < load.decode_tokens.0 {
                bail!("--decode-min/--decode-max must satisfy 1 <= min <= max");
            }
            let llm = LlmOptions {
                load,
                coalesce: !args.flag("no-coalesce"),
                max_batch: args.usize_opt("max-batch", LlmOptions::default().max_batch)?,
                ..Default::default()
            };
            // The chaos plan and integrity mode ride the coordinator the
            // LLM runtime serves through — `serve-llm --chaos SEED` used
            // to silently ignore the plan (ISSUE 8 satellite fix); token
            // conservation is still checked below.
            let chaos = parse_chaos(&args, devices.len())?;
            let recorder = if args.get("trace-out").is_some() {
                xdna_gemm::trace::Recorder::on()
            } else {
                xdna_gemm::trace::Recorder::Off
            };
            let device_gens = devices.clone();
            let opts = CoordinatorOptions {
                gen,
                devices,
                chaos,
                integrity: parse_integrity(args.get("integrity").unwrap_or("off"))?,
                max_integrity_retries: args.usize_opt("integrity-retries", 2)?,
                recorder: recorder.clone(),
                ..Default::default()
            };
            let (report, metrics) = harness::serve_llm(opts, &llm)?;
            harness::write_trace_artifacts(
                &recorder,
                &device_gens,
                &metrics,
                Some(&report),
                args.get("trace-out"),
                args.get("metrics-out"),
            )?;
            if args.flag("json") {
                let doc = xdna_gemm::util::json::obj(vec![
                    ("llm", report.to_json()),
                    ("fleet", metrics.to_json()),
                ]);
                println!("{}", doc.to_string_pretty());
            } else {
                println!("{}", report.summary());
            }
            if !report.conserved() {
                bail!("token conservation violated: {report:?}");
            }
            if !args.flag("json") {
                println!("{}", metrics.summary());
            }
        }
        "plan" => {
            let gen = parse_gen(args.get("gen").unwrap_or("xdna2"))?;
            let p = parse_precision(args.get("precision").unwrap_or("i8i8"))?;
            let cfg = TransformerConfig {
                precision: p,
                seq: args.usize_opt("seq", 512)?,
                n_layers: args.usize_opt("layers", 12)?,
                d_model: args.usize_opt("d-model", 768)?,
                d_ffn: args.usize_opt("d-ffn", 3072)?,
                vocab: args.usize_opt("vocab", 50257)?,
            };
            // --mixed interleaves a bf16 copy of every layer chain so
            // the isolated baseline reconfigures on each precision flip
            // and the planner's design grouping becomes visible.
            let chains = if args.flag("mixed") && p != Precision::Bf16 {
                xdna_gemm::plan::mixed_transformer_chains(&cfg, Precision::Bf16)
            } else {
                xdna_gemm::plan::transformer_chains(&cfg)
            };
            let planner = xdna_gemm::plan::Planner::new(gen);
            let fused =
                xdna_gemm::plan::evaluate(&planner.plan(&chains), BdMode::Overlapped);
            let isolated = xdna_gemm::plan::evaluate(
                &planner.plan_isolated(&chains),
                BdMode::Overlapped,
            );
            if args.flag("json") {
                if args.flag("serve") {
                    bail!("--json and --serve are mutually exclusive (run them separately)");
                }
                // Machine-readable PlanReport pair (scripts/bench.sh
                // consumes this instead of scraping the summary lines).
                let doc = xdna_gemm::util::json::obj(vec![
                    ("command", xdna_gemm::util::json::s("plan")),
                    ("gen", xdna_gemm::util::json::s(gen.name())),
                    ("precision", xdna_gemm::util::json::s(p.name())),
                    ("chains", xdna_gemm::util::json::num(chains.len() as f64)),
                    ("isolated", isolated.to_json()),
                    ("chained", fused.to_json()),
                    (
                        "speedup",
                        xdna_gemm::util::json::num(fused.speedup_over(&isolated)),
                    ),
                ]);
                println!("{}", doc.to_string_pretty());
                return Ok(());
            }
            println!(
                "chain plan for {gen}/{}: {} chains over seq={} d={} ffn={} x{} layers",
                p.paper_name(),
                chains.len(),
                cfg.seq,
                cfg.d_model,
                cfg.d_ffn,
                cfg.n_layers
            );
            println!("isolated: {}", isolated.summary());
            println!("chained:  {}", fused.summary());
            println!(
                "savings: dispatch {:.3} ms | reconfig {:.3} ms | DRAM {:.1} MB \
                 ({:.3} ms steady) → {:.2}x speedup",
                (isolated.t_dispatch - fused.t_dispatch) * 1e3,
                (isolated.t_reconfig - fused.t_reconfig) * 1e3,
                (isolated.dram_bytes - fused.dram_bytes) / 1e6,
                (isolated.t_steady - fused.t_steady) * 1e3,
                fused.speedup_over(&isolated)
            );
            if args.flag("serve") {
                let n_devices = args.usize_opt("devices", 2)?;
                let opts = CoordinatorOptions::fleet(vec![gen; n_devices.max(1)]);
                let m = harness::serve_chains(opts, &chains)?;
                println!("\nserved through the coordinator fleet:\n{}", m.summary());
            }
        }
        "compile" => {
            use xdna_gemm::graph::{self, AssignOptions, ModelGraph, PartitionOptions};
            use xdna_gemm::util::json::{num, obj, s};
            let gen = parse_gen(args.get("gen").unwrap_or("xdna2"))?;
            let n_devices = args.usize_opt("devices", 2)?.max(1);
            let pattern = match args.get("mix") {
                Some(m) => parse_mix(m)?,
                None => vec![gen],
            };
            let fleet = expand_mix(&pattern, n_devices);
            let p = parse_precision(args.get("precision").unwrap_or("i8i8"))?;
            let cfg = TransformerConfig {
                precision: p,
                seq: args.usize_opt("seq", 512)?,
                n_layers: args.usize_opt("layers", 1)?,
                d_model: args.usize_opt("d-model", 768)?,
                d_ffn: args.usize_opt("d-ffn", 3072)?,
                vocab: args.usize_opt("vocab", 50257)?,
            };
            let g = match args.get("graph") {
                Some(path) => ModelGraph::from_json_str(&std::fs::read_to_string(path)?)?,
                None => match args.get("workload").unwrap_or("attention") {
                    "attention" => graph::attention_graph(&cfg)?,
                    "moe" => graph::moe_graph(
                        cfg.seq,
                        cfg.d_model,
                        cfg.d_ffn,
                        args.usize_opt("experts", 4)?,
                        p,
                    )?,
                    "transformer" => graph::transformer_graph(&cfg),
                    other => bail!("unknown workload '{other}' (attention|moe|transformer)"),
                },
            };
            let budget = args.f64_opt("budget", 1.0)?;
            let assigned = graph::assign(
                &g,
                &AssignOptions { budget_per_node: budget, fleet: fleet.clone() },
            )?;
            let low = graph::lower(&assigned.graph);
            let part =
                graph::partition(&assigned.graph, &low, &PartitionOptions::fleet(fleet.clone()));
            let iso = graph::partition(
                &assigned.graph,
                &graph::isolate(&assigned.graph),
                &PartitionOptions::fleet(fleet.clone()),
            );
            let single = graph::partition(
                &assigned.graph,
                &low,
                &PartitionOptions::fleet(vec![fleet[0]]),
            );
            let vs_isolated = iso.makespan_s / part.makespan_s;
            let vs_single = single.makespan_s / part.makespan_s;
            if args.flag("json") {
                if args.flag("serve") {
                    bail!("--json and --serve are mutually exclusive (run them separately)");
                }
                // The lowered chains also get the chain planner's
                // single-device PlanReport (same schema as `plan --json`).
                let planner = xdna_gemm::plan::Planner::new(fleet[0]);
                let chained = xdna_gemm::plan::evaluate(
                    &planner.plan(&low.chains),
                    BdMode::Overlapped,
                );
                let doc = obj(vec![
                    ("command", s("compile")),
                    ("graph", assigned.graph.to_json()),
                    ("assignment", assigned.to_json()),
                    ("lowered", low.to_json()),
                    ("plan_report_single_device", chained.to_json()),
                    ("partition", part.to_json()),
                    (
                        "baselines",
                        obj(vec![
                            ("isolated_makespan_s", num(iso.makespan_s)),
                            ("single_device_makespan_s", num(single.makespan_s)),
                        ]),
                    ),
                    ("speedup_vs_isolated", num(vs_isolated)),
                    ("speedup_vs_single_device", num(vs_single)),
                ]);
                println!("{}", doc.to_string_pretty());
                return Ok(());
            }
            println!(
                "graph '{}': {} nodes, {} edges ({} fan-outs, {} joins), {:.2} GMACs",
                assigned.graph.name,
                assigned.graph.len(),
                assigned.graph.edges(),
                assigned.graph.fan_outs(),
                assigned.graph.joins(),
                assigned.graph.total_ops() / 2e9
            );
            println!(
                "assignment: budget {:.2} err units, spent {:.2} | est {:.3} ms isolated-sum",
                assigned.err_budget,
                assigned.err_spent,
                assigned.est_s * 1e3
            );
            for (node, choice) in assigned.graph.nodes().iter().zip(&assigned.choices) {
                println!(
                    "  {:<16} {:>6} on {:<5} est {:>8.3} ms",
                    node.shape.name,
                    node.shape.precision.to_string(),
                    choice.gen.name(),
                    choice.est_s * 1e3
                );
            }
            println!(
                "lowered: {} chains ({} chainable edges), {} staged cross-chain tensors",
                low.chains.len(),
                low.chain_edges(),
                low.staged.len()
            );
            let fleet_names: Vec<&str> = fleet.iter().map(|d| d.name()).collect();
            println!("partition on [{}]:", fleet_names.join(", "));
            for sc in &part.schedule {
                println!(
                    "  dev{} {:<24} start {:>8.3} ms  xfer {:>6.3} ms  exec {:>8.3} ms  \
                     finish {:>8.3} ms",
                    sc.device,
                    low.chains[sc.chain].name,
                    sc.start_s * 1e3,
                    sc.xfer_s * 1e3,
                    sc.exec_s * 1e3,
                    sc.finish_s * 1e3
                );
            }
            println!(
                "makespan {:.3} ms (critical path {:.3} ms, serial {:.3} ms) | \
                 isolated {:.3} ms → {vs_isolated:.2}x | single-device {:.3} ms → {vs_single:.2}x",
                part.makespan_s * 1e3,
                part.critical_path_s * 1e3,
                part.serial_s * 1e3,
                iso.makespan_s * 1e3,
                single.makespan_s * 1e3
            );
            if args.flag("serve") {
                let recorder = if args.get("trace-out").is_some() {
                    xdna_gemm::trace::Recorder::on()
                } else {
                    xdna_gemm::trace::Recorder::Off
                };
                let opts = CoordinatorOptions {
                    devices: fleet.clone(),
                    backend: if args.flag("functional") {
                        Backend::Functional
                    } else {
                        Backend::SimOnly
                    },
                    exec_threads: args.usize_opt("threads", 1)?,
                    recorder: recorder.clone(),
                    ..Default::default()
                };
                let coord = xdna_gemm::coordinator::Coordinator::start(opts);
                let responses = graph::serve_graph(
                    &coord,
                    &assigned.graph,
                    &low,
                    &part,
                    args.flag("functional"),
                )?;
                let staged: usize = responses.iter().map(|r| r.staged_edges).sum();
                let fused: usize = responses.iter().map(|r| r.fused_edges).sum();
                let m = coord.shutdown()?;
                harness::write_trace_artifacts(
                    &recorder,
                    &fleet,
                    &m,
                    None,
                    args.get("trace-out"),
                    args.get("metrics-out"),
                )?;
                println!(
                    "\nserved through the coordinator fleet ({} chains, {} staged tensors, \
                     {} fused edges):\n{}",
                    responses.len(),
                    staged,
                    fused,
                    m.summary()
                );
            }
        }
        "artifacts" => {
            let dir = args.get("dir").unwrap_or("artifacts");
            let mut rt = xdna_gemm::runtime::Runtime::load(dir)?;
            println!("platform: {}", rt.platform());
            for name in rt.artifact_names() {
                let meta = rt.meta(&name).unwrap().clone();
                print!("  {name}: {}x{}x{} {:?}", meta.m, meta.k, meta.n, meta.arg_dtypes);
                if args.flag("compile") {
                    rt.ensure_compiled(&name)?;
                    print!("  [compiled]");
                }
                println!();
            }
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
    Ok(())
}

fn run_roofline(gen: Generation, points: usize) -> Result<()> {
    let figname = if gen == Generation::Xdna { "fig7" } else { "fig8" };
    let precisions = [Precision::I8I8, Precision::I8I16, Precision::Bf16];
    for p in precisions {
        for layout in [Layout::ColMajor, Layout::RowMajor] {
            let s = harness::roofline(gen, p, layout, points);
            println!("{}", s.to_ascii(64, 10));
            println!("peak: {:.2} TOPS over {} points\n", s.max_y(), s.points.len());
            s.save_csv(&format!("{figname}_{}_{}", p.name(), layout.name()))?;
        }
        let (peak, gap) = harness::sweep_summary(gen, p, points.min(100));
        println!(
            "{gen} {}: up to {peak:.2} TOPS; col-major beats row-major by {gap:.1}% on average\n",
            p.paper_name()
        );
    }
    Ok(())
}

/// The shared `--chaos SEED [--chaos-events E] [--chaos-horizon H]
/// [--chaos-corrupt C]` flags, parsed into a seeded fault plan.
/// `--chaos-corrupt C` layers `C` silent result corruptions per device
/// on top of the base plan (detected and recovered under
/// `--integrity abft|full`, served corrupt under `--integrity off`).
fn parse_chaos(args: &Args, n_devices: usize) -> Result<Option<FaultPlan>> {
    let Some(s) = args.get("chaos") else { return Ok(None) };
    let seed: u64 =
        s.parse().map_err(|_| anyhow::anyhow!("--chaos expects a u64 seed, got '{s}'"))?;
    let horizon = args.usize_opt("chaos-horizon", 64)? as u64;
    let events = args.usize_opt("chaos-events", 4)?;
    let corrupt = args.usize_opt("chaos-corrupt", 0)?;
    let mut plan = FaultPlan::from_seed(seed, n_devices, horizon, events);
    if corrupt > 0 {
        plan = plan.with_corruption(seed, n_devices, horizon, corrupt);
    }
    Ok(Some(plan))
}

/// `exec --precision fp32_split`: accuracy-recovery + cost demo. Runs
/// the three-limb split GEMM and a plain bf16 GEMM over the same f32
/// operands, compares both against the f64 oracle, and prices the limb
/// dispatches on the generation's bf16 balanced design.
fn run_fp32_split_demo(gen: Generation, m: usize, k: usize, n: usize, threads: usize) -> Result<()> {
    use xdna_gemm::coordinator::functional_inputs;
    use xdna_gemm::dtype::Bf16;
    use xdna_gemm::dtype_split;
    use xdna_gemm::gemm::refimpl;
    use xdna_gemm::mem::Matrix;
    use xdna_gemm::workload::GemmShape;

    let shape = GemmShape::new("cli", m, k, n, Precision::Fp32Split);
    let (a, b) = functional_inputs(&shape, Precision::Fp32Split)?;
    let c = dtype_split::split_exec(&a, &b, threads.max(1))?;
    let oracle = dtype_split::gemm_f64(&a, &b);

    // Plain bf16 on the same operands: one rounding per input element.
    let quantize = |src: &Matrix| -> Result<Matrix> {
        let mut out = Matrix::zeroed(src.rows, src.cols, 2, src.layout)?;
        for i in 0..src.rows {
            for j in 0..src.cols {
                out.set_bf16(i, j, Bf16::from_f32(src.get_f32(i, j)));
            }
        }
        Ok(out)
    };
    let cb = refimpl::ref_gemm(&quantize(&a)?, &quantize(&b)?, Precision::Bf16)?;

    let mut err_split = 0f64;
    let mut err_bf16 = 0f64;
    for i in 0..m {
        for j in 0..n {
            let want = oracle[i * n + j];
            err_split = err_split.max((c.get_f32(i, j) as f64 - want).abs());
            err_bf16 = err_bf16.max((cb.get_bf16(i, j).to_f32() as f64 - want).abs());
        }
    }
    let bound = dtype_split::error_bound(k, 6.0, 6.0);
    let bf16_t =
        simulate_gemm(&xdna_gemm::arch::balanced_config(gen, Precision::Bf16), m, k, n, BdMode::Overlapped)
            .t_total;
    let split_t = bf16_t * dtype_split::LIMB_GEMMS as f64;
    println!(
        "fp32_split {m}x{k}x{n} on {gen} ({} bf16 limb GEMMs, {threads} threads):",
        dtype_split::LIMB_GEMMS
    );
    println!("  max |err| vs f64 oracle: split {err_split:.3e} | plain bf16 {err_bf16:.3e}");
    println!(
        "  recovery: {:.1}x tighter than bf16 (derived bound {bound:.3e})",
        err_bf16 / err_split.max(f64::MIN_POSITIVE)
    );
    println!(
        "  simulated device time: {:.3} ms vs bf16 {:.3} ms ({:.1}x, budget <= 4x)",
        split_t * 1e3,
        bf16_t * 1e3,
        split_t / bf16_t
    );
    Ok(())
}

fn parse_gen(s: &str) -> Result<Generation> {
    Generation::parse(s).ok_or_else(|| anyhow::anyhow!("unknown generation '{s}'"))
}

fn parse_precision(s: &str) -> Result<Precision> {
    Precision::parse(s).ok_or_else(|| anyhow::anyhow!("unknown precision '{s}'"))
}
