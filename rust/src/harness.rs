//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (the DESIGN.md §4 experiment index). Shared by the CLI
//! (`xdna-gemm table2 ...`) and the bench targets (`cargo bench`).
//!
//! Each function returns the paper-vs-reproduced rows; EXPERIMENTS.md
//! records a run of each.

use crate::arch::{balanced_config, Generation};
use crate::dtype::{Layout, Precision};
use crate::gemm::exec::{ExecOptions, Executor};
use crate::gemm::refimpl;
use crate::optimizer::{optimize_balanced, solve_single_core, BalancedOptions, IpOptions};
use crate::report::{Series, Table};
use crate::sim::{simulate_gemm, trace, BdMode};
use crate::tiling::{KernelTile, TilingConfig};
use crate::workload::roofline_sweep;

/// Paper values for Table 1 (single-core): kernel, MACs/cycle, L1 KB.
pub const TABLE1_PAPER: &[(Generation, Precision, (usize, usize, usize), f64, f64)] = &[
    (Generation::Xdna, Precision::I8I8, (64, 232, 64), 233.0, 62.0),
    (Generation::Xdna, Precision::I8I16, (64, 216, 64), 217.6, 62.0),
    (Generation::Xdna, Precision::I8I32, (48, 280, 48), 192.0, 61.5),
    (Generation::Xdna, Precision::Bf16, (64, 104, 64), 112.6, 60.0),
    (Generation::Xdna2, Precision::I8I8, (64, 232, 64), 450.6, 62.0),
    (Generation::Xdna2, Precision::I8I16, (64, 216, 64), 419.8, 62.0),
    (Generation::Xdna2, Precision::I8I32, (48, 280, 48), 384.0, 61.5),
    (Generation::Xdna2, Precision::Bf16, (48, 152, 48), 158.1, 61.5),
];

/// Paper values for Tables 2–3 (balanced designs, B col-major):
/// kernel, MACs/cycle, peak-comp TOPS, eval size, actual NPU TOPS.
pub type E2ERow = (
    Generation,
    Precision,
    (usize, usize, usize), // kernel
    f64,                   // paper MACs/cycle
    f64,                   // paper peak comp TOPS
    (usize, usize, usize), // eval GEMM size
    f64,                   // paper actual TOPS
);

pub const TABLE23_PAPER: &[E2ERow] = &[
    (Generation::Xdna, Precision::I8I8, (112, 112, 112), 212.5, 6.80, (4032, 4032, 4032), 6.52),
    (Generation::Xdna, Precision::I8I16, (96, 112, 96), 192.0, 6.14, (4224, 4032, 4224), 5.85),
    (Generation::Xdna, Precision::I8I32, (80, 88, 96), 146.0, 4.67, (4160, 4224, 4224), 4.42),
    (Generation::Xdna, Precision::Bf16, (96, 56, 96), 99.8, 3.19, (4224, 4032, 4224), 3.12),
    (Generation::Xdna2, Precision::I8I8, (144, 72, 144), 343.0, 39.52, (4032, 4320, 4608), 37.35),
    (Generation::Xdna2, Precision::I8I16, (128, 72, 112), 307.2, 35.39, (4096, 4320, 4480), 30.77),
    (Generation::Xdna2, Precision::I8I32, (96, 64, 96), 256.0, 29.49, (4224, 4224, 4608), 24.74),
    (Generation::Xdna2, Precision::Bf16, (112, 48, 96), 137.2, 15.81, (4032, 4224, 4608), 14.52),
];

/// T1 — Table 1: single-core kernels. For each (gen, precision): the IP's
/// winner and the paper's kernel side by side (model throughput, L1).
pub fn table1(gen_filter: Option<Generation>) -> Table {
    let mut t = Table::new(
        "Table 1 — single-core GEMM kernels (model vs paper)",
        &[
            "dev", "precision", "paper kernel", "paper MACs/cyc", "model MACs/cyc",
            "IP winner", "IP MACs/cyc", "L1 KB (paper)", "L1 KB (winner)",
        ],
    );
    for &(gen, p, (m, k, n), paper_mpc, paper_l1) in TABLE1_PAPER {
        if gen_filter.is_some_and(|g| g != gen) {
            continue;
        }
        let paper_tile = KernelTile::new(m, k, n);
        let prof = trace::profile_kernel(gen, p, &paper_tile);
        let winner = &solve_single_core(gen, p, &IpOptions::default(), 1)[0];
        t.row(vec![
            gen.to_string(),
            p.paper_name().to_string(),
            paper_tile.label(),
            format!("{paper_mpc:.1}"),
            format!("{:.1}", prof.macs_per_cycle),
            winner.tile.label(),
            format!("{:.1}", winner.macs_per_cycle),
            format!("{paper_l1:.1}"),
            format!("{:.1}", winner.l1_bytes as f64 / 1024.0),
        ]);
    }
    t
}

/// T2/T3 — Tables 2–3: balanced designs at the paper's exact sizes.
pub fn table23(gen: Generation) -> Table {
    let title = match gen {
        Generation::Xdna => "Table 2 — XDNA balanced designs (B col-major)",
        Generation::Xdna2 => "Table 3 — XDNA2 balanced designs (B col-major)",
    };
    let mut t = Table::new(
        title,
        &[
            "precision", "kernel", "m·n", "MACs/cyc (paper)", "MACs/cyc (model)",
            "peak TOPS (paper)", "peak TOPS (model)", "GEMM size",
            "actual TOPS (paper)", "actual TOPS (model)", "bound",
        ],
    );
    for &(g, p, kernel, paper_mpc, paper_peak, size, paper_tops) in TABLE23_PAPER {
        if g != gen {
            continue;
        }
        let cfg = balanced_config(gen, p);
        assert_eq!(
            (cfg.kernel.m_ct, cfg.kernel.k_ct, cfg.kernel.n_ct),
            kernel,
            "arch table drifted from harness table"
        );
        let r = simulate_gemm(&cfg, size.0, size.1, size.2, BdMode::Overlapped);
        t.row(vec![
            p.paper_name().to_string(),
            cfg.kernel.label(),
            format!("{:.1}K", cfg.kernel.out_elems() as f64 / 1024.0),
            format!("{paper_mpc:.1}"),
            format!("{:.1}", r.kernel_macs_per_cycle),
            format!("{paper_peak:.2}"),
            format!("{:.2}", r.peak_comp_tops),
            format!("{}x{}x{}", size.0, size.1, size.2),
            format!("{paper_tops:.2}"),
            format!("{:.2}", r.tops),
            format!("{:?}", r.bound),
        ]);
    }
    t
}

/// F6 — Fig. 6: TOPS vs k_mt for the two showcased kernels.
pub fn fig6() -> Vec<(Series, f64)> {
    let cases: [(Generation, Precision, (usize, usize, usize), f64); 2] = [
        // (gen, precision, eval size, paper saturated TOPS)
        (Generation::Xdna, Precision::Bf16, (4224, 4032, 4224), 3.12),
        (Generation::Xdna2, Precision::I8I16, (4096, 4320, 4480), 30.77),
    ];
    let mut out = Vec::new();
    for (gen, p, size, paper) in cases {
        let base = balanced_config(gen, p);
        let mut s = Series::new(
            &format!("Fig6 {gen} {} kernel {}", p.paper_name(), base.kernel.label()),
            "k_mt (elements)",
            "TOPS",
        );
        for mult in 1..=14 {
            let k_mt = base.kernel.k_ct * mult;
            let cfg = TilingConfig { k_mt, ..base };
            if cfg.validate().is_err() {
                break; // L2 capacity (incl. XDNA2 neighbor sharing)
            }
            let r = simulate_gemm(&cfg, size.0, size.1, size.2, BdMode::Overlapped);
            s.push(k_mt as f64, r.tops_padded);
        }
        out.push((s, paper));
    }
    out
}

/// F7/F8 — Figs. 7–8: roofline sweeps (>400 sizes ≤ 8K per precision and
/// B layout).
pub fn roofline(gen: Generation, p: Precision, layout: Layout, points: usize) -> Series {
    let cfg = balanced_config(gen, p).with_b_layout(layout);
    let mut s = Series::new(
        &format!("{gen} {} B-{}", p.paper_name(), layout.name()),
        "arithmetic intensity (ops/B)",
        "TOPS",
    );
    for (m, k, n) in roofline_sweep(&cfg, points, 8192, 0xF1C) {
        let r = simulate_gemm(&cfg, m, k, n, BdMode::Overlapped);
        s.push(r.arithmetic_intensity, r.tops);
    }
    s
}

/// Summary stats of one sweep (peak TOPS + col-vs-row gap) — the numbers
/// quoted in Sec. 5.2.3.
pub fn sweep_summary(gen: Generation, p: Precision, points: usize) -> (f64, f64) {
    let col = roofline(gen, p, Layout::ColMajor, points);
    let row = roofline(gen, p, Layout::RowMajor, points);
    let mean = |s: &Series| s.points.iter().map(|p| p.1).sum::<f64>() / s.points.len() as f64;
    let gap = 100.0 * (mean(&col) / mean(&row) - 1.0);
    (col.max_y(), gap)
}

/// A1 — Sec. 5.2.2 baseline: the non-optimized example [18] cannot stage
/// contiguous k_mt tiles (k_mt = k_ct).
pub fn ablation_baseline() -> Table {
    let mut t = Table::new(
        "A1 — optimized k_mt vs non-contiguous baseline [18] (Sec. 5.2.2)",
        &["dev", "precision", "baseline TOPS", "optimized TOPS", "speedup", "paper speedup"],
    );
    for (gen, p, size, paper_x) in [
        (Generation::Xdna, Precision::Bf16, (4224usize, 4032usize, 4224usize), 2.4),
        (Generation::Xdna2, Precision::I8I16, (4096, 4320, 4480), 3.6),
    ] {
        let tuned = balanced_config(gen, p);
        let baseline = TilingConfig { k_mt: tuned.kernel.k_ct, ..tuned };
        let r_base = simulate_gemm(&baseline, size.0, size.1, size.2, BdMode::Overlapped);
        let r_opt = simulate_gemm(&tuned, size.0, size.1, size.2, BdMode::Overlapped);
        t.row(vec![
            gen.to_string(),
            p.paper_name().to_string(),
            format!("{:.2}", r_base.tops),
            format!("{:.2}", r_opt.tops),
            format!("{:.2}x", r_opt.tops / r_base.tops),
            format!("{paper_x:.1}x"),
        ]);
    }
    t
}

/// A3 — Sec. 5.3.2: single vs double C buffering (re-optimized each way).
pub fn ablation_cbuffer() -> Table {
    let mut t = Table::new(
        "A3 — single vs double C buffer (Sec. 5.3.2)",
        &["dev", "precision", "single-C TOPS", "double-C TOPS", "gain", "paper gain"],
    );
    for (gen, p, paper_gain) in [
        (Generation::Xdna2, Precision::I8I16, "18%"),
        (Generation::Xdna, Precision::Bf16, "13%"),
    ] {
        let single = optimize_balanced(gen, p, &BalancedOptions::default()).unwrap();
        let dbl = optimize_balanced(
            gen,
            p,
            &BalancedOptions { c_double_buffered: true, ..Default::default() },
        )
        .unwrap();
        t.row(vec![
            gen.to_string(),
            p.paper_name().to_string(),
            format!("{:.2} ({})", single.winner_report.tops, single.winner.kernel.label()),
            format!("{:.2} ({})", dbl.winner_report.tops, dbl.winner.kernel.label()),
            format!("{:.0}%", 100.0 * (single.winner_report.tops / dbl.winner_report.tops - 1.0)),
            paper_gain.to_string(),
        ]);
    }
    t
}

/// A4 — Sec. 5.3.3: overlapped vs sequential BD reconfiguration.
pub fn ablation_bd_overlap() -> Table {
    let mut t = Table::new(
        "A4 — BD reconfiguration overlap (Sec. 5.3.3)",
        &["dev", "precision", "overlapped TOPS", "sequential TOPS", "drop", "paper drop"],
    );
    for (gen, size, paper) in [
        (Generation::Xdna2, (4096usize, 4320usize, 4480usize), "28%"),
        (Generation::Xdna, (4224, 4032, 4224), "27%"),
    ] {
        let cfg = balanced_config(gen, Precision::I8I16);
        let over = simulate_gemm(&cfg, size.0, size.1, size.2, BdMode::Overlapped);
        let seq = simulate_gemm(&cfg, size.0, size.1, size.2, BdMode::Sequential);
        t.row(vec![
            gen.to_string(),
            "int8-int16".to_string(),
            format!("{:.2}", over.tops),
            format!("{:.2}", seq.tops),
            format!("{:.0}%", 100.0 * (1.0 - seq.tops / over.tops)),
            paper.to_string(),
        ]);
    }
    t
}

/// A2 — Sec. 5.3.1: design reuse vs per-size reconfiguration on a
/// transformer trace.
pub fn ablation_reconfig(gen: Generation) -> Table {
    use crate::workload::TransformerConfig;
    let mut t = Table::new(
        "A2 — design reuse vs full reconfiguration per size (Sec. 5.3.1)",
        &["policy", "device time (ms)", "reconfig time (ms)", "sustained TOPS"],
    );
    let trace = TransformerConfig::default().trace();
    let cfg = balanced_config(gen, trace[0].precision);
    let mut total_gemm = 0.0;
    let mut ops = 0.0;
    for g in &trace {
        let r = simulate_gemm(&cfg, g.m, g.k, g.n, BdMode::Overlapped);
        total_gemm += r.t_total;
        ops += g.ops();
    }
    let reconfig = gen.spec().reconfig_s;
    let distinct = {
        let mut shapes: Vec<_> = trace.iter().map(|g| (g.m, g.k, g.n)).collect();
        shapes.sort();
        shapes.dedup();
        shapes.len()
    };
    // Policy A (the paper's): one design, cheap per-size parameter update.
    t.row(vec![
        "reuse design (paper)".into(),
        format!("{:.2}", (total_gemm + reconfig) * 1e3),
        format!("{:.2}", reconfig * 1e3),
        format!("{:.2}", ops / (total_gemm + reconfig) / 1e12),
    ]);
    // Policy B: a dedicated design per GEMM size → reconfigure on every
    // shape change (per-layer sequence alternates shapes).
    let switches = trace.len(); // consecutive layer GEMMs all differ in shape
    let t_b = total_gemm + switches as f64 * reconfig;
    t.row(vec![
        format!("reconfigure per size ({distinct} designs)"),
        format!("{:.2}", t_b * 1e3),
        format!("{:.2}", switches as f64 * reconfig * 1e3),
        format!("{:.2}", ops / t_b / 1e12),
    ]);
    t
}

/// One `functional_perf` measurement: the packed executor's wall-clock
/// rates at a design point (DESIGN.md §9).
#[derive(Clone, Copy, Debug)]
pub struct FunctionalPerf {
    pub secs_per_gemm: f64,
    pub gemms_per_s: f64,
    /// Effective DRAM-image traffic rate: (A + B + C) bytes per GEMM
    /// over the measured wall clock.
    pub gb_per_s: f64,
    pub threads: usize,
}

/// Time the functional executor end to end (packed panels + scoped-thread
/// fan-out) over `iters` GEMMs with deterministic random operands.
/// Shared by `xdna-gemm exec` and the `hotpath` bench artifact.
pub fn functional_perf(
    cfg: &TilingConfig,
    m: usize,
    k: usize,
    n: usize,
    opts: ExecOptions,
    iters: usize,
) -> crate::Result<FunctionalPerf> {
    let p = cfg.precision;
    let mut a = refimpl::input_matrix(m, k, p, Layout::RowMajor)?;
    let mut b = refimpl::input_matrix(k, n, p, cfg.b_layout)?;
    refimpl::fill_random(&mut a, p, 1);
    refimpl::fill_random(&mut b, p, 2);
    let exec = Executor::with_options(*cfg, opts);
    let iters = iters.max(1);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(exec.execute(&a, &b)?);
    }
    let secs = t0.elapsed().as_secs_f64() / iters as f64;
    let bytes = (p.bytes_in(m * k) + p.bytes_in(k * n) + p.bytes_out(m * n)) as f64;
    Ok(FunctionalPerf {
        secs_per_gemm: secs,
        gemms_per_s: 1.0 / secs,
        gb_per_s: bytes / secs / 1e9,
        threads: opts.threads,
    })
}

/// Drive a coordinator fleet over `trace` (cycled to `n` requests,
/// request names suffixed with their index) and return the final fleet
/// metrics after a drained shutdown. Shared by `xdna-gemm serve`, the
/// `serve` example, and the fleet integration tests (DESIGN.md §4).
pub fn serve_trace(
    opts: crate::coordinator::CoordinatorOptions,
    trace: &[crate::workload::GemmShape],
    n: usize,
) -> crate::Result<crate::coordinator::FleetMetrics> {
    use crate::coordinator::{Coordinator, GemmRequest};
    anyhow::ensure!(!trace.is_empty(), "empty trace");
    let n_tenants = opts.tenant_specs().len();
    let coord = Coordinator::start(opts);
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        let g = &trace[i % trace.len()];
        // Multi-tenant serving: spread the trace round-robin across the
        // configured tenants (tenant 0 when none were configured).
        rxs.push(coord.submit_for(
            i % n_tenants,
            GemmRequest::sim(crate::workload::GemmShape {
                name: format!("{}#{i}", g.name),
                ..g.clone()
            }),
        )?);
    }
    for rx in rxs {
        rx.recv()?;
    }
    coord.shutdown()
}

/// Drive a coordinator fleet over whole chains (chain affinity: each
/// chain lands on one leader, fused edges elide DRAM round-trips) and
/// return the final fleet metrics after a drained shutdown. Shared by
/// `xdna-gemm plan --serve`, the `chain` example, and the fleet tests.
pub fn serve_chains(
    opts: crate::coordinator::CoordinatorOptions,
    chains: &[crate::plan::GemmChain],
) -> crate::Result<crate::coordinator::FleetMetrics> {
    use crate::coordinator::Coordinator;
    anyhow::ensure!(chains.iter().any(|c| !c.is_empty()), "no non-empty chains");
    let n_tenants = opts.tenant_specs().len();
    let coord = Coordinator::start(opts);
    let mut rxs = Vec::with_capacity(chains.len());
    for (i, chain) in chains.iter().filter(|c| !c.is_empty()).enumerate() {
        rxs.push(coord.submit_chain_for(i % n_tenants, chain.clone())?);
    }
    for rx in rxs {
        rx.recv()?;
    }
    coord.shutdown()
}

/// Drive a coordinator fleet with the continuous-batching LLM serving
/// runtime (DESIGN.md §13): prefill chains through the wide design
/// class, per-round coalesced decode batches through the skinny class.
/// Returns the serving report plus the fleet metrics after a drained
/// shutdown. Shared by `xdna-gemm serve-llm`, the `llm_serving` bench,
/// and the fleet tests.
pub fn serve_llm(
    opts: crate::coordinator::CoordinatorOptions,
    llm: &crate::coordinator::LlmOptions,
) -> crate::Result<(crate::coordinator::LlmReport, crate::coordinator::FleetMetrics)> {
    use crate::coordinator::Coordinator;
    let coord = Coordinator::start(opts);
    let report = crate::coordinator::serve_llm(&coord, llm);
    let metrics = coord.shutdown()?;
    Ok((report?, metrics))
}

/// Write the observability artifacts of a finished serving run: the
/// Chrome trace-event JSON (`--trace-out`, loadable in Perfetto) and
/// the Prometheus-text metrics (`--metrics-out`). A `None` path skips
/// that artifact; `llm` folds the LLM report's metric families on top
/// of the fleet projection. Shared by `serve`, `serve-llm`, and
/// `compile --serve`.
pub fn write_trace_artifacts(
    recorder: &crate::trace::Recorder,
    devices: &[Generation],
    metrics: &crate::coordinator::FleetMetrics,
    llm: Option<&crate::coordinator::LlmReport>,
    trace_out: Option<&str>,
    metrics_out: Option<&str>,
) -> crate::Result<()> {
    if let Some(path) = trace_out {
        std::fs::write(path, crate::trace::render(&recorder.facts(), devices))
            .map_err(|e| anyhow::anyhow!("writing trace to {path}: {e}"))?;
    }
    if let Some(path) = metrics_out {
        let mut reg = crate::trace::MetricsRegistry::from_fleet(metrics);
        if let Some(rep) = llm {
            reg.absorb_llm(rep);
        }
        std::fs::write(path, reg.render_prometheus())
            .map_err(|e| anyhow::anyhow!("writing metrics to {path}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_all_rows() {
        let t = table1(None);
        assert_eq!(t.rows.len(), 8);
        assert!(table1(Some(Generation::Xdna)).rows.len() == 4);
    }

    #[test]
    fn table23_matches_paper_within_bounds() {
        for gen in Generation::ALL {
            let t = table23(gen);
            assert_eq!(t.rows.len(), 4);
        }
    }

    #[test]
    fn fig6_series_shapes() {
        let series = fig6();
        assert_eq!(series.len(), 2);
        for (s, paper) in &series {
            assert!(s.points.len() >= 5, "{}", s.name);
            // Rising then saturating near the paper's value.
            let first = s.points[0].1;
            let last = s.max_y();
            assert!(last > 2.0 * first, "{}: no k_mt effect", s.name);
            assert!((last - paper).abs() / paper < 0.15, "{}: {last} vs {paper}", s.name);
        }
    }

    #[test]
    fn ablation_tables_render() {
        assert_eq!(ablation_baseline().rows.len(), 2);
        assert_eq!(ablation_bd_overlap().rows.len(), 2);
        let t = ablation_reconfig(Generation::Xdna2);
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn functional_perf_reports_sane_rates() {
        // A tiny design point (mirrors the executor unit-test config) so
        // the measurement itself stays fast in debug builds.
        let cfg = TilingConfig::new(
            Generation::Xdna,
            Precision::I8I8,
            8,
            16,
            16,
            32,
            4,
            4,
            Layout::ColMajor,
        )
        .unwrap();
        let (nm, nk, nn) = cfg.native();
        let perf =
            functional_perf(&cfg, nm, nk, nn, crate::gemm::exec::ExecOptions::default(), 1)
                .unwrap();
        assert!(perf.secs_per_gemm > 0.0);
        assert!(perf.gemms_per_s > 0.0 && perf.gb_per_s > 0.0);
        assert_eq!(perf.threads, 1);
    }

    #[test]
    fn small_roofline_sweep_works() {
        let s = roofline(Generation::Xdna, Precision::I8I8, Layout::ColMajor, 25);
        assert!(s.points.len() >= 25);
        assert!(s.max_y() < 8.2, "cannot beat peak: {}", s.max_y());
    }
}
