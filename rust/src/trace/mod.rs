//! Virtual-time flight recorder for the serving stack (DESIGN.md §16).
//!
//! Answers *why a run was slow*: every served unit leaves a span chain
//! (route → \[requeue\] → reconfig → dispatch with the sim's phase
//! breakdown → integrity), every device an occupancy timeline, and
//! every chaos incident (injected fault, leader respawn, spill) an
//! instant event — all on the coordinator's deterministic virtual
//! clock, exportable as Chrome trace-event JSON (`--trace-out`,
//! loadable in Perfetto) and as Prometheus-text metrics
//! (`--metrics-out`).
//!
//! Layering:
//! * [`model`]    — the deterministic fact types hooks record.
//! * [`recorder`] — the enum-gated sink (`Recorder::Off` costs one
//!   discriminant test and zero allocations on the unit hot path).
//! * [`chrome`]   — canonical-replay Chrome trace-event exporter
//!   (same seed ⇒ byte-identical file).
//! * [`metrics`]  — `MetricsRegistry`: counters + fixed-bucket
//!   histograms, projected from `FleetMetrics` at export time.
//! * [`roofline`] — ridge points and per-dispatch bound attribution
//!   (the paper's Figs. 7–8 lens).
//!
//! Not to be confused with [`crate::sim::trace`], the per-core cycle
//! accounting inside the simulator: this module traces the *serving
//! stack* above it.

pub mod chrome;
pub mod metrics;
pub mod model;
pub mod recorder;
pub mod roofline;

pub use chrome::{chrome_trace, render};
pub use metrics::{Histogram, MetricsRegistry, LATENCY_BUCKETS_S};
pub use model::{key_label, DispatchFact, RequeueReason, TraceFact};
pub use recorder::{Recorder, TraceSink};
pub use roofline::{ridge_point, RooflineTag};
