//! The fact model of the flight recorder (DESIGN.md §16).
//!
//! The recorder does **not** log wall-clock timestamps. Leaders race the
//! router for batch membership, so anything stamped at runtime (arrival
//! order, batch composition, which op triggered an LRU eviction) is
//! timing-dependent and would break the byte-identical-trace contract.
//! Instead every hook records a *fact*: a value that is fully determined
//! by (seed, options, workload) — the simulated phase costs of a
//! dispatch, the seed-scheduled fault that fired at forward `seq`, the
//! requeue verdict a leader reached. The exporter
//! ([`crate::trace::chrome`]) then *replays* the fact multiset on a
//! canonical virtual timeline; the append order observed at runtime is
//! irrelevant because every fact bucket is sorted by its own
//! deterministic key before layout.

use crate::arch::Generation;
use crate::coordinator::{DesignKey, FaultKind, Integrity, MClass, RouteKind};
use crate::dtype::Precision;
use crate::sim::Bound;

/// Everything deterministic about one executed GEMM dispatch: identity,
/// shape, design, the sim's phase breakdown, and the roofline
/// attribution the span is annotated with. For a chain, one fact per op
/// (`op` = position, `chain` = the chain id); for a plain request a
/// single fact with `op == 0`.
///
/// `t_*` are the per-dispatch phase costs from [`crate::sim::GemmReport`];
/// the device charge for the op is
/// `t_total * dispatches + fault_stall_s + integrity_s` — the exact
/// expression `run_request` / `run_chain` put on the virtual device
/// clock, which the exporter re-partitions into child phase spans.
#[derive(Clone, Debug, PartialEq)]
pub struct DispatchFact {
    /// Coordinator unit id (request or chain id).
    pub unit: u64,
    /// Op index within the unit (0 for plain requests).
    pub op: usize,
    /// Chain id when this op executed as part of a chain.
    pub chain: Option<u64>,
    pub device: usize,
    pub gen: Generation,
    pub name: String,
    pub tenant: usize,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Executed design class (normalized: fp32-split runs as bf16 limbs).
    pub key: DesignKey,
    /// Logical precision of the op as submitted (may be `Fp32Split`).
    pub precision: Precision,
    /// Physical host submissions: `LIMB_GEMMS` for an fp32-split op,
    /// else 1.
    pub dispatches: f64,
    pub t_comp: f64,
    pub t_mem: f64,
    pub t_prologue: f64,
    pub t_stall: f64,
    pub t_dispatch: f64,
    pub t_total: f64,
    /// Injected `DmaStall` charge (chain op 0 / request only).
    pub fault_stall_s: f64,
    /// Integrity-check charge (`integrity_seconds`).
    pub integrity_s: f64,
    /// Roofline x-coordinate: ops per DRAM byte.
    pub arithmetic_intensity: f64,
    /// Ridge point of (gen, executed precision): ops/byte where peak
    /// compute meets peak DRAM bandwidth.
    pub ridge: f64,
    pub tops: f64,
    pub bound: Bound,
    pub integrity: Integrity,
}

/// Why a leader sent a unit back to the router.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RequeueReason {
    /// `FaultKind::DropResponse` swallowed the reply.
    DropResponse,
    /// The unit was tagged by a `FaultKind::LeaderKill`.
    LeaderKill,
    /// Integrity verification failed and a retry budget remained.
    IntegrityRetry,
}

impl RequeueReason {
    pub fn name(self) -> &'static str {
        match self {
            RequeueReason::DropResponse => "drop_response",
            RequeueReason::LeaderKill => "leader_kill",
            RequeueReason::IntegrityRetry => "integrity_retry",
        }
    }
}

/// One deterministic event observed by the serving stack. See the
/// module docs for why these carry no timestamps.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceFact {
    /// Router placement decision for a unit (fresh admit or spill
    /// re-route after a leader death).
    Route {
        unit: u64,
        device: usize,
        kind: RouteKind,
        est_s: f64,
    },
    /// An executed dispatch with full phase + roofline attribution.
    Dispatch(Box<DispatchFact>),
    /// A leader handed the unit back to the router.
    Requeue {
        unit: u64,
        device: usize,
        reason: RequeueReason,
    },
    /// A seed-scheduled fault fired at forward `seq` on `device`,
    /// tagged onto `unit`.
    Fault {
        device: usize,
        seq: u64,
        kind: FaultKind,
        unit: u64,
    },
    /// The router respawned a dead leader in place.
    Respawn { device: usize },
    /// A unit was orphaned by a dead leader and re-routed elsewhere.
    Spill { unit: u64 },
    /// An explicit cache warm landed `key` on `device`.
    Warm { device: usize, key: DesignKey },
    /// A staged-graph chain retired with `edges` fused staging edges
    /// (recorded by `graph::exec::serve_graph`).
    Stage { unit: u64, device: usize, edges: usize },
}

/// Stable human label for a design key, used for span args and metric
/// labels: `precision/layout/mclass`.
pub fn key_label(key: DesignKey) -> String {
    format!(
        "{}/{}/{}",
        key.precision.name(),
        key.b_layout.name(),
        match key.m_class {
            MClass::Skinny => "skinny",
            MClass::Wide => "wide",
        }
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Layout;

    #[test]
    fn requeue_reason_names_are_stable() {
        assert_eq!(RequeueReason::DropResponse.name(), "drop_response");
        assert_eq!(RequeueReason::LeaderKill.name(), "leader_kill");
        assert_eq!(RequeueReason::IntegrityRetry.name(), "integrity_retry");
    }

    #[test]
    fn key_label_is_stable() {
        let key = DesignKey::for_shape(&crate::workload::GemmShape::new(
            "t",
            512,
            512,
            512,
            Precision::I8I8,
        ));
        assert_eq!(key.b_layout, Layout::RowMajor);
        assert_eq!(key_label(key), "i8i8/rowmajor/wide");
    }
}
