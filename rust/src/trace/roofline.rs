//! Roofline attribution for dispatch spans (paper Figs. 7–8).
//!
//! The simulator already classifies each dispatch compute- vs
//! memory-bound from its own phase model (`t_comp >= t_mem`, the
//! balance the paper's Sec. 5.3 optimizes toward). This module adds the
//! roofline coordinates the span is annotated with: the generation's
//! *ridge point* — the arithmetic intensity where the compute roof
//! meets the DRAM-bandwidth roof — so a trace viewer can read each op's
//! `arithmetic_intensity` against it without re-deriving machine
//! constants.

use crate::arch::Generation;
use crate::dtype::Precision;
use crate::sim::dram::DramModel;
use crate::sim::{Bound, GemmReport};

/// Ridge point (ops/byte) of the (generation, precision) roofline:
/// `peak_ops_per_s / peak_dram_bytes_per_s`. Intensities above it can
/// saturate the MACs; below it the run is DRAM-limited no matter how
/// good the schedule. Uses the spec peak MAC rate and the DRAM model's
/// asymptotic bandwidth — the same constants `sim::engine` builds its
/// phase model from.
pub fn ridge_point(gen: Generation, p: Precision) -> f64 {
    gen.spec().peak_tops(p) * 1e12 / DramModel::for_gen(gen).bw_max
}

/// The span annotation bundle for one simulated dispatch: roofline
/// x-coordinate, the roofline's ridge, and the engine's own verdict.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RooflineTag {
    pub arithmetic_intensity: f64,
    pub ridge: f64,
    pub bound: Bound,
}

/// Annotate a sim report. `p` is the *executed* precision (the design's,
/// not the logical op's — an fp32-split limb runs on the bf16 roofline).
pub fn tag(gen: Generation, p: Precision, report: &GemmReport) -> RooflineTag {
    RooflineTag {
        arithmetic_intensity: report.arithmetic_intensity,
        ridge: ridge_point(gen, p),
        bound: report.bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{balanced_config, skinny_balanced_config};
    use crate::sim::{simulate_gemm, BdMode};

    /// Pinned against the machine constants: XDNA i8i8 peak 8.192 TOPS
    /// over 32.4 GB/s ⇒ ~252.8 ops/B; XDNA2 58.9824 TOPS over 70.5 GB/s
    /// ⇒ ~836.6 ops/B. These are the ridge lines of Figs. 7–8.
    #[test]
    fn ridge_points_match_machine_constants() {
        let r1 = ridge_point(Generation::Xdna, Precision::I8I8);
        let r2 = ridge_point(Generation::Xdna2, Precision::I8I8);
        assert!((r1 - 8.192e12 / 32.4e9).abs() < 1e-9, "{r1}");
        assert!((r2 - 58.9824e12 / 70.5e9).abs() < 1e-9, "{r2}");
        assert!((r1 - 252.83950617283952).abs() < 1e-9);
        assert!((r2 - 836.6297872340426).abs() < 1e-9);
    }

    /// bf16 halves the MAC rate, so its ridge is half the i8i8 ridge.
    #[test]
    fn bf16_ridge_is_half_of_i8() {
        for gen in Generation::ALL {
            let i8 = ridge_point(gen, Precision::I8I8);
            let bf = ridge_point(gen, Precision::Bf16);
            assert!((bf - i8 / 2.0).abs() < 1e-9);
        }
    }

    /// Verdicts with robust margins, pinned cross-language (mirrored by
    /// `python/tests/test_trace_model.py`): the XDNA balanced design is
    /// compute-bound at square kilo-shapes (~10% margin); the XDNA2
    /// balanced design is tuned *just* onto the memory side of its much
    /// higher ridge at its own Table 3 shape (~2.5% margin — striking
    /// the balance is the paper's point); a skinny decode GEMV is
    /// DRAM-limited everywhere (4–6x margin). The tag must carry the
    /// engine's verdict verbatim. Square 1024³ on XDNA2 is a ~0.1%
    /// knife-edge and deliberately NOT pinned.
    #[test]
    fn tag_reflects_engine_bound() {
        let xb = balanced_config(Generation::Xdna, Precision::I8I8);
        let big = simulate_gemm(&xb, 1024, 1024, 1024, BdMode::Overlapped);
        let t = tag(Generation::Xdna, Precision::I8I8, &big);
        assert_eq!(t.bound, Bound::Compute);
        assert_eq!(t.bound, big.bound);
        assert!((t.arithmetic_intensity - big.arithmetic_intensity).abs() < 1e-12);
        let x2 = balanced_config(Generation::Xdna2, Precision::I8I8);
        let table3 = simulate_gemm(&x2, 4032, 4320, 4608, BdMode::Overlapped);
        assert_eq!(tag(Generation::Xdna2, Precision::I8I8, &table3).bound, Bound::Memory);
        // A decode-style GEMV on the dedicated skinny design streams a
        // full B panel per row of output.
        for gen in Generation::ALL {
            let scfg = skinny_balanced_config(gen, Precision::I8I8);
            let skinny = simulate_gemm(&scfg, 1, 4096, 4096, BdMode::Overlapped);
            assert_eq!(tag(gen, Precision::I8I8, &skinny).bound, Bound::Memory);
        }
    }
}
