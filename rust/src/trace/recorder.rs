//! The enum-gated fact sink.
//!
//! `Recorder::Off` is a unit variant: a disabled recorder is one enum
//! discriminant test on the hot path and allocates nothing — the
//! coordinator clones it into every leader at spawn, so there is no
//! `Option<Mutex<..>>` to poke per unit. `Recorder::On` shares one
//! `TraceSink` across the router and all leaders via `Arc`; facts are
//! appended under a mutex that is only ever contended by design (a few
//! pushes per unit, far off the per-element hot loops).

use std::sync::{Arc, Mutex};

use super::model::TraceFact;

/// Shared fact log behind a [`Recorder::On`].
#[derive(Debug, Default)]
pub struct TraceSink {
    facts: Mutex<Vec<TraceFact>>,
}

impl TraceSink {
    fn push(&self, fact: TraceFact) {
        self.facts.lock().expect("trace sink poisoned").push(fact);
    }

    fn snapshot(&self) -> Vec<TraceFact> {
        self.facts.lock().expect("trace sink poisoned").clone()
    }
}

/// The recorder handle threaded through `CoordinatorOptions`. Cloning
/// is cheap (unit variant or `Arc` bump) and every clone feeds the same
/// sink, so the handle kept by `main` sees the facts leaders recorded.
#[derive(Clone, Debug, Default)]
pub enum Recorder {
    /// Disabled: every hook is a discriminant test, zero allocations.
    #[default]
    Off,
    /// Enabled: facts append to the shared sink.
    On(Arc<TraceSink>),
}

impl Recorder {
    /// A fresh enabled recorder with an empty sink.
    pub fn on() -> Recorder {
        Recorder::On(Arc::new(TraceSink::default()))
    }

    pub fn is_on(&self) -> bool {
        matches!(self, Recorder::On(_))
    }

    /// Record an already-built fact.
    pub fn record(&self, fact: TraceFact) {
        if let Recorder::On(sink) = self {
            sink.push(fact);
        }
    }

    /// Record lazily: the closure (and any allocation inside it) only
    /// runs when the recorder is on. This is the hook used on the unit
    /// hot path.
    pub fn with<F: FnOnce() -> TraceFact>(&self, build: F) {
        if let Recorder::On(sink) = self {
            sink.push(build());
        }
    }

    /// Snapshot of every fact recorded so far. Call after
    /// `Coordinator::shutdown` for a complete log (leaders are joined
    /// by then, so nothing is still in flight).
    pub fn facts(&self) -> Vec<TraceFact> {
        match self {
            Recorder::Off => Vec::new(),
            Recorder::On(sink) => sink.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_records_nothing_and_never_runs_the_closure() {
        let r = Recorder::Off;
        assert!(!r.is_on());
        r.with(|| unreachable!("closure must not run when off"));
        assert!(r.facts().is_empty());
    }

    #[test]
    fn clones_share_one_sink() {
        let r = Recorder::on();
        let c = r.clone();
        c.record(TraceFact::Respawn { device: 3 });
        r.with(|| TraceFact::Spill { unit: 7 });
        let facts = r.facts();
        assert_eq!(facts.len(), 2);
        assert_eq!(facts[0], TraceFact::Respawn { device: 3 });
        assert_eq!(facts[1], TraceFact::Spill { unit: 7 });
    }

    #[test]
    fn default_is_off() {
        assert!(!Recorder::default().is_on());
    }
}
