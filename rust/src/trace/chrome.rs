//! Chrome trace-event export (Perfetto / `chrome://tracing` loadable).
//!
//! The exporter *replays* the recorded fact multiset on a canonical
//! virtual timeline instead of trusting runtime order: per device, the
//! dispatch facts are sorted by `(unit, op)` and laid out back-to-back
//! on a per-device virtual clock, with design residency replayed along
//! the way (a reconfiguration span whenever the design key changes,
//! residency invalidated before a unit tagged by a `CacheStorm` or
//! `LeaderKill` fault). Leaders race each other for batch membership at
//! runtime, so the *append order* of facts is nondeterministic — but
//! the multiset is seed-determined, and every bucket is sorted by a
//! deterministic key here, which is what makes the exported file
//! byte-identical across runs (pinned by `tests/trace_golden.rs` and
//! the CI determinism job).
//!
//! Layout per device (`pid = device + 1`):
//! * `tid 0` ("engine") — the occupancy timeline: reconfiguration
//!   spans and one complete (`ph: "X"`) span per dispatched op,
//!   annotated with the roofline attribution, containing child spans
//!   for the sim's phase breakdown (`dma-in`, `compute`/`dma`,
//!   `bd-stall`, `dispatch`, `fault-stall`, `integrity`).
//! * `tid 1` ("faults") — instant (`ph: "i"`) events for injected
//!   faults, leader respawns, route/spill/stage marks, and `X` spans
//!   covering the re-execution window of every requeued unit.
//!
//! Timestamps are microseconds of *virtual device time* (the same
//! clock `FleetMetrics` accounts), not wall-clock.

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::arch::Generation;
use crate::coordinator::{DesignKey, FaultKind};
use crate::util::json::{self, Json};

use super::model::{key_label, DispatchFact, TraceFact};

/// One unit's replayed execution window: (device, start_s, end_s).
type Window = (usize, f64, f64);

fn event(
    name: &str,
    ph: &str,
    pid: usize,
    tid: usize,
    ts_us: f64,
    dur_us: Option<f64>,
    args: Vec<(&str, Json)>,
) -> Json {
    let mut fields = vec![
        ("name", json::s(name)),
        ("ph", json::s(ph)),
        ("pid", json::num(pid as f64)),
        ("tid", json::num(tid as f64)),
        ("ts", json::num(ts_us)),
    ];
    if let Some(d) = dur_us {
        fields.push(("dur", json::num(d)));
    }
    if ph == "i" {
        // Instant scope: thread.
        fields.push(("s", json::s("t")));
    }
    if !args.is_empty() {
        fields.push(("args", json::obj(args)));
    }
    json::obj(fields)
}

fn meta(name: &str, pid: usize, tid: Option<usize>, value: &str) -> Json {
    let mut fields = vec![
        ("name", json::s(name)),
        ("ph", json::s("M")),
        ("pid", json::num(pid as f64)),
    ];
    if let Some(t) = tid {
        fields.push(("tid", json::num(t as f64)));
    }
    fields.push(("args", json::obj(vec![("name", json::s(value))])));
    json::obj(fields)
}

/// The parent span duration of one dispatch fact: exactly what the
/// leader charged to the virtual device clock for the op, minus the
/// reconfiguration (replayed as its own span).
fn span_seconds(f: &DispatchFact) -> f64 {
    f.t_total * f.dispatches + f.fault_stall_s + f.integrity_s
}

/// Append the phase-breakdown child spans of a dispatch. The children
/// partition the parent: their durations sum to [`span_seconds`] (the
/// steady phase is computed by subtraction, so the partition is exact
/// up to float associativity).
fn push_phases(events: &mut Vec<Json>, pid: usize, start_s: f64, f: &DispatchFact) {
    let steady = f.t_total - f.t_prologue - f.t_stall - f.t_dispatch;
    let steady_name = match f.bound {
        crate::sim::Bound::Compute => "compute",
        crate::sim::Bound::Memory => "dma",
    };
    let phases: [(&str, f64); 6] = [
        ("dma-in", f.t_prologue * f.dispatches),
        (steady_name, steady * f.dispatches),
        ("bd-stall", f.t_stall * f.dispatches),
        ("dispatch", f.t_dispatch * f.dispatches),
        ("fault-stall", f.fault_stall_s),
        ("integrity", f.integrity_s),
    ];
    let mut t = start_s;
    for (name, dur) in phases {
        if dur <= 0.0 {
            continue;
        }
        events.push(event(
            name,
            "X",
            pid,
            0,
            t * 1e6,
            Some(dur * 1e6),
            vec![("phase", json::s(name))],
        ));
        t += dur;
    }
}

fn dispatch_span(pid: usize, start_s: f64, f: &DispatchFact) -> Json {
    let dur = span_seconds(f);
    let mut args = vec![
        ("unit", json::num(f.unit as f64)),
        ("op", json::num(f.op as f64)),
        ("tenant", json::num(f.tenant as f64)),
        ("m", json::num(f.m as f64)),
        ("k", json::num(f.k as f64)),
        ("n", json::num(f.n as f64)),
        ("design", Json::Str(key_label(f.key))),
        ("precision", json::s(f.precision.name())),
        ("dispatches", json::num(f.dispatches)),
        ("tops", json::num(f.tops)),
        ("arithmetic_intensity", json::num(f.arithmetic_intensity)),
        ("ridge_point", json::num(f.ridge)),
        ("bound", json::s(f.bound.name())),
        ("integrity", json::s(f.integrity.name())),
        ("device_seconds", json::num(dur)),
    ];
    if let Some(c) = f.chain {
        args.push(("chain", json::num(c as f64)));
    }
    event(&f.name, "X", pid, 0, start_s * 1e6, Some(dur * 1e6), args)
}

/// Build the Chrome trace-event document for a recorded fact log.
/// `devices` is the fleet's generation list (`CoordinatorOptions::
/// device_gens()`); every fact's `device` indexes into it.
pub fn chrome_trace(facts: &[TraceFact], devices: &[Generation]) -> Json {
    // ---- bucket the fact multiset by kind, then sort each bucket by
    // its deterministic key (append order is runtime-dependent).
    let mut dispatches: BTreeMap<usize, Vec<&DispatchFact>> = BTreeMap::new();
    let mut routes: Vec<(u64, usize, &'static str, f64)> = Vec::new();
    let mut requeues: Vec<(u64, usize, &'static str)> = Vec::new();
    let mut faults: BTreeMap<usize, Vec<(u64, FaultKind, u64)>> = BTreeMap::new();
    let mut respawns: BTreeMap<usize, usize> = BTreeMap::new();
    let mut spills: Vec<u64> = Vec::new();
    let mut warms: BTreeMap<usize, Vec<DesignKey>> = BTreeMap::new();
    let mut stages: Vec<(u64, usize, usize)> = Vec::new();
    for fact in facts {
        match fact {
            TraceFact::Dispatch(f) => dispatches.entry(f.device).or_default().push(f),
            TraceFact::Route { unit, device, kind, est_s } => {
                routes.push((*unit, *device, kind.name(), *est_s))
            }
            TraceFact::Requeue { unit, device, reason } => {
                requeues.push((*unit, *device, reason.name()))
            }
            TraceFact::Fault { device, seq, kind, unit } => {
                faults.entry(*device).or_default().push((*seq, *kind, *unit))
            }
            TraceFact::Respawn { device } => *respawns.entry(*device).or_default() += 1,
            TraceFact::Spill { unit } => spills.push(*unit),
            TraceFact::Warm { device, key } => warms.entry(*device).or_default().push(*key),
            TraceFact::Stage { unit, device, edges } => stages.push((*unit, *device, *edges)),
        }
    }
    for bucket in dispatches.values_mut() {
        bucket.sort_by(|a, b| (a.unit, a.op).cmp(&(b.unit, b.op)));
    }
    for bucket in faults.values_mut() {
        bucket.sort_by(|a, b| a.0.cmp(&b.0));
    }
    routes.sort_by(|a, b| (a.0, a.1, a.2).cmp(&(b.0, b.1, b.2)));
    requeues.sort_by(|a, b| (a.0, a.2, a.1).cmp(&(b.0, b.2, b.1)));
    spills.sort_unstable();
    spills.dedup();
    stages.sort_unstable();

    let mut events: Vec<Json> = Vec::new();
    for (d, gen) in devices.iter().enumerate() {
        let pid = d + 1;
        events.push(meta("process_name", pid, None, &format!("device{d} ({})", gen.name())));
        events.push(meta("thread_name", pid, Some(0), "engine"));
        events.push(meta("thread_name", pid, Some(1), "faults"));
    }

    // ---- engine lanes: canonical replay of each device's dispatches.
    let mut windows: HashMap<u64, Window> = HashMap::new();
    let mut dev_end = vec![0.0_f64; devices.len()];
    for (d, gen) in devices.iter().enumerate() {
        let pid = d + 1;
        let reconfig_s = gen.spec().reconfig_s;
        let mut t = 0.0_f64;
        let mut resident: Option<DesignKey> = None;
        for key in warms.get(&d).map(Vec::as_slice).unwrap_or(&[]) {
            events.push(event(
                "warm",
                "i",
                pid,
                0,
                t * 1e6,
                None,
                vec![("design", Json::Str(key_label(*key)))],
            ));
            resident = Some(*key);
        }
        // Units tagged by a storm or kill run on cold design state.
        let invalidated: HashSet<u64> = faults
            .get(&d)
            .map(|fs| {
                fs.iter()
                    .filter(|(_, kind, _)| {
                        matches!(kind, FaultKind::CacheStorm | FaultKind::LeaderKill)
                    })
                    .map(|(_, _, unit)| *unit)
                    .collect()
            })
            .unwrap_or_default();
        let mut last_unit = None;
        for f in dispatches.get(&d).map(Vec::as_slice).unwrap_or(&[]) {
            if last_unit != Some(f.unit) && invalidated.contains(&f.unit) {
                resident = None;
            }
            last_unit = Some(f.unit);
            if resident != Some(f.key) {
                events.push(event(
                    "reconfig",
                    "X",
                    pid,
                    0,
                    t * 1e6,
                    Some(reconfig_s * 1e6),
                    vec![("design", Json::Str(key_label(f.key)))],
                ));
                t += reconfig_s;
                resident = Some(f.key);
            }
            let dur = span_seconds(f);
            events.push(dispatch_span(pid, t, f));
            push_phases(&mut events, pid, t, f);
            windows
                .entry(f.unit)
                .and_modify(|w| {
                    if w.0 != d {
                        // Spilled unit: its window restarts on the
                        // device that finally served it.
                        w.1 = t;
                    }
                    w.0 = d;
                    w.2 = t + dur;
                })
                .or_insert((d, t, t + dur));
            t += dur;
        }
        dev_end[d] = t;
    }

    // ---- fault lanes: instants + requeue windows, sorted per device
    // by (ts, name, unit) so the emission order is canonical.
    let mut lanes: Vec<Vec<(f64, String, u64, Json)>> = vec![Vec::new(); devices.len()];
    let at = |unit: u64, d: usize| -> f64 {
        match windows.get(&unit) {
            Some(&(wd, start, _)) if wd == d => start,
            _ => dev_end.get(d).copied().unwrap_or(0.0),
        }
    };
    for (&d, fs) in &faults {
        let pid = d + 1;
        for (seq, kind, unit) in fs {
            let ts = at(*unit, d);
            let mut args = vec![
                ("kind", json::s(kind.name())),
                ("seq", json::num(*seq as f64)),
                ("unit", json::num(*unit as f64)),
            ];
            if kind.stall_seconds() > 0.0 {
                args.push(("stall_s", json::num(kind.stall_seconds())));
            }
            let name = format!("fault:{}", kind.name());
            let ev = event(&name, "i", pid, 1, ts * 1e6, None, args);
            lanes[d].push((ts, name, *unit, ev));
        }
    }
    // The k-th respawn on a device answers its k-th injected kill (a
    // respawn without a recorded kill — a genuine leader panic — lands
    // at the end of the device timeline).
    for (&d, &n) in &respawns {
        let kills: Vec<u64> = faults
            .get(&d)
            .map(|fs| {
                fs.iter()
                    .filter(|(_, kind, _)| matches!(kind, FaultKind::LeaderKill))
                    .map(|(_, _, unit)| *unit)
                    .collect()
            })
            .unwrap_or_default();
        for i in 0..n {
            let ts = kills.get(i).map(|&u| at(u, d)).unwrap_or(dev_end[d]);
            lanes[d].push((
                ts,
                "leader-respawn".into(),
                i as u64,
                event("leader-respawn", "i", d + 1, 1, ts * 1e6, None, vec![]),
            ));
        }
    }
    for (unit, device, kind, est_s) in &routes {
        if *device >= devices.len() {
            continue;
        }
        let ts = at(*unit, *device);
        let name = format!("route:{kind}");
        let args = vec![("unit", json::num(*unit as f64)), ("est_s", json::num(*est_s))];
        lanes[*device].push((
            ts,
            name.clone(),
            *unit,
            event(&name, "i", device + 1, 1, ts * 1e6, None, args),
        ));
    }
    for (unit, _requeued_from, reason) in &requeues {
        // The span covers the unit's eventual re-execution window, on
        // the device that finally served it (usually the same one it
        // was requeued from; a spilled unit lands elsewhere).
        let Some(&(wd, start, end)) = windows.get(unit) else { continue };
        let name = format!("requeue:{reason}");
        let args = vec![("unit", json::num(*unit as f64)), ("reason", json::s(reason))];
        lanes[wd].push((
            start,
            name.clone(),
            *unit,
            event(&name, "X", wd + 1, 1, start * 1e6, Some((end - start) * 1e6), args),
        ));
    }
    for unit in &spills {
        let Some(&(wd, start, _)) = windows.get(unit) else { continue };
        let args = vec![("unit", json::num(*unit as f64))];
        let ev = event("spill", "i", wd + 1, 1, start * 1e6, None, args);
        lanes[wd].push((start, "spill".into(), *unit, ev));
    }
    for (unit, device, edges) in &stages {
        if *device >= devices.len() {
            continue;
        }
        let ts = at(*unit, *device);
        lanes[*device].push((
            ts,
            "staged-edges".into(),
            *unit,
            event(
                "staged-edges",
                "i",
                device + 1,
                1,
                ts * 1e6,
                None,
                vec![("unit", json::num(*unit as f64)), ("edges", json::num(*edges as f64))],
            ),
        ));
    }
    for lane in &mut lanes {
        lane.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
        for (_, _, _, e) in lane.drain(..) {
            events.push(e);
        }
    }

    json::obj(vec![
        ("displayTimeUnit", json::s("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Serialize the trace document: stable key order, stable number
/// formatting — the byte-identical artifact `--trace-out` writes.
pub fn render(facts: &[TraceFact], devices: &[Generation]) -> String {
    let mut s = chrome_trace(facts, devices).to_string_pretty();
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Integrity;
    use crate::dtype::Precision;
    use crate::sim::Bound;
    use crate::trace::model::RequeueReason;
    use crate::workload::GemmShape;

    fn fact(unit: u64, op: usize, device: usize) -> DispatchFact {
        let shape = GemmShape::new("op", 512, 512, 512, Precision::I8I8);
        DispatchFact {
            unit,
            op,
            chain: None,
            device,
            gen: Generation::Xdna2,
            name: format!("op#{unit}"),
            tenant: 0,
            m: 512,
            k: 512,
            n: 512,
            key: DesignKey::for_shape(&shape),
            precision: Precision::I8I8,
            dispatches: 1.0,
            t_comp: 4e-3,
            t_mem: 3e-3,
            t_prologue: 5e-4,
            t_stall: 0.0,
            t_dispatch: 1e-4,
            t_total: 4.6e-3,
            fault_stall_s: 0.0,
            integrity_s: 0.0,
            arithmetic_intensity: 170.0,
            ridge: 836.6,
            tops: 20.0,
            bound: Bound::Compute,
            integrity: Integrity::NotChecked,
        }
    }

    fn spans(doc: &Json) -> Vec<&Json> {
        doc.get("traceEvents")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect()
    }

    #[test]
    fn replay_is_independent_of_fact_order() {
        let a = TraceFact::Dispatch(Box::new(fact(0, 0, 0)));
        let b = TraceFact::Dispatch(Box::new(fact(1, 0, 0)));
        let devs = [Generation::Xdna2];
        let fwd = render(&[a.clone(), b.clone()], &devs);
        let rev = render(&[b, a], &devs);
        assert_eq!(fwd, rev, "canonical sort must erase append order");
    }

    #[test]
    fn dispatches_lay_out_back_to_back_with_one_reconfig() {
        let doc = chrome_trace(
            &[
                TraceFact::Dispatch(Box::new(fact(0, 0, 0))),
                TraceFact::Dispatch(Box::new(fact(1, 0, 0))),
            ],
            &[Generation::Xdna2],
        );
        let xs = spans(&doc);
        // reconfig + 2 parents + phase children (dma-in, compute,
        // dispatch per parent).
        let reconfigs: Vec<_> = xs
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("reconfig"))
            .collect();
        assert_eq!(reconfigs.len(), 1, "same key: exactly one reconfiguration");
        let parents: Vec<_> = xs
            .iter()
            .filter(|e| e.get("args").and_then(|a| a.get("bound")).is_some())
            .collect();
        assert_eq!(parents.len(), 2);
        // Unit 0 starts after the reconfig; unit 1 starts where 0 ends.
        let reconfig_us = Generation::Xdna2.spec().reconfig_s * 1e6;
        let t0 = parents[0].get("ts").and_then(Json::as_f64).unwrap();
        let d0 = parents[0].get("dur").and_then(Json::as_f64).unwrap();
        let t1 = parents[1].get("ts").and_then(Json::as_f64).unwrap();
        assert!((t0 - reconfig_us).abs() < 1e-6);
        assert!((t1 - (t0 + d0)).abs() < 1e-6);
    }

    #[test]
    fn phase_children_partition_the_parent_span() {
        let mut f = fact(7, 0, 0);
        f.fault_stall_s = 2e-3;
        f.integrity_s = 1e-4;
        let doc = chrome_trace(&[TraceFact::Dispatch(Box::new(f.clone()))], &[Generation::Xdna2]);
        let xs = spans(&doc);
        let parent = xs
            .iter()
            .find(|e| e.get("args").and_then(|a| a.get("bound")).is_some())
            .expect("parent span");
        let dur = parent.get("dur").and_then(Json::as_f64).unwrap();
        let child_sum: f64 = xs
            .iter()
            .filter(|e| e.get("args").and_then(|a| a.get("phase")).is_some())
            .map(|e| e.get("dur").and_then(Json::as_f64).unwrap())
            .sum();
        assert!((child_sum - dur).abs() < 1e-6 * dur.max(1.0), "{child_sum} vs {dur}");
        assert!((dur / 1e6 - span_seconds(&f)).abs() < 1e-12);
    }

    #[test]
    fn storm_invalidates_residency_and_fault_marks_the_unit_window() {
        let doc = chrome_trace(
            &[
                TraceFact::Dispatch(Box::new(fact(0, 0, 0))),
                TraceFact::Dispatch(Box::new(fact(1, 0, 0))),
                TraceFact::Fault { device: 0, seq: 2, kind: FaultKind::CacheStorm, unit: 1 },
            ],
            &[Generation::Xdna2],
        );
        let xs = spans(&doc);
        let reconfigs: Vec<_> = xs
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("reconfig"))
            .collect();
        assert_eq!(reconfigs.len(), 2, "storm before unit 1 forces a second reconfig");
        let all = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let inst = all
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("fault:cache_storm"))
            .expect("fault instant");
        assert_eq!(inst.get("ph").and_then(Json::as_str), Some("i"));
        // The instant sits at unit 1's (second) parent span start.
        let parents: Vec<_> = xs
            .iter()
            .filter(|e| e.get("args").and_then(|a| a.get("bound")).is_some())
            .collect();
        let t1 = parents[1].get("ts").and_then(Json::as_f64).unwrap();
        assert_eq!(inst.get("ts").and_then(Json::as_f64), Some(t1));
    }

    #[test]
    fn requeue_spans_cover_the_reexecution_window() {
        let doc = chrome_trace(
            &[
                TraceFact::Dispatch(Box::new(fact(3, 0, 0))),
                TraceFact::Requeue { unit: 3, device: 0, reason: RequeueReason::DropResponse },
            ],
            &[Generation::Xdna2],
        );
        let all = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let rq = all
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("requeue:drop_response"))
            .expect("requeue span");
        assert_eq!(rq.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(rq.get("tid").and_then(Json::as_f64), Some(1.0));
        assert!(rq.get("dur").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn metadata_names_every_device_and_lane() {
        let doc = chrome_trace(&[], &[Generation::Xdna, Generation::Xdna2]);
        let all = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let metas: Vec<_> =
            all.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("M")).collect();
        assert_eq!(metas.len(), 6, "process_name + 2 thread_names per device");
        assert!(all.iter().any(|e| {
            e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str)
                == Some("device1 (xdna2)")
        }));
    }
}
