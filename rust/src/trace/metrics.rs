//! Counters + fixed-bucket histograms with Prometheus-text rendering.
//!
//! The registry is a *projection*, not a hot-path participant: it is
//! built once at export time from the rollups the coordinator already
//! keeps (`FleetMetrics`, `LlmReport`), so `--metrics-out` costs the
//! serving loop nothing and works even with the recorder off. Counter
//! and histogram names follow Prometheus conventions (`*_total`,
//! `*_seconds`); labels use the `{name="value"}` form. Everything is
//! stored in `BTreeMap`s, so a render is deterministically ordered.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::coordinator::{FleetMetrics, LlmReport};

/// Fixed histogram bucket upper bounds, in seconds. Chosen to straddle
/// the simulated device times of the paper's Table 2–3 shapes (~0.1–10
/// ms) with headroom for chains and stalls; mirrored verbatim by
/// `python/tests/test_trace_model.py`.
pub const LATENCY_BUCKETS_S: [f64; 16] = [
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5,
    5.0, 10.0,
];

/// One fixed-bucket histogram: `counts[i]` observations landed in
/// `(bounds[i-1], bounds[i]]`; the final slot is the `+Inf` overflow.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// `LATENCY_BUCKETS_S.len() + 1` slots (last = +Inf).
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { counts: vec![0; LATENCY_BUCKETS_S.len() + 1], sum: 0.0, count: 0 }
    }
}

impl Histogram {
    /// Index of the bucket `v` lands in: the first bound `>= v`, or the
    /// overflow slot.
    pub fn bucket_index(v: f64) -> usize {
        LATENCY_BUCKETS_S.iter().position(|&b| v <= b).unwrap_or(LATENCY_BUCKETS_S.len())
    }

    pub fn observe(&mut self, v: f64) {
        self.counts[Self::bucket_index(v)] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Cumulative count at bucket `i` (Prometheus `le` semantics).
    pub fn cumulative(&self, i: usize) -> u64 {
        self.counts[..=i].iter().sum()
    }
}

/// A counter + histogram registry rendered as Prometheus text.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn inc(&mut self, name: &str, by: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += by;
    }

    /// Set a counter outright (used for gauges-reported-as-counters
    /// like busy seconds, where the rollup already holds the total).
    pub fn set(&mut self, name: &str, v: f64) {
        self.counters.insert(name.to_string(), v);
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().observe(v);
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.counters.get(name).copied().unwrap_or(0.0)
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Project a finished fleet run into the registry. Pure function of
    /// the rollup: calling it twice on the same metrics doubles nothing
    /// because it starts from the rollup's absolute totals each time
    /// (`set`) for scalar families and rebuilds histograms from the
    /// per-record streams.
    pub fn from_fleet(m: &FleetMetrics) -> MetricsRegistry {
        let mut r = MetricsRegistry::default();
        r.set("gemm_requests_total", m.count() as f64);
        r.set("gemm_ops_total", m.total_ops());
        r.set("gemm_reconfigurations_total", m.reconfigurations() as f64);
        r.set("gemm_chains_total", m.chains.len() as f64);
        r.set("router_affinity_hits_total", m.router_hits as f64);
        r.set("router_misses_total", m.router_misses as f64);
        r.set("router_spills_total", m.router_spills as f64);
        r.set("leader_respawns_total", m.leader_respawns as f64);
        r.set("requeues_total", m.total_requeued() as f64);
        let (checked, passed, recovered, failed) = m.integrity_totals();
        r.set("integrity_checked_total", checked as f64);
        r.set("integrity_passed_total", passed as f64);
        r.set("integrity_recovered_total", recovered as f64);
        r.set("integrity_failed_total", failed as f64);
        for f in m.fault_log() {
            r.inc(&format!("faults_total{{kind=\"{}\"}}", f.kind.name()), 1.0);
        }
        for (d, dm) in m.devices.iter().enumerate() {
            let label = format!("device=\"{d}\",gen=\"{}\"", dm.gen.name());
            r.set(&format!("device_requests_total{{{label}}}"), dm.metrics.count() as f64);
            r.set(&format!("device_busy_seconds{{{label}}}"), dm.metrics.total_device_s());
            r.set(&format!("design_cache_hits_total{{{label}}}"), dm.cache.hits as f64);
            r.set(&format!("design_cache_misses_total{{{label}}}"), dm.cache.misses as f64);
            r.set(&format!("design_cache_evictions_total{{{label}}}"), dm.cache.evictions as f64);
            for rec in &dm.metrics.records {
                r.observe("gemm_device_seconds", rec.device_s);
                r.observe("gemm_host_latency_seconds", rec.host_latency_s);
            }
        }
        for t in &m.tenants {
            let label = format!("tenant=\"{}\"", t.name);
            r.set(&format!("tenant_submitted_total{{{label}}}"), t.submitted as f64);
            r.set(&format!("tenant_completed_total{{{label}}}"), t.completed as f64);
            r.set(&format!("tenant_failed_total{{{label}}}"), t.failed as f64);
            r.set(&format!("tenant_requeued_total{{{label}}}"), t.requeued as f64);
        }
        r
    }

    /// Fold an LLM serving report in on top of the fleet projection.
    pub fn absorb_llm(&mut self, rep: &LlmReport) {
        self.set("llm_sessions_total", rep.sessions as f64);
        self.set("llm_sessions_completed_total", rep.sessions_completed as f64);
        self.set("llm_sessions_failed_total", rep.sessions_failed as f64);
        self.set("llm_tokens_submitted_total", rep.tokens_submitted as f64);
        self.set("llm_tokens_completed_total", rep.tokens_completed as f64);
        self.set("llm_tokens_failed_total", rep.tokens_failed as f64);
        self.set("llm_tokens_per_second", rep.tokens_per_s);
        self.set("llm_decode_busy_seconds", rep.decode_busy_s);
        self.set("llm_decode_rounds_total", rep.decode_rounds as f64);
    }

    /// Prometheus text exposition. Families are sorted by name; within
    /// a family, label sets are sorted (the `BTreeMap` key order).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_family = String::new();
        for (key, v) in &self.counters {
            let family = key.split('{').next().unwrap_or(key);
            if family != last_family {
                let _ = writeln!(out, "# TYPE {family} counter");
                last_family = family.to_string();
            }
            let _ = writeln!(out, "{key} {}", fmt_num(*v));
        }
        for (name, h) in &self.histograms {
            let _ = writeln!(out, "# TYPE {name} histogram");
            for (i, bound) in LATENCY_BUCKETS_S.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "{name}_bucket{{le=\"{}\"}} {}",
                    fmt_num(*bound),
                    h.cumulative(i)
                );
            }
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
            let _ = writeln!(out, "{name}_sum {}", fmt_num(h.sum));
            let _ = writeln!(out, "{name}_count {}", h.count);
        }
        out
    }
}

/// Deterministic number formatting shared with the JSON layer: integral
/// values print without a trailing `.0`, everything else uses Rust's
/// shortest-roundtrip `f64` `Display`.
fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_inclusive_upper() {
        // `le` semantics: a value equal to a bound lands in that bucket.
        assert_eq!(Histogram::bucket_index(1e-4), 0);
        assert_eq!(Histogram::bucket_index(1.0000001e-4), 1);
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(10.0), 15);
        assert_eq!(Histogram::bucket_index(10.1), 16, "overflow slot");
        assert_eq!(LATENCY_BUCKETS_S.len(), 16);
        assert!(LATENCY_BUCKETS_S.windows(2).all(|w| w[0] < w[1]), "sorted");
    }

    #[test]
    fn histogram_observes_and_accumulates() {
        let mut h = Histogram::default();
        h.observe(2e-4); // bucket 1
        h.observe(2e-4);
        h.observe(3.0); // bucket 14 (<= 5.0)
        assert_eq!(h.count, 3);
        assert!((h.sum - 3.0004).abs() < 1e-12);
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[14], 1);
        assert_eq!(h.cumulative(0), 0);
        assert_eq!(h.cumulative(1), 2);
        assert_eq!(h.cumulative(14), 3);
    }

    #[test]
    fn prometheus_rendering_is_deterministic_and_typed() {
        let mut r = MetricsRegistry::default();
        r.inc("b_total", 2.0);
        r.inc("a_total", 1.0);
        r.inc("a_total", 1.0);
        r.observe("lat_seconds", 2e-3);
        let text = r.render_prometheus();
        let again = r.render_prometheus();
        assert_eq!(text, again);
        // Sorted families, each typed once.
        let a = text.find("# TYPE a_total counter").expect("a family");
        let b = text.find("# TYPE b_total counter").expect("b family");
        assert!(a < b);
        assert!(text.contains("a_total 2\n"));
        assert!(text.contains("# TYPE lat_seconds histogram"));
        assert!(text.contains("lat_seconds_bucket{le=\"0.0025\"} 1"));
        assert!(text.contains("lat_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("lat_seconds_count 1"));
    }

    #[test]
    fn labeled_counters_share_one_type_line() {
        let mut r = MetricsRegistry::default();
        r.inc("faults_total{kind=\"leader_kill\"}", 1.0);
        r.inc("faults_total{kind=\"cache_storm\"}", 2.0);
        let text = r.render_prometheus();
        assert_eq!(text.matches("# TYPE faults_total counter").count(), 1);
        assert!(text.contains("faults_total{kind=\"cache_storm\"} 2"));
    }

    #[test]
    fn fleet_projection_is_idempotent() {
        let m = FleetMetrics::default();
        let r1 = MetricsRegistry::from_fleet(&m);
        let r2 = MetricsRegistry::from_fleet(&m);
        assert_eq!(r1.render_prometheus(), r2.render_prometheus());
        assert_eq!(r1.counter("gemm_requests_total"), 0.0);
    }
}
