//! Command-processor / ShimTile BD queue mechanics (Sec. 4.4).
//!
//! Each ShimTile owns 16 buffer descriptors and an input task queue. The
//! paper's protocol keeps five BDs in flight for each of the A, B and C
//! streams (15 of 16 BDs used), waits on the *output* BD's task-completion
//! token (input BDs are then necessarily done too), reconfigures the
//! retired triple, and enqueues the next — so DMA transfers overlap with
//! BD reconfiguration. The ablation of Sec. 5.3.3 disables the overlap:
//! synchronize → reconfigure → enqueue strictly in sequence, which stalls
//! the DMAs once per output BD and costs 27–28% end to end.
//!
//! This module simulates the queue mechanics (occupancy invariants, stall
//! counting); the per-stall latency is a per-generation calibrated
//! constant consumed by [`super::engine`].

use crate::arch::Generation;

/// BD-reconfiguration policy.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BdMode {
    /// The paper's protocol: reconfiguration overlaps DMA transfers.
    Overlapped,
    /// Ablation (Sec. 5.3.3): sync + reconfigure with the queue idle.
    Sequential,
}

/// Per-stall DMA-idle time for the sequential mode: one completion-token
/// round trip through the command processor plus the rewrite of three BDs.
/// Calibrated against the paper's 27%/28% end-to-end degradations
/// (Sec. 5.3.3; DESIGN.md §5.3).
pub fn stall_seconds(gen: Generation) -> f64 {
    match gen {
        Generation::Xdna => 18.8e-6,
        Generation::Xdna2 => 6.2e-6,
    }
}

/// Result of walking the queue protocol over a whole GEMM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QueueStats {
    /// Output-BD triples processed (one per `(m_rows·m_ct) × n_ct` C
    /// block, Sec. 4.4).
    pub triples: usize,
    /// DMA-idle stall events (0 when overlapped).
    pub stalls: usize,
    /// Lowest BD occupancy observed while work remained.
    pub min_occupancy: usize,
    /// Highest BD occupancy (must be ≤ 16).
    pub max_occupancy: usize,
}

/// ShimTile BD queue simulator.
#[derive(Clone, Copy, Debug)]
pub struct ShimQueue {
    pub bd_capacity: usize,
    /// Triples submitted up front (the paper uses five → 15 BDs).
    pub prefill_triples: usize,
}

impl Default for ShimQueue {
    fn default() -> Self {
        ShimQueue { bd_capacity: 16, prefill_triples: 5 }
    }
}

impl ShimQueue {
    /// Walk the protocol for `n_triples` output blocks.
    pub fn run(&self, n_triples: usize, mode: BdMode) -> QueueStats {
        assert!(
            3 * self.prefill_triples <= self.bd_capacity,
            "prefill exceeds BD capacity"
        );
        let mut queued = n_triples.min(self.prefill_triples);
        let mut submitted = queued;
        let mut stalls = 0usize;
        let mut min_occ = usize::MAX;
        let mut max_occ = 0usize;

        while queued > 0 {
            max_occ = max_occ.max(3 * queued);
            // Front triple's C BD completes; its A/B BDs finished earlier.
            queued -= 1;
            if submitted < n_triples {
                min_occ = min_occ.min(3 * queued);
                match mode {
                    BdMode::Overlapped => {
                        // Reconfigure the retired triple while the next
                        // transfers run; re-enqueue immediately.
                    }
                    BdMode::Sequential => {
                        // DMA idles for the sync + reconfigure round trip.
                        stalls += 1;
                    }
                }
                queued += 1;
                submitted += 1;
            }
        }
        if min_occ == usize::MAX {
            min_occ = 0;
        }
        QueueStats { triples: n_triples, stalls, min_occupancy: min_occ, max_occupancy: max_occ }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlapped_steady_state_keeps_15_bds() {
        let q = ShimQueue::default();
        let s = q.run(100, BdMode::Overlapped);
        assert_eq!(s.stalls, 0);
        assert_eq!(s.max_occupancy, 15); // 5 triples × 3 BDs
        // Occupancy dips to 12 momentarily between retire and re-enqueue.
        assert_eq!(s.min_occupancy, 12);
    }

    #[test]
    fn sequential_stalls_once_per_refill() {
        let q = ShimQueue::default();
        let s = q.run(100, BdMode::Sequential);
        assert_eq!(s.stalls, 95); // everything beyond the prefill
        let s2 = q.run(3, BdMode::Sequential);
        assert_eq!(s2.stalls, 0); // fits entirely in the prefill
    }

    #[test]
    fn capacity_never_exceeded() {
        let q = ShimQueue::default();
        for n in [1, 5, 6, 17, 1000] {
            for mode in [BdMode::Overlapped, BdMode::Sequential] {
                let s = q.run(n, mode);
                assert!(s.max_occupancy <= q.bd_capacity, "{n} {mode:?}");
                assert_eq!(s.triples, n);
            }
        }
    }

    #[test]
    #[should_panic(expected = "prefill exceeds BD capacity")]
    fn prefill_bound_checked() {
        ShimQueue { bd_capacity: 16, prefill_triples: 6 }.run(10, BdMode::Overlapped);
    }
}
