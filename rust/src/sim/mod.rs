//! The calibrated NPU performance simulator.
//!
//! Stands in for the two mini PCs of the paper's evaluation (DESIGN.md §1):
//! every constant is either an architecture fact ([`crate::arch`]) or a
//! parameter fitted against the paper's own published measurements
//! (Tables 1–3, Fig. 6, Secs. 5.2–5.3) — the fit and residuals live in
//! DESIGN.md §5 and are re-checked by this module's tests.
//!
//! * [`core`]    — single-core kernel cycle model (hardware-trace fit).
//! * [`dram`]    — effective DRAM bandwidth vs contiguous-run length.
//! * [`cmdproc`] — command-processor / ShimTile BD queue mechanics
//!   (overlapped vs sequential reconfiguration, Sec. 4.4).
//! * [`engine`]  — whole-GEMM wall-clock estimator with phase breakdown.
//! * [`trace`]   — trace-unit-style per-core cycle accounting.

pub mod cmdproc;
pub mod core;
pub mod dram;
pub mod engine;
pub mod trace;

pub use engine::{
    abft_check_seconds, simulate_gemm, simulate_gemm_with, BdMode, Bound, DispatchOverrides,
    GemmReport,
};
