//! Trace-unit emulation (Sec. 5.1 measures kernels "via hardware profiling
//! utilizing the NPU trace unit"): per-core cycle accounting for one GEMM.
//!
//! The engine fills one [`CoreTrace`] per simulated run; the `table1`
//! harness and the profiling CLI print them the way `xrt_smi` /
//! mlir-aie's trace tooling would.

use crate::arch::Generation;
use crate::dtype::Precision;
use crate::tiling::KernelTile;

use super::core;

/// Cycle breakdown of one core over a whole GEMM (all cores are identical
/// by construction — the paper's independent-cores mapping).
#[derive(Clone, Copy, Debug, Default)]
pub struct CoreTrace {
    /// Cycles in the MAC kernel (includes modeled bank-conflict stalls).
    pub mac_cycles: f64,
    /// Cycles in the vectorized zeroing kernel.
    pub zero_cycles: f64,
    /// Cycles blocked on the single-buffer C drain.
    pub drain_cycles: f64,
    /// Cycles idle waiting on input DMAs (memory-bound portion).
    pub dma_idle_cycles: f64,
    /// Kernel invocations executed.
    pub invocations: u64,
}

impl CoreTrace {
    pub fn busy_cycles(&self) -> f64 {
        self.mac_cycles + self.zero_cycles + self.drain_cycles
    }

    pub fn total_cycles(&self) -> f64 {
        self.busy_cycles() + self.dma_idle_cycles
    }

    /// Fraction of time in the MAC kernel.
    pub fn mac_utilization(&self) -> f64 {
        if self.total_cycles() == 0.0 {
            return 0.0;
        }
        self.mac_cycles / self.total_cycles()
    }
}

/// Profile a single kernel invocation the way Table 1 does: cycle count
/// and achieved MACs/cycle from the trace model.
#[derive(Clone, Copy, Debug)]
pub struct KernelProfile {
    pub cycles: f64,
    pub macs_per_cycle: f64,
    pub efficiency: f64,
    pub l1_bytes: usize,
    pub l1_utilization: f64,
}

pub fn profile_kernel(gen: Generation, p: Precision, t: &KernelTile) -> KernelProfile {
    let spec = gen.spec();
    let l1 = t.l1_bytes(p, false);
    KernelProfile {
        cycles: core::kernel_cycles(gen, p, t),
        macs_per_cycle: core::macs_per_cycle(gen, p, t),
        efficiency: core::efficiency(gen, p, t),
        l1_bytes: l1,
        l1_utilization: l1 as f64 / spec.l1_budget() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Generation;

    #[test]
    fn profile_matches_table1_l1_column() {
        // Table 1: int8-int8 64x232x64 uses 62.0 KB (97%).
        let p = profile_kernel(
            Generation::Xdna,
            Precision::I8I8,
            &KernelTile::new(64, 232, 64),
        );
        assert!((p.l1_bytes as f64 / 1024.0 - 62.0).abs() < 0.1);
        assert!((p.l1_utilization - 0.97).abs() < 0.02);
        assert!((p.macs_per_cycle - 233.0).abs() < 3.0);
    }

    #[test]
    fn trace_accounting() {
        let t = CoreTrace {
            mac_cycles: 900.0,
            zero_cycles: 50.0,
            drain_cycles: 50.0,
            dma_idle_cycles: 1000.0,
            invocations: 10,
        };
        assert_eq!(t.busy_cycles(), 1000.0);
        assert_eq!(t.total_cycles(), 2000.0);
        assert!((t.mac_utilization() - 0.45).abs() < 1e-12);
    }
}
