//! Whole-GEMM wall-clock estimator: the simulator's top level.
//!
//! Combines the single-core cycle model, the effective-bandwidth model,
//! the BD-queue protocol and the buffering scheme into the phase-accurate
//! estimate of DESIGN.md §5.3:
//!
//! ```text
//! T ≈ max(T_comp, T_mem)          double-buffered steady state
//!   + T_prologue                  first A/B panels before compute starts
//!   + T_bd_stalls                 0 when reconfiguration is overlapped
//!   + T_dispatch                  host→NPU invocation overhead
//! ```
//!
//! `T_comp` already folds the per-reduction zeroing kernel and the
//! single-buffer C drain (which serialize with compute — Sec. 5.3.2);
//! `T_mem` is Eq. 10 with Eqs. 6–8 traffic and run-length-dependent
//! bandwidth. Validated against every end-to-end number in Tables 2–3,
//! Fig. 6 and the Sec. 5.3 ablations (tests below + `rust/benches`).

use crate::dtype::{Layout, Precision};
use crate::tiling::TilingConfig;

pub use super::cmdproc::BdMode;
use super::cmdproc::{stall_seconds, ShimQueue};
use super::core;
use super::dram::DramModel;
use super::trace::CoreTrace;

/// Host dispatch overhead (wall-clock measurement includes OS + NPU
/// dispatch time, Sec. 5.2). Calibrated: DESIGN.md §5.3.
fn dispatch_seconds(gen: crate::arch::Generation) -> f64 {
    match gen {
        crate::arch::Generation::Xdna => 0.5e-3,
        crate::arch::Generation::Xdna2 => 0.1e-3,
    }
}

/// What bound the run.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Bound {
    Compute,
    Memory,
}

impl Bound {
    /// Stable label used in trace-span annotations and metrics.
    pub fn name(self) -> &'static str {
        match self {
            Bound::Compute => "compute-bound",
            Bound::Memory => "memory-bound",
        }
    }
}

/// Chain-aware dispatch context (`crate::plan`): which DRAM round-trips
/// and host costs this dispatch skips because a chain planner proved the
/// operand already resident or the submission shared. The default (all
/// `false`) is the isolated dispatch `simulate_gemm` models.
#[derive(Clone, Copy, Default, PartialEq, Eq, Debug)]
pub struct DispatchOverrides {
    /// A is already staged in L2 — it is the previous chain op's C, kept
    /// resident — so the Eq. 6 DRAM read and A's share of the prologue
    /// are elided.
    pub a_in_l2: bool,
    /// C stays resident in L2 for the next chain op — the Eq. 8 DRAM
    /// write is elided.
    pub c_stays_in_l2: bool,
    /// Same design as the previous dispatch of the chain: the op rides
    /// the same host submission, so the per-op dispatch overhead is
    /// elided (only the chain's first op pays it).
    pub elide_dispatch: bool,
}

/// Full simulation report for one GEMM dispatch.
#[derive(Clone, Debug)]
pub struct GemmReport {
    /// Requested problem.
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Padded to the native grid (Sec. 5.3.1).
    pub pm: usize,
    pub pk: usize,
    pub pn: usize,

    /// Phase times (seconds).
    pub t_comp: f64,
    pub t_read: f64,
    pub t_write: f64,
    pub t_mem: f64,
    pub t_prologue: f64,
    pub t_stall: f64,
    pub t_dispatch: f64,
    pub t_total: f64,

    /// DRAM traffic (bytes): Eqs. 6, 7, 8 on the padded problem.
    pub a_bytes: f64,
    pub b_bytes: f64,
    pub c_bytes: f64,

    /// Achieved throughput on the *requested* operations.
    pub tops: f64,
    /// Throughput counting padded (wasted) operations too.
    pub tops_padded: f64,
    /// Single-core kernel stats.
    pub kernel_macs_per_cycle: f64,
    pub efficiency: f64,
    /// `eff · peak` — Tables 2–3 "Peak Comp. TOPS" column.
    pub peak_comp_tops: f64,
    pub bound: Bound,
    /// BD-queue stalls (sequential mode only).
    pub bd_stalls: usize,
    /// Arithmetic intensity: ops per DRAM byte (roofline x-axis,
    /// Figs. 7–8).
    pub arithmetic_intensity: f64,
    /// Per-core trace-unit view.
    pub trace: CoreTrace,
}

impl GemmReport {
    /// The steady-state phase of the dispatch: total minus prologue,
    /// BD stalls and host dispatch — `max(t_comp, t_mem)` by
    /// construction, but computed by subtraction so the flight
    /// recorder's phase partition (`dma-in` + steady + `bd-stall` +
    /// `dispatch` == `t_total`) holds exactly in floating point.
    pub fn steady_seconds(&self) -> f64 {
        self.t_total - self.t_prologue - self.t_stall - self.t_dispatch
    }
}

/// Modeled cost of the coordinator's ABFT checksum pass at one shape
/// (DESIGN.md §14): `m·k + k·n + 2·m·n + 2·k` MAC-equivalents
/// ([`crate::gemm::abft::checksum_ops`]) charged at the generation's
/// peak MAC rate for the precision — the check is dense streaming
/// arithmetic over data already resident, so peak rate is the right
/// (optimistic, overhead-minimizing) model. The point of the model is
/// the *ratio*: `O(mk + kn + mn)` checksum work vanishes next to the
/// `O(mkn)` GEMM it protects.
pub fn abft_check_seconds(
    gen: crate::arch::Generation,
    p: Precision,
    m: usize,
    k: usize,
    n: usize,
) -> f64 {
    crate::gemm::abft::checksum_ops(m, k, n) / (gen.spec().peak_tops(p) * 1e12)
}

/// Simulate one GEMM dispatch of `m × k × n` under `cfg`.
///
/// Arbitrary sizes are zero-padded to the native grid exactly as the
/// runtime does (Sec. 5.3.1); the report exposes both raw and padded
/// throughput.
pub fn simulate_gemm(cfg: &TilingConfig, m: usize, k: usize, n: usize, mode: BdMode) -> GemmReport {
    simulate_gemm_with(cfg, m, k, n, mode, DispatchOverrides::default())
}

/// [`simulate_gemm`] with chain-aware elisions: operands a planner keeps
/// L2-resident move zero DRAM bytes, and same-design chain ops past the
/// first pay no host dispatch. The report's byte/phase fields account
/// only what actually moved, so chain totals stay self-consistent.
pub fn simulate_gemm_with(
    cfg: &TilingConfig,
    m: usize,
    k: usize,
    n: usize,
    mode: BdMode,
    ov: DispatchOverrides,
) -> GemmReport {
    let spec = cfg.gen.spec();
    let p: Precision = cfg.precision;
    let kt = &cfg.kernel;
    let (pm, pk, pn) = cfg.padded(m, k, n);
    let (native_m, _, native_n) = cfg.native();

    // --- compute side -----------------------------------------------------
    let kernel_cycles = core::kernel_cycles(cfg.gen, p, kt);
    let reductions = pk / kt.k_ct;
    let tiles_per_core = (pm / native_m) * (pn / native_n);
    let zero_cycles = core::zeroing_cycles(p, kt);
    // Single-buffered C serializes its drain with compute; double-buffered
    // C hides it (but shrinks the feasible kernel set — Sec. 5.3.2).
    let drain_cycles = if cfg.c_double_buffered {
        0.0
    } else {
        core::c_drain_cycles(cfg.gen, p, kt)
    };
    let cycles_per_tile = reductions as f64 * kernel_cycles + zero_cycles + drain_cycles;
    let comp_cycles = tiles_per_core as f64 * cycles_per_tile;
    let t_comp = comp_cycles / spec.clock_hz;

    // --- memory side (Eqs. 6-8 + bandwidth model) --------------------------
    let dram = DramModel::for_gen(cfg.gen);
    let mkn = pm as f64 * pk as f64 * pn as f64;
    let a_bytes = if ov.a_in_l2 {
        0.0
    } else {
        mkn * p.in_bytes_f() / (kt.n_ct * cfg.n_cols) as f64
    };
    let b_bytes = mkn * p.in_bytes_f() / (kt.m_ct * cfg.m_rows) as f64;
    let c_bytes = if ov.c_stays_in_l2 {
        0.0
    } else {
        pm as f64 * pn as f64 * p.out_bytes_f()
    };

    let a_run = cfg.k_mt as f64 * p.in_bytes_f();
    let b_run = match cfg.b_layout {
        Layout::ColMajor => cfg.k_mt as f64 * p.in_bytes_f(),
        Layout::RowMajor => kt.n_ct as f64 * p.in_bytes_f() * dram.row_coalesce,
    };
    let c_run = kt.n_ct as f64 * p.out_bytes_f() * dram.row_coalesce;

    let t_read = dram.xfer_time(a_bytes, a_run) + dram.xfer_time(b_bytes, b_run);
    let t_write = dram.xfer_time(c_bytes, c_run);
    // Reads (MM2S) and writes (S2MM) ride separate channel directions;
    // the slower direction dominates.
    let t_mem = t_read.max(t_write);

    // --- BD queue (Sec. 4.4) ----------------------------------------------
    let c_bd_total = (pm / native_m) * (pn / kt.n_ct);
    let per_shim = c_bd_total.div_ceil(cfg.n_cols);
    let queue = ShimQueue::default();
    let qstats = queue.run(per_shim, mode);
    let bd_stalls = qstats.stalls * cfg.n_cols;
    let t_stall = bd_stalls as f64 * stall_seconds(cfg.gen);

    // --- prologue + dispatch ----------------------------------------------
    let a_first = if ov.a_in_l2 {
        0.0
    } else {
        (cfg.m_rows * kt.m_ct * cfg.k_mt) as f64 * p.in_bytes_f()
    };
    let b_first_elems = match cfg.b_layout {
        Layout::ColMajor => cfg.n_cols * cfg.k_mt * kt.n_ct,
        Layout::RowMajor => cfg.n_cols * kt.k_ct * kt.n_ct,
    };
    let b_first = b_first_elems as f64 * p.in_bytes_f();
    let t_prologue = dram.xfer_time(a_first, a_run) + dram.xfer_time(b_first, b_run);
    let t_dispatch = if ov.elide_dispatch { 0.0 } else { dispatch_seconds(cfg.gen) };

    let t_total = t_comp.max(t_mem) + t_prologue + t_stall + t_dispatch;

    let ops = 2.0 * m as f64 * k as f64 * n as f64;
    let ops_padded = 2.0 * mkn;
    let kernel_mpc = core::macs_per_cycle(cfg.gen, p, kt);
    let eff = core::efficiency(cfg.gen, p, kt);

    let mac_cycles = tiles_per_core as f64 * reductions as f64 * kernel_cycles;
    let total_core_cycles = t_total * spec.clock_hz;

    GemmReport {
        m,
        k,
        n,
        pm,
        pk,
        pn,
        t_comp,
        t_read,
        t_write,
        t_mem,
        t_prologue,
        t_stall,
        t_dispatch,
        t_total,
        a_bytes,
        b_bytes,
        c_bytes,
        tops: ops / t_total / 1e12,
        tops_padded: ops_padded / t_total / 1e12,
        kernel_macs_per_cycle: kernel_mpc,
        efficiency: eff,
        peak_comp_tops: cfg.peak_comp_tops(kernel_mpc),
        bound: if t_comp >= t_mem { Bound::Compute } else { Bound::Memory },
        bd_stalls,
        arithmetic_intensity: ops_padded / (a_bytes + b_bytes + c_bytes),
        trace: CoreTrace {
            mac_cycles,
            zero_cycles: tiles_per_core as f64 * zero_cycles,
            drain_cycles: tiles_per_core as f64 * drain_cycles,
            dma_idle_cycles: (total_core_cycles - tiles_per_core as f64 * cycles_per_tile).max(0.0),
            invocations: (tiles_per_core * reductions) as u64,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{balanced_config, Generation};
    use crate::dtype::Precision;

    /// End-to-end validation: the bold rows of Tables 2 and 3 at the
    /// paper's exact GEMM sizes.
    /// (gen, precision, (M, K, N), paper "Actual NPU TOPS", tolerance %)
    const PAPER_E2E: &[(Generation, Precision, (usize, usize, usize), f64, f64)] = &[
        (Generation::Xdna, Precision::I8I8, (4032, 4032, 4032), 6.52, 5.0),
        (Generation::Xdna, Precision::I8I16, (4224, 4032, 4224), 5.85, 5.0),
        (Generation::Xdna, Precision::I8I32, (4160, 4224, 4224), 4.42, 5.0),
        (Generation::Xdna, Precision::Bf16, (4224, 4032, 4224), 3.12, 5.0),
        (Generation::Xdna2, Precision::I8I8, (4032, 4320, 4608), 37.35, 5.0),
        (Generation::Xdna2, Precision::I8I16, (4096, 4320, 4480), 30.77, 5.0),
        (Generation::Xdna2, Precision::I8I32, (4224, 4224, 4608), 24.74, 8.0),
        (Generation::Xdna2, Precision::Bf16, (4032, 4224, 4608), 14.52, 5.0),
    ];

    #[test]
    fn reproduces_tables_2_and_3_bold_rows() {
        for &(gen, p, (m, k, n), paper, tol) in PAPER_E2E {
            let cfg = balanced_config(gen, p);
            let r = simulate_gemm(&cfg, m, k, n, BdMode::Overlapped);
            let err = 100.0 * (r.tops - paper).abs() / paper;
            assert!(
                err <= tol,
                "{gen}/{p}: {:.2} TOPS vs paper {paper} ({err:.1}% > {tol}%)",
                r.tops
            );
            // Padding must be a no-op at the paper's aligned sizes.
            assert_eq!((r.pm, r.pk, r.pn), (m, k, n));
        }
    }

    #[test]
    fn abft_cost_model_golden() {
        // Pinned against python/tests/test_integrity_model.py: 1024³
        // int8 on XDNA2 — 4 196 352 checksum MACs at 2·32·512·1.8 GHz.
        let est = abft_check_seconds(Generation::Xdna2, Precision::I8I8, 1024, 1024, 1024);
        let golden = 7.114583333333334e-08;
        assert!((est - golden).abs() / golden < 1e-12, "{est}");
        // And the ratio argument that makes ABFT viable: < 0.2% of the
        // GEMM it protects, on both generations.
        for gen in [Generation::Xdna, Generation::Xdna2] {
            let cfg = balanced_config(gen, Precision::I8I8);
            let r = simulate_gemm(&cfg, 1024, 1024, 1024, BdMode::Overlapped);
            let check = abft_check_seconds(gen, Precision::I8I8, 1024, 1024, 1024);
            assert!(check / r.t_total < 0.002, "{gen}: {}", check / r.t_total);
        }
    }

    #[test]
    fn peak_comp_tops_column_matches() {
        // Table 2: XDNA int8-int8 112x112x112 → 6.80; Table 3: XDNA2
        // bf16 112x48x96 → 15.81.
        let c = balanced_config(Generation::Xdna, Precision::I8I8);
        let r = simulate_gemm(&c, 4032, 4032, 4032, BdMode::Overlapped);
        assert!((r.peak_comp_tops - 6.80).abs() < 0.1, "{}", r.peak_comp_tops);
        let c2 = balanced_config(Generation::Xdna2, Precision::Bf16);
        let r2 = simulate_gemm(&c2, 4032, 4224, 4608, BdMode::Overlapped);
        assert!((r2.peak_comp_tops - 15.81).abs() < 0.8, "{}", r2.peak_comp_tops);
    }

    #[test]
    fn sequential_bd_mode_degrades_as_in_sec_533() {
        // Paper: int8-int16 ~4K — 28% slower on XDNA2, 27% on XDNA.
        for (gen, size, paper_drop, tol) in [
            (Generation::Xdna2, (4096, 4320, 4480), 0.28, 0.06),
            (Generation::Xdna, (4224, 4032, 4224), 0.27, 0.06),
        ] {
            let cfg = balanced_config(gen, Precision::I8I16);
            let over = simulate_gemm(&cfg, size.0, size.1, size.2, BdMode::Overlapped);
            let seq = simulate_gemm(&cfg, size.0, size.1, size.2, BdMode::Sequential);
            let drop = 1.0 - seq.tops / over.tops;
            assert!(
                (drop - paper_drop).abs() <= tol,
                "{gen}: drop {drop:.3} vs paper {paper_drop}"
            );
            assert!(seq.bd_stalls > 0 && over.bd_stalls == 0);
        }
    }

    #[test]
    fn kmt_sweep_reproduces_fig6_shape() {
        // Fig. 6a: XDNA bf16 96x56x96 — 1.27 TOPS at k_mt=56, saturating
        // ~3.1 by k_mt=224.
        // k_mt values that divide K=4032 (misaligned k_mt pads K and
        // genuinely costs throughput — covered by `padding_costs_*`).
        let base = balanced_config(Generation::Xdna, Precision::Bf16);
        let mut prev = 0.0;
        let mut results = Vec::new();
        for k_mt in [56, 112, 224, 336, 448] {
            let cfg = crate::tiling::TilingConfig { k_mt, ..base };
            let r = simulate_gemm(&cfg, 4224, 4032, 4224, BdMode::Overlapped);
            assert!(r.tops >= prev - 0.02, "non-monotone at {k_mt}");
            prev = r.tops;
            results.push((k_mt, r.tops));
        }
        let at56 = results[0].1;
        let at224 = results[2].1;
        let at448 = results[4].1;
        assert!((at56 - 1.27).abs() < 0.15, "k_mt=56: {at56}");
        assert!((at224 - 3.12).abs() < 0.15, "k_mt=224: {at224}");
        // Saturation: doubling the chosen k_mt gains <2%.
        assert!(at448 / at224 < 1.02);
    }

    #[test]
    fn col_major_beats_row_major_more_on_xdna2() {
        // Sec. 5.2.3: layout gap is much larger on XDNA2 than XDNA.
        let mut gaps = Vec::new();
        for gen in Generation::ALL {
            let cfg = balanced_config(gen, Precision::I8I16);
            let (m, k, n) = match gen {
                Generation::Xdna => (4224, 4032, 4224),
                Generation::Xdna2 => (4096, 4320, 4480),
            };
            let col = simulate_gemm(&cfg, m, k, n, BdMode::Overlapped);
            let row = simulate_gemm(
                &cfg.with_b_layout(crate::dtype::Layout::RowMajor),
                m,
                k,
                n,
                BdMode::Overlapped,
            );
            assert!(col.tops >= row.tops, "{gen}");
            gaps.push(1.0 - row.tops / col.tops);
        }
        assert!(gaps[1] > gaps[0] + 0.05, "XDNA2 gap {:.3} vs XDNA {:.3}", gaps[1], gaps[0]);
    }

    #[test]
    fn padding_costs_show_in_tops_but_not_padded_tops() {
        let cfg = balanced_config(Generation::Xdna, Precision::Bf16);
        let aligned = simulate_gemm(&cfg, 384, 224, 384, BdMode::Overlapped);
        let ragged = simulate_gemm(&cfg, 385, 225, 385, BdMode::Overlapped);
        assert!(ragged.tops < aligned.tops);
        assert_eq!((ragged.pm, ragged.pk, ragged.pn), (768, 448, 768));
        assert!(ragged.tops_padded > ragged.tops);
    }

    #[test]
    fn trace_is_consistent() {
        let cfg = balanced_config(Generation::Xdna2, Precision::I8I8);
        let r = simulate_gemm(&cfg, 4032, 4320, 4608, BdMode::Overlapped);
        assert!(r.trace.mac_cycles > 0.0);
        assert!(r.trace.total_cycles() * (1.0 - 1e-9) <= r.t_total * cfg.gen.spec().clock_hz);
        assert!(r.trace.mac_utilization() > 0.5, "{}", r.trace.mac_utilization());
        assert_eq!(r.trace.invocations, (7 * 4 * 60) as u64);
    }

    #[test]
    fn dispatch_overrides_elide_exactly_their_phases() {
        let cfg = balanced_config(Generation::Xdna2, Precision::I8I8);
        let (m, k, n) = (4032, 4320, 4608);
        let base = simulate_gemm(&cfg, m, k, n, BdMode::Overlapped);

        // Elided dispatch removes exactly t_dispatch and nothing else.
        let nodisp = simulate_gemm_with(
            &cfg,
            m,
            k,
            n,
            BdMode::Overlapped,
            DispatchOverrides { elide_dispatch: true, ..Default::default() },
        );
        assert_eq!(nodisp.t_dispatch, 0.0);
        assert!((base.t_total - nodisp.t_total - base.t_dispatch).abs() < 1e-12);

        // L2-resident A moves zero A bytes and shortens read + prologue;
        // L2-resident C moves zero C bytes. B (the weights) always reads.
        let fused = simulate_gemm_with(
            &cfg,
            m,
            k,
            n,
            BdMode::Overlapped,
            DispatchOverrides { a_in_l2: true, c_stays_in_l2: true, elide_dispatch: true },
        );
        assert_eq!(fused.a_bytes, 0.0);
        assert_eq!(fused.c_bytes, 0.0);
        assert!(fused.b_bytes == base.b_bytes && fused.b_bytes > 0.0);
        assert!(fused.t_read < base.t_read);
        assert_eq!(fused.t_write, 0.0);
        assert!(fused.t_prologue < base.t_prologue);
        assert!(fused.t_total < base.t_total);
        // Compute work is untouched by residency.
        assert_eq!(fused.t_comp, base.t_comp);

        // Defaults reproduce the isolated dispatch bit for bit.
        let dflt = simulate_gemm_with(&cfg, m, k, n, BdMode::Overlapped, Default::default());
        assert_eq!(dflt.t_total, base.t_total);
        assert_eq!(dflt.a_bytes, base.a_bytes);
    }

    #[test]
    fn native_bfp16_beats_bf16_emulation_on_xdna2() {
        // The DESIGN.md §10 acceptance bar: ≥1.5x simulated throughput
        // over the bf16 balanced design at the paper's Table-3 bf16
        // shape (cross-checked numerically in
        // python/tests/test_bfp16_model.py). Sources of the gap: 512 vs
        // 192 MACs/cycle (Table 1) minus the 12-bit wire's still-real
        // DRAM traffic and the bfp16 grid's padding at this shape.
        let bf16 = balanced_config(Generation::Xdna2, Precision::Bf16);
        let bfp16 = balanced_config(Generation::Xdna2, Precision::Bfp16);
        let (m, k, n) = (4032, 4224, 4608);
        let r_bf = simulate_gemm(&bf16, m, k, n, BdMode::Overlapped);
        let r_bfp = simulate_gemm(&bfp16, m, k, n, BdMode::Overlapped);
        let speedup = r_bfp.tops / r_bf.tops;
        assert!(speedup >= 1.5, "bfp16 {:.2} vs bf16 {:.2}: {speedup:.3}x", r_bfp.tops, r_bf.tops);
        // Not a free lunch: 12-bit elements still move 3/4 of bf16's
        // bytes, so the datapath's 2.67x cannot survive intact.
        assert!(speedup <= 2.3, "{speedup:.3}x suspiciously high — calibration drift");
        // bfp16 DRAM bytes per element are 3/4 of bf16's; same padded
        // problem would make a_bytes compare 0.75x exactly, but the
        // designs pad differently, so just check the direction.
        assert!(r_bfp.a_bytes + r_bfp.b_bytes < r_bf.a_bytes + r_bf.b_bytes);
    }

    #[test]
    fn small_gemm_dominated_by_dispatch() {
        // Low-ARI points of Figs. 7-8: tiny GEMMs are overhead-bound.
        let cfg = balanced_config(Generation::Xdna2, Precision::I8I8);
        let (nm, nk, nn) = cfg.native();
        let r = simulate_gemm(&cfg, nm, nk, nn, BdMode::Overlapped);
        assert!(r.tops < 10.0, "one native tile can't reach steady state: {}", r.tops);
        assert!(r.t_dispatch / r.t_total > 0.3);
    }
}
