//! Effective DRAM bandwidth model (Sec. 5.2.2's mechanism).
//!
//! The NPU reaches DRAM through the ShimTile DMAs, the NPU NoC and the
//! SoC fabric (Sec. 3.1). Short scattered bursts waste most of the stream:
//! the paper's whole `k_mt` mechanism exists to lengthen contiguous reads.
//! We model
//!
//! ```text
//! BW_eff(x) = BW_max · x / (x + x₀)
//! ```
//!
//! where `x` is the average contiguous run length in bytes of the access
//! stream (computable exactly from the ShimTile BD — `Bd::
//! avg_contig_run_bytes`) and `(BW_max, x₀)` are per-generation constants
//! fitted to the paper's micro-benchmarks and end-to-end results:
//!
//! * XDNA:  BW_max = 32.4 GB/s, x₀ = 435 B  → BW(448 B) ≈ 16.4 GB/s,
//!   matching the "~15 GB/s" micro-benchmark + Table 2 balance points.
//! * XDNA2: BW_max = 70.5 GB/s, x₀ = 178 B  → BW(432 B) ≈ 50 GB/s,
//!   matching the "~50 GB/s" micro-benchmark + Table 3.
//!
//! Row-major B reads are `n_ct·ty`-byte bursts, but adjacent columns'
//! panels partially coalesce in the NoC; the fitted coalescing factors
//! (XDNA ≈ 2.8 columns, XDNA2 ≈ 1.45) reproduce the paper's sweep-average
//! layout gaps — 4.8/4.4/0.57% on XDNA vs 19.1/25.2/8.7% on XDNA2
//! (Sec. 5.2.3, attributed to "complex interaction between the NPU NoC,
//! the SoC-level fabric and DRAM").

use crate::arch::Generation;

/// Per-generation DRAM path constants (fit: DESIGN.md §5.2).
#[derive(Clone, Copy, Debug)]
pub struct DramModel {
    pub bw_max: f64,
    pub x0_bytes: f64,
    /// Effective number of adjacent column panels whose row-major-B (and
    /// C) bursts coalesce in the NoC.
    pub row_coalesce: f64,
    /// Per-stream ceiling: one matrix's stream rides one MM2S channel per
    /// ShimTile, so it can never exceed `shims × channel width × clock`
    /// regardless of burst length. This is what makes k_mt *saturate*
    /// (Fig. 6: XDNA caps at ~16 GB/s → saturation near k_mt·ty ≈ 430 B,
    /// exactly where the paper stops raising k_mt).
    pub stream_cap: f64,
}

impl DramModel {
    pub fn for_gen(gen: Generation) -> DramModel {
        match gen {
            // stream_cap: 4 shims × 4 B/cycle × 1.0 GHz. row_coalesce
            // calibrated to the paper's 4.8/4.4/0.57% sweep-average
            // layout gaps (Sec. 5.2.3).
            Generation::Xdna => DramModel {
                bw_max: 32.4e9,
                x0_bytes: 435.0,
                row_coalesce: 2.8,
                stream_cap: 16.0e9,
            },
            // stream_cap: 8 shims × 4 B/cycle × 1.8 GHz. XDNA2's NoC/SoC
            // fabric barely coalesces row-major bursts — the reason its
            // layout gaps (19.1/25.2/8.7%) dwarf XDNA's (Sec. 5.2.3).
            Generation::Xdna2 => DramModel {
                bw_max: 70.5e9,
                x0_bytes: 178.0,
                row_coalesce: 1.45,
                stream_cap: 57.6e9,
            },
        }
    }

    /// Effective bandwidth (B/s) at average contiguous run `x` bytes.
    pub fn bw_eff(&self, run_bytes: f64) -> f64 {
        assert!(run_bytes > 0.0, "empty access stream");
        (self.bw_max * run_bytes / (run_bytes + self.x0_bytes)).min(self.stream_cap)
    }

    /// Time to move `bytes` with runs of `run_bytes`.
    pub fn xfer_time(&self, bytes: f64, run_bytes: f64) -> f64 {
        if bytes == 0.0 {
            return 0.0;
        }
        bytes / self.bw_eff(run_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_microbenchmarks() {
        // Sec. 5.2.1: "~15 GB/s and ~50 GB/s for XDNA and XDNA2" when
        // imitating GEMM transfers (k_mt-sized runs).
        let x = DramModel::for_gen(Generation::Xdna);
        let bw = x.bw_eff(448.0) / 1e9;
        assert!((14.0..18.0).contains(&bw), "XDNA {bw}");
        let x2 = DramModel::for_gen(Generation::Xdna2);
        let bw2 = x2.bw_eff(432.0) / 1e9;
        assert!((47.0..53.0).contains(&bw2), "XDNA2 {bw2}");
    }

    #[test]
    fn monotone_and_saturating() {
        let m = DramModel::for_gen(Generation::Xdna2);
        let mut last = 0.0;
        for x in [32.0, 64.0, 128.0, 432.0, 1024.0, 65536.0] {
            let bw = m.bw_eff(x);
            assert!(bw >= last);
            assert!(bw < m.bw_max);
            last = bw;
        }
        // Saturation: the last doubling gains <2%.
        assert!(m.bw_eff(65536.0) / m.bw_eff(32768.0) < 1.02);
    }

    #[test]
    fn stream_cap_creates_finite_saturation_point() {
        // XDNA: the hyperbola crosses the 16 GB/s channel ceiling near
        // 430 B — the paper's chosen k_mt·ty (448 B for int8, 448 B for
        // bf16 at k_mt=224) sits right at saturation.
        let m = DramModel::for_gen(Generation::Xdna);
        assert_eq!(m.bw_eff(2048.0), m.stream_cap);
        assert!(m.bw_eff(400.0) < m.stream_cap);
        let crossover = m.x0_bytes * m.stream_cap / (m.bw_max - m.stream_cap);
        assert!((380.0..480.0).contains(&crossover), "{crossover}");
    }

    #[test]
    fn short_runs_collapse_bandwidth() {
        // The Fig. 6 mechanism: k_mt = k_ct gives a fraction of peak.
        let m = DramModel::for_gen(Generation::Xdna);
        assert!(m.bw_eff(112.0) < 0.45 * m.bw_eff(448.0) * 2.0); // sanity
        assert!(m.bw_eff(112.0) / 1e9 < 7.5);
    }
}
