//! Single-core GEMM kernel cycle model.
//!
//! Fitting the 16 published (kernel size → MACs/cycle) measurements of
//! Tables 1–3 shows they are explained to a couple of percent by
//!
//! ```text
//! cycles(m_ct, k_ct, n_ct) = m_ct·k_ct·n_ct / peak  +  β · m_ct·n_ct
//! ```
//!
//! — ideal pipelined MAC issue plus a per-output-element cost: the paper's
//! "loads/stores for accumulations and ... memory stalls caused by bank
//! conflicts" (Sec. 4.5.1), which is exactly why its IP minimizes
//! `m_ct·n_ct` as the secondary objective. `peak` folds the issue-rate
//! ceiling of each AIE-API mode (int8→int32 and bf16-on-bfp16 modes have
//! lower ceilings). Residuals: ≤1.5% on the bold balanced kernels, ≤8% on
//! the second-ranked rows (see tests).

use crate::arch::Generation;
use crate::dtype::Precision;
use crate::tiling::KernelTile;

/// Fitted per-output-element overhead β (cycles per C element) — DESIGN.md
/// §5.1.
pub fn beta(gen: Generation, p: Precision) -> f64 {
    match (gen, p) {
        (Generation::Xdna, Precision::I8I8) => 0.0895,
        (Generation::Xdna, Precision::I8I16) => 0.148,
        (Generation::Xdna, Precision::I8I32) => 0.21,
        (Generation::Xdna, Precision::Bf16) => 0.117,
        (Generation::Xdna2, Precision::I8I8) => 0.068,
        (Generation::Xdna2, Precision::I8I16) => 0.094,
        (Generation::Xdna2, Precision::I8I32) => 0.105,
        (Generation::Xdna2, Precision::Bf16) => 0.115,
        // Native bfp16 has no published kernels to fit (Sec. 5.3.4 defers
        // it) — projected values: XDNA2 issues at the int8-class rate and
        // stores 12-bit blocks, between the 8-bit (0.068) and 16-bit
        // (0.094) narrows plus the encode's max-reduction; XDNA's
        // decode-to-bf16 emulation sits near bf16 (0.117) plus the
        // in-core repack.
        (Generation::Xdna2, Precision::Bfp16) => 0.085,
        (Generation::Xdna, Precision::Bfp16) => 0.13,
        // The logical fp32_split precision has no kernels of its own —
        // its limb GEMMs run the bf16 design, so cost probes that reach
        // this model (e.g. the optimizer's IP enumeration) see bf16's
        // fitted overhead. The dispatch-count multiple is charged at the
        // scheduling layer, never here.
        (Generation::Xdna, Precision::Fp32Split) => 0.117,
        (Generation::Xdna2, Precision::Fp32Split) => 0.115,
    }
}

/// Kernel execution cycles for one `m_ct × k_ct × n_ct` invocation
/// (includes the bank-conflict stalls hardware tracing would see).
pub fn kernel_cycles(gen: Generation, p: Precision, t: &KernelTile) -> f64 {
    let peak = gen.spec().peak_macs_per_cycle(p);
    t.macs() as f64 / peak + beta(gen, p) * t.out_elems() as f64
}

/// Achieved single-core throughput in MACs/cycle (Table 1/2/3 column).
pub fn macs_per_cycle(gen: Generation, p: Precision, t: &KernelTile) -> f64 {
    t.macs() as f64 / kernel_cycles(gen, p, t)
}

/// Single-core efficiency `eff` (Sec. 4.5.1): attained / peak throughput.
/// Because all cores run the same kernel independently, this is also the
/// whole-array efficiency used in Eq. 9.
pub fn efficiency(gen: Generation, p: Precision, t: &KernelTile) -> f64 {
    macs_per_cycle(gen, p, t) / gen.spec().peak_macs_per_cycle(p)
}

/// Vectorized zeroing-kernel cycles (Sec. 4.2.1): runs once per complete
/// K-reduction to re-initialize the stationary C tile. Full-width vector
/// stores move 128 B/cycle (keeps every published kernel under the
/// paper's "<10% of GEMM kernel time").
pub fn zeroing_cycles(p: Precision, t: &KernelTile) -> f64 {
    p.bytes_out(t.out_elems() as usize) as f64 / 128.0
}

/// C-tile drain cycles with the single-buffer design (Sec. 5.3.2): the
/// L1→L2 DMA moves `dma_bytes_per_cycle` and the core must wait before
/// re-zeroing (no second buffer to compute into).
pub fn c_drain_cycles(gen: Generation, p: Precision, t: &KernelTile) -> f64 {
    p.bytes_out(t.out_elems() as usize) as f64 / gen.spec().dma_bytes_per_cycle
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Generation::{Xdna, Xdna2};
    use crate::dtype::Precision::*;

    /// Every throughput number the paper publishes for single-core kernels.
    /// (gen, precision, kernel, paper MACs/cycle, tolerance %)
    const PAPER_KERNELS: &[(Generation, Precision, (usize, usize, usize), f64, f64)] = &[
        // Table 1 (single-core optima).
        (Xdna, I8I8, (64, 232, 64), 233.0, 2.0),
        (Xdna, I8I16, (64, 216, 64), 217.6, 2.0),
        (Xdna, I8I32, (48, 280, 48), 192.0, 2.0),
        (Xdna, Bf16, (64, 104, 64), 112.6, 2.0),
        (Xdna2, I8I8, (64, 232, 64), 450.6, 2.0),
        (Xdna2, I8I16, (64, 216, 64), 419.8, 2.0),
        (Xdna2, I8I32, (48, 280, 48), 384.0, 2.0),
        (Xdna2, Bf16, (48, 152, 48), 158.1, 7.0),
        // Table 2 (XDNA balanced + runners-up).
        (Xdna, I8I8, (112, 112, 112), 212.5, 2.0),
        (Xdna, I8I8, (112, 104, 128), 207.4, 2.0),
        (Xdna, I8I16, (96, 112, 96), 192.0, 2.0),
        (Xdna, I8I16, (80, 104, 128), 186.9, 2.0),
        (Xdna, I8I32, (80, 88, 96), 146.0, 2.0),
        (Xdna, I8I32, (64, 80, 128), 133.1, 8.0),
        (Xdna, Bf16, (96, 56, 96), 99.8, 2.0),
        (Xdna, Bf16, (96, 48, 112), 97.3, 2.0),
        // Table 3 (XDNA2 balanced + runners-up).
        (Xdna2, I8I8, (144, 72, 144), 343.0, 2.0),
        (Xdna2, I8I8, (160, 64, 144), 322.6, 3.5),
        (Xdna2, I8I16, (128, 72, 112), 307.2, 2.0),
        (Xdna2, I8I16, (160, 64, 96), 271.4, 8.0),
        (Xdna2, I8I32, (96, 64, 96), 256.0, 2.0),
        // The 128x56x80 runner-up is the one published point the two-term
        // model cannot reconcile with its siblings (fitting it exactly
        // would break 48x280x48 and 96x64x96); see DESIGN.md §5.1.
        (Xdna2, I8I32, (128, 56, 80), 209.9, 17.0),
        (Xdna2, Bf16, (112, 48, 96), 137.2, 5.0),
        (Xdna2, Bf16, (160, 40, 80), 124.1, 2.0),
    ];

    #[test]
    fn cycle_model_reproduces_all_published_kernels() {
        for &(gen, p, (m, k, n), paper, tol) in PAPER_KERNELS {
            let t = KernelTile::new(m, k, n);
            let got = macs_per_cycle(gen, p, &t);
            let err = 100.0 * (got - paper).abs() / paper;
            assert!(
                err <= tol,
                "{gen}/{p} {m}x{k}x{n}: model {got:.1} vs paper {paper:.1} ({err:.1}% > {tol}%)"
            );
        }
    }

    #[test]
    fn efficiency_in_unit_range_and_monotonic_in_kct() {
        // Larger k_ct amortizes the per-output overhead → higher eff.
        let gen = Xdna2;
        let mut last = 0.0;
        for k_ct in [8, 24, 72, 144, 288] {
            let e = efficiency(gen, I8I8, &KernelTile::new(64, k_ct, 64));
            assert!(e > 0.0 && e < 1.0);
            assert!(e > last, "eff must rise with k_ct");
            last = e;
        }
    }

    #[test]
    fn smaller_output_tile_higher_efficiency_at_fixed_macs() {
        // The IP's secondary objective: at (roughly) constant MACs, the
        // kernel with the smaller m_ct·n_ct wins.
        let big_out = KernelTile::new(160, 64, 144); // mn = 23040
        let small_out = KernelTile::new(144, 72, 144); // mn = 20736
        assert!(
            efficiency(Xdna2, I8I8, &small_out) > efficiency(Xdna2, I8I8, &big_out)
        );
    }

    #[test]
    fn zeroing_is_small_fraction_of_kernel() {
        // Sec. 5.2.1 cites "<10% of GEMM kernel time" for the XDNA2
        // int8-int8 160x64x144 example; the wide-output int32 kernels run
        // a little hotter but stay "typically" small.
        let cited = KernelTile::new(160, 64, 144);
        let frac = zeroing_cycles(I8I8, &cited) / kernel_cycles(Xdna2, I8I8, &cited);
        assert!(frac < 0.10, "cited example: {frac:.3}");
        for &(gen, p, (m, k, n), _, _) in PAPER_KERNELS {
            let t = KernelTile::new(m, k, n);
            let frac = zeroing_cycles(p, &t) / kernel_cycles(gen, p, &t);
            assert!(frac < 0.15, "{gen}/{p} {m}x{k}x{n}: zeroing {frac:.3}");
        }
    }
}
