//! The paper's two-stage optimization methodology (Sec. 4.5).
//!
//! * [`ip`] — single-core integer program (Sec. 4.5.1): exhaustively
//!   maximize kernel MACs (tie-break: minimize the output tile) under the
//!   DMA-bandwidth (Eq. 4) and L1-capacity (Eq. 5) constraints.
//! * [`balanced`] — system-level balanced-point search (Sec. 4.5.2):
//!   walk `k_ct` down from the compute-optimal kernel, re-solve the IP per
//!   step with the `m_ct·n_ct`-maximizing objective, "measure" each
//!   candidate on the calibrated simulator, and stop at the first
//!   performance drop — compute and memory are then balanced.
//!
//! [`balanced::optimize_skinny`] runs the skinny-M variant of the search
//! (ISSUE 7): kernel M fixed at `SKINNY_M_MAX / m_rows`, candidates
//! ranked at the decode-batch M instead of the 4K square, Eq. 4 waived
//! (every skinny kernel is DMA-bound by construction).

pub mod balanced;
pub mod ip;

pub use balanced::{
    eval_size_for, optimize_balanced, optimize_skinny, BalancedOptions, BalancedResult,
};
pub use ip::{solve_single_core, IpObjective, IpOptions, IpSolution};
