//! System-level balanced-point search (Sec. 4.5.2).
//!
//! The single-core optimum (huge `k_ct`, small `m_ct·n_ct`) is *memory
//! bound* at the system level — Eqs. 6–7 put `m_ct`, `n_ct` in the
//! denominator of DRAM traffic. The paper's procedure walks toward the
//! balance point:
//!
//! 1. start from the single-core IP winner and verify the GEMM is memory
//!    bound;
//! 2. each iteration: *decrease* `k_ct` by one `s`-step, re-solve the IP
//!    with fixed `k_ct` maximizing `m_ct·n_ct` (the smallest possible
//!    `T_comp` increase with the biggest traffic reduction), pick the
//!    saturating `k_mt` (Sec. 5.2.2), and **measure** (here: simulate) the
//!    top-ranked design at the evaluation size;
//! 3. stop at the first performance drop — the previous iterate is the
//!    balanced optimum (`T_comp ≈ T_mem`).

use anyhow::{bail, Result};

use crate::arch::Generation;
use crate::dtype::{Layout, Precision};
use crate::sim::{simulate_gemm, BdMode, GemmReport};
use crate::tiling::{round_up, TilingConfig};

use super::ip::{solve_single_core, IpObjective, IpOptions, STEP_K, STEP_N};

#[derive(Clone, Copy, Debug)]
pub struct BalancedOptions {
    pub b_layout: Layout,
    pub c_double_buffered: bool,
    /// Evaluation GEMM target (~4K square like the paper); rounded up to
    /// each candidate's native grid.
    pub eval_size: usize,
    /// k_mt saturation threshold: pick the smallest k_mt whose simulated
    /// TOPS is within this fraction of the best feasible k_mt's.
    pub kmt_saturation: f64,
    /// Cap on k_mt multiples explored (L2 capacity prunes anyway).
    pub max_kmt_multiple: usize,
    /// Override the evaluation M (rounded up to the candidate's native
    /// M). `None` evaluates at `eval_size` in all three dimensions — the
    /// paper's large-M regime. The skinny-M search
    /// ([`optimize_skinny`]) sets this to the decode-batch M so
    /// candidates are ranked on the workload they will actually serve.
    pub eval_m: Option<usize>,
}

impl Default for BalancedOptions {
    fn default() -> Self {
        BalancedOptions {
            b_layout: Layout::ColMajor,
            c_double_buffered: false,
            eval_size: 4000,
            kmt_saturation: 0.99,
            max_kmt_multiple: 16,
            eval_m: None,
        }
    }
}

/// One measured iteration of the search.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    pub cfg: TilingConfig,
    pub eval: (usize, usize, usize),
    pub tops: f64,
    pub memory_bound: bool,
}

#[derive(Clone, Debug)]
pub struct BalancedResult {
    pub winner: TilingConfig,
    pub winner_report: GemmReport,
    pub eval: (usize, usize, usize),
    pub history: Vec<IterationRecord>,
}

/// Evaluation size for a config: the paper evaluates at "~4K" GEMMs that
/// are exact multiples of the native size.
pub fn eval_size_for(cfg: &TilingConfig, target: usize) -> (usize, usize, usize) {
    let (nm, nk, nn) = cfg.native();
    (round_up(target, nm), round_up(target, nk), round_up(target, nn))
}

/// Evaluation dimensions honoring `opts.eval_m` (skinny-M searches rank
/// candidates at the decode-batch M, not the 4K square).
fn eval_dims(cfg: &TilingConfig, opts: &BalancedOptions) -> (usize, usize, usize) {
    let (m, k, n) = eval_size_for(cfg, opts.eval_size);
    match opts.eval_m {
        Some(em) => (round_up(em, cfg.native().0), k, n),
        None => (m, k, n),
    }
}

/// Pick the contiguity parameter k_mt (Sec. 5.2.2): smallest multiple of
/// `k_ct` at which performance saturates, subject to L2 capacity.
pub fn choose_kmt(
    gen: Generation,
    p: Precision,
    kernel: crate::tiling::KernelTile,
    opts: &BalancedOptions,
) -> Result<TilingConfig> {
    let spec = gen.spec();
    let mut candidates = Vec::new();
    for mult in 1..=opts.max_kmt_multiple {
        let k_mt = kernel.k_ct * mult;
        let cfg = TilingConfig::new(
            gen,
            p,
            kernel.m_ct,
            kernel.k_ct,
            kernel.n_ct,
            k_mt,
            spec.array_rows,
            spec.shim_cols,
            opts.b_layout,
        );
        match cfg {
            Ok(c) => {
                let c = c.with_c_double_buffered(opts.c_double_buffered);
                let (m, k, n) = eval_dims(&c, opts);
                let r = simulate_gemm(&c, m, k, n, BdMode::Overlapped);
                candidates.push((c, r.tops));
            }
            Err(_) => break, // L2 exhausted (incl. neighbor-sharing rule)
        }
    }
    if candidates.is_empty() {
        bail!("no feasible k_mt for kernel {}", kernel.label());
    }
    let best = candidates.iter().map(|(_, t)| *t).fold(0.0, f64::max);
    let chosen = candidates
        .iter()
        .find(|(_, t)| *t >= opts.kmt_saturation * best)
        .unwrap();
    Ok(chosen.0)
}

/// Run the full Sec. 4.5.2 procedure.
pub fn optimize_balanced(
    gen: Generation,
    p: Precision,
    opts: &BalancedOptions,
) -> Result<BalancedResult> {
    // Starting point: the single-core optimum (Sec. 4.5.1).
    let ip_opts = IpOptions { c_double_buffered: opts.c_double_buffered, ..Default::default() };
    let start = solve_single_core(gen, p, &ip_opts, 1);
    let Some(start) = start.first() else {
        bail!("single-core IP found no feasible kernel for {gen}/{p}")
    };

    let mut history: Vec<IterationRecord> = Vec::new();

    let measure = |cfg: &TilingConfig, history: &mut Vec<IterationRecord>| {
        let eval = eval_dims(cfg, opts);
        let r = simulate_gemm(cfg, eval.0, eval.1, eval.2, BdMode::Overlapped);
        history.push(IterationRecord {
            cfg: *cfg,
            eval,
            tops: r.tops,
            memory_bound: matches!(r.bound, crate::sim::engine::Bound::Memory),
        });
        r.tops
    };

    // Iteration 0: the compute-optimal kernel (expected memory bound).
    let cfg0 = choose_kmt(gen, p, start.tile, opts)?;
    let tops0 = measure(&cfg0, &mut history);
    let mut best: Option<(TilingConfig, f64)> = Some((cfg0, tops0));

    // Walk k_ct downward.
    let mut k_ct = start.tile.k_ct;
    while k_ct > STEP_K {
        k_ct -= STEP_K;
        let sols = solve_single_core(
            gen,
            p,
            &IpOptions {
                objective: IpObjective::MaxOutputTile { k_ct },
                c_double_buffered: opts.c_double_buffered,
                ..Default::default()
            },
            1,
        );
        let Some(sol) = sols.first() else { continue };
        let Ok(cfg) = choose_kmt(gen, p, sol.tile, opts) else { continue };
        let tops = measure(&cfg, &mut history);
        let (_, best_tops) = best.unwrap();
        let rec = history.last().unwrap();
        if tops > best_tops {
            best = Some((cfg, tops));
        }
        // Stop condition (Sec. 4.5.2): performance dropped *and* the GEMM
        // has become compute bound — compute and memory crossed, the best
        // iterate so far is the balanced point. (Plateau noise while still
        // memory bound is not the crossover; keep walking.)
        if !rec.memory_bound && tops < best_tops {
            break;
        }
    }

    let (winner, _) = best.unwrap();
    let eval = eval_dims(&winner, opts);
    let winner_report = simulate_gemm(&winner, eval.0, eval.1, eval.2, BdMode::Overlapped);
    Ok(BalancedResult { winner, winner_report, eval, history })
}

/// Skinny-M balanced search (ISSUE 7): dedicated designs for coalesced
/// decode batches (`M <= arch::SKINNY_M_MAX`).
///
/// The Sec. 4.5.2 walk does not transfer to this regime:
///
/// * the kernel M-tile is *fixed* by the class — `SKINNY_M_MAX /
///   m_rows = 16` — so one array pass covers the whole batch and no M
///   padding beyond the class cap is ever paid;
/// * Eq. 4 is deliberately **not** enforced. It requires kernel compute
///   cycles to cover the B-panel DMA (`k_ct·n_ct` bytes), which at
///   `m_ct = 16` would need ~3.5× more MACs than the tile has (XDNA2
///   int8 needs `m_ct ≳ 56`): every skinny kernel is inherently
///   DMA-bound, and pruning on Eq. 4 would reject the entire class.
///   The search ranks candidates by *simulated* throughput at the
///   decode-batch M instead, which prices the DMA bound in directly.
///
/// The scan fixes `m_ct = 16`, sweeps `k_ct`, takes the largest
/// L1-feasible `n_ct` for each (A and C tiles are tiny at m=16, so L1
/// slack goes to the B panel), and reuses [`choose_kmt`] — evaluated at
/// `eval_m` (default `SKINNY_M_MAX`) — for the contiguity parameter.
/// The landscape is flat: with one native-M block, B streams from DRAM
/// exactly once regardless of kernel shape, so B traffic — the dominant
/// term — is invariant and candidates differ only in overheads. The
/// shipped `arch::skinny_balanced_config` table sits on this plateau
/// (pinned loosely in tests, like the wide table).
pub fn optimize_skinny(
    gen: Generation,
    p: Precision,
    opts: &BalancedOptions,
) -> Result<BalancedResult> {
    let spec = gen.spec();
    let m_ct = crate::arch::SKINNY_M_MAX / spec.array_rows;
    let opts = &BalancedOptions {
        eval_m: Some(opts.eval_m.unwrap_or(crate::arch::SKINNY_M_MAX)),
        ..*opts
    };
    let budget = spec.l1_budget();
    let c_bufs = if opts.c_double_buffered { 2 } else { 1 };
    let (in_bits, out_bits) = (p.in_bits(), p.out_bits());

    let mut history: Vec<IterationRecord> = Vec::new();
    let mut best: Option<(TilingConfig, f64)> = None;
    let mut k_ct = STEP_K;
    while k_ct <= 1024 {
        // Largest n_ct under the bit-exact L1 bound (Eq. 5):
        // 2·m·k·in + 2·k·n·in + c_bufs·m·n·out <= budget.
        let a_term = 2 * m_ct * k_ct * in_bits;
        if a_term >= budget * 8 {
            break;
        }
        let n_cap = (budget * 8 - a_term) / (2 * k_ct * in_bits + c_bufs * m_ct * out_bits);
        let n_ct = ((n_cap / STEP_N) * STEP_N).min(256);
        if n_ct < STEP_N {
            break;
        }
        let kernel = crate::tiling::KernelTile::new(m_ct, k_ct, n_ct);
        // No eq4_ok here — see the function docs.
        if let Ok(cfg) = choose_kmt(gen, p, kernel, opts) {
            let eval = eval_dims(&cfg, opts);
            let r = simulate_gemm(&cfg, eval.0, eval.1, eval.2, BdMode::Overlapped);
            history.push(IterationRecord {
                cfg,
                eval,
                tops: r.tops,
                memory_bound: matches!(r.bound, crate::sim::engine::Bound::Memory),
            });
            let better = match best {
                None => true,
                Some((_, t)) => r.tops > t,
            };
            if better {
                best = Some((cfg, r.tops));
            }
        }
        k_ct += STEP_K;
    }

    let Some((winner, _)) = best else {
        bail!("skinny search found no feasible kernel for {gen}/{p}")
    };
    let eval = eval_dims(&winner, opts);
    let winner_report = simulate_gemm(&winner, eval.0, eval.1, eval.2, BdMode::Overlapped);
    Ok(BalancedResult { winner, winner_report, eval, history })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{balanced_config, Generation};
    use crate::sim::engine::Bound;

    #[test]
    fn search_starts_memory_bound_and_ends_balanced() {
        let r = optimize_balanced(
            Generation::Xdna2,
            Precision::I8I16,
            &BalancedOptions::default(),
        )
        .unwrap();
        // The compute-optimal starting kernel must be memory bound
        // (Sec. 5.2.1: 17.86 TOPS vs the 30.77 balanced kernel).
        assert!(r.history.first().unwrap().memory_bound);
        // The search must improve on it substantially.
        let start_tops = r.history.first().unwrap().tops;
        assert!(r.winner_report.tops > 1.4 * start_tops);
    }

    #[test]
    fn winner_matches_paper_balance_point_within_tolerance() {
        // The search optimizes *our* simulator, so its winner must be at
        // least as good as the paper's published balanced config under the
        // same simulator, and the paper's config must be close (the search
        // landscape near the optimum is flat).
        for gen in Generation::ALL {
            for p in Precision::ALL {
                let res = optimize_balanced(gen, p, &BalancedOptions::default()).unwrap();
                let paper = balanced_config(gen, p);
                let eval = eval_size_for(&paper, 4000);
                let paper_tops =
                    simulate_gemm(&paper, eval.0, eval.1, eval.2, BdMode::Overlapped).tops;
                assert!(
                    res.winner_report.tops >= paper_tops * 0.97,
                    "{gen}/{p}: search {:.2} vs paper cfg {:.2}",
                    res.winner_report.tops,
                    paper_tops
                );
                // Gross-drift guard only: the search optimizes *this*
                // simulator, whose landscape near the flat optimum differs
                // from the authors' hardware by a few percent (it also
                // legitimately exploits k_mt headroom beyond the paper's
                // saturation choice — see DESIGN.md §5.2).
                assert!(
                    paper_tops >= res.winner_report.tops * 0.80,
                    "{gen}/{p}: paper cfg {paper_tops:.2} too far below search {:.2} — \
                     calibration drift",
                    res.winner_report.tops
                );
            }
        }
    }

    #[test]
    fn bfp16_search_confirms_the_shipped_configs() {
        // The bfp16 rows of `arch::balanced_config` are this repo's own
        // balanced-search winners (native bfp16 has no paper row). Keep
        // them honest against the live search on both generations: the
        // search may drift a little (flat optimum), never a lot.
        for gen in Generation::ALL {
            let res =
                optimize_balanced(gen, Precision::Bfp16, &BalancedOptions::default()).unwrap();
            let shipped = balanced_config(gen, Precision::Bfp16);
            let eval = eval_size_for(&shipped, 4000);
            let shipped_tops =
                simulate_gemm(&shipped, eval.0, eval.1, eval.2, BdMode::Overlapped).tops;
            assert!(
                res.winner_report.tops >= shipped_tops * 0.97,
                "{gen}: search {:.2} below shipped {shipped_tops:.2}",
                res.winner_report.tops
            );
            assert!(
                shipped_tops >= res.winner_report.tops * 0.80,
                "{gen}: shipped {shipped_tops:.2} far below search {:.2} — update arch.rs",
                res.winner_report.tops
            );
            // And the search trajectory starts memory-bound, exactly
            // like the byte precisions (Sec. 4.5.2).
            assert!(res.history.first().unwrap().memory_bound, "{gen}");
        }
    }

    #[test]
    fn winner_is_near_balance() {
        // At the winner, T_comp and T_mem are within ~35% of each other
        // (the k_ct grid is coarse, exact equality is not attainable).
        let r = optimize_balanced(
            Generation::Xdna,
            Precision::Bf16,
            &BalancedOptions::default(),
        )
        .unwrap();
        let rep = &r.winner_report;
        let ratio = rep.t_comp / rep.t_mem;
        assert!((0.65..1.55).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn kmt_chooser_prefers_smallest_saturating() {
        // Paper picks k_mt=224 for XDNA bf16 96x56x96 — 4 multiples of 56.
        let cfg = choose_kmt(
            Generation::Xdna,
            Precision::Bf16,
            crate::tiling::KernelTile::new(96, 56, 96),
            &BalancedOptions::default(),
        )
        .unwrap();
        assert!(
            cfg.k_mt >= 168 && cfg.k_mt <= 336,
            "k_mt {} not near the paper's 224",
            cfg.k_mt
        );
    }

    #[test]
    fn double_buffered_c_costs_end_to_end_performance() {
        // Ablation A3 (Sec. 5.3.2): 18% on XDNA2 int8-int16, 13% on XDNA
        // bf16. Tolerances are loose — the search re-optimizes around the
        // constraint.
        for (gen, p, paper_gain) in [
            (Generation::Xdna2, Precision::I8I16, 1.18),
            (Generation::Xdna, Precision::Bf16, 1.13),
        ] {
            let single = optimize_balanced(gen, p, &BalancedOptions::default()).unwrap();
            let dbl = optimize_balanced(
                gen,
                p,
                &BalancedOptions { c_double_buffered: true, ..Default::default() },
            )
            .unwrap();
            let gain = single.winner_report.tops / dbl.winner_report.tops;
            assert!(
                gain > 1.02 && (gain - paper_gain).abs() < 0.15,
                "{gen}/{p}: single/double gain {gain:.3} vs paper {paper_gain}"
            );
        }
    }

    #[test]
    fn skinny_search_finds_the_decode_batch_plateau() {
        // The skinny landscape is flat (one native-M block → B streams
        // once regardless of kernel shape), so the shipped table must sit
        // within loose factors of the live search winner — and both must
        // clearly beat the wide paper config at decode-batch M, which
        // pads M 5–17x.
        use crate::arch::{skinny_balanced_config, SKINNY_M_MAX};
        for (gen, p) in [
            (Generation::Xdna2, Precision::I8I8),
            (Generation::Xdna, Precision::Bf16),
            (Generation::Xdna2, Precision::Bfp16),
        ] {
            let res = optimize_skinny(gen, p, &BalancedOptions::default()).unwrap();
            assert!(!res.history.is_empty());
            assert_eq!(res.winner.native().0, SKINNY_M_MAX, "{gen}/{p}");
            assert_eq!(res.eval.0, SKINNY_M_MAX, "ranked at the decode-batch M");
            for rec in &res.history {
                assert!(rec.cfg.validate().is_ok());
                assert_eq!(rec.cfg.kernel.m_ct, 16);
            }
            let shipped = skinny_balanced_config(gen, p);
            let eval = res.eval;
            let shipped_tops =
                simulate_gemm(&shipped, eval.0, eval.1, eval.2, BdMode::Overlapped).tops;
            assert!(
                res.winner_report.tops >= 0.7 * shipped_tops,
                "{gen}/{p}: search {:.3} far below shipped {shipped_tops:.3}",
                res.winner_report.tops
            );
            assert!(
                shipped_tops >= 0.5 * res.winner_report.tops,
                "{gen}/{p}: shipped {shipped_tops:.3} far below search {:.3} — \
                 update arch::skinny_balanced_config",
                res.winner_report.tops
            );
            // The class exists because the wide design wastes the array at
            // decode M. The gap is bounded: B traffic (the dominant term)
            // is identical — at M=64 both classes stream B exactly once,
            // since `b_bytes = pm·pk·pn·ty/(m_ct·m_rows)` and wide's pm
            // is its own native M — so skinny wins on A traffic, padded
            // compute and prologue only. Measured ratios: 1.70x (XDNA2
            // int8), 1.83x (XDNA bf16), 1.70x (XDNA2 bfp16); pin at 1.5x.
            let wide = balanced_config(gen, p);
            let wide_tops =
                simulate_gemm(&wide, SKINNY_M_MAX, eval.1, eval.2, BdMode::Overlapped).tops;
            assert!(
                res.winner_report.tops >= 1.5 * wide_tops,
                "{gen}/{p}: skinny {:.3} vs wide {wide_tops:.3} at M={SKINNY_M_MAX}",
                res.winner_report.tops
            );
            // The shipped table itself must also beat wide, not just the
            // live search winner.
            assert!(shipped_tops > wide_tops, "{gen}/{p}: shipped skinny loses to wide");
        }
    }

    #[test]
    fn skinny_search_would_be_empty_under_eq4() {
        // Documentation-as-test for why optimize_skinny skips Eq. 4: at
        // m_ct = 16 the kernel has too few MACs to cover the B-panel DMA,
        // so the wide IP (which enforces Eq. 4) never returns an m=16
        // kernel even when the grid is clamped to it.
        use super::super::ip::{solve_single_core, IpOptions};
        for gen in Generation::ALL {
            let sols = solve_single_core(
                gen,
                Precision::I8I8,
                &IpOptions { max_m: 16, ..Default::default() },
                10_000,
            );
            assert!(
                sols.is_empty(),
                "{gen}: Eq. 4 should prune every m_ct<=16 kernel, got {:?}",
                sols.first().map(|s| s.tile)
            );
        }
    }

    #[test]
    fn history_records_the_crossover() {
        let r = optimize_balanced(
            Generation::Xdna2,
            Precision::I8I8,
            &BalancedOptions::default(),
        )
        .unwrap();
        assert!(r.history.len() >= 3, "needs a few iterations");
        // Winner's bound can be either side of the knife edge, but the
        // first iterate is memory-bound and some iterate is compute-bound.
        assert!(r.history.iter().any(|h| h.memory_bound));
        assert!(r.history.iter().any(|h| !h.memory_bound));
    }
}
