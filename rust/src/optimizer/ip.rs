//! Single-core kernel integer program (Sec. 4.5.1).
//!
//! Exhaustive search over `m_ct × k_ct × n_ct` (the paper: "The IP is
//! solved exhaustively ... the exhaustive search takes less than 1 s").
//!
//! Constraints:
//! * micro-tile alignment — the search grid steps by `(4, 8, 8)`: the
//!   mode shapes of the AIE API plus the 32-bit DMA granularity and the
//!   16-byte vector-store alignment (bf16 modes are `r×s×t = 4×8×4`, but
//!   efficient stores want `n_ct` multiples of 8 — this also matches every
//!   kernel size published in the paper);
//! * Eq. 4 — kernel must not be DMA-bandwidth-bound (A and B arrive at
//!   `dma_bytes_per_cycle` while the kernel computes);
//! * Eq. 5 — L1 capacity with double-buffered A/B and (by default)
//!   single-buffered C.
//!
//! Objectives (Sec. 4.5.1 / 4.5.2):
//! * `MaxThroughput` — the Table-1 objective. The paper words it as
//!   "maximize MACs, tie-break minimize `m_ct·n_ct`", justified as
//!   "maximizing the overall efficiency"; taken literally, max-MACs
//!   selects a balanced-shaped kernel (`~144×72×148`) that contradicts
//!   the published winners, so we optimize the stated *intent* directly:
//!   maximize modeled MACs/cycle (which rewards large `k_ct` and small
//!   `m_ct·n_ct` exactly as the paper describes). The optimum is flat —
//!   winners match the published kernels' throughput to <1% (tests).
//! * `MaxOutputTile` — fixed `k_ct`, maximize `m·n`, tie-break maximize
//!   MACs (the per-iteration objective of the balanced search).

use crate::arch::Generation;
use crate::dtype::Precision;
use crate::sim::core;
use crate::tiling::KernelTile;

/// Search grid steps (see module docs).
pub const STEP_M: usize = 4;
pub const STEP_K: usize = 8;
pub const STEP_N: usize = 8;

#[derive(Clone, Copy, Debug)]
pub enum IpObjective {
    /// Maximize single-core throughput (Sec. 4.5.1; see module docs).
    MaxThroughput,
    /// Fix `k_ct`; maximize `m_ct·n_ct`; tie-break max MACs (Sec. 4.5.2).
    MaxOutputTile { k_ct: usize },
}

#[derive(Clone, Copy, Debug)]
pub struct IpOptions {
    pub objective: IpObjective,
    /// Upper bounds of the search grid (generous; L1 prunes harder).
    pub max_m: usize,
    pub max_n: usize,
    pub max_k: usize,
    /// Double-buffer C (ablation A3) instead of the paper's single buffer.
    pub c_double_buffered: bool,
}

impl Default for IpOptions {
    fn default() -> Self {
        IpOptions {
            objective: IpObjective::MaxThroughput,
            max_m: 256,
            max_n: 256,
            max_k: 1024,
            c_double_buffered: false,
        }
    }
}

/// One ranked solution.
#[derive(Clone, Copy, Debug)]
pub struct IpSolution {
    pub tile: KernelTile,
    pub macs: u64,
    pub out_elems: u64,
    pub macs_per_cycle: f64,
    pub efficiency: f64,
    pub l1_bytes: usize,
}

impl IpSolution {
    fn build(gen: Generation, p: Precision, t: KernelTile, c_dbl: bool) -> IpSolution {
        IpSolution {
            tile: t,
            macs: t.macs(),
            out_elems: t.out_elems(),
            macs_per_cycle: core::macs_per_cycle(gen, p, &t),
            efficiency: core::efficiency(gen, p, &t),
            l1_bytes: t.l1_bytes(p, c_dbl),
        }
    }
}

/// Eq. 4 with the calibrated cycle model standing in for
/// `eff · peak_MACs`: kernel cycles must cover both input DMA times.
fn eq4_ok(gen: Generation, p: Precision, t: &KernelTile) -> bool {
    let spec = gen.spec();
    let cycles = core::kernel_cycles(gen, p, t);
    let ca = (t.m_ct * t.k_ct) as f64 * p.in_bytes_f() / spec.dma_bytes_per_cycle;
    let cb = (t.k_ct * t.n_ct) as f64 * p.in_bytes_f() / spec.dma_bytes_per_cycle;
    cycles >= ca && cycles >= cb
}

/// Exhaustively solve the IP; returns the `top` best solutions in rank
/// order.
pub fn solve_single_core(
    gen: Generation,
    p: Precision,
    opts: &IpOptions,
    top: usize,
) -> Vec<IpSolution> {
    let spec = gen.spec();
    let budget = spec.l1_budget();
    let mut solutions: Vec<IpSolution> = Vec::new();

    let (k_lo, k_hi, k_step) = match opts.objective {
        IpObjective::MaxThroughput => (STEP_K, opts.max_k, STEP_K),
        IpObjective::MaxOutputTile { k_ct } => (k_ct, k_ct, STEP_K),
    };

    let c_bufs = if opts.c_double_buffered { 2 } else { 1 };
    // Work in *bits* so the bound is exact for bfp16's 12-bit amortized
    // elements too (byte-granular precisions reduce to the old formula).
    let in_bits = p.in_bits();
    let out_bits = p.out_bits();

    let mut m = STEP_M;
    while m <= opts.max_m {
        let mut n = STEP_N;
        while n <= opts.max_n {
            // For fixed (m, n) the L1 bound gives the max k directly:
            // 2·m·k·ty + 2·k·n·ty + c_bufs·m·n·ty_out <= budget.
            let c_term = c_bufs * m * n * out_bits;
            if c_term < budget * 8 {
                let k_cap = (budget * 8 - c_term) / (2 * in_bits * (m + n));
                let k_max = (k_cap / STEP_K) * STEP_K;
                let hi = k_max.min(k_hi);
                let mut k = k_lo;
                while k <= hi {
                    let t = KernelTile::new(m, k, n);
                    if eq4_ok(gen, p, &t) {
                        solutions.push(IpSolution::build(gen, p, t, opts.c_double_buffered));
                    }
                    k += k_step;
                }
            }
            n += STEP_N;
        }
        m += STEP_M;
    }

    match opts.objective {
        IpObjective::MaxThroughput => {
            solutions.sort_by(|a, b| {
                b.macs_per_cycle
                    .partial_cmp(&a.macs_per_cycle)
                    .unwrap()
                    .then(a.out_elems.cmp(&b.out_elems))
                    .then(b.macs.cmp(&a.macs))
            });
        }
        IpObjective::MaxOutputTile { .. } => {
            solutions.sort_by(|a, b| {
                b.out_elems.cmp(&a.out_elems).then(b.macs.cmp(&a.macs))
            });
        }
    }
    solutions.truncate(top);
    solutions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Generation::{Xdna, Xdna2};
    use crate::dtype::Precision::*;

    #[test]
    fn matches_table1_throughput_within_one_percent() {
        // The optimum is flat: the IP's winner must achieve the published
        // Table-1 kernel's modeled throughput to <1% (and never be worse —
        // it maximizes exactly that quantity), and the published kernel
        // must be feasible. (Exact argmax recovery is not possible: the
        // paper tie-broke on *measured* hardware efficiency.)
        let table1 = [
            (Xdna, I8I8, (64, 232, 64)),
            (Xdna, I8I16, (64, 216, 64)),
            (Xdna, I8I32, (48, 280, 48)),
            (Xdna, Bf16, (64, 104, 64)),
            (Xdna2, I8I8, (64, 232, 64)),
            (Xdna2, I8I16, (64, 216, 64)),
            (Xdna2, I8I32, (48, 280, 48)),
            (Xdna2, Bf16, (48, 152, 48)),
        ];
        for (gen, p, (m, k, n)) in table1 {
            let paper = KernelTile::new(m, k, n);
            assert!(paper.l1_bytes(p, false) <= gen.spec().l1_budget());
            let paper_mpc = core::macs_per_cycle(gen, p, &paper);
            let sols = solve_single_core(gen, p, &IpOptions::default(), 1);
            let got = &sols[0];
            assert!(
                got.macs_per_cycle >= paper_mpc * 0.999,
                "{gen}/{p}: winner {:?} slower than the paper's kernel",
                got.tile
            );
            // Upper bound is looser: sub-64 tiles are where the linear-β
            // fit is least trustworthy, and the paper's tie-break was a
            // hardware measurement we can't see.
            assert!(
                got.macs_per_cycle <= paper_mpc * 1.035,
                "{gen}/{p}: winner {:?} ({:.1}) suspiciously beats paper {:?} \
                 ({paper_mpc:.1}) — calibration drift",
                got.tile,
                got.macs_per_cycle,
                paper
            );
        }
    }

    #[test]
    fn winners_have_table1_shape() {
        // Qualitative Table-1 shape: compute-optimal kernels have large
        // k_ct and small, near-square m_ct x n_ct.
        for gen in [Xdna, Xdna2] {
            for p in [I8I8, I8I16, I8I32, Bf16] {
                let s = &solve_single_core(gen, p, &IpOptions::default(), 1)[0];
                assert!(
                    s.tile.k_ct > s.tile.m_ct && s.tile.k_ct > s.tile.n_ct,
                    "{gen}/{p}: {:?} not reduction-deep",
                    s.tile
                );
                assert!(s.l1_bytes as f64 >= 0.90 * gen.spec().l1_budget() as f64);
            }
        }
    }

    #[test]
    fn eq4_prunes_dma_bound_kernels() {
        // A kernel with tiny n is DMA-bound on A (Eq. 4) and must be
        // rejected: n=8 gives C_comp ~ m·k·8/256 << m·k/4.
        let sols = solve_single_core(Xdna, I8I8, &IpOptions::default(), 10_000);
        assert!(sols.iter().all(|s| s.tile.n_ct >= 32));
        assert!(sols.iter().all(|s| s.tile.m_ct >= 32));
    }

    #[test]
    fn fixed_kct_objective_maximizes_output_tile() {
        let opts = IpOptions {
            objective: IpObjective::MaxOutputTile { k_ct: 72 },
            ..Default::default()
        };
        let sols = solve_single_core(Xdna2, I8I16, &opts, 5);
        assert!(!sols.is_empty());
        let best = &sols[0];
        assert_eq!(best.tile.k_ct, 72);
        // Known optimum of max m·n under 144(m+n) + 2mn <= 64512 on the
        // (4, 8) grid: 120x120 (paper shipped the nearby 128x112 based on
        // measured eff; both are within 0.5% of each other's product).
        assert!(best.out_elems >= 14_336, "{:?}", best.tile);
        // All returned solutions satisfy L1.
        for s in &sols {
            assert!(s.l1_bytes <= Xdna2.spec().l1_budget());
        }
    }

    #[test]
    fn double_buffered_c_shrinks_winners() {
        // Ablation A3: with 2x C buffers the feasible kernels are smaller.
        let single = solve_single_core(Xdna2, I8I16, &IpOptions::default(), 1);
        let dbl = solve_single_core(
            Xdna2,
            I8I16,
            &IpOptions { c_double_buffered: true, ..Default::default() },
            1,
        );
        assert!(dbl[0].macs < single[0].macs);
    }

    #[test]
    fn search_is_fast_enough() {
        // Paper: "the exhaustive search takes less than 1 s in all cases".
        let t0 = std::time::Instant::now();
        for gen in crate::arch::Generation::ALL {
            for p in crate::dtype::Precision::ALL {
                solve_single_core(gen, p, &IpOptions::default(), 2);
            }
        }
        assert!(t0.elapsed().as_secs_f64() < 1.0, "{:?}", t0.elapsed());
    }
}
