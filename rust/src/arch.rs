//! NPU architecture descriptions for the two Ryzen AI generations (Sec. 3).
//!
//! Facts sourced from the paper and its references ([4, 24, 51]):
//! XDNA (Phoenix Point): 4×5 CompTile array (20 cores), 1.0 GHz max;
//! XDNA2 (Krackan Point): 4×8 array (32 cores), 1.8 GHz max. Both have
//! 64 KB L1 per CompTile and 512 KB per MemTile, 2+2 DMA channels on
//! Comp/Shim tiles, 6+6 on MemTiles, 16 BDs per ShimTile.
//!
//! The paper maps GEMM on a 4×4 sub-array of XDNA (no ShimTile under the
//! last column) and the full 4×8 of XDNA2 (Sec. 4.2.1).

use crate::dtype::{Layout, Precision};
use crate::tiling::TilingConfig;

/// NPU generation.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum Generation {
    Xdna,
    Xdna2,
}

impl Generation {
    pub const ALL: [Generation; 2] = [Generation::Xdna, Generation::Xdna2];

    pub fn name(self) -> &'static str {
        match self {
            Generation::Xdna => "xdna",
            Generation::Xdna2 => "xdna2",
        }
    }

    pub fn parse(s: &str) -> Option<Generation> {
        match s.to_ascii_lowercase().as_str() {
            "xdna" | "phoenix" | "xdna1" => Some(Generation::Xdna),
            "xdna2" | "krackan" => Some(Generation::Xdna2),
            _ => None,
        }
    }

    pub fn spec(self) -> &'static NpuSpec {
        match self {
            Generation::Xdna => &XDNA,
            Generation::Xdna2 => &XDNA2,
        }
    }
}

impl std::fmt::Display for Generation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Static description of one NPU generation.
#[derive(Clone, Debug)]
pub struct NpuSpec {
    pub gen: Generation,
    /// Physical CompTile array: rows × columns.
    pub array_rows: usize,
    pub array_cols: usize,
    /// Columns with a ShimTile (XDNA's last column has none, Sec. 4.2.1),
    /// i.e. the columns usable for the paper's symmetric mapping.
    pub shim_cols: usize,
    /// L1 bytes per CompTile (1 KB reserved for stack — Eq. 5 uses 63 KB).
    pub l1_bytes: usize,
    pub l1_reserved_bytes: usize,
    /// L2 bytes per MemTile.
    pub l2_bytes_per_tile: usize,
    /// MM2S + S2MM DMA channels per tile kind.
    pub comptile_channels: (usize, usize),
    pub memtile_channels: (usize, usize),
    pub shimtile_channels: (usize, usize),
    /// Buffer descriptors available per ShimTile (Sec. 4.4).
    pub shim_bds: usize,
    /// Max tensor-addressing dims per tile DMA (Sec. 3.2).
    pub comptile_addr_dims: usize,
    pub memtile_addr_dims: usize,
    pub shimtile_addr_dims: usize,
    /// Max clock in turbo mode (Hz).
    pub clock_hz: f64,
    /// Full-design reconfiguration latency (Sec. 5.3.1), seconds.
    pub reconfig_s: f64,
    /// Whether MemTiles may spill buffers into a neighbouring MemTile
    /// (XDNA2 mapping exploits this, Sec. 4.2.2).
    pub neighbor_memtile_sharing: bool,
    /// DMA bandwidth per channel between adjacent memory levels, in bytes
    /// per core-cycle (stream switches move 32 bits/cycle; AIE-ML L1/L2
    /// interfaces sustain 4 B/cycle per channel).
    pub dma_bytes_per_cycle: f64,
}

impl NpuSpec {
    /// Cores used by the paper's GEMM mapping (`m_rows * n_cols`).
    pub fn mapped_cores(&self) -> usize {
        self.array_rows * self.shim_cols
    }

    /// All physical cores.
    pub fn total_cores(&self) -> usize {
        self.array_rows * self.array_cols
    }

    /// Usable L1 for GEMM buffers (Eq. 5's 63 KB).
    pub fn l1_budget(&self) -> usize {
        self.l1_bytes - self.l1_reserved_bytes
    }

    /// Peak MACs/cycle for one core at a precision.
    ///
    /// XDNA advertises 10 TOPS int8 at 1.0 GHz over 20 cores →
    /// 256 MACs/cycle/core; XDNA2 doubles the int8 datapath (50 TOPS class,
    /// 1.8 GHz, 32 cores → 512). bf16 runs at half the int8 rate on XDNA;
    /// on XDNA2 the bf16-on-bfp16 emulation reaches ~192 MACs/cycle
    /// effective (Sec. 5.1, Table 1 fits; see DESIGN.md §5.1). The
    /// int8→int32 mode pays a wider output shuffle (Table 1: 192/384
    /// MACs/cycle ceilings → effective peak 224/448). *Native* bfp16 runs
    /// XDNA2's block datapath at the full int8-class 512 (Sec. 5.3.4 —
    /// the whole motivation for the DESIGN.md §10 path); XDNA has no
    /// bfp16 datapath, so it executes bfp16 operands by decoding blocks
    /// to bf16 in-core at the bf16 rate (keeps heterogeneous fleets
    /// total: any request runs anywhere, natively fast only on XDNA2).
    pub fn peak_macs_per_cycle(&self, p: Precision) -> f64 {
        match (self.gen, p) {
            (Generation::Xdna, Precision::I8I8) => 256.0,
            (Generation::Xdna, Precision::I8I16) => 256.0,
            (Generation::Xdna, Precision::I8I32) => 224.0,
            (Generation::Xdna, Precision::Bf16) => 128.0,
            (Generation::Xdna, Precision::Bfp16) => 128.0,
            (Generation::Xdna2, Precision::I8I8) => 512.0,
            (Generation::Xdna2, Precision::I8I16) => 512.0,
            (Generation::Xdna2, Precision::I8I32) => 448.0,
            (Generation::Xdna2, Precision::Bf16) => 192.0,
            (Generation::Xdna2, Precision::Bfp16) => 512.0,
            // Logical fp32_split executes as bf16 limb GEMMs, so its
            // per-dispatch peak is the bf16 rate; the 3× dispatch count
            // is charged where dispatches are counted (assign / plan /
            // partition), not here.
            (Generation::Xdna, Precision::Fp32Split) => 128.0,
            (Generation::Xdna2, Precision::Fp32Split) => 192.0,
        }
    }

    /// Theoretical peak of the *mapped* array in TOPS at max clock
    /// (`peak_TOPS` in Eq. 9): `2 * cores * MACs/cycle * f`.
    pub fn peak_tops(&self, p: Precision) -> f64 {
        2.0 * self.mapped_cores() as f64 * self.peak_macs_per_cycle(p) * self.clock_hz / 1e12
    }

    /// Total L2 capacity across the mapped MemTiles.
    pub fn l2_total(&self) -> usize {
        self.shim_cols * self.l2_bytes_per_tile
    }
}

/// XDNA (Ryzen 9 7940HS, Minisforum UM790 Pro).
pub static XDNA: NpuSpec = NpuSpec {
    gen: Generation::Xdna,
    array_rows: 4,
    array_cols: 5,
    shim_cols: 4,
    l1_bytes: 64 * 1024,
    l1_reserved_bytes: 1024,
    l2_bytes_per_tile: 512 * 1024,
    comptile_channels: (2, 2),
    memtile_channels: (6, 6),
    shimtile_channels: (2, 2),
    shim_bds: 16,
    comptile_addr_dims: 3,
    memtile_addr_dims: 4,
    shimtile_addr_dims: 3,
    clock_hz: 1.0e9,
    reconfig_s: 3.4e-3,
    neighbor_memtile_sharing: false,
    dma_bytes_per_cycle: 4.0,
};

/// XDNA2 (Ryzen AI 7 350, ASRock 4x4 BOX-AI350).
pub static XDNA2: NpuSpec = NpuSpec {
    gen: Generation::Xdna2,
    array_rows: 4,
    array_cols: 8,
    shim_cols: 8,
    l1_bytes: 64 * 1024,
    l1_reserved_bytes: 1024,
    l2_bytes_per_tile: 512 * 1024,
    comptile_channels: (2, 2),
    memtile_channels: (6, 6),
    shimtile_channels: (2, 2),
    shim_bds: 16,
    comptile_addr_dims: 3,
    memtile_addr_dims: 4,
    shimtile_addr_dims: 3,
    clock_hz: 1.8e9,
    reconfig_s: 4.9e-3,
    neighbor_memtile_sharing: true,
    // XDNA2 doubles the per-core datapath; its L1 DMA interfaces must be
    // 8 B/cycle — at 4 B/cycle the Table-1 kernels (n_ct = 64 at 450.6
    // MACs/cycle) would violate Eq. 4, contradicting the paper's own
    // hardware measurements.
    dma_bytes_per_cycle: 8.0,
};

/// The paper's optimal *balanced* configurations (Tables 2 & 3 bold rows +
/// the `k_mt` choices of Sec. 5.2.2). These are also what
/// `optimizer::balanced` re-derives and what `python/compile/configs.py`
/// ships as AOT artifacts (consistency checked in `rust/tests/manifest.rs`).
///
/// The bfp16 rows have no paper counterpart (native bfp16 is the
/// Sec. 5.3.4 future work this crate implements): they are this repo's
/// own balanced-search winners under the calibrated simulator, validated
/// by `optimizer::balanced` tests and the `bfp16_vs_bf16` bench.
pub fn balanced_config(gen: Generation, p: Precision) -> TilingConfig {
    // fp32_split has no schedule of its own (`TilingConfig::validate`
    // rejects it): it executes as bf16 limb GEMMs, so its balanced
    // design *is* the bf16 design.
    let p = if p == Precision::Fp32Split { Precision::Bf16 } else { p };
    let (m_ct, k_ct, n_ct, k_mt) = match (gen, p) {
        (Generation::Xdna, Precision::I8I8) => (112, 112, 112, 448),
        (Generation::Xdna, Precision::I8I16) => (96, 112, 96, 448),
        (Generation::Xdna, Precision::I8I32) => (80, 88, 96, 352),
        (Generation::Xdna, Precision::Bf16) => (96, 56, 96, 224),
        (Generation::Xdna, Precision::Bfp16) => (100, 104, 72, 312),
        (Generation::Xdna2, Precision::I8I8) => (144, 72, 144, 432),
        (Generation::Xdna2, Precision::I8I16) => (128, 72, 112, 432),
        (Generation::Xdna2, Precision::I8I32) => (96, 64, 96, 384),
        (Generation::Xdna2, Precision::Bf16) => (112, 48, 96, 384),
        (Generation::Xdna2, Precision::Bfp16) => (140, 40, 144, 440),
        (_, Precision::Fp32Split) => unreachable!("remapped to bf16 above"),
    };
    let spec = gen.spec();
    TilingConfig::new(
        gen,
        p,
        m_ct,
        k_ct,
        n_ct,
        k_mt,
        spec.array_rows,
        spec.shim_cols,
        Layout::ColMajor,
    )
    .expect("paper configs are valid")
}

/// The largest problem-M the skinny design class targets: coalesced
/// decode batches of up to 64 tokens (ISSUE 7). Shapes with `m` at or
/// below this route to [`skinny_balanced_config`]-derived designs; the
/// router's [`crate::coordinator::DesignKey`] keys on the class.
pub const SKINNY_M_MAX: usize = 64;

/// Dedicated skinny-M balanced configurations for coalesced decode
/// batches (M ≈ 8–64). The paper's balanced points assume M is large —
/// e.g. the XDNA2 int8 design's native M is 144·4 = 576, so an M=33
/// decode batch pads 17×. These designs fix the kernel M-tile at 16
/// (native M = 16·4 = 64, one `SKINNY_M_MAX` block) and keep the wide
/// design's K/N kernel shape and `k_mt`, which stays valid by strict
/// monotonicity: shrinking `m_ct` only shrinks the A/C L1 buffers and
/// the A/C L2 footprints against an already-valid point.
///
/// Note these kernels are *inherently* DMA-bound — Eq. 4 needs
/// `m_ct ≳ 56` on XDNA2 int8 to cover the B stream — so unlike the wide
/// table there is no compute-bound balanced point to find; the skinny
/// search (`optimizer::optimize_skinny`) confirms the landscape is flat
/// (B traffic dominates at M ≤ 64) and these picks sit on its plateau.
pub fn skinny_balanced_config(gen: Generation, p: Precision) -> TilingConfig {
    // Same remap as `balanced_config`: the logical fp32_split precision
    // schedules as bf16.
    let p = if p == Precision::Fp32Split { Precision::Bf16 } else { p };
    let wide = balanced_config(gen, p);
    let spec = gen.spec();
    TilingConfig::new(
        gen,
        p,
        16,
        wide.kernel.k_ct,
        wide.kernel.n_ct,
        wide.k_mt,
        spec.array_rows,
        spec.shim_cols,
        Layout::ColMajor,
    )
    .expect("skinny configs shrink a valid wide config")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        assert_eq!(XDNA.total_cores(), 20);
        assert_eq!(XDNA.mapped_cores(), 16);
        assert_eq!(XDNA2.total_cores(), 32);
        assert_eq!(XDNA2.mapped_cores(), 32);
    }

    #[test]
    fn peak_tops_match_paper_class() {
        // XDNA ~10 TOPS int8 over all 20 cores; our mapped 16 cores → 8.19.
        let t = XDNA.peak_tops(Precision::I8I8);
        assert!((8.0..8.4).contains(&t), "{t}");
        // XDNA2: 2*32*512*1.8e9 = 59 TOPS class (50 TOPS marketing at
        // nominal clocks).
        let t2 = XDNA2.peak_tops(Precision::I8I8);
        assert!((58.0..60.0).contains(&t2), "{t2}");
    }

    #[test]
    fn table_kernel_peaks_consistent_with_measurements() {
        // Table 1 measured MACs/cycle must not exceed the modeled peaks.
        assert!(233.0 <= XDNA.peak_macs_per_cycle(Precision::I8I8));
        assert!(217.6 <= XDNA.peak_macs_per_cycle(Precision::I8I16));
        assert!(192.0 <= XDNA.peak_macs_per_cycle(Precision::I8I32));
        assert!(112.6 <= XDNA.peak_macs_per_cycle(Precision::Bf16));
        assert!(450.6 <= XDNA2.peak_macs_per_cycle(Precision::I8I8));
        assert!(419.8 <= XDNA2.peak_macs_per_cycle(Precision::I8I16));
        assert!(384.0 <= XDNA2.peak_macs_per_cycle(Precision::I8I32));
        assert!(158.1 <= XDNA2.peak_macs_per_cycle(Precision::Bf16));
    }

    #[test]
    fn balanced_configs_valid_for_all() {
        for gen in Generation::ALL {
            for p in Precision::ALL_EXTENDED {
                let cfg = balanced_config(gen, p);
                assert_eq!(cfg.m_rows, 4);
                assert_eq!(cfg.n_cols, gen.spec().shim_cols);
            }
        }
    }

    #[test]
    fn fp32_split_maps_to_the_bf16_design() {
        // The logical precision must never own a schedule: both config
        // constructors hand back the bf16 design, and the per-dispatch
        // peak is the bf16 rate on both generations.
        for gen in Generation::ALL {
            let split = balanced_config(gen, Precision::Fp32Split);
            let bf16 = balanced_config(gen, Precision::Bf16);
            assert_eq!(split.precision, Precision::Bf16);
            assert_eq!(split.label(), bf16.label());
            let skinny = skinny_balanced_config(gen, Precision::Fp32Split);
            assert_eq!(skinny.precision, Precision::Bf16);
            assert_eq!(
                gen.spec().peak_macs_per_cycle(Precision::Fp32Split),
                gen.spec().peak_macs_per_cycle(Precision::Bf16)
            );
        }
    }

    #[test]
    fn skinny_configs_valid_and_one_block_covers_the_class() {
        for gen in Generation::ALL {
            for p in Precision::ALL_EXTENDED {
                let cfg = skinny_balanced_config(gen, p);
                let (nm, _, _) = cfg.native();
                assert_eq!(nm, SKINNY_M_MAX, "{gen} {p:?}: native M is one skinny block");
                // The whole point: a decode batch pads dramatically less
                // than under the wide design.
                let wide = balanced_config(gen, p);
                for m in [8, 33, 64] {
                    let skinny_eff = cfg.padding_efficiency(m, 768, 768);
                    let wide_eff = wide.padding_efficiency(m, 768, 768);
                    assert!(
                        skinny_eff > 2.0 * wide_eff,
                        "{gen} {p:?} M={m}: skinny {skinny_eff:.3} vs wide {wide_eff:.3}"
                    );
                }
            }
        }
    }

    #[test]
    fn native_bfp16_runs_at_the_int8_class_rate() {
        // Table 1 / Sec. 5.3.4: XDNA2's datapath is bfp16-native — the
        // bf16 mode (158-192 MACs/cycle) is an *emulation* on it; the
        // native path hits the int8-class 512. XDNA has no bfp16
        // datapath and decodes to bf16 (128).
        assert_eq!(XDNA2.peak_macs_per_cycle(Precision::Bfp16), 512.0);
        assert_eq!(
            XDNA2.peak_macs_per_cycle(Precision::Bfp16),
            XDNA2.peak_macs_per_cycle(Precision::I8I8)
        );
        let bf16 = XDNA2.peak_macs_per_cycle(Precision::Bf16);
        assert!(XDNA2.peak_macs_per_cycle(Precision::Bfp16) > 2.0 * bf16);
        assert_eq!(
            XDNA.peak_macs_per_cycle(Precision::Bfp16),
            XDNA.peak_macs_per_cycle(Precision::Bf16)
        );
    }
}
