//! Functional graph execution: real bytes through the DAG (DESIGN.md §11).
//!
//! Three entry points, all over the same dataflow semantics:
//!
//! * [`execute_functional`] — every node through the packed executor
//!   ([`crate::gemm::exec::Executor`]), staging each producer's C into
//!   its consumers' A (cloned on fan-out, elementwise-rejoined via
//!   [`join_images`] on fan-in). Returns per-node C images.
//! * [`reference_results`] — the same dataflow through
//!   [`crate::gemm::refimpl::ref_gemm`]: the per-node differential
//!   oracle (`rust/tests/graph_e2e.rs`).
//! * [`serve_graph`] — the DAG through the PR-1 coordinator: lowered
//!   chains submitted in dependency order, pinned to the partitioner's
//!   devices (`Coordinator::submit_chain_staged`), staged tensors fed as
//!   each consumer chain's entry A. Chain tails are exactly the staged
//!   producers (a lowering invariant), so `ChainResponse::result` is the
//!   tensor the consumers need.
//!
//! Join semantics: the elementwise residual add in the producer's output
//! dtype — int8 with saturation (the `srs` step), bf16 with
//! round-to-nearest-even after each f32 add, left-fold in input order.
//! Deterministic, and shared verbatim by the executor and reference
//! paths, so the per-node differential isolates the GEMMs.

use std::sync::mpsc::Receiver;

use anyhow::{bail, ensure, Context, Result};

use crate::arch::{balanced_config, Generation};
use crate::coordinator::{
    functional_a, functional_b, ChainResponse, ChainStaging, Coordinator, DesignKey,
};
use crate::dtype::{sat_i8, Bf16, Layout, Precision};
use crate::gemm::abft;
use crate::gemm::exec::{ExecOptions, Executor};
use crate::gemm::refimpl;
use crate::mem::Matrix;
use crate::trace::TraceFact;

use super::ir::ModelGraph;
use super::lower::Lowered;
use super::partition::Partition;

/// Elementwise rejoin of equal-shaped row-major C images in `p`'s output
/// dtype: left-fold add with int8 saturation / bf16 rounding per step.
pub fn join_images(parts: &[Matrix], p: Precision) -> Result<Matrix> {
    ensure!(!parts.is_empty(), "empty join");
    let (rows, cols) = (parts[0].rows, parts[0].cols);
    for m in parts {
        ensure!(m.layout == Layout::RowMajor, "join operands must be row-major C images");
        ensure!((m.rows, m.cols) == (rows, cols), "join shape mismatch");
    }
    let mut acc = parts[0].clone();
    match p {
        Precision::I8I8 => {
            for m in &parts[1..] {
                for i in 0..rows {
                    for j in 0..cols {
                        acc.set_i8(i, j, sat_i8(acc.get_i8(i, j) as i32 + m.get_i8(i, j) as i32));
                    }
                }
            }
        }
        Precision::Bf16 => {
            for m in &parts[1..] {
                for i in 0..rows {
                    for j in 0..cols {
                        let v = acc.get_bf16(i, j).to_f32() + m.get_bf16(i, j).to_f32();
                        acc.set_bf16(i, j, Bf16::from_f32(v));
                    }
                }
            }
        }
        // fp32_split Cs are f32 images; their rejoin is the plain f32
        // add (no narrowing step — DESIGN.md §15).
        Precision::Fp32Split => {
            for m in parts {
                ensure!(m.elem_bytes == 4, "fp32_split join needs f32 images");
            }
            for m in &parts[1..] {
                for i in 0..rows {
                    for j in 0..cols {
                        acc.set_f32(i, j, acc.get_f32(i, j) + m.get_f32(i, j));
                    }
                }
            }
        }
        _ => bail!("{p} images have no elementwise rejoin"),
    }
    Ok(acc)
}

/// Resolve node `id`'s A image from the already-computed producer Cs
/// (`results[..id]` must be filled for its inputs).
fn staged_a(g: &ModelGraph, results: &[Matrix], id: usize) -> Result<Option<Matrix>> {
    let node = g.node(id);
    Ok(match node.inputs.len() {
        0 => None,
        1 => Some(results[node.inputs[0]].clone()),
        _ => {
            let parts: Vec<Matrix> =
                node.inputs.iter().map(|&p| results[p].clone()).collect();
            let jp = g.node(node.inputs[0]).shape.precision;
            Some(join_images(&parts, jp)?)
        }
    })
}

fn node_design(gen: Generation, shape: &crate::workload::GemmShape) -> crate::tiling::TilingConfig {
    let key = DesignKey::for_shape(shape);
    balanced_config(gen, key.precision).with_b_layout(key.b_layout)
}

/// Execute the whole DAG through the packed executor on one generation's
/// balanced designs. Deterministic inputs per node
/// ([`functional_a`]/[`functional_b`] — the coordinator's generators),
/// bit-identical for every `threads` value.
pub fn execute_functional(
    g: &ModelGraph,
    gen: Generation,
    threads: usize,
) -> Result<Vec<Matrix>> {
    let mut results: Vec<Matrix> = Vec::with_capacity(g.len());
    for id in 0..g.len() {
        let node = g.node(id);
        // Logical fp32_split ops never enter the packed executor: the
        // limb GEMMs + f32 rejoin run through dtype_split (same per-row
        // kernel as the coordinator path, bit-exact at every thread
        // count). Operands are generated at the *logical* precision —
        // f32 images — not the normalized bf16 design's.
        if node.shape.precision == Precision::Fp32Split {
            let a = match staged_a(g, &results, id)? {
                Some(a) => a,
                None => functional_a(&node.shape, Precision::Fp32Split)?,
            };
            let b = functional_b(&node.shape, Precision::Fp32Split)?;
            let c = crate::dtype_split::split_exec(&a, &b, threads)
                .with_context(|| format!("node '{}'", node.shape.name))?;
            results.push(c);
            continue;
        }
        let cfg = node_design(gen, &node.shape);
        let exec = Executor::with_options(cfg, ExecOptions { threads, ..Default::default() });
        let a = match staged_a(g, &results, id)? {
            Some(a) => a,
            None => functional_a(&node.shape, cfg.precision)?,
        };
        let b = functional_b(&node.shape, cfg.precision)?;
        let c = exec
            .execute(&a, &b)
            .with_context(|| format!("node '{}'", node.shape.name))?;
        results.push(c);
    }
    Ok(results)
}

/// The per-node oracle: the same dataflow with every GEMM through
/// [`refimpl::ref_gemm`].
pub fn reference_results(g: &ModelGraph) -> Result<Vec<Matrix>> {
    let mut results: Vec<Matrix> = Vec::with_capacity(g.len());
    for id in 0..g.len() {
        let node = g.node(id);
        let p = node.shape.precision;
        let a = match staged_a(g, &results, id)? {
            Some(a) => a,
            None => functional_a(&node.shape, p)?,
        };
        let b = functional_b(&node.shape, p)?;
        let c = refimpl::ref_gemm(&a, &b, p)
            .with_context(|| format!("node '{}'", node.shape.name))?;
        results.push(c);
    }
    Ok(results)
}

/// Drive the lowered, partitioned DAG through a running [`Coordinator`]:
/// chains submitted in the partitioner's (dependency-respecting)
/// schedule order, each pinned to its assigned device. Submission is
/// eager and receiving lazy — a chain waits only for the producers
/// whose staged C it actually needs, so independent chains on different
/// devices overlap on the fleet (q/k fill one leader while the
/// critical-path chain runs on another). When `functional` is set,
/// every staged edge feeds the producer chain's functional C into the
/// consumer chain's entry A. Returns the chain responses in chain-index
/// order.
pub fn serve_graph(
    coord: &Coordinator,
    g: &ModelGraph,
    lowered: &Lowered,
    part: &Partition,
    functional: bool,
) -> Result<Vec<ChainResponse>> {
    ensure!(part.device_of.len() == lowered.chains.len(), "partition/lowering mismatch");
    let mut responses: Vec<Option<ChainResponse>> = Vec::new();
    responses.resize_with(lowered.chains.len(), || None);
    // In-flight receivers in submission order; schedule order respects
    // dependencies, so a producer is always submitted (and therefore in
    // this queue or already resolved) before its consumer needs it.
    let mut pending: std::collections::VecDeque<(usize, Receiver<ChainResponse>)> =
        std::collections::VecDeque::new();
    for sc in &part.schedule {
        let ci = sc.chain;
        let head = lowered.chain_head(ci);
        let producers = &g.node(head).inputs;
        let a0 = if functional && !producers.is_empty() {
            let mut parts = Vec::with_capacity(producers.len());
            for &p in producers {
                let pc = lowered.node_pos[p].0;
                while responses[pc].is_none() {
                    let (rc, rx) =
                        pending.pop_front().expect("producer submitted before its consumer");
                    let resp =
                        rx.recv().map_err(|e| anyhow::anyhow!("coordinator dropped: {e}"))?;
                    responses[rc] = Some(resp);
                }
                let c = responses[pc]
                    .as_ref()
                    .and_then(|r| r.result.as_ref())
                    .with_context(|| {
                        format!(
                            "chain '{}' produced no functional result for node '{}'",
                            lowered.chains[pc].name,
                            g.node(p).shape.name
                        )
                    })?;
                parts.push(c.clone());
            }
            if parts.len() == 1 {
                Some(parts.pop().expect("one part"))
            } else {
                Some(join_images(&parts, g.node(producers[0]).shape.precision)?)
            }
        } else {
            None
        };
        // Checksum the staged edge at the producer side: the consuming
        // leader re-validates the image before executing on it, so a
        // cross-chain tensor corrupted in transit is detected at the
        // edge instead of silently feeding the downstream chain.
        let a0_sums = a0.as_ref().map(abft::capture);
        let rx = coord.submit_chain_staged(
            lowered.chains[ci].clone(),
            ChainStaging { device: Some(sc.device), a0, a0_sums },
        )?;
        pending.push_back((ci, rx));
    }
    for (ci, rx) in pending {
        let resp = rx.recv().map_err(|e| anyhow::anyhow!("coordinator dropped: {e}"))?;
        responses[ci] = Some(resp);
    }
    let responses: Vec<ChainResponse> =
        responses.into_iter().map(|r| r.expect("every chain scheduled")).collect();
    // Chains that consumed a staged cross-chain edge leave an instant
    // on the trace's fault/annotation lane (chain-index order, so the
    // fact log is deterministic regardless of completion order).
    for resp in &responses {
        if resp.staged_edges > 0 {
            coord.recorder().with(|| TraceFact::Stage {
                unit: resp.id,
                device: resp.device,
                edges: resp.staged_edges,
            });
        }
    }
    Ok(responses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_join_saturates_and_folds_left() {
        let mut a = Matrix::zeroed(4, 4, 1, Layout::RowMajor).unwrap();
        let mut b = Matrix::zeroed(4, 4, 1, Layout::RowMajor).unwrap();
        a.set_i8(0, 0, 100);
        b.set_i8(0, 0, 100);
        a.set_i8(1, 1, -100);
        b.set_i8(1, 1, -100);
        a.set_i8(2, 2, 3);
        b.set_i8(2, 2, -5);
        let j = join_images(&[a.clone(), b.clone()], Precision::I8I8).unwrap();
        assert_eq!(j.get_i8(0, 0), 127, "saturates up");
        assert_eq!(j.get_i8(1, 1), -128, "saturates down");
        assert_eq!(j.get_i8(2, 2), -2);
        // Three-way fold saturates stepwise (left fold, not wide sum).
        let j3 = join_images(&[a.clone(), b, a], Precision::I8I8).unwrap();
        assert_eq!(j3.get_i8(0, 0), 127);
    }

    #[test]
    fn bf16_join_rounds_each_step() {
        let mut a = Matrix::zeroed(4, 4, 2, Layout::RowMajor).unwrap();
        let mut b = Matrix::zeroed(4, 4, 2, Layout::RowMajor).unwrap();
        a.set_bf16(0, 0, Bf16::from_f32(1.5));
        b.set_bf16(0, 0, Bf16::from_f32(2.25));
        let j = join_images(&[a, b], Precision::Bf16).unwrap();
        assert_eq!(j.get_bf16(0, 0).to_f32(), Bf16::from_f32(3.75).to_f32());
    }

    #[test]
    fn join_rejects_blocks_and_mismatches() {
        let a = Matrix::zeroed(4, 4, 1, Layout::RowMajor).unwrap();
        let b = Matrix::zeroed(4, 8, 1, Layout::RowMajor).unwrap();
        assert!(join_images(&[a.clone(), b], Precision::I8I8).is_err());
        assert!(join_images(&[], Precision::I8I8).is_err());
        let blk = Matrix::zeroed_bfp16(4, 8, Layout::RowMajor).unwrap();
        assert!(join_images(&[blk.clone(), blk], Precision::Bfp16).is_err());
    }
}
